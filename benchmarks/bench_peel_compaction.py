"""Compaction-runtime benchmark: passes x edge-slots-scanned and wall-clock
for ``compaction in (off, twophase, geometric)`` on power-law graphs.

This is the repo's first tracked perf-trajectory point for the peel hot
path: the geometric ladder's claim is that pass k scans O(m_k) edge slots
instead of O(m) (amortized O(m) total, the Lemma-4 shrink made operational),
with bit-identical results.  Run with::

    PYTHONPATH=src python -m benchmarks.bench_peel_compaction [--n 200000]

Writes experiments/bench/BENCH_peel.json with, per eps:
  * per-mode passes, total edge slots scanned, warm wall-clock (jit
    substrate; ladder programs pre-compiled, min over repeats),
  * slots/wall reduction factors vs 'off',
  * a bit-identity flag (best_alive/best_density/passes equal across modes).
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

import jax

from repro.core import Problem, Solver
from repro.graph.generators import chung_lu_power_law


def _timed(fn, repeats: int):
    out = fn()  # warm: compiles every ladder rung once
    jax.block_until_ready(out.best_alive)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out.best_alive)
        best = min(best, time.perf_counter() - t0)
    return best, out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=200_000)
    ap.add_argument("--avg-deg", type=float, default=10.0)
    ap.add_argument("--exponent", type=float, default=2.0)
    ap.add_argument("--eps", type=float, nargs="+", default=[0.1, 0.5])
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument(
        "--out", default=os.path.join("experiments", "bench", "BENCH_peel.json")
    )
    args = ap.parse_args(argv)

    edges = chung_lu_power_law(
        args.n, exponent=args.exponent, avg_deg=args.avg_deg, seed=0
    )
    m_pad = edges.n_edges_padded
    report = {
        "graph": {
            "family": "chung_lu_power_law",
            "n_nodes": args.n,
            "n_edges": int(edges.num_real_edges()),
            "n_edges_padded": m_pad,
            "exponent": args.exponent,
            "avg_deg": args.avg_deg,
        },
        "backend": "exact",
        "substrate": "jit",
        "platform": jax.default_backend(),
        "eps": {},
    }

    for eps in args.eps:
        solver = Solver()
        rows = {}
        ref = None
        for mode in ("off", "twophase", "geometric"):
            prob = Problem.undirected(eps=eps, compaction=mode)
            wall, res = _timed(lambda p=prob: solver.solve(edges, p), args.repeats)
            passes = int(res.passes)
            if mode == "off":
                slots = passes * m_pad
                segments = 1
            else:
                lad = res.extras["compaction"]
                slots = int(lad["edge_slots_scanned"])
                segments = len(lad["segments"])
            if ref is None:
                ref = res
                identical = True
            else:
                identical = (
                    np.array_equal(
                        np.asarray(res.best_alive), np.asarray(ref.best_alive)
                    )
                    and float(res.best_density) == float(ref.best_density)
                    and int(res.passes) == int(ref.passes)
                )
            rows[mode] = {
                "passes": passes,
                "segments": segments,
                "edge_slots_scanned": slots,
                "wall_s": round(wall, 4),
                "rho": round(float(res.best_density), 4),
                "bit_identical_to_off": identical,
            }
            print(f"eps={eps} {mode}: {rows[mode]}")
        off = rows["off"]
        for mode in ("twophase", "geometric"):
            rows[mode]["slots_reduction_x"] = round(
                off["edge_slots_scanned"] / max(rows[mode]["edge_slots_scanned"], 1), 2
            )
            rows[mode]["wall_speedup_x"] = round(
                off["wall_s"] / max(rows[mode]["wall_s"], 1e-9), 2
            )
        report["eps"][str(eps)] = rows

    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
