"""Compaction-runtime benchmark: passes x edge-slots-scanned and wall-clock
for ``compaction in (off, twophase, geometric)`` on power-law graphs, plus
the MESH substrate's two geometric schedules (host gather/reshard ladder vs
the single-program collective-only ladder).

This is the repo's tracked perf-trajectory point for the peel hot path:
the geometric ladder's claim is that pass k scans O(m_k) edge slots
instead of O(m) (amortized O(m) total, the Lemma-4 shrink made operational),
with bit-identical results; the mesh-ladder claim (PR 5) is that the whole
schedule runs as ONE compiled ``shard_map`` program — zero host round-trips
between rungs — at no wall-clock regression vs the host ladder it replaces.
Run with::

    PYTHONPATH=src python -m benchmarks.bench_peel_compaction [--n 200000]

Writes experiments/bench/BENCH_peel.json with, per eps:
  * per-mode passes, total edge slots scanned, warm wall-clock (jit
    substrate; ladder programs pre-compiled, min over repeats),
  * slots/wall reduction factors vs 'off',
  * a bit-identity flag (best_alive/best_density/passes equal across modes),
  * a ``mesh`` block: host-ladder vs single-program ladder host_round_trips,
    wall, and bit-identity (1-device mesh unless more devices are visible).
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

import jax
from jax.sharding import Mesh

from repro.core import Problem, Solver
from repro.graph.generators import chung_lu_power_law


def _timed(fn, repeats: int):
    out = fn()  # warm: compiles every ladder rung once
    jax.block_until_ready(out.best_alive)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out.best_alive)
        best = min(best, time.perf_counter() - t0)
    return best, out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=200_000)
    ap.add_argument("--avg-deg", type=float, default=10.0)
    ap.add_argument("--exponent", type=float, default=2.0)
    ap.add_argument("--eps", type=float, nargs="+", default=[0.1, 0.5])
    # 5 repeats since the mesh-ladder entry landed: the host-ladder vs
    # single-program comparison sits within run-to-run noise at 3.
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument(
        "--out", default=os.path.join("experiments", "bench", "BENCH_peel.json")
    )
    args = ap.parse_args(argv)

    edges = chung_lu_power_law(
        args.n, exponent=args.exponent, avg_deg=args.avg_deg, seed=0
    )
    m_pad = edges.n_edges_padded
    report = {
        "graph": {
            "family": "chung_lu_power_law",
            "n_nodes": args.n,
            "n_edges": int(edges.num_real_edges()),
            "n_edges_padded": m_pad,
            "exponent": args.exponent,
            "avg_deg": args.avg_deg,
        },
        "backend": "exact",
        "substrate": "jit",
        "platform": jax.default_backend(),
        "eps": {},
    }

    for eps in args.eps:
        solver = Solver()
        rows = {}
        ref = None
        for mode in ("off", "twophase", "geometric"):
            prob = Problem.undirected(eps=eps, compaction=mode)
            wall, res = _timed(lambda p=prob: solver.solve(edges, p), args.repeats)
            passes = int(res.passes)
            if mode == "off":
                slots = passes * m_pad
                segments = 1
            else:
                lad = res.extras["compaction"]
                slots = int(lad["edge_slots_scanned"])
                segments = len(lad["segments"])
            if ref is None:
                ref = res
                identical = True
            else:
                identical = (
                    np.array_equal(
                        np.asarray(res.best_alive), np.asarray(ref.best_alive)
                    )
                    and float(res.best_density) == float(ref.best_density)
                    and int(res.passes) == int(ref.passes)
                )
            rows[mode] = {
                "passes": passes,
                "segments": segments,
                "edge_slots_scanned": slots,
                "wall_s": round(wall, 4),
                "rho": round(float(res.best_density), 4),
                "bit_identical_to_off": identical,
            }
            print(f"eps={eps} {mode}: {rows[mode]}")
        off = rows["off"]
        for mode in ("twophase", "geometric"):
            rows[mode]["slots_reduction_x"] = round(
                off["edge_slots_scanned"] / max(rows[mode]["edge_slots_scanned"], 1), 2
            )
            rows[mode]["wall_speedup_x"] = round(
                off["wall_s"] / max(rows[mode]["wall_s"], 1e-9), 2
            )

        # ---- mesh substrate: host gather/reshard ladder vs the single-
        # program collective-only ladder that replaced it (PR 5) ----
        devs = jax.devices()
        mesh = Mesh(np.asarray(devs).reshape(len(devs)), ("data",))
        prob_mesh = Problem.undirected(
            eps=eps, substrate="mesh", compaction="geometric"
        )
        resolved = prob_mesh.resolve(edges.n_nodes, have_mesh=True)

        def host_ladder():
            # _run_compacted is the retained host schedule (twophase's
            # machinery); invoking it directly is the replaced baseline.
            out, ladder, _ = solver._run_compacted(edges, resolved, mesh, None)
            host_ladder.ladder = ladder
            return out

        wall_host, out_host = _timed(host_ladder, args.repeats)
        wall_prog, res_prog = _timed(
            lambda: solver.solve(edges, prob_mesh, mesh=mesh), args.repeats
        )
        wall_moff, _ = _timed(
            lambda: solver.solve(
                edges,
                Problem.undirected(eps=eps, substrate="mesh", compaction="off"),
                mesh=mesh,
            ),
            args.repeats,
        )
        lad_prog = res_prog.extras["compaction"]
        mesh_identical = (
            np.array_equal(
                np.asarray(res_prog.best_alive), np.asarray(ref.best_alive)
            )
            and float(res_prog.best_density) == float(ref.best_density)
            and int(res_prog.passes) == int(ref.passes)
            and np.array_equal(
                np.asarray(out_host.best_alive), np.asarray(res_prog.best_alive)
            )
        )
        rows["mesh"] = {
            "n_devices": len(devs),
            "off": {"wall_s": round(wall_moff, 4)},
            "host_ladder": {
                "wall_s": round(wall_host, 4),
                "host_round_trips": host_ladder.ladder["host_round_trips"],
                "segments": len(host_ladder.ladder["segments"]),
            },
            "single_program_ladder": {
                "wall_s": round(wall_prog, 4),
                "host_round_trips": lad_prog["host_round_trips"],
                "segments": len(lad_prog["segments"]),
                "edge_slots_scanned": int(lad_prog["edge_slots_scanned"]),
            },
            "wall_vs_host_ladder_x": round(
                wall_host / max(wall_prog, 1e-9), 2
            ),
            "wall_vs_mesh_off_x": round(wall_moff / max(wall_prog, 1e-9), 2),
            "bit_identical_to_off": mesh_identical,
        }
        print(f"eps={eps} mesh: {rows['mesh']}")
        report["eps"][str(eps)] = rows

    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
