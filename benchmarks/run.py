"""Benchmark runner: ``python -m benchmarks.run [names...]``.

Runs every paper-table/figure benchmark, prints CSV blocks, and writes
experiments/bench/<name>.csv for EXPERIMENTS.md.
"""

from __future__ import annotations

import os
import sys
import time


def main(argv=None) -> int:
    argv = argv if argv is not None else sys.argv[1:]
    from benchmarks.paper_benches import ALL, _rows_to_csv

    names = [a for a in argv if not a.startswith("-")] or list(ALL)
    out_dir = os.path.join("experiments", "bench")
    os.makedirs(out_dir, exist_ok=True)
    failures = 0
    for name in names:
        fn = ALL[name]
        t0 = time.time()
        print(f"== {name} ==", flush=True)
        try:
            rows = fn()
            csv = _rows_to_csv(rows)
            print(csv)
            with open(os.path.join(out_dir, f"{name}.csv"), "w") as f:
                f.write(csv + "\n")
            print(f"-- {name}: {len(rows)} rows in {time.time()-t0:.1f}s\n", flush=True)
        except Exception as e:  # keep going; report at the end
            import traceback

            failures += 1
            print(f"-- {name} FAILED: {type(e).__name__}: {e}")
            traceback.print_exc()
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
