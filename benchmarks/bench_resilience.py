"""Resilience-layer benchmark: fault-free overhead + fault-storm behavior.

    PYTHONPATH=src python -m benchmarks.bench_resilience [--n 30000] [--queries 64]

Measures the PR's two acceptance numbers (ISSUE 8):

  * **fault-free overhead** — the bench_serve query stream answered by a
    plain engine vs an engine with a full ResilienceConfig (deadlines,
    retry budget, breaker, shedding, every degrade rung enabled) and NO
    FaultPlan installed.  Target: < 2% wall-clock overhead.  Also reports
    the raw cost of an uninstalled ``faults.fire`` hook (ns/call).
  * **fault storm** — the same stream under a seeded FaultPlan that fails
    a fraction of all ``serve.solve`` dispatches (primary solves, retries
    AND fallback solves alike).  Reports the outcome histogram, p50/p99,
    and the ``answered_fraction`` (status ok or degraded).  Target:
    >= 99% answered with ZERO fabricated results — every answer is
    verified against an independent solve (ok: same bucket program;
    degraded radius:r — a real solve of the smaller ego-net; last_good —
    the previously verified healthy answer).

Writes experiments/bench/BENCH_resilience.json (committed baseline).
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from repro import faults
from repro.core import Problem, Solver
from repro.faults import FaultPlan
from repro.graph.generators import chung_lu_power_law
from repro.serve.densest import DensestQueryEngine
from repro.serve.resilience import ResilienceConfig


def _pct(xs, q):
    return float(np.percentile(np.asarray(xs), q))


def _lat_stats(lat_s, wall_s, n):
    return {
        "p50_ms": round(_pct(lat_s, 50) * 1e3, 3),
        "p99_ms": round(_pct(lat_s, 99) * 1e3, 3),
        "wall_s": round(wall_s, 4),
        "qps": round(n / wall_s, 2),
    }


def _best_wall(engine, seeds, repeats):
    best = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        results = engine.query_many(seeds)
        wall = time.perf_counter() - t0
        if best is None or wall < best[0]:
            best = (wall, results)
    return best


def _members_of(res, nodes):
    alive = np.nonzero(np.asarray(res.best_alive))[0]
    return nodes[alive[alive < len(nodes)]]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=30_000)
    ap.add_argument("--avg-deg", type=float, default=8.0)
    ap.add_argument("--queries", type=int, default=64)
    ap.add_argument("--radius", type=int, default=2)
    ap.add_argument("--max-batch", type=int, default=16)
    ap.add_argument("--max-ego-nodes", type=int, default=128)
    ap.add_argument("--eps", type=float, default=0.5)
    ap.add_argument("--max-passes", type=int, default=32)
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--storm-prob", type=float, default=0.35)
    ap.add_argument("--storm-seed", type=int, default=1202)
    ap.add_argument("--out", default=os.path.join(
        "experiments", "bench", "BENCH_resilience.json"))
    args = ap.parse_args(argv)

    edges = chung_lu_power_law(
        args.n, exponent=2.0, avg_deg=args.avg_deg, seed=0
    )
    prob = Problem.undirected(
        eps=args.eps, max_passes=args.max_passes, compaction="off"
    )
    seeds = np.random.default_rng(7).integers(0, args.n, args.queries).tolist()
    cfg = ResilienceConfig(
        deadline_ms=250.0,
        max_retries=2,
        backoff_base_ms=0.5,
        breaker_threshold=8,
        breaker_cooldown_s=5.0,
        max_queue=4096,
    )

    def fresh_engine(**kw):
        return DensestQueryEngine(
            edges, prob, radius=args.radius, max_batch=args.max_batch,
            max_ego_nodes=args.max_ego_nodes, max_wait_ms=0.0, **kw
        )

    report = {
        "config": {
            "n_nodes": args.n,
            "n_edges": int(edges.num_real_edges()),
            "queries": args.queries,
            "radius": args.radius,
            "max_batch": args.max_batch,
            "max_ego_nodes": args.max_ego_nodes,
            "eps": args.eps,
            "max_passes": args.max_passes,
            "resilience": {
                "deadline_ms": cfg.deadline_ms,
                "max_retries": cfg.max_retries,
                "breaker_threshold": cfg.breaker_threshold,
                "max_queue": cfg.max_queue,
            },
        }
    }

    # ---- raw hook cost: an uninstalled fire() is one global read --------
    assert faults.installed() is None
    reps = 1_000_000
    t0 = time.perf_counter()
    for _ in range(reps):
        faults.fire("bench.site", key=0)
    per_call_ns = (time.perf_counter() - t0) / reps * 1e9
    report["uninstalled_fire_ns_per_call"] = round(per_call_ns, 1)
    print(f"uninstalled fire(): {per_call_ns:.0f} ns/call")

    # ---- fault-free overhead: plain vs resilience-enabled ---------------
    plain = fresh_engine()
    resilient = fresh_engine(resilience=cfg)
    plain.query_many(seeds)  # warm every bucket program once
    resilient.query_many(seeds)
    wall_p, res_p = _best_wall(plain, seeds, args.repeats)
    wall_r, res_r = _best_wall(resilient, seeds, args.repeats)
    report["fault_free_plain"] = _lat_stats(
        [r.latency_s for r in res_p], wall_p, args.queries
    )
    report["fault_free_resilient"] = _lat_stats(
        [r.latency_s for r in res_r], wall_r, args.queries
    )
    overhead = (wall_r - wall_p) / wall_p * 100.0
    report["fault_free_overhead_pct"] = round(overhead, 2)
    print("fault_free plain:    ", report["fault_free_plain"])
    print("fault_free resilient:", report["fault_free_resilient"])
    print(f"fault-free overhead: {overhead:+.2f}%")

    # Bit-identity across the two engines (the zero-cost contract).
    for a, b in zip(res_p, res_r):
        assert a.density == b.density and b.status == "ok", a.seed
        assert np.array_equal(a.nodes, b.nodes), a.seed
    report["fault_free_bit_identical"] = True

    # ---- fault storm ----------------------------------------------------
    # Healthy reference answers (also primes the storm engine's last-good
    # cache) + reference solves for degraded-answer verification.
    storm_eng = fresh_engine(resilience=cfg)
    healthy = {r.seed: r for r in storm_eng.query_many(seeds)}
    check = Solver()

    plan = FaultPlan(seed=args.storm_seed).fail_prob(
        "serve.solve", args.storm_prob
    )
    with faults.active(plan):
        t0 = time.perf_counter()
        storm = storm_eng.query_many(seeds)
        storm_wall = time.perf_counter() - t0

    outcomes = {}
    fabricated = 0
    answered = 0
    for r in storm:
        key = r.fallback if r.status == "degraded" else r.status
        key = key.split(":")[0] if key and key.startswith("radius") else key
        outcomes[key] = outcomes.get(key, 0) + 1
        if r.answered:
            answered += 1
        # Verify NOTHING was fabricated: every answer must re-derive from
        # an independent computation of real data.
        if r.status == "ok":
            padded, nodes = storm_eng.extract(r.seed, args.radius)
            ref = check.solve(padded, prob)
            if not (
                float(ref.best_density) == r.density
                and np.array_equal(_members_of(ref, nodes), r.nodes)
            ):
                fabricated += 1
        elif r.status == "degraded" and r.fallback.startswith("radius:"):
            rr = int(r.fallback.split(":")[1])
            padded, nodes = storm_eng.extract(r.seed, rr)
            ref = check.solve(padded, prob)
            if not (
                float(ref.best_density) == r.density
                and np.array_equal(_members_of(ref, nodes), r.nodes)
            ):
                fabricated += 1
        elif r.status == "degraded" and r.fallback == "last_good":
            h = healthy[r.seed]
            if not (
                h.density == r.density and np.array_equal(h.nodes, r.nodes)
            ):
                fabricated += 1

    frac = answered / len(storm)
    report["fault_storm"] = {
        "storm_seed": args.storm_seed,
        "fail_prob": args.storm_prob,
        "injected_failures": plan.failures_at("serve.solve"),
        "solve_hits": plan.hits_at("serve.solve"),
        "outcomes": outcomes,
        "answered_fraction": round(frac, 4),
        "fabricated_results": fabricated,
        "solve_retries": storm_eng.solve_retries,
        "deadline_stops": storm_eng.deadline_stops,
        "breaker_open_skips": storm_eng.breaker_open_skips,
        "latency": _lat_stats(
            [r.latency_s for r in storm], storm_wall, len(storm)
        ),
    }
    print("fault_storm:", json.dumps(report["fault_storm"], indent=2))
    assert fabricated == 0, "a storm answer failed independent verification"

    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
