"""Turnstile runtime benchmark: sketch update throughput, query latency,
and sampled-peel accuracy on churned dynamic streams.

    PYTHONPATH=src python -m benchmarks.bench_turnstile [--n 100000] [--trials 12]

Measures the three turnstile claims (ISSUE acceptance criteria):

  * **update throughput** — ±edge batches absorbed per second by the
    donated jitted sketch-update program (steady state: the first trial's
    compile is excluded), plus the trace counts proving one compilation
    per pow2 batch bucket;
  * **query latency vs from-scratch repeel** — ``TurnstileDensest.query()``
    (host recovery + sample peel on a pow2 bucket) against the pre-sketch
    alternative: materialize the surviving edge set from the recorded
    stream (``apply_updates``) and run an insert-mode ``solve()`` of the
    FULL graph, solve warm.  The headline ``query_speedup_x`` is the
    ratio;
  * **accuracy** — per seeded trial, the sampled-peel density against the
    exact insert-mode peel of the surviving graph (built with the
    :func:`repro.graph.edgelist.apply_updates` host reference).  The churn
    stream deletes >= 20 % of a power-law + planted-dense-block graph; the
    MTVV envelope is (1+eps)(2+2eps) and ``envelope_pass_rate`` reports
    the fraction of trials inside it;
  * **scaling** — query latency is O(tau·polylog), independent of the
    stream, while the repeel baseline grows linearly with the live edge
    count: a sweep over stream densities shows the speedup widening.  The
    headline ``query_speedup_x`` is taken at the largest sweep point.

Writes experiments/bench/BENCH_turnstile.json (committed baseline).
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from repro.core import Problem, Solver
from repro.core.turnstile import TurnstileDensest
from repro.graph.edgelist import apply_updates, from_numpy
from repro.graph.generators import planted_dense_subgraph


def _pct(xs, q):
    return float(np.percentile(np.asarray(xs), q))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=100_000)
    ap.add_argument("--avg-deg", type=float, default=8.0)
    ap.add_argument("--planted-k", type=int, default=300)
    ap.add_argument("--planted-p", type=float, default=0.4)
    ap.add_argument("--delete-frac", type=float, default=0.25,
                    help="churn: fraction of the stream deleted (>= 0.2)")
    ap.add_argument("--trials", type=int, default=12)
    ap.add_argument("--eps", type=float, default=0.3)
    ap.add_argument("--sample-edges", type=int, default=1 << 14,
                    help="l0 sample budget tau (per-query peel size)")
    ap.add_argument("--batch", type=int, default=1 << 16,
                    help="update batch size fed to the sketch")
    ap.add_argument("--query-repeats", type=int, default=3)
    ap.add_argument("--scaling-deg", default="8,16,32",
                    help="comma list of avg degrees for the scaling sweep "
                         "(query flat, repeel linear in m)")
    ap.add_argument("--out", default=os.path.join(
        "experiments", "bench", "BENCH_turnstile.json"))
    args = ap.parse_args(argv)

    envelope = (1 + args.eps) * (2 + 2 * args.eps)
    prob_exact = Problem.undirected(eps=args.eps, compaction="off")
    solver = Solver()  # shared: trial 2+ runs every program warm

    trials = []
    update_walls, query_walls, repeel_walls, ratios = [], [], [], []
    for trial in range(args.trials):
        g, _ = planted_dense_subgraph(
            args.n, args.avg_deg, args.planted_k, args.planted_p, seed=trial
        )
        m = int(np.asarray(g.mask).sum())
        src = np.asarray(g.src)[:m].copy()
        dst = np.asarray(g.dst)[:m].copy()
        rng = np.random.default_rng(10_000 + trial)
        n_del = int(args.delete_frac * m)
        del_idx = rng.choice(m, size=n_del, replace=False)
        deletes = np.stack([src[del_idx], dst[del_idx]], axis=1)
        base = from_numpy(src, dst, args.n)
        final, stats = apply_updates(base, deletes=deletes)
        assert stats["missing_deletes"] == 0

        td = TurnstileDensest(
            args.n,
            Problem.undirected(
                eps=args.eps, compaction="off", stream_mode="turnstile",
                sample_edges=args.sample_edges, sketch_seed=trial,
            ),
            solver=solver,
        )
        # ---- updates: insert the full stream, then the delete churn ----
        t0 = time.perf_counter()
        for lo in range(0, m, args.batch):
            td.apply(insert_edges=(src[lo:lo + args.batch],
                                   dst[lo:lo + args.batch]))
        for lo in range(0, n_del, args.batch):
            td.apply(delete_edges=(deletes[lo:lo + args.batch, 0],
                                   deletes[lo:lo + args.batch, 1]))
        import jax
        jax.block_until_ready(td.sketch.tables)
        upd_wall = time.perf_counter() - t0

        # ---- query: recovery + sample peel, best of K warm runs --------
        q_best = None
        res = None
        for _ in range(args.query_repeats):
            t0 = time.perf_counter()
            res = td.query()
            q = time.perf_counter() - t0
            q_best = q if q_best is None else min(q_best, q)

        # ---- baseline: from-scratch exact repeel.  Without the sketch,
        # answering after churn means materializing the surviving edge
        # set from the recorded stream (apply_updates) and peeling ALL of
        # it — both steps are what the sampled query replaces, so both
        # are inside the timer (the solve itself runs warm, like query).
        r_best = None
        exact = None
        for _ in range(args.query_repeats):
            t0 = time.perf_counter()
            survivors, _ = apply_updates(base, deletes=deletes)
            exact = solver.solve(survivors, prob_exact)
            float(exact.best_density)
            r = time.perf_counter() - t0
            r_best = r if r_best is None else min(r_best, r)

        info = res.extras["turnstile"]
        ratio = float(res.best_density) / float(exact.best_density)
        trials.append({
            "seed": trial,
            "m_inserted": m,
            "m_deleted": n_del,
            "m_live": int(np.asarray(final.mask).sum()),
            "update_wall_s": round(upd_wall, 4),
            "query_s": round(q_best, 4),
            "exact_repeel_s": round(r_best, 4),
            "sample_level": info["level"],
            "sample_edges_recovered": info["sample_edges_recovered"],
            "recovery_failures": info["recovery_failures"],
            "density_turnstile": round(float(res.best_density), 4),
            "density_exact_peel": round(float(exact.best_density), 4),
            "ratio": round(ratio, 4),
            "in_envelope": bool(1.0 / envelope <= ratio <= envelope),
            "update_trace_count": td.sketch.trace_count,
        })
        print(f"trial {trial}: {trials[-1]}")
        if trial > 0:  # steady state: trial 0 pays every compile
            update_walls.append((upd_wall, m + n_del))
            query_walls.append(q_best)
            repeel_walls.append(r_best)
        ratios.append(ratio)

    # ---- scaling sweep: the query touches O(tau) edges no matter how
    # dense the stream gets, the repeel touches all of them.  Same churn
    # protocol as the trials, one seed per density point.
    scaling = []
    for deg in [float(x) for x in args.scaling_deg.split(",") if x]:
        g, _ = planted_dense_subgraph(
            args.n, deg, args.planted_k, args.planted_p, seed=0
        )
        m = int(np.asarray(g.mask).sum())
        src = np.asarray(g.src)[:m].copy()
        dst = np.asarray(g.dst)[:m].copy()
        rng = np.random.default_rng(77)
        del_idx = rng.choice(m, size=int(args.delete_frac * m), replace=False)
        deletes = np.stack([src[del_idx], dst[del_idx]], axis=1)
        base = from_numpy(src, dst, args.n)

        td = TurnstileDensest(
            args.n,
            Problem.undirected(
                eps=args.eps, compaction="off", stream_mode="turnstile",
                sample_edges=args.sample_edges, sketch_seed=0,
            ),
            solver=solver,
        )
        for lo in range(0, m, args.batch):
            td.apply(insert_edges=(src[lo:lo + args.batch],
                                   dst[lo:lo + args.batch]))
        for lo in range(0, len(del_idx), args.batch):
            td.apply(delete_edges=(deletes[lo:lo + args.batch, 0],
                                   deletes[lo:lo + args.batch, 1]))

        q_best = r_best = None
        for _ in range(args.query_repeats):
            t0 = time.perf_counter()
            td.query()
            q = time.perf_counter() - t0
            q_best = q if q_best is None else min(q_best, q)
        for _ in range(args.query_repeats):
            t0 = time.perf_counter()
            survivors, _ = apply_updates(base, deletes=deletes)
            float(solver.solve(survivors, prob_exact).best_density)
            r = time.perf_counter() - t0
            r_best = r if r_best is None else min(r_best, r)
        scaling.append({
            "avg_deg": deg,
            "m_live": m - len(del_idx),
            "query_s": round(q_best, 4),
            "exact_repeel_s": round(r_best, 4),
            "speedup_x": round(r_best / max(q_best, 1e-9), 1),
        })
        print(f"scaling: {scaling[-1]}")

    q50 = _pct(query_walls, 50)
    r50 = _pct(repeel_walls, 50)
    top = max(scaling, key=lambda s: s["m_live"]) if scaling else None
    report = {
        "config": {
            "n_nodes": args.n,
            "avg_deg": args.avg_deg,
            "planted_k": args.planted_k,
            "planted_p": args.planted_p,
            "delete_frac": args.delete_frac,
            "trials": args.trials,
            "eps": args.eps,
            "sample_edges": args.sample_edges,
            "batch": args.batch,
            "scaling_deg": args.scaling_deg,
        },
        "update_throughput": {
            "edges_per_s": round(
                sum(k for _, k in update_walls)
                / max(sum(w for w, _ in update_walls), 1e-9), 1
            ),
            "steady_state_trials": len(update_walls),
        },
        "query": {
            "p50_s": round(q50, 4),
            "exact_repeel_p50_s": round(r50, 4),
            "trial_speedup_x": round(r50 / max(q50, 1e-9), 1),
            # headline: speedup at the densest sweep point — the query is
            # stream-size independent, so this is where sketching pays.
            "query_speedup_x": (top["speedup_x"] if top
                                else round(r50 / max(q50, 1e-9), 1)),
        },
        "scaling": scaling,
        "accuracy": {
            "envelope": round(envelope, 4),
            "envelope_pass_rate": round(
                sum(t["in_envelope"] for t in trials) / len(trials), 4
            ),
            "ratio_min": round(min(ratios), 4),
            "ratio_max": round(max(ratios), 4),
        },
        "trials": trials,
    }
    print("update_throughput:", report["update_throughput"])
    print("query:", report["query"])
    print("accuracy:", report["accuracy"])

    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
