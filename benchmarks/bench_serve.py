"""Serving-tier benchmark: seed-batched query engine + persistent cache.

    PYTHONPATH=src python -m benchmarks.bench_serve [--n 30000] [--queries 64]

Measures the three serving claims (ISSUE acceptance criteria):

  * **batched vs sequential throughput** — the same per-seed query stream
    answered three ways:
      - ``sequential_exact``: the pre-engine pattern — extract the ego-net,
        build an exact-shape EdgeList, call ``solve()``.  Every distinct
        (n_ego, m_ego) is a new program shape, so the stream pays a
        compile per distinct shape (THE failure mode the engine's pow2
        bucketing removes);
      - ``sequential_bucketed``: ablation — the engine's bucketed
        extraction with warm programs, but one ``solve()`` per query
        (bucketing without batching);
      - ``batched``: the engine (bucketing + coalesced ``solve_batch``).
    Reports p50/p99 latency and qps for each; the headline
    ``batched_vs_sequential_qps_x`` compares the engine against
    ``sequential_exact``.
  * **bit-identity** — every batched answer is checked against a
    standalone ``solve()`` of the same extracted buffer before any number
    is reported.
  * **cold-start** — first-query latency in a FRESH subprocess, uncached
    (traces + XLA-compiles) vs with a warm ``cache_dir``
    (``core/progcache.py`` disk tier; the child asserts it compiled
    NOTHING), plus the populate cost.  This is the replica-restart /
    autoscale path the persistent cache exists for.
  * **local-vs-BFS scaling sweep** — per-query work (nodes touched, p50
    latency) of ``extraction='local'`` (Andersen pruned-frontier,
    core/local.py) stays flat across a 30k->300k node sweep while the
    untruncated radius-2 BFS ego-net grows with the graph
    (``--skip-sweep`` for smoke runs).

Writes experiments/bench/BENCH_serve.json (committed baseline).
"""

from __future__ import annotations

import argparse
import json
import os
import shutil
import subprocess
import sys
import tempfile
import time

import numpy as np

from repro.core import Problem, Solver
from repro.graph.generators import chung_lu_power_law
from repro.serve.densest import DensestQueryEngine

# Runs in the parent (measurement) and in each subprocess (cold-start
# protocol): build the same graph/problem/engine from the same argv knobs.
_CHILD = """
import json, time
import numpy as np
from repro.core import Problem
from repro.graph.generators import chung_lu_power_law
from repro.serve.densest import DensestQueryEngine

cfg = json.loads({cfg!r})
edges = chung_lu_power_law(cfg["n"], exponent=2.0, avg_deg=cfg["avg_deg"], seed=0)
prob = Problem.undirected(eps=cfg["eps"], max_passes=cfg["max_passes"],
                          compaction="off")
eng = DensestQueryEngine(
    edges, prob, cache_dir=cfg["cache_dir"], radius=cfg["radius"],
    max_ego_nodes=cfg["max_ego_nodes"], max_wait_ms=0.0,
)
# Backend init happens at replica startup either way; keep it out of the
# first-query measurement so cold vs warm isolates program ACQUISITION
# (trace + XLA compile vs disk load).
import jax.numpy as jnp
jnp.zeros(4).block_until_ready()
t0 = time.perf_counter()
r = eng.query(cfg["seed"])
first = time.perf_counter() - t0
if cfg["expect_warm"]:
    assert eng.solver.trace_count == 0, (
        "warm-cache child traced %d programs" % eng.solver.trace_count)
    assert eng.solver.disk_hits >= 1, "warm-cache child never hit disk"
print("BENCH_CHILD " + json.dumps({{
    "first_query_s": first,
    "density": r.density,
    "trace_count": eng.solver.trace_count,
    "disk_hits": eng.solver.disk_hits,
    "disk_misses": eng.solver.disk_misses,
}}))
"""


def _pct(xs, q):
    return float(np.percentile(np.asarray(xs), q))


def _lat_stats(lat_s, wall_s, n):
    return {
        "p50_ms": round(_pct(lat_s, 50) * 1e3, 3),
        "p99_ms": round(_pct(lat_s, 99) * 1e3, 3),
        "wall_s": round(wall_s, 4),
        "qps": round(n / wall_s, 2),
    }


def _run_child(cfg):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath("src") + os.pathsep + env.get(
        "PYTHONPATH", ""
    )
    out = subprocess.run(
        [sys.executable, "-c", _CHILD.format(cfg=json.dumps(cfg))],
        capture_output=True, text=True, env=env, timeout=1200,
    )
    if out.returncode != 0:
        raise RuntimeError(f"bench child failed:\n{out.stderr[-3000:]}")
    line = [l for l in out.stdout.splitlines() if l.startswith("BENCH_CHILD ")]
    return json.loads(line[-1][len("BENCH_CHILD "):])


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=30_000)
    ap.add_argument("--avg-deg", type=float, default=8.0)
    ap.add_argument("--queries", type=int, default=64)
    ap.add_argument("--radius", type=int, default=1)
    ap.add_argument("--max-batch", type=int, default=16)
    ap.add_argument("--max-ego-nodes", type=int, default=128)
    ap.add_argument("--eps", type=float, default=0.5)
    ap.add_argument("--max-passes", type=int, default=32)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--cache-dir", default=None,
                    help="disk cache for the cold-start protocol "
                         "(default: a fresh temp dir)")
    ap.add_argument("--skip-cold-start", action="store_true",
                    help="skip the subprocess cold-start measurements")
    ap.add_argument("--skip-sweep", action="store_true",
                    help="skip the local-vs-BFS extraction scaling sweep")
    ap.add_argument("--sweep-sizes", default="30000,95000,300000",
                    help="comma-separated graph sizes for the scaling sweep")
    ap.add_argument("--sweep-queries", type=int, default=32)
    ap.add_argument("--skip-naive", action="store_true",
                    help="skip the compile-per-shape sequential_exact "
                         "baseline (it dominates wall time)")
    ap.add_argument("--out", default=os.path.join(
        "experiments", "bench", "BENCH_serve.json"))
    args = ap.parse_args(argv)

    edges = chung_lu_power_law(
        args.n, exponent=2.0, avg_deg=args.avg_deg, seed=0
    )
    # compaction pinned off: solve_batch's stacked-lane path requires it,
    # and the sequential baseline must run the IDENTICAL program family.
    prob = Problem.undirected(
        eps=args.eps, max_passes=args.max_passes, compaction="off"
    )
    seeds = np.random.default_rng(7).integers(0, args.n, args.queries).tolist()

    def fresh_engine(**kw):
        return DensestQueryEngine(
            edges, prob, radius=args.radius, max_batch=args.max_batch,
            max_ego_nodes=args.max_ego_nodes, max_wait_ms=0.0, **kw
        )

    report = {
        "config": {
            "n_nodes": args.n,
            "n_edges": int(edges.num_real_edges()),
            "queries": args.queries,
            "radius": args.radius,
            "max_batch": args.max_batch,
            "max_ego_nodes": args.max_ego_nodes,
            "eps": args.eps,
            "max_passes": args.max_passes,
        }
    }

    # ---- batched engine (the serving path) ------------------------------
    eng = fresh_engine()
    eng.query_many(seeds)  # warm every bucket program once
    best = None
    for _ in range(args.repeats):
        t0 = time.perf_counter()
        results = eng.query_many(seeds)
        wall = time.perf_counter() - t0
        if best is None or wall < best[0]:
            best = (wall, results)
    wall, results = best
    report["batched"] = _lat_stats(
        [r.latency_s for r in results], wall, args.queries
    )
    report["batched"].update(
        distinct_buckets=len(eng.bucket_histogram),
        lanes_solved=eng.lanes_solved,
        pad_lanes=eng.pad_lanes,
        programs_compiled=eng.solver.trace_count,
    )
    print("batched:", report["batched"])

    # ---- bit-identity gate ----------------------------------------------
    check = Solver()
    for r in results:
        padded, nodes = eng.extract(r.seed)
        ref = check.solve(padded, prob)
        assert float(ref.best_density) == r.density, (r.seed, r.density)
        ba = np.nonzero(np.asarray(ref.best_alive))[0]
        assert np.array_equal(nodes[ba[ba < len(nodes)]], r.nodes), r.seed
    report["bit_identical_to_solve"] = True
    print(f"bit-identity: {len(results)} answers == sequential solve()")

    # ---- sequential_exact: the pre-engine pattern -----------------------
    # Extract the ego-net, build an EXACT-shape EdgeList, call solve().
    # Distinct (n_ego, m_ego) pairs are distinct program shapes, so the
    # stream compiles per shape — the compile storm pow2 bucketing removes.
    if not args.skip_naive:
        from repro.graph.edgelist import EdgeList

        def exact_subgraph(seed):
            padded, nodes = eng.extract(seed)
            m = max(int(np.asarray(padded.mask).sum()), 1)
            return EdgeList(
                src=np.asarray(padded.src)[:m],
                dst=np.asarray(padded.dst)[:m],
                weight=np.asarray(padded.weight)[:m],
                mask=np.asarray(padded.mask)[:m],
                n_nodes=max(len(nodes), 1),
            )

        naive = Solver()
        lat = []
        t0 = time.perf_counter()
        for s in seeds:
            q0 = time.perf_counter()
            out = naive.solve(exact_subgraph(s), prob)
            float(out.best_density)  # block
            lat.append(time.perf_counter() - q0)
        wall = time.perf_counter() - t0
        report["sequential_exact"] = _lat_stats(lat, wall, args.queries)
        report["sequential_exact"]["programs_compiled"] = naive.trace_count
        print("sequential_exact:", report["sequential_exact"])

    # ---- sequential_bucketed: bucketing without batching (ablation) -----
    seq = Solver()
    for s in seeds:  # warm every per-bucket program once
        seq.solve(eng.extract(s)[0], prob)
    best = None
    for _ in range(args.repeats):
        lat = []
        t0 = time.perf_counter()
        for s in seeds:
            q0 = time.perf_counter()
            out = seq.solve(eng.extract(s)[0], prob)
            float(out.best_density)  # block
            lat.append(time.perf_counter() - q0)
        wall = time.perf_counter() - t0
        if best is None or wall < best[0]:
            best = (wall, lat)
    wall, lat = best
    report["sequential_bucketed"] = _lat_stats(lat, wall, args.queries)
    report["sequential_bucketed"]["programs_compiled"] = seq.trace_count
    print("sequential_bucketed:", report["sequential_bucketed"])

    if "sequential_exact" in report:
        ratio = report["batched"]["qps"] / report["sequential_exact"]["qps"]
        report["batched_vs_sequential_qps_x"] = round(ratio, 2)
        print(f"batched vs sequential(exact) qps: {ratio:.2f}x")
    ab = report["batched"]["qps"] / report["sequential_bucketed"]["qps"]
    report["batched_vs_bucketed_qps_x"] = round(ab, 2)
    print(f"batched vs sequential(bucketed, warm) qps: {ab:.2f}x")

    # ---- cold start: fresh subprocess, uncached vs warm disk cache ------
    if not args.skip_cold_start:
        cache_dir = args.cache_dir or tempfile.mkdtemp(prefix="bench_serve_")
        owns_dir = args.cache_dir is None
        try:
            base = {
                "n": args.n, "avg_deg": args.avg_deg, "eps": args.eps,
                "max_passes": args.max_passes, "radius": args.radius,
                "max_ego_nodes": args.max_ego_nodes, "seed": seeds[0],
            }
            cold = _run_child(
                dict(base, cache_dir=None, expect_warm=False)
            )
            t0 = time.perf_counter()
            populate = _run_child(
                dict(base, cache_dir=cache_dir, expect_warm=False)
            )
            populate_wall = time.perf_counter() - t0
            warm = _run_child(
                dict(base, cache_dir=cache_dir, expect_warm=True)
            )
            assert warm["density"] == cold["density"], "cold/warm mismatch"
            report["cold_start"] = {
                "uncached_first_query_s": round(cold["first_query_s"], 4),
                "uncached_programs_compiled": cold["trace_count"],
                "populate_first_query_s": round(
                    populate["first_query_s"], 4
                ),
                "populate_child_wall_s": round(populate_wall, 4),
                "warm_disk_first_query_s": round(warm["first_query_s"], 4),
                "warm_disk_programs_compiled": warm["trace_count"],
                "warm_disk_hits": warm["disk_hits"],
                "cold_start_speedup_x": round(
                    cold["first_query_s"] / max(warm["first_query_s"], 1e-9),
                    1,
                ),
            }
            print("cold_start:", report["cold_start"])
        finally:
            if owns_dir:
                shutil.rmtree(cache_dir, ignore_errors=True)

    # ---- local-vs-BFS extraction scaling sweep --------------------------
    # THE substrate='local' claim (ISSUE 10): per-query work of the
    # Andersen extraction is governed by the budget, not by n, so nodes
    # touched and p50 latency stay FLAT across a 10x graph sweep while the
    # radius-2 BFS ego-net (untruncated, the honest comparison) grows with
    # the graph.  Both modes answer through the identical engine surface.
    if not args.skip_sweep:
        sizes = [int(s) for s in args.sweep_sizes.split(",")]
        sweep = {
            "sizes": sizes,
            "queries": args.sweep_queries,
            "bfs_radius": 2,
            "local_budget": None,  # engine default (constants.LOCAL_BUDGET)
            "bfs": [],
            "local": [],
        }
        for n in sizes:
            g = chung_lu_power_law(
                n, exponent=2.0, avg_deg=args.avg_deg, seed=1
            )
            ss = np.random.default_rng(11).integers(
                0, n, args.sweep_queries
            ).tolist()
            for mode in ("bfs", "local"):
                kw = (
                    {"radius": 2, "max_ego_nodes": None}
                    if mode == "bfs"
                    else {"extraction": "local"}
                )
                e = DensestQueryEngine(
                    g, prob, max_batch=args.max_batch, max_wait_ms=0.0, **kw
                )
                e.query_many(ss)  # warm every bucket program once
                t0 = time.perf_counter()
                rs = e.query_many(ss)
                wall = time.perf_counter() - t0
                point = {
                    "n": n,
                    "mean_extracted_nodes": round(
                        float(np.mean([r.n_ego for r in rs])), 1
                    ),
                    "p50_ms": round(
                        _pct([r.latency_s for r in rs], 50) * 1e3, 3
                    ),
                    "qps": round(len(ss) / wall, 2),
                }
                if mode == "local":
                    sweep["local_budget"] = e.local_budget
                    # counters span warm + measured passes: per-query mean.
                    point["mean_nodes_touched"] = round(
                        e.local_nodes_touched / (2 * len(ss)), 1
                    )
                    point["mean_edges_scanned"] = round(
                        e.local_edges_scanned / (2 * len(ss)), 1
                    )
                sweep[mode].append(point)
                print(f"sweep n={n} {mode}: {point}")
        first, last = sweep["local"][0], sweep["local"][-1]
        sweep["local_work_growth_x"] = round(
            last["mean_nodes_touched"] / max(first["mean_nodes_touched"], 1e-9),
            2,
        )
        fb, lb = sweep["bfs"][0], sweep["bfs"][-1]
        sweep["bfs_work_growth_x"] = round(
            lb["mean_extracted_nodes"]
            / max(fb["mean_extracted_nodes"], 1e-9),
            2,
        )
        report["local_vs_bfs_sweep"] = sweep
        print(
            "sweep work growth over "
            f"{sizes[0]}->{sizes[-1]}: local "
            f"{sweep['local_work_growth_x']}x, "
            f"bfs {sweep['bfs_work_growth_x']}x"
        )

    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
