"""Out-of-core streaming benchmark: async-pipeline overlap win and ladder
spill residency for the semi-streaming substrate.

The substrate's two claims after the out-of-core overhaul:

  * the bounded-prefetch async pipeline (chunk reads + device degree
    kernels + in-order host reduction overlapped) beats the synchronous
    one-chunk-at-a-time pass, bit-identically;
  * the geometric ladder with ``spill_dir`` completes with bounded host
    residency (pipeline window only — rebuilt survivor streams live on
    disk), still bit-identical to ``compaction='off'``.

Run with::

    PYTHONPATH=src python -m benchmarks.bench_stream [--n 100000]

Writes experiments/bench/BENCH_stream.json with, per mode (sync, async,
async+geometric in-RAM, async+geometric spilled): wall-clock (min over
repeats), passes, peak resident chunks/edges, compactions/spill rungs, and
bit-identity vs the synchronous baseline; plus the overlap speedup factor.
The stream itself is memmap-backed (written once to a scratch edge store),
so edges never sit in host RAM whole.
"""

from __future__ import annotations

import argparse
import json
import os
import shutil
import tempfile
import time

import numpy as np

import jax

from repro.core.streaming import StreamingDensest, chunked_from_memmap
from repro.graph.edgelist import save_edges_memmap
from repro.graph.generators import chung_lu_power_law


def _run(make_drv, repeats: int):
    st = make_drv().run(resume=False)  # warm: compiles the chunk kernels
    best = float("inf")
    drv = None
    for _ in range(repeats):
        drv = make_drv()
        t0 = time.perf_counter()
        st = drv.run(resume=False)
        best = min(best, time.perf_counter() - t0)
    return best, st, drv


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    # Defaults reproduce the COMMITTED baseline (like bench_peel_compaction):
    # running with no flags must regenerate a comparable BENCH_stream.json,
    # never silently overwrite it with a different configuration.
    ap.add_argument("--n", type=int, default=200_000)
    ap.add_argument("--avg-deg", type=float, default=8.0)
    ap.add_argument("--exponent", type=float, default=2.0)
    ap.add_argument("--eps", type=float, default=0.1)
    ap.add_argument("--chunk", type=int, default=1 << 13)
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--prefetch", type=int, default=4)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument(
        "--out", default=os.path.join("experiments", "bench", "BENCH_stream.json")
    )
    args = ap.parse_args(argv)

    edges = chung_lu_power_law(
        args.n, exponent=args.exponent, avg_deg=args.avg_deg, seed=0
    )
    mask = np.asarray(edges.mask)
    src = np.asarray(edges.src)[mask]
    dst = np.asarray(edges.dst)[mask]
    w = np.asarray(edges.weight)[mask]
    scratch = tempfile.mkdtemp(prefix="bench_stream_")
    store = save_edges_memmap(os.path.join(scratch, "store"), src, dst, w)
    stream = chunked_from_memmap(store, chunk=args.chunk)
    n_chunks = -(-len(src) // args.chunk)

    # Speculation is a fault-tolerance knob (it DUPLICATES tail chunks); it
    # stays off in the timed modes so the numbers isolate pipeline overlap.
    modes = {
        "sync": dict(n_workers=1, prefetch=1, speculative=False),
        "async": dict(
            n_workers=args.workers, prefetch=args.prefetch, speculative=False
        ),
        "geometric_ram": dict(
            n_workers=args.workers, prefetch=args.prefetch, speculative=False,
            compaction="geometric",
        ),
        "geometric_spill": dict(
            n_workers=args.workers, prefetch=args.prefetch, speculative=False,
            compaction="geometric",
            spill_dir=os.path.join(scratch, "spill"),
        ),
    }
    report = {
        "graph": {
            "family": "chung_lu_power_law",
            "n_nodes": args.n,
            "n_edges": int(len(src)),
            "exponent": args.exponent,
            "avg_deg": args.avg_deg,
        },
        "eps": args.eps,
        "chunk": args.chunk,
        "n_chunks": n_chunks,
        "workers": args.workers,
        "prefetch": args.prefetch,
        "platform": jax.default_backend(),
        "modes": {},
    }
    ref = None
    try:
        for name, kw in modes.items():
            wall, st, drv = _run(
                lambda kw=kw: StreamingDensest(
                    stream, n_nodes=args.n, eps=args.eps, **kw
                ),
                args.repeats,
            )
            if ref is None:
                ref = st
                identical = True
            else:
                identical = (
                    st.best_rho == ref.best_rho
                    and (st.best_alive == ref.best_alive).all()
                    and st.pass_idx == ref.pass_idx
                    and st.history == ref.history
                )
            report["modes"][name] = {
                "wall_s": round(wall, 4),
                "passes": st.pass_idx,
                "rho": round(st.best_rho, 4),
                "peak_resident_chunks": drv.peak_resident_chunks,
                "peak_resident_edges": drv.peak_resident_edges,
                "compactions": drv.compactions,
                "spill_rungs": drv.spill_rungs,
                "speculative_reissues": drv.speculative_reissues,
                "bit_identical_to_sync": identical,
            }
            print(f"{name}: {report['modes'][name]}")
        sync_w = report["modes"]["sync"]["wall_s"]
        for name in ("async", "geometric_ram", "geometric_spill"):
            report["modes"][name]["speedup_vs_sync_x"] = round(
                sync_w / max(report["modes"][name]["wall_s"], 1e-9), 2
            )
        ram = report["modes"]["geometric_ram"]["peak_resident_edges"]
        sp = report["modes"]["geometric_spill"]["peak_resident_edges"]
        report["spill_residency_reduction_x"] = round(ram / max(sp, 1), 2)
    finally:
        shutil.rmtree(scratch, ignore_errors=True)

    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
