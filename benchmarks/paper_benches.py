"""One benchmark per paper table/figure (Bahmani et al., VLDB'12), at
CPU-tractable scales with the same shapes as the paper's plots.

  table2    §6.2 Table 2  approximation factor rho*/rho~ vs exact, per eps
  fig61     §6.3 Fig 6.1  eps -> (passes, density rel. to eps=0)
  fig62_63  §6.3 Fig 6.2/6.3  per-pass density / |V| / |E| trajectories
  table3    §6.4 Table 3  directed: rho for (eps, delta) grid
  fig64_66  §6.4 Fig 6.4/6.6  directed c-sweep at delta=2
  table4    §6.5 Table 4  sketch-to-exact density ratio vs (eps, b)
  fig67     §6.6 Fig 6.7  distributed per-pass wall time (MapReduce analogue)
  kernels   per-kernel micro-bench (XLA ref path wall time + work stats)
"""

from __future__ import annotations

import time
from typing import Any, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    charikar_greedy,
    densest_directed_search,
    densest_subgraph,
    densest_subgraph_exact,
    densest_subgraph_sketched,
)
from repro.graph import generators as gen


def _rows_to_csv(rows: List[Dict[str, Any]]) -> str:
    if not rows:
        return ""
    keys = list(rows[0].keys())
    out = [",".join(keys)]
    for r in rows:
        out.append(",".join(str(r.get(k, "")) for k in keys))
    return "\n".join(out)


# ---------------------------------------------------------------------------
# Table 2: quality of approximation vs exact optimum
# ---------------------------------------------------------------------------

def table2(eps_list=(0.001, 0.1, 1.0)) -> List[Dict[str, Any]]:
    graphs = {
        "as-like": gen.erdos_renyi(n=1500, avg_deg=4.0, seed=1),
        "collab-pl": gen.chung_lu_power_law(n=1500, exponent=2.1, avg_deg=8.0, seed=2),
        "dense-core": gen.planted_dense_subgraph(
            n=1200, avg_deg=4.0, k=60, p_dense=0.5, seed=3
        )[0],
        "ba": gen.barabasi_albert(n=1500, m_attach=5, seed=4),
    }
    rows = []
    for name, edges in graphs.items():
        _, rho_star = densest_subgraph_exact(edges)
        _, rho_greedy = charikar_greedy(edges)
        row = {
            "graph": name,
            "n": edges.n_nodes,
            "m": int(edges.num_real_edges()),
            "rho_star": round(rho_star, 4),
            "charikar_ratio": round(rho_star / max(rho_greedy, 1e-9), 4),
        }
        for eps in eps_list:
            res = densest_subgraph(edges, eps=eps, track_history=False)
            ratio = rho_star / max(float(res.best_density), 1e-9)
            row[f"ratio_eps{eps}"] = round(ratio, 4)
            row[f"passes_eps{eps}"] = int(res.passes)
            assert ratio <= 2 * (1 + eps) + 1e-6, (name, eps, ratio)
        rows.append(row)
    return rows


# ---------------------------------------------------------------------------
# Fig 6.1: eps vs approximation + passes
# ---------------------------------------------------------------------------


def fig61(eps_list=(0.001, 0.01, 0.1, 0.5, 1.0, 2.0, 4.0)) -> List[Dict[str, Any]]:
    edges = gen.chung_lu_power_law(n=200_000, exponent=2.0, avg_deg=12.0, seed=7)
    base = None
    rows = []
    for eps in eps_list:
        t0 = time.time()
        res = densest_subgraph(edges, eps=eps, track_history=False)
        jax.block_until_ready(res.best_density)
        rho = float(res.best_density)
        if base is None:
            base = rho
        rows.append(
            {
                "eps": eps,
                "density": round(rho, 3),
                "rel_density": round(rho / base, 4),
                "passes": int(res.passes),
                "wall_s": round(time.time() - t0, 2),
            }
        )
    return rows


# ---------------------------------------------------------------------------
# Fig 6.2 / 6.3: per-pass trajectories
# ---------------------------------------------------------------------------


def fig62_63(eps=0.5) -> List[Dict[str, Any]]:
    edges = gen.chung_lu_power_law(n=100_000, exponent=2.0, avg_deg=10.0, seed=8)
    res = densest_subgraph(edges, eps=eps, track_history=True)
    rows = []
    hn = np.asarray(res.history_n)
    hm = np.asarray(res.history_m)
    hr = np.asarray(res.history_rho)
    for t in range(int(res.passes)):
        rows.append(
            {
                "pass": t,
                "nodes": int(hn[t]),
                "edges": int(hm[t]),
                "density": round(float(hr[t]), 3),
            }
        )
    return rows


# ---------------------------------------------------------------------------
# Table 3 + Fig 6.4/6.6: directed
# ---------------------------------------------------------------------------


def _directed_graph():
    return gen.directed_planted(
        n=30_000, avg_deg=8.0, ks=150, kt=60, p_dense=0.4, seed=9
    )[0]


def table3() -> List[Dict[str, Any]]:
    edges = _directed_graph()
    rows = []
    for eps in (0.0, 1.0, 2.0):
        for delta in (2.0, 10.0, 100.0):
            best, best_c, rhos, passes = densest_directed_search(
                edges, eps=max(eps, 1e-9), delta=delta
            )
            rows.append(
                {
                    "eps": eps,
                    "delta": delta,
                    "rho": round(float(best.best_density), 3),
                    "best_c": round(best_c, 4),
                    "total_passes": int(passes.sum()),
                }
            )
    return rows


def fig64_66(eps=1.0, delta=2.0) -> List[Dict[str, Any]]:
    from repro.core.peel_directed import c_grid

    edges = _directed_graph()
    best, best_c, rhos, passes = densest_directed_search(
        edges, eps=eps, delta=delta
    )
    rows = []
    for c, rho, p in zip(c_grid(edges.n_nodes, delta), rhos, passes):
        rows.append(
            {"c": round(float(c), 4), "rho": round(float(rho), 3), "passes": int(p)}
        )
    return rows


# ---------------------------------------------------------------------------
# Table 4: Count-Sketch quality/memory trade-off
# ---------------------------------------------------------------------------


def table4(t: int = 5) -> List[Dict[str, Any]]:
    edges = gen.chung_lu_power_law(n=97_600, exponent=2.0, avg_deg=16.0, seed=10)
    n = edges.n_nodes
    rows = []
    for eps in (0.0, 0.5, 1.0, 1.5, 2.0):
        # eps=0 row: threshold exactly 2*rho (paper's Table 4 top row);
        # cap passes so the while_loop bound stays sane.
        exact = densest_subgraph(
            edges, eps=max(eps, 1e-9), max_passes=256, track_history=False
        )
        row = {"eps": eps, "rho_exact_counts": round(float(exact.best_density), 3)}
        for b in (3000, 4000, 5000):
            sk = densest_subgraph_sketched(
                edges, eps=max(eps, 1e-9), t=t, b=b, seed=11, max_passes=256
            )
            row[f"ratio_b{b}"] = round(
                float(sk.best_density) / max(float(exact.best_density), 1e-9), 4
            )
            row[f"mem_frac_b{b}"] = round(t * b / n, 3)
        rows.append(row)
    return rows


# ---------------------------------------------------------------------------
# Fig 6.7: distributed per-pass wall time (the MapReduce analogue)
# ---------------------------------------------------------------------------


def fig67() -> List[Dict[str, Any]]:
    """Per-pass wall time of the edge-sharded shard_map peel on the host
    mesh, for growing graph sizes (the Hadoop plot's shape, CPU scale).

    If jax is still single-device, re-executes itself in a subprocess with 8
    forced host devices so the collectives are real."""
    import json as _json
    import os as _os
    import subprocess
    import sys as _sys

    if jax.device_count() == 1 and not _os.environ.get("_FIG67_CHILD"):
        env = dict(_os.environ)
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        env["_FIG67_CHILD"] = "1"
        env.setdefault("PYTHONPATH", "src")
        code = (
            "import json; from benchmarks.paper_benches import fig67; "
            "print('FIG67='+json.dumps(fig67()))"
        )
        out = subprocess.run(
            [_sys.executable, "-c", code], env=env, capture_output=True,
            text=True, timeout=1200,
        )
        for line in out.stdout.splitlines():
            if line.startswith("FIG67="):
                return _json.loads(line[len("FIG67="):])
        raise RuntimeError(f"fig67 child failed: {out.stderr[-2000:]}")

    from jax.sharding import Mesh

    from repro.core.mapreduce import densest_subgraph_distributed

    n_dev = jax.device_count()
    mesh = Mesh(np.asarray(jax.devices()).reshape(n_dev), ("data",))
    rows = []
    for n, avg in ((50_000, 8.0), (200_000, 10.0), (500_000, 12.0)):
        edges = gen.chung_lu_power_law(n=n, exponent=2.0, avg_deg=avg, seed=12)
        t0 = time.time()
        res = densest_subgraph_distributed(edges, mesh, ("data",), eps=0.5)
        jax.block_until_ready(res.best_density)
        wall = time.time() - t0
        passes = int(res.passes)
        rows.append(
            {
                "nodes": n,
                "edges": int(edges.num_real_edges()),
                "devices": n_dev,
                "passes": passes,
                "wall_s": round(wall, 2),
                "s_per_pass": round(wall / max(passes, 1), 3),
                "edges_per_s": int(int(edges.num_real_edges()) * passes / wall),
            }
        )
    return rows


# ---------------------------------------------------------------------------
# Kernel micro-benches (wall time of the jitted XLA ref vs pallas-interpret
# correctness cost is meaningless on CPU; we report ref wall time + work)
# ---------------------------------------------------------------------------


def kernels() -> List[Dict[str, Any]]:
    from repro.graph.partition import bucket_edges_by_tile
    from repro.kernels.peel_degree.ref import tiled_degrees_ref

    rows = []
    rng = np.random.default_rng(0)
    for n, e in ((100_000, 800_000),):
        src = rng.integers(0, n, e).astype(np.int32)
        dst = rng.integers(0, n, e).astype(np.int32)
        t0 = time.time()
        tiled = bucket_edges_by_tile(src, dst, n, tile_size=1024, block=512)
        t_shuffle = time.time() - t0
        w = jnp.asarray((tiled.edge_index >= 0).astype(np.float32))
        tl = jnp.asarray(tiled.target_local)
        f = jax.jit(lambda tl, w: tiled_degrees_ref(tl, w, tile_size=1024))
        jax.block_until_ready(f(tl, w))
        t0 = time.time()
        for _ in range(5):
            out = f(tl, w)
        jax.block_until_ready(out)
        rows.append(
            {
                "kernel": "peel_degree(ref-xla)",
                "nodes": n,
                "edge_slots": int(tiled.target_local.size),
                "one_time_shuffle_s": round(t_shuffle, 2),
                "us_per_pass": round((time.time() - t0) / 5 * 1e6, 0),
            }
        )
    return rows


def lemma5(k_values=(4, 5, 6, 7)) -> List[Dict[str, Any]]:
    """Lemma 5 lower-bound instances: the k-block construction forces
    Omega(log n / log log n) passes; measured passes must grow ~k/log k."""
    rows = []
    for k in k_values:
        edges = gen.lemma5_instance(k)
        res = densest_subgraph(edges, eps=0.05, track_history=False)
        rows.append(
            {
                "k": k,
                "n": edges.n_nodes,
                "m": int(edges.num_real_edges()),
                "passes": int(res.passes),
                "k_over_logk": round(k / np.log2(max(k, 2)), 2),
            }
        )
    # passes should be increasing in k (the lower-bound family bites)
    ps = [r["passes"] for r in rows]
    assert all(b >= a for a, b in zip(ps, ps[1:])), ps
    return rows


ALL = {
    "table2": table2,
    "fig61": fig61,
    "fig62_63": fig62_63,
    "table3": table3,
    "fig64_66": fig64_66,
    "table4": table4,
    "fig67": fig67,
    "lemma5": lemma5,
    "kernels": kernels,
}
