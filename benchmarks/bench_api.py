"""Front-door benchmark: Solver compile caching + solve_batch throughput.

    PYTHONPATH=src python -m benchmarks.bench_api [--n 100000] [--avg-deg 8]

Measures
  * cold-compile vs cached ``solve`` latency (the Solver's program cache is
    what lets a serving tier skip retracing at request rates), including a
    same-shape DIFFERENT graph (the production request pattern), and
  * ``solve_batch`` eps-sweep throughput vs sequential per-eps ``solve``
    calls (the ROADMAP batched driver).

Writes experiments/bench/BENCH_api.json.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

import jax

from repro.core import Problem, Solver
from repro.graph.edgelist import EdgeList
from repro.graph.generators import chung_lu_power_law


def _timed(fn):
    t0 = time.perf_counter()
    out = fn()
    jax.block_until_ready(out)
    return time.perf_counter() - t0, out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=100_000)
    ap.add_argument("--avg-deg", type=float, default=8.0)
    ap.add_argument("--eps", type=float, default=0.5)
    ap.add_argument("--max-passes", type=int, default=48)
    ap.add_argument("--grid", type=int, default=8, help="eps sweep size")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--out", default=os.path.join("experiments", "bench", "BENCH_api.json"))
    args = ap.parse_args(argv)

    edges = chung_lu_power_law(args.n, exponent=2.0, avg_deg=args.avg_deg, seed=0)
    perm = np.random.default_rng(1).permutation(edges.src.shape[0])
    other = EdgeList(
        src=edges.src[perm], dst=edges.dst[perm], weight=edges.weight[perm],
        mask=edges.mask[perm], n_nodes=edges.n_nodes,
    )
    m = int(edges.num_real_edges())
    # compaction pinned off: this bench tracks the ONE-program cache path
    # (cold vs cached latency, retrace counts); the ladder default would
    # add per-rung programs.  The ladder has its own tracked baseline in
    # bench_peel_compaction.py.
    prob = Problem.undirected(eps=args.eps, max_passes=args.max_passes,
                              compaction="off")
    report = {
        "n_nodes": args.n,
        "n_edges": m,
        "eps": args.eps,
        "max_passes": args.max_passes,
    }

    # ---- cold vs cached solve -------------------------------------------
    solver = Solver()
    cold_s, _ = _timed(lambda: solver.solve(edges, prob))
    warm = min(_timed(lambda: solver.solve(edges, prob))[0] for _ in range(args.repeats))
    same_shape = min(
        _timed(lambda: solver.solve(other, prob))[0] for _ in range(args.repeats)
    )
    report["solve"] = {
        "cold_compile_s": round(cold_s, 4),
        "cached_same_graph_s": round(warm, 4),
        "cached_same_shape_new_graph_s": round(same_shape, 4),
        "compile_overhead_x": round(cold_s / max(warm, 1e-9), 1),
        "trace_count": solver.trace_count,
        "cache_hits": solver.cache_hits,
        "cache_misses": solver.cache_misses,
    }
    print("solve:", report["solve"])
    assert solver.trace_count == 1, "same-shape solves must not retrace"

    # ---- batched sweep vs sequential ------------------------------------
    eps_grid = [round(0.1 + 0.1 * i, 3) for i in range(args.grid)]
    batch_solver = Solver()
    batch_cold, _ = _timed(
        lambda: batch_solver.solve_batch(
            edges, Problem.undirected(max_passes=args.max_passes), eps=eps_grid
        )
    )
    batch_warm = min(
        _timed(
            lambda: batch_solver.solve_batch(
                edges, Problem.undirected(max_passes=args.max_passes), eps=eps_grid
            )
        )[0]
        for _ in range(args.repeats)
    )

    seq_solver = Solver()
    probs = [
        Problem.undirected(eps=e, max_passes=args.max_passes, compaction="off")
        for e in eps_grid
    ]
    for p in probs:  # warm every per-eps program
        seq_solver.solve(edges, p)

    def run_seq():
        return [seq_solver.solve(edges, p) for p in probs]

    seq_warm = min(_timed(run_seq)[0] for _ in range(args.repeats))
    report["solve_batch"] = {
        "eps_grid": eps_grid,
        "batch_cold_s": round(batch_cold, 4),
        "batch_warm_s": round(batch_warm, 4),
        "sequential_warm_s": round(seq_warm, 4),
        "batch_speedup_x": round(seq_warm / max(batch_warm, 1e-9), 2),
        "batch_trace_count": batch_solver.trace_count,
    }
    print("solve_batch:", report["solve_batch"])

    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
