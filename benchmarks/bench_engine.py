"""Degree-backend benchmark for the PeelEngine: exact vs Count-Sketch vs
Pallas tiled, same policy and graph — the perf baseline future PRs compare
against.  Run with::

    PYTHONPATH=src python -m benchmarks.bench_engine [--n 200000] [--avg-deg 10]

Writes experiments/bench/engine_backends.csv.
"""

from __future__ import annotations

import argparse
import os
import time

import jax

from repro.core.countsketch import SketchBackend, make_sketch_params
from repro.core.engine import ExactBackend, UndirectedThreshold, run_peel
from repro.graph.generators import chung_lu_power_law


def _time(fn, *args, repeats: int = 3):
    out = fn(*args)  # compile
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best, out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=200_000)
    ap.add_argument("--avg-deg", type=float, default=10.0)
    ap.add_argument("--eps", type=float, default=0.5)
    ap.add_argument("--max-passes", type=int, default=64)
    ap.add_argument("--sketch-b", type=int, default=1 << 15)
    ap.add_argument("--tile-size", type=int, default=2048)
    args = ap.parse_args(argv)

    edges = chung_lu_power_law(args.n, exponent=2.0, avg_deg=args.avg_deg, seed=0)
    m = int(edges.num_real_edges())
    policy = UndirectedThreshold(args.eps)
    mp = args.max_passes

    backends = {"exact": ExactBackend()}
    backends["sketch"] = SketchBackend(
        make_sketch_params(t=5, b=args.sketch_b, seed=1)
    )
    try:
        from repro.kernels.peel_degree.ops import (
            degree_backend_from_tiling,
            tiling_for_edges,
        )

        backends["pallas"] = degree_backend_from_tiling(
            tiling_for_edges(edges, tile_size=args.tile_size)
        )
    except Exception as e:  # kernel path unavailable on this platform
        print(f"pallas backend skipped: {type(e).__name__}: {e}")

    rows = []
    ref_rho = None
    for name, backend in backends.items():
        fn = jax.jit(lambda e, b=backend: run_peel(e, policy, b, mp))
        wall, res = _time(fn, edges)
        passes = int(res.passes)
        rho = float(res.best_density)
        if name == "exact":
            ref_rho = rho
        rows.append(
            {
                "backend": name,
                "nodes": args.n,
                "edges": m,
                "passes": passes,
                "wall_s": round(wall, 4),
                "s_per_pass": round(wall / max(passes, 1), 5),
                "edges_per_s": int(m * passes / wall) if wall > 0 else 0,
                "rho": round(rho, 4),
                "rho_vs_exact": round(rho / ref_rho, 4) if ref_rho else 1.0,
            }
        )
        print(rows[-1])

    out_dir = os.path.join("experiments", "bench")
    os.makedirs(out_dir, exist_ok=True)
    keys = list(rows[0])
    csv = "\n".join(
        [",".join(keys)] + [",".join(str(r[k]) for k in keys) for r in rows]
    )
    path = os.path.join(out_dir, "engine_backends.csv")
    with open(path, "w") as f:
        f.write(csv + "\n")
    print(f"wrote {path}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
