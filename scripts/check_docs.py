"""Documentation checks: keep README.md and docs/ honest.

Three checks (CI runs all; the link + rule-table checks also run in
tier-1 via tests/test_docs.py):

1. **Link check** (``--links-only``): every repo path referenced from
   README.md and docs/*.md (``src/...``, ``tests/...``, markdown link
   targets, and dotted ``repro.*`` module names) must exist.  Catches the
   classic rot where a doc keeps pointing at a module a refactor moved.

2. **Rule-table sync** (runs with the link check; jax-free): every rule
   id in docs/analysis.md's rule table exists in the ``repro.analysis``
   registry, and every registered rule (meta rules included) has a row —
   a checker added without documentation, or a stale documented rule,
   fails here.

3. **README snippet smoke**: the first ```python fenced block of README.md
   (the 30-second quickstart) is extracted and executed VERBATIM in a
   subprocess, so the front-door example on the landing page can never
   silently break.

Run from the repo root::

    PYTHONPATH=src python scripts/check_docs.py          # all checks
    python scripts/check_docs.py --links-only            # fast, no jax
"""

from __future__ import annotations

import argparse
import os
import re
import subprocess
import sys
import tempfile

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

# Path-like tokens rooted at a known top-level directory, e.g.
# ``src/repro/core/api.py`` or ``examples/quickstart.py``.
_PATH_RE = re.compile(
    r"\b(?:src|tests|benchmarks|examples|docs|experiments|scripts)"
    r"/[\w./-]+\b"
)
# Markdown link targets: [text](target).
_MDLINK_RE = re.compile(r"\]\(([^)#\s]+)\)")
# Dotted module references, e.g. ``repro.core.mapreduce.mesh_compact_edges``.
_MODULE_RE = re.compile(r"\brepro(?:\.\w+)+")


def _doc_files():
    files = [os.path.join(REPO, "README.md")]
    docs = os.path.join(REPO, "docs")
    if os.path.isdir(docs):
        files += [
            os.path.join(docs, f) for f in sorted(os.listdir(docs))
            if f.endswith(".md")
        ]
    return files


def _check_module_token(token: str):
    """``repro.a.b.c`` resolves component by component under src/repro;
    once a ``.py`` file is hit, the rest are attributes.  Only the FINAL
    component may be an attribute of a package (e.g. ``repro.core.solve``);
    an unresolvable middle component is a rotted reference."""
    parts = token.split(".")[1:]  # drop the leading "repro"
    path = os.path.join(REPO, "src", "repro")
    for i, comp in enumerate(parts):
        as_dir = os.path.join(path, comp)
        as_py = as_dir + ".py"
        if os.path.isdir(as_dir):
            path = as_dir
            continue
        if os.path.isfile(as_py):
            return None  # rest are attributes of the module
        if i == len(parts) - 1:
            return None  # attribute of a package (repro.core.solve)
        return f"module reference {token!r}: {comp!r} not found under {path}"
    return None


def check_links() -> list:
    errors = []
    for doc in _doc_files():
        rel = os.path.relpath(doc, REPO)
        text = open(doc).read()
        # Path and module tokens are checked EVERYWHERE, fenced code blocks
        # included — an example that imports a moved module is still rot.
        # Path tokens are repo-rooted; markdown link targets resolve the
        # way GitHub renders them — relative to the CONTAINING document.
        targets = {(t, REPO) for t in _PATH_RE.findall(text)}
        for m in _MDLINK_RE.finditer(text):
            t = m.group(1)
            if not t.startswith(("http://", "https://", "mailto:")):
                targets.add((t, os.path.dirname(doc)))
        for t, base in sorted(targets):
            p = os.path.normpath(os.path.join(base, t.rstrip("/").rstrip(".")))
            if not os.path.exists(p):
                errors.append(f"{rel}: referenced path {t!r} does not exist")
        for token in sorted(set(_MODULE_RE.findall(text))):
            err = _check_module_token(token)
            if err:
                errors.append(f"{rel}: {err}")
    return errors


def check_rule_table() -> list:
    """docs/analysis.md's rule table <-> the repro.analysis registry, both
    directions.  repro.analysis is deliberately jax-free, so this check
    runs everywhere the link check does."""
    sys.path.insert(0, os.path.join(REPO, "src"))
    from repro.analysis import all_rules

    doc = os.path.join(REPO, "docs", "analysis.md")
    if not os.path.isfile(doc):
        return ["docs/analysis.md is missing (rule table lives there)"]
    documented = set()
    for line in open(doc):
        m = re.match(r"\|\s*`([\w-]+)`\s*\|", line)
        if m:
            documented.add(m.group(1))
    registered = set(all_rules())
    errors = []
    for rid in sorted(registered - documented):
        errors.append(
            f"docs/analysis.md: registered rule {rid!r} has no table row"
        )
    for rid in sorted(documented - registered):
        errors.append(
            f"docs/analysis.md: documented rule {rid!r} is not in the "
            "repro.analysis registry"
        )
    return errors


def extract_readme_snippet() -> str:
    text = open(os.path.join(REPO, "README.md")).read()
    m = re.search(r"```python\n(.*?)```", text, re.DOTALL)
    if not m:
        raise SystemExit("README.md has no ```python quickstart block")
    return m.group(1)


def run_readme_snippet() -> int:
    snippet = extract_readme_snippet()
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "readme_quickstart.py")
        with open(path, "w") as f:
            f.write(snippet)
        print("--- running README quickstart snippet verbatim ---")
        proc = subprocess.run([sys.executable, path], env=env, cwd=td)
    return proc.returncode


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--links-only", action="store_true",
                    help="skip the snippet execution (no jax import)")
    args = ap.parse_args(argv)

    errors = check_links()
    for e in errors:
        print(f"LINK ERROR: {e}", file=sys.stderr)
    n_docs = len(_doc_files())
    print(f"link check: {n_docs} docs scanned, {len(errors)} errors")
    rule_errors = check_rule_table()
    for e in rule_errors:
        print(f"RULE TABLE ERROR: {e}", file=sys.stderr)
    print(f"rule-table sync: {len(rule_errors)} errors")
    if errors or rule_errors:
        return 1
    if args.links_only:
        return 0
    return run_readme_snippet()


if __name__ == "__main__":
    raise SystemExit(main())
