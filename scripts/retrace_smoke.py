"""Retrace-budget smoke: the README quickstart shape never retraces twice.

The Solver's whole point (see docs/compaction.md and the cache-key rule in
docs/analysis.md) is that same-shape requests hit the in-memory program
cache: ``trace_count`` grows only on a genuine cache miss — one trace per
(problem key, pow2 rung bucket) — and repeat solves, same-bucket graphs,
and repeat sweeps retrace **nothing**.  This smoke pins those counts for
the README-quickstart-shaped workload, so a change that silently widens a
cache key (or reads a key-exempt field inside a builder) fails CI with the
counter diff instead of shipping a 10x compile-time regression.

Run from the repo root (CI runs it next to the tier-1 suite)::

    PYTHONPATH=src python scripts/retrace_smoke.py
"""

from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import Problem, Solver  # noqa: E402
from repro.graph.generators import planted_dense_subgraph  # noqa: E402


def check(label: str, stats: dict, expect: dict) -> list:
    errors = []
    for key, want in expect.items():
        got = stats[key]
        if got != want:
            errors.append(f"{label}: {key}={got}, pinned {want}")
    # The structural invariant behind every pin: a trace happens only on
    # a program-cache miss, never on a hit.
    if stats["trace_count"] != stats["cache_misses"]:
        errors.append(
            f"{label}: trace_count={stats['trace_count']} != "
            f"cache_misses={stats['cache_misses']} — a cache hit retraced"
        )
    status = "ok" if not errors else "FAIL"
    print(
        f"{status:>4}  {label}: misses={stats['cache_misses']} "
        f"hits={stats['cache_hits']} traces={stats['trace_count']}"
    )
    return errors


def main() -> int:
    solver = Solver()  # fresh counters; no persistent tier
    errors = []

    edges, _ = planted_dense_subgraph(
        n=2000, avg_deg=4, k=60, p_dense=0.6, seed=7
    )
    prob = Problem.undirected(eps=0.5)

    # 1. Cold solve: the compaction ladder compiles exactly TWO programs —
    #    the ingest rung at the graph's own (n, E) shape, plus one compacted
    #    rung at the pow2 bucket (256 nodes / 2048 edges) the survivors
    #    shrink into.  (Pins assume the quickstart graph: one ladder step.)
    solver.solve(edges, prob)
    errors += check(
        "cold solve", solver.stats(),
        {"cache_misses": 2, "trace_count": 2, "cache_hits": 0,
         "cached_programs": 2},
    )

    # 2. Same graph + problem again: every rung lookup hits, ZERO traces.
    solver.solve(edges, prob)
    errors += check(
        "repeat solve", solver.stats(),
        {"cache_misses": 2, "trace_count": 2, "cache_hits": 2,
         "cached_programs": 2},
    )

    # 3. A different graph of the same shape class (same n, ~same m,
    #    different seed): the ingest rung keys on the exact edge-array
    #    shape, so a different m is ONE honest miss — but the compacted
    #    pow2 rung is shared across graphs and must hit.
    edges2, _ = planted_dense_subgraph(
        n=2000, avg_deg=4, k=60, p_dense=0.6, seed=8
    )
    solver.solve(edges2, prob)
    errors += check(
        "same-bucket rung", solver.stats(),
        {"cache_misses": 3, "trace_count": 3, "cache_hits": 3,
         "cached_programs": 3},
    )

    # 4. The README eps sweep: one new vmapped program for the batch shape.
    solver.solve_batch(
        edges, Problem.undirected(max_passes=64), eps=[0.1, 0.5, 1.0]
    )
    errors += check(
        "eps sweep", solver.stats(),
        {"cache_misses": 4, "trace_count": 4, "cache_hits": 3,
         "cached_programs": 4},
    )

    # 5. Sweep again: the batched program is cached too.
    solver.solve_batch(
        edges, Problem.undirected(max_passes=64), eps=[0.1, 0.5, 1.0]
    )
    errors += check(
        "repeat sweep", solver.stats(),
        {"cache_misses": 4, "trace_count": 4, "cache_hits": 4,
         "cached_programs": 4},
    )

    if errors:
        print("\nretrace smoke FAILED:", file=sys.stderr)
        for e in errors:
            print(f"  {e}", file=sys.stderr)
        return 1
    print("retrace smoke: all counters at pinned values")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
