#!/usr/bin/env python
"""Stdlib line-coverage mirror of the CI coverage gate (no pytest-cov).

CI's tests-fast job gates on ``pytest --cov=repro --cov-fail-under=N``
(.github/workflows/ci.yml); the dev image has no coverage tooling, so
this script reproduces the measurement with a ``sys.settrace`` hook that
instruments ONLY frames under src/repro (everything else returns None
from the tracer, so jax/numpy internals run untraced at full speed) and
derives the denominator from compiled code objects (``co_lines``), a
close approximation of coverage.py's statement set.

Usage:  PYTHONPATH=src python scripts/line_cov.py [extra pytest args]

Runs the not-slow suite by default (exactly what CI gates on) and prints
per-package and total percentages.  The committed ``--cov-fail-under``
floor in ci.yml sits a few points below this script's measurement to
absorb the (small, systematic) difference from coverage.py's parser.
"""

import os
import sys
import threading

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src", "repro")

_covered = {}  # abspath -> set of executed line numbers


def _make_local(lines):
    def local(frame, event, arg):
        if event == "line":
            lines.add(frame.f_lineno)
        return local

    return local


def _tracer(frame, event, arg):
    if event != "call":
        return None
    fn = frame.f_code.co_filename
    if not fn.startswith(SRC):
        return None
    lines = _covered.setdefault(fn, set())
    lines.add(frame.f_lineno)
    return _make_local(lines)


def _executable_lines(path):
    with open(path, encoding="utf-8") as fh:
        code = compile(fh.read(), path, "exec")
    lines = set()
    stack = [code]
    while stack:
        c = stack.pop()
        lines.update(ln for _, _, ln in c.co_lines() if ln)
        stack.extend(k for k in c.co_consts if hasattr(k, "co_lines"))
    return lines


def main(argv):
    threading.settrace(_tracer)
    sys.settrace(_tracer)
    import pytest  # after settrace: collection-time imports count

    rc = pytest.main(
        ["-q", "-m", "not slow", os.path.join(REPO, "tests"), *argv]
    )
    sys.settrace(None)
    threading.settrace(None)
    if rc != 0:
        print("line_cov: test run failed; coverage not reported")
        return int(rc)

    total_hit = total_lines = 0
    rows = []
    for dirpath, _, names in sorted(os.walk(SRC)):
        for name in sorted(names):
            if not name.endswith(".py"):
                continue
            path = os.path.join(dirpath, name)
            want = _executable_lines(path)
            hit = want & _covered.get(path, set())
            total_hit += len(hit)
            total_lines += len(want)
            pct = 100.0 * len(hit) / len(want) if want else 100.0
            rows.append((pct, os.path.relpath(path, REPO), len(hit), len(want)))
    for pct, rel, h, w in sorted(rows):
        print(f"{pct:6.1f}%  {h:5d}/{w:<5d}  {rel}")
    grand = 100.0 * total_hit / total_lines if total_lines else 100.0
    print(f"TOTAL {grand:.2f}%  ({total_hit}/{total_lines} lines)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
