#!/usr/bin/env python
"""Invariant-linter front door: runs repro.analysis over the library tree.

Usage (from the repo root)::

    PYTHONPATH=src python scripts/analyze.py              # lint src/repro
    PYTHONPATH=src python scripts/analyze.py --strict     # CI gate
    PYTHONPATH=src python scripts/analyze.py --list-rules
    PYTHONPATH=src python scripts/analyze.py path/to/file.py   # fixture mode

Paths given explicitly as FILES are analyzed unscoped — every rule runs
regardless of its path scope (how the fixture corpus trips rules that
normally apply only inside src/repro).  Directories are walked scoped.

Exit status: 0 when clean; 1 when any finding (``--strict``) or any
error-severity finding (default) survives suppression.  No jax import
anywhere on this path — the gate runs in a bare CPython.
"""

from __future__ import annotations

import argparse
import os
import sys

_HERE = os.path.dirname(os.path.abspath(__file__))
_ROOT = os.path.dirname(_HERE)
sys.path.insert(0, os.path.join(_ROOT, "src"))

from repro.analysis import (  # noqa: E402  (path bootstrap above)
    Project,
    all_rules,
    analyze_paths,
    render_finding,
)

_DEFAULT_TARGETS = ("src/repro",)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="analyze.py", description="repro invariant linter"
    )
    ap.add_argument(
        "paths",
        nargs="*",
        help=(
            "files (analyzed unscoped: all rules) and/or directories "
            "(walked scoped); default: src/repro"
        ),
    )
    ap.add_argument(
        "--strict",
        action="store_true",
        help="exit 1 on ANY finding, warnings included (the CI gate)",
    )
    ap.add_argument(
        "--list-rules",
        action="store_true",
        help="print every rule id + summary and exit",
    )
    ap.add_argument(
        "--root",
        default=_ROOT,
        help="repo root anchoring relative paths (default: this repo)",
    )
    args = ap.parse_args(argv)

    if args.list_rules:
        for rid, summary in all_rules().items():
            print(f"{rid:20s} {summary}")
        return 0

    project = Project.load(args.root if args.root != _ROOT else None)
    targets = args.paths or [os.path.join(args.root, t) for t in _DEFAULT_TARGETS]

    findings = []
    for t in targets:
        ap_t = os.path.abspath(t)
        scoped = os.path.isdir(ap_t)
        findings.extend(
            analyze_paths([ap_t], root=args.root, project=project, scoped=scoped)
        )

    for f in findings:
        print(render_finding(f))
    gating = [
        f for f in findings if args.strict or f.severity == "error"
    ]
    n = len(findings)
    print(
        f"analyze: {n} finding{'s' if n != 1 else ''}"
        + (f" ({len(gating)} gating)" if n else "")
    )
    return 1 if gating else 0


if __name__ == "__main__":
    sys.exit(main())
