"""Algorithm 1 behaviour tests: approximation guarantee, pass bound, best-set
semantics, weighted graphs, planted-structure recovery."""

import numpy as np
import pytest

from repro.core import (
    charikar_greedy,
    densest_subgraph,
    densest_subgraph_brute,
    densest_subgraph_exact,
    density_of,
    max_passes_bound,
)
from repro.graph import from_numpy
from repro.graph.generators import (
    chung_lu_power_law,
    erdos_renyi,
    lemma5_instance,
    planted_dense_subgraph,
    weighted_preferential,
)


def _density_np(edges, nodes):
    mask = np.asarray(edges.mask)
    src = np.asarray(edges.src)[mask]
    dst = np.asarray(edges.dst)[mask]
    w = np.asarray(edges.weight)[mask]
    inset = np.zeros(edges.n_nodes, bool)
    inset[nodes] = True
    return float(np.sum(w * (inset[src] & inset[dst]))) / max(len(nodes), 1)


def test_k4_plus_pendant():
    # K4 on {0,1,2,3} plus pendant 4: densest subgraph is K4 (rho=1.5).
    src = [0, 0, 0, 1, 1, 2, 3]
    dst = [1, 2, 3, 2, 3, 3, 4]
    edges = from_numpy(src, dst, 5)
    res = densest_subgraph(edges, eps=0.001)
    alive = np.nonzero(np.asarray(res.best_alive))[0]
    assert set(alive.tolist()) == {0, 1, 2, 3}
    assert float(res.best_density) == pytest.approx(1.5)


def test_reported_density_matches_recomputation():
    edges = erdos_renyi(200, avg_deg=8, seed=1)
    res = densest_subgraph(edges, eps=0.3)
    nodes = np.nonzero(np.asarray(res.best_alive))[0]
    assert float(res.best_density) == pytest.approx(_density_np(edges, nodes), rel=1e-5)
    assert float(density_of(edges, res.best_alive)) == pytest.approx(
        float(res.best_density), rel=1e-5
    )


@pytest.mark.parametrize("eps", [0.001, 0.1, 0.5, 1.0])
@pytest.mark.parametrize("seed", [0, 1])
def test_approximation_guarantee_vs_exact(eps, seed):
    """Lemma 3: output density >= rho* / (2+2eps) — mirrors paper Table 2."""
    edges = erdos_renyi(120, avg_deg=10, seed=seed)
    _, rho_star = densest_subgraph_exact(edges)
    res = densest_subgraph(edges, eps=eps)
    assert float(res.best_density) >= rho_star / (2 * (1 + eps)) - 1e-6
    assert float(res.best_density) <= rho_star + 1e-6


def test_pass_bound_lemma4():
    """Lemma 4: O(log_{1+eps} n) passes."""
    for eps in (0.1, 0.5, 1.0):
        edges = chung_lu_power_law(3000, avg_deg=10, seed=0)
        res = densest_subgraph(edges, eps=eps)
        assert int(res.passes) <= max_passes_bound(3000, eps)


def test_planted_dense_block_recovered():
    edges, planted = planted_dense_subgraph(500, avg_deg=4, k=30, p_dense=0.8, seed=3)
    res = densest_subgraph(edges, eps=0.25)
    found = set(np.nonzero(np.asarray(res.best_alive))[0].tolist())
    # The dense block dominates; recovered set should be mostly the planted one.
    overlap = len(found & set(planted.tolist()))
    assert overlap >= 0.8 * len(planted)
    assert len(found) <= 3 * len(planted)


def test_weighted_graph_support():
    # Two triangles; one has weight-10 edges -> must win.
    src = np.array([0, 1, 0, 3, 4, 3])
    dst = np.array([1, 2, 2, 4, 5, 5])
    w = np.array([1, 1, 1, 10, 10, 10], np.float32)
    edges = from_numpy(src, dst, 6, weight=w)
    res = densest_subgraph(edges, eps=0.1)
    alive = set(np.nonzero(np.asarray(res.best_alive))[0].tolist())
    assert alive == {3, 4, 5}
    assert float(res.best_density) == pytest.approx(10.0)


def test_weighted_preferential_lemma6_runs_many_passes():
    """Lemma 6's weighted preferential-attachment instance forces more passes
    than a comparable ER graph at the same eps."""
    g_w = weighted_preferential(256)
    g_er = erdos_renyi(256, avg_deg=16, seed=0)
    p_w = int(densest_subgraph(g_w, eps=0.5).passes)
    p_er = int(densest_subgraph(g_er, eps=0.5).passes)
    assert p_w >= p_er


def test_lemma5_instance_pass_count_grows():
    """Lemma 5 construction: passes grow with k (Omega(k/log k))."""
    p_small = int(densest_subgraph(lemma5_instance(3), eps=0.5).passes)
    p_big = int(densest_subgraph(lemma5_instance(5), eps=0.5).passes)
    assert p_big > p_small >= 2


def test_matches_brute_force_on_tiny_graphs():
    rng = np.random.default_rng(0)
    for trial in range(5):
        n = 9
        m = 14
        src = rng.integers(0, n, m)
        dst = rng.integers(0, n, m)
        keep = src != dst
        edges = from_numpy(src[keep], dst[keep], n)
        _, rho_star = densest_subgraph_brute(edges)
        res = densest_subgraph(edges, eps=0.05)
        assert float(res.best_density) >= rho_star / 2.1 - 1e-6
        assert float(res.best_density) <= rho_star + 1e-6


def test_history_trajectory_is_consistent():
    edges = erdos_renyi(300, avg_deg=8, seed=5)
    res = densest_subgraph(edges, eps=0.5)
    t = int(res.passes)
    hn = np.asarray(res.history_n)[:t]
    # Node count strictly decreases (at least one removal per pass).
    assert (np.diff(hn) < 0).all()
    assert hn[0] == 300
    # Density history contains the best density.
    hr = np.asarray(res.history_rho)[:t]
    assert float(res.best_density) == pytest.approx(float(hr.max()), rel=1e-6)


def test_charikar_baseline_quality():
    """The paper's [10] baseline: our eps->0 run should be close to it."""
    edges = erdos_renyi(150, avg_deg=10, seed=2)
    _, rho_greedy = charikar_greedy(edges)
    res = densest_subgraph(edges, eps=0.001)
    # Batched removal with tiny eps ~ Charikar; allow small slack.
    assert float(res.best_density) >= 0.9 * rho_greedy
