"""Substrate tests: checkpointing (atomic/async/keep-k/restore), trainer
restart semantics, resumable pipelines, neighbor sampler, serving engine."""

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import (
    CheckpointManager,
    all_steps,
    restore_checkpoint,
    restore_latest,
    save_checkpoint,
)


# ------------------------------ checkpoint ----------------------------------


def _state(x=1.0):
    return {"w": jnp.full((4, 3), x), "opt": {"m": jnp.zeros(5), "step": jnp.asarray(7)}}


def test_checkpoint_roundtrip(tmp_path):
    d = str(tmp_path / "ck")
    save_checkpoint(d, 10, _state(2.0), metadata={"foo": "bar"})
    restored, meta = restore_checkpoint(d, 10, jax.eval_shape(lambda: _state()))
    assert meta == {"foo": "bar"}
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.full((4, 3), 2.0))
    assert int(restored["opt"]["step"]) == 7


def test_checkpoint_keep_k_and_latest(tmp_path):
    d = str(tmp_path / "ck")
    for s in (1, 2, 3, 4, 5):
        save_checkpoint(d, s, _state(float(s)), keep=2)
    assert all_steps(d) == [4, 5]
    restored, meta, step = restore_latest(d, jax.eval_shape(lambda: _state()))
    assert step == 5
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.full((4, 3), 5.0))


def test_checkpoint_async_then_join(tmp_path):
    d = str(tmp_path / "ck")
    m = CheckpointManager(d, keep=3)
    m.save_async(1, _state(1.5), metadata={"step": 1})
    m.join()
    assert m.latest_step() == 1


def test_checkpoint_atomicity_no_partial_dir(tmp_path):
    """tmp dirs never count as checkpoints."""
    d = str(tmp_path / "ck")
    os.makedirs(os.path.join(d, "tmp.99.123"))
    assert all_steps(d) == []


def test_checkpoint_reshard_on_restore(tmp_path):
    """Restore with explicit shardings (elastic-rescale path)."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    d = str(tmp_path / "ck")
    save_checkpoint(d, 1, {"w": jnp.arange(8.0)})
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1), ("data",))
    sh = {"w": NamedSharding(mesh, P("data"))}
    restored, _ = restore_checkpoint(
        d, 1, jax.eval_shape(lambda: {"w": jnp.arange(8.0)}), shardings=sh
    )
    assert restored["w"].sharding == sh["w"]


# ------------------------------- trainer ------------------------------------


def _toy_trainer(tmp_path, total=10, ckpt_every=3):
    from repro.data.pipeline import SyntheticStream
    from repro.train.trainer import Trainer, TrainerConfig

    def make(rng, step):
        return jnp.asarray(rng.standard_normal(4).astype(np.float32))

    data = SyntheticStream(make, seed=1)

    @jax.jit
    def step_fn(state, batch):
        new = state + jnp.sum(batch)
        return new, {"loss": jnp.sum(batch) ** 2}

    cfg = TrainerConfig(
        total_steps=total, ckpt_dir=str(tmp_path / "ck"), ckpt_every=ckpt_every,
        log_every=1,
    )
    return Trainer(cfg, step_fn, jnp.zeros(()), data)


def test_trainer_runs_and_checkpoints(tmp_path):
    tr = _toy_trainer(tmp_path)
    out = tr.run()
    assert out["status"] == "done" and out["step"] == 10
    assert tr.ckpt.latest_step() == 10


def test_trainer_restart_is_bitwise_identical(tmp_path):
    """Run 10 steps straight vs 10 steps with a crash+restart at step 6:
    final state and batch stream must match exactly (resumable pipeline)."""
    ref = _toy_trainer(tmp_path / "a", total=10)
    ref_out = ref.run()
    ref_state = np.asarray(ref.state)

    tr1 = _toy_trainer(tmp_path / "b", total=10, ckpt_every=3)
    tr1.cfg = dataclasses.replace(tr1.cfg, total_steps=6)
    tr1.run()  # saves at step 6 on completion
    tr2 = _toy_trainer(tmp_path / "b", total=10, ckpt_every=3)
    assert tr2.try_restore()
    assert tr2.step == 6  # steps 0..5 done; next step to execute is 6
    # state must continue from the checkpoint; drive to completion
    out = tr2.run()
    assert out["step"] == 10
    np.testing.assert_allclose(np.asarray(tr2.state), ref_state, rtol=1e-6)


def test_trainer_watchdog(tmp_path):
    import time

    from repro.data.pipeline import SyntheticStream
    from repro.train.trainer import StepTimeout, Trainer, TrainerConfig

    def make(rng, step):
        return jnp.zeros(1)

    def slow_step(state, batch):
        time.sleep(0.2)
        return state, {"loss": jnp.zeros(())}

    cfg = TrainerConfig(
        total_steps=3, ckpt_dir=str(tmp_path / "ck"), ckpt_every=0,
        step_timeout_s=0.05,
    )
    tr = Trainer(cfg, slow_step, jnp.zeros(()), SyntheticStream(make))
    with pytest.raises(StepTimeout):
        tr.run()
    # the watchdog checkpointed before aborting
    assert tr.ckpt.latest_step() >= 0


# ------------------------------- pipeline -----------------------------------


def test_stream_restart_reproduces_batches():
    from repro.data.pipeline import lm_token_stream

    s1 = lm_token_stream(vocab=100, batch=2, seq=8, seed=3)
    batches = [next(s1) for _ in range(5)]
    ck = None
    s2 = lm_token_stream(vocab=100, batch=2, seq=8, seed=3)
    for i in range(3):
        next(s2)
    ck = s2.checkpoint_state()
    s3 = lm_token_stream(vocab=100, batch=2, seq=8, seed=999)
    s3.restore(ck)
    for i in (3, 4):
        b = next(s3)
        np.testing.assert_array_equal(
            np.asarray(b["tokens"]), np.asarray(batches[i]["tokens"])
        )


# ------------------------------- sampler ------------------------------------


def test_layered_sampler_shapes_and_validity():
    from repro.graph.sampler import CSRGraph, LayeredSampler

    rng = np.random.default_rng(0)
    n, e = 500, 3000
    src = rng.integers(0, n, e).astype(np.int32)
    dst = rng.integers(0, n, e).astype(np.int32)
    g = CSRGraph.from_edges(src, dst, n)
    labels = rng.integers(0, 7, n)
    s = LayeredSampler(g, labels, batch_nodes=16, fanout=(5, 3), seed=2)
    b = next(s)
    assert b["hop0"].shape == (16,)
    assert b["hop1"].shape == (16, 5) and b["hop2"].shape == (16, 5, 3)
    # every unmasked hop1 neighbor is a real neighbor of its root
    adj = {i: set() for i in range(n)}
    for u, v in zip(src, dst):
        adj[u].add(v)
        adj[v].add(u)
    for i in range(16):
        root = b["hop0"][i]
        for j in range(5):
            if b["hop1_mask"][i, j] > 0:
                assert b["hop1"][i, j] in adj[root]
    # determinism + resumability
    s2 = LayeredSampler(g, labels, batch_nodes=16, fanout=(5, 3), seed=2)
    b2 = next(s2)
    np.testing.assert_array_equal(b["hop1"], b2["hop1"])


def test_sampler_isolated_nodes_masked():
    from repro.graph.sampler import CSRGraph, LayeredSampler

    # star graph: node 0 connected to 1..4; nodes 5..9 isolated
    src = np.zeros(4, np.int32)
    dst = np.arange(1, 5, dtype=np.int32)
    g = CSRGraph.from_edges(src, dst, 10)
    s = LayeredSampler(g, np.zeros(10), batch_nodes=10, fanout=(3, 2), seed=0)
    b = next(s)
    roots = b["hop0"]
    iso = roots >= 5
    assert (b["hop1_mask"][iso] == 0).all()


# ----------------------------- serve engine ---------------------------------


def test_serve_engine_matches_full_forward():
    """Greedy continuous-batched decode == argmax chain of full forwards."""
    from repro.configs import get_arch
    from repro.models.transformer import forward
    from repro.serve.engine import Request, ServeEngine
    from repro.train.step import init_model_params

    spec = get_arch("llama3.2-3b")
    cfg = dataclasses.replace(spec.reduced_config, remat=False)
    params = init_model_params(spec, jax.random.PRNGKey(0), cfg=cfg)
    rng = np.random.default_rng(0)
    prompts = [
        rng.integers(0, cfg.vocab, p, dtype=np.int32) for p in (5, 9, 7)
    ]
    eng = ServeEngine(params, cfg, n_slots=2, max_len=64)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new=4))
    done = eng.run_to_completion()
    assert len(done) == 3
    for req in done:
        # reference: greedy argmax over repeated full forwards
        toks = list(req.prompt)
        for _ in range(4):
            logits, _ = forward(params, cfg, jnp.asarray(toks, jnp.int32)[None])
            toks.append(int(jnp.argmax(logits[0, -1])))
        assert req.tokens == toks[len(req.prompt):], (req.rid, req.tokens, toks[len(req.prompt):])
