"""Launch-layer coverage: cell building, sharding sanitization, HLO
analyzer and roofline math.  Mesh-dependent parts run in a subprocess with
forced host devices (jax locks the device count at first init)."""

import json
import subprocess
import sys

import pytest

from repro.launch import hlo_stats
from repro.launch.roofline import Roofline

# ------------------------------ hlo_stats -----------------------------------

_TOY_HLO = """
%body.1 (p.1: (s32[], f32[8,128])) -> (s32[], f32[8,128]) {
  %p.1 = (s32[], f32[8,128]) parameter(0)
  %g0 = s32[] get-tuple-element(%p.1), index=0
  %g1 = f32[8,128]{1,0} get-tuple-element(%p.1), index=1
  %c1 = s32[] constant(1)
  %add.1 = s32[] add(%g0, %c1)
  %w = f32[128,128]{1,0} constant({...})
  %dot.1 = f32[8,128]{1,0} dot(%g1, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,128]{1,0} all-reduce(%dot.1), channel_id=1, replica_groups=[2,4]<=[8], to_apply=%sum
  ROOT %t = (s32[], f32[8,128]) tuple(%add.1, %ar)
}

%cond.1 (p.2: (s32[], f32[8,128])) -> pred[] {
  %p.2 = (s32[], f32[8,128]) parameter(0)
  %g2 = s32[] get-tuple-element(%p.2), index=0
  %c10 = s32[] constant(10)
  ROOT %lt = pred[] compare(%g2, %c10), direction=LT
}

ENTRY %main.1 (a: f32[8,128]) -> f32[8,128] {
  %a = f32[8,128]{1,0} parameter(0)
  %c0 = s32[] constant(0)
  %t0 = (s32[], f32[8,128]) tuple(%c0, %a)
  %w1 = (s32[], f32[8,128]) while(%t0), condition=%cond.1, body=%body.1
  ROOT %out = f32[8,128]{1,0} get-tuple-element(%w1), index=1
}
"""


def test_hlo_stats_trip_count_and_flops():
    stats = hlo_stats.analyze(_TOY_HLO, n_devices=8)
    # dot: 2*8*128*128 flops, x10 loop trips
    assert stats["flops"] == pytest.approx(2 * 8 * 128 * 128 * 10)
    # all-reduce over groups of 4: 2 * 4KiB * 3/4 per trip
    assert stats["collective_bytes"] == pytest.approx(
        2 * (8 * 128 * 4) * 3 / 4 * 10
    )
    assert stats["unknown_trip_loops"] == 0


def test_hlo_stats_promoted_allreduce_halved():
    hlo = _TOY_HLO.replace("to_apply=%sum", "to_apply=%add.clone_promoted")
    stats = hlo_stats.analyze(hlo, n_devices=8)
    assert stats["collective_bytes"] == pytest.approx(
        2 * (8 * 128 * 2) * 3 / 4 * 10  # bf16 wire
    )


def test_roofline_terms_and_bound():
    r = Roofline(
        arch="x", shape="y", mesh="16x16", n_devices=256,
        flops_per_dev=197e12,  # exactly 1 second of compute
        hbm_bytes_per_dev=819e9 / 2,  # 0.5 s
        coll_bytes_per_dev=50e9 * 2,  # 2 s
        model_flops_total=197e12 * 256 / 2,  # half the compiled flops useful
    )
    assert r.compute_s == pytest.approx(1.0)
    assert r.memory_s == pytest.approx(0.5)
    assert r.collective_s == pytest.approx(2.0)
    assert r.bound == "collective"
    assert r.model_flops_ratio == pytest.approx(0.5)
    # useful/chips/peak = 0.5 s; step = 2 s -> 25%
    assert r.roofline_fraction == pytest.approx(0.25)


# --------------------------- cell building (subprocess) ---------------------

_CELL_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
from repro.launch.mesh import make_mesh
from repro.launch.cells import build_cell, lower_cell, SkipCell
from repro.launch import hlo_stats

mesh = make_mesh((2, 4), ("data", "model"))
out = {}
for arch, shape in [
    ("llama3.2-3b", "train_4k"),
    ("graphsage-reddit", "ogb_products"),
    ("two-tower-retrieval", "serve_p99"),
    ("densest-mapreduce", "flickr_sm"),
]:
    cell = build_cell(arch, shape, mesh=mesh)
    compiled = lower_cell(cell).compile()
    stats = hlo_stats.analyze(compiled.as_text(), 8)
    out[f"{arch}/{shape}"] = {
        "flops": stats["flops"], "coll": stats["collective_bytes"],
    }
# skip machinery
try:
    build_cell("qwen2-72b", "long_500k", mesh=mesh)
    out["skip"] = "MISSED"
except SkipCell:
    out["skip"] = "ok"
print("RESULT=" + json.dumps(out))
"""


@pytest.mark.slow
def test_cells_compile_on_small_mesh():
    res = subprocess.run(
        [sys.executable, "-c", _CELL_SCRIPT],
        capture_output=True, text=True, timeout=1200,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
    )
    line = [l for l in res.stdout.splitlines() if l.startswith("RESULT=")]
    assert line, res.stderr[-2000:]
    out = json.loads(line[0][len("RESULT="):])
    assert out["skip"] == "ok"
    assert out["llama3.2-3b/train_4k"]["flops"] > 1e12
    assert out["densest-mapreduce/flickr_sm"]["coll"] > 0
