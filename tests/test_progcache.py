"""Persistent program cache (core/progcache.py + Solver disk tier) and the
bounded in-memory cache.

The serving contract under test:

  * a FRESH process with a warm ``cache_dir`` compiles ZERO programs — the
    executable loads from disk (``trace_count == 0``, ``disk_hits`` > 0) and
    the answer is bit-identical to the compiling process's;
  * corrupted or version-stale entries silently fall back to a recompile
    (and are overwritten with a good entry);
  * ``cache_dir`` is cache-key-exempt: toggling it never mints a program;
  * ``max_cached_programs`` bounds the in-memory cache with LRU eviction.
"""

import os
import pickle
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import Problem, Solver
from repro.core import progcache
from repro.graph.generators import chung_lu_power_law

N = 1500
PROB = dict(eps=0.5, max_passes=12)


def _graph(seed=0, n=N):
    return chung_lu_power_law(n, exponent=2.0, avg_deg=6.0, seed=seed)


def _prob(**kw):
    base = dict(PROB)
    base.update(kw)
    return Problem.undirected(**base)


# ---------------------------------------------------------------------------
# disk round trip
# ---------------------------------------------------------------------------


def test_disk_cache_round_trip_same_process(tmp_path):
    d = str(tmp_path / "cache")
    edges = _graph()
    s1 = Solver(cache_dir=d)
    r1 = s1.solve(edges, _prob(compaction="off"))
    assert s1.disk_misses == 1 and s1.disk_hits == 0
    assert s1.trace_count == 1
    assert len(os.listdir(d)) == 1

    # A second Solver (fresh in-memory cache, same disk) never traces.
    s2 = Solver(cache_dir=d)
    r2 = s2.solve(edges, _prob(compaction="off"))
    assert s2.trace_count == 0
    assert s2.disk_hits == 1 and s2.disk_misses == 0
    assert float(r1.best_density) == float(r2.best_density)
    assert np.array_equal(np.asarray(r1.best_alive), np.asarray(r2.best_alive))


def test_disk_cache_serves_the_compaction_ladder(tmp_path):
    # Every cseg (ladder rung) program rides the disk tier too.
    d = str(tmp_path / "cache")
    edges = _graph()
    s1 = Solver(cache_dir=d)
    r1 = s1.solve(edges, _prob())  # compaction='auto' -> geometric
    assert s1.disk_misses >= 2  # multiple rung programs
    s2 = Solver(cache_dir=d)
    r2 = s2.solve(edges, _prob())
    assert s2.trace_count == 0 and s2.disk_misses == 0
    assert s2.disk_hits == s1.disk_misses
    assert float(r1.best_density) == float(r2.best_density)


@pytest.mark.slow
def test_disk_cache_round_trip_fresh_subprocess(tmp_path):
    """The cold-start win itself: a brand-new PROCESS compiles nothing."""
    d = str(tmp_path / "cache")
    edges = _graph()
    s1 = Solver(cache_dir=d)
    r1 = s1.solve(edges, _prob(compaction="off"))
    script = textwrap.dedent(
        f"""
        import numpy as np
        from repro.core import Problem, Solver
        from repro.graph.generators import chung_lu_power_law

        edges = chung_lu_power_law({N}, exponent=2.0, avg_deg=6.0, seed=0)
        s = Solver(cache_dir={d!r})
        r = s.solve(edges, Problem.undirected(eps=0.5, max_passes=12,
                                              compaction="off"))
        assert s.trace_count == 0, f"fresh process traced: {{s.trace_count}}"
        assert s.disk_hits == 1 and s.disk_misses == 0, (
            s.disk_hits, s.disk_misses)
        print("DENSITY", repr(float(r.best_density)))
        print("PROGCACHE_SUBPROC_OK")
        """
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath("src") + os.pathsep + env.get(
        "PYTHONPATH", ""
    )
    out = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert "PROGCACHE_SUBPROC_OK" in out.stdout
    density = float(out.stdout.split("DENSITY", 1)[1].split()[0])
    assert density == float(r1.best_density)


# ---------------------------------------------------------------------------
# fallback paths
# ---------------------------------------------------------------------------


def test_corrupted_entry_falls_back_and_heals(tmp_path):
    d = str(tmp_path / "cache")
    edges = _graph()
    baseline = float(Solver(cache_dir=d).solve(edges, _prob(compaction="off")).best_density)
    (entry,) = os.listdir(d)
    with open(os.path.join(d, entry), "wb") as f:
        f.write(b"\x00not a pickle")
    s = Solver(cache_dir=d)
    r = s.solve(edges, _prob(compaction="off"))
    assert s.disk_hits == 0 and s.disk_misses == 1
    assert s.trace_count == 1  # recompiled
    assert float(r.best_density) == baseline
    # ...and the recompile overwrote the bad entry with a good one.
    s2 = Solver(cache_dir=d)
    s2.solve(edges, _prob(compaction="off"))
    assert s2.disk_hits == 1 and s2.trace_count == 0


def test_stale_fingerprint_reads_as_miss(tmp_path):
    d = str(tmp_path / "cache")
    edges = _graph()
    Solver(cache_dir=d).solve(edges, _prob(compaction="off"))
    (entry,) = os.listdir(d)
    path = os.path.join(d, entry)
    with open(path, "rb") as f:
        blob = pickle.loads(f.read())
    blob["fingerprint"] = dict(blob["fingerprint"], jaxlib="0.0.0-other")
    with open(path, "wb") as f:
        f.write(pickle.dumps(blob))
    s = Solver(cache_dir=d)
    s.solve(edges, _prob(compaction="off"))
    assert s.disk_hits == 0 and s.trace_count == 1


def test_load_missing_and_key_mismatch(tmp_path):
    assert progcache.load(str(tmp_path / "nope.jaxprog"), ("k",)) is None
    # Same file, different key: the in-payload key check rejects it.
    d = str(tmp_path / "cache")
    edges = _graph()
    Solver(cache_dir=d).solve(edges, _prob(compaction="off"))
    (entry,) = os.listdir(d)
    assert progcache.load(os.path.join(d, entry), ("other-key",)) is None


def test_store_is_best_effort(tmp_path):
    # An unserializable object must not raise out of store().
    assert progcache.store(str(tmp_path / "x.jaxprog"), ("k",), object()) is False


# ---------------------------------------------------------------------------
# cache-key exemption + LRU bound
# ---------------------------------------------------------------------------


def test_cache_dir_is_cache_key_exempt(tmp_path):
    edges = _graph()
    s = Solver()
    s.solve(edges, _prob(compaction="off"))
    before = s.cache_size()
    s.solve(edges, _prob(compaction="off", cache_dir=str(tmp_path)))
    assert s.cache_size() == before  # no new program
    assert s.cache_hits >= 1


def test_solver_cache_dir_wins_over_problem(tmp_path):
    d_solver = str(tmp_path / "solver")
    d_prob = str(tmp_path / "problem")
    edges = _graph()
    s = Solver(cache_dir=d_solver)
    s.solve(edges, _prob(compaction="off", cache_dir=d_prob))
    assert os.path.isdir(d_solver) and len(os.listdir(d_solver)) == 1
    assert not os.path.exists(d_prob)


def test_lru_bound_evicts_and_counts(tmp_path):
    g_small = _graph(n=600)
    g_mid = _graph(n=900)
    g_big = _graph(n=1200)
    s = Solver(max_cached_programs=2)
    s.solve(g_small, _prob(compaction="off"))
    s.solve(g_mid, _prob(compaction="off"))
    assert s.cache_size() == 2 and s.cache_evictions == 0
    s.solve(g_big, _prob(compaction="off"))
    assert s.cache_size() == 2 and s.cache_evictions == 1
    # g_small's program was the LRU victim: solving it again is a miss...
    misses = s.cache_misses
    s.solve(g_small, _prob(compaction="off"))
    assert s.cache_misses == misses + 1
    # ...while g_big (recently used) is still resident.
    hits = s.cache_hits
    s.solve(g_big, _prob(compaction="off"))
    assert s.cache_hits == hits + 1


def test_lru_eviction_reloads_from_disk(tmp_path):
    # Evicted programs with a disk entry come back without a recompile.
    d = str(tmp_path / "cache")
    g_small = _graph(n=600)
    g_big = _graph(n=1200)
    s = Solver(cache_dir=d, max_cached_programs=1)
    s.solve(g_small, _prob(compaction="off"))
    s.solve(g_big, _prob(compaction="off"))  # evicts g_small's program
    assert s.cache_evictions == 1
    traces = s.trace_count
    s.solve(g_small, _prob(compaction="off"))
    assert s.trace_count == traces  # reloaded from disk, not recompiled
    assert s.disk_hits == 1


def test_lru_default_is_unbounded():
    s = Solver()
    assert s.max_cached_programs is None
    for n in (600, 900, 1200):
        s.solve(_graph(n=n), _prob(compaction="off"))
    assert s.cache_size() == 3 and s.cache_evictions == 0


def test_max_cached_programs_validated():
    with pytest.raises(ValueError):
        Solver(max_cached_programs=0)
