"""Count-Sketch (§5.1) tests: estimator accuracy on heavy nodes, sketched
peeling quality (Table 4 analogue)."""

import numpy as np

from repro.core import (
    densest_subgraph,
    densest_subgraph_sketched,
    make_sketch_params,
    query_degrees,
    sketch_degrees_from_edges,
)
from repro.core.density import alive_edge_weight
from repro.graph.generators import chung_lu_power_law, planted_dense_subgraph

import jax.numpy as jnp


def _exact_degrees_np(edges):
    mask = np.asarray(edges.mask)
    src = np.asarray(edges.src)[mask]
    dst = np.asarray(edges.dst)[mask]
    deg = np.zeros(edges.n_nodes)
    np.add.at(deg, src, 1)
    np.add.at(deg, dst, 1)
    return deg


def test_sketch_accurate_on_heavy_nodes():
    edges = chung_lu_power_law(2000, avg_deg=10, seed=0)
    deg = _exact_degrees_np(edges)
    p = make_sketch_params(t=5, b=1 << 12, seed=1)
    alive = jnp.ones((edges.n_nodes,), bool)
    counters = sketch_degrees_from_edges(p, edges, alive_edge_weight(edges, alive))
    est = np.asarray(query_degrees(p, counters, jnp.arange(edges.n_nodes)))
    heavy = deg >= np.quantile(deg, 0.99)
    rel_err = np.abs(est[heavy] - deg[heavy]) / np.maximum(deg[heavy], 1)
    # Count-Sketch guarantee: heavy hitters estimated well.
    assert np.median(rel_err) < 0.15


def test_sketch_error_decreases_with_buckets():
    edges = chung_lu_power_law(2000, avg_deg=10, seed=0)
    deg = _exact_degrees_np(edges)
    alive = jnp.ones((edges.n_nodes,), bool)
    errs = []
    for b in (1 << 8, 1 << 10, 1 << 13):
        p = make_sketch_params(t=5, b=b, seed=2)
        counters = sketch_degrees_from_edges(p, edges, alive_edge_weight(edges, alive))
        est = np.asarray(query_degrees(p, counters, jnp.arange(edges.n_nodes)))
        errs.append(np.mean(np.abs(est - deg)))
    assert errs[2] < errs[1] < errs[0]


def test_sketched_peeling_close_to_exact():
    """Table 4 analogue: sketched density within a modest factor of exact."""
    edges, _ = planted_dense_subgraph(1500, avg_deg=4, k=40, p_dense=0.8, seed=4)
    exact = float(densest_subgraph(edges, eps=0.5).best_density)
    sk = float(
        densest_subgraph_sketched(edges, eps=0.5, t=5, b=1 << 12, seed=0).best_density
    )
    assert sk >= 0.75 * exact  # paper sees 0.89-1.05 at eps<=1
    assert sk <= 1.25 * exact


def test_sketch_memory_is_sublinear():
    p = make_sketch_params(t=5, b=1 << 10)
    # 5 * 1024 counters vs n=100k degree floats.
    assert p.n_tables * p.n_buckets < 100_000 // 2
