"""Algorithm 2 (size >= k) tests: size constraint, approximation, pass count."""

import numpy as np
import pytest

from repro.core import (
    densest_subgraph,
    densest_subgraph_at_least_k,
    densest_subgraph_exact,
)
from repro.graph.generators import erdos_renyi, planted_dense_subgraph


@pytest.mark.parametrize("k", [5, 20, 60])
def test_size_constraint_respected(k):
    edges = erdos_renyi(150, avg_deg=8, seed=0)
    res = densest_subgraph_at_least_k(edges, k=k, eps=0.5)
    assert int(res.best_size) >= k
    alive = np.asarray(res.best_alive)
    assert alive.sum() == int(res.best_size)


def test_matches_unconstrained_when_k_small():
    """Lemma 10 regime: if the optimum has more than k nodes, Algorithm 2
    achieves the same (2+2eps) guarantee."""
    edges = erdos_renyi(150, avg_deg=10, seed=1)
    nodes_star, rho_star = densest_subgraph_exact(edges)
    k = max(2, len(nodes_star) // 2)
    res = densest_subgraph_at_least_k(edges, k=k, eps=0.25)
    assert float(res.best_density) >= rho_star / (2 * 1.25) - 1e-6


def test_theorem9_bound_when_k_large():
    """(3+3eps) guarantee vs the size-constrained optimum (checked against the
    unconstrained optimum which upper-bounds it)."""
    edges, _ = planted_dense_subgraph(300, avg_deg=4, k=25, p_dense=0.9, seed=2)
    k = 100  # force a set bigger than the planted block
    res = densest_subgraph_at_least_k(edges, k=k, eps=0.5)
    assert int(res.best_size) >= k
    _, rho_star = densest_subgraph_exact(edges)
    # rho*_{>=k} <= rho*; the bound below is necessary, not sufficient, but
    # catches gross regressions.
    assert float(res.best_density) <= rho_star + 1e-5
    assert float(res.best_density) > 0.0


def test_fractional_removal_makes_more_passes():
    """Algorithm 2 removes fewer nodes per pass than Algorithm 1 =>
    at least as many passes."""
    edges = erdos_renyi(400, avg_deg=8, seed=3)
    p1 = int(densest_subgraph(edges, eps=0.5).passes)
    p2 = int(densest_subgraph_at_least_k(edges, k=2, eps=0.5).passes)
    assert p2 >= p1
