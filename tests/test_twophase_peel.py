"""Two-phase compacted distributed peel == single-phase peel (same best
density and set): compaction is pure renumbering, Lemma 4 bounds phase-2
size.  Runs on a 1-device mesh (the collective structure is identical)."""

import jax
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.core.mapreduce import (
    make_distributed_peel,
    make_distributed_peel_twophase,
    shard_edges,
)
from repro.graph import generators as gen


def _mesh():
    return Mesh(np.asarray(jax.devices()[:1]).reshape(1), ("data",))


@pytest.mark.parametrize("seed,eps,k1", [(0, 0.5, 3), (1, 1.0, 2), (2, 0.3, 5)])
def test_twophase_matches_single_phase(seed, eps, k1):
    edges, _ = gen.planted_dense_subgraph(
        n=400, avg_deg=4.0, k=40, p_dense=0.6, seed=seed
    )
    mesh = _mesh()
    sh = shard_edges(edges, mesh, ("data",))
    one = make_distributed_peel(mesh, ("data",), eps=eps, n_nodes=sh.n_nodes)
    two = make_distributed_peel_twophase(
        mesh, ("data",), eps=eps, n_nodes=sh.n_nodes, phase1_passes=k1
    )
    r1 = one(sh.src, sh.dst, sh.weight, sh.mask)
    r2 = two(sh.src, sh.dst, sh.weight, sh.mask)
    assert float(r2.best_density) == pytest.approx(float(r1.best_density), rel=1e-6)
    np.testing.assert_array_equal(
        np.asarray(r1.best_alive), np.asarray(r2.best_alive)
    )


def test_twophase_lemma4_bound_holds():
    """After k passes the alive count is below n/(1+eps)^k (the static size
    the compaction relies on)."""
    from repro.core.peel import densest_subgraph

    edges = gen.chung_lu_power_law(n=5000, exponent=2.0, avg_deg=10.0, seed=3)
    eps = 0.5
    res = densest_subgraph(edges, eps=eps, track_history=True)
    hn = np.asarray(res.history_n)[: int(res.passes)]
    for k in range(1, len(hn)):
        assert hn[k] <= edges.n_nodes / (1 + eps) ** k + 1e-9


def test_distributed_topk_meets_guarantee():
    """Distributed Algorithm 2: |S~| >= k and rho(S~) within (3+3eps) of the
    best-known >=k density (checked against exhaustive peel candidates)."""
    from repro.core.density import density_of
    from repro.core.mapreduce import make_distributed_topk_peel
    from repro.core.peel_topk import densest_subgraph_at_least_k

    eps, k = 0.5, 30
    edges, _ = gen.planted_dense_subgraph(
        n=300, avg_deg=4.0, k=25, p_dense=0.8, seed=7
    )
    mesh = _mesh()
    sh = shard_edges(edges, mesh, ("data",))
    fn = make_distributed_topk_peel(
        mesh, ("data",), k=k, eps=eps, n_nodes=sh.n_nodes
    )
    r = fn(sh.src, sh.dst, sh.weight, sh.mask)
    n_sel = int(np.asarray(r.best_alive).sum())
    assert n_sel >= k
    # density of the returned set really is its density
    assert float(density_of(sh, r.best_alive)) == pytest.approx(
        float(r.best_density), rel=1e-5
    )
    # agrees with the single-device Algorithm 2 within the approximation
    ref = densest_subgraph_at_least_k(edges, k=k, eps=eps)
    assert float(r.best_density) >= float(ref.best_density) / (3 * (1 + eps))
