"""Turnstile runtime: ℓ0-sampling sketches + dynamic-stream maintenance.

Contracts under test:

  * **hashing dedup regression** — ``kernels/hashing.py`` is bit-identical
    to the original Count-Sketch inline formula on fixed seeds (the
    refactor must not move any bucket or flip any sign);
  * **sketch linearity** — delta(A) + delta(B) == delta(A ∪ B) bitwise,
    sketch merge equivalence, insert-then-delete restores exact zeros;
  * **recovery** — level 0 when the live graph fits the budget (the
    sample IS the graph), fingerprint validation never admits a false
    edge even at tiny cell counts, numpy decoder mirrors == XLA hashes;
  * **accuracy** — sampled-peel density on a churned stream (>= 20 %
    deletions, planted dense block) stays inside the MTVV
    (1+eps)(2+2eps) envelope, seed for seed, with
    :func:`repro.graph.edgelist.apply_updates` as the exact reference;
  * **compile economics** — same-bucket update batches reuse ONE traced
    program (``trace_count``);
  * **front door** — ``Problem(stream_mode='turnstile')`` validation
    matrix, one-shot ``solve()`` equivalence, serve-layer caching.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import Problem, Solver, TurnstileDensest, TurnstileSketch, solve
from repro.core import countsketch
from repro.core.countsketch import make_sketch_params
from repro.core.turnstile import (
    _np_edge_cells,
    _np_edge_fingerprint,
    _np_edge_level,
)
from repro.graph.edgelist import EdgeList, apply_updates, from_numpy
from repro.graph.generators import chung_lu_power_law, planted_dense_subgraph
from repro.kernels import hashing
from repro.kernels.l0_sampler import (
    edge_cells,
    edge_fingerprint,
    edge_level,
    l0_delta,
    make_l0_params,
)


def _live_edges(g: EdgeList):
    m = int(np.asarray(g.mask).sum())
    return np.asarray(g.src)[:m].copy(), np.asarray(g.dst)[:m].copy()


def _edge_keys(u, v, n):
    lo = np.minimum(u, v).astype(np.int64)
    hi = np.maximum(u, v).astype(np.int64)
    return lo * n + hi


# -- hashing dedup regression (satellite: countsketch must not move) --------


def test_hashing_matches_original_countsketch_formula():
    """The shared mix32/bucket32/sign32 reproduce the pre-refactor inline
    Count-Sketch hash bit for bit on fixed seeds."""
    rng = np.random.default_rng(7)
    a = (rng.integers(0, 1 << 31, 4, dtype=np.uint32) * 2 + 1).astype(np.uint32)
    c = rng.integers(0, 1 << 31, 4, dtype=np.uint32)
    x = rng.integers(0, 1 << 31, 257, dtype=np.uint32)
    n_buckets = 1 << 10
    for j in range(4):
        with np.errstate(over="ignore"):
            h = np.uint32(a[j]) * x + np.uint32(c[j])
            h = h ^ (h >> np.uint32(16))
        got = np.asarray(
            hashing.mix32(jnp.uint32(a[j]), jnp.uint32(c[j]), jnp.asarray(x))
        )
        np.testing.assert_array_equal(got, h)
        np.testing.assert_array_equal(
            np.asarray(hashing.bucket32(jnp.asarray(h), n_buckets)),
            (h % np.uint32(n_buckets)).astype(np.int32),
        )
        np.testing.assert_array_equal(
            np.asarray(hashing.sign32(jnp.asarray(h))),
            np.where((h >> np.uint32(31)) == 0, 1.0, -1.0).astype(np.float32),
        )


def test_countsketch_hashes_pinned_on_fixed_seed():
    """End-to-end pin: SketchParams(seed=3) buckets/signs equal the
    original formula applied to the stored multipliers."""
    p = make_sketch_params(3, 512, seed=3)
    ids = jnp.arange(1000, dtype=jnp.int32)
    got_b = np.asarray(countsketch._hash_bucket(p, ids))
    got_s = np.asarray(countsketch._hash_sign(p, ids))
    a_h, c_h = np.asarray(p.a_h), np.asarray(p.c_h)
    a_g, c_g = np.asarray(p.a_g), np.asarray(p.c_g)
    x = np.arange(1000, dtype=np.uint32)
    for j in range(3):
        with np.errstate(over="ignore"):
            hb = a_h[j] * x + c_h[j]
            hb ^= hb >> np.uint32(16)
            hs = a_g[j] * x + c_g[j]
            hs ^= hs >> np.uint32(16)
        np.testing.assert_array_equal(got_b[j], (hb % np.uint32(512)).astype(np.int32))
        np.testing.assert_array_equal(
            got_s[j], np.where((hs >> np.uint32(31)) == 0, 1.0, -1.0)
        )


def test_decoder_numpy_mirrors_match_xla_hashes():
    """The host decoder's numpy re-hashes are bit-identical to the XLA
    ops that built the sketch (wraparound uint32 semantics match)."""
    p = make_l0_params(n_levels=16, n_cells=1 << 9, n_tables=3, seed=11)
    rng = np.random.default_rng(0)
    u = rng.integers(0, 5000, 400).astype(np.int32)
    v = (u + 1 + rng.integers(0, 100, 400)).astype(np.int32)
    uj, vj = jnp.asarray(u), jnp.asarray(v)
    np.testing.assert_array_equal(np.asarray(edge_level(p, uj, vj)), _np_edge_level(p, u, v))
    np.testing.assert_array_equal(np.asarray(edge_cells(p, uj, vj)), _np_edge_cells(p, u, v))
    np.testing.assert_array_equal(
        np.asarray(edge_fingerprint(p, uj, vj)).view(np.int32),
        _np_edge_fingerprint(p, u, v),
    )


# -- sketch linearity -------------------------------------------------------


def test_l0_delta_is_linear():
    """delta(A) + delta(B) == delta(A ∪ B) bit for bit."""
    p = make_l0_params(n_levels=12, n_cells=1 << 8, n_tables=3, seed=2)
    rng = np.random.default_rng(1)
    u = rng.integers(0, 2000, 600).astype(np.int32)
    v = rng.integers(0, 2000, 600).astype(np.int32)
    s = np.where(rng.random(600) < 0.7, 1, -1).astype(np.int32)
    half = 300
    dA = l0_delta(jnp.asarray(u[:half]), jnp.asarray(v[:half]), jnp.asarray(s[:half]), p, use_pallas=False)
    dB = l0_delta(jnp.asarray(u[half:]), jnp.asarray(v[half:]), jnp.asarray(s[half:]), p, use_pallas=False)
    dAB = l0_delta(jnp.asarray(u), jnp.asarray(v), jnp.asarray(s), p, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(dA) + np.asarray(dB), np.asarray(dAB))


def test_l0_pallas_interpret_matches_reference():
    """The Pallas kernel (interpret mode) is bit-identical to the
    segment-sum reference, including sign-0 padding rows."""
    p = make_l0_params(n_levels=8, n_cells=1 << 8, n_tables=3, seed=4)
    rng = np.random.default_rng(2)
    u = jnp.asarray(rng.integers(0, 3000, 300).astype(np.int32))
    v = jnp.asarray(rng.integers(0, 3000, 300).astype(np.int32))
    s = jnp.asarray(np.where(rng.random(300) < 0.6, 1, -1).astype(np.int32))
    ref = l0_delta(u, v, s, p, use_pallas=False)
    ker = l0_delta(u, v, s, p, use_pallas=True, interpret=True)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(ker))


def test_sketch_merge_equals_union_and_validates():
    g = chung_lu_power_law(600, seed=9)
    src, dst = _live_edges(g)
    half = len(src) // 2
    sA = TurnstileSketch(600, 1 << 9, seed=5).apply((src[:half], dst[:half]))
    sB = TurnstileSketch(600, 1 << 9, seed=5).apply((src[half:], dst[half:]))
    sAB = TurnstileSketch(600, 1 << 9, seed=5).apply((src, dst))
    sA.merge(sB)
    np.testing.assert_array_equal(np.asarray(sA.tables), np.asarray(sAB.tables))
    with pytest.raises(ValueError, match="identical geometry"):
        sA.merge(TurnstileSketch(600, 1 << 9, seed=6))
    with pytest.raises(TypeError):
        sA.merge("not a sketch")


def test_insert_then_delete_restores_exact_zeros():
    g = chung_lu_power_law(500, seed=1)
    src, dst = _live_edges(g)
    sk = TurnstileSketch(500, 1 << 9, seed=0)
    sk.apply(insert_edges=(src, dst))
    assert np.asarray(sk.tables).any()
    # Delete with REVERSED endpoints: canonicalization makes them cancel.
    sk.apply(delete_edges=(dst, src))
    assert not np.asarray(sk.tables).any()
    edges, level, info = sk.recover()
    assert len(edges) == 0 and level == 0 and info["exact"]


def test_same_seed_is_bit_reproducible():
    g = chung_lu_power_law(800, seed=3)
    src, dst = _live_edges(g)
    prob = Problem.undirected(
        stream_mode="turnstile", sample_edges=1 << 10, sketch_seed=42
    )
    tds = [TurnstileDensest(800, prob, solver=Solver()) for _ in range(2)]
    for td in tds:
        td.apply(insert_edges=(src, dst))
        td.apply(delete_edges=(src[:50], dst[:50]))
    np.testing.assert_array_equal(
        np.asarray(tds[0].sketch.tables), np.asarray(tds[1].sketch.tables)
    )
    r0, r1 = tds[0].query(), tds[1].query()
    assert float(r0.best_density) == float(r1.best_density)


# -- recovery ---------------------------------------------------------------


def test_exact_recovery_when_graph_fits_budget():
    """m <= tau: level 0, the recovered sample IS the live edge set."""
    g = chung_lu_power_law(400, seed=8)
    src, dst = _live_edges(g)
    sk = TurnstileSketch(400, 1 << 11, seed=1)
    sk.apply((src, dst))
    edges, level, info = sk.recover()
    assert level == 0 and info["exact"]
    assert info["sample_rate"] == 1.0
    got = set(_edge_keys(edges[:, 0], edges[:, 1], 400).tolist())
    want = set(_edge_keys(src, dst, 400).tolist())
    assert got == want


def test_recovery_never_fabricates_edges_at_tiny_cell_count():
    """With C far below m the low levels cannot decode; whatever level
    finally decodes must contain ONLY true edges (fingerprint + cell +
    level re-hash validation)."""
    g = chung_lu_power_law(3000, avg_deg=4.0, seed=6)
    src, dst = _live_edges(g)
    sk = TurnstileSketch(3000, 256, seed=2)
    sk.apply((src, dst))
    edges, level, info = sk.recover()
    assert level > 0  # the whole graph cannot possibly fit 256 cells
    want = set(_edge_keys(src, dst, 3000).tolist())
    got = _edge_keys(edges[:, 0], edges[:, 1], 3000)
    assert set(got.tolist()) <= want
    assert info["sample_edges_recovered"] == len(edges) <= info["level_suffix_count"]


def test_corrupted_stream_degrades_but_never_fabricates():
    """Deleting a never-inserted edge leaves count -3 debris that blocks
    level 0 (it can never peel to all-zeros); recover() climbs past the
    corruption, counts the failures, and still returns only true edges."""
    sk = TurnstileSketch(100, 256, seed=0)
    sk.apply(insert_edges=np.asarray([[0, 1], [1, 2]]))
    sk.apply(delete_edges=np.asarray([[7, 9], [7, 9], [7, 9]]))  # count -3
    edges, level, info = sk.recover()
    assert sk.recovery_failures >= 1 and level >= 1  # level 0 is corrupt
    want = set(_edge_keys(np.asarray([0, 1]), np.asarray([1, 2]), 100).tolist())
    got = set(_edge_keys(edges[:, 0], edges[:, 1], 100).tolist())
    assert got <= want


# -- compile economics ------------------------------------------------------


def test_update_compiles_once_per_batch_bucket():
    sk = TurnstileSketch(2000, 1 << 9, seed=0)
    rng = np.random.default_rng(0)
    for _ in range(4):  # four same-bucket batches -> one trace
        e = rng.integers(0, 2000, (500, 2)).astype(np.int32)
        sk.apply(insert_edges=e)
    assert sk.trace_count == 1
    assert sk.batches_applied == 4 and sk.updates_applied == 2000
    sk.apply(insert_edges=rng.integers(0, 2000, (3000, 2)).astype(np.int32))
    assert sk.trace_count == 2  # new pow2 bucket -> exactly one more trace


# -- accuracy under churn (the MTVV envelope) -------------------------------


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_churn_density_within_envelope(seed):
    """Power-law background + planted dense block, >= 20 % deletions:
    the sampled-peel density stays within (1+eps)(2+2eps) of the exact
    insert-mode peel on the surviving graph (apply_updates reference)."""
    n, eps = 4000, 0.3
    g, _ = planted_dense_subgraph(n, 6.0, 120, 0.6, seed=seed)
    src, dst = _live_edges(g)
    m = len(src)
    rng = np.random.default_rng(1000 + seed)
    n_del = int(0.3 * m)  # 30 % churn, above the 20 % floor
    del_idx = rng.choice(m, size=n_del, replace=False)
    deletes = np.stack([src[del_idx], dst[del_idx]], axis=1)
    base = from_numpy(src, dst, n)
    final, stats = apply_updates(base, deletes=deletes)
    assert stats["deleted"] == n_del and stats["missing_deletes"] == 0

    prob = Problem.undirected(
        eps=eps, stream_mode="turnstile", sample_edges=1 << 11, sketch_seed=seed
    )
    td = TurnstileDensest(n, prob, solver=Solver())
    td.apply(insert_edges=(src, dst))
    td.apply(delete_edges=(deletes[:, 0], deletes[:, 1]))
    res = td.query()
    info = res.extras["turnstile"]
    assert info["level"] >= 1  # ~11k live edges cannot fit 2048: a real sample

    exact = solve(final, Problem.undirected(eps=eps, compaction="off"))
    envelope = (1 + eps) * (2 + 2 * eps)
    ratio = float(res.best_density) / float(exact.best_density)
    assert 1.0 / envelope <= ratio <= envelope, (ratio, info)


# -- front door -------------------------------------------------------------


def test_problem_validation_matrix():
    with pytest.raises(ValueError, match="stream_mode"):
        Problem.undirected(stream_mode="bogus")
    with pytest.raises(ValueError, match="sample_edges"):
        Problem.undirected(stream_mode="turnstile", sample_edges=0)
    with pytest.raises(ValueError, match="objective='undirected'"):
        Problem.directed(stream_mode="turnstile").resolve(100)
    with pytest.raises(ValueError, match="sketch a sketch"):
        Problem.undirected(stream_mode="turnstile", backend="sketch").resolve(100)
    with pytest.raises(ValueError, match="substrate"):
        Problem.undirected(stream_mode="turnstile", substrate="mesh").resolve(100)
    # Compaction is an irrelevant knob: quietly forced off, never an error.
    p = Problem.undirected(stream_mode="turnstile", compaction="geometric").resolve(100)
    assert p.compaction == "off" and p.substrate == "jit" and p.backend == "exact"


def test_one_shot_solve_matches_insert_mode_when_exact():
    """m <= tau: the front-door turnstile solve recovers the WHOLE graph
    (level 0) and its density equals the plain insert-mode solve."""
    g = chung_lu_power_law(1200, seed=5)
    r_t = solve(g, Problem.undirected(stream_mode="turnstile"))
    r_i = solve(g, Problem.undirected(compaction="off"))
    assert float(r_t.best_density) == pytest.approx(float(r_i.best_density))
    info = r_t.extras["turnstile"]
    assert info["exact"] and info["level"] == 0
    assert r_t.provenance.substrate == "turnstile"


def test_solve_turnstile_rejects_directed_and_weighted():
    src = np.asarray([0, 1, 2], np.int32)
    dst = np.asarray([1, 2, 0], np.int32)
    d = from_numpy(src, dst, 3, directed=True)
    with pytest.raises(ValueError, match="undirected"):
        solve(d, Problem.undirected(stream_mode="turnstile"))
    w = from_numpy(src, dst, 3, weight=np.asarray([2.0, 1.0, 1.0], np.float32))
    with pytest.raises(ValueError, match="unweighted"):
        solve(w, Problem.undirected(stream_mode="turnstile"))


def test_solve_batch_rejects_turnstile():
    from repro.core import solve_batch

    g = chung_lu_power_law(300, seed=0)
    with pytest.raises(ValueError, match="turnstile"):
        solve_batch(
            g,
            Problem.undirected(stream_mode="turnstile"),
            eps=[0.25, 0.5],
        )


# -- exact host reference (apply_updates) -----------------------------------


def test_apply_updates_semantics():
    base = from_numpy(
        np.asarray([0, 1, 2], np.int32), np.asarray([1, 2, 3], np.int32), 5
    )
    # Reversed endpoints match; survivors keep stable order; inserts append.
    out, stats = apply_updates(
        base,
        inserts=np.asarray([[3, 4], [4, 3]]),  # within-batch dup collapses
        deletes=np.asarray([[2, 1], [0, 4]]),  # one live, one missing
    )
    assert stats == {
        "dup_inserts": 1,
        "missing_deletes": 1,
        "deleted": 1,
        "inserted": 1,
    }
    u, v = _live_edges(out)
    np.testing.assert_array_equal(u, [0, 2, 3])
    np.testing.assert_array_equal(v, [1, 3, 4])
    # Inserting a live edge is a counted no-op (set semantics).
    out2, stats2 = apply_updates(out, inserts=np.asarray([[1, 0]]))
    assert stats2["dup_inserts"] == 1 and stats2["inserted"] == 0
    np.testing.assert_array_equal(np.asarray(out2.src), np.asarray(out.src))
    # Same edge on both sides of one batch is order-ambiguous.
    with pytest.raises(ValueError, match="must not insert and delete"):
        apply_updates(base, inserts=np.asarray([[0, 1]]), deletes=np.asarray([[1, 0]]))


# -- serving ----------------------------------------------------------------


def test_serve_service_caches_between_updates():
    from repro.serve import DensestQueryEngine, TurnstileDensityService

    g = chung_lu_power_law(700, seed=2)
    src, dst = _live_edges(g)
    svc = TurnstileDensityService(
        700, Problem.undirected(stream_mode="turnstile", sample_edges=1 << 10)
    )
    svc.apply(insert_edges=(src, dst))
    d1 = svc.density()
    d2 = svc.density()  # no update in between: served from cache
    assert d1 == d2
    assert svc.stats()["queries_served"] == 2
    assert svc.stats()["queries_computed"] == 1
    svc.apply(delete_edges=(src[:40], dst[:40]))
    svc.density()
    assert svc.stats()["queries_computed"] == 2

    eng = DensestQueryEngine(g).attach_turnstile(svc)
    assert eng.current_density() == svc.density()
    assert svc.stats()["queries_computed"] == 2  # attachment reads the cache
    with pytest.raises(ValueError, match="n_nodes"):
        DensestQueryEngine(g).attach_turnstile(TurnstileDensityService(701))
    with pytest.raises(ValueError, match="attach_turnstile"):
        DensestQueryEngine(g).current_density()


def test_empty_sketch_query_is_well_defined():
    td = TurnstileDensest(
        50, Problem.undirected(stream_mode="turnstile"), solver=Solver()
    )
    res = td.query()
    assert float(res.best_density) == 0.0
    assert res.extras["turnstile"]["sample_edges_recovered"] == 0


# -- property: update-linearity on arbitrary stream splits ------------------
# Written with hypothesis when available (CI installs it), seeded
# parametrization otherwise — either way the property itself runs.

try:
    from hypothesis import given, settings, strategies as st

    _prop = lambda f: settings(max_examples=15, deadline=None)(  # noqa: E731
        given(st.integers(0, 2**31 - 1), st.integers(1, 80))(f)
    )
except ImportError:
    _prop = lambda f: pytest.mark.parametrize(  # noqa: E731
        "seed,cut", [(0, 1), (1, 37), (2, 80), (3, 50), (4, 99)]
    )(f)


@_prop
def test_property_split_invariance(seed, cut):
    """Any split of an update stream into batches yields the same sketch
    (linearity + commutativity of the donated update program)."""
    rng = np.random.default_rng(seed)
    k = 100
    e = rng.integers(0, 500, (k, 2)).astype(np.int32)
    cut = min(cut, k - 1)
    one = TurnstileSketch(500, 256, seed=9).apply(insert_edges=e)
    two = (
        TurnstileSketch(500, 256, seed=9)
        .apply(insert_edges=e[:cut])
        .apply(insert_edges=e[cut:])
    )
    np.testing.assert_array_equal(np.asarray(one.tables), np.asarray(two.tables))
