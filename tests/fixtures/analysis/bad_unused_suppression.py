"""Trips unused-suppression: a well-formed exemption matching no finding."""


def harmless(x: int) -> int:
    # repro: allow(atomic-io) stale: the write this guarded was deleted (finding)
    return x + 1
