"""Trips cache-key: exempt-field reads in traced code + a drifted registry.

The `_FIELD_CLASS` / `Problem` pair here is a miniature of the real one
in core/api.py, drifted in all three ways the rule closes off: an
unclassified field, a stale entry, and a bogus classification value.
The exempt-field reads use REAL exempt names from the repo registry
(``stream_chunk``, ``cache_dir``) — fixture mode runs against the real
project surfaces.
"""

import dataclasses

import jax


def _build_solve_program(prob, n_pad):
    chunk = prob.stream_chunk  # exempt field inside a builder (finding)

    def run(edges):
        return edges[:chunk]

    return jax.jit(run)


@jax.jit
def _kernel(prob, x):
    cache = prob.cache_dir  # exempt read in a traced def (finding)
    del cache
    return -x


@dataclasses.dataclass(frozen=True)
class Problem:
    eps: float = 0.1
    objective: str = "densest"
    shiny_new_knob: int = 0  # not classified below (finding)


_FIELD_CLASS = {
    "eps": "static",
    "objective": "decorative",  # not static/conditional/exempt (finding)
    "renamed_away": "exempt",  # matches no Problem field (finding)
}
