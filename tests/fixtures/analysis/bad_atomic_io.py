"""Trips atomic-io: every raw-write shape the rule closes off.

The os.replace line reproduces the PR 8 near-miss verbatim: a checkpoint
published with a bare rename, no tmp-file fsync — the acceptance
criterion's "deliberately reintroduce a raw os.replace checkpoint write"
case.
"""

import os
import pathlib


def save_checkpoint(state: bytes, path: str) -> None:
    with open(path + ".new", "wb") as f:  # raw write-mode open (finding)
        f.write(state)
    os.replace(path + ".new", path)  # raw publish outside ioutil (finding)


def log_line(path: str, line: str) -> None:
    with open(path, mode="a") as f:  # append is write-capable too (finding)
        f.write(line)


def flush_hard(f) -> None:
    f.flush()
    os.fsync(f.fileno())  # durability outside ioutil (finding)


def sidecar(path: str, text: str) -> None:
    pathlib.Path(path).write_text(text)  # bypasses the primitive (finding)
