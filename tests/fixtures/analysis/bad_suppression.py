"""Trips bad-suppression four ways; the unjustified one also leaves its
violation unsuppressed (a bad suppression never suppresses)."""

import os


def unjustified(tmp: str, final: str) -> None:
    # repro: allow(atomic-io)
    os.replace(tmp, final)  # stays a finding: suppression above has no why


def unknown_rule(x: int) -> int:
    # repro: allow(definitely-not-a-rule) nobody checked the rule id
    return x


def meta_rule(x: int) -> int:
    # repro: allow(bad-suppression) the exemption mechanism cannot exempt itself
    return x


def malformed(x: int) -> int:
    # repro: allow atomic-io forgot the parens
    return x
