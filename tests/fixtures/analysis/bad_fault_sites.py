"""Trips fault-sites: an unregistered site name and a hook-less IO try."""

import json

from repro import faults


def publish(path: str, payload: dict) -> bool:
    faults.fire("streaming.checkpoint_svae")  # typo'd site name (finding)
    return True


def load(path: str):
    try:  # except-wrapped IO with no fire() hook (finding)
        with open(path) as f:
            return json.load(f)
    except Exception:
        return None
