"""Trips single-engine twice: a re-derived threshold and a shadow def."""


def peel_once(eps, rho, degs):
    thresh = 2.0 * (1.0 + eps) * rho  # re-typed threshold (finding)
    return degs < thresh


def removal_threshold(eps, rho):  # shadow of the engine's one site (finding)
    return (1 + eps) * 2 * rho  # reversed operand order (finding)
