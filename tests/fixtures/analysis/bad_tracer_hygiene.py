"""Trips tracer-hygiene: every host-round-trip shape inside traced defs."""

from functools import partial

import jax
import numpy as np


@jax.jit
def branchy(x, y):
    if x > 0:  # Python branch on a traced value (finding)
        return y
    while y.sum() < 0:  # Python loop on a traced value (finding)
        y = y + 1
    return y if y.size else x  # ternary is host control flow too (finding)


@partial(jax.jit, static_argnames=("n",))
def casts(v, n):
    k = int(v[0])  # host cast of a traced value (finding)
    a = np.asarray(v)  # host numpy on a traced value (finding)
    jax.device_get(v)  # explicit transfer (finding)
    v.block_until_ready()  # sync point (finding)
    return a[: n + k]


def _inner(z):
    return float(z)  # traced via the jit() call below (finding)


run = jax.jit(_inner)
