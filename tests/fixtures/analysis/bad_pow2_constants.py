"""Trips pow2-constants: literal floors at call sites and a re-typed alias."""

from repro.graph.partition import ladder_schedule, pow2_bucket

_REBUILD_NODE_FLOOR = 64  # re-typed capacity constant (finding)


def pad_plan(n_alive: int, m0: int):
    n_pad = pow2_bucket(n_alive, 64)  # literal positional floor (finding)
    cap = pow2_bucket(n_alive, floor=256)  # literal keyword floor (finding)
    rungs = ladder_schedule(m0, floor=4096, stride=4)  # both literal (2 findings)
    return n_pad, cap, rungs
