"""Zero findings: real violations, each under a justified suppression."""

import os


def publish(tmp: str, final: str) -> None:
    # repro: allow(atomic-io) fixture pin: standalone comment covers the next line
    os.replace(tmp, final)


def append(path: str, line: str) -> None:
    with open(path, "a") as f:  # repro: allow(atomic-io) fixture pin: trailing comment covers its own line
        f.write(line)
