"""Zero findings: rule-shaped text in strings and comments is inert.

The threshold below is inside a string literal; the os.replace is in a
comment; the allow() syntax inside a string must NOT parse as a
suppression (and therefore must NOT raise unused-suppression either).
"""

DOC = """
The peel threshold is 2.0 * (1.0 + eps) * rho and a checkpoint published
with os.replace(tmp, final) would be torn-write unsafe.
"""

HOWTO = "# repro: allow(atomic-io) this is a string, not a comment"

# A comment mentioning open(path, "w") and os.fsync(fd) is not a call.


def documented(x: int) -> int:
    """pow2_bucket(n, 64) in a docstring is prose, not a call site."""
    return x
