"""Andersen local exploration (core/local.py) + the ``substrate='local'``
lowering (core/api.py) + the serving engine's ``extraction='local'`` mode
(serve/densest.py).

Contracts under test:

  * **explorer invariants** — candidates are sorted, unique, contain the
    seed, respect the budget; repeated queries on one explorer are
    deterministic and leave the scratch arrays clean;
  * **pruning semantics** — a clique closes over itself while a pendant
    path hanging off it is pruned away (``frontier_exhausted``); budget=1
    returns exactly the seed; an isolated seed exhausts immediately;
  * **api lowering** — ``Problem(substrate='local')`` resolution
    (exact backend, compaction forced off), the validation matrix
    (directed objective, sketch/pallas backend, turnstile, mesh, missing
    seed, seed on a whole-graph substrate), provenance + ``extras['local']``
    counters, and the surviving guarantee (result nodes ⊆ candidates,
    density <= exact optimum);
  * **program-cache reuse** — repeated local queries at one bucket never
    retrace;
  * **serving parity** — ``DensestQueryEngine(extraction='local')``
    answers bit-identically to the api front door and the budget-halving
    degrade rung returns REAL (recomputed) data.
"""

import dataclasses

import numpy as np
import pytest

from repro import faults
from repro.core import Problem, Solver, densest_subgraph_brute, solve
from repro.core.local import LocalExplorer, check_count, check_seed
from repro.faults import FaultPlan
from repro.graph.edgelist import from_numpy
from repro.graph.generators import planted_dense_subgraph
from repro.serve.densest import DensestQueryEngine, ResilienceConfig

EPS = 0.5
PROB = Problem.undirected(eps=EPS)
PROB_LOCAL = dataclasses.replace(PROB, substrate="local")


def _planted(n=400, k=30, seed=7):
    return planted_dense_subgraph(n, 4.0, k, 0.6, seed=seed)


def _clique_plus_path(kq=6, path_len=5):
    """A kq-clique with a pendant path hanging off node 0."""
    src, dst = [], []
    for u in range(kq):
        for v in range(u + 1, kq):
            src.append(u)
            dst.append(v)
    for i in range(path_len):
        a = 0 if i == 0 else kq + i - 1
        src.append(a)
        dst.append(kq + i)
    n = kq + path_len
    return from_numpy(np.asarray(src), np.asarray(dst), n), n


# ---------------------------------------------------------------------------
# explorer invariants
# ---------------------------------------------------------------------------


def test_explore_invariants_and_determinism():
    g, planted = _planted()
    ex = LocalExplorer.from_edgelist(g)
    for s in [int(planted[0]), 0, 17]:
        a = ex.explore(s, budget=64)
        b = ex.explore(s, budget=64)  # same explorer: scratch must be clean
        np.testing.assert_array_equal(a.candidates, b.candidates)
        c = a.candidates
        assert s in c
        assert len(c) <= 64
        assert np.array_equal(c, np.unique(c))  # sorted + unique
        assert a.nodes_touched >= len(c)
        assert a.edges_scanned > 0
    # Scratch arrays are fully reset after queries.
    assert not ex._member.any()
    assert not ex._deg_t.any()


def test_budget_one_returns_exactly_the_seed():
    g, _ = _planted()
    ex = LocalExplorer.from_edgelist(g)
    a = ex.explore(5, budget=1)
    np.testing.assert_array_equal(a.candidates, [5])
    assert a.rounds == 0


def test_isolated_seed_exhausts_immediately():
    g = from_numpy(np.asarray([0]), np.asarray([1]), 4)  # nodes 2,3 isolated
    ex = LocalExplorer.from_edgelist(g)
    a = ex.explore(3, budget=8)
    np.testing.assert_array_equal(a.candidates, [3])
    assert a.frontier_exhausted


def test_pruning_keeps_clique_drops_pendant_path():
    g, n = _clique_plus_path(kq=6, path_len=5)
    ex = LocalExplorer.from_edgelist(g)
    a = ex.explore(1, budget=n)
    # The clique closes over itself; every path vertex beyond the first
    # has deg 1 into T < rho(T), so the pruning stops the walk down the
    # path and reports the set as closed.
    assert set(range(6)) <= set(a.candidates.tolist())
    assert a.frontier_exhausted
    assert (6 + 4) not in a.candidates  # path tail never admitted
    assert len(a.candidates) < n


def test_volume_cap_skips_hub_rows():
    """A power-law hub one hop from the seed is never admitted (so never
    scanned) when its row does not fit in the work budget, while the small
    rows around it still are — total work stays <= budget * volume_factor
    by construction."""
    # seed 0 -- {1 (hub, degree 1001), 2, 3, 4, 5}; the hub's other edges
    # fan out to 1000 fresh nodes.
    src = [0, 0, 0, 0, 0] + [1] * 1000
    dst = [1, 2, 3, 4, 5] + list(range(6, 1006))
    g = from_numpy(np.asarray(src), np.asarray(dst), 1006)
    ex = LocalExplorer.from_edgelist(g)
    a = ex.explore(0, budget=50, volume_factor=2)  # cap = 100 slots
    assert 1 not in a.candidates  # hub skipped, not scanned
    assert {2, 3, 4, 5} <= set(a.candidates.tolist())
    assert a.edges_scanned <= 100
    # With room for the hub's row the same exploration admits it.
    b = ex.explore(0, budget=50, volume_factor=50)
    assert 1 in b.candidates


def test_alpha_zero_disables_density_pruning():
    g, n = _clique_plus_path(kq=6, path_len=5)
    ex = LocalExplorer.from_edgelist(g)
    # alpha=0 admits any frontier vertex with >= 1 tie: plain BFS growth,
    # so the whole connected component is eventually swallowed.
    a = ex.explore(1, budget=n, max_rounds=n, alpha=0.0)
    assert len(a.candidates) == n


def test_seed_and_count_validation():
    g, _ = _planted(n=50, k=8)
    ex = LocalExplorer.from_edgelist(g)
    with pytest.raises(TypeError):
        check_seed(2.5, 50)
    with pytest.raises(TypeError):
        check_seed(True, 50)
    with pytest.raises(TypeError):
        check_seed("5", 50)
    with pytest.raises(ValueError):
        check_seed(-1, 50)
    with pytest.raises(ValueError):
        check_seed(50, 50)
    assert check_seed(np.int64(5), 50) == 5
    with pytest.raises(ValueError):
        ex.explore(5, budget=0)
    with pytest.raises(TypeError):
        ex.explore(5, budget=2.0)
    with pytest.raises(ValueError):
        ex.explore(5, alpha=-0.5)
    with pytest.raises(ValueError):
        check_count(0, "radius")
    directed = from_numpy(
        np.asarray([0]), np.asarray([1]), 3, directed=True
    )
    with pytest.raises(ValueError, match="undirected"):
        LocalExplorer.from_edgelist(directed)


# ---------------------------------------------------------------------------
# api lowering: Problem(substrate='local')
# ---------------------------------------------------------------------------


def test_resolve_forces_exact_backend_and_no_compaction():
    r = PROB_LOCAL.resolve(1000)
    assert r.substrate == "local"
    assert r.backend == "exact"
    assert r.compaction == "off"


def test_problem_validation_matrix():
    with pytest.raises(ValueError, match="undirected"):
        dataclasses.replace(Problem.directed(), substrate="local").resolve(10)
    with pytest.raises(ValueError, match="candidate"):
        dataclasses.replace(PROB_LOCAL, backend="sketch").resolve(10)
    with pytest.raises(ValueError, match="turnstile"):
        dataclasses.replace(PROB_LOCAL, stream_mode="turnstile").resolve(10)
    with pytest.raises(ValueError):
        dataclasses.replace(PROB_LOCAL, local_budget=0)
    with pytest.raises(ValueError):
        dataclasses.replace(PROB_LOCAL, local_rounds=0)
    with pytest.raises(ValueError):
        dataclasses.replace(PROB_LOCAL, local_alpha=-1.0)


def test_solve_validation_matrix():
    g, _ = _planted(n=60, k=8)
    with pytest.raises(ValueError, match="seed"):
        solve(g, PROB_LOCAL)  # missing seed
    with pytest.raises(ValueError, match="per-seed"):
        solve(g, PROB, seed=3)  # seed on a whole-graph substrate
    with pytest.raises(ValueError, match="mesh"):
        Solver().solve(g, PROB_LOCAL, seed=3, mesh=object())
    with pytest.raises(ValueError, match="degree_fn"):
        Solver().solve(g, PROB_LOCAL, seed=3, degree_fn=lambda *a: None)


def test_solve_local_provenance_extras_and_guarantee():
    g, planted = _planted()
    s = int(planted[0])
    res = solve(g, PROB_LOCAL, seed=s)
    assert res.provenance.substrate == "local"
    assert res.provenance.backend == "exact"
    assert res.provenance.compaction == "off"
    info = res.extras["local"]
    assert info["seed"] == s
    cand = info["candidates"]
    assert s in cand
    assert info["n_candidates"] == len(cand)
    assert info["nodes_touched"] >= info["n_candidates"]
    # The answer is a genuine subgraph of the candidate set...
    nodes = res.nodes()
    assert set(nodes.tolist()) <= set(np.asarray(cand).tolist())
    assert int(res.best_size) == len(nodes)
    # ...so its density never exceeds the exact optimum of a small graph.
    small, sp = _planted(n=18, k=6, seed=3)
    _, rho_star = densest_subgraph_brute(small)
    r2 = solve(small, PROB_LOCAL, seed=int(sp[0]))
    assert float(r2.best_density) <= rho_star + 1e-5


def test_local_queries_share_one_cached_program():
    g, planted = _planted()
    solver = Solver()
    r1 = solver.solve(g, PROB_LOCAL, seed=int(planted[0]))
    n_traces = solver.trace_count
    r2 = solver.solve(g, PROB_LOCAL, seed=int(planted[1]))
    assert solver.trace_count == n_traces  # same pow2 bucket: no retrace
    assert r2.provenance.cache_hit
    assert r1.extras["local"]["bucket"] == r2.extras["local"]["bucket"]


# ---------------------------------------------------------------------------
# serving parity: extraction='local'
# ---------------------------------------------------------------------------


def test_engine_local_matches_api_bitwise():
    g, planted = _planted()
    eng = DensestQueryEngine(
        g, PROB, solver=Solver(), extraction="local", max_wait_ms=0.0
    )
    solver = Solver()
    for s in [int(planted[0]), 0, 17]:
        r = eng.query(s)
        assert r.status == "ok"
        api = solver.solve(g, PROB_LOCAL, seed=s)
        assert r.density == float(api.best_density)
        np.testing.assert_array_equal(
            r.nodes, np.flatnonzero(np.asarray(api.best_alive))
        )
    st = eng.stats()
    assert st["local_nodes_touched"] > 0
    assert st["local_edges_scanned"] > 0


def test_engine_accepts_local_substrate_problem():
    g, planted = _planted()
    eng = DensestQueryEngine(
        g,
        dataclasses.replace(PROB_LOCAL, local_budget=128),
        solver=Solver(),
        max_wait_ms=0.0,
    )
    assert eng.extraction == "local"
    assert eng.local_budget == 128
    r = eng.query(int(planted[0]))
    assert r.status == "ok"
    assert r.density == float(
        Solver()
        .solve(
            g,
            dataclasses.replace(PROB_LOCAL, local_budget=128),
            seed=int(planted[0]),
        )
        .best_density
    )


def test_engine_knob_validation():
    g, _ = _planted(n=60, k=8)
    bfs = DensestQueryEngine(g, PROB, solver=Solver(), max_wait_ms=0.0)
    loc = DensestQueryEngine(
        g, PROB, solver=Solver(), extraction="local", max_wait_ms=0.0
    )
    with pytest.raises(ValueError, match="radius"):
        loc.query(3, 2)  # radius on a local engine
    with pytest.raises(ValueError, match="budget"):
        bfs.query(3, budget=16)  # budget on a bfs engine
    with pytest.raises(ValueError, match="extraction"):
        DensestQueryEngine(g, PROB, solver=Solver(), extraction="dfs")
    directed_prob = Problem.directed()
    with pytest.raises(ValueError):
        DensestQueryEngine(
            g, directed_prob, solver=Solver(), extraction="local"
        )


def test_engine_budget_override_and_degrade_rung():
    g, planted = _planted()
    s = int(planted[0])
    cfg = ResilienceConfig(
        max_retries=0, degrade_turnstile=False, degrade_last_good=False
    )
    eng = DensestQueryEngine(
        g,
        PROB,
        solver=Solver(),
        extraction="local",
        max_wait_ms=0.0,
        resilience=cfg,
    )
    # Per-query budget override answers normally.
    r = eng.query(s, budget=128)
    assert r.status == "ok" and r.n_ego <= 128
    # Poison the default-budget bucket: the first degrade rung halves the
    # budget and answers with REAL data (identical to the direct solve).
    padded, _ = eng.extract(s, budget=eng.local_budget)
    gkey = (padded.n_nodes, padded.n_edges_padded)
    plan = FaultPlan().fail_prob("serve.solve", 1.0, key=gkey)
    with faults.active(plan):
        res = eng.query(s)
    assert res.status == "degraded"
    assert res.fallback == "budget:256"
    small, _ = eng.extract(s, budget=256)
    want = Solver().solve(small, PROB.resolve(small.n_nodes))
    assert res.density == float(want.best_density)
