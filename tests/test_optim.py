"""Optimizer substrate: int8 block-quantized Adam moments under jit (the
llama4 configuration), moment-dtype equivalence bounds, gradient-compression
error feedback."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import AdamWConfig, apply_updates, init_state
from repro.optim.adamw import Quantized, _dequantize, _quantize
from repro.optim.compression import (
    CompressionConfig,
    compress_decompress_psum,
)


def _params(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "w": jnp.asarray(rng.standard_normal((33, 17)).astype(np.float32)),
        "b": jnp.asarray(rng.standard_normal(7).astype(np.float32)),
    }


def test_quantize_roundtrip_error_bound():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal(1000).astype(np.float32) * 3)
    q = _quantize(x, 256)
    y = _dequantize(q)
    # per-block absmax int8: error <= scale/2 = absmax/254
    assert float(jnp.max(jnp.abs(x - y))) <= float(jnp.max(jnp.abs(x))) / 127


@pytest.mark.parametrize("dtype", ["fp32", "bf16", "int8"])
def test_adamw_moment_dtypes_under_jit(dtype):
    """int8 moments cross the jit boundary (Quantized has static shape) and
    track the fp32 trajectory within quantization tolerance."""
    cfg = AdamWConfig(lr=1e-2, weight_decay=0.0, moment_dtype=dtype)
    cfg32 = AdamWConfig(lr=1e-2, weight_decay=0.0, moment_dtype="fp32")
    params = _params()
    state = init_state(params, cfg)
    state32 = init_state(params, cfg32)
    p, p32 = params, params

    @jax.jit
    def step(p, s, g, c_is_int8=(dtype == "int8")):
        return apply_updates(p, g, s, cfg)

    @jax.jit
    def step32(p, s, g):
        return apply_updates(p, g, s, cfg32)

    rng = np.random.default_rng(2)
    for i in range(5):
        g = jax.tree.map(
            lambda x: jnp.asarray(
                rng.standard_normal(x.shape).astype(np.float32)
            ),
            params,
        )
        p, state, _ = step(p, state, g)
        p32, state32, _ = step32(p32, state32, g)
    for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(p32)):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=0, atol=5e-3
        )


def test_int8_state_is_actually_small():
    cfg = AdamWConfig(moment_dtype="int8")
    params = {"w": jnp.zeros((1024, 256), jnp.float32)}
    st = init_state(params, cfg)
    q = st.mu["w"]
    assert isinstance(q, Quantized)
    assert q.q.dtype == jnp.int8
    bytes_q = q.q.size + q.scale.size * 4
    assert bytes_q < 1024 * 256 * 4 * 0.3  # >3x smaller than fp32


def test_compression_error_feedback_does_not_accumulate():
    """int8+EF: the *running* compression error stays bounded while the sum
    of compressed grads converges to the sum of true grads."""
    cfg = CompressionConfig(kind="int8_ef", block=64)
    rng = np.random.default_rng(3)
    g_true_sum = np.zeros(512, np.float64)
    g_comp_sum = np.zeros(512, np.float64)
    err = {"g": jnp.zeros(512)}
    for i in range(30):
        g = rng.standard_normal(512).astype(np.float32) * 0.1
        g_true_sum += g
        out, err, _ = compress_decompress_psum(
            {"g": jnp.asarray(g)}, err, cfg
        )
        g_comp_sum += np.asarray(out["g"], np.float64)
    # with error feedback the cumulative sums track each other closely
    drift = np.abs(g_comp_sum - g_true_sum).max()
    assert drift < 0.05, drift


def test_bf16_compression_halves_and_roundtrips():
    cfg = CompressionConfig(kind="bf16")
    g = {"g": jnp.asarray(np.linspace(-1, 1, 128, dtype=np.float32))}
    out, _, factor = compress_decompress_psum(g, None, cfg)
    assert factor == 0.5
    np.testing.assert_allclose(np.asarray(out["g"]), np.asarray(g["g"]), atol=1e-2)
