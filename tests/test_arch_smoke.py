"""Per-architecture smoke tests: instantiate a REDUCED config of each of the
10 assigned archs, run one forward/train step on CPU, assert output shapes
and absence of NaNs.  (Full configs are exercised via the dry-run only.)"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.data.synthetic import make_batch
from repro.optim import AdamWConfig, init_state
from repro.train.step import (
    init_model_params,
    make_loss_fn,
    make_train_step,
    specialize_gnn_config,
)

OPT = AdamWConfig(lr=1e-3, weight_decay=0.01)


def _assert_finite(tree):
    for leaf in jax.tree.leaves(tree):
        assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32)))), "NaN/Inf found"


def _run_train_step(spec, shape_kind, cfg, batch):
    params = init_model_params(spec, jax.random.PRNGKey(0), cfg=cfg)
    loss_fn = make_loss_fn(spec, shape_kind, cfg=cfg)
    opt_state = init_state(params, OPT)
    step = jax.jit(make_train_step(loss_fn, OPT))
    new_params, new_opt, metrics = step(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    _assert_finite(new_params)
    # Params actually moved.
    moved = any(
        float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params))
    )
    assert moved
    return metrics


# ---------------------------- LM family -------------------------------------

LM_ARCHS = [
    "llama3.2-3b",
    "starcoder2-7b",
    "qwen2-72b",
    "mixtral-8x7b",
    "llama4-maverick-400b-a17b",
]


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_train_step(arch):
    spec = get_arch(arch)
    cfg = spec.reduced_config
    batch = make_batch(spec, "train", reduced_shape=dict(seq_len=64, global_batch=2))
    metrics = _run_train_step(spec, "train", cfg, batch)
    assert metrics["loss"] > 0


@pytest.mark.parametrize("arch", ["llama3.2-3b", "mixtral-8x7b"])
def test_lm_forward_shapes(arch):
    from repro.models.transformer import forward

    spec = get_arch(arch)
    cfg = spec.reduced_config
    params = init_model_params(spec, jax.random.PRNGKey(1), cfg=cfg)
    tokens = jnp.zeros((2, 16), jnp.int32)
    logits, moe_loss = forward(params, cfg, tokens)
    assert logits.shape == (2, 16, cfg.vocab)
    assert logits.dtype == jnp.float32
    _assert_finite(logits)


@pytest.mark.parametrize("arch", ["qwen2-72b", "mixtral-8x7b"])
def test_lm_prefill_decode_consistency(arch):
    """Greedy decode after prefill == argmax of full forward at each position.

    MoE capacity is raised so no tokens are dropped: GShard-style capacity
    dropping legitimately makes batched-forward != decode otherwise.
    """
    from repro.models.transformer import decode_step, forward, prefill

    spec = get_arch(arch)
    cfg = dataclasses.replace(spec.reduced_config, remat=False)
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0)
        )
    params = init_model_params(spec, jax.random.PRNGKey(2), cfg=cfg)
    rng = np.random.default_rng(0)
    s = 24
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (2, s), dtype=np.int32))

    logits_full, _ = forward(params, cfg, tokens)
    logits_pre, cache, cur_len = prefill(params, cfg, tokens, extra_slots=4)
    np.testing.assert_allclose(
        np.asarray(logits_pre),
        np.asarray(logits_full[:, -1]),
        rtol=2e-2, atol=2e-2,
    )
    # One decode step vs forward on the extended sequence.
    nxt = jnp.argmax(logits_pre, -1).astype(jnp.int32)[:, None]
    logits_dec, cache, cur_len = decode_step(params, cfg, cache, nxt, cur_len)
    ext = jnp.concatenate([tokens, nxt], axis=1)
    logits_full2, _ = forward(params, cfg, ext)
    np.testing.assert_allclose(
        np.asarray(logits_dec),
        np.asarray(logits_full2[:, -1]),
        rtol=5e-2, atol=5e-2,
    )


def test_lm_swa_rolling_cache_matches_window():
    """Mixtral rolling cache: decode with cache of size window == full attn
    over the last `window` tokens."""
    from repro.models.transformer import decode_step, forward, prefill

    spec = get_arch("mixtral-8x7b")
    cfg = dataclasses.replace(
        spec.reduced_config, remat=False, window=16,
        moe=dataclasses.replace(spec.reduced_config.moe, capacity_factor=8.0),
    )
    params = init_model_params(spec, jax.random.PRNGKey(3), cfg=cfg)
    rng = np.random.default_rng(1)
    s = 40  # prompt longer than the window
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (1, s), dtype=np.int32))
    logits_pre, cache, cur_len = prefill(params, cfg, tokens)
    assert cache["k"].shape[2] == 16  # rolling buffer = window slots
    logits_full, _ = forward(params, cfg, tokens)
    np.testing.assert_allclose(
        np.asarray(logits_pre), np.asarray(logits_full[:, -1]), rtol=5e-2, atol=5e-2
    )
    nxt = jnp.argmax(logits_pre, -1).astype(jnp.int32)[:, None]
    logits_dec, _, _ = decode_step(params, cfg, cache, nxt, cur_len)
    ext = jnp.concatenate([tokens, nxt], axis=1)
    logits_full2, _ = forward(params, cfg, ext)
    np.testing.assert_allclose(
        np.asarray(logits_dec), np.asarray(logits_full2[:, -1]), rtol=6e-2, atol=6e-2
    )


# ---------------------------- GNN family ------------------------------------

GNN_ARCHS = ["mace", "egnn", "graphsage-reddit", "equiformer-v2"]


@pytest.mark.parametrize("arch", GNN_ARCHS)
def test_gnn_full_graph_train_step(arch):
    spec = get_arch(arch)
    shape = dict(n_nodes=60, n_edges=240, d_feat=12, n_classes=5)
    cfg = specialize_gnn_config(spec.reduced_config, shape)
    batch = make_batch(spec, "full_train", reduced_shape=shape)
    _run_train_step(spec, "full_train", cfg, batch)


@pytest.mark.parametrize("arch", GNN_ARCHS)
def test_gnn_molecule_train_step(arch):
    spec = get_arch(arch)
    shape = dict(batch=4, n_nodes=12, n_edges=24, d_feat=8)
    cfg = specialize_gnn_config(spec.reduced_config, {**shape, "n_classes": 0})
    batch = make_batch(spec, "molecule_train", reduced_shape=shape)
    _run_train_step(spec, "molecule_train", cfg, batch)


def test_sage_sampled_train_step():

    spec = get_arch("graphsage-reddit")
    shape = dict(n_nodes=500, d_feat=16, batch_nodes=8, fanout=(5, 3), n_classes=4)
    cfg = specialize_gnn_config(spec.reduced_config, shape)
    rng = np.random.default_rng(0)
    r, f1, f2 = 8, 5, 3
    batch = {
        "feat_table": jnp.asarray(rng.standard_normal((500, 16), dtype=np.float32)),
        "hop0": jnp.asarray(rng.integers(0, 500, r, dtype=np.int32)),
        "hop1": jnp.asarray(rng.integers(0, 500, (r, f1), dtype=np.int32)),
        "hop2": jnp.asarray(rng.integers(0, 500, (r, f1, f2), dtype=np.int32)),
        "hop1_mask": jnp.ones((r, f1), jnp.float32),
        "hop2_mask": jnp.ones((r, f1, f2), jnp.float32),
        "labels": jnp.asarray(rng.integers(0, 4, r, dtype=np.int32)),
    }
    _run_train_step(spec, "sampled_train", cfg, batch)


# --------------------------- RecSys family -----------------------------------


def test_recsys_train_step():
    spec = get_arch("two-tower-retrieval")
    batch = make_batch(spec, "train", reduced_shape=dict(batch=32))
    metrics = _run_train_step(spec, "train", spec.reduced_config, batch)
    assert metrics["loss"] > 0


def test_recsys_serve_and_retrieval():
    from repro.train.step import make_recsys_retrieval, make_recsys_serve

    spec = get_arch("two-tower-retrieval")
    cfg = spec.reduced_config
    params = init_model_params(spec, jax.random.PRNGKey(0), cfg=cfg)
    batch = make_batch(spec, "train", reduced_shape=dict(batch=16))
    scores = jax.jit(make_recsys_serve(cfg))(params, batch)
    assert scores.shape == (16,)
    _assert_finite(scores)

    rng = np.random.default_rng(0)
    rbatch = {
        "user_id": jnp.asarray([3], jnp.int32),
        "hist": jnp.asarray(rng.integers(0, cfg.n_items, (1, cfg.hist_len), dtype=np.int32)),
        "hist_mask": jnp.ones((1, cfg.hist_len), jnp.float32),
        "cand_ids": jnp.asarray(rng.integers(0, cfg.n_items, 512, dtype=np.int32)),
    }
    out = jax.jit(make_recsys_retrieval(cfg, k=10))(params, rbatch)
    assert out["indices"].shape == (10,)
    # top-k really is sorted descending
    s = np.asarray(out["scores"])
    assert (np.diff(s) <= 1e-6).all()


def test_embedding_bag_matches_manual():
    from repro.models.recsys import embedding_bag_padded, embedding_bag_ragged

    rng = np.random.default_rng(0)
    table = jnp.asarray(rng.standard_normal((50, 8), dtype=np.float32))
    flat = jnp.asarray([1, 2, 3, 10, 11, 20], jnp.int32)
    bags = jnp.asarray([0, 0, 0, 1, 1, 2], jnp.int32)
    out = embedding_bag_ragged(table, flat, bags, 3, "mean")
    expect0 = np.asarray(table)[[1, 2, 3]].mean(0)
    np.testing.assert_allclose(np.asarray(out[0]), expect0, rtol=1e-6)
    # Padded path agrees with ragged path.
    ids = jnp.asarray([[1, 2, 3], [10, 11, 0], [20, 0, 0]], jnp.int32)
    mask = jnp.asarray([[1, 1, 1], [1, 1, 0], [1, 0, 0]], jnp.float32)
    out2 = embedding_bag_padded(table, ids, mask, "mean")
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2), rtol=1e-6)
