"""GPipe pipeline over a mesh axis: output must equal the sequential stack,
including under grad; bubble accounting sanity."""

import numpy as np
import pytest

# The pipeline test needs >1 device; spawn is handled by forcing host devices
# only when this module runs in its own process (pytest-forked not available,
# so we guard: if jax is already initialized with 1 device, skip).
import jax

if jax.device_count() == 1:
    pytest.skip(
        "pipeline test needs multiple host devices; run tests/launch suite "
        "(scripts set XLA_FLAGS before jax init)",
        allow_module_level=True,
    )

import jax.numpy as jnp
from jax.sharding import Mesh

from repro.sharding.pipeline import bubble_fraction, pipelined_apply


def test_bubble_fraction():
    assert bubble_fraction(4, 12) == pytest.approx(3 / 15)
    assert bubble_fraction(1, 8) == 0.0


def _mesh(n):
    return Mesh(np.asarray(jax.devices()[:n]).reshape(n), ("pod",))


def test_pipeline_matches_sequential():
    n_stages, n_micro, mb, d = 4, 8, 2, 16
    mesh = _mesh(n_stages)
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.standard_normal((n_stages, d, d)).astype(np.float32) * 0.3)
    x = jnp.asarray(rng.standard_normal((n_micro, mb, d)).astype(np.float32))

    def stage_fn(wi, h):
        return jnp.tanh(h @ wi)

    got = pipelined_apply(mesh, "pod", stage_fn, w, x)
    want = x
    for s in range(n_stages):
        want = jnp.tanh(want @ w[s])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_pipeline_grads_match_sequential():
    n_stages, n_micro, mb, d = 2, 4, 2, 8
    mesh = _mesh(n_stages)
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.standard_normal((n_stages, d, d)).astype(np.float32) * 0.3)
    x = jnp.asarray(rng.standard_normal((n_micro, mb, d)).astype(np.float32))

    def stage_fn(wi, h):
        return jnp.tanh(h @ wi)

    def loss_pipe(w):
        return jnp.mean(pipelined_apply(mesh, "pod", stage_fn, w, x) ** 2)

    def loss_seq(w):
        h = x
        for s in range(n_stages):
            h = jnp.tanh(h @ w[s])
        return jnp.mean(h**2)

    gp = jax.grad(loss_pipe)(w)
    gs = jax.grad(loss_seq)(w)
    np.testing.assert_allclose(np.asarray(gp), np.asarray(gs), rtol=5e-4, atol=1e-5)
