"""Cross-substrate property harness for per-seed serving (ISSUE 10).

One contract, checked for EVERY extraction mode (``'bfs'`` and
``'local'``) over random graphs × random seeds:

  * the seed is always in the extracted candidate set, and the answer is
    a subset of it; ``QueryResult.seed_in_set`` truthfully reports
    whether the peel kept the seed;
  * the returned density never exceeds the exact (brute-force) optimum
    of the WHOLE graph — locality can only lose density, never invent
    it — and clears the documented surviving envelope: a (2+2eps)
    approximation of the densest subgraph INSIDE the extracted set
    (core/local.py module docstring);
  * ``query()`` is bit-reproducible across two fresh engines (fresh
    Solvers, same graph): float-equal density, identical node sets.

The checks live in :func:`_check_contract`, exercised two ways: a fixed
pseudo-random corpus (always runs, keeps the contract in tier-1 even
where hypothesis is not installed) and a hypothesis strategy sweeping
adversarial shapes (CI).  The local mode additionally pins engine
answers bitwise to the ``substrate='local'`` api front door.
"""

import dataclasses

import numpy as np
import pytest

from repro.core import Problem, Solver, densest_subgraph_brute, solve
from repro.graph import from_numpy
from repro.serve.densest import DensestQueryEngine

EPS = 0.5
PROB = Problem.undirected(eps=EPS, compaction="off")
PROB_LOCAL = dataclasses.replace(PROB, substrate="local")
MODES = ("bfs", "local")

# Shared across examples so each (bucket, problem) compiles once per
# solver; two DISTINCT solvers make the reproducibility check honest
# (nothing shared below the engine surface).
_S1, _S2, _S_API = Solver(), Solver(), Solver()


def _random_graph(rng: np.random.Generator):
    n = int(rng.integers(4, 13))
    m = int(rng.integers(3, 31))
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    keep = src != dst
    if keep.sum() == 0:
        src, dst, keep = np.asarray([0]), np.asarray([1]), np.asarray([True])
    return from_numpy(src[keep], dst[keep], n)


def _induced(g, nodes):
    """Compact induced subgraph of ``nodes`` (reference, set-based)."""
    member = np.zeros(g.n_nodes, bool)
    member[nodes] = True
    local = np.zeros(g.n_nodes, np.int64)
    local[nodes] = np.arange(len(nodes))
    mask = np.asarray(g.mask)
    src = np.asarray(g.src)[mask]
    dst = np.asarray(g.dst)[mask]
    w = np.asarray(g.weight)[mask]
    keep = member[src] & member[dst]
    return from_numpy(
        local[src[keep]], local[dst[keep]], len(nodes), weight=w[keep]
    )


def _engine(g, mode, solver):
    return DensestQueryEngine(
        g, PROB, solver=solver, extraction=mode, max_wait_ms=0.0
    )


def _check_contract(g, seed, mode):
    e1 = _engine(g, mode, _S1)
    e2 = _engine(g, mode, _S2)
    r1 = e1.query(seed)
    r2 = e2.query(seed)
    assert r1.status == "ok"

    # Extraction containment: the seed is in the candidate set and the
    # answer never leaves it.  (The PEEL may drop the seed — that is what
    # seed_in_set reports — but the extraction never does.)
    if mode == "local":
        _, cand = e1.extract(seed, budget=e1.local_budget)
    else:
        _, cand = e1.extract(seed, e1.radius)
    cand_set = set(cand.tolist())
    assert seed in cand_set
    assert set(r1.nodes.tolist()) <= cand_set
    assert r1.seed_in_set == (seed in set(r1.nodes.tolist()))

    # Surviving guarantee: density <= whole-graph exact optimum, and
    # >= (exact optimum INSIDE the extracted set) / (2 + 2 eps).
    _, rho_star = densest_subgraph_brute(g)
    assert r1.density <= rho_star + 1e-4
    sub = _induced(g, cand)
    if int(np.asarray(sub.mask).sum()) > 0:
        _, rho_local = densest_subgraph_brute(sub)
        assert r1.density >= rho_local / (2 * (1 + EPS)) - 1e-4
    else:
        assert r1.density == 0.0

    # Bit-reproducibility across fresh engines + fresh solvers.
    assert r1.density == r2.density
    np.testing.assert_array_equal(r1.nodes, r2.nodes)

    # The local engine is the api front door, bit for bit.
    if mode == "local":
        api = _S_API.solve(g, PROB_LOCAL, seed=seed)
        assert r1.density == float(api.best_density)
        np.testing.assert_array_equal(
            r1.nodes, np.flatnonzero(np.asarray(api.best_alive))
        )


# ---------------------------------------------------------------------------
# fixed corpus: always runs (tier-1), no hypothesis required
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", MODES)
def test_contract_fixed_corpus(mode):
    rng = np.random.default_rng(1234)
    for _ in range(6):
        g = _random_graph(rng)
        for seed in {0, int(rng.integers(0, g.n_nodes))}:
            _check_contract(g, seed, mode)


# ---------------------------------------------------------------------------
# hypothesis sweep: adversarial shapes (CI installs hypothesis)
# ---------------------------------------------------------------------------

# A try/import (not module-level importorskip) so the fixed corpus above
# STAYS in tier-1 where hypothesis is absent.
try:
    from hypothesis import HealthCheck, given, settings, strategies as st
except ImportError:  # pragma: no cover - exercised where hypothesis is absent

    @pytest.mark.skip(reason="hypothesis not installed; property sweep skipped")
    def test_property_serve_contract():
        raise AssertionError("unreachable")

else:

    @st.composite
    def graph_and_seed(draw):
        n = draw(st.integers(4, 12))
        m = draw(st.integers(3, 30))
        src = draw(
            st.lists(st.integers(0, n - 1), min_size=m, max_size=m).map(
                np.asarray
            )
        )
        dst = draw(
            st.lists(st.integers(0, n - 1), min_size=m, max_size=m).map(
                np.asarray
            )
        )
        keep = src != dst
        if keep.sum() == 0:
            src = np.asarray([0])
            dst = np.asarray([1])
            keep = np.asarray([True])
        return from_numpy(src[keep], dst[keep], n), draw(
            st.integers(0, n - 1)
        )

    @given(graph_and_seed(), st.sampled_from(MODES))
    @settings(
        max_examples=8,
        deadline=None,
        suppress_health_check=[HealthCheck.too_slow],
    )
    def test_property_serve_contract(gs, mode):
        g, seed = gs
        _check_contract(g, seed, mode)
