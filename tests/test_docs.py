"""Documentation surface stays valid (tier-1 guard for scripts/check_docs.py).

The link check runs in-process (no jax import); the README quickstart
snippet's verbatim EXECUTION is the CI examples job's step (it compiles
real programs), but its extraction and shape are asserted here so a README
edit cannot silently drop the runnable quickstart.
"""

import os
import sys

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(REPO, "scripts"))

import check_docs  # noqa: E402


def test_readme_and_docs_exist():
    assert os.path.isfile(os.path.join(REPO, "README.md"))
    assert os.path.isfile(os.path.join(REPO, "docs", "compaction.md"))


def test_docs_links_resolve():
    errors = check_docs.check_links()
    assert errors == [], "\n".join(errors)


def test_rule_table_in_sync_with_registry():
    errors = check_docs.check_rule_table()
    assert errors == [], "\n".join(errors)


def test_module_link_checker_catches_rot():
    assert check_docs._check_module_token("repro.core.api.Solver") is None
    assert check_docs._check_module_token("repro.core.solve") is None
    assert check_docs._check_module_token("repro.no_such_module.api") is not None


def test_readme_quickstart_snippet_is_runnable_shape():
    snippet = check_docs.extract_readme_snippet()
    # The snippet must exercise the front door end to end.
    for needle in ("Problem", "solve(", "solve_batch(", "best_density"):
        assert needle in snippet, f"README quickstart lost {needle!r}"
    compile(snippet, "README.md#quickstart", "exec")  # must parse
