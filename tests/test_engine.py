"""PeelEngine equivalence: every policy × backend combination must return
bit-identical best sets (and equal densities) to independent float32 numpy
references implementing the PRE-refactor pass bodies, plus approximation
property tests against the exact max-flow oracle.

The numpy references replicate the old loops' float32 arithmetic exactly
(unweighted graphs keep all degree/total sums integer-valued, so summation
order cannot perturb the threshold comparisons); any drift in the engine's
pass body shows up as a set difference here.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.core import densest_subgraph_exact, density_of
from repro.core.countsketch import SketchBackend, make_sketch_params, sketched_degree_fn
from repro.core.engine import (
    AtLeastKFraction,
    DirectedST,
    ExactBackend,
    FnBackend,
    MeshSegmentSumBackend,
    UndirectedThreshold,
    run_peel,
    undirected_pass_step,
)
from repro.graph import from_numpy
from repro.graph.generators import directed_planted, erdos_renyi, planted_dense_subgraph

f32 = np.float32


# ---------------------------------------------------------------------------
# Pre-refactor reference implementations (numpy, float32 arithmetic)
# ---------------------------------------------------------------------------


def _np_edges(edges):
    mask = np.asarray(edges.mask)
    return (
        np.asarray(edges.src)[mask],
        np.asarray(edges.dst)[mask],
        np.asarray(edges.weight)[mask].astype(f32),
    )


def _deg(src, dst, w, alive, n):
    ok = alive[src] & alive[dst]
    deg = np.zeros(n, f32)
    np.add.at(deg, src, np.where(ok, w, f32(0)))
    np.add.at(deg, dst, np.where(ok, w, f32(0)))
    return deg, f32(np.sum(np.where(ok, w, f32(0))))


def ref_undirected(edges, eps, max_passes):
    """Old core/peel.py body (Algorithm 1)."""
    src, dst, w = _np_edges(edges)
    n = edges.n_nodes
    alive = np.ones(n, bool)
    best_alive, best_rho = alive.copy(), -np.inf
    t = 0
    while alive.any() and t < max_passes:
        deg, total = _deg(src, dst, w, alive, n)
        n_alive = int(alive.sum())
        rho = f32(total / f32(max(n_alive, 1)))
        if rho > best_rho:
            best_alive, best_rho = alive.copy(), rho
        thresh = f32(f32(2.0 * (1.0 + eps)) * rho)
        deg_alive = np.where(alive, deg, np.inf)
        remove = alive & ((deg <= thresh) | (deg <= deg_alive.min()))
        alive = alive & ~remove
        t += 1
    return best_alive, float(best_rho), t


def ref_at_least_k(edges, k, eps, max_passes, *, min_deg_fallback=True, ceil_count=False):
    """Old core/peel_topk.py / mapreduce topk body (Algorithm 2)."""
    src, dst, w = _np_edges(edges)
    n = edges.n_nodes
    alive = np.ones(n, bool)
    best_alive, best_rho, best_size = alive.copy(), -np.inf, 0
    t = 0
    while int(alive.sum()) >= k and t < max_passes:
        deg, total = _deg(src, dst, w, alive, n)
        n_alive = int(alive.sum())
        rho = f32(total / f32(max(n_alive, 1)))
        if n_alive >= k and rho > best_rho:
            best_alive, best_rho, best_size = alive.copy(), rho, n_alive
        thresh = f32(f32(2.0 * (1.0 + eps)) * rho)
        if min_deg_fallback:
            deg_alive = np.where(alive, deg, np.inf)
            cand = alive & ((deg <= thresh) | (deg <= deg_alive.min()))
        else:
            cand = alive & (deg <= thresh)
        if ceil_count:
            r = int(np.ceil(f32(f32(f32(n_alive) * f32(eps)) / f32(1.0 + eps))))
        else:
            r = int(f32(f32(eps / (1.0 + eps)) * f32(n_alive)))
        r = max(r, 1)
        key = np.where(cand, deg, np.inf)
        order = np.argsort(key, kind="stable")
        rank = np.empty(n, np.int64)
        rank[order] = np.arange(n)
        alive = alive & ~(cand & (rank < r))
        t += 1
    return best_alive, float(best_rho), best_size, t


def ref_directed(edges, c, eps, max_passes):
    """Old core/peel_directed.py body (Algorithm 3)."""
    mask = np.asarray(edges.mask)
    src = np.asarray(edges.src)[mask]
    dst = np.asarray(edges.dst)[mask]
    w = np.asarray(edges.weight)[mask].astype(f32)
    n = edges.n_nodes
    s_alive = np.ones(n, bool)
    t_alive = np.ones(n, bool)
    best_s, best_t, best_rho = s_alive.copy(), t_alive.copy(), -np.inf
    t = 0
    while s_alive.any() and t_alive.any() and t < max_passes:
        ok = s_alive[src] & t_alive[dst]
        wa = np.where(ok, w, f32(0))
        out_deg = np.zeros(n, f32)
        in_deg = np.zeros(n, f32)
        np.add.at(out_deg, src, wa)
        np.add.at(in_deg, dst, wa)
        total = f32(wa.sum())
        ns, nt = int(s_alive.sum()), int(t_alive.sum())
        ns_f, nt_f = f32(max(ns, 1)), f32(max(nt, 1))
        rho = f32(total / f32(np.sqrt(f32(ns_f * nt_f))))
        if rho > best_rho:
            best_s, best_t, best_rho = s_alive.copy(), t_alive.copy(), rho
        if ns_f / nt_f >= c:
            thr = f32(f32(f32(1.0 + eps) * total) / ns_f)
            outd = np.where(s_alive, out_deg, np.inf)
            rm = s_alive & ((out_deg <= thr) | (out_deg <= outd.min()))
            s_alive = s_alive & ~rm
        else:
            thr = f32(f32(f32(1.0 + eps) * total) / nt_f)
            ind = np.where(t_alive, in_deg, np.inf)
            rm = t_alive & ((in_deg <= thr) | (in_deg <= ind.min()))
            t_alive = t_alive & ~rm
        t += 1
    return best_s, best_t, float(best_rho), t


# ---------------------------------------------------------------------------
# Backends under test
# ---------------------------------------------------------------------------


def _mesh():
    return Mesh(np.asarray(jax.devices()[:1]).reshape(1), ("data",))


def _backend(name):
    if name == "exact":
        return ExactBackend()
    if name == "mesh":
        return MeshSegmentSumBackend(("data",))
    raise ValueError(name)


def _run(edges, policy, backend_name, max_passes):
    """run_peel on the jit substrate (exact) or the shard_map substrate
    (mesh, 1 device — the collective structure is identical)."""
    if backend_name == "exact":
        fn = jax.jit(
            lambda e: run_peel(e, policy, ExactBackend(), max_passes)
        )
        return fn(edges)
    assert backend_name == "mesh"
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map
    from repro.core.mapreduce import shard_edges
    from repro.graph.edgelist import EdgeList

    mesh = _mesh()
    sh = shard_edges(edges, mesh, ("data",))
    backend = MeshSegmentSumBackend(("data",))

    def local(src, dst, weight, mask):
        e = EdgeList(src=src, dst=dst, weight=weight, mask=mask, n_nodes=sh.n_nodes)
        return run_peel(e, policy, backend, max_passes)

    fn = jax.jit(
        shard_map(
            local, mesh=mesh, in_specs=(P(("data",)),) * 4, out_specs=P(),
            check_vma=False,
        )
    )
    return fn(sh.src, sh.dst, sh.weight, sh.mask)


GRAPHS = [
    ("er", lambda: erdos_renyi(180, avg_deg=8, seed=0)),
    ("planted", lambda: planted_dense_subgraph(250, avg_deg=4, k=25, p_dense=0.8, seed=3)[0]),
]


# ---------------------------------------------------------------------------
# Policy × backend matrix vs the pre-refactor references
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["exact", "mesh"])
@pytest.mark.parametrize("graph", [g for g, _ in GRAPHS])
@pytest.mark.parametrize("eps", [0.1, 0.5])
def test_matrix_undirected_threshold(graph, backend, eps):
    edges = dict(GRAPHS)[graph]()
    mp = 64
    res = _run(edges, UndirectedThreshold(eps), backend, mp)
    ref_alive, ref_rho, ref_passes = ref_undirected(edges, eps, mp)
    np.testing.assert_array_equal(np.asarray(res.best_alive), ref_alive)
    assert float(res.best_density) == pytest.approx(ref_rho, rel=1e-6)
    assert int(res.passes) == ref_passes


@pytest.mark.parametrize("backend", ["exact", "mesh"])
@pytest.mark.parametrize("variant", ["floor_fallback", "ceil_plain"])
def test_matrix_at_least_k(backend, variant):
    edges = dict(GRAPHS)["planted"]()
    k, eps, mp = 30, 0.5, 64
    fallback = variant == "floor_fallback"
    policy = AtLeastKFraction(
        k=k, eps=eps, min_deg_fallback=fallback, ceil_count=not fallback
    )
    res = _run(edges, policy, backend, mp)
    ref_alive, ref_rho, ref_size, ref_passes = ref_at_least_k(
        edges, k, eps, mp, min_deg_fallback=fallback, ceil_count=not fallback
    )
    np.testing.assert_array_equal(np.asarray(res.best_alive), ref_alive)
    assert float(res.best_density) == pytest.approx(ref_rho, rel=1e-6)
    assert int(res.best_size) == ref_size
    assert int(res.passes) == ref_passes


@pytest.mark.parametrize("backend", ["exact", "mesh"])
@pytest.mark.parametrize("c", [0.5, 1.0, 2.0])
def test_matrix_directed_st(backend, c):
    edges, _, _ = directed_planted(200, avg_deg=3, ks=15, kt=12, p_dense=0.9, seed=5)
    eps, mp = 0.5, 64
    res = _run(edges, DirectedST(eps=eps, c=jnp.float32(c)), backend, mp)
    ref_s, ref_t, ref_rho, ref_passes = ref_directed(edges, c, eps, mp)
    np.testing.assert_array_equal(np.asarray(res.best_alive), ref_s)
    np.testing.assert_array_equal(np.asarray(res.best_t), ref_t)
    assert float(res.best_density) == pytest.approx(ref_rho, rel=1e-6)
    assert int(res.passes) == ref_passes


# ---------------------------------------------------------------------------
# Approximate backends: sketch (class == legacy degree_fn hook) and Pallas
# ---------------------------------------------------------------------------


def test_sketch_backend_matches_degree_fn_hook():
    """SketchBackend through the engine == the pre-refactor degree_fn path."""
    edges, _ = planted_dense_subgraph(600, avg_deg=4, k=30, p_dense=0.8, seed=1)
    params = make_sketch_params(t=5, b=1 << 12, seed=7)
    mp = 64
    a = jax.jit(
        lambda e: run_peel(e, UndirectedThreshold(0.5), SketchBackend(params), mp)
    )(edges)
    b = jax.jit(
        lambda e: run_peel(
            e, UndirectedThreshold(0.5), FnBackend(sketched_degree_fn(params)), mp
        )
    )(edges)
    np.testing.assert_array_equal(np.asarray(a.best_alive), np.asarray(b.best_alive))
    assert float(a.best_density) == float(b.best_density)
    assert int(a.passes) == int(b.passes)


def test_sketch_backend_directed_runs_and_is_sane():
    """DirectedST × SketchBackend: per-endpoint counter tables give a dense
    pair close to the exact-backend answer on a strongly planted block."""
    edges, _, _ = directed_planted(300, avg_deg=3, ks=20, kt=15, p_dense=0.95, seed=2)
    params = make_sketch_params(t=5, b=1 << 13, seed=3)
    mp = 64
    policy = DirectedST(eps=0.5, c=jnp.float32(1.0))
    sk = jax.jit(lambda e: run_peel(e, policy, SketchBackend(params), mp))(edges)
    ex = jax.jit(lambda e: run_peel(e, policy, ExactBackend(), mp))(edges)
    assert float(sk.best_density) >= 0.5 * float(ex.best_density)


def test_pallas_backend_matches_exact():
    """The tiled-degree kernel backend is exact arithmetic -> identical sets."""
    from repro.kernels.peel_degree.ops import (
        degree_backend_from_tiling,
        tiling_for_edges,
    )

    edges = erdos_renyi(300, avg_deg=6, seed=4)
    tiled = tiling_for_edges(edges, tile_size=128, block=128)
    backend = degree_backend_from_tiling(tiled, use_pallas=True)
    mp = 64
    a = jax.jit(lambda e: run_peel(e, UndirectedThreshold(0.5), backend, mp))(edges)
    b = jax.jit(lambda e: run_peel(e, UndirectedThreshold(0.5), ExactBackend(), mp))(edges)
    np.testing.assert_array_equal(np.asarray(a.best_alive), np.asarray(b.best_alive))
    assert float(a.best_density) == pytest.approx(float(b.best_density), rel=1e-6)


# ---------------------------------------------------------------------------
# Streaming substrate shares the policy step
# ---------------------------------------------------------------------------


def test_undirected_pass_step_equals_engine_pass():
    """One undirected_pass_step == one engine pass (same removal bitmap)."""
    edges = erdos_renyi(150, avg_deg=8, seed=6)
    res1 = jax.jit(lambda e: run_peel(e, UndirectedThreshold(0.5), ExactBackend(), 1))(
        edges
    )
    alive = jnp.ones((edges.n_nodes,), bool)
    ok = edges.mask & alive[edges.src] & alive[edges.dst]
    w_alive = jnp.where(ok, edges.weight, 0.0)
    deg, total = ExactBackend().undirected(edges, w_alive)
    new_alive, rho = undirected_pass_step(alive, deg, float(total), 0.5)
    np.testing.assert_array_equal(np.asarray(new_alive), np.asarray(res1.alive))
    assert float(rho) == float(res1.best_density)


# ---------------------------------------------------------------------------
# Segmented runs (the compaction runtime's engine contract)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("eps", [0.1, 0.5])
def test_segmented_run_equals_single_run(eps):
    """compact_below + init_alive/init_t re-entry == one uncompacted run:
    same best set (earliest-wins tie merge), density, pass count, history."""
    edges = erdos_renyi(220, avg_deg=8, seed=2)
    mp = 64
    policy = UndirectedThreshold(eps)
    full = jax.jit(
        lambda e: run_peel(e, policy, ExactBackend(), mp, track_history=True)
    )(edges)
    m = int(edges.num_real_edges())
    seg1 = jax.jit(
        lambda e: run_peel(
            e, policy, ExactBackend(), mp, track_history=True,
            compact_below=m // 2, init_best_empty=True,
        )
    )(edges)
    assert int(seg1.passes) < int(full.passes)  # the trigger actually fired
    seg2 = jax.jit(
        lambda e, a, t: run_peel(
            e, policy, ExactBackend(), mp, track_history=True,
            init_alive=a, init_t=t, init_best_empty=True,
        )
    )(edges, seg1.alive, seg1.passes)
    use2 = float(seg2.best_density) > float(seg1.best_density)
    best = seg2.best_alive if use2 else seg1.best_alive
    np.testing.assert_array_equal(np.asarray(best), np.asarray(full.best_alive))
    assert max(float(seg1.best_density), float(seg2.best_density)) == float(
        full.best_density
    )
    assert int(seg2.passes) == int(full.passes)
    np.testing.assert_array_equal(np.asarray(seg2.alive), np.asarray(full.alive))
    hn1 = np.asarray(seg1.history_n)
    merged = np.where(hn1 >= 0, hn1, np.asarray(seg2.history_n))
    np.testing.assert_array_equal(merged, np.asarray(full.history_n))


def test_compact_below_none_is_classic_loop():
    """compact_below=None must not change anything (the off path)."""
    edges = erdos_renyi(150, avg_deg=6, seed=9)
    a = jax.jit(
        lambda e: run_peel(e, UndirectedThreshold(0.5), ExactBackend(), 64)
    )(edges)
    b = jax.jit(
        lambda e: run_peel(
            e, UndirectedThreshold(0.5), ExactBackend(), 64, compact_below=None
        )
    )(edges)
    np.testing.assert_array_equal(np.asarray(a.best_alive), np.asarray(b.best_alive))
    assert int(a.passes) == int(b.passes)


def test_compact_edges_prefix_sum_relabeling():
    """The in-program compact step (engine.compact_edges): surviving slots
    move to the front, original order preserved, everything else drops —
    including survivors past a too-small capacity (the terminated-segment
    overflow case, whose edges are never peeled again)."""
    from repro.core.engine import compact_edges

    ok = jnp.asarray([False, True, False, True, True, False, True])
    src = jnp.arange(7, dtype=jnp.int32) * 10
    w = jnp.arange(7, dtype=jnp.float32)
    csrc, cw = jax.jit(lambda o, a, b: compact_edges(o, (a, b), 4))(ok, src, w)
    np.testing.assert_array_equal(np.asarray(csrc), [10, 30, 40, 60])
    np.testing.assert_array_equal(np.asarray(cw), [1.0, 3.0, 4.0, 6.0])
    # Capacity 2: the first two survivors (in order) are kept, extras drop.
    (csrc2,) = jax.jit(lambda o, a: compact_edges(o, (a,), 2))(ok, src)
    np.testing.assert_array_equal(np.asarray(csrc2), [10, 30])
    # Capacity beyond the survivor count zero-fills the tail.
    (csrc8,) = jax.jit(lambda o, a: compact_edges(o, (a,), 8))(ok, src)
    np.testing.assert_array_equal(np.asarray(csrc8), [10, 30, 40, 60, 0, 0, 0, 0])


def _relabel_graph(edges, perm):
    """Applies a node permutation and keeps edge order (a stable relabel)."""
    p = jnp.asarray(perm, jnp.int32)
    from repro.graph.edgelist import EdgeList

    return EdgeList(
        src=p[edges.src], dst=p[edges.dst], weight=edges.weight,
        mask=edges.mask, n_nodes=edges.n_nodes,
    )


@pytest.mark.parametrize("seed", range(4))
def test_relabel_peel_unrelabel_roundtrip_seeded(seed):
    """Seeded variant of the relabel round-trip (runs without hypothesis)."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(8, 40))
    src = rng.integers(0, n, 3 * n)
    dst = rng.integers(0, n, 3 * n)
    keep = src != dst
    edges = from_numpy(src[keep], dst[keep], n)
    perm = rng.permutation(n)
    base = jax.jit(
        lambda e: run_peel(e, UndirectedThreshold(0.5), ExactBackend(), 64)
    )(edges)
    rel = jax.jit(
        lambda e: run_peel(e, UndirectedThreshold(0.5), ExactBackend(), 64)
    )(_relabel_graph(edges, perm))
    np.testing.assert_array_equal(
        np.asarray(rel.best_alive)[perm], np.asarray(base.best_alive)
    )
    assert float(rel.best_density) == float(base.best_density)
    assert int(rel.passes) == int(base.passes)


def test_relabel_peel_unrelabel_roundtrip_hypothesis():
    """The compaction ladder's core assumption, as a property: relabeling
    nodes, peeling, and mapping the best-set bitmap back is EXACTLY the
    peel of the original graph (Algorithm 1's removal rule is id-free)."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    mp = 64

    @st.composite
    def cases(draw):
        n = draw(st.integers(5, 24))
        m = draw(st.integers(4, 60))
        rng = np.random.default_rng(draw(st.integers(0, 2**31 - 1)))
        src = rng.integers(0, n, m)
        dst = rng.integers(0, n, m)
        keep = src != dst
        if keep.sum() == 0:
            src, dst, keep = np.asarray([0]), np.asarray([1]), np.asarray([True])
        perm = rng.permutation(n)
        return from_numpy(src[keep], dst[keep], n), perm

    @given(cases(), st.sampled_from([0.1, 0.5]))
    @settings(max_examples=25, deadline=None)
    def check(case, eps):
        edges, perm = case
        base = jax.jit(
            lambda e: run_peel(e, UndirectedThreshold(eps), ExactBackend(), mp)
        )(edges)
        rel = jax.jit(
            lambda e: run_peel(e, UndirectedThreshold(eps), ExactBackend(), mp)
        )(_relabel_graph(edges, perm))
        back = np.asarray(rel.best_alive)[perm]  # unrelabel the bitmap
        np.testing.assert_array_equal(back, np.asarray(base.best_alive))
        assert float(rel.best_density) == float(base.best_density)
        assert int(rel.passes) == int(base.passes)

    check()


# ---------------------------------------------------------------------------
# Approximation property: engine density >= rho* / (2(1+eps))
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(6))
@pytest.mark.parametrize("eps", [0.1, 0.5, 1.0])
def test_property_guarantee_vs_exact_seeded(seed, eps):
    """Lemma 3 on random small graphs, through the engine directly."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(8, 40))
    m = int(rng.integers(n, 4 * n))
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    keep = src != dst
    if keep.sum() == 0:
        return
    edges = from_numpy(src[keep], dst[keep], n)
    _, rho_star = densest_subgraph_exact(edges)
    res = jax.jit(
        lambda e: run_peel(e, UndirectedThreshold(eps), ExactBackend(), 128)
    )(edges)
    assert float(res.best_density) >= rho_star / (2 * (1 + eps)) - 1e-5
    assert float(res.best_density) <= rho_star + 1e-5
    assert float(density_of(edges, res.best_alive)) == pytest.approx(
        float(res.best_density), rel=1e-5, abs=1e-6
    )


def test_property_guarantee_hypothesis():
    """Hypothesis variant of the Lemma-3 property (skips if unavailable)."""
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @st.composite
    def graphs(draw):
        n = draw(st.integers(4, 16))
        m = draw(st.integers(3, 40))
        src = np.asarray(draw(st.lists(st.integers(0, n - 1), min_size=m, max_size=m)))
        dst = np.asarray(draw(st.lists(st.integers(0, n - 1), min_size=m, max_size=m)))
        keep = src != dst
        if keep.sum() == 0:
            src, dst, keep = np.asarray([0]), np.asarray([1]), np.asarray([True])
        return from_numpy(src[keep], dst[keep], n)

    @given(graphs(), st.sampled_from([0.1, 0.5, 1.0]))
    @settings(max_examples=20, deadline=None)
    def check(edges, eps):
        _, rho_star = densest_subgraph_exact(edges)
        res = jax.jit(
            lambda e: run_peel(e, UndirectedThreshold(eps), ExactBackend(), 64)
        )(edges)
        assert float(res.best_density) >= rho_star / (2 * (1 + eps)) - 1e-5

    check()
