"""Chunked (flash-style) attention must match the dense XLA path exactly
(same math, different schedule) across GQA ratios, windows, and validity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import gqa_attention


def _rand(rng, shape):
    return jnp.asarray(rng.standard_normal(shape).astype(np.float32))


@pytest.mark.parametrize(
    "sq,sk,hq,hkv,window",
    [
        (64, 64, 4, 4, None),
        (64, 64, 8, 2, None),
        (48, 48, 4, 2, 16),  # sliding window
        (37, 37, 6, 3, None),  # non-multiple of chunk
        (16, 80, 4, 2, None),  # cross-attention-ish shapes (kv longer)
    ],
)
def test_chunked_matches_dense(sq, sk, hq, hkv, window):
    rng = np.random.default_rng(0)
    b, d = 2, 16
    q = _rand(rng, (b, sq, hq, d))
    k = _rand(rng, (b, sk, hkv, d))
    v = _rand(rng, (b, sk, hkv, d))
    qpos = jnp.arange(sk - sq, sk, dtype=jnp.int32)  # queries are the tail
    kpos = jnp.arange(sk, dtype=jnp.int32)
    dense = gqa_attention(
        q, k, v, q_positions=qpos, kv_positions=kpos, window=window, impl="xla"
    )
    chunked = gqa_attention(
        q, k, v, q_positions=qpos, kv_positions=kpos, window=window,
        impl="xla_chunked", q_chunk=16, kv_chunk=32,
    )
    np.testing.assert_allclose(
        np.asarray(chunked), np.asarray(dense), rtol=2e-5, atol=2e-5
    )


def test_chunked_respects_kv_valid():
    rng = np.random.default_rng(1)
    b, s, hq, hkv, d = 2, 32, 4, 2, 8
    q = _rand(rng, (b, s, hq, d))
    k = _rand(rng, (b, s, hkv, d))
    v = _rand(rng, (b, s, hkv, d))
    pos = jnp.arange(s, dtype=jnp.int32)
    valid = jnp.asarray(rng.random((b, s)) < 0.8)
    valid = valid.at[:, 0].set(True)  # keep at least one valid kv per row
    dense = gqa_attention(
        q, k, v, q_positions=pos, kv_positions=pos, kv_valid=valid, impl="xla"
    )
    chunked = gqa_attention(
        q, k, v, q_positions=pos, kv_positions=pos, kv_valid=valid,
        impl="xla_chunked", q_chunk=8, kv_chunk=8,
    )
    np.testing.assert_allclose(
        np.asarray(chunked), np.asarray(dense), rtol=2e-5, atol=2e-5
    )


@pytest.mark.parametrize(
    "sq,hq,hkv,window,qc,kc",
    [
        (32, 4, 4, None, 8, 8),
        (37, 6, 3, None, 16, 8),   # GQA + padding
        (48, 4, 2, 16, 16, 16),    # sliding window
    ],
)
def test_flash_backward_matches_dense_autodiff(sq, hq, hkv, window, qc, kc):
    """The custom-VJP flash backward (per-chunk recompute) must agree with
    autodiff through the dense path."""
    rng = np.random.default_rng(3)
    b, d = 2, 16
    q = _rand(rng, (b, sq, hq, d))
    k = _rand(rng, (b, sq, hkv, d))
    v = _rand(rng, (b, sq, hkv, d))
    pos = jnp.arange(sq, dtype=jnp.int32)
    w = _rand(rng, (b, sq, hq, d))  # O(1) cotangents

    def loss(impl):
        def f(q, k, v):
            o = gqa_attention(
                q, k, v, q_positions=pos, kv_positions=pos, window=window,
                impl=impl, q_chunk=qc, kv_chunk=kc,
            )
            return jnp.mean(o * w)
        return f

    gd = jax.grad(loss("xla"), argnums=(0, 1, 2))(q, k, v)
    gc = jax.grad(loss("xla_chunked"), argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(gd, gc):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b_), rtol=2e-4, atol=1e-5
        )


def test_auto_dispatches_small_to_dense():
    rng = np.random.default_rng(2)
    b, s, h, d = 1, 8, 2, 4
    q = _rand(rng, (b, s, h, d))
    k = _rand(rng, (b, s, h, d))
    v = _rand(rng, (b, s, h, d))
    pos = jnp.arange(s, dtype=jnp.int32)
    out = gqa_attention(q, k, v, q_positions=pos, kv_positions=pos, impl="auto")
    ref = gqa_attention(q, k, v, q_positions=pos, kv_positions=pos, impl="xla")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-6)
