"""Tier-1 gate for the invariant linter (repro.analysis).

Two halves:

  * the CURRENT TREE is clean — ``analyze_paths(["src/repro"])`` returns
    no findings (suppressions all justified and all used), and the CLI
    gate (``scripts/analyze.py --strict``) exits 0;
  * the RULES WORK — every known-bad fixture in tests/fixtures/analysis/
    trips exactly the rules its name promises, with pinned counts, the
    ok_* fixtures stay silent, and every registered rule is tripped by at
    least one fixture (a checker nobody can trip is dead weight).

Deliberately jax-free: this file must pass in the same bare CPython the
CI static-analysis job uses.
"""

import os
import subprocess
import sys

import pytest

from repro.analysis import (
    META_RULES,
    Project,
    RULES,
    all_rules,
    analyze_file,
    analyze_paths,
    render_finding,
)

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
FIXTURES = os.path.join(REPO, "tests", "fixtures", "analysis")


def _counts(findings):
    out = {}
    for f in findings:
        out[f.rule] = out.get(f.rule, 0) + 1
    return out


def _fixture(name):
    return analyze_file(os.path.join(FIXTURES, name), rel=name, scoped=False)


# --------------------------------------------------------------------------
# the tree is clean
# --------------------------------------------------------------------------


def test_tree_is_clean():
    findings = analyze_paths(
        ["src/repro"], root=REPO, project=Project.load(), scoped=True
    )
    assert findings == [], "\n".join(render_finding(f) for f in findings)


@pytest.mark.slow
def test_cli_strict_exits_zero():
    proc = subprocess.run(
        [sys.executable, os.path.join(REPO, "scripts", "analyze.py"), "--strict"],
        capture_output=True,
        text=True,
        cwd=REPO,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr


@pytest.mark.slow
def test_cli_lists_every_rule():
    proc = subprocess.run(
        [
            sys.executable,
            os.path.join(REPO, "scripts", "analyze.py"),
            "--list-rules",
        ],
        capture_output=True,
        text=True,
        cwd=REPO,
    )
    assert proc.returncode == 0
    for rid in all_rules():
        assert rid in proc.stdout


# --------------------------------------------------------------------------
# the fixture corpus trips every rule
# --------------------------------------------------------------------------

# fixture file -> exact {rule id: finding count}
CASES = {
    "bad_single_engine.py": {"single-engine": 3},
    "bad_atomic_io.py": {"atomic-io": 5},
    "bad_fault_sites.py": {"fault-sites": 2},
    "bad_cache_key.py": {"cache-key": 5},
    "bad_tracer_hygiene.py": {"tracer-hygiene": 8},
    "bad_pow2_constants.py": {"pow2-constants": 5},
    "bad_unused_suppression.py": {"unused-suppression": 1},
    "bad_suppression.py": {"bad-suppression": 4, "atomic-io": 1},
    "ok_suppressed.py": {},
    "ok_strings_comments.py": {},
}


@pytest.mark.parametrize("name,expected", sorted(CASES.items()))
def test_fixture(name, expected):
    findings = _fixture(name)
    got = _counts(findings)
    assert got == expected, "\n".join(render_finding(f) for f in findings)


def test_every_rule_has_a_tripping_fixture():
    tripped = set()
    for name in CASES:
        tripped.update(f.rule for f in _fixture(name))
    registered = set(RULES) | set(META_RULES)
    assert registered == tripped, (
        f"rules with no tripping fixture: {sorted(registered - tripped)}; "
        f"fixtures tripping unknown rules: {sorted(tripped - registered)}"
    )


def test_findings_carry_anchor_and_hint():
    for f in _fixture("bad_atomic_io.py"):
        assert f.line > 0
        assert f.rule == "atomic-io"
        assert f.hint  # every checker finding ships a fix-it hint
        assert "bad_atomic_io.py" in render_finding(f)


# --------------------------------------------------------------------------
# acceptance: reintroducing a raw os.replace checkpoint write fails the gate
# --------------------------------------------------------------------------


def test_reintroduced_raw_checkpoint_write_fails(tmp_path):
    pkg = tmp_path / "src" / "repro"
    pkg.mkdir(parents=True)
    bad = pkg / "ckpt.py"
    bad.write_text(
        "import os\n"
        "def save(state, path):\n"
        "    with open(path + '.new', 'wb') as f:\n"
        "        f.write(state)\n"
        "    os.replace(path + '.new', path)\n"
    )
    findings = analyze_paths(
        ["src/repro"], root=str(tmp_path), project=Project.load(), scoped=True
    )
    assert _counts(findings) == {"atomic-io": 2}
    lines = sorted(f.line for f in findings)
    assert lines == [3, 5]  # the open() and the os.replace, by line
    assert all(f.path == "src/repro/ckpt.py" for f in findings)


def test_unjustified_suppression_does_not_suppress(tmp_path):
    bad = tmp_path / "sneaky.py"
    bad.write_text(
        "import os\n"
        "# repro: allow(atomic-io)\n"
        "os.replace('a', 'b')\n"
    )
    got = _counts(analyze_file(str(bad), rel="sneaky.py", scoped=False))
    assert got == {"atomic-io": 1, "bad-suppression": 1}


def test_syntax_error_is_a_finding(tmp_path):
    bad = tmp_path / "broken.py"
    bad.write_text("def oops(:\n")
    findings = analyze_file(str(bad), rel="broken.py", scoped=False)
    assert [f.rule for f in findings] == ["syntax-error"]
