"""Algorithm 3 (directed) tests: approximation vs brute force, c-grid search,
pass bound, planted S->T recovery."""

import numpy as np
import pytest

from repro.core import (
    c_grid,
    densest_directed_brute,
    densest_directed_search,
    densest_subgraph_directed,
)
from repro.graph import from_numpy
from repro.graph.generators import directed_planted, erdos_renyi


def test_directed_brute_comparison_tiny():
    rng = np.random.default_rng(0)
    for seed in range(4):
        n = 7
        src = rng.integers(0, n, 16)
        dst = rng.integers(0, n, 16)
        keep = src != dst
        edges = from_numpy(src[keep], dst[keep], n, directed=True)
        _, _, rho_star = densest_directed_brute(edges)
        res, best_c, _, _ = densest_directed_search(edges, eps=0.05, delta=1.3)
        # (2+2eps) * delta guarantee.
        bound = rho_star / (2 * 1.05 * 1.3)
        assert float(res.best_density) >= bound - 1e-6
        assert float(res.best_density) <= rho_star + 1e-6


def test_planted_directed_block():
    edges, s_ids, t_ids = directed_planted(
        300, avg_deg=3, ks=20, kt=15, p_dense=0.9, seed=1
    )
    res, best_c, rhos, passes = densest_directed_search(edges, eps=0.5, delta=2.0)
    s_found = set(np.nonzero(np.asarray(res.best_s))[0].tolist())
    t_found = set(np.nonzero(np.asarray(res.best_t))[0].tolist())
    assert len(s_found & set(s_ids.tolist())) >= 0.7 * len(s_ids)
    assert len(t_found & set(t_ids.tolist())) >= 0.7 * len(t_ids)
    # Planted block has ~sqrt(20*15)*0.9 density; background ~3.
    assert float(res.best_density) > 5.0


def test_directed_pass_bound():
    edges = erdos_renyi(500, avg_deg=6, seed=2, directed=True)
    r = densest_subgraph_directed(edges, c=1.0, eps=0.5)
    # Lemma 13: O(log_{1+eps} n) for each of S and T.
    import math

    bound = 2 * (math.ceil(math.log(500) / math.log(1.5)) + 4)
    assert int(r.passes) <= bound


def test_c_grid_covers_range():
    grid = c_grid(1000, delta=2.0)
    assert grid.min() <= 1.0 / 1000
    assert grid.max() >= 1000
    # Geometric spacing.
    ratios = grid[1:] / grid[:-1]
    assert np.allclose(ratios, 2.0, rtol=1e-5)


def test_best_pair_density_matches_recomputation():
    edges, _, _ = directed_planted(200, avg_deg=3, ks=12, kt=12, p_dense=0.8, seed=5)
    res = densest_subgraph_directed(edges, c=1.0, eps=0.5)
    s = np.asarray(res.best_s)
    t = np.asarray(res.best_t)
    mask = np.asarray(edges.mask)
    src = np.asarray(edges.src)[mask]
    dst = np.asarray(edges.dst)[mask]
    m_in = np.sum(s[src] & t[dst])
    expect = m_in / np.sqrt(s.sum() * t.sum())
    assert float(res.best_density) == pytest.approx(float(expect), rel=1e-5)


def test_vmapped_c_search_matches_loop():
    """One-program vmapped c-grid == the python-loop search (same densities
    for every c, same winner)."""
    from repro.core.peel_directed import (
        densest_directed_search,
        densest_directed_search_vmapped,
    )
    from repro.graph.generators import directed_planted

    edges, _, _ = directed_planted(
        n=2000, avg_deg=5.0, ks=40, kt=16, p_dense=0.5, seed=4
    )
    best, best_c, rhos, passes = densest_directed_search(edges, eps=0.5)
    vc, vrho, vrhos, vpasses = densest_directed_search_vmapped(edges, eps=0.5)
    np.testing.assert_allclose(vrhos, rhos, rtol=1e-5)
    assert vc == best_c
    assert vrho == pytest.approx(float(best.best_density), rel=1e-6)
