"""Streaming driver + MapReduce (shard_map) equivalence and fault tolerance.

Multi-device shard_map equivalence runs in a subprocess with
XLA_FORCE_HOST_PLATFORM_DEVICE_COUNT=8 so the main test process keeps seeing
one device (per the project rule).
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import StreamingDensest, chunked_from_arrays, densest_subgraph
from repro.graph.generators import erdos_renyi, planted_dense_subgraph


def _edges_np(edges):
    mask = np.asarray(edges.mask)
    return (
        np.asarray(edges.src)[mask],
        np.asarray(edges.dst)[mask],
        np.asarray(edges.weight)[mask],
    )


def test_streaming_matches_in_memory():
    edges, _ = planted_dense_subgraph(800, avg_deg=4, k=30, p_dense=0.8, seed=0)
    ref = densest_subgraph(edges, eps=0.5)
    src, dst, w = _edges_np(edges)
    drv = StreamingDensest(
        chunked_from_arrays(src, dst, w, chunk=257),
        n_nodes=edges.n_nodes,
        eps=0.5,
        n_workers=3,
    )
    st = drv.run(resume=False)
    assert st.best_rho == pytest.approx(float(ref.best_density), rel=1e-5)
    assert (st.best_alive == np.asarray(ref.best_alive)).all()
    assert st.pass_idx == int(ref.passes)


def test_streaming_checkpoint_restart(tmp_path):
    """Kill the run after a few passes; resuming must give identical output."""
    edges = erdos_renyi(600, avg_deg=8, seed=1)
    src, dst, w = _edges_np(edges)
    ref = densest_subgraph(edges, eps=0.5)

    ckpt = str(tmp_path / "ck")
    drv = StreamingDensest(
        chunked_from_arrays(src, dst, w, chunk=1000),
        n_nodes=edges.n_nodes,
        eps=0.5,
        checkpoint_dir=ckpt,
        n_workers=2,
    )
    # Simulated crash: run only 2 passes.
    st_partial = drv.run(max_passes=2, resume=False)
    assert st_partial.pass_idx == 2

    drv2 = StreamingDensest(
        chunked_from_arrays(src, dst, w, chunk=1000),
        n_nodes=edges.n_nodes,
        eps=0.5,
        checkpoint_dir=ckpt,
        n_workers=2,
    )
    st = drv2.run(resume=True)  # resumes from pass 2
    assert st.best_rho == pytest.approx(float(ref.best_density), rel=1e-5)
    assert (st.best_alive == np.asarray(ref.best_alive)).all()


def test_streaming_speculative_reissue_is_idempotent():
    edges = erdos_renyi(400, avg_deg=6, seed=2)
    src, dst, w = _edges_np(edges)
    ref = densest_subgraph(edges, eps=1.0)
    drv = StreamingDensest(
        chunked_from_arrays(src, dst, w, chunk=64),
        n_nodes=edges.n_nodes,
        eps=1.0,
        n_workers=4,
        speculative=True,
        speculate_tail_frac=0.5,  # aggressively re-issue half the chunks
    )
    st = drv.run(resume=False)
    assert st.best_rho == pytest.approx(float(ref.best_density), rel=1e-5)


_MAPREDUCE_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax
    from repro.core import densest_subgraph, densest_subgraph_distributed
    from repro.core.mapreduce import make_distributed_directed_peel, shard_edges
    from repro.core.peel_directed import densest_subgraph_directed
    from repro.graph.generators import planted_dense_subgraph, directed_planted

    assert len(jax.devices()) == 8
    mesh = jax.make_mesh((8,), ("data",))

    # Undirected equivalence: identical best set + density for any sharding.
    edges, _ = planted_dense_subgraph(500, avg_deg=4, k=25, p_dense=0.8, seed=0)
    ref = densest_subgraph(edges, eps=0.5)
    res = densest_subgraph_distributed(edges, mesh, ("data",), eps=0.5)
    assert abs(float(res.best_density) - float(ref.best_density)) < 1e-5
    assert (np.asarray(res.best_alive) == np.asarray(ref.best_alive)).all()
    assert int(res.passes) == int(ref.passes)

    # Permuted edge order must give identical results (order independence).
    perm = np.random.default_rng(0).permutation(edges.src.shape[0])
    from repro.graph.edgelist import EdgeList
    import jax.numpy as jnp
    edges_p = EdgeList(
        src=edges.src[perm], dst=edges.dst[perm], weight=edges.weight[perm],
        mask=edges.mask[perm], n_nodes=edges.n_nodes)
    res_p = densest_subgraph_distributed(edges_p, mesh, ("data",), eps=0.5)
    assert (np.asarray(res_p.best_alive) == np.asarray(ref.best_alive)).all()

    # Directed equivalence.
    dg, _, _ = directed_planted(300, avg_deg=3, ks=15, kt=15, p_dense=0.9, seed=1)
    dref = densest_subgraph_directed(dg, c=1.0, eps=0.5)
    dsh = shard_edges(dg, mesh, ("data",))
    dfn = make_distributed_directed_peel(mesh, ("data",), eps=0.5, n_nodes=dsh.n_nodes)
    ds, dt, drho, dp = dfn(dsh.src, dsh.dst, dsh.weight, dsh.mask, 1.0)
    assert abs(float(drho) - float(dref.best_density)) < 1e-5
    assert (np.asarray(ds) == np.asarray(dref.best_s)).all()
    print("MAPREDUCE_EQUIV_OK")
    """
)


def test_mapreduce_equivalence_8_devices():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    out = subprocess.run(
        [sys.executable, "-c", _MAPREDUCE_SCRIPT],
        capture_output=True,
        text=True,
        env=env,
        timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert "MAPREDUCE_EQUIV_OK" in out.stdout
