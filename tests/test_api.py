"""Front-door redesign tests (core/api.py).

Three guarantees:
  1. EQUIVALENCE MATRIX — ``solve()`` is bit-identical to every legacy entry
     point (and to a direct engine lowering) across the policy × backend ×
     substrate cells.
  2. BATCHING — ``solve_batch`` (multi-eps / multi-c / stacked graphs) is
     bit-identical to a Python loop of per-item ``solve`` calls and runs as
     ONE traced program.
  3. CACHING — a repeated same-shape ``solve`` hits the Solver's program
     cache and does not retrace.

eps values in batched comparisons are f32-exact (dyadic) so that the python
float scalar folding of the unbatched path and the traced-f32 arithmetic of
the vmapped path agree to the bit.
"""

import dataclasses
import warnings

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.core import (
    DenseSubgraphResult,
    Problem,
    Solver,
    StreamingDensest,
    chunked_from_arrays,
    densest_directed_search,
    densest_subgraph,
    densest_subgraph_at_least_k,
    densest_subgraph_directed,
    densest_subgraph_distributed,
    densest_subgraph_sketched,
    solve,
    solve_batch,
)
from repro.core.engine import (
    AtLeastKFraction,
    DirectedST,
    ExactBackend,
    UndirectedThreshold,
    run_peel,
)
from repro.graph.edgelist import EdgeList
from repro.graph.generators import (
    directed_planted,
    erdos_renyi,
    planted_dense_subgraph,
)


def _und():
    return planted_dense_subgraph(260, avg_deg=4, k=25, p_dense=0.8, seed=3)[0]


def _dir():
    return directed_planted(200, avg_deg=3, ks=15, kt=12, p_dense=0.9, seed=5)[0]


def _same(a, b):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def _same_result(r, l):
    """Bit-identical best set / density / passes (and T side if present)."""
    _same(r.best_alive, l.best_alive)
    assert float(r.best_density) == float(l.best_density)
    assert int(r.passes) == int(l.passes)
    assert int(r.best_size) == int(l.best_size)
    if np.asarray(r.best_t).size:
        _same(r.best_t, l.best_t)


# ---------------------------------------------------------------------------
# 1. Equivalence matrix: solve() vs legacy entry points and direct lowering
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("eps", [0.1, 0.5])
def test_solve_undirected_matches_legacy_and_engine(eps):
    edges = _und()
    r = solve(edges, Problem.undirected(eps=eps, track_history=True))
    legacy = densest_subgraph(edges, eps=eps)
    _same_result(r, legacy)
    _same(r.history_n, legacy.history_n)
    # Independent lowering straight onto the engine.
    mp = Problem.undirected(eps=eps).resolved_max_passes(edges.n_nodes)
    ref = jax.jit(
        lambda e: run_peel(
            e, UndirectedThreshold(eps), ExactBackend(), mp, track_history=True
        )
    )(edges)
    _same_result(r, ref)
    assert r.provenance.backend == "exact"
    assert r.provenance.substrate == "jit"


@pytest.mark.parametrize("variant", ["floor_fallback", "ceil_plain"])
def test_solve_at_least_k_matches_legacy_and_engine(variant):
    edges = _und()
    k, eps = 30, 0.5
    fallback = variant == "floor_fallback"
    prob = Problem.at_least_k(
        k=k, eps=eps, min_deg_fallback=fallback, ceil_count=not fallback
    )
    r = solve(edges, prob)
    mp = prob.resolved_max_passes(edges.n_nodes)
    ref = jax.jit(
        lambda e: run_peel(
            e,
            AtLeastKFraction(
                k=k, eps=eps, min_deg_fallback=fallback, ceil_count=not fallback
            ),
            ExactBackend(),
            mp,
        )
    )(edges)
    _same_result(r, ref)
    if fallback:  # the single-device legacy realization
        _same_result(r, densest_subgraph_at_least_k(edges, k=k, eps=eps))


@pytest.mark.parametrize("c", [0.5, 1.0, 2.0])
def test_solve_directed_matches_legacy_and_engine(c):
    edges = _dir()
    eps = 0.5
    prob = Problem.directed(c=c, eps=eps)
    r = solve(edges, prob)
    _same_result(r, densest_subgraph_directed(edges, c=c, eps=eps))
    mp = prob.resolved_max_passes(edges.n_nodes)
    ref = jax.jit(
        lambda e: run_peel(
            e, DirectedST(eps=eps, c=jnp.float32(c)), ExactBackend(), mp
        )
    )(edges)
    _same_result(r, ref)


def test_solve_directed_grid_matches_legacy_search():
    edges = _dir()
    r = solve(edges, Problem.directed(c=None, eps=0.5))
    legacy, best_c, rhos, passes = densest_directed_search(edges, eps=0.5)
    assert r.extras["best_c"] == best_c
    np.testing.assert_array_equal(r.extras["c_density"], rhos)
    np.testing.assert_array_equal(r.extras["c_passes"], passes)
    _same_result(r, legacy)


def test_solve_sketch_matches_legacy_sketched():
    edges = _und()
    t, b, seed = 5, 1 << 12, 7
    prob = Problem.undirected(
        eps=0.5, backend="sketch", sketch_tables=t, sketch_buckets=b,
        sketch_seed=seed, track_history=True,
    )
    r = solve(edges, prob)
    _same_result(r, densest_subgraph_sketched(edges, eps=0.5, t=t, b=b, seed=seed))
    assert r.provenance.backend == "sketch"


def test_solve_pallas_matches_exact():
    edges = erdos_renyi(300, avg_deg=6, seed=4)
    rp = solve(
        edges, Problem.undirected(eps=0.5, backend="pallas", tile_size=128, tile_block=128)
    )
    re = solve(edges, Problem.undirected(eps=0.5))
    _same_result(rp, re)  # tiled degrees are exact arithmetic
    assert rp.provenance.backend == "pallas"


def test_solve_mesh_matches_jit():
    edges = _und()
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1), ("data",))
    rm = solve(edges, Problem.undirected(eps=0.5, substrate="mesh"), mesh=mesh)
    rj = solve(edges, Problem.undirected(eps=0.5))
    _same_result(rm, rj)
    _same_result(rm, densest_subgraph_distributed(edges, mesh, ("data",), eps=0.5))
    assert rm.provenance.substrate == "mesh"


def test_solve_streaming_matches_jit_and_driver():
    edges = _und()
    rs = solve(
        edges,
        Problem.undirected(eps=0.5, substrate="streaming", stream_chunk=257,
                           stream_workers=2),
    )
    rj = solve(edges, Problem.undirected(eps=0.5))
    _same(rs.best_alive, rj.best_alive)
    assert float(rs.best_density) == pytest.approx(float(rj.best_density), rel=1e-5)
    assert int(rs.passes) == int(rj.passes)
    # And it is the same driver the legacy entry point runs.
    mask = np.asarray(edges.mask)
    st = StreamingDensest(
        chunked_from_arrays(
            np.asarray(edges.src)[mask], np.asarray(edges.dst)[mask],
            np.asarray(edges.weight)[mask], chunk=257,
        ),
        n_nodes=edges.n_nodes, eps=0.5, n_workers=2,
    ).run(resume=False)
    _same(rs.best_alive, st.best_alive)
    assert float(rs.best_density) == pytest.approx(st.best_rho, rel=1e-6)


# ---------------------------------------------------------------------------
# 2. solve_batch == loop of per-item solve, in one traced program
# ---------------------------------------------------------------------------


def test_solve_batch_eps_matches_loop():
    edges = _und()
    grid = [0.125, 0.25, 0.5, 1.0]  # f32-exact eps values
    prob = Problem.undirected(max_passes=48, track_history=True)
    s = Solver()
    rb = s.solve_batch(edges, prob, eps=grid)
    assert rb.provenance.batch == "eps"
    assert rb.best_alive.shape == (len(grid), edges.n_nodes)
    for i, e in enumerate(grid):
        ri = s.solve(edges, Problem.undirected(eps=e, max_passes=48, track_history=True))
        _same(rb.best_alive[i], ri.best_alive)
        assert float(rb.best_density[i]) == float(ri.best_density)
        assert int(rb.passes[i]) == int(ri.passes)
        _same(rb.history_n[i], ri.history_n)


def test_solve_batch_eps_at_least_k_matches_loop():
    edges = _und()
    grid = [0.25, 0.5, 1.0]
    s = Solver()
    rb = s.solve_batch(edges, Problem.at_least_k(k=30, max_passes=48), eps=grid)
    for i, e in enumerate(grid):
        ri = s.solve(edges, Problem.at_least_k(k=30, eps=e, max_passes=48))
        _same(rb.best_alive[i], ri.best_alive)
        assert float(rb.best_density[i]) == float(ri.best_density)


def test_solve_batch_c_matches_loop():
    edges = _dir()
    cs = [0.5, 1.0, 2.0, 4.0]
    s = Solver()
    rb = s.solve_batch(edges, Problem.directed(eps=0.5, max_passes=48), c=cs)
    assert rb.provenance.batch == "c"
    for i, c in enumerate(cs):
        ri = s.solve(edges, Problem.directed(c=c, eps=0.5, max_passes=48))
        _same(rb.best_alive[i], ri.best_alive)
        _same(rb.best_t[i], ri.best_t)
        assert float(rb.best_density[i]) == float(ri.best_density)
        assert int(rb.passes[i]) == int(ri.passes)


def test_solve_batch_graphs_matches_loop():
    g1 = erdos_renyi(250, avg_deg=6, seed=4)
    perm = np.random.default_rng(1).permutation(g1.src.shape[0])
    g2 = EdgeList(
        src=g1.src[perm], dst=g1.dst[perm], weight=g1.weight[perm],
        mask=g1.mask[perm], n_nodes=g1.n_nodes,
    )
    prob = Problem.undirected(eps=0.5, max_passes=32)
    s = Solver()
    rb = s.solve_batch([g1, g2], prob)
    assert rb.provenance.batch == "graphs"
    for i, g in enumerate((g1, g2)):
        ri = s.solve(g, prob)
        _same(rb.best_alive[i], ri.best_alive)
        assert float(rb.best_density[i]) == float(ri.best_density)


def test_solve_batch_is_one_program():
    """A 4-point eps sweep traces exactly once (one XLA program)."""
    edges = _und()
    s = Solver()
    s.solve_batch(edges, Problem.undirected(max_passes=32), eps=[0.25, 0.5, 1.0, 2.0])
    assert s.trace_count == 1
    assert s.cache_misses == 1
    # Same-shape re-run: cache hit, still no retrace.
    s.solve_batch(edges, Problem.undirected(max_passes=32), eps=[0.25, 0.5, 1.0, 2.0])
    assert s.trace_count == 1
    assert s.cache_hits == 1


def test_solve_batch_needs_exactly_one_axis():
    edges = _und()
    with pytest.raises(ValueError):
        solve_batch(edges, Problem.undirected())
    with pytest.raises(ValueError):
        solve_batch(edges, Problem.directed(c=1.0), eps=[0.5], c=[1.0])


# ---------------------------------------------------------------------------
# 3. Compile caching: repeated same-shape solves never retrace
# ---------------------------------------------------------------------------


def test_solver_cache_no_retrace_same_shape():
    """The off path compiles exactly one program per (shape, statics).
    (compaction defaults to 'auto' since the ROADMAP flip, so the one-
    program expectation needs the explicit 'off'; the ladder-default cache
    behavior is test_default_compaction_ladder_caches below.)"""
    edges = _und()
    perm = np.random.default_rng(0).permutation(edges.src.shape[0])
    other = EdgeList(
        src=edges.src[perm], dst=edges.dst[perm], weight=edges.weight[perm],
        mask=edges.mask[perm], n_nodes=edges.n_nodes,
    )
    s = Solver()
    prob = Problem.undirected(eps=0.5, compaction="off")
    s.solve(edges, prob)
    assert (s.trace_count, s.cache_misses, s.cache_hits) == (1, 1, 0)
    s.solve(other, prob)  # same shapes, different data
    assert (s.trace_count, s.cache_misses, s.cache_hits) == (1, 1, 1)
    s.solve(edges, prob)
    assert (s.trace_count, s.cache_misses, s.cache_hits) == (1, 1, 2)
    # A different static field is a different program.
    s.solve(edges, Problem.undirected(eps=0.25, compaction="off"))
    assert s.cache_misses == 2 and s.trace_count == 2


def test_default_compaction_ladder_caches():
    """The DEFAULT Problem now rides the geometric ladder (compaction='auto'
    -> geometric for exact backends, the ROADMAP flip): the first solve
    compiles one program per pow2 rung bucket; same-shape re-solves hit the
    program cache everywhere (no retrace anywhere in the ladder)."""
    edges = _und()
    s = Solver()
    r1 = s.solve(edges, Problem.undirected(eps=0.5))
    assert r1.provenance.compaction == "geometric"
    rungs = len(r1.extras["compaction"]["segments"])
    assert rungs >= 1
    assert s.trace_count == s.cache_misses  # one trace per rung bucket
    traces, misses = s.trace_count, s.cache_misses
    r2 = s.solve(edges, Problem.undirected(eps=0.5))
    assert (s.trace_count, s.cache_misses) == (traces, misses)
    assert s.cache_hits == rungs
    assert r2.provenance.cache_hit


def test_solve_batch_eps_keys_fixed_directed_c():
    """eps-sweep programs bake the fixed directed c into the closure, so a
    different c must be a cache MISS (regression: c was excluded from every
    key and the second c silently reused the first c's program)."""
    edges = _dir()
    s = Solver()
    r1 = s.solve_batch(edges, Problem.directed(c=1.0, max_passes=48), eps=[0.5])
    r8 = s.solve_batch(edges, Problem.directed(c=8.0, max_passes=48), eps=[0.5])
    assert s.cache_misses == 2
    for c, rb in ((1.0, r1), (8.0, r8)):
        ri = s.solve(edges, Problem.directed(c=c, eps=0.5, max_passes=48))
        _same(rb.best_alive[0], ri.best_alive)
        assert float(rb.best_density[0]) == float(ri.best_density)


def test_solve_batch_accepts_prestacked_edgelist():
    from repro.core import stack_graphs

    g1 = erdos_renyi(250, avg_deg=6, seed=4)
    perm = np.random.default_rng(1).permutation(g1.src.shape[0])
    g2 = EdgeList(
        src=g1.src[perm], dst=g1.dst[perm], weight=g1.weight[perm],
        mask=g1.mask[perm], n_nodes=g1.n_nodes,
    )
    prob = Problem.undirected(eps=0.5, max_passes=32)
    s = Solver()
    rb = s.solve_batch(stack_graphs([g1, g2]), prob)
    for i, g in enumerate((g1, g2)):
        _same(rb.best_alive[i], s.solve(g, prob).best_alive)


def test_cache_ignores_fields_the_program_never_reads():
    """Knobs of cells that are not running (streaming params on a jit solve,
    tile params on an exact backend) must not force a recompile — on the
    off path AND on the default ladder's per-rung programs."""
    edges = _und()
    s = Solver()
    s.solve(edges, Problem.undirected(eps=0.5, compaction="off"))
    s.solve(edges, Problem.undirected(eps=0.5, compaction="off",
                                      stream_workers=8, stream_chunk=64))
    s.solve(edges, Problem.undirected(eps=0.5, compaction="off",
                                      tile_size=256, wire_dtype="bf16"))
    s.solve(edges, Problem.undirected(eps=0.5, compaction="off",
                                      c_delta=3.0, sketch_buckets=1 << 8))
    assert s.cache_misses == 1 and s.cache_hits == 3 and s.trace_count == 1
    # Default (auto -> geometric) path: irrelevant knobs may not recompile
    # any ladder rung either.
    s2 = Solver()
    s2.solve(edges, Problem.undirected(eps=0.5))
    misses, traces = s2.cache_misses, s2.trace_count
    s2.solve(edges, Problem.undirected(eps=0.5, stream_workers=8, stream_chunk=64))
    s2.solve(edges, Problem.undirected(eps=0.5, tile_size=256))
    assert (s2.cache_misses, s2.trace_count) == (misses, traces)
    assert s2.cache_hits == 2 * misses


def test_solve_rejects_silently_dropped_kwargs():
    edges = _und()
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1), ("data",))
    with pytest.raises(ValueError):
        solve(edges, Problem.undirected(substrate="mesh"), mesh=mesh,
              degree_fn=lambda e, w: w)
    with pytest.raises(ValueError):
        solve(edges, Problem.undirected(), checkpoint_dir="/tmp/nope")


def test_auto_substrate_without_mesh_is_jit():
    """substrate='auto' with no mesh supplied must run (jit), whatever the
    host device count."""
    edges = _und()
    r = solve(edges, Problem.undirected(substrate="auto"))
    assert r.provenance.substrate == "jit"


def test_c_delta_validated():
    with pytest.raises(ValueError):
        Problem.directed(c_delta=1.0)


def test_auto_backend_resolves_exact_for_streaming():
    """streaming + backend='auto' must pick the exact cell even above the
    auto-sketch node threshold (the only cell the driver implements)."""
    p = Problem.undirected(backend="auto", substrate="streaming").resolve(5_000_000)
    assert p.backend == "exact"


def test_solver_cache_directed_shares_program_across_c():
    """c is a runtime scalar: the whole grid (and any fixed c) reuses ONE
    compiled program — the paper's ~35-min-per-c cost collapses.  (Pinned
    to compaction='off'; the ladder-path analogue is
    test_compaction_ladder_shares_programs_across_c.)"""
    edges = _dir()
    s = Solver()
    s.solve(edges, Problem.directed(c=1.0, eps=0.5, compaction="off"))
    s.solve(edges, Problem.directed(c=2.0, eps=0.5, compaction="off"))
    s.solve(edges, Problem.directed(c=None, eps=0.5, compaction="off"))  # grid
    assert s.trace_count == 1
    assert s.cache_misses == 1
    assert s.cache_hits == 2


# ---------------------------------------------------------------------------
# Compaction runtime: bit-identity matrix vs compaction='off'
# ---------------------------------------------------------------------------


def _same_full(a, b):
    """Every outcome array bit-identical (best/final sets, scalars, history)."""
    _same_result(a, b)
    _same(a.alive, b.alive)
    if np.asarray(a.t_alive).size:
        _same(a.t_alive, b.t_alive)


@pytest.mark.parametrize("mode", ["geometric", "twophase"])
@pytest.mark.parametrize("eps", [0.1, 0.5])
def test_compaction_undirected_jit_bit_identical(mode, eps):
    edges = _und()
    s = Solver()
    off = s.solve(edges, Problem.undirected(eps=eps, track_history=True))
    on = s.solve(
        edges, Problem.undirected(eps=eps, track_history=True, compaction=mode)
    )
    _same_full(off, on)
    _same(off.history_n, on.history_n)
    _same(off.history_rho, on.history_rho)
    assert on.provenance.compaction == mode
    lad = on.extras["compaction"]
    assert lad["passes"] == int(off.passes)
    assert sum(seg["passes"] for seg in lad["segments"]) == int(off.passes)


@pytest.mark.parametrize("mode", ["geometric", "twophase"])
def test_compaction_at_least_k_jit_bit_identical(mode):
    edges = _und()
    s = Solver()
    off = s.solve(edges, Problem.at_least_k(k=30, eps=0.5))
    on = s.solve(edges, Problem.at_least_k(k=30, eps=0.5, compaction=mode))
    _same_full(off, on)


@pytest.mark.parametrize("mode", ["geometric", "twophase"])
@pytest.mark.parametrize("c", [0.5, 1.0, 2.0])
def test_compaction_directed_jit_bit_identical(mode, c):
    edges = _dir()
    s = Solver()
    off = s.solve(edges, Problem.directed(c=c, eps=0.5))
    on = s.solve(edges, Problem.directed(c=c, eps=0.5, compaction=mode))
    _same_full(off, on)


def test_compaction_directed_grid_matches_off():
    edges = _dir()
    s = Solver()
    off = s.solve(edges, Problem.directed(c=None, eps=0.5))
    on = s.solve(edges, Problem.directed(c=None, eps=0.5, compaction="geometric"))
    assert on.extras["best_c"] == off.extras["best_c"]
    np.testing.assert_array_equal(on.extras["c_density"], off.extras["c_density"])
    np.testing.assert_array_equal(on.extras["c_passes"], off.extras["c_passes"])
    _same_result(on, off)


def test_compaction_pallas_backend_rides_the_ladder():
    edges = erdos_renyi(300, avg_deg=6, seed=4)
    s = Solver()
    prob = Problem.undirected(eps=0.5, backend="pallas", tile_size=128, tile_block=128)
    off = s.solve(edges, prob)
    on = s.solve(edges, dataclasses.replace(prob, compaction="geometric"))
    _same_full(off, on)


def test_compaction_mesh_substrate_bit_identical():
    edges = _und()
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1), ("data",))
    s = Solver()
    off = s.solve(edges, Problem.undirected(eps=0.2, substrate="mesh"), mesh=mesh)
    on = s.solve(
        edges,
        Problem.undirected(eps=0.2, substrate="mesh", compaction="geometric"),
        mesh=mesh,
    )
    _same_full(off, on)


def test_compaction_streaming_substrate_bit_identical():
    edges = _und()
    s = Solver()
    off = s.solve(
        edges,
        Problem.undirected(eps=0.5, substrate="streaming", stream_chunk=257,
                           stream_workers=2),
    )
    on = s.solve(
        edges,
        Problem.undirected(eps=0.5, substrate="streaming", stream_chunk=257,
                           stream_workers=2, compaction="geometric"),
    )
    _same(off.best_alive, on.best_alive)
    _same(off.alive, on.alive)
    assert float(off.best_density) == float(on.best_density)
    assert int(off.passes) == int(on.passes)


def test_compaction_scans_fewer_edge_slots():
    """The acceptance metric in miniature: the geometric ladder scans
    strictly fewer edge slots than passes x padded-m."""
    edges = _und()
    s = Solver()
    on = s.solve(edges, Problem.undirected(eps=0.1, compaction="geometric"))
    lad = on.extras["compaction"]
    off_slots = int(on.passes) * edges.n_edges_padded
    assert lad["edge_slots_scanned"] < off_slots


def test_compaction_ladder_shares_programs_across_c():
    """c is a runtime argument of segment programs too: rung cache keys for
    two fixed c values must be IDENTICAL (regression: c keyed the rungs and
    every fixed c recompiled the whole ladder)."""
    s = Solver()
    edges = _dir()
    p1 = Problem.directed(c=0.5, eps=0.5, compaction="geometric").resolve(edges.n_nodes)
    p2 = Problem.directed(c=1.0, eps=0.5, compaction="geometric").resolve(edges.n_nodes)
    for kind in ("cseg", "cseg_mesh", "ladder_mesh"):
        k1 = s._key(kind, p1, 32, 128, 1024, "float32", None, (64,))
        k2 = s._key(kind, p2, 32, 128, 1024, "float32", None, (64,))
        assert k1 == k2
    a = s.solve(edges, Problem.directed(c=0.5, eps=0.5, compaction="geometric"))
    b = s.solve(edges, Problem.directed(c=1.0, eps=0.5, compaction="geometric"))
    _same_full(s.solve(edges, Problem.directed(c=0.5, eps=0.5)), a)
    _same_full(s.solve(edges, Problem.directed(c=1.0, eps=0.5)), b)


def test_compaction_ladder_programs_are_cached():
    """Same graph re-solved: every ladder rung must be a program-cache hit
    (the Solver keys rungs on bucket shape, not graph content)."""
    edges = _und()
    s = Solver()
    s.solve(edges, Problem.undirected(eps=0.25, compaction="geometric"))
    traces = s.trace_count
    r2 = s.solve(edges, Problem.undirected(eps=0.25, compaction="geometric"))
    assert s.trace_count == traces  # no retrace anywhere in the ladder
    assert r2.provenance.cache_hit


@pytest.mark.parametrize("mode", ["geometric", "twophase"])
def test_compaction_zero_pass_runs_match_off(mode):
    """Degenerate runs where the loop never executes a pass (k > n, or
    max_passes=0) must still match 'off', which returns the full initial
    set (regression: the ladder used to return an all-empty best set)."""
    edges = erdos_renyi(50, avg_deg=4, seed=0)
    s = Solver()
    for prob in (
        Problem.at_least_k(k=60, eps=0.5),
        Problem.undirected(eps=0.5, max_passes=0),
    ):
        off = s.solve(edges, prob)
        on = s.solve(edges, dataclasses.replace(prob, compaction=mode))
        _same_full(off, on)


def test_compaction_auto_resolution_and_validation():
    # 'auto' is the DEFAULT since the ROADMAP flip (PR 5).
    assert Problem().compaction == "auto"
    # auto -> geometric for exact, off for sketch.
    assert Problem.undirected(compaction="auto").resolve(100).compaction == "geometric"
    # An explicit ladder steers backend='auto' to exact even above the
    # sketch threshold (sketch can't ride the ladder).
    big = Problem.undirected(backend="auto", compaction="geometric").resolve(2_000_000)
    assert big.backend == "exact" and big.compaction == "geometric"
    assert (
        Problem.undirected(backend="sketch", compaction="auto").resolve(100).compaction
        == "off"
    )
    with pytest.raises(ValueError):
        Problem.undirected(backend="sketch", compaction="geometric").resolve(100)
    with pytest.raises(ValueError):
        Problem.undirected(substrate="streaming", compaction="twophase").resolve(100)
    with pytest.raises(ValueError):
        Problem(compaction="nope")
    # Explicit ladder modes are rejected by the batched driver; auto is not.
    edges = _und()
    with pytest.raises(ValueError):
        solve_batch(
            edges, Problem.undirected(max_passes=16, compaction="geometric"),
            eps=[0.5],
        )
    rb = solve_batch(
        edges, Problem.undirected(max_passes=16, compaction="auto"), eps=[0.5]
    )
    assert rb.provenance.compaction == "off"
    # degree_fn hooks bind one buffer; an EXPLICIT ladder conflicts...
    with pytest.raises(ValueError):
        solve(
            edges, Problem.undirected(compaction="geometric"),
            degree_fn=lambda e, w: w,
        )
    # ...but the 'auto' DEFAULT quietly falls back to the uncompacted loop
    # (regression: the flip used to break every existing degree_fn call).
    from repro.core.engine import segment_degree_count

    def hook(e, w_alive):
        return segment_degree_count(e.src, e.dst, w_alive, e.n_nodes)[0]

    s = Solver()
    r_hook = s.solve(edges, Problem.undirected(eps=0.5), degree_fn=hook)
    assert r_hook.provenance.compaction == "off"
    _same_full(r_hook, s.solve(edges, Problem.undirected(eps=0.5, compaction="off")))


# ---------------------------------------------------------------------------
# Result type and deprecation aliases
# ---------------------------------------------------------------------------


def test_result_is_pytree_with_static_provenance():
    edges = _und()
    r = solve(edges, Problem.undirected(eps=0.5))
    jax.block_until_ready(r)
    leaves = jax.tree_util.tree_leaves(r)
    assert any(l.shape == (edges.n_nodes,) for l in leaves)
    mapped = jax.tree_util.tree_map(lambda x: x, r)
    assert mapped.provenance == r.provenance  # static metadata survives
    assert r.nodes().size == int(r.best_size)
    assert isinstance(r, DenseSubgraphResult)


@pytest.mark.parametrize(
    "module,name",
    [
        ("repro.core", "PeelResult"),
        ("repro.core", "PeelTopKResult"),
        ("repro.core", "DirectedPeelResult"),
        ("repro.core.peel", "PeelResult"),
        ("repro.core.peel_topk", "PeelTopKResult"),
        ("repro.core.peel_directed", "DirectedPeelResult"),
    ],
)
def test_deprecated_result_aliases_warn(module, name):
    import importlib

    mod = importlib.import_module(module)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        alias = getattr(mod, name)
    assert alias is DenseSubgraphResult
    assert any(issubclass(w.category, DeprecationWarning) for w in caught)


def test_problem_validation():
    with pytest.raises(ValueError):
        Problem(objective="nope")
    with pytest.raises(ValueError):
        Problem(objective="at_least_k")  # k missing
    with pytest.raises(ValueError):
        Problem.directed(c=1.0, backend="pallas").resolve(100)
    with pytest.raises(ValueError):
        Problem.undirected(substrate="streaming", backend="sketch").resolve(100)
    # auto axes resolve to concrete cells.
    p = Problem.undirected(backend="auto", substrate="auto").resolve(100)
    assert p.backend == "exact" and p.substrate in ("jit", "mesh")


# ---------------------------------------------------------------------------
# Satellite: streaming chunk reducer dtype stability
# ---------------------------------------------------------------------------


def test_chunk_stats_accumulates_float32():
    from repro.core.streaming import _chunk_stats

    src = jnp.asarray([0, 1, 2, 0], jnp.int32)
    dst = jnp.asarray([1, 2, 3, 2], jnp.int32)
    alive = jnp.ones((4,), bool)
    for dtype in (jnp.bfloat16, jnp.float16, jnp.float32):
        deg, total, n_ok = _chunk_stats(src, dst, jnp.ones((4,), dtype), alive)
        assert deg.dtype == jnp.float32
        assert total.dtype == jnp.float32
        assert float(total) == 4.0
        assert int(n_ok) == 4  # the geometric-compaction trigger count
        np.testing.assert_array_equal(np.asarray(deg), [2.0, 2.0, 3.0, 1.0])
    # Dead-endpoint edges drop out of the alive count.
    deg, total, n_ok = _chunk_stats(
        src, dst, jnp.ones((4,), jnp.float32), alive.at[3].set(False)
    )
    assert int(n_ok) == 3 and float(total) == 3.0
