"""Tile-partitioned (shard_map) neighbor sum == GSPMD-default oracle, values
AND gradients, on a forced multi-device host mesh."""

import numpy as np
import pytest

import jax

if jax.device_count() < 4:
    pytest.skip(
        "needs >= 4 host devices (run under XLA_FLAGS="
        "--xla_force_host_platform_device_count=8)",
        allow_module_level=True,
    )

import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.gnn.dist import (
    build_edge_tiling,
    make_tiled_neighbor_sum,
    neighbor_sum_reference,
)


def _setup(seed=0, n=97, e=400, c=8, n_dev=4):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, e).astype(np.int32)
    dst = rng.integers(0, n, e).astype(np.int32)
    tiling = build_edge_tiling(src, dst, n, n_dev)
    mesh = Mesh(np.asarray(jax.devices()[:n_dev]).reshape(n_dev), ("d",))
    x = rng.standard_normal((tiling.n_nodes_padded, c)).astype(np.float32)
    x[n:] = 0.0
    w = rng.random(e).astype(np.float32)
    return tiling, mesh, jnp.asarray(x), jnp.asarray(w), src, dst, n


def test_tiled_neighbor_sum_matches_reference():
    tiling, mesh, x, w, src, dst, n = _setup()
    f = make_tiled_neighbor_sum(tiling, mesh, ("d",))
    xs = jax.device_put(x, NamedSharding(mesh, P("d")))
    got = jax.jit(f)(xs, w)
    want = neighbor_sum_reference(
        x, w, jnp.asarray(src), jnp.asarray(dst), tiling.n_nodes_padded
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_tiled_neighbor_sum_grads_match_reference():
    tiling, mesh, x, w, src, dst, n = _setup(seed=1)
    f = make_tiled_neighbor_sum(tiling, mesh, ("d",))
    rng = np.random.default_rng(9)
    probe = jnp.asarray(
        rng.standard_normal((tiling.n_nodes_padded, x.shape[1])).astype(np.float32)
    )

    def loss_tiled(x, w):
        return jnp.sum(f(x, w) * probe)

    def loss_ref(x, w):
        z = neighbor_sum_reference(
            x, w, jnp.asarray(src), jnp.asarray(dst), tiling.n_nodes_padded
        )
        return jnp.sum(z * probe)

    gx_t, gw_t = jax.grad(loss_tiled, argnums=(0, 1))(x, w)
    gx_r, gw_r = jax.grad(loss_ref, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(np.asarray(gx_t), np.asarray(gx_r), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(gw_t), np.asarray(gw_r), rtol=1e-4, atol=1e-5)


def test_tiling_covers_every_edge_once():
    rng = np.random.default_rng(2)
    n, e = 50, 200
    src = rng.integers(0, n, e).astype(np.int32)
    dst = rng.integers(0, n, e).astype(np.int32)
    t = build_edge_tiling(src, dst, n, 4)
    ids_in = np.sort(t.in_eid[t.in_eid >= 0])
    ids_out = np.sort(t.out_eid[t.out_eid >= 0])
    np.testing.assert_array_equal(ids_in, np.arange(e))
    np.testing.assert_array_equal(ids_out, np.arange(e))
    # dst-local ids really live in their tile
    for d in range(4):
        sel = t.in_eid[d] >= 0
        np.testing.assert_array_equal(
            dst[t.in_eid[d][sel]] // t.tile_n, np.full(sel.sum(), d)
        )
