"""GNN model behaviour: exact equivariance/invariance properties (MACE, EGNN),
permutation invariance, spherical-harmonics identities, segment ops."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.data.synthetic import gnn_molecule_batch
from repro.train.step import init_model_params, specialize_gnn_config


def _rotation_matrix(rng):
    a = rng.standard_normal((3, 3))
    q, r = np.linalg.qr(a)
    q = q * np.sign(np.diag(r))
    if np.linalg.det(q) < 0:
        q[:, 0] = -q[:, 0]
    return jnp.asarray(q.astype(np.float32))


def _mol_batch(seed=0, batch=3, nodes=10, edges=30, d_feat=8):
    rng = np.random.default_rng(seed)
    return gnn_molecule_batch(rng, batch, nodes, edges, d_feat, True), rng


@pytest.mark.parametrize("arch", ["mace", "egnn"])
def test_energy_rotation_invariance(arch):
    """Rotating + translating all positions must not change energies."""
    import importlib

    spec = get_arch(arch)
    cfg = dataclasses.replace(
        specialize_gnn_config(spec.reduced_config, {"d_feat": 8, "n_classes": 0}),
        compute_dtype=jnp.float32,
    )
    m = importlib.import_module(
        {"mace": "repro.models.gnn.mace", "egnn": "repro.models.gnn.egnn"}[arch]
    )
    params = init_model_params(spec, jax.random.PRNGKey(0), cfg=cfg)
    batch, rng = _mol_batch()
    loss1, met1 = m.loss_energy(params, cfg, batch)
    R = _rotation_matrix(rng)
    t = jnp.asarray(rng.standard_normal(3).astype(np.float32))
    batch_rot = dict(batch)
    batch_rot["positions"] = batch["positions"] @ R.T + t
    loss2, met2 = m.loss_energy(params, cfg, batch_rot)
    assert float(jnp.abs(loss1 - loss2)) < 1e-4


def test_egnn_coordinates_are_equivariant():
    """EGNN coordinate outputs rotate exactly with the input rotation."""
    from repro.models.gnn import egnn as m

    spec = get_arch("egnn")
    cfg = dataclasses.replace(
        specialize_gnn_config(spec.reduced_config, {"d_feat": 8, "n_classes": 0}),
        compute_dtype=jnp.float32,
    )
    params = init_model_params(spec, jax.random.PRNGKey(0), cfg=cfg)
    batch, rng = _mol_batch(seed=3)
    _, x1 = m.forward(params, cfg, batch)
    R = _rotation_matrix(rng)
    batch_rot = dict(batch)
    batch_rot["positions"] = batch["positions"] @ R.T
    _, x2 = m.forward(params, cfg, batch_rot)
    np.testing.assert_allclose(
        np.asarray(x1 @ R.T), np.asarray(x2), rtol=1e-4, atol=1e-4
    )


def test_spherical_harmonics_orthonormal():
    """Monte-Carlo check: int Y_a Y_b dOmega = delta_ab (l<=2)."""
    from repro.models.gnn.mace import spherical_harmonics_l2

    rng = np.random.default_rng(0)
    v = rng.standard_normal((200_000, 3))
    v = v / np.linalg.norm(v, axis=1, keepdims=True)
    Y = np.asarray(spherical_harmonics_l2(jnp.asarray(v.astype(np.float32))))
    gram = 4 * np.pi * (Y.T @ Y) / v.shape[0]
    np.testing.assert_allclose(gram, np.eye(9), atol=0.05)


def test_mace_invariants_rotation_stable():
    """The B-basis invariant monomials are exactly rotation invariant."""
    from repro.models.gnn.mace import _invariants, spherical_harmonics_l2

    rng = np.random.default_rng(1)
    v = rng.standard_normal((50, 3)).astype(np.float32)
    v /= np.linalg.norm(v, axis=1, keepdims=True)
    R = _rotation_matrix(rng)
    h = rng.standard_normal((50, 4)).astype(np.float32)
    A1 = (spherical_harmonics_l2(jnp.asarray(v))[:, :, None] * h[:, None, :]).sum(0)[None]
    A2 = (spherical_harmonics_l2(jnp.asarray(v) @ R.T)[:, :, None] * h[:, None, :]).sum(0)[None]
    np.testing.assert_allclose(
        np.asarray(_invariants(A1)), np.asarray(_invariants(A2)), rtol=2e-3, atol=2e-3
    )


@pytest.mark.parametrize("arch", ["mace", "egnn", "graphsage-reddit", "equiformer-v2"])
def test_node_permutation_equivariance(arch):
    """Relabeling nodes permutes outputs correspondingly (message passing is
    symmetric)."""
    import importlib

    spec = get_arch(arch)
    shape = {"d_feat": 8, "n_classes": 3}
    cfg = dataclasses.replace(
        specialize_gnn_config(spec.reduced_config, shape), compute_dtype=jnp.float32
    )
    mod = {
        "mace": "repro.models.gnn.mace",
        "egnn": "repro.models.gnn.egnn",
        "graphsage-reddit": "repro.models.gnn.graphsage",
        "equiformer-v2": "repro.models.gnn.equiformer_v2",
    }[arch]
    m = importlib.import_module(mod)
    params = init_model_params(spec, jax.random.PRNGKey(0), cfg=cfg)

    rng = np.random.default_rng(5)
    n, e = 20, 60
    batch = {
        "features": jnp.asarray(rng.standard_normal((n, 8), dtype=np.float32)),
        "src": jnp.asarray(rng.integers(0, n, e, dtype=np.int32)),
        "dst": jnp.asarray(rng.integers(0, n, e, dtype=np.int32)),
        "edge_mask": jnp.ones((e,), bool),
        "positions": jnp.asarray(rng.standard_normal((n, 3), dtype=np.float32)),
    }
    perm = rng.permutation(n).astype(np.int32)
    inv = np.empty(n, np.int32)
    inv[perm] = np.arange(n, dtype=np.int32)
    batch_p = {
        "features": batch["features"][perm],
        "src": jnp.asarray(inv)[batch["src"]],
        "dst": jnp.asarray(inv)[batch["dst"]],
        "edge_mask": batch["edge_mask"],
        "positions": batch["positions"][perm],
    }

    if arch == "graphsage-reddit":
        out1 = m.forward_full(params, cfg, batch)
        out2 = m.forward_full(params, cfg, batch_p)
    elif arch == "equiformer-v2":
        out1 = m.forward(params, cfg, batch)[:, 0, :]
        out2 = m.forward(params, cfg, batch_p)[:, 0, :]
    elif arch == "egnn":
        out1 = m.forward(params, cfg, batch)[0]
        out2 = m.forward(params, cfg, batch_p)[0]
    else:
        out1 = m.forward(params, cfg, batch)
        out2 = m.forward(params, cfg, batch_p)
    np.testing.assert_allclose(
        np.asarray(out1)[perm], np.asarray(out2), rtol=2e-3, atol=2e-3
    )


def test_segment_softmax_normalizes():
    from repro.models.common import segment_softmax

    rng = np.random.default_rng(0)
    scores = jnp.asarray(rng.standard_normal(100).astype(np.float32))
    seg = jnp.asarray(rng.integers(0, 10, 100, dtype=np.int32))
    p = segment_softmax(scores, seg, 10)
    sums = jax.ops.segment_sum(p, seg, num_segments=10)
    np.testing.assert_allclose(np.asarray(sums), np.ones(10), rtol=1e-5)
