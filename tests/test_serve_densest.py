"""Seed-batched densest-subgraph query engine (serve/densest.py).

Contracts under test:

  * **extraction correctness** — the engine's CSR ego-net (nodes AND
    induced edges) matches an obvious set-based reference BFS over the raw
    edge list, and peeling the extracted+relabeled subgraph equals peeling
    the full graph restricted to that neighborhood;
  * **bucket-coalescing bit-identity** — every batched answer equals a
    standalone ``solve()`` of the same padded ego-net (density float-equal,
    node set exactly equal);
  * **micro-batching mechanics** — FIFO deque admission, ``max_batch``
    flush, ``max_wait_ms`` deadline flush under an injected clock, pow2
    lane padding;
  * **knob validation** and edge cases (isolated seeds, whole-graph egos).
"""

import collections

import numpy as np
import pytest

from repro.core import Problem, Solver, solve
from repro.graph.edgelist import EdgeList, from_numpy, to_csr
from repro.graph.generators import chung_lu_power_law
from repro.graph.partition import pow2_bucket
from repro.serve.densest import DensestQueryEngine

EPS = 0.5
PROB = Problem.undirected(eps=EPS, compaction="off")


def _graph(n=800, seed=0, avg_deg=6.0):
    return chung_lu_power_law(n, exponent=2.0, avg_deg=avg_deg, seed=seed)


def _engine(g, **kw):
    kw.setdefault("max_wait_ms", 0.0)  # tests flush explicitly
    return DensestQueryEngine(g, PROB, **kw)


# ---------------------------------------------------------------------------
# reference extraction (set-based, deliberately naive)
# ---------------------------------------------------------------------------


def _ref_ego(g: EdgeList, seed: int, radius: int):
    """Reference BFS + induced-subgraph over the raw (host) edge list."""
    mask = np.asarray(g.mask)
    src = np.asarray(g.src)[mask]
    dst = np.asarray(g.dst)[mask]
    w = np.asarray(g.weight)[mask]
    adj = collections.defaultdict(set)
    for u, v in zip(src.tolist(), dst.tolist()):
        adj[u].add(v)
        adj[v].add(u)
    members = {seed}
    frontier = {seed}
    for _ in range(radius):
        nxt = set()
        for u in frontier:
            nxt |= adj[u]
        frontier = nxt - members
        members |= frontier
        if not frontier:
            break
    nodes = np.asarray(sorted(members), np.int64)
    keep = np.isin(src, nodes) & np.isin(dst, nodes)
    # Each undirected edge once, canonical (min, max) order.
    es = np.minimum(src[keep], dst[keep])
    ed = np.maximum(src[keep], dst[keep])
    return nodes, es, ed, w[keep]


def test_ego_extraction_matches_reference_bfs():
    g = _graph(n=600, seed=3)
    eng = _engine(g, radius=2)
    rng = np.random.default_rng(0)
    for seed in rng.integers(0, 600, 12).tolist():
        padded, nodes = eng.extract(seed)
        ref_nodes, es, ed, ew = _ref_ego(g, seed, 2)
        assert np.array_equal(nodes, ref_nodes)
        # Engine edges, mapped back to original ids, canonical order.
        msk = np.asarray(padded.mask)
        gs = nodes[np.asarray(padded.src)[msk]]
        gd = nodes[np.asarray(padded.dst)[msk]]
        gw = np.asarray(padded.weight)[msk]
        lo, hi = np.minimum(gs, gd), np.maximum(gs, gd)
        key = lambda a, b: np.lexsort((b, a))
        oe, og = key(lo, hi), key(es, ed)
        assert np.array_equal(lo[oe], es[og])
        assert np.array_equal(hi[oe], ed[og])
        assert np.array_equal(gw[oe], ew[og])


def test_extracted_peel_matches_full_graph_restriction():
    """Peeling the relabeled extraction == peeling the full graph restricted
    to the neighborhood (same reference subgraph built independently)."""
    g = _graph(n=500, seed=7)
    eng = _engine(g, radius=2)
    # Seeds with at least one edge (a zero-edge reference would not pad
    # out to the engine's edge-bucket floor).
    degs = np.diff(to_csr(g)[0])
    seeds = np.nonzero(degs > 0)[0][[0, 7, 42]].tolist()
    for seed in seeds:
        padded, nodes = eng.extract(seed)
        ref_nodes, es, ed, ew = _ref_ego(g, seed, 2)
        # Build the restriction ourselves, pad it into the SAME buckets.
        relabel = {int(n): i for i, n in enumerate(ref_nodes)}
        rs = np.asarray([relabel[int(u)] for u in es], np.int32)
        rd = np.asarray([relabel[int(v)] for v in ed], np.int32)
        ref = from_numpy(
            rs, rd, pow2_bucket(len(ref_nodes), eng.node_floor), weight=ew
        )
        ref = ref.with_padding(padded.n_edges_padded)
        a = solve(padded, PROB)
        b = solve(ref, PROB)
        assert float(a.best_density) == float(b.best_density)
        # Same best set in ORIGINAL ids (edge order within the buffer may
        # differ between the two constructions; the peel result may not).
        sa = np.nonzero(np.asarray(a.best_alive))[0]
        sb = np.nonzero(np.asarray(b.best_alive))[0]
        assert np.array_equal(
            nodes[sa[sa < len(nodes)]], ref_nodes[sb[sb < len(ref_nodes)]]
        )


# ---------------------------------------------------------------------------
# bucket-coalescing bit-identity
# ---------------------------------------------------------------------------


def test_batched_answers_bit_identical_to_sequential_solve():
    g = _graph(n=900, seed=1)
    eng = _engine(g, radius=2, max_batch=8)
    seeds = np.random.default_rng(2).integers(0, 900, 24).tolist()
    results = eng.query_many(seeds)
    assert [r.seed for r in results] == seeds
    seq = Solver()
    for r in results:
        padded, nodes = eng.extract(r.seed)
        ref = seq.solve(padded, PROB)
        assert float(ref.best_density) == r.density
        ba = np.nonzero(np.asarray(ref.best_alive))[0]
        assert np.array_equal(nodes[ba[ba < len(nodes)]], r.nodes)
        assert r.seed_in_set == bool(np.isin(r.seed, r.nodes))


def test_coalesced_buckets_share_programs():
    g = _graph(n=900, seed=1)
    eng = _engine(g, radius=1, max_batch=8)
    seeds = np.random.default_rng(5).integers(0, 900, 32).tolist()
    eng.query_many(seeds)
    trace_first = eng.solver.trace_count
    eng.query_many(seeds)  # same shapes again: zero new programs
    assert eng.solver.trace_count == trace_first
    assert eng.lanes_solved >= len(seeds)
    # Lane counts are pow2-padded so batch size never mints a program.
    for (n_b, m_b), lanes in eng.bucket_histogram.items():
        assert n_b == pow2_bucket(n_b) and m_b == pow2_bucket(m_b)


# ---------------------------------------------------------------------------
# micro-batching mechanics
# ---------------------------------------------------------------------------


class _Clock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_deadline_flush_under_injected_clock():
    clk = _Clock()
    g = _graph(n=300)
    eng = DensestQueryEngine(
        g, PROB, max_batch=8, max_wait_ms=10.0, time_fn=clk
    )
    eng.submit(3)
    assert eng.step() == []  # not full, not old: nothing due
    assert eng.pending() == 1
    clk.t = 0.009
    assert eng.step() == []  # 9ms < 10ms deadline
    clk.t = 0.011
    out = eng.step()  # oldest aged past the deadline -> flush
    assert len(out) == 1 and out[0].seed == 3
    assert out[0].latency_s == pytest.approx(0.011)
    assert eng.pending() == 0


def test_full_batch_flushes_without_deadline():
    clk = _Clock()
    g = _graph(n=300)
    eng = DensestQueryEngine(
        g, PROB, max_batch=4, max_wait_ms=1e9, time_fn=clk
    )
    for s in range(3):
        eng.submit(s)
    assert eng.step() == []  # 3 < max_batch and deadline far away
    eng.submit(3)
    out = eng.step()  # 4th arrival fills the batch
    assert [r.seed for r in out] == [0, 1, 2, 3]  # FIFO order
    assert eng.batches_flushed == 1


def test_queue_is_a_deque_and_fifo():
    g = _graph(n=300)
    eng = _engine(g, max_batch=2)
    assert isinstance(eng._queue, collections.deque)
    qids = [eng.submit(s) for s in (5, 6, 7)]
    out = eng.flush()  # two batches: [5, 6] then [7]
    assert [r.qid for r in out] == qids
    assert eng.batches_flushed == 2


def test_lane_padding_is_pow2():
    g = _graph(n=300)
    eng = _engine(g, radius=1, max_batch=8)
    eng.query_many([1, 2, 3])  # likely one bucket of 3 -> 4 lanes
    assert eng.lanes_solved == sum(eng.bucket_histogram.values())
    for (_, _), lanes in eng.bucket_histogram.items():
        assert lanes == pow2_bucket(lanes)


# ---------------------------------------------------------------------------
# edge cases + validation
# ---------------------------------------------------------------------------


def test_isolated_seed():
    # Node 4 has no edges: the ego-net is just the seed, density 0.
    g = from_numpy(np.asarray([0, 1]), np.asarray([1, 2]), 5)
    eng = _engine(g)
    r = eng.query(4)
    assert r.n_ego == 1 and r.m_ego == 0
    assert r.density == 0.0
    assert np.array_equal(r.nodes, [4])


def test_radius_covers_whole_component():
    g = from_numpy(np.asarray([0, 1, 2]), np.asarray([1, 2, 3]), 4)
    eng = _engine(g, radius=3)
    padded, nodes = eng.extract(0)
    assert np.array_equal(nodes, [0, 1, 2, 3])
    assert int(np.asarray(padded.mask).sum()) == 3


def test_max_ego_nodes_truncates_deterministically():
    g = _graph(n=600, seed=3)
    eng = _engine(g, radius=2, max_ego_nodes=20)
    # Pick a seed with a big 2-hop ball.
    indptr, _ = to_csr(g)
    seed = int(np.argmax(np.diff(indptr)))
    _, nodes = eng.extract(seed)
    assert len(nodes) <= 20
    _, nodes2 = eng.extract(seed)
    assert np.array_equal(nodes, nodes2)


def test_scratch_membership_resets_between_queries():
    g = _graph(n=400, seed=2)
    eng = _engine(g, radius=2)
    _, n1 = eng.extract(7)
    assert not eng._member.any()
    _, n2 = eng.extract(7)
    assert np.array_equal(n1, n2)


def test_validation():
    g = _graph(n=300)
    directed = EdgeList(
        src=g.src, dst=g.dst, weight=g.weight, mask=g.mask,
        n_nodes=g.n_nodes, directed=True,
    )
    with pytest.raises(ValueError, match="undirected"):
        DensestQueryEngine(directed, PROB)
    with pytest.raises(ValueError, match="substrate"):
        DensestQueryEngine(g, Problem.undirected(substrate="streaming"))
    with pytest.raises(ValueError, match="directed"):
        DensestQueryEngine(g, Problem.directed())
    with pytest.raises(ValueError, match="backend"):
        DensestQueryEngine(g, Problem.undirected(backend="pallas"))
    with pytest.raises(ValueError, match="radius"):
        DensestQueryEngine(g, PROB, radius=0)
    with pytest.raises(ValueError, match="max_batch"):
        DensestQueryEngine(g, PROB, max_batch=0)
    with pytest.raises(ValueError, match="seed"):
        _engine(g).submit(300)
    with pytest.raises(ValueError, match="seed"):
        _engine(g).extract(-1)


def test_submit_rejects_bad_seeds_eagerly():
    """Regression: ``submit`` validates the seed AT SUBMIT time, not at
    flush — a float seed used to slip past the range check, truncate
    silently inside the numpy extraction, and answer for the wrong node."""
    g = _graph(n=300)
    eng = _engine(g)
    for bad in (2.5, np.float64(2.0), True, np.bool_(False), "5", None):
        with pytest.raises(TypeError, match="seed"):
            eng.submit(bad)
    for bad in (-1, 300, np.int64(10_000)):
        with pytest.raises(ValueError, match="seed"):
            eng.submit(bad)
    # Nothing bad was enqueued: the healthy np-integer seed still answers.
    qid = eng.submit(np.int64(5))
    (res,) = eng.flush()
    assert res.qid == qid and res.status == "ok"


def test_per_query_knob_validation():
    g = _graph(n=300)
    eng = _engine(g)
    with pytest.raises(ValueError, match="radius"):
        eng.submit(5, 0)
    with pytest.raises(TypeError, match="radius"):
        eng.submit(5, 1.5)
    with pytest.raises(ValueError, match="budget"):
        eng.submit(5, budget=16)  # budget is the local-extraction knob


def test_works_with_at_least_k_objective():
    g = _graph(n=400, seed=4)
    prob = Problem.at_least_k(k=4, eps=EPS, compaction="off")
    eng = DensestQueryEngine(g, prob, max_wait_ms=0.0)
    r = eng.query(10)
    padded, nodes = eng.extract(10)
    ref = solve(padded, prob)
    assert float(ref.best_density) == r.density


def test_disk_cache_threads_through_engine(tmp_path):
    g = _graph(n=400, seed=6)
    d = str(tmp_path / "cache")
    e1 = DensestQueryEngine(g, PROB, cache_dir=d, max_wait_ms=0.0)
    r1 = e1.query(11)
    assert e1.solver.disk_misses >= 1
    e2 = DensestQueryEngine(g, PROB, cache_dir=d, max_wait_ms=0.0)
    r2 = e2.query(11)
    assert e2.solver.trace_count == 0 and e2.solver.disk_hits >= 1
    assert r1.density == r2.density and np.array_equal(r1.nodes, r2.nodes)
