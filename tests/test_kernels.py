"""Per-kernel validation: pallas_call in interpret mode vs pure-jnp oracle,
sweeping shapes and dtypes, plus end-to-end equivalence inside Algorithm 1."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.countsketch import make_sketch_params
from repro.graph.generators import planted_dense_subgraph
from repro.graph.partition import bucket_edges_by_tile
from repro.kernels.count_sketch.ops import count_sketch_update
from repro.kernels.count_sketch.ref import count_sketch_update_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.peel_degree.kernel import tiled_degrees_pallas
from repro.kernels.peel_degree.ref import degrees_from_tiled, tiled_degrees_ref


# ------------------------------ peel_degree ---------------------------------


@pytest.mark.parametrize(
    "n_nodes,n_edges,tile_size,block_e",
    [
        (100, 400, 32, 64),
        (1000, 5000, 128, 128),
        (257, 1000, 64, 256),  # n_nodes not a tile multiple
        (64, 50, 64, 64),      # single tile, fewer edges than block
    ],
)
def test_peel_degree_kernel_matches_ref(n_nodes, n_edges, tile_size, block_e):
    rng = np.random.default_rng(0)
    src = rng.integers(0, n_nodes, n_edges).astype(np.int32)
    dst = rng.integers(0, n_nodes, n_edges).astype(np.int32)
    tiled = bucket_edges_by_tile(src, dst, n_nodes, tile_size, block_e)
    w_edges = rng.random(n_edges).astype(np.float32)
    # Route per-edge weights through the static bucketing.
    ei = tiled.edge_index
    w = np.where(ei >= 0, w_edges[np.maximum(ei, 0)], 0.0).astype(np.float32)

    got = tiled_degrees_pallas(
        jnp.asarray(tiled.target_local), jnp.asarray(w),
        tile_size=tile_size, block_e=block_e, interpret=True,
    )
    want = tiled_degrees_ref(
        jnp.asarray(tiled.target_local), jnp.asarray(w), tile_size=tile_size
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)

    # And against a direct numpy degree count.
    deg = np.zeros(n_nodes, np.float64)
    np.add.at(deg, src, w_edges)
    np.add.at(deg, dst, w_edges)
    got_nodes = degrees_from_tiled(got, n_nodes)
    np.testing.assert_allclose(np.asarray(got_nodes), deg, rtol=1e-4, atol=1e-4)


def test_peel_degree_weighted_dtypes():
    rng = np.random.default_rng(1)
    n, e, ts = 200, 800, 64
    src = rng.integers(0, n, e).astype(np.int32)
    dst = rng.integers(0, n, e).astype(np.int32)
    tiled = bucket_edges_by_tile(src, dst, n, ts, 128)
    for dt in (np.float32,):
        w = np.where(
            tiled.edge_index >= 0,
            rng.random(tiled.edge_index.shape).astype(dt),
            0,
        ).astype(dt)
        got = tiled_degrees_pallas(
            jnp.asarray(tiled.target_local), jnp.asarray(w),
            tile_size=ts, block_e=128,
        )
        want = tiled_degrees_ref(
            jnp.asarray(tiled.target_local), jnp.asarray(w), tile_size=ts
        )
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


def test_peel_with_pallas_degree_fn_matches_exact():
    """Algorithm 1 driven by the Pallas degree kernel == exact-degree run."""
    from repro.core.peel import densest_subgraph
    from repro.kernels.peel_degree.ops import degree_fn_from_tiling, tiling_for_edges

    edges, _ = planted_dense_subgraph(n=300, avg_deg=4.0, k=25, p_dense=0.8, seed=3)
    tiled = tiling_for_edges(edges, tile_size=64, block=128)
    fn = degree_fn_from_tiling(tiled, use_pallas=True)
    res_pallas = densest_subgraph(edges, eps=0.5, degree_fn=fn, track_history=False)
    res_exact = densest_subgraph(edges, eps=0.5, track_history=False)
    assert float(res_pallas.best_density) == pytest.approx(
        float(res_exact.best_density), rel=1e-5
    )
    np.testing.assert_array_equal(
        np.asarray(res_pallas.best_alive), np.asarray(res_exact.best_alive)
    )


# ------------------------------ count_sketch --------------------------------


@pytest.mark.parametrize(
    "n_endpoints,t,b,block_e",
    [
        (1000, 3, 256, 256),
        (4096, 5, 2048, 512),
        (999, 2, 128, 128),   # padding path
        (512, 1, 4096, 512),  # single table, col chunking
    ],
)
def test_count_sketch_kernel_matches_ref(n_endpoints, t, b, block_e):
    rng = np.random.default_rng(2)
    params = make_sketch_params(t, b, seed=7)
    x = jnp.asarray(rng.integers(0, 10_000, n_endpoints, dtype=np.int32))
    w = jnp.asarray(rng.random(n_endpoints).astype(np.float32))
    got = count_sketch_update(x, w, params, use_pallas=True, block_e=block_e)
    want = count_sketch_update_ref(x, w, params)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_count_sketch_query_quality_from_kernel():
    """Degrees estimated from kernel-built counters track exact degrees for
    heavy nodes (the §5.1 guarantee the peel relies on)."""
    from repro.core.countsketch import query_degrees

    edges, _ = planted_dense_subgraph(n=400, avg_deg=3.0, k=40, p_dense=0.9, seed=5)
    params = make_sketch_params(5, 1 << 11, seed=1)
    src, dst = edges.src, edges.dst
    w = jnp.where(edges.mask, edges.weight, 0.0)
    from repro.kernels.count_sketch.ops import sketch_edges

    counters = sketch_edges(src, dst, w, params, use_pallas=True)
    est = query_degrees(params, counters, jnp.arange(edges.n_nodes, dtype=jnp.int32))
    exact = np.zeros(edges.n_nodes, np.float64)
    np.add.at(exact, np.asarray(src), np.asarray(w))
    np.add.at(exact, np.asarray(dst), np.asarray(w))
    heavy = exact >= 20
    assert heavy.sum() >= 30
    err = np.abs(np.asarray(est)[heavy] - exact[heavy])
    assert np.median(err) <= 3.0


# ----------------------------- flash_attention ------------------------------


@pytest.mark.parametrize(
    "b,s,hq,hkv,d,window,dtype",
    [
        (2, 256, 4, 4, 64, None, jnp.float32),
        (1, 256, 8, 2, 64, None, jnp.float32),     # GQA
        (2, 384, 4, 2, 32, 128, jnp.float32),      # sliding window
        (1, 300, 2, 1, 64, None, jnp.float32),     # padding path
        (1, 256, 4, 4, 64, None, jnp.bfloat16),    # bf16 inputs
    ],
)
def test_flash_kernel_matches_ref(b, s, hq, hkv, d, window, dtype):
    rng = np.random.default_rng(4)
    q = jnp.asarray(rng.standard_normal((b, s, hq, d)), dtype)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, d)), dtype)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, d)), dtype)
    pos = jnp.arange(s, dtype=jnp.int32)
    got = flash_attention(
        q, k, v, q_positions=pos, kv_positions=pos, window=window,
        block_q=128, block_kv=128, interpret=True,
    )
    # Oracle on the flattened layout.
    from repro.kernels.flash_attention.ops import _to_flat_heads

    qf, kf, vf = _to_flat_heads(q, k, v)
    want = flash_attention_ref(
        qf, kf, vf, pos[None], pos[None], window=window
    ).reshape(b, hq, s, d).transpose(0, 2, 1, 3)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol,
    )


def test_flash_kernel_matches_gqa_attention_xla():
    """Kernel output == the model-layer dense path (end-to-end contract)."""
    from repro.models.attention import gqa_attention

    rng = np.random.default_rng(6)
    b, s, hq, hkv, d = 2, 256, 6, 3, 32
    q = jnp.asarray(rng.standard_normal((b, s, hq, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    pos = jnp.arange(s, dtype=jnp.int32)
    got = flash_attention(q, k, v, q_positions=pos, kv_positions=pos)
    want = gqa_attention(q, k, v, q_positions=pos, kv_positions=pos, impl="xla")
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5
    )


def test_flash_trainable_grads_match_dense():
    from repro.kernels.flash_attention.ops import flash_attention_trainable
    from repro.models.attention import gqa_attention

    rng = np.random.default_rng(7)
    b, s, hq, hkv, d = 1, 256, 4, 2, 32
    q = jnp.asarray(rng.standard_normal((b, s, hq, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    pos = jnp.arange(s, dtype=jnp.int32)
    w = jnp.asarray(rng.standard_normal((b, s, hq, d)), jnp.float32)

    def loss_pallas(q, k, v):
        o = flash_attention_trainable(
            q, k, v, q_positions=pos, kv_positions=pos,
            bwd_q_chunk=64, bwd_kv_chunk=64,
        )
        return jnp.mean(o * w)

    def loss_dense(q, k, v):
        o = gqa_attention(q, k, v, q_positions=pos, kv_positions=pos, impl="xla")
        return jnp.mean(o * w)

    gp = jax.grad(loss_pallas, argnums=(0, 1, 2))(q, k, v)
    gd = jax.grad(loss_dense, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(gp, gd):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b_), rtol=2e-4, atol=1e-5
        )
