"""Hypothesis property-based tests for the core invariants."""

import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed; property tests skipped"
)
from hypothesis import given, settings, strategies as st

from repro.core import (
    densest_subgraph,
    densest_subgraph_at_least_k,
    densest_subgraph_brute,
    density_of,
    max_passes_bound,
)
from repro.graph import from_numpy


@st.composite
def small_graphs(draw):
    n = draw(st.integers(4, 12))
    m = draw(st.integers(3, 30))
    src = draw(
        st.lists(st.integers(0, n - 1), min_size=m, max_size=m).map(np.asarray)
    )
    dst = draw(
        st.lists(st.integers(0, n - 1), min_size=m, max_size=m).map(np.asarray)
    )
    keep = src != dst
    if keep.sum() == 0:
        src = np.asarray([0])
        dst = np.asarray([1])
        keep = np.asarray([True])
    return from_numpy(src[keep], dst[keep], n)


@given(small_graphs(), st.sampled_from([0.1, 0.5, 1.0]))
@settings(max_examples=25, deadline=None)
def test_property_approximation_and_passes(edges, eps):
    _, rho_star = densest_subgraph_brute(edges)
    res = densest_subgraph(edges, eps=eps)
    # (2+2eps) guarantee and validity.
    assert float(res.best_density) >= rho_star / (2 * (1 + eps)) - 1e-5
    assert float(res.best_density) <= rho_star + 1e-5
    # Pass bound.
    assert int(res.passes) <= max_passes_bound(edges.n_nodes, eps)
    # Reported density is the density of the reported set.
    assert float(density_of(edges, res.best_alive)) == pytest.approx(
        float(res.best_density), rel=1e-5, abs=1e-6
    )


@given(small_graphs(), st.integers(2, 6))
@settings(max_examples=15, deadline=None)
def test_property_topk_size(edges, k):
    res = densest_subgraph_at_least_k(edges, k=min(k, edges.n_nodes), eps=0.5)
    assert int(res.best_size) >= min(k, edges.n_nodes)


@given(
    st.integers(8, 40),
    st.floats(0.05, 1.5),
    st.integers(0, 10_000),
)
@settings(max_examples=15, deadline=None)
def test_property_monotone_under_weight_scaling(n, scale, seed):
    """rho scales linearly with uniform edge-weight scaling; the best set is
    unchanged."""
    rng = np.random.default_rng(seed)
    m = 3 * n
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    keep = src != dst
    if keep.sum() < 2:
        return
    w = rng.uniform(0.5, 2.0, keep.sum()).astype(np.float32)
    e1 = from_numpy(src[keep], dst[keep], n, weight=w)
    e2 = from_numpy(src[keep], dst[keep], n, weight=w * scale)
    r1 = densest_subgraph(e1, eps=0.5)
    r2 = densest_subgraph(e2, eps=0.5)
    assert float(r2.best_density) == pytest.approx(
        scale * float(r1.best_density), rel=1e-4
    )
    assert (np.asarray(r1.best_alive) == np.asarray(r2.best_alive)).all()
