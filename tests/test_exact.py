"""Exact solver validation: Goldberg max-flow vs brute force enumeration."""

import numpy as np
import pytest

from repro.core import densest_subgraph_brute, densest_subgraph_exact
from repro.graph import from_numpy
from repro.graph.generators import erdos_renyi


@pytest.mark.parametrize("seed", range(6))
def test_flow_matches_brute_force(seed):
    rng = np.random.default_rng(seed)
    n = 11
    m = rng.integers(8, 26)
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    keep = src != dst
    edges = from_numpy(src[keep], dst[keep], n)
    _, rho_brute = densest_subgraph_brute(edges)
    nodes, rho_flow = densest_subgraph_exact(edges)
    assert rho_flow == pytest.approx(rho_brute, abs=1e-9)
    # Returned set actually achieves the optimum.
    mask = np.asarray(edges.mask)
    s = np.asarray(edges.src)[mask]
    d = np.asarray(edges.dst)[mask]
    inset = np.zeros(n, bool)
    inset[nodes] = True
    assert np.sum(inset[s] & inset[d]) / len(nodes) == pytest.approx(rho_brute)


def test_exact_on_clique_with_tail():
    # K5 (density 2.0) + a path of 10 nodes.
    src = [0, 0, 0, 0, 1, 1, 1, 2, 2, 3] + list(range(4, 14))
    dst = [1, 2, 3, 4, 2, 3, 4, 3, 4, 4] + list(range(5, 15))
    edges = from_numpy(src, dst, 15)
    nodes, rho = densest_subgraph_exact(edges)
    assert rho == pytest.approx(2.0)
    assert set(nodes.tolist()) == {0, 1, 2, 3, 4}


def test_exact_scales_to_moderate_graphs():
    edges = erdos_renyi(300, avg_deg=10, seed=0)
    nodes, rho = densest_subgraph_exact(edges)
    assert rho >= 5.0  # ER(300, deg 10): rho(V) = 5, optimum >= that
    assert 0 < len(nodes) <= 300
