"""Chaos suite: deterministic fault injection + the deadline/retry/degrade
layer (repro/faults.py, serve/resilience.py and the instrumented sites).

Contracts under test:

  * **FaultPlan determinism** — fail-nth schedules, seeded fail-prob
    storms (bit-reproducible across plans with the same seed), latency
    injection through an injectable sleep, hit/failure accounting;
  * **no-plan bit-identity** — with no plan installed (or an EMPTY plan),
    every instrumented path produces outputs identical to the
    uninstrumented code: serving answers, streaming runs, progcache
    round-trips (the PR's zero-cost acceptance criterion);
  * **streaming retry budget** — a deterministic chunk failure surfaces
    its REAL error after exactly one retry; a transient failure recovers
    bit-identically; a speculative duplicate does NOT consume the retry
    budget — all driven through FaultPlan, no monkeypatching;
  * **checkpoint fail-open** — a corrupt/truncated checkpoint warns,
    quarantines with one atomic rename, and resumes fresh;
  * **spill publish** — a failed publish aborts the rung (no manifest,
    nothing for resume to adopt);
  * **progcache** — store/load faults stay fail-open; first store failure
    logs once, later ones only count;
  * **turnstile** — an injected decode failure escalates a level and the
    recovered sample still contains only true edges (never fabricated);
    the density service serves the last-good answer on recompute failure;
  * **serving resilience** — group-failure isolation (with or without a
    ResilienceConfig), bounded retry with deterministic backoff, the
    degradation ladder (radius -> turnstile -> last-good -> failed),
    bounded-queue load shedding, per-bucket circuit breaker, deadline
    budgets.
"""

import logging
import os

import numpy as np
import pytest

from repro import faults
from repro.core import Problem, Solver
from repro.core.streaming import StreamingDensest, chunked_from_arrays
from repro.core.turnstile import TurnstileSketch
from repro.faults import FaultPlan, FaultRule, InjectedFault
from repro.graph.edgelist import EdgeSpillWriter, open_edge_spill
from repro.graph.generators import (
    chung_lu_power_law,
    erdos_renyi,
    planted_dense_subgraph,
)
from repro.serve.densest import DensestQueryEngine
from repro.serve.resilience import CircuitBreaker, ResilienceConfig
from repro.serve.turnstile import TurnstileDensityService

EPS = 0.5
PROB = Problem.undirected(eps=EPS, compaction="off")
SITE_CHUNK = "streaming.chunk"


@pytest.fixture(autouse=True)
def _no_plan_leaks():
    """A leaked process-global plan would poison every later test."""
    assert faults.installed() is None
    yield
    faults.uninstall()


class _Clock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def _edges_np(edges):
    mask = np.asarray(edges.mask)
    return (
        np.asarray(edges.src)[mask],
        np.asarray(edges.dst)[mask],
        np.asarray(edges.weight)[mask],
    )


@pytest.fixture(scope="module")
def graph():
    edges = erdos_renyi(300, avg_deg=8, seed=3)
    return edges, _edges_np(edges)


# ---------------------------------------------------------------------------
# FaultPlan mechanics
# ---------------------------------------------------------------------------


def test_fail_nth_is_deterministic():
    plan = FaultPlan().fail_nth("s", 1, 3, key="k")
    outcomes = []
    for _ in range(4):
        try:
            plan.fire("s", "k")
            outcomes.append("ok")
        except InjectedFault as e:
            outcomes.append(f"fail@{e.hit}")
    assert outcomes == ["fail@1", "ok", "fail@3", "ok"]
    assert plan.hits_at("s", "k") == 4
    assert plan.failures_at("s", "k") == 2
    # Other keys are independent streams (their own 1-based hit counts).
    plan.fire("s", "other")
    assert plan.hits_at("s", "other") == 1
    assert plan.hits_at("s") == 5  # aggregate over keys


def test_fail_prob_storm_is_seed_reproducible():
    def storm(seed):
        plan = FaultPlan(seed=seed).fail_prob("s", 0.3)
        pat = []
        for i in range(300):
            try:
                plan.fire("s", i % 7)
                pat.append(0)
            except InjectedFault:
                pat.append(1)
        return pat, plan.failures_at("s")

    a, na = storm(11)
    b, nb = storm(11)
    c, nc = storm(12)
    assert a == b and na == nb  # same seed: bit-identical storm
    assert a != c  # different seed: different storm
    assert 0.15 < na / 300 < 0.45  # roughly the requested rate


def test_fail_prob_max_fails_budget():
    plan = FaultPlan().fail_prob("s", 1.0, max_fails=2)
    fails = 0
    for _ in range(5):
        try:
            plan.fire("s")
        except InjectedFault:
            fails += 1
    assert fails == 2 and plan.failures_at("s") == 2


def test_latency_injection_uses_sleep_fn():
    slept = []
    plan = FaultPlan(sleep_fn=slept.append).latency(
        "s", 0.25, key="k", nth=(2,)
    )
    plan.fire("s", "k")
    assert slept == []  # nth=(2,): hit 1 does not sleep
    plan.fire("s", "k")
    assert slept == [0.25]
    plan.fire("s", "other")  # keyed rule: other keys unaffected
    assert slept == [0.25]


def test_no_plan_fire_is_noop_and_context_restores():
    faults.fire("anything", key=123)  # no plan installed: pure no-op
    plan = FaultPlan().fail_nth("s", 1)
    with faults.active(plan):
        assert faults.installed() is plan
        with pytest.raises(InjectedFault):
            faults.fire("s")
    assert faults.installed() is None
    faults.install(plan)
    assert faults.installed() is plan
    faults.uninstall()
    assert faults.installed() is None
    with pytest.raises(TypeError):
        faults.install("not a plan")


def test_rule_validation():
    with pytest.raises(ValueError, match="fail_prob"):
        FaultRule(site="s", fail_prob=1.5)
    with pytest.raises(ValueError, match="latency_s"):
        FaultRule(site="s", latency_s=-1.0)
    with pytest.raises(ValueError, match="max_fails"):
        FaultRule(site="s", max_fails=-1)


# ---------------------------------------------------------------------------
# No-plan / empty-plan bit-identity (the zero-cost acceptance criterion)
# ---------------------------------------------------------------------------


def test_serving_bit_identical_without_plan_and_with_empty_plan():
    g = chung_lu_power_law(400, exponent=2.0, avg_deg=6.0, seed=0)
    seeds = [1, 7, 19, 42, 97]

    def answers(resilience, plan):
        eng = DensestQueryEngine(
            g, PROB, radius=2, max_wait_ms=0.0, resilience=resilience
        )
        if plan is None:
            return eng.query_many(seeds)
        with faults.active(plan):
            return eng.query_many(seeds)

    ref = answers(None, None)
    with_cfg = answers(ResilienceConfig(max_retries=2, deadline_ms=50.0), None)
    with_empty = answers(None, FaultPlan())
    for res in (with_cfg, with_empty):
        for a, b in zip(ref, res):
            assert b.status == "ok" and b.fallback is None
            assert b.error is None and b.attempts == 1
            assert a.density == b.density  # float-equal, not approx
            np.testing.assert_array_equal(a.nodes, b.nodes)
            assert a.bucket == b.bucket


def test_streaming_bit_identical_with_empty_plan(graph):
    edges, (src, dst, w) = graph
    stream = chunked_from_arrays(src, dst, w, chunk=97)
    ref = StreamingDensest(stream, n_nodes=edges.n_nodes, n_workers=3).run(
        max_passes=6, resume=False
    )
    with faults.active(FaultPlan()):
        st = StreamingDensest(stream, n_nodes=edges.n_nodes, n_workers=3).run(
            max_passes=6, resume=False
        )
    assert st.best_rho == ref.best_rho
    np.testing.assert_array_equal(st.best_alive, ref.best_alive)
    assert st.history == ref.history


# ---------------------------------------------------------------------------
# Streaming retry budget (driven through FaultPlan, no monkeypatching)
# ---------------------------------------------------------------------------


def test_deterministic_chunk_failure_surfaces_after_exactly_one_retry(graph):
    edges, (src, dst, w) = graph
    stream = chunked_from_arrays(src, dst, w, chunk=97)
    plan = FaultPlan().fail_nth(SITE_CHUNK, 1, 2, key=2)  # attempt AND retry
    drv = StreamingDensest(stream, n_nodes=edges.n_nodes, n_workers=3)
    with faults.active(plan):
        with pytest.raises(InjectedFault) as exc:
            drv.run(max_passes=2, resume=False)
    assert exc.value.key == 2  # the REAL error of the failing chunk
    # Exactly one failure-triggered re-issue: attempt (hit 1) + retry
    # (hit 2), then the error surfaces — no retry loop.
    assert plan.hits_at(SITE_CHUNK, 2) == 2
    assert drv.speculative_reissues == 1


def test_transient_chunk_failure_recovers_bit_identically(graph):
    edges, (src, dst, w) = graph
    stream = chunked_from_arrays(src, dst, w, chunk=97)
    ref = StreamingDensest(stream, n_nodes=edges.n_nodes, n_workers=3).run(
        max_passes=4, resume=False
    )
    plan = FaultPlan().fail_nth(SITE_CHUNK, 1, key=2)  # first attempt only
    drv = StreamingDensest(stream, n_nodes=edges.n_nodes, n_workers=3)
    with faults.active(plan):
        st = drv.run(max_passes=4, resume=False)
    assert plan.hits_at(SITE_CHUNK, 2) >= 2  # attempt + its retry
    assert st.best_rho == ref.best_rho
    np.testing.assert_array_equal(st.best_alive, ref.best_alive)
    assert st.history == ref.history


def test_speculative_duplicate_does_not_consume_retry_budget(graph):
    """A chunk whose first attempt straggles (injected latency) gets a
    speculative duplicate.  The duplicate FAILS while the original is
    still in flight — first-success-wins must IGNORE that failure (no
    retry budget spent), so when the original then also fails, the one
    real retry still remains and the pass completes."""
    edges, (src, dst, w) = graph
    stream = chunked_from_arrays(src, dst, w, chunk=97)
    # Warm the jitted chunk kernel so real work is fast vs the 1s sleep.
    # Single pass: each extra pass re-streams the chunks and fires its own
    # attempt (plus tail-duplicate) hits, which would blur the count below.
    ref = StreamingDensest(stream, n_nodes=edges.n_nodes, n_workers=3).run(
        max_passes=1, resume=False
    )
    k = 2
    plan = (
        FaultPlan()
        .latency(SITE_CHUNK, 1.0, key=k, nth=(1,))  # attempt 1 straggles
        .fail_nth(SITE_CHUNK, 1, 2, key=k)  # attempt 1 AND duplicate fail
    )
    drv = StreamingDensest(
        stream,
        n_nodes=edges.n_nodes,
        n_workers=3,
        speculative=True,
        speculate_tail_frac=1.0,  # duplicate the whole straggler tail
        prefetch=64,  # the whole stream fits one window
    )
    with faults.active(plan):
        st = drv.run(max_passes=1, resume=False)
    # hit 1: straggling first attempt (fails at ~1s); hit 2: speculative
    # duplicate (fails fast, original still live -> ignored, budget
    # intact); hit 3: the one real retry (succeeds).
    assert plan.hits_at(SITE_CHUNK, k) == 3
    assert plan.failures_at(SITE_CHUNK, k) == 2
    assert st.best_rho == ref.best_rho
    np.testing.assert_array_equal(st.best_alive, ref.best_alive)


# ---------------------------------------------------------------------------
# Checkpoint fail-open (quarantine + fresh start)
# ---------------------------------------------------------------------------


def _ckpt_run(graph, tmp_path, **kw):
    edges, (src, dst, w) = graph
    return StreamingDensest(
        chunked_from_arrays(src, dst, w, chunk=128),
        n_nodes=edges.n_nodes,
        checkpoint_dir=str(tmp_path),
        **kw,
    )


def test_truncated_checkpoint_quarantined_and_run_starts_fresh(
    graph, tmp_path
):
    ref = _ckpt_run(graph, tmp_path).run(max_passes=3, resume=False)
    path = os.path.join(str(tmp_path), "stream_state.npz")
    blob = open(path, "rb").read()
    with open(path, "wb") as f:
        f.write(blob[: len(blob) // 2])  # torn copy / bad disk
    drv = _ckpt_run(graph, tmp_path)
    with pytest.warns(RuntimeWarning, match="quarantined"):
        st = drv.run(max_passes=3, resume=True)
    assert os.path.exists(path + ".corrupt")  # one atomic rename
    # The fresh run reproduces the from-scratch result exactly.
    assert st.best_rho == ref.best_rho
    np.testing.assert_array_equal(st.best_alive, ref.best_alive)
    assert st.history == ref.history


def test_injected_checkpoint_load_fault_fails_open(graph, tmp_path):
    _ckpt_run(graph, tmp_path).run(max_passes=2, resume=False)
    path = os.path.join(str(tmp_path), "stream_state.npz")
    plan = FaultPlan().fail_nth("streaming.checkpoint_load", 1)
    with faults.active(plan):
        with pytest.warns(RuntimeWarning, match="quarantined"):
            st = _ckpt_run(graph, tmp_path).run(max_passes=2, resume=True)
    assert plan.hits_at("streaming.checkpoint_load") == 1
    assert os.path.exists(path + ".corrupt")  # the (healthy) file, shelved
    assert st.pass_idx <= 2  # ran fresh to completion


def test_injected_checkpoint_save_fault_surfaces(graph, tmp_path):
    plan = FaultPlan().fail_nth("streaming.checkpoint_save", 1)
    drv = _ckpt_run(graph, tmp_path)
    with faults.active(plan):
        with pytest.raises(InjectedFault):
            drv.run(max_passes=2, resume=False)


# ---------------------------------------------------------------------------
# Spill publish failure -> aborted rung
# ---------------------------------------------------------------------------


def test_spill_publish_fault_aborts_and_leaves_no_manifest(tmp_path):
    spill_dir = str(tmp_path / "rung_0000")
    w = EdgeSpillWriter(spill_dir, np.float32)
    w.append(
        np.asarray([0, 1], np.int32),
        np.asarray([1, 2], np.int32),
        np.asarray([1.0, 1.0], np.float32),
    )
    plan = FaultPlan().fail_nth("edgelist.spill_publish", 1)
    with faults.active(plan):
        with pytest.raises(InjectedFault):
            w.finalize(caps=[2], rung=0)
    w.abort()  # the streaming caller's failure path
    assert not os.path.exists(spill_dir)  # nothing for resume to adopt
    assert open_edge_spill(spill_dir) is None


def test_streaming_ladder_aborts_partial_rung_on_publish_fault(tmp_path):
    edges, _ = planted_dense_subgraph(
        800, avg_deg=6, k=40, p_dense=0.8, seed=0
    )
    src, dst, w = _edges_np(edges)
    spill = tmp_path / "spill"
    drv = StreamingDensest(
        chunked_from_arrays(src, dst, w, chunk=512),
        n_nodes=edges.n_nodes,
        eps=0.2,
        compaction="geometric",
        spill_dir=str(spill),
    )
    plan = FaultPlan().fail_nth("edgelist.spill_publish", 1)
    with faults.active(plan):
        with pytest.raises(InjectedFault):
            drv.run(resume=False)
    # The partial rung directory was dropped; no manifest anywhere.
    if spill.is_dir():
        for name in os.listdir(spill):
            assert not os.path.exists(spill / name / "manifest.json")


# ---------------------------------------------------------------------------
# progcache faults: fail-open + log-once
# ---------------------------------------------------------------------------


def test_progcache_store_fault_counts_and_logs_once(tmp_path, caplog):
    g1 = erdos_renyi(64, avg_deg=6, seed=0)
    g2 = erdos_renyi(128, avg_deg=6, seed=1)
    ref = Solver().solve(g1, PROB)
    solver = Solver(cache_dir=str(tmp_path))
    plan = FaultPlan().fail_prob("progcache.store", 1.0)
    with caplog.at_level(logging.WARNING, logger="repro.progcache"):
        with faults.active(plan):
            res = solver.solve(g1, PROB)
            solver.solve(g2, PROB)  # second failed store: counted, silent
    assert float(res.best_density) == float(ref.best_density)  # fail-open
    assert solver.disk_store_errors == 2
    assert solver.stats()["disk_store_errors"] == 2
    warned = [r for r in caplog.records if r.name == "repro.progcache"]
    assert len(warned) == 1  # rate-limited: log once, count the rest
    assert os.listdir(str(tmp_path)) == []  # nothing was published


def test_progcache_load_fault_fails_open_to_recompile(tmp_path):
    g = erdos_renyi(64, avg_deg=6, seed=0)
    warm = Solver(cache_dir=str(tmp_path))
    ref = warm.solve(g, PROB)
    assert warm.disk_misses == 1  # published an entry
    cold = Solver(cache_dir=str(tmp_path))
    plan = FaultPlan().fail_prob("progcache.load", 1.0)
    with faults.active(plan):
        res = cold.solve(g, PROB)
    assert cold.disk_hits == 0 and cold.disk_misses == 1  # load failed open
    assert float(res.best_density) == float(ref.best_density)
    # Without the plan the same entry loads fine (the entry is intact).
    fresh = Solver(cache_dir=str(tmp_path))
    fresh.solve(g, PROB)
    assert fresh.disk_hits == 1


# ---------------------------------------------------------------------------
# Turnstile: decode faults escalate, never fabricate; service serves stale
# ---------------------------------------------------------------------------


def _edge_keys(u, v, n):
    lo = np.minimum(u, v).astype(np.int64)
    hi = np.maximum(u, v).astype(np.int64)
    return lo * n + hi


def test_turnstile_decode_fault_escalates_and_never_fabricates():
    g = chung_lu_power_law(400, seed=8)
    m = int(np.asarray(g.mask).sum())
    src = np.asarray(g.src)[:m].copy()
    dst = np.asarray(g.dst)[:m].copy()
    sk = TurnstileSketch(400, 1 << 11, seed=1)
    sk.apply((src, dst))
    ref_edges, ref_level, _ = sk.recover()
    assert ref_level == 0  # sanity: normally exact at level 0
    # key=0 pins the fault to level 0's decode; an unkeyed rule would be a
    # wildcard and kill the FIRST attempt at EVERY level (hits count
    # per-key), failing the whole escalation ladder.
    plan = FaultPlan().fail_nth("turnstile.decode", 1, key=0)
    with faults.active(plan):
        edges, level, info = sk.recover()
    assert level > 0 and info["first_level_tried"] == 0
    assert sk.recovery_failures == 1
    assert sk.recovery_escalations == 1
    # The escalated sample holds ONLY true edges — never fabricated.
    want = set(_edge_keys(src, dst, 400).tolist())
    got = set(_edge_keys(edges[:, 0], edges[:, 1], 400).tolist())
    assert got <= want and len(got) > 0


def test_turnstile_service_serves_stale_on_recovery_failure():
    rng = np.random.default_rng(0)
    e1 = rng.integers(0, 300, size=(200, 2)).astype(np.int32)
    e1 = e1[e1[:, 0] != e1[:, 1]]
    svc = TurnstileDensityService(
        300, Problem.undirected(stream_mode="turnstile", sample_edges=1 << 10)
    )
    svc.apply(insert_edges=e1)
    d0 = svc.density()
    e2 = np.asarray([[1, 2], [2, 3], [1, 3]], np.int32)
    svc.apply(insert_edges=e2)  # marks the cached answer stale
    plan = FaultPlan().fail_prob("turnstile.decode", 1.0)  # kill ALL levels
    with faults.active(plan):
        d1 = svc.density()  # recompute fails -> stale last-good served
    assert d1 == d0
    st = svc.stats()
    assert st["stale_results_served"] == 1 and st["queries_failed"] == 1
    assert "recovery failed" in st["last_error"]
    assert st["recovery_escalations"] == svc.driver.sketch.recovery_escalations
    assert "disk_store_errors" in st
    # The dirty flag survived the failure: the next healthy read recomputes.
    before = svc.queries_computed
    d2 = svc.density()
    assert svc.queries_computed == before + 1
    assert np.isfinite(d2)


def test_turnstile_service_serve_stale_off_raises():
    svc = TurnstileDensityService(
        100,
        Problem.undirected(stream_mode="turnstile", sample_edges=1 << 8),
        serve_stale=False,
    )
    svc.apply(insert_edges=np.asarray([[0, 1], [1, 2]], np.int32))
    svc.density()
    svc.apply(insert_edges=np.asarray([[2, 3]], np.int32))
    with faults.active(FaultPlan().fail_prob("turnstile.decode", 1.0)):
        with pytest.raises(RuntimeError):
            svc.density()


# ---------------------------------------------------------------------------
# Serving: group isolation, retry, degradation ladder, shedding, breaker
# ---------------------------------------------------------------------------


def _serve_graph():
    return chung_lu_power_law(500, exponent=2.0, avg_deg=6.0, seed=2)


def _two_bucket_seeds(eng, want=3):
    """Seeds split across two distinct bucket groups of ``eng``."""
    by_key = {}
    for s in range(eng.n_nodes):
        padded, _ = eng.extract(s)
        by_key.setdefault(
            (padded.n_nodes, padded.n_edges_padded), []
        ).append(s)
        if (
            len(by_key) >= 2
            and sorted(len(v) for v in by_key.values())[-2] >= want
        ):
            big = sorted(by_key, key=lambda k: -len(by_key[k]))[:2]
            if all(len(by_key[k]) >= want for k in big):
                return {k: by_key[k][:want] for k in big}
    raise AssertionError("graph has only one bucket shape")


def test_group_failure_poisons_only_its_own_lanes_without_config():
    g = _serve_graph()
    # Small bucket floors: at the default 64/256 floors every radius-1
    # ego-net of this graph pads into ONE bucket shape, and the test needs
    # two distinct bucket groups in one flush.
    eng = DensestQueryEngine(
        g, PROB, radius=1, max_wait_ms=0.0, node_floor=8, edge_floor=32
    )
    groups = _two_bucket_seeds(eng)
    (bad_key, bad_seeds), (ok_key, ok_seeds) = groups.items()
    ref = DensestQueryEngine(
        g, PROB, radius=1, max_wait_ms=0.0, node_floor=8, edge_floor=32
    )
    ref_by_seed = {r.seed: r for r in ref.query_many(ok_seeds)}
    plan = FaultPlan().fail_nth("serve.solve", 1, key=bad_key)
    with faults.active(plan):
        out = eng.query_many(bad_seeds + ok_seeds)
    by_seed = {r.seed: r for r in out}
    assert len(out) == len(bad_seeds) + len(ok_seeds)  # nothing lost
    for s in bad_seeds:  # the failed group: explicit per-lane errors
        r = by_seed[s]
        assert r.status == "failed" and not r.answered
        assert "InjectedFault" in r.error
        assert np.isnan(r.density) and r.size == 0 and r.attempts == 1
    for s in ok_seeds:  # the sibling group: bit-identical answers
        r = by_seed[s]
        assert r.status == "ok"
        assert r.density == ref_by_seed[s].density
        np.testing.assert_array_equal(r.nodes, ref_by_seed[s].nodes)
    assert eng.queries_failed == len(bad_seeds)


def test_retry_recovers_with_deterministic_backoff():
    g = _serve_graph()
    slept = []
    cfg = ResilienceConfig(max_retries=2, backoff_base_ms=4.0, jitter_seed=9)
    eng = DensestQueryEngine(
        g, PROB, radius=1, max_wait_ms=0.0,
        resilience=cfg, sleep_fn=slept.append,
    )
    ref = DensestQueryEngine(g, PROB, radius=1, max_wait_ms=0.0)
    seed = 5
    padded, _ = eng.extract(seed)
    gkey = (padded.n_nodes, padded.n_edges_padded)
    plan = FaultPlan().fail_nth("serve.solve", 1, key=gkey)
    with faults.active(plan):
        res = eng.query(seed)
    want = ref.query(seed)
    assert res.status == "ok" and res.attempts == 2
    assert res.density == want.density
    np.testing.assert_array_equal(res.nodes, want.nodes)
    assert eng.solve_retries == 1
    # The backoff slept exactly the config's deterministic schedule.
    assert slept == [cfg.backoff_s(1, key=gkey)]
    step = cfg.backoff_base_ms / 1000.0
    assert step * (1 - cfg.backoff_jitter) <= slept[0] <= step


def test_degrade_to_smaller_radius():
    g = _serve_graph()
    cfg = ResilienceConfig(
        max_retries=0, degrade_turnstile=False, degrade_last_good=False
    )
    eng = DensestQueryEngine(
        g, PROB, radius=2, max_wait_ms=0.0, resilience=cfg
    )
    seed = 5
    padded, _ = eng.extract(seed, 2)
    gkey = (padded.n_nodes, padded.n_edges_padded)
    plan = FaultPlan().fail_prob("serve.solve", 1.0, key=gkey)
    with faults.active(plan):
        res = eng.query(seed)
    assert res.status == "degraded" and res.degraded and res.answered
    assert res.fallback == "radius:1" and "InjectedFault" in res.error
    # The degraded answer is REAL: identical to solving the radius-1
    # ego-net directly.
    small, nodes = eng.extract(seed, 1)
    want = Solver().solve(small, PROB)
    assert res.density == float(want.best_density)
    alive = np.asarray(want.best_alive)
    want_nodes = nodes[np.nonzero(alive)[0][np.nonzero(alive)[0] < len(nodes)]]
    np.testing.assert_array_equal(res.nodes, want_nodes)
    assert eng.queries_degraded == 1


class _StubTurnstile:
    """Duck-typed TurnstileDensityService: a pinned density reading."""

    def __init__(self, n_nodes, rho):
        self.n_nodes = n_nodes
        self.rho = rho

    def density(self):
        return self.rho

    def apply(self, *a, **kw):
        return self


def test_degrade_to_turnstile_density_then_last_good():
    g = _serve_graph()
    cfg = ResilienceConfig(max_retries=0, degrade_radius=False)
    eng = DensestQueryEngine(
        g, PROB, radius=1, max_wait_ms=0.0, resilience=cfg
    )
    eng.attach_turnstile(_StubTurnstile(g.n_nodes, rho=3.25))
    seed = 5
    good = eng.query(seed)  # healthy: also primes the last-good cache
    assert good.status == "ok"
    plan = FaultPlan().fail_prob("serve.solve", 1.0)  # every solve fails
    with faults.active(plan):
        res = eng.query(seed)
    # last_good outranks nothing here: the ladder tries turnstile FIRST
    # only when radius is disabled and turnstile is attached.
    assert res.status == "degraded"
    assert res.fallback == "turnstile_density"
    assert res.density == 3.25 and res.size == 0
    # Detach the sidecar: the same storm now lands on last_good.
    eng._turnstile = None
    with faults.active(plan):
        res2 = eng.query(seed)
    assert res2.status == "degraded" and res2.fallback == "last_good"
    assert res2.density == good.density
    np.testing.assert_array_equal(res2.nodes, good.nodes)
    assert res2.qid != good.qid and "InjectedFault" in res2.error


def test_failed_when_ladder_exhausted_but_flush_survives():
    g = _serve_graph()
    cfg = ResilienceConfig(max_retries=0)  # radius=1: no smaller radius
    eng = DensestQueryEngine(
        g, PROB, radius=1, max_wait_ms=0.0, resilience=cfg
    )
    plan = FaultPlan().fail_prob("serve.solve", 1.0)
    with faults.active(plan):
        res = eng.query(5)  # flush() returns; nothing raises
    assert res.status == "failed" and not res.answered
    assert np.isnan(res.density) and "InjectedFault" in res.error
    # A later healthy query on the same engine works (queue not poisoned).
    ok = eng.query(5)
    assert ok.status == "ok"


def test_bounded_queue_sheds_with_explicit_rejected_outcome():
    g = _serve_graph()
    cfg = ResilienceConfig(max_queue=2)
    eng = DensestQueryEngine(
        g, PROB, radius=1, max_wait_ms=0.0, resilience=cfg
    )
    qids = [eng.submit(s) for s in (1, 2, 3, 4)]
    assert eng.pending() == 2  # two admitted, two shed
    out = eng.flush()
    assert sorted(r.qid for r in out) == sorted(qids)  # nobody vanishes
    by_qid = {r.qid: r for r in out}
    statuses = [by_qid[q].status for q in qids]
    assert statuses == ["ok", "ok", "rejected", "rejected"]
    for q in qids[2:]:
        r = by_qid[q]
        assert r.attempts == 0 and "queue full" in r.error
        assert not r.answered
    assert eng.queries_rejected == 2


def test_circuit_breaker_opens_cools_down_and_probes():
    clk = _Clock()
    g = _serve_graph()
    cfg = ResilienceConfig(
        max_retries=0, breaker_threshold=2, breaker_cooldown_s=30.0
    )
    eng = DensestQueryEngine(
        g, PROB, radius=1, max_wait_ms=0.0, resilience=cfg, time_fn=clk
    )
    seed = 5
    padded, _ = eng.extract(seed)
    gkey = (padded.n_nodes, padded.n_edges_padded)
    plan = FaultPlan().fail_prob("serve.solve", 1.0, key=gkey)
    with faults.active(plan):
        eng.query(seed)
        eng.query(seed)  # 2 consecutive failures: circuit opens
        assert eng._breaker.state(gkey) == "open"
        hits = plan.hits_at("serve.solve", gkey)
        r = eng.query(seed)  # open: no real attempt reaches the solver
        assert plan.hits_at("serve.solve", gkey) == hits
        assert r.status == "failed" and "CircuitOpen" in r.error
        assert r.attempts == 0
        assert eng.breaker_open_skips == 1
        clk.t += 31.0  # cooldown elapses: one half-open probe goes through
        eng.query(seed)
        assert plan.hits_at("serve.solve", gkey) == hits + 1
        assert eng._breaker.state(gkey) == "open"  # probe failed: re-open
    clk.t += 31.0
    ok = eng.query(seed)  # healthy probe closes the circuit
    assert ok.status == "ok"
    assert eng._breaker.state(gkey) == "closed"
    assert eng._breaker.opened >= 2


def test_deadline_budget_stops_retries():
    clk = _Clock()
    g = _serve_graph()
    cfg = ResilienceConfig(
        max_retries=5, deadline_ms=5.0, backoff_base_ms=10.0
    )

    def sleeping_clock(s):
        clk.t += s  # backoff sleeps advance the injected clock

    eng = DensestQueryEngine(
        g, PROB, radius=1, max_wait_ms=0.0,
        resilience=cfg, time_fn=clk, sleep_fn=sleeping_clock,
    )
    plan = FaultPlan().fail_prob("serve.solve", 1.0)
    with faults.active(plan):
        res = eng.query(5)
    # Attempt 1 fails inside budget -> one backoff (>= 5ms) -> attempt 2
    # fails past the deadline -> no further retries, straight to the
    # ladder (exhausted here) — NOT 5 retries.
    assert res.attempts == 2
    assert eng.deadline_stops == 1 and eng.solve_retries == 1
    assert res.status == "failed"


def test_circuit_breaker_unit_semantics():
    clk = _Clock()
    br = CircuitBreaker(threshold=2, cooldown_s=10.0, time_fn=clk)
    assert br.state("k") == "closed" and br.allow("k")
    br.record_failure("k")
    assert br.state("k") == "closed"  # below threshold
    br.record_failure("k")
    assert br.state("k") == "open" and not br.allow("k")
    clk.t += 10.0
    assert br.state("k") == "half_open" and br.allow("k")
    br.record_failure("k")  # failed probe: re-opens with fresh cooldown
    assert br.state("k") == "open" and br.opened == 2
    clk.t += 10.0
    br.record_success("k")
    assert br.state("k") == "closed" and br.opened == 2
    assert br.state("other") == "closed"  # keys are independent
    with pytest.raises(ValueError):
        CircuitBreaker(threshold=0, cooldown_s=1.0)


def test_resilience_config_validation_and_backoff():
    with pytest.raises(ValueError, match="deadline_ms"):
        ResilienceConfig(deadline_ms=0.0)
    with pytest.raises(ValueError, match="max_retries"):
        ResilienceConfig(max_retries=-1)
    with pytest.raises(ValueError, match="backoff_mult"):
        ResilienceConfig(backoff_mult=0.5)
    with pytest.raises(ValueError, match="max_queue"):
        ResilienceConfig(max_queue=0)
    cfg = ResilienceConfig(backoff_base_ms=2.0, backoff_mult=3.0)
    with pytest.raises(ValueError):
        cfg.backoff_s(0)
    # Deterministic: same (retry, key) -> same wait; exponential envelope.
    assert cfg.backoff_s(1, "k") == cfg.backoff_s(1, "k")
    for retry in (1, 2, 3):
        step = 2.0 * 3.0 ** (retry - 1) / 1000.0
        assert step * 0.5 <= cfg.backoff_s(retry, "k") <= step


def test_serve_engine_bounded_queue_sheds():
    """ServeEngine shares the explicit-shed admission contract (unit-level:
    the queue logic needs no model weights)."""
    from repro.serve.engine import Request, ServeEngine

    eng = ServeEngine.__new__(ServeEngine)
    eng.queue = __import__("collections").deque()
    eng.max_queue = 2
    eng.rejected = 0
    reqs = [Request(rid=i, prompt=np.zeros(2, np.int32)) for i in range(4)]
    outcomes = [eng.submit(r) for r in reqs]
    assert outcomes == [True, True, False, False]
    assert eng.rejected == 2 and len(eng.queue) == 2
    assert [r.rejected for r in reqs] == [False, False, True, True]
