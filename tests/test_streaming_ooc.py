"""Out-of-core streaming runtime: async pipeline, exception safety, spill
ladder.

Covers the exception-safe chunk pipeline (real errors propagate,
first-success-wins under speculation), the bounded-prefetch residency
contract, bit-identity of the async pipeline vs the synchronous path, the
memmap spill ladder (bit-identity + checkpoint/resume mid-ladder), and the
rung-trigger accounting regression.
"""

import numpy as np
import pytest

from repro.core import Problem, Solver, densest_subgraph
from repro.core.streaming import (
    StreamingDensest,
    _TIMINGS_WINDOW,
    chunked_from_arrays,
    chunked_from_memmap,
)
from repro.graph.edgelist import (
    EdgeSpillWriter,
    open_edge_spill,
    open_edges_memmap,
    save_edges_memmap,
)
from repro.graph.generators import erdos_renyi, planted_dense_subgraph


def _edges_np(edges):
    mask = np.asarray(edges.mask)
    return (
        np.asarray(edges.src)[mask],
        np.asarray(edges.dst)[mask],
        np.asarray(edges.weight)[mask],
    )


@pytest.fixture(scope="module")
def graph():
    edges = erdos_renyi(500, avg_deg=8, seed=3)
    return edges, _edges_np(edges)


# ---------------------------------------------------------------------------
# Exception safety
# ---------------------------------------------------------------------------


def test_failing_chunk_stream_raises_real_error(graph):
    """A chunk stream that raises on one chunk surfaces ITS error (the seed
    bug swallowed it into a downstream ``KeyError: idx``)."""
    edges, (src, dst, w) = graph
    base = chunked_from_arrays(src, dst, w, chunk=97)

    def bad_stream():
        for i, c in enumerate(base()):
            if i == 3:
                raise RuntimeError("chunk 3 exploded")
            yield c

    drv = StreamingDensest(bad_stream, n_nodes=edges.n_nodes, n_workers=3)
    with pytest.raises(RuntimeError, match="chunk 3 exploded"):
        drv.run(resume=False)


def test_failing_chunk_worker_raises_real_error(graph):
    """A chunk whose WORKER fails (bad payload) raises the worker's real
    exception, not KeyError — with and without speculation."""
    edges, (src, dst, w) = graph
    base = chunked_from_arrays(src, dst, w, chunk=97)

    def poisoned():
        for i, (s, d, ww) in enumerate(base()):
            if i == 2:
                yield s, d, np.array(["boom"] * len(ww), object)
            else:
                yield s, d, ww

    for speculative in (False, True):
        drv = StreamingDensest(
            poisoned, n_nodes=edges.n_nodes, n_workers=3,
            speculative=speculative,
        )
        with pytest.raises(TypeError):
            drv.run(resume=False)


def test_flaky_chunk_first_success_wins(graph, monkeypatch):
    """A transiently failing chunk is retried (speculative duplicate of a
    failed attempt) and the pass completes with the successful result."""
    import repro.core.streaming as sm

    edges, (src, dst, w) = graph
    ref = StreamingDensest(
        chunked_from_arrays(src, dst, w, chunk=97), n_nodes=edges.n_nodes
    ).run(resume=False)

    orig = sm._chunk_stats
    state = {"failed": False}

    def flaky(s, d, ww, alive):
        if not state["failed"]:
            state["failed"] = True
            raise OSError("transient chunk read error")
        return orig(s, d, ww, alive)

    monkeypatch.setattr(sm, "_chunk_stats", flaky)
    drv = StreamingDensest(
        chunked_from_arrays(src, dst, w, chunk=97),
        n_nodes=edges.n_nodes, n_workers=3, speculative=True,
    )
    st = drv.run(resume=False)
    assert drv.speculative_reissues >= 1
    assert st.best_rho == ref.best_rho
    assert (st.best_alive == ref.best_alive).all()


def test_failed_pass_keeps_previous_checkpoint(graph, tmp_path):
    """Exception safety of the deferred finalization: a pass that explodes
    must not lose the previously completed pass's checkpoint."""
    edges, (src, dst, w) = graph
    base = chunked_from_arrays(src, dst, w, chunk=200)
    calls = {"n": 0}

    def explode_on_third_pass():
        calls["n"] += 1
        if calls["n"] == 3:
            raise RuntimeError("pass 3 stream lost")
        yield from base()

    ck = str(tmp_path / "ck")
    drv = StreamingDensest(
        explode_on_third_pass, n_nodes=edges.n_nodes, checkpoint_dir=ck
    )
    with pytest.raises(RuntimeError, match="pass 3 stream lost"):
        drv.run(resume=False)
    st = drv._load()
    assert st is not None and st.pass_idx == 2  # both completed passes saved


# ---------------------------------------------------------------------------
# Async pipeline: residency bound + bit-identity vs the synchronous path
# ---------------------------------------------------------------------------


def test_prefetch_bounds_resident_chunks(graph):
    edges, (src, dst, w) = graph
    for prefetch in (1, 2, 5):
        drv = StreamingDensest(
            chunked_from_arrays(src, dst, w, chunk=64),  # ~30+ chunks
            n_nodes=edges.n_nodes, n_workers=4, prefetch=prefetch,
        )
        drv.run(resume=False)
        assert 0 < drv.peak_resident_chunks <= prefetch
        assert drv.peak_resident_edges <= prefetch * 64


@pytest.mark.parametrize("chunk", [64, 257, 1000])
def test_async_pipeline_bit_identical_to_sync(graph, chunk):
    """The in-order reduce frontier makes the async pipeline bit-identical
    to a synchronous one-chunk-at-a-time pass, for every chunk size."""
    edges, (src, dst, w) = graph
    sync = StreamingDensest(
        chunked_from_arrays(src, dst, w, chunk=chunk),
        n_nodes=edges.n_nodes, n_workers=1, prefetch=1, speculative=False,
    ).run(resume=False)
    drv = StreamingDensest(
        chunked_from_arrays(src, dst, w, chunk=chunk),
        n_nodes=edges.n_nodes, n_workers=4, prefetch=6,
        speculative=True, speculate_tail_frac=0.5,
    )
    st = drv.run(resume=False)
    assert st.best_rho == sync.best_rho  # exact, not approx
    assert (st.best_alive == sync.best_alive).all()
    assert (st.alive == sync.alive).all()
    assert st.pass_idx == sync.pass_idx
    assert st.history == sync.history


def test_chunk_timings_bounded(graph):
    """The straggler-timing record is a rolling window, not a per-chunk
    per-pass leak."""
    edges, (src, dst, w) = graph
    drv = StreamingDensest(
        chunked_from_arrays(src, dst, w, chunk=32), n_nodes=edges.n_nodes
    )
    drv.run(resume=False)
    assert drv.chunk_timings.maxlen == _TIMINGS_WINDOW
    assert len(drv.chunk_timings) <= _TIMINGS_WINDOW


# ---------------------------------------------------------------------------
# History record: (n_alive, e_alive, rho) — not total weight
# ---------------------------------------------------------------------------


def test_history_records_alive_edge_count(tmp_path):
    """With non-unit weights the middle history slot is the alive EDGE
    COUNT (the seed recorded total weight against the documented (n, m,
    rho) contract), and the checkpoint reshape(-1, 3) round-trips."""
    edges = erdos_renyi(300, avg_deg=6, seed=7)
    src, dst, w = _edges_np(edges)
    w = w * 3.5  # make weight != edge count
    ck = str(tmp_path / "ck")
    drv = StreamingDensest(
        chunked_from_arrays(src, dst, w, chunk=128),
        n_nodes=edges.n_nodes, checkpoint_dir=ck,
    )
    st = drv.run(resume=False)
    n0, m0, rho0 = st.history[0]
    assert n0 == edges.n_nodes
    assert m0 == len(src)  # alive edge count, not 3.5x the weight
    assert rho0 == pytest.approx(3.5 * len(src) / edges.n_nodes)
    loaded = StreamingDensest(
        chunked_from_arrays(src, dst, w, chunk=128),
        n_nodes=edges.n_nodes, checkpoint_dir=ck,
    )._load()
    assert [tuple(map(float, h)) for h in loaded.history] == [
        tuple(map(float, h)) for h in st.history
    ]


# ---------------------------------------------------------------------------
# Compaction ladder: rung-trigger accounting + spill
# ---------------------------------------------------------------------------


def test_compact_stream_returns_padded_slot_total(graph):
    """Regression: ``_compact_stream`` must return the PADDED slot total of
    the rebuilt stream (the quantity the rung trigger compares against and
    the next ``_pass_stats`` reports), not the unpadded kept-edge count."""
    edges, (src, dst, w) = graph
    drv = StreamingDensest(
        chunked_from_arrays(src, dst, w, chunk=100),
        n_nodes=edges.n_nodes, compaction="geometric",
    )
    alive_c = np.zeros(edges.n_nodes, bool)
    alive_c[: edges.n_nodes // 3] = True  # kill 2/3 of the nodes
    id_map = np.arange(edges.n_nodes, dtype=np.int64)
    stream, new_alive, new_id_map, n_slots = drv._compact_stream(
        chunked_from_arrays(src, dst, w, chunk=100), alive_c, id_map, 1
    )
    from repro.graph.partition import pow2_bucket

    rebuilt = list(stream())
    assert n_slots == sum(len(c[0]) for c in rebuilt)  # what a pass streams
    kept = int((alive_c[src] & alive_c[dst]).sum())
    assert n_slots >= kept  # pow2 padding
    per_chunk_kept = [
        int((alive_c[s] & alive_c[d]).sum())
        for s, d, _ in chunked_from_arrays(src, dst, w, chunk=100)()
    ]
    assert n_slots == sum(
        pow2_bucket(k, floor=256) for k in per_chunk_kept if k > 0
    )


def _run_geo(stream, n_nodes, eps=0.2, **kw):
    drv = StreamingDensest(
        stream, n_nodes=n_nodes, eps=eps, compaction="geometric", **kw
    )
    return drv.run(resume=False), drv


def test_spill_ladder_bit_identical_and_out_of_core(tmp_path):
    """The acceptance criterion: a memmap-backed stream whose ladder
    survivors exceed the residency cap completes via ``spill_dir``,
    bit-identical to ``compaction='off'``, with bounded host residency."""
    edges, _ = planted_dense_subgraph(800, avg_deg=6, k=40, p_dense=0.8, seed=0)
    src, dst, w = _edges_np(edges)
    store = save_edges_memmap(str(tmp_path / "store"), src, dst, w)
    stream = chunked_from_memmap(store, chunk=512)

    off = StreamingDensest(stream, n_nodes=edges.n_nodes, eps=0.2).run(
        resume=False
    )
    cap = 600  # pipeline window (1 x 512) fits; ladder survivors do not
    with pytest.raises(RuntimeError, match="spill_dir"):
        # Proof the scenario is real: without a spill the survivors of the
        # first rung overflow this cap.
        _run_geo(stream, edges.n_nodes, residency_cap_edges=cap, prefetch=1)
    st, drv = _run_geo(
        stream, edges.n_nodes,
        spill_dir=str(tmp_path / "spill"), residency_cap_edges=cap,
        prefetch=1,
    )
    assert drv.compactions >= 1 and drv.spill_rungs == drv.compactions
    assert st.best_rho == off.best_rho
    assert (st.best_alive == off.best_alive).all()
    assert st.pass_idx == off.pass_idx
    assert st.history == off.history
    # Host residency never exceeded the pipeline window (the rebuilt
    # streams lived on disk): cap >> window, so this bounds both.
    assert drv.peak_resident_edges <= cap
    # The final rung is on disk and finalized.
    assert drv._cur_rung_dir is not None
    assert open_edge_spill(drv._cur_rung_dir) is not None


def test_residency_cap_without_spill_raises(tmp_path):
    edges, _ = planted_dense_subgraph(800, avg_deg=6, k=40, p_dense=0.8, seed=0)
    src, dst, w = _edges_np(edges)
    stream = chunked_from_arrays(src, dst, w, chunk=512)
    with pytest.raises(RuntimeError, match="spill_dir"):
        _run_geo(stream, edges.n_nodes, residency_cap_edges=64)


@pytest.mark.parametrize("spill", [False, True])
def test_resume_mid_ladder_equivalence(tmp_path, spill):
    """Kill a geometric run mid-ladder; resuming (with or without a spill)
    must reproduce the uninterrupted run exactly."""
    edges = erdos_renyi(600, avg_deg=8, seed=1)
    src, dst, w = _edges_np(edges)
    stream = chunked_from_arrays(src, dst, w, chunk=500)
    ref, ref_drv = _run_geo(stream, edges.n_nodes)
    assert ref_drv.compactions >= 1  # the scenario really is mid-ladder

    kw = dict(checkpoint_dir=str(tmp_path / "ck"))
    if spill:
        kw["spill_dir"] = str(tmp_path / "spill")
    # Run from scratch, stop mid-ladder, then resume to completion.
    drv1 = StreamingDensest(
        stream, n_nodes=edges.n_nodes, eps=0.2, compaction="geometric", **kw
    )
    st1 = drv1.run(max_passes=4, resume=False)
    assert st1.pass_idx == 4
    drv2 = StreamingDensest(
        stream, n_nodes=edges.n_nodes, eps=0.2, compaction="geometric", **kw
    )
    st = drv2.run(resume=True)
    assert st.best_rho == ref.best_rho
    assert (st.best_alive == ref.best_alive).all()
    assert st.pass_idx == ref.pass_idx
    assert st.history == ref.history
    if spill:
        assert drv1.spill_rungs >= 1  # the interrupted run spilled


def test_resume_never_adopts_foreign_spill_rung(tmp_path):
    """Regression: a spill_dir shared with an earlier, different-eps run
    must not leak that run's final rung into a later resume (fresh starts
    clear foreign rungs; manifests are stamped with eps)."""
    edges = erdos_renyi(600, avg_deg=8, seed=1)
    src, dst, w = _edges_np(edges)
    stream = chunked_from_arrays(src, dst, w, chunk=500)
    kw = dict(
        checkpoint_dir=str(tmp_path / "ck"), spill_dir=str(tmp_path / "spill")
    )

    # Run A (eps=0.3) completes, leaving its final rung in spill_dir.
    a = StreamingDensest(
        stream, n_nodes=edges.n_nodes, eps=0.3, compaction="geometric", **kw
    )
    a.run(resume=False)
    assert a.spill_rungs >= 1

    # Run B (eps=0.2) starts fresh in the SAME spill_dir, dies mid-ladder,
    # then resumes — it must reproduce the uninterrupted eps=0.2 run, not a
    # hybrid seeded from run A's survivor stream.
    ref, _ = _run_geo(stream, edges.n_nodes)  # eps=0.2, no spill
    b1 = StreamingDensest(
        stream, n_nodes=edges.n_nodes, eps=0.2, compaction="geometric", **kw
    )
    b1.run(max_passes=4, resume=False)
    b2 = StreamingDensest(
        stream, n_nodes=edges.n_nodes, eps=0.2, compaction="geometric", **kw
    )
    st = b2.run(resume=True)
    assert st.best_rho == ref.best_rho
    assert (st.best_alive == ref.best_alive).all()
    assert st.pass_idx == ref.pass_idx
    assert st.history == ref.history


# ---------------------------------------------------------------------------
# Memmap edge stores + spill writer primitives
# ---------------------------------------------------------------------------


def test_edge_store_roundtrip(tmp_path):
    rng = np.random.default_rng(0)
    src = rng.integers(0, 100, 1000).astype(np.int32)
    dst = rng.integers(0, 100, 1000).astype(np.int32)
    w = rng.random(1000).astype(np.float32)
    store = save_edges_memmap(str(tmp_path / "store"), src, dst, w)
    s, d, ww = open_edges_memmap(store)
    np.testing.assert_array_equal(np.asarray(s), src)
    np.testing.assert_array_equal(np.asarray(d), dst)
    np.testing.assert_array_equal(np.asarray(ww), w)
    # Chunk stream over the store slices the memmaps without materializing.
    chunks = list(chunked_from_memmap(store, 300)())
    assert [len(c[0]) for c in chunks] == [300, 300, 300, 100]
    np.testing.assert_array_equal(np.concatenate([c[2] for c in chunks]), w)


def test_spill_writer_atomic_manifest(tmp_path):
    d = str(tmp_path / "spill")
    wtr = EdgeSpillWriter(d, np.float32)
    wtr.append(np.arange(4, dtype=np.int32), np.arange(4, dtype=np.int32),
               np.ones(4, np.float32))
    # Unfinalized (crash mid-spill): invisible to readers.
    assert open_edge_spill(d) is None
    wtr.finalize(caps=[4], rung=0)
    src, dst, w, man = open_edge_spill(d)
    assert man["n_slots"] == 4 and man["caps"] == [4] and man["rung"] == 0
    np.testing.assert_array_equal(np.asarray(src), np.arange(4))


# ---------------------------------------------------------------------------
# Front door: Problem knobs lower onto the driver
# ---------------------------------------------------------------------------


def test_problem_stream_knobs_lowering(tmp_path):
    edges = erdos_renyi(400, avg_deg=6, seed=5)
    s = Solver()
    ref = densest_subgraph(edges, eps=0.5)
    res = s.solve(
        edges,
        Problem.undirected(
            eps=0.5, substrate="streaming", compaction="geometric",
            stream_chunk=257, stream_prefetch=2, stream_workers=2,
            spill_dir=str(tmp_path / "spill"),
        ),
    )
    assert (np.asarray(res.best_alive) == np.asarray(ref.best_alive)).all()
    assert float(res.best_density) == pytest.approx(
        float(ref.best_density), rel=1e-6
    )
    info = res.extras["streaming"]
    assert 0 < info["peak_resident_chunks"] <= 2
    assert info["compactions"] == info["spill_rungs"]

    with pytest.raises(ValueError, match="stream_prefetch"):
        Problem.undirected(stream_prefetch=0)
    with pytest.raises(ValueError, match="residency_cap_edges"):
        Problem.undirected(residency_cap_edges=0)
    # residency_cap_edges lowers onto the driver: an impossible in-RAM cap
    # with no spill_dir must surface the driver's error.
    with pytest.raises(RuntimeError, match="residency_cap_edges"):
        s.solve(
            edges,
            Problem.undirected(
                eps=0.5, substrate="streaming", compaction="geometric",
                stream_chunk=257, residency_cap_edges=1,
            ),
        )
    # spill_dir without the geometric ladder would be a silent no-op: both
    # the front door and the driver reject it.  (Since the 'auto' default
    # flip, a default-compaction streaming Problem resolves to geometric —
    # spill_dir is then valid; only an explicit 'off' still rejects.)
    with pytest.raises(ValueError, match="spill_dir"):
        Problem.undirected(
            substrate="streaming", compaction="off", spill_dir="/x"
        ).resolve(100)
    auto_spill = Problem.undirected(substrate="streaming", spill_dir="/x").resolve(100)
    assert auto_spill.compaction == "geometric"
    with pytest.raises(ValueError, match="spill_dir"):
        StreamingDensest(lambda: iter(()), n_nodes=4, spill_dir="/x")
    # Streaming knobs never key compiled programs (no spurious recompiles).
    p1 = Problem.undirected().resolve(100)
    p2 = Problem.undirected(
        stream_prefetch=3, spill_dir="/x", stream_chunk=1, stream_workers=9
    ).resolve(100)
    assert s._key("solve", p1, 8, 100, 64, "float32", None) == s._key(
        "solve", p2, 8, 100, 64, "float32", None
    )
