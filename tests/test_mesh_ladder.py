"""Single-program mesh compaction ladder (mesh × compaction='geometric').

The tentpole contract: the WHOLE geometric ladder — every peel segment and
every inter-rung edge compaction — runs inside ONE compiled
``jit(shard_map)`` program, collective-only (no host gather/reshard per
rung), bit-identical to the host-ladder and ``compaction='off'`` paths for
integer-valued weights.

Single-device degeneracy and schedule/report shape run in-process; the
multi-device cases (uneven survivor counts across devices, a rung whose
survivors all land on one device, permuted shard order) run in a subprocess
with ``--xla_force_host_platform_device_count=8`` so the main test process
keeps seeing one device (per the project rule).
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax
from jax.sharding import Mesh

import repro.core.api as api_mod
from repro.core import (
    Problem,
    Solver,
    make_distributed_peel_ladder,
    shard_edges,
)
from repro.graph.partition import ladder_schedule, pow2_bucket
from repro.graph.generators import directed_planted, planted_dense_subgraph


def _und():
    return planted_dense_subgraph(260, avg_deg=4, k=25, p_dense=0.8, seed=3)[0]


def _dir():
    return directed_planted(200, avg_deg=3, ks=15, kt=12, p_dense=0.9, seed=5)[0]


def _mesh1():
    return Mesh(np.asarray(jax.devices()[:1]).reshape(1), ("data",))


@pytest.fixture
def small_ladder_floor(monkeypatch):
    """Shrink the ladder's bucket floor so the few-hundred-edge test graphs
    actually exercise multi-rung schedules (the production floor of 4096
    global edges would collapse them to one rung)."""
    monkeypatch.setattr(api_mod, "_LADDER_MIN_EDGES", 64)


def _same_full(a, b):
    np.testing.assert_array_equal(np.asarray(a.best_alive), np.asarray(b.best_alive))
    assert float(a.best_density) == float(b.best_density)
    assert int(a.passes) == int(b.passes)
    assert int(a.best_size) == int(b.best_size)
    np.testing.assert_array_equal(np.asarray(a.alive), np.asarray(b.alive))
    if np.asarray(a.best_t).size:
        np.testing.assert_array_equal(np.asarray(a.best_t), np.asarray(b.best_t))
        np.testing.assert_array_equal(np.asarray(a.t_alive), np.asarray(b.t_alive))


# ---------------------------------------------------------------------------
# Static schedule (graph/partition.ladder_schedule)
# ---------------------------------------------------------------------------


def test_ladder_schedule_is_static_halving():
    assert ladder_schedule(1024, floor=256) == (1024, 512, 256)
    assert ladder_schedule(1000, floor=256) == (1024, 512, 256)  # pow2 bucketed
    assert ladder_schedule(256, floor=256) == (256,)  # floor -> single rung
    assert ladder_schedule(100, floor=256) == (128,)  # floor clamps to top
    assert ladder_schedule(1, floor=1) == (1,)
    sched = ladder_schedule(1 << 20, floor=256)
    assert all(a == 2 * b for a, b in zip(sched, sched[1:]))
    assert sched[0] == 1 << 20 and sched[-1] == 256
    # Every rung is a pow2_bucket fixed point: one compile per bucket.
    assert all(pow2_bucket(c) == c for c in sched)
    # Coarser strides shrink faster (fewer compaction collectives).
    assert ladder_schedule(1 << 20, floor=256, stride=4) == (
        1 << 20, 1 << 18, 1 << 16, 1 << 14, 1 << 12, 1 << 10, 1 << 8
    )
    with pytest.raises(ValueError):
        ladder_schedule(1024, stride=1)


# ---------------------------------------------------------------------------
# Single-device degeneracy: mesh ladder == jit host ladder == off, to the bit
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("eps", [0.1, 0.5])
def test_single_device_mesh_ladder_degenerates_to_jit_ladder(eps, small_ladder_floor):
    edges = _und()
    mesh = _mesh1()
    s = Solver()
    off = s.solve(
        edges, Problem.undirected(eps=eps, track_history=True, compaction="off")
    )
    jit_ladder = s.solve(
        edges, Problem.undirected(eps=eps, track_history=True, compaction="geometric")
    )
    mesh_ladder = s.solve(
        edges,
        Problem.undirected(
            eps=eps, track_history=True, compaction="geometric", substrate="mesh"
        ),
        mesh=mesh,
    )
    _same_full(off, jit_ladder)
    _same_full(off, mesh_ladder)
    np.testing.assert_array_equal(
        np.asarray(jit_ladder.history_n), np.asarray(mesh_ladder.history_n)
    )
    np.testing.assert_array_equal(
        np.asarray(jit_ladder.history_rho), np.asarray(mesh_ladder.history_rho)
    )
    lad = mesh_ladder.extras["compaction"]
    assert lad["single_program"] is True
    assert lad["host_round_trips"] == 0
    assert sum(seg["passes"] for seg in lad["segments"]) == int(off.passes)
    # The in-program compaction must actually run (multi-rung schedule);
    # with the production floor these graphs would be one trivial rung.
    assert len(lad["segments"]) > 1
    # The host jit ladder, by contrast, pays one round-trip per rung.
    jl = jit_ladder.extras["compaction"]
    assert jl["single_program"] is False
    assert jl["host_round_trips"] == len(jl["segments"]) >= 1


@pytest.mark.parametrize("c", [0.5, 1.0, None])
def test_mesh_ladder_directed_matches_host_ladder(c, small_ladder_floor):
    edges = _dir()
    mesh = _mesh1()
    s = Solver()
    off = s.solve(
        edges, Problem.directed(c=c, eps=0.5, substrate="mesh", compaction="off"),
        mesh=mesh,
    )
    on = s.solve(
        edges,
        Problem.directed(c=c, eps=0.5, substrate="mesh", compaction="geometric"),
        mesh=mesh,
    )
    _same_full(off, on)
    if c is None:
        assert on.extras["best_c"] == off.extras["best_c"]
        np.testing.assert_array_equal(
            on.extras["c_density"], off.extras["c_density"]
        )


def test_mesh_ladder_at_least_k_and_zero_pass_runs(small_ladder_floor):
    edges = _und()
    mesh = _mesh1()
    s = Solver()
    for k in (30, edges.n_nodes + 10):  # k > n: the zero-pass degenerate run
        off = s.solve(
            edges,
            Problem.at_least_k(k=k, eps=0.5, substrate="mesh", compaction="off"),
            mesh=mesh,
        )
        on = s.solve(
            edges,
            Problem.at_least_k(
                k=k, eps=0.5, substrate="mesh", compaction="geometric"
            ),
            mesh=mesh,
        )
        _same_full(off, on)


def test_mesh_ladder_program_is_cached_and_shares_across_c(small_ladder_floor):
    """Re-solves hit the one cached ladder program; c is a runtime scalar so
    fixed-c ladders and the grid share it too."""
    edges = _und()
    mesh = _mesh1()
    s = Solver()
    s.solve(
        edges,
        Problem.undirected(eps=0.25, substrate="mesh", compaction="geometric"),
        mesh=mesh,
    )
    traces = s.trace_count
    r2 = s.solve(
        edges,
        Problem.undirected(eps=0.25, substrate="mesh", compaction="geometric"),
        mesh=mesh,
    )
    assert s.trace_count == traces
    assert r2.provenance.cache_hit
    dg = _dir()
    s.solve(
        dg, Problem.directed(c=0.5, eps=0.5, substrate="mesh",
                             compaction="geometric"),
        mesh=mesh,
    )
    t2 = s.trace_count
    s.solve(
        dg, Problem.directed(c=2.0, eps=0.5, substrate="mesh",
                             compaction="geometric"),
        mesh=mesh,
    )
    assert s.trace_count == t2  # same single program, new c


def test_make_distributed_peel_ladder_builder_single_device(small_ladder_floor):
    edges = _und()
    mesh = _mesh1()
    run = make_distributed_peel_ladder(
        mesh, ("data",), eps=0.5, n_nodes=edges.n_nodes,
        m_edges=edges.n_edges_padded,
    )
    assert run.n_edge_slots == run.schedule[0] * 1
    # Rung 0 is the exact input buffer; the tail is pow2-bucketed and
    # strictly descending.
    assert all(a > b for a, b in zip(run.schedule, run.schedule[1:]))
    assert all(pow2_bucket(c) == c for c in run.schedule[1:])
    padded = edges.with_padding(run.n_edge_slots)
    sh = shard_edges(padded, mesh, ("data",))
    out = run(sh.src, sh.dst, sh.weight, sh.mask)
    ref = Solver().solve(edges, Problem.undirected(eps=0.5, compaction="off"))
    np.testing.assert_array_equal(
        np.asarray(out.best_alive), np.asarray(ref.best_alive)
    )
    assert float(out.best_density) == float(ref.best_density)
    assert int(out.passes) == int(ref.passes)


# ---------------------------------------------------------------------------
# Multi-device: uneven survivors, one-device rungs, shard-order independence
# ---------------------------------------------------------------------------

_LADDER_8DEV_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax
    import jax.numpy as jnp
    import repro.core.api as api_mod
    from repro.core import Problem, Solver, make_distributed_peel_ladder, shard_edges
    from repro.graph.edgelist import EdgeList
    from repro.graph.generators import planted_dense_subgraph

    # Small floor so these few-thousand-edge graphs build multi-rung
    # ladders (production floor: 4096 global edges).
    api_mod._LADDER_MIN_EDGES = 64
    assert len(jax.devices()) == 8
    mesh = jax.make_mesh((8,), ("data",))
    s = Solver()

    def same(a, b):
        assert np.array_equal(np.asarray(a.best_alive), np.asarray(b.best_alive))
        assert float(a.best_density) == float(b.best_density)
        assert int(a.passes) == int(b.passes)
        assert np.array_equal(np.asarray(a.alive), np.asarray(b.alive))

    # Uneven survivor counts across devices: a planted block scatters its
    # survivors nonuniformly over the 8 edge shards.
    edges, _ = planted_dense_subgraph(500, avg_deg=4, k=25, p_dense=0.8, seed=0)
    off = s.solve(edges, Problem.undirected(eps=0.2, compaction="off"))
    on = s.solve(
        edges,
        Problem.undirected(eps=0.2, substrate="mesh", compaction="geometric"),
        mesh=mesh,
    )
    same(off, on)
    lad = on.extras["compaction"]
    assert lad["single_program"] and lad["host_round_trips"] == 0
    assert sum(g["passes"] for g in lad["segments"]) == int(off.passes)
    assert len(lad["segments"]) > 1  # the collective compaction really ran

    # A rung whose survivors all land on ONE device: the dense block's edges
    # occupy the first slots of the edge array, i.e. shard 0; after the
    # sparse background peels away, every surviving edge lives on device 0.
    rng = np.random.default_rng(1)
    n = 400
    ks, kd = np.triu_indices(40, k=1)            # 780 clique edges, shard 0
    bs = rng.integers(40, n, 1200); bd = rng.integers(40, n, 1200)
    keep = bs != bd
    src = np.concatenate([ks, bs[keep]]).astype(np.int32)
    dst = np.concatenate([kd, bd[keep]]).astype(np.int32)
    g = EdgeList(
        src=jnp.asarray(src), dst=jnp.asarray(dst),
        weight=jnp.ones(src.size, jnp.float32),
        mask=jnp.ones(src.size, bool), n_nodes=n,
    )
    off2 = s.solve(g, Problem.undirected(eps=0.1, compaction="off"))
    on2 = s.solve(
        g, Problem.undirected(eps=0.1, substrate="mesh", compaction="geometric"),
        mesh=mesh,
    )
    same(off2, on2)
    assert int(off2.best_size) >= 40 * 0.9  # the clique survives the peel

    # Shard-order independence: permuting the edge array (hence which shard
    # holds what) must not change anything (unit weights).
    perm = rng.permutation(src.size)
    gp = EdgeList(
        src=g.src[perm], dst=g.dst[perm], weight=g.weight[perm],
        mask=g.mask[perm], n_nodes=n,
    )
    onp_ = s.solve(
        gp, Problem.undirected(eps=0.1, substrate="mesh", compaction="geometric"),
        mesh=mesh,
    )
    same(off2, onp_)

    # Raw single-program builder parity.
    run = make_distributed_peel_ladder(
        mesh, ("data",), eps=0.2, n_nodes=edges.n_nodes,
        m_edges=edges.n_edges_padded,
    )
    padded = edges.with_padding(run.n_edge_slots)
    sh = shard_edges(padded, mesh, ("data",))
    out = run(sh.src, sh.dst, sh.weight, sh.mask)
    assert np.array_equal(np.asarray(out.best_alive), np.asarray(off.best_alive))
    assert int(out.passes) == int(off.passes)
    print("MESH_LADDER_8DEV_OK")
    """
)


@pytest.mark.slow
def test_mesh_ladder_equivalence_8_devices():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    out = subprocess.run(
        [sys.executable, "-c", _LADDER_8DEV_SCRIPT],
        capture_output=True,
        text=True,
        env=env,
        timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert "MESH_LADDER_8DEV_OK" in out.stdout
