"""Shared host-I/O primitives (no jax / heavy deps: importable everywhere).

One implementation of the crash-safe file publish used by the streaming
checkpoint and the edge-spill manifest; ``checkpoint/manager.py`` holds the
directory-level form of the same two-phase commit.
"""

from __future__ import annotations

import os
import tempfile
from typing import Callable, IO


def atomic_write_file(
    final_path: str,
    write_fn: Callable[[IO], None],
    mode: str = "wb",
    suffix: str = ".tmp",
) -> None:
    """Crash-safe publish: ``write_fn(f)`` into a same-directory temp file,
    flush + fsync, then ``os.replace`` onto ``final_path`` — a reader sees
    the old content or the new, never a torn write.  The temp file is
    removed on failure."""
    d = os.path.dirname(final_path) or "."
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, suffix=suffix)
    try:
        with os.fdopen(fd, mode) as f:
            write_fn(f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, final_path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
