from repro.graph.edgelist import EdgeList, dedup_edges, from_numpy, to_csr

__all__ = ["EdgeList", "dedup_edges", "from_numpy", "to_csr"]
