from repro.graph.edgelist import (
    EdgeList,
    EdgeSpillWriter,
    dedup_edges,
    from_numpy,
    open_edge_spill,
    open_edges_memmap,
    save_edges_memmap,
    to_csr,
)

__all__ = [
    "EdgeList",
    "EdgeSpillWriter",
    "dedup_edges",
    "from_numpy",
    "open_edge_spill",
    "open_edges_memmap",
    "save_edges_memmap",
    "to_csr",
]
