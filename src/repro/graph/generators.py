"""Synthetic graph generators (host-side numpy) used by tests and benchmarks.

These replace the paper's proprietary / large public datasets (FLICKR, IM,
LIVEJOURNAL, TWITTER are not available offline): we generate graphs with the
same structural features the paper's experiments rely on — heavy-tailed degree
distributions, planted dense communities, and the Lemma 5 pass-lower-bound
instance.
"""

from __future__ import annotations

from typing import Tuple

import numpy as np

from repro.graph.edgelist import EdgeList, dedup_edges, from_numpy


def erdos_renyi(n: int, avg_deg: float, seed: int = 0, directed: bool = False) -> EdgeList:
    rng = np.random.default_rng(seed)
    m = int(n * avg_deg / (1 if directed else 2))
    src = rng.integers(0, n, size=2 * m + 16)
    dst = rng.integers(0, n, size=2 * m + 16)
    src, dst = dedup_edges(src, dst, directed=directed)
    src, dst = src[:m], dst[:m]
    return from_numpy(src, dst, n, directed=directed)


def planted_dense_subgraph(
    n: int,
    avg_deg: float,
    k: int,
    p_dense: float,
    seed: int = 0,
) -> Tuple[EdgeList, np.ndarray]:
    """ER background + a planted dense block on the first ``k`` nodes.

    Returns the graph and the planted node-index array.
    """
    rng = np.random.default_rng(seed)
    m_bg = int(n * avg_deg / 2)
    src_bg = rng.integers(0, n, size=m_bg)
    dst_bg = rng.integers(0, n, size=m_bg)
    # Dense block: each pair kept with prob p_dense.
    iu = np.triu_indices(k, 1)
    keep = rng.random(iu[0].shape[0]) < p_dense
    src = np.concatenate([src_bg, iu[0][keep]])
    dst = np.concatenate([dst_bg, iu[1][keep]])
    src, dst = dedup_edges(src, dst, directed=False)
    return from_numpy(src, dst, n), np.arange(k)


def chung_lu_power_law(
    n: int, exponent: float = 2.2, avg_deg: float = 8.0, seed: int = 0
) -> EdgeList:
    """Chung-Lu graph with power-law expected degrees (heavy-tail, like the
    paper's social graphs)."""
    rng = np.random.default_rng(seed)
    w = (np.arange(1, n + 1) ** (-1.0 / (exponent - 1.0))).astype(np.float64)
    w *= n * avg_deg / w.sum()
    p = w / w.sum()
    m = int(n * avg_deg / 2)
    src = rng.choice(n, size=m, p=p)
    dst = rng.choice(n, size=m, p=p)
    src, dst = dedup_edges(src, dst, directed=False)
    return from_numpy(src, dst, n)


def barabasi_albert(n: int, m_attach: int = 4, seed: int = 0) -> EdgeList:
    rng = np.random.default_rng(seed)
    targets = list(range(m_attach))
    repeated: list[int] = list(range(m_attach))
    src_l: list[int] = []
    dst_l: list[int] = []
    for v in range(m_attach, n):
        chosen = rng.choice(np.asarray(repeated), size=m_attach, replace=False)
        for t in set(int(c) for c in chosen):
            src_l.append(v)
            dst_l.append(t)
            repeated.append(v)
            repeated.append(t)
    src, dst = dedup_edges(np.asarray(src_l), np.asarray(dst_l), directed=False)
    del targets
    return from_numpy(src, dst, n)


def _regular_circulant(n: int, d: int, offset_nodes: int) -> Tuple[np.ndarray, np.ndarray]:
    """A d-regular graph on n nodes (circulant; d=1 => perfect matching)."""
    assert d < n
    src_l = []
    dst_l = []
    if d == 1:
        assert n % 2 == 0
        a = np.arange(0, n, 2)
        src_l.append(a)
        dst_l.append(a + 1)
    else:
        assert d % 2 == 0 or n % 2 == 0
        half = d // 2
        a = np.arange(n)
        for j in range(1, half + 1):
            src_l.append(a)
            dst_l.append((a + j) % n)
        if d % 2 == 1:
            a2 = np.arange(n // 2)
            src_l.append(a2)
            dst_l.append((a2 + n // 2) % n)
    src = np.concatenate(src_l) + offset_nodes
    dst = np.concatenate(dst_l) + offset_nodes
    return src, dst


def lemma5_instance(k: int) -> EdgeList:
    """The Lemma 5 pass-lower-bound instance.

    k disjoint subgraphs G_1..G_k where G_i is 2^{i-1}-regular on 2^{2k+1-i}
    nodes; every G_i has 2^{2k-1} edges.  Algorithm 1 provably needs
    Omega(k / log k) passes on this graph.
    """
    srcs, dsts = [], []
    offset = 0
    for i in range(1, k + 1):
        ni = 2 ** (2 * k + 1 - i)
        di = 2 ** (i - 1)
        s, d = _regular_circulant(ni, di, offset)
        srcs.append(s)
        dsts.append(d)
        offset += ni
    src = np.concatenate(srcs)
    dst = np.concatenate(dsts)
    src, dst = dedup_edges(src, dst, directed=False)
    return from_numpy(src, dst, offset)


def directed_planted(
    n: int, avg_deg: float, ks: int, kt: int, p_dense: float, seed: int = 0
) -> Tuple[EdgeList, np.ndarray, np.ndarray]:
    """Directed ER + planted dense S->T block (S = first ks nodes, T = next kt)."""
    rng = np.random.default_rng(seed)
    m_bg = int(n * avg_deg)
    src_bg = rng.integers(0, n, size=m_bg)
    dst_bg = rng.integers(0, n, size=m_bg)
    s_ids = np.arange(ks)
    t_ids = np.arange(ks, ks + kt)
    grid_s, grid_t = np.meshgrid(s_ids, t_ids, indexing="ij")
    keep = rng.random(grid_s.size) < p_dense
    src = np.concatenate([src_bg, grid_s.ravel()[keep]])
    dst = np.concatenate([dst_bg, grid_t.ravel()[keep]])
    src, dst = dedup_edges(src, dst, directed=True)
    return from_numpy(src, dst, n, directed=True), s_ids, t_ids


def bipartite_spam(
    n_users: int,
    n_items: int,
    avg_deg: float,
    spam_users: int,
    spam_items: int,
    p_spam: float,
    seed: int = 0,
) -> Tuple[EdgeList, np.ndarray, np.ndarray]:
    """User->item bipartite interaction graph with a planted spam block
    (the paper's link-spam application, adapted to recsys interactions).

    Nodes 0..n_users-1 are users; n_users..n_users+n_items-1 are items.
    Spam block: the *last* ``spam_users`` users and ``spam_items`` items.
    """
    rng = np.random.default_rng(seed)
    m_bg = int(n_users * avg_deg)
    src_bg = rng.integers(0, n_users, size=m_bg)
    dst_bg = rng.integers(0, n_items, size=m_bg) + n_users
    su = np.arange(n_users - spam_users, n_users)
    si = np.arange(n_items - spam_items, n_items) + n_users
    gs, gi = np.meshgrid(su, si, indexing="ij")
    keep = rng.random(gs.size) < p_spam
    src = np.concatenate([src_bg, gs.ravel()[keep]])
    dst = np.concatenate([dst_bg, gi.ravel()[keep]])
    src, dst = dedup_edges(src, dst, directed=True)
    n = n_users + n_items
    return from_numpy(src, dst, n, directed=True), su, si


def planted_partition(
    n: int, k: int, p_in, p_out: float, seed: int = 0
) -> Tuple[EdgeList, np.ndarray]:
    """k equal communities: edge prob p_in inside (scalar or per-community
    list — unequal densities make the peel extract them in order), p_out
    across.  Returns (graph, community labels int[n]); sampled sparsely
    (expected-count binomial per block) so large n stays cheap.
    """
    rng = np.random.default_rng(seed)
    labels = np.repeat(np.arange(k), n // k + 1)[:n]
    p_in_list = [p_in] * k if np.isscalar(p_in) else list(p_in)
    srcs, dsts = [], []
    idx_of = [np.nonzero(labels == c)[0] for c in range(k)]
    for a in range(k):
        na = len(idx_of[a])
        m_in = rng.binomial(na * (na - 1) // 2, p_in_list[a])
        srcs.append(idx_of[a][rng.integers(0, na, m_in)])
        dsts.append(idx_of[a][rng.integers(0, na, m_in)])
        for b in range(a + 1, k):
            nb = len(idx_of[b])
            m_x = rng.binomial(na * nb, p_out)
            srcs.append(idx_of[a][rng.integers(0, na, m_x)])
            dsts.append(idx_of[b][rng.integers(0, nb, m_x)])
    src = np.concatenate(srcs)
    dst = np.concatenate(dsts)
    src, dst = dedup_edges(src, dst, directed=False)
    return from_numpy(src, dst, n), labels


def weighted_preferential(n: int, seed: int = 0) -> EdgeList:
    """Deterministic weighted preferential-attachment graph from the Lemma 6
    proof sketch: node u arriving connects to all previous v with weight
    proportional to v's current (weighted) degree."""
    deg = np.zeros(n, np.float64)
    srcs, dsts, ws = [], [], []
    deg[0] = deg[1] = 1.0
    srcs.append(0)
    dsts.append(1)
    ws.append(1.0)
    for u in range(2, n):
        w_uv = deg[:u] / deg[:u].sum()
        srcs.extend([u] * u)
        dsts.extend(range(u))
        ws.extend(w_uv.tolist())
        deg[:u] += w_uv
        deg[u] = w_uv.sum()
    return from_numpy(
        np.asarray(srcs), np.asarray(dsts), n, weight=np.asarray(ws, np.float32)
    )
