"""Edge-list graph container used throughout the system.

The densest-subgraph algorithms (core/), the GNN message-passing substrate
(models/gnn/) and the Pallas peel kernel (kernels/peel_degree/) all consume
this one representation: flat ``src``/``dst``/``weight`` arrays with an
explicit padding ``mask`` so the edge count can be padded to a multiple of the
device count for sharding.  ``n_nodes`` is static metadata (needed as the
``num_segments`` of every ``segment_sum``).
"""

from __future__ import annotations

import dataclasses
import json
import os
import shutil
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import faults


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class EdgeList:
    """A (possibly weighted, possibly padded) edge list.

    Attributes:
      src: int32[E] source node ids (undirected graphs store each edge once).
      dst: int32[E] destination node ids.
      weight: float32[E] edge weights (1.0 for unweighted graphs).
      mask: bool[E] True for real edges, False for padding.
      n_nodes: static number of nodes.
      directed: static flag; undirected edges are stored once and counted for
        both endpoints' degrees.
    """

    src: jax.Array
    dst: jax.Array
    weight: jax.Array
    mask: jax.Array
    n_nodes: int = dataclasses.field(metadata=dict(static=True))
    directed: bool = dataclasses.field(default=False, metadata=dict(static=True))

    @property
    def n_edges_padded(self) -> int:
        return self.src.shape[0]

    def num_real_edges(self) -> jax.Array:
        return jnp.sum(self.mask.astype(jnp.int32))

    def with_padding(self, multiple: int) -> "EdgeList":
        """Pads the edge arrays so E is a multiple of ``multiple``."""
        e = self.src.shape[0]
        pad = (-e) % multiple
        if pad == 0:
            return self
        z32 = jnp.zeros((pad,), jnp.int32)
        zf = jnp.zeros((pad,), jnp.float32)
        zb = jnp.zeros((pad,), bool)
        return EdgeList(
            src=jnp.concatenate([self.src, z32]),
            dst=jnp.concatenate([self.dst, z32]),
            weight=jnp.concatenate([self.weight, zf]),
            mask=jnp.concatenate([self.mask, zb]),
            n_nodes=self.n_nodes,
            directed=self.directed,
        )


def from_numpy(
    src: np.ndarray,
    dst: np.ndarray,
    n_nodes: int,
    *,
    weight: np.ndarray | None = None,
    directed: bool = False,
) -> EdgeList:
    src = np.asarray(src, np.int32)
    dst = np.asarray(dst, np.int32)
    if weight is None:
        weight = np.ones_like(src, np.float32)
    mask = np.ones_like(src, bool)
    return EdgeList(
        src=jnp.asarray(src),
        dst=jnp.asarray(dst),
        weight=jnp.asarray(np.asarray(weight, np.float32)),
        mask=jnp.asarray(mask),
        n_nodes=int(n_nodes),
        directed=directed,
    )


def apply_updates(
    edges: EdgeList,
    inserts: np.ndarray | None = None,
    deletes: np.ndarray | None = None,
) -> Tuple[EdgeList, dict]:
    """Host-side exact reference for one turnstile update batch.

    Applies ``deletes`` then ``inserts`` to the undirected edge SET of
    ``edges`` and returns ``(new_edges, stats)``.  This is the ground
    truth the turnstile sketch tests/examples compare against: surviving
    edges keep their original stream order (stable), inserted edges are
    appended in batch order with weight 1.0, and the result is unpadded.

    Semantics (the well-formed-stream contract of core/turnstile.py):

    * edges are undirected — endpoint order is ignored for matching;
    * deleting an edge that is not live is a NO-OP, counted in
      ``stats['missing_deletes']`` (the sketch has no such tolerance:
      a missing delete corrupts it);
    * inserting an edge that is already live is a NO-OP, counted in
      ``stats['dup_inserts']`` (set semantics — the sketch would become
      a multiset and fail recovery);
    * duplicate entries WITHIN one batch collapse to one (first wins),
      counted in the same stats;
    * a batch must not contain the same edge in both lists — deletes are
      applied first, so insert+delete of one edge in one batch is
      order-ambiguous and raises.

    ``inserts``/``deletes`` are (k, 2) int arrays (or None).
    """
    ins = np.asarray(inserts if inserts is not None else np.zeros((0, 2)), np.int64)
    del_ = np.asarray(deletes if deletes is not None else np.zeros((0, 2)), np.int64)
    if ins.ndim != 2 or ins.shape[1] != 2 or del_.ndim != 2 or del_.shape[1] != 2:
        raise ValueError("inserts/deletes must be (k, 2) edge arrays")
    if edges.directed:
        raise ValueError("apply_updates models undirected turnstile streams")
    mask = np.asarray(edges.mask)
    src = np.asarray(edges.src, np.int64)[mask]
    dst = np.asarray(edges.dst, np.int64)[mask]
    w = np.asarray(edges.weight)[mask]
    n = int(edges.n_nodes)

    def keys(a, b):
        return np.minimum(a, b) * n + np.maximum(a, b)

    live = keys(src, dst)
    dk_all = keys(del_[:, 0], del_[:, 1])
    ik_all = keys(ins[:, 0], ins[:, 1])
    dk, d_first = np.unique(dk_all, return_index=True)
    ik, i_first = np.unique(ik_all, return_index=True)
    both = np.intersect1d(dk, ik)
    if len(both):
        raise ValueError(
            "a batch must not insert and delete the same edge (deletes "
            f"apply first, making the order ambiguous): {len(both)} overlap"
        )
    stats = {
        "dup_inserts": int(len(ik_all) - len(ik)),
        "missing_deletes": int(len(dk_all) - len(dk)),
    }
    # Deletes first: drop live edges whose key is in dk (stable order).
    hit = np.isin(live, dk)
    stats["deleted"] = int(hit.sum())
    stats["missing_deletes"] += int(len(dk) - hit.sum())
    src, dst, w, live = src[~hit], dst[~hit], w[~hit], live[~hit]
    # Inserts: append batch-order-first occurrences not already live.
    fresh = ~np.isin(ik, live)
    stats["dup_inserts"] += int(len(ik) - fresh.sum())
    stats["inserted"] = int(fresh.sum())
    keep = np.sort(i_first[fresh])  # batch order, not key order
    src = np.concatenate([src, ins[keep, 0]])
    dst = np.concatenate([dst, ins[keep, 1]])
    w = np.concatenate([w, np.ones(len(keep), np.float32)])
    out = EdgeList(
        src=jnp.asarray(src.astype(np.int32)),
        dst=jnp.asarray(dst.astype(np.int32)),
        weight=jnp.asarray(w.astype(np.float32)),
        mask=jnp.asarray(np.ones(len(src), bool)),
        n_nodes=n,
        directed=False,
    )
    return out, stats


def dedup_edges(
    src: np.ndarray, dst: np.ndarray, *, directed: bool
) -> Tuple[np.ndarray, np.ndarray]:
    """Removes self loops and duplicate edges (numpy, host side)."""
    src = np.asarray(src, np.int64)
    dst = np.asarray(dst, np.int64)
    keep = src != dst
    src, dst = src[keep], dst[keep]
    if not directed:
        lo = np.minimum(src, dst)
        hi = np.maximum(src, dst)
        src, dst = lo, hi
    key = src * (dst.max(initial=0) + 1) + dst
    _, idx = np.unique(key, return_index=True)
    return src[idx].astype(np.int32), dst[idx].astype(np.int32)


# ---------------------------------------------------------------------------
# Out-of-core edge stores (the streaming substrate's disk-resident graphs)
# ---------------------------------------------------------------------------

_STORE_ARRAYS = ("src", "dst", "weight")


def save_edges_memmap(
    store_dir: str,
    src: np.ndarray,
    dst: np.ndarray,
    weight: Optional[np.ndarray] = None,
) -> str:
    """Writes an on-disk edge store: ``src.npy``/``dst.npy``/``weight.npy``
    written through ``np.lib.format.open_memmap`` (self-describing dtype and
    shape, no manifest needed).  Pair with
    :func:`repro.core.streaming.chunked_from_memmap` for a chunk stream
    whose edges never enter host RAM whole."""
    os.makedirs(store_dir, exist_ok=True)
    if weight is None:
        weight = np.ones(len(src), np.float32)
    arrays = (
        np.asarray(src, np.int32),
        np.asarray(dst, np.int32),
        np.asarray(weight),
    )
    for name, arr in zip(_STORE_ARRAYS, arrays):
        mm = np.lib.format.open_memmap(
            os.path.join(store_dir, f"{name}.npy"),
            mode="w+",
            dtype=arr.dtype,
            shape=arr.shape,
        )
        mm[:] = arr
        mm.flush()
        del mm
    return store_dir


def open_edges_memmap(
    store_dir: str,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Read-mode memmaps ``(src, dst, weight)`` of an edge store written by
    :func:`save_edges_memmap` — slicing reads only the touched pages."""
    return tuple(
        np.load(os.path.join(store_dir, f"{name}.npy"), mmap_mode="r")
        for name in _STORE_ARRAYS
    )


class EdgeSpillWriter:
    """Append-only on-disk edge store with an atomic manifest.

    The streaming compaction ladder spills a rebuilt survivor stream
    through this: per-array raw ``.bin`` files are appended chunk by chunk
    (O(chunk) host memory at any moment), then :meth:`finalize` publishes
    ``manifest.json`` atomically (tmp + fsync + ``os.replace``) — a crash
    mid-spill leaves no manifest and the partial spill is ignored on
    resume."""

    def __init__(self, spill_dir: str, w_dtype):
        os.makedirs(spill_dir, exist_ok=True)
        self.dir = spill_dir
        self.w_dtype = np.dtype(w_dtype)
        self._files = {
            # repro: allow(atomic-io) append-only data files: invisible until finalize publishes the manifest
            name: open(os.path.join(spill_dir, f"{name}.bin"), "wb")
            for name in ("src", "dst", "w")
        }
        self.n_slots = 0

    def append(self, src: np.ndarray, dst: np.ndarray, w: np.ndarray) -> None:
        if not (len(src) == len(dst) == len(w)):
            raise ValueError("spill chunk arrays must have equal length")
        np.asarray(src, np.int32).tofile(self._files["src"])
        np.asarray(dst, np.int32).tofile(self._files["dst"])
        np.asarray(w, self.w_dtype).tofile(self._files["w"])
        self.n_slots += len(src)

    def close(self) -> None:
        for f in self._files.values():
            if not f.closed:
                f.close()

    def abort(self) -> None:
        """Failure path: close the fds and drop the partial spill directory
        (nothing was published, so nothing could resume from it)."""
        self.close()
        shutil.rmtree(self.dir, ignore_errors=True)

    def finalize(self, **meta) -> dict:
        """Flushes/fsyncs the data files, then atomically publishes the
        manifest (extra ``meta`` keys ride along; see
        :func:`repro.ioutil.atomic_write_file`).  Only after this returns
        does :func:`open_edge_spill` see the spill."""
        from repro.ioutil import atomic_write_file

        # Chaos hook: a publish failure must leave NO manifest (the caller
        # aborts the rung; resume ignores unfinalized spills).
        faults.fire("edgelist.spill_publish")
        for f in self._files.values():
            f.flush()
            # repro: allow(atomic-io) data-file durability must precede the manifest publish below
            os.fsync(f.fileno())
            f.close()
        manifest = dict(meta)
        manifest["n_slots"] = int(self.n_slots)
        manifest["w_dtype"] = self.w_dtype.str
        atomic_write_file(
            os.path.join(self.dir, "manifest.json"),
            lambda f: json.dump(manifest, f),
            mode="w",
            suffix=".json.tmp",
        )
        return manifest


def open_edge_spill(spill_dir: str):
    """Opens a FINALIZED spill: ``(src, dst, w, manifest)`` with the arrays
    as read-mode memmaps, or None when no manifest exists (unfinalized or
    absent — e.g. a spill interrupted mid-write)."""
    man_path = os.path.join(spill_dir, "manifest.json")
    if not os.path.exists(man_path):
        return None
    with open(man_path) as f:
        manifest = json.load(f)
    n = int(manifest["n_slots"])

    def mm(name, dtype):
        path = os.path.join(spill_dir, f"{name}.bin")
        if n == 0:
            return np.zeros(0, dtype)
        return np.memmap(path, dtype=dtype, mode="r", shape=(n,))

    return (
        mm("src", np.int32),
        mm("dst", np.int32),
        mm("w", np.dtype(manifest["w_dtype"])),
        manifest,
    )


def to_csr(edges: EdgeList, return_weights: bool = False):
    """Host-side CSR (indptr, indices[, weights]) over the symmetrized
    adjacency.  ``return_weights`` adds the per-slot edge weight aligned
    with ``indices`` (each undirected edge's weight appears under both
    endpoints) — the serving engine's host-resident adjacency
    (:class:`repro.serve.densest.DensestQueryEngine`) extracts weighted
    ego-nets from it."""
    mask = np.asarray(edges.mask)
    src = np.asarray(edges.src)[mask]
    dst = np.asarray(edges.dst)[mask]
    w = np.asarray(edges.weight)[mask]
    if edges.directed:
        s, d, ww = src, dst, w
    else:
        s = np.concatenate([src, dst])
        d = np.concatenate([dst, src])
        ww = np.concatenate([w, w])
    order = np.argsort(s, kind="stable")
    s, d, ww = s[order], d[order], ww[order]
    indptr = np.zeros(edges.n_nodes + 1, np.int64)
    np.add.at(indptr, s + 1, 1)
    indptr = np.cumsum(indptr)
    if return_weights:
        return indptr, d.astype(np.int32), ww
    return indptr, d.astype(np.int32)
