"""Edge-list graph container used throughout the system.

The densest-subgraph algorithms (core/), the GNN message-passing substrate
(models/gnn/) and the Pallas peel kernel (kernels/peel_degree/) all consume
this one representation: flat ``src``/``dst``/``weight`` arrays with an
explicit padding ``mask`` so the edge count can be padded to a multiple of the
device count for sharding.  ``n_nodes`` is static metadata (needed as the
``num_segments`` of every ``segment_sum``).
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class EdgeList:
    """A (possibly weighted, possibly padded) edge list.

    Attributes:
      src: int32[E] source node ids (undirected graphs store each edge once).
      dst: int32[E] destination node ids.
      weight: float32[E] edge weights (1.0 for unweighted graphs).
      mask: bool[E] True for real edges, False for padding.
      n_nodes: static number of nodes.
      directed: static flag; undirected edges are stored once and counted for
        both endpoints' degrees.
    """

    src: jax.Array
    dst: jax.Array
    weight: jax.Array
    mask: jax.Array
    n_nodes: int = dataclasses.field(metadata=dict(static=True))
    directed: bool = dataclasses.field(default=False, metadata=dict(static=True))

    @property
    def n_edges_padded(self) -> int:
        return self.src.shape[0]

    def num_real_edges(self) -> jax.Array:
        return jnp.sum(self.mask.astype(jnp.int32))

    def with_padding(self, multiple: int) -> "EdgeList":
        """Pads the edge arrays so E is a multiple of ``multiple``."""
        e = self.src.shape[0]
        pad = (-e) % multiple
        if pad == 0:
            return self
        z32 = jnp.zeros((pad,), jnp.int32)
        zf = jnp.zeros((pad,), jnp.float32)
        zb = jnp.zeros((pad,), bool)
        return EdgeList(
            src=jnp.concatenate([self.src, z32]),
            dst=jnp.concatenate([self.dst, z32]),
            weight=jnp.concatenate([self.weight, zf]),
            mask=jnp.concatenate([self.mask, zb]),
            n_nodes=self.n_nodes,
            directed=self.directed,
        )


def from_numpy(
    src: np.ndarray,
    dst: np.ndarray,
    n_nodes: int,
    *,
    weight: np.ndarray | None = None,
    directed: bool = False,
) -> EdgeList:
    src = np.asarray(src, np.int32)
    dst = np.asarray(dst, np.int32)
    if weight is None:
        weight = np.ones_like(src, np.float32)
    mask = np.ones_like(src, bool)
    return EdgeList(
        src=jnp.asarray(src),
        dst=jnp.asarray(dst),
        weight=jnp.asarray(np.asarray(weight, np.float32)),
        mask=jnp.asarray(mask),
        n_nodes=int(n_nodes),
        directed=directed,
    )


def dedup_edges(
    src: np.ndarray, dst: np.ndarray, *, directed: bool
) -> Tuple[np.ndarray, np.ndarray]:
    """Removes self loops and duplicate edges (numpy, host side)."""
    src = np.asarray(src, np.int64)
    dst = np.asarray(dst, np.int64)
    keep = src != dst
    src, dst = src[keep], dst[keep]
    if not directed:
        lo = np.minimum(src, dst)
        hi = np.maximum(src, dst)
        src, dst = lo, hi
    key = src * (dst.max(initial=0) + 1) + dst
    _, idx = np.unique(key, return_index=True)
    return src[idx].astype(np.int32), dst[idx].astype(np.int32)


def to_csr(edges: EdgeList) -> Tuple[np.ndarray, np.ndarray]:
    """Host-side CSR (indptr, indices) over the symmetrized adjacency."""
    src = np.asarray(edges.src)[np.asarray(edges.mask)]
    dst = np.asarray(edges.dst)[np.asarray(edges.mask)]
    if edges.directed:
        s, d = src, dst
    else:
        s = np.concatenate([src, dst])
        d = np.concatenate([dst, src])
    order = np.argsort(s, kind="stable")
    s, d = s[order], d[order]
    indptr = np.zeros(edges.n_nodes + 1, np.int64)
    np.add.at(indptr, s + 1, 1)
    indptr = np.cumsum(indptr)
    return indptr, d.astype(np.int32)
