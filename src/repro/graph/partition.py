"""Edge bucketing for the tile-based Pallas degree kernel.

Hadoop computes degrees with a per-pass shuffle; on TPU we do the shuffle
ONCE, statically: endpoints are bucketed by node *tile* (a contiguous range
of ``tile_size`` node ids), each tile's edge list padded to a block multiple,
and every subsequent pass reuses that layout — the per-pass work becomes a
dense one-hot matmul per (tile, edge-block), which is MXU work instead of
data-dependent scatter.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np


def pow2_bucket(x: int, floor: int = 1) -> int:
    """Smallest power of two >= x, floored — THE bucket-size rule of the
    compaction ladder (edge/node buffers, streaming degree vectors, tile
    capacities), shared so every consumer lands on the same shape set."""
    return max(floor, 1 << max(int(x) - 1, 0).bit_length())


def ladder_schedule(m0: int, floor: int = 1, stride: int = 2) -> Tuple[int, ...]:
    """The STATIC geometric bucket schedule of the single-program compaction
    ladder (device-local pow2 re-bucketing): descending per-shard slot
    capacities ``pow2(m0), pow2(m0)/stride, ..., >= pow2(floor)``.

    Rung ``i`` peels with its ``compact_below`` trigger at the NEXT rung's
    capacity, so on trigger exit the survivors provably fit rung ``i+1``.
    That invariant is what makes the whole ladder's shapes computable up
    front from ``(m0, floor, stride)``, letting every rung live inside ONE
    compiled ``shard_map`` program (no host gather/reshard between rungs;
    see ``Problem(compaction='geometric')`` on the mesh substrate).  A
    larger pow2 ``stride`` trades extra scanned slots (a pass lingers on a
    buffer up to ``stride``× its survivors) for fewer compaction
    collectives — total gather traffic is ``m0 · stride/(stride-1)``.
    """
    if stride < 2:
        raise ValueError(f"stride={stride} must be >= 2")
    top = pow2_bucket(max(int(m0), 1))
    fl = min(pow2_bucket(max(int(floor), 1)), top)
    sizes = [top]
    while sizes[-1] // stride >= fl:
        sizes.append(sizes[-1] // stride)
    return tuple(sizes)


@dataclasses.dataclass(frozen=True)
class TiledEdges:
    """Static tiling of (duplicated) edge endpoints.

    For an undirected graph each edge (u, v) contributes twice: once under
    target u and once under target v (deg counts both endpoints).

    Attributes:
      target_local: int32[n_tiles, max_epT] endpoint id within its tile.
      source:       int32[n_tiles, max_epT] the other endpoint's global id.
      edge_index:   int32[n_tiles, max_epT] index into the original edge
                    array (to look up the current pass's alive-weight);
                    -1 for padding slots.
      tile_size:    nodes per tile (node i lives in tile i // tile_size).
      n_nodes:      original node count.
    """

    target_local: np.ndarray
    source: np.ndarray
    edge_index: np.ndarray
    tile_size: int
    n_nodes: int

    @property
    def n_tiles(self) -> int:
        return self.target_local.shape[0]

    @property
    def max_edges_per_tile(self) -> int:
        return self.target_local.shape[1]


def bucket_edges_by_tile(
    src: np.ndarray,
    dst: np.ndarray,
    n_nodes: int,
    tile_size: int = 1024,
    block: int = 256,
    directed: bool = False,
    pow2_pad: bool = False,
) -> TiledEdges:
    """One-time 'shuffle': group endpoint updates by node tile.

    For directed graphs, only dst-targeted updates are produced (out-degree
    is bucketed separately by swapping arguments).

    ``pow2_pad`` rounds the per-tile capacity (``max_epT``) up to the next
    power of two after the block rounding.  The capacity is content-dependent
    (the max in-tile degree), so without it every compaction rung would mint
    a fresh kernel shape; with it the ladder's tilings land on O(log E)
    bucketed shapes that the Solver's program cache reuses across segments
    and graphs.
    """
    src = np.asarray(src, np.int64)
    dst = np.asarray(dst, np.int64)
    e = src.shape[0]
    if directed:
        targets = dst
        sources = src
        eidx = np.arange(e, dtype=np.int64)
    else:
        targets = np.concatenate([dst, src])
        sources = np.concatenate([src, dst])
        eidx = np.concatenate([np.arange(e), np.arange(e)]).astype(np.int64)

    n_tiles = (n_nodes + tile_size - 1) // tile_size
    tile_of = targets // tile_size
    order = np.argsort(tile_of, kind="stable")
    targets, sources, eidx, tile_of = (
        targets[order], sources[order], eidx[order], tile_of[order],
    )
    counts = np.bincount(tile_of, minlength=n_tiles)
    max_epT = int(counts.max(initial=0))
    max_epT = ((max_epT + block - 1) // block) * block
    max_epT = max(max_epT, block)
    if pow2_pad:
        max_epT = pow2_bucket(max_epT)

    tl = np.zeros((n_tiles, max_epT), np.int32)
    sg = np.zeros((n_tiles, max_epT), np.int32)
    ei = np.full((n_tiles, max_epT), -1, np.int32)
    starts = np.concatenate([[0], np.cumsum(counts)])
    for t in range(n_tiles):
        s, c = starts[t], counts[t]
        tl[t, :c] = (targets[s : s + c] - t * tile_size).astype(np.int32)
        sg[t, :c] = sources[s : s + c].astype(np.int32)
        ei[t, :c] = eidx[s : s + c].astype(np.int32)
    return TiledEdges(
        target_local=tl, source=sg, edge_index=ei,
        tile_size=tile_size, n_nodes=n_nodes,
    )
