"""Layered (fanout) neighbor sampler — GraphSAGE's own minibatch scheme.

A REAL sampler over a CSR adjacency, not a stub: uniform with replacement
when deg > fanout would undersample, without replacement otherwise; isolated
nodes self-loop (mask 0).  Deterministic per (seed, step) so the pipeline is
resumable (data/pipeline.py contract), and the hop tensors have the exact
static shapes the ``minibatch_lg`` dry-run cell lowers.

Output layout matches models/gnn/graphsage.forward_sampled:
  hop0 [R], hop1 [R, f1], hop2 [R, f1, f2] (+ masks), labels [R].
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np


@dataclasses.dataclass
class CSRGraph:
    indptr: np.ndarray  # int64[N+1]
    indices: np.ndarray  # int32[nnz]

    @property
    def n_nodes(self) -> int:
        return len(self.indptr) - 1

    @staticmethod
    def from_edges(src: np.ndarray, dst: np.ndarray, n_nodes: int) -> "CSRGraph":
        # symmetrized
        s = np.concatenate([src, dst]).astype(np.int64)
        d = np.concatenate([dst, src]).astype(np.int64)
        order = np.argsort(s, kind="stable")
        s, d = s[order], d[order]
        indptr = np.zeros(n_nodes + 1, np.int64)
        np.add.at(indptr, s + 1, 1)
        return CSRGraph(np.cumsum(indptr), d.astype(np.int32))


def _sample_neighbors(
    g: CSRGraph, nodes: np.ndarray, fanout: int, rng: np.random.Generator
) -> Tuple[np.ndarray, np.ndarray]:
    """(neigh int32[len(nodes), fanout], mask float32[...]) per node."""
    n = len(nodes)
    out = np.zeros((n, fanout), np.int32)
    mask = np.zeros((n, fanout), np.float32)
    starts = g.indptr[nodes]
    degs = g.indptr[nodes + 1] - starts
    for i in range(n):
        deg = int(degs[i])
        if deg == 0:
            out[i, :] = nodes[i]  # self-loop, masked out
            continue
        s = int(starts[i])
        if deg <= fanout:
            idx = rng.permutation(deg)
            take = g.indices[s : s + deg][idx]
            out[i, : len(take)] = take
            mask[i, : len(take)] = 1.0
        else:
            sel = rng.integers(0, deg, fanout)
            out[i] = g.indices[s + sel]
            mask[i] = 1.0
    return out, mask


class LayeredSampler:
    """Resumable minibatch sampler: (seed, step) -> hop block."""

    def __init__(
        self,
        graph: CSRGraph,
        labels: np.ndarray,
        batch_nodes: int,
        fanout: Tuple[int, int],
        seed: int = 0,
    ):
        self.g = graph
        self.labels = labels
        self.batch_nodes = batch_nodes
        self.fanout = fanout
        self.seed = seed
        self.step = 0

    def __iter__(self):
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed << 20) ^ self.step)
        self.step += 1
        f1, f2 = self.fanout
        roots = rng.integers(0, self.g.n_nodes, self.batch_nodes).astype(np.int32)
        hop1, m1 = _sample_neighbors(self.g, roots, f1, rng)
        hop2, m2 = _sample_neighbors(self.g, hop1.reshape(-1), f2, rng)
        return {
            "hop0": roots,
            "hop1": hop1,
            "hop2": hop2.reshape(self.batch_nodes, f1, f2),
            "hop1_mask": m1,
            "hop2_mask": (
                m2.reshape(self.batch_nodes, f1, f2) * m1[:, :, None]
            ).astype(np.float32),
            "labels": self.labels[roots].astype(np.int32),
        }

    def checkpoint_state(self):
        return {"seed": self.seed, "step": self.step}

    def restore(self, state):
        self.seed = int(state["seed"])
        self.step = int(state["step"])
