"""Architecture registry: ``--arch <id>`` resolution for launch/dryrun/train."""

from __future__ import annotations

from typing import Dict

from repro.configs.base import ArchSpec


def _load() -> Dict[str, ArchSpec]:
    from repro.configs import (
        densest_mapreduce,
        egnn_cfg,
        equiformer_v2_cfg,
        graphsage_reddit,
        llama3_2_3b,
        llama4_maverick_400b,
        mace_cfg,
        mixtral_8x7b,
        qwen2_72b,
        starcoder2_7b,
        two_tower_retrieval,
    )

    specs = [
        llama3_2_3b.SPEC,
        starcoder2_7b.SPEC,
        qwen2_72b.SPEC,
        mixtral_8x7b.SPEC,
        llama4_maverick_400b.SPEC,
        mace_cfg.SPEC,
        egnn_cfg.SPEC,
        graphsage_reddit.SPEC,
        equiformer_v2_cfg.SPEC,
        two_tower_retrieval.SPEC,
        densest_mapreduce.SPEC,
    ]
    return {s.arch_id: s for s in specs}


_REGISTRY: Dict[str, ArchSpec] | None = None


def get_arch(arch_id: str) -> ArchSpec:
    global _REGISTRY
    if _REGISTRY is None:
        _REGISTRY = _load()
    if arch_id not in _REGISTRY:
        raise KeyError(
            f"unknown arch {arch_id!r}; available: {sorted(_REGISTRY)}"
        )
    return _REGISTRY[arch_id]


def all_archs() -> Dict[str, ArchSpec]:
    global _REGISTRY
    if _REGISTRY is None:
        _REGISTRY = _load()
    return dict(_REGISTRY)


def assigned_cells(include_densest: bool = False):
    """The 40 assigned (arch x shape) cells (+ optional paper-workload cells)."""
    cells = []
    for arch_id, spec in all_archs().items():
        if spec.family == "densest" and not include_densest:
            continue
        for shape_name in spec.shapes:
            cells.append((arch_id, shape_name))
    return cells
