"""qwen2-72b [arXiv:2407.10671]: 80L d_model=8192 64H (GQA kv=8)
d_ff=29568 vocab=152064, SwiGLU, RMSNorm, QKV bias, RoPE."""

import dataclasses

from repro.configs.base import ArchSpec, lm_shapes
from repro.models.transformer import LM_PARAM_RULES, TransformerConfig

CONFIG = TransformerConfig(
    name="qwen2-72b",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=29568,
    vocab=152064,
    mlp_type="swiglu",
    norm="rmsnorm",
    qkv_bias=True,
    rope_theta=1_000_000.0,
)

REDUCED = dataclasses.replace(
    CONFIG, n_layers=2, d_model=128, n_heads=8, n_kv_heads=2, d_head=16,
    d_ff=384, vocab=512,
)

SPEC = ArchSpec(
    arch_id="qwen2-72b",
    family="lm",
    config=CONFIG,
    reduced_config=REDUCED,
    param_rules=LM_PARAM_RULES,
    shapes=lm_shapes(
        long_skip_reason=(
            "pure full-attention arch: 524k decode excluded; see DESIGN.md"
        )
    ),
    rule_overrides={
        # Perf iteration (EXPERIMENTS.md §Perf): pure FSDP over all 256 chips
        # for training — collective traffic becomes weight-proportional
        # (~0.6 TB/dev) instead of activation-proportional (~4 TB/dev at
        # batch 1M tokens). TP layouts remain for prefill/decode kinds.
        "train": {
            "batch": ("data", "model"), "fsdp": ("data", "model"),
            "tp": None, "heads4": None, "kv_heads": None, "heads": None,
            "mlp": None, "vocab": None, "embed": None, "seq": None,
        },
    },
    notes="64 q heads / 16 = 4 per shard; kv=8 heads sharded on flattened dim",
)
