"""egnn [arXiv:2102.09844]: 4L d_hidden=64, E(n)-equivariant."""

import dataclasses

from repro.configs.base import ArchSpec, gnn_shapes
from repro.models.gnn.egnn import EGNN_PARAM_RULES, EGNNConfig

CONFIG = EGNNConfig(n_layers=4, d_hidden=64)
REDUCED = dataclasses.replace(CONFIG, n_layers=2, d_hidden=16)

SPEC = ArchSpec(
    arch_id="egnn",
    family="gnn",
    config=CONFIG,
    reduced_config=REDUCED,
    param_rules=EGNN_PARAM_RULES,
    shapes=gnn_shapes({"molecule": 16}),
    notes="exactly E(n)-equivariant; property-tested under random rotations",
)
