"""two-tower-retrieval [RecSys'19 YouTube; unverified]: embed_dim=256,
tower MLP 1024-512-256, dot interaction, in-batch sampled softmax w/ logQ."""

import dataclasses

from repro.configs.base import ArchSpec, recsys_shapes
from repro.models.recsys import TWO_TOWER_PARAM_RULES, TwoTowerConfig

CONFIG = TwoTowerConfig(
    n_users=8_388_608, n_items=2_097_152, embed_dim=256,
    tower_dims=(1024, 512, 256), hist_len=32,
)
REDUCED = dataclasses.replace(
    CONFIG, n_users=4096, n_items=2048, embed_dim=32, tower_dims=(64, 32), hist_len=8
)

SPEC = ArchSpec(
    arch_id="two-tower-retrieval",
    family="recsys",
    config=CONFIG,
    reduced_config=REDUCED,
    param_rules=TWO_TOWER_PARAM_RULES,
    shapes=recsys_shapes(),
    rule_overrides={
        # retrieval_cand: batch=1 -> candidates carry the parallelism.
        "retrieval": {"batch": None, "vocab": ("data", "model")},
    },
    notes="column-sharded 8.4M/2.1M-row tables; EmbeddingBag via take+segment_sum",
)
