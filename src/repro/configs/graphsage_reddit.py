"""graphsage-reddit [arXiv:1706.02216]: 2L d_hidden=128 mean aggregator,
sample sizes 25-10 (the assigned minibatch_lg shape samples 15-10)."""

import dataclasses

from repro.configs.base import ArchSpec, gnn_shapes
from repro.models.gnn.graphsage import SAGE_PARAM_RULES, SAGEConfig

CONFIG = SAGEConfig(n_layers=2, d_hidden=128, fanouts=(15, 10))
REDUCED = dataclasses.replace(CONFIG, d_hidden=32)

SPEC = ArchSpec(
    arch_id="graphsage-reddit",
    family="gnn",
    config=CONFIG,
    reduced_config=REDUCED,
    param_rules=SAGE_PARAM_RULES,
    shapes=gnn_shapes({"molecule": 16}),
    notes="minibatch_lg uses the real layered neighbor sampler",
)
