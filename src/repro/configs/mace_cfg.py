"""mace [arXiv:2206.07697]: 2L d_hidden=128 l_max=2 correlation=3 n_rbf=8,
E(3)-equivariant ACE message passing (see DESIGN.md for the faithful
simplifications of the product basis)."""

import dataclasses

from repro.configs.base import ArchSpec, gnn_shapes
from repro.models.gnn.mace import MACE_PARAM_RULES, MACEConfig

CONFIG = MACEConfig(n_layers=2, d_hidden=128, l_max=2, correlation=3, n_rbf=8)
REDUCED = dataclasses.replace(CONFIG, d_hidden=32, n_rbf=4)

SPEC = ArchSpec(
    arch_id="mace",
    family="gnn",
    config=CONFIG,
    reduced_config=REDUCED,
    param_rules=MACE_PARAM_RULES,
    shapes=gnn_shapes({"molecule": 16}),
    notes="graph-dataset shapes use synthesized positions + node-class head",
)
