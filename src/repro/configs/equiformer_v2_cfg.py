"""equiformer-v2 [arXiv:2306.12059]: 12L d_hidden=128 l_max=6 m_max=2 8H,
eSCN-style SO(2) convolutions (see DESIGN.md for the l>=2 frame-alignment
deviation)."""

import dataclasses

from repro.configs.base import ArchSpec, gnn_shapes
from repro.models.gnn.equiformer_v2 import EQ2_PARAM_RULES, EquiformerV2Config

CONFIG = EquiformerV2Config(n_layers=12, d_hidden=128, l_max=6, m_max=2, n_heads=8)
REDUCED = dataclasses.replace(CONFIG, n_layers=2, d_hidden=32, l_max=3, n_heads=4)

SPEC = ArchSpec(
    arch_id="equiformer-v2",
    family="gnn",
    config=CONFIG,
    reduced_config=REDUCED,
    param_rules=EQ2_PARAM_RULES,
    shapes=gnn_shapes({"molecule": 16}),
    notes="per-m SO(2) matmuls restricted to |m|<=2; 49 spherical components",
)
