"""llama3.2-3b [hf:meta-llama/Llama-3.2-1B-family; unverified]:
28L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=128256, SwiGLU, RoPE,
tied embeddings."""

import dataclasses

from repro.configs.base import ArchSpec, lm_shapes
from repro.models.transformer import LM_PARAM_RULES, TransformerConfig

CONFIG = TransformerConfig(
    name="llama3.2-3b",
    n_layers=28,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_head=128,
    d_ff=8192,
    vocab=128256,
    mlp_type="swiglu",
    norm="rmsnorm",
    rope_theta=500_000.0,
    tie_embeddings=True,
)

REDUCED = dataclasses.replace(
    CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_head=32,
    d_ff=256, vocab=512,
)

SPEC = ArchSpec(
    arch_id="llama3.2-3b",
    family="lm",
    config=CONFIG,
    reduced_config=REDUCED,
    param_rules=LM_PARAM_RULES,
    shapes=lm_shapes(
        long_skip_reason=(
            "pure full-attention arch: 524k-token KV with quadratic attention "
            "is excluded per assignment (see DESIGN.md long_500k skips)"
        )
    ),
    rule_overrides={
        # Perf iteration (EXPERIMENTS.md §Perf): pure FSDP over all 256 chips
        # for training — collective traffic becomes weight-proportional
        # (~0.6 TB/dev) instead of activation-proportional (~4 TB/dev at
        # batch 1M tokens). TP layouts remain for prefill/decode kinds.
        "train": {
            "batch": ("data", "model"), "fsdp": ("data", "model"),
            "tp": None, "heads4": None, "kv_heads": None, "heads": None,
            "mlp": None, "vocab": None, "embed": None, "seq": None,
        },
    },
    # flat d_q=3072 divides 16; 4D attention shards unevenly on heads4
    # (24 -> pad 32, 1.33x) — far cheaper than replicated attention (16x).
    notes="tied embeddings; GQA 24/8; uneven heads4 sharding (24 -> 32 pad)",
)
