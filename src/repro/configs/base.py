"""ArchSpec: one record per assigned architecture — model config, reduced
smoke config, sharding rules, and the arch's own input-shape set."""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Mapping, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One input-shape cell for an architecture."""

    name: str
    kind: str  # train | prefill | decode | decode_long | full_train |
    #            sampled_train | molecule_train | serve | retrieval
    params: Mapping[str, Any]
    skip_reason: Optional[str] = None  # non-None => documented skip


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str  # lm | gnn | recsys | densest
    config: Any
    reduced_config: Any
    param_rules: Sequence[Tuple[str, Tuple[Optional[str], ...]]]
    shapes: Mapping[str, ShapeSpec]
    # Extra logical-axis rules overriding the family defaults, per shape kind.
    rule_overrides: Mapping[str, Mapping[str, Any]] = dataclasses.field(
        default_factory=dict
    )
    notes: str = ""

    def shape(self, name: str) -> ShapeSpec:
        return self.shapes[name]

    def runnable_shapes(self):
        return {k: v for k, v in self.shapes.items() if v.skip_reason is None}


# ---- shared shape sets ------------------------------------------------------


def lm_shapes(long_skip_reason: Optional[str]) -> Dict[str, ShapeSpec]:
    return {
        "train_4k": ShapeSpec("train_4k", "train", dict(seq_len=4096, global_batch=256)),
        "prefill_32k": ShapeSpec(
            "prefill_32k", "prefill", dict(seq_len=32768, global_batch=32)
        ),
        "decode_32k": ShapeSpec(
            "decode_32k", "decode", dict(seq_len=32768, global_batch=128)
        ),
        "long_500k": ShapeSpec(
            "long_500k",
            "decode_long",
            dict(seq_len=524288, global_batch=1),
            skip_reason=long_skip_reason,
        ),
    }


def gnn_shapes(d_feat_defaults: Mapping[str, int]) -> Dict[str, ShapeSpec]:
    return {
        "full_graph_sm": ShapeSpec(
            "full_graph_sm",
            "full_train",
            dict(n_nodes=2708, n_edges=10556, d_feat=1433, n_classes=7),
        ),
        "minibatch_lg": ShapeSpec(
            "minibatch_lg",
            "sampled_train",
            dict(
                n_nodes=232_965,
                n_edges=114_615_892,
                batch_nodes=1024,
                fanout=(15, 10),
                d_feat=602,
                n_classes=41,
            ),
        ),
        "ogb_products": ShapeSpec(
            "ogb_products",
            "full_train",
            dict(n_nodes=2_449_029, n_edges=61_859_140, d_feat=100, n_classes=47),
        ),
        "molecule": ShapeSpec(
            "molecule",
            "molecule_train",
            dict(n_nodes=30, n_edges=64, batch=128, d_feat=d_feat_defaults.get("molecule", 16)),
        ),
    }


def recsys_shapes() -> Dict[str, ShapeSpec]:
    return {
        "train_batch": ShapeSpec("train_batch", "train", dict(batch=65_536)),
        "serve_p99": ShapeSpec("serve_p99", "serve", dict(batch=512)),
        "serve_bulk": ShapeSpec("serve_bulk", "serve", dict(batch=262_144)),
        "retrieval_cand": ShapeSpec(
            "retrieval_cand", "retrieval", dict(batch=1, n_candidates=1_000_000)
        ),
    }
