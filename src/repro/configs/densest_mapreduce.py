"""The paper's own workload as an 'architecture': distributed Algorithm 1
over edge-sharded graphs at the paper's experimental scales (Table 1).

The dry-run cell lowers ONE full peel (the entire O(log_{1+eps} n)-pass
while_loop) with edges sharded over every mesh axis and O(n) replicated node
state — proving the MapReduce-analogue distribution is coherent at
TWITTER/IM scale."""

import dataclasses
from typing import Mapping

from repro.configs.base import ArchSpec, ShapeSpec


@dataclasses.dataclass(frozen=True)
class DensestConfig:
    name: str = "densest-mapreduce"
    eps: float = 0.5
    max_passes: int = 64


CONFIG = DensestConfig()
REDUCED = dataclasses.replace(CONFIG, max_passes=16)

SHAPES: Mapping[str, ShapeSpec] = {
    # FLICKR-scale (Table 1): 976K nodes, 7.6M edges.
    "flickr_sm": ShapeSpec(
        "flickr_sm", "peel", dict(n_nodes=976_000, n_edges=7_600_000)
    ),
    # LIVEJOURNAL-scale: 4.84M nodes, 68.9M edges.
    "livejournal_md": ShapeSpec(
        "livejournal_md", "peel", dict(n_nodes=4_840_000, n_edges=68_900_000)
    ),
    # TWITTER-scale: 50.7M nodes, 2.7B edges.
    "twitter_lg": ShapeSpec(
        "twitter_lg", "peel", dict(n_nodes=50_700_000, n_edges=2_700_000_000)
    ),
    # IM-scale: 645M nodes, 6.1B edges — Count-Sketch node state (t=5, b=2^17)
    # since the exact O(n) degree vector would be 2.6 GB replicated.
    "im_xl": ShapeSpec(
        "im_xl",
        "peel_sketched",
        dict(n_nodes=645_000_000, n_edges=6_100_000_000, t=5, b=1 << 17),
    ),
}

SPEC = ArchSpec(
    arch_id="densest-mapreduce",
    family="densest",
    config=CONFIG,
    reduced_config=REDUCED,
    param_rules=[],
    shapes=SHAPES,
    notes="the paper's own workload; edges sharded over all mesh axes",
)
