"""starcoder2-7b [arXiv:2402.19173]: 32L d_model=4608 36H (GQA kv=4)
d_ff=18432 vocab=49152, GELU MLP, LayerNorm, qkv-bias, RoPE."""

import dataclasses

from repro.configs.base import ArchSpec, lm_shapes
from repro.models.transformer import LM_PARAM_RULES, TransformerConfig

CONFIG = TransformerConfig(
    name="starcoder2-7b",
    n_layers=32,
    d_model=4608,
    n_heads=36,
    n_kv_heads=4,
    d_head=128,
    d_ff=18432,
    vocab=49152,
    mlp_type="gelu",
    norm="layernorm",
    qkv_bias=True,
    rope_theta=100_000.0,
)

REDUCED = dataclasses.replace(
    CONFIG, n_layers=2, d_model=144, n_heads=6, n_kv_heads=2, d_head=24,
    d_ff=288, vocab=512,
)

SPEC = ArchSpec(
    arch_id="starcoder2-7b",
    family="lm",
    config=CONFIG,
    reduced_config=REDUCED,
    param_rules=LM_PARAM_RULES,
    shapes=lm_shapes(
        long_skip_reason=(
            "pure full-attention arch (assigned config): 524k decode excluded; "
            "see DESIGN.md long_500k skips"
        )
    ),
    rule_overrides={
        # Perf iteration (EXPERIMENTS.md §Perf): pure FSDP over all 256 chips
        # for training — collective traffic becomes weight-proportional
        # (~0.6 TB/dev) instead of activation-proportional (~4 TB/dev at
        # batch 1M tokens). TP layouts remain for prefill/decode kinds.
        "train": {
            "batch": ("data", "model"), "fsdp": ("data", "model"),
            "tp": None, "heads4": None, "kv_heads": None, "heads": None,
            "mlp": None, "vocab": None, "embed": None, "seq": None,
        },
    },
    # flat d_q=4608 and d_kv=512 both divide 16; 4D heads shard unevenly
    # (36 -> pad 48) via the heads4 axis inside attention.
    notes="GELU MLP + LayerNorm + qkv bias per StarCoder2",
)
