"""llama4-maverick-400b-a17b [hf:meta-llama/Llama-4 family; unverified]:
48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 128 experts
top-1 (17B active).  Modality frontend (early fusion) is a STUB per the
assignment: input_specs provide token/patch embeddings directly."""

import dataclasses

from repro.configs.base import ArchSpec, lm_shapes
from repro.models.moe import MoEConfig
from repro.models.transformer import LM_PARAM_RULES, TransformerConfig

CONFIG = TransformerConfig(
    name="llama4-maverick-400b-a17b",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_head=128,
    d_ff=8192,
    vocab=202048,
    mlp_type="swiglu",
    norm="rmsnorm",
    rope_theta=500_000.0,
    moe=MoEConfig(n_experts=128, top_k=1, capacity_factor=1.25, group_size=1024),
)

REDUCED = dataclasses.replace(
    CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_head=32,
    d_ff=128, vocab=512,
    moe=MoEConfig(n_experts=8, top_k=1, capacity_factor=1.5, group_size=64),
)

SPEC = ArchSpec(
    arch_id="llama4-maverick-400b-a17b",
    family="lm",
    config=CONFIG,
    reduced_config=REDUCED,
    param_rules=LM_PARAM_RULES,
    shapes=lm_shapes(
        long_skip_reason=(
            "assigned config is full-attention (iRoPE chunked-attention "
            "variant not part of the assigned spec): 524k decode excluded; "
            "see DESIGN.md"
        )
    ),
    rule_overrides={
        # 128 experts over the data axis (128 / 16 = 8): expert parallelism;
        # token->expert dispatch lowers to an all-to-all.
        "*": {"expert": ("data",)},  # 40 heads -> pad 48 on heads4
    },
    notes="EP over data axis (128 experts), int8 Adam moments to fit HBM",
)
