"""mixtral-8x7b [arXiv:2401.04088]: 32L d_model=4096 32H (GQA kv=8)
d_ff=14336 vocab=32000, MoE 8 experts top-2, sliding-window attention 4096.

SWA makes the long_500k decode cell O(window): the rolling KV cache holds
4096 slots regardless of the 524k context."""

import dataclasses

from repro.configs.base import ArchSpec, lm_shapes
from repro.models.moe import MoEConfig
from repro.models.transformer import LM_PARAM_RULES, TransformerConfig

CONFIG = TransformerConfig(
    name="mixtral-8x7b",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=14336,
    vocab=32000,
    mlp_type="swiglu",
    norm="rmsnorm",
    rope_theta=1_000_000.0,
    window=4096,
    moe=MoEConfig(n_experts=8, top_k=2, capacity_factor=1.25, group_size=1024),
)

REDUCED = dataclasses.replace(
    CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_head=32,
    d_ff=256, vocab=512, window=64,
    moe=MoEConfig(n_experts=4, top_k=2, capacity_factor=1.25, group_size=64),
)

SPEC = ArchSpec(
    arch_id="mixtral-8x7b",
    family="lm",
    config=CONFIG,
    reduced_config=REDUCED,
    param_rules=LM_PARAM_RULES,
    shapes=lm_shapes(long_skip_reason=None),  # SWA => sub-quadratic: runs
    rule_overrides={
        # 8 experts % 16 devices != 0 -> experts replicated, expert FFN is TP
        # over 'model' (d_ff 14336 / 16 = 896).
        "*": {"expert": None},
        # Perf iteration (EXPERIMENTS.md §Perf): FSDP-256 for training — at
        # 47B params the weight gathers (~0.6 TB/dev) still beat TP's
        # activation collectives (~2.4 TB/dev) at the 1M-token batch.
        # (Refuted for llama4's 774B params, which stays EP: weight traffic
        # dominates there.)
        "train": {
            "batch": ("data", "model"), "fsdp": ("data", "model"),
            "tp": None, "heads4": None, "kv_heads": None, "heads": None,
            "mlp": None, "vocab": None, "embed": None, "seq": None,
            "expert": None, "expert_batch": None,
        },
        # batch=1 long-decode: no data parallelism available; spread TP over
        # both axes (d_ff 14336 % 256 == 0, vocab 32000 % 256 == 0).
        "decode_long": {
            "expert": None, "batch": None, "fsdp": None,
            "tp": ("data", "model"), "kv_seq": ("model",),
            "heads": None, "kv_heads": None, "mlp": ("data", "model"),
            "vocab": ("data", "model"),
        },
    },
    notes="SWA 4096 rolling cache; MoE 8e top-2 with TP-sharded experts",
)
