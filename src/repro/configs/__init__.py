from repro.configs.base import ArchSpec, ShapeSpec
from repro.configs.registry import all_archs, assigned_cells, get_arch

__all__ = ["ArchSpec", "ShapeSpec", "all_archs", "assigned_cells", "get_arch"]
