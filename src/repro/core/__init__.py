"""Core library: the paper's densest-subgraph algorithms.

Public API:
  densest_subgraph                 Algorithm 1 (undirected, (2+2eps)-approx)
  densest_subgraph_at_least_k      Algorithm 2 (size >= k, (3+3eps)-approx)
  densest_subgraph_directed        Algorithm 3 (directed, per-c)
  densest_directed_search          Algorithm 3 + geometric c grid
  densest_subgraph_sketched        Algorithm 1 with Count-Sketch degrees
  densest_subgraph_distributed     MapReduce analogue on a device mesh
  StreamingDensest                 semi-streaming driver w/ checkpoint+stragglers
  densest_subgraph_exact           Goldberg max-flow exact oracle
  charikar_greedy                  node-at-a-time 2-approx baseline [10]
"""

from repro.core.charikar import charikar_greedy
from repro.core.countsketch import (
    densest_subgraph_sketched,
    make_sketch_params,
    query_degrees,
    sketch_degrees_from_edges,
    sketched_degree_fn,
)
from repro.core.density import density_of, max_passes_bound, undirected_stats
from repro.core.exact import (
    densest_directed_brute,
    densest_subgraph_brute,
    densest_subgraph_exact,
)
from repro.core.mapreduce import (
    densest_subgraph_distributed,
    make_distributed_directed_peel,
    make_distributed_peel,
    shard_edges,
)
from repro.core.peel import PeelResult, densest_subgraph, densest_subgraph_sets
from repro.core.peel_directed import (
    c_grid,
    densest_directed_search,
    densest_directed_search_vmapped,
    densest_subgraph_directed,
)
from repro.core.peel_topk import densest_subgraph_at_least_k
from repro.core.streaming import StreamingDensest, chunked_from_arrays

__all__ = [
    "PeelResult",
    "StreamingDensest",
    "c_grid",
    "charikar_greedy",
    "chunked_from_arrays",
    "densest_directed_brute",
    "densest_directed_search",
    "densest_directed_search_vmapped",
    "densest_subgraph",
    "densest_subgraph_at_least_k",
    "densest_subgraph_brute",
    "densest_subgraph_directed",
    "densest_subgraph_distributed",
    "densest_subgraph_exact",
    "densest_subgraph_sets",
    "densest_subgraph_sketched",
    "density_of",
    "make_distributed_directed_peel",
    "make_distributed_peel",
    "make_sketch_params",
    "query_degrees",
    "shard_edges",
    "sketch_degrees_from_edges",
    "sketched_degree_fn",
    "undirected_stats",
]
