"""Core library: the paper's densest-subgraph algorithms.

One front door (core/api.py): declare a :class:`Problem` (objective × eps ×
backend × substrate), call :func:`solve` / :func:`solve_batch`, get a
:class:`DenseSubgraphResult`.  The :class:`Solver` memoizes compiled
programs so production request rates never retrace; ``solve_batch`` runs
multi-eps / multi-c / stacked-graph sweeps as one XLA program.

    from repro.core import Problem, solve
    res = solve(edges, Problem.undirected(eps=0.5))
    res = solve(edges, Problem.directed())            # c-grid search
    res = solve(edges, Problem.at_least_k(k=100))

All peel variants are one engine (core/engine.py): a single pass body
parameterized by RemovalPolicy × DegreeBackend, launched on a jit, host
streaming, or shard_map substrate.  The historical entry points below are
thin delegations through the same lowering and stay bit-identical:

  densest_subgraph                 Algorithm 1 (undirected, (2+2eps)-approx)
  densest_subgraph_at_least_k      Algorithm 2 (size >= k, (3+3eps)-approx)
  densest_subgraph_directed        Algorithm 3 (directed, per-c)
  densest_directed_search          Algorithm 3 + geometric c grid
  densest_subgraph_sketched        Algorithm 1 with Count-Sketch degrees
  densest_subgraph_distributed     MapReduce analogue on a device mesh
  StreamingDensest                 semi-streaming driver w/ checkpoint+stragglers
  TurnstileDensest/TurnstileSketch ℓ0-sketch dynamic-stream runtime (±edges)
  LocalExplorer                    Andersen pruned-frontier exploration
                                   (substrate='local', per-seed queries)
  densest_subgraph_exact           Goldberg max-flow exact oracle
  charikar_greedy                  node-at-a-time 2-approx baseline [10]
  run_peel / PeelOutcome           the engine itself (policies × backends)
"""

from repro.core.api import (
    DenseSubgraphResult,
    Problem,
    Provenance,
    Solver,
    default_solver,
    deprecated_alias_getattr,
    solve,
    solve_batch,
    stack_graphs,
)
from repro.core.charikar import charikar_greedy
from repro.core.countsketch import (
    SketchBackend,
    densest_subgraph_sketched,
    make_sketch_params,
    query_degrees,
    sketch_degrees_from_edges,
    sketch_endpoint_counters,
    sketched_degree_fn,
)
from repro.core.density import density_of, max_passes_bound, undirected_stats
from repro.core.engine import (
    AtLeastKFraction,
    DirectedST,
    ExactBackend,
    FnBackend,
    MeshSegmentSumBackend,
    PeelOutcome,
    PeelState,
    UndirectedThreshold,
    removal_threshold,
    run_peel,
    segment_degree_count,
    undirected_pass_step,
)
from repro.core.exact import (
    densest_directed_brute,
    densest_subgraph_brute,
    densest_subgraph_exact,
)
from repro.core.local import LocalExploration, LocalExplorer
from repro.core.mapreduce import (
    densest_subgraph_distributed,
    make_distributed_directed_peel,
    make_distributed_peel,
    make_distributed_peel_compacted,
    make_distributed_peel_ladder,
    shard_edges,
)
from repro.core.peel import densest_subgraph, densest_subgraph_sets
from repro.core.peel_directed import (
    c_grid,
    densest_directed_search,
    densest_directed_search_vmapped,
    densest_subgraph_directed,
)
from repro.core.peel_topk import densest_subgraph_at_least_k
from repro.core.streaming import (
    StreamingDensest,
    chunked_from_arrays,
    chunked_from_memmap,
)
from repro.core.turnstile import TurnstileDensest, TurnstileSketch

# Deprecated result-type aliases (kept importable; warn on access).
__getattr__ = deprecated_alias_getattr(
    __name__,
    {
        "PeelResult": DenseSubgraphResult,
        "PeelTopKResult": DenseSubgraphResult,
        "DirectedPeelResult": DenseSubgraphResult,
    },
)


__all__ = [
    "AtLeastKFraction",
    "DenseSubgraphResult",
    "DirectedST",
    "ExactBackend",
    "FnBackend",
    "LocalExploration",
    "LocalExplorer",
    "MeshSegmentSumBackend",
    "PeelOutcome",
    "PeelResult",  # deprecated alias of DenseSubgraphResult
    "PeelState",
    "PeelTopKResult",  # deprecated alias of DenseSubgraphResult
    "Problem",
    "Provenance",
    "SketchBackend",
    "Solver",
    "StreamingDensest",
    "TurnstileDensest",
    "TurnstileSketch",
    "UndirectedThreshold",
    "c_grid",
    "charikar_greedy",
    "chunked_from_arrays",
    "chunked_from_memmap",
    "default_solver",
    "densest_directed_brute",
    "densest_directed_search",
    "densest_directed_search_vmapped",
    "densest_subgraph",
    "densest_subgraph_at_least_k",
    "densest_subgraph_brute",
    "densest_subgraph_directed",
    "densest_subgraph_distributed",
    "densest_subgraph_exact",
    "densest_subgraph_sets",
    "densest_subgraph_sketched",
    "density_of",
    "make_distributed_directed_peel",
    "make_distributed_peel",
    "make_distributed_peel_compacted",
    "make_distributed_peel_ladder",
    "make_sketch_params",
    "max_passes_bound",
    "query_degrees",
    "removal_threshold",
    "run_peel",
    "segment_degree_count",
    "shard_edges",
    "sketch_degrees_from_edges",
    "sketch_endpoint_counters",
    "sketched_degree_fn",
    "solve",
    "solve_batch",
    "stack_graphs",
    "undirected_pass_step",
    "undirected_stats",
]
