"""Semi-streaming driver for Algorithm 1 (the paper's streaming model).

The graph's edge list lives outside accelerator memory (numpy arrays, memmap
or any chunk iterator); only O(n) node state (alive bitmap, degree vector,
best set) is held.  Each pass streams the edges chunk by chunk, accumulating
degrees with a jitted kernel — exactly the paper's "store and update the
current node degrees" loop.

Production concerns implemented here (this is the fault-tolerance layer for
the paper's own workload):
  * per-pass atomic checkpointing of the O(n) state -> restart resumes
    mid-algorithm after a crash;
  * straggler mitigation: chunks are dispatched to a worker pool and the
    slowest tail is speculatively re-issued (Hadoop-style backup tasks);
    results are idempotent so first-completion wins;
  * chunk results are pure reductions, so retries/duplicates are safe.
"""

from __future__ import annotations

import json
import os
import tempfile
import threading
import time
from concurrent.futures import FIRST_COMPLETED, ThreadPoolExecutor, wait
from dataclasses import dataclass, field
from typing import Callable, Iterable, Iterator, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Chunk = Tuple[np.ndarray, np.ndarray, np.ndarray]  # (src, dst, weight)


@jax.jit
def _chunk_stats(src, dst, w, alive):
    """Partial (degree vector, total weight, alive edge count) for one edge
    chunk.

    Accumulates in float32 regardless of the incoming weight dtype so the
    chunk reduction is stable for low-precision edge streams (bf16/f16
    weights) and identical across chunkings.  The degree count itself is
    the engine's :func:`~repro.core.engine.segment_degree_count` (§5.2's
    reduce-side count exists once); the alive edge count feeds the
    geometric compaction trigger."""
    from repro.core.engine import segment_degree_count

    ok = alive[src] & alive[dst]
    w_alive = jnp.where(ok, w.astype(jnp.float32), jnp.float32(0.0))
    deg, total = segment_degree_count(src, dst, w_alive, alive.shape[0])
    return deg, total, jnp.sum(ok.astype(jnp.int32))


@dataclass
class StreamState:
    alive: np.ndarray
    best_alive: np.ndarray
    best_rho: float
    pass_idx: int
    history: list = field(default_factory=list)  # (n, m, rho) per pass


class StreamingDensest:
    """Multi-pass semi-streaming Algorithm 1 with checkpoint/restart."""

    def __init__(
        self,
        chunk_stream: Callable[[], Iterator[Chunk]],
        n_nodes: int,
        eps: float = 0.5,
        checkpoint_dir: Optional[str] = None,
        n_workers: int = 4,
        speculative: bool = True,
        speculate_tail_frac: float = 0.2,
        compaction: str = "off",
    ):
        if compaction not in ("off", "geometric"):
            raise ValueError(
                f"compaction={compaction!r} not in ('off', 'geometric')"
            )
        self.chunk_stream = chunk_stream
        self.n_nodes = n_nodes
        self.eps = eps
        self.checkpoint_dir = checkpoint_dir
        self.n_workers = n_workers
        self.speculative = speculative
        self.speculate_tail_frac = speculate_tail_frac
        self.compaction = compaction
        self.chunk_timings: list[float] = []
        self.speculative_reissues = 0
        self.compactions = 0  # geometric: stream rebuilds performed

    # ----- checkpointing -------------------------------------------------
    def _ckpt_path(self) -> Optional[str]:
        if self.checkpoint_dir is None:
            return None
        return os.path.join(self.checkpoint_dir, "stream_state.npz")

    def _save(self, st: StreamState) -> None:
        """Atomic checkpoint write: savez to a temp file, fsync, then
        ``os.replace`` — a crash at any point leaves either the old or the
        new checkpoint, never a torn one.  The temp file is removed on
        failure as well."""
        path = self._ckpt_path()
        if path is None:
            return
        os.makedirs(self.checkpoint_dir, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=self.checkpoint_dir, suffix=".npz.tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                np.savez(
                    f,
                    alive=st.alive,
                    best_alive=st.best_alive,
                    best_rho=np.float64(st.best_rho),
                    pass_idx=np.int64(st.pass_idx),
                    history=np.asarray(st.history, np.float64).reshape(-1, 3),
                )
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    def _load(self) -> Optional[StreamState]:
        path = self._ckpt_path()
        if path is None or not os.path.exists(path):
            return None
        z = np.load(path)
        return StreamState(
            alive=z["alive"],
            best_alive=z["best_alive"],
            best_rho=float(z["best_rho"]),
            pass_idx=int(z["pass_idx"]),
            history=[tuple(r) for r in z["history"]],
        )

    # ----- one streaming pass --------------------------------------------
    def _pass_stats(
        self,
        alive_np: np.ndarray,
        stream: Optional[Callable[[], Iterator[Chunk]]] = None,
    ) -> Tuple[np.ndarray, float, int, int]:
        """Streams all chunks once; returns (degree vector, total weight,
        alive edge count, edge slots streamed).

        Chunks are processed by a worker pool; the slowest tail is
        speculatively re-issued.  Reductions are order-independent.
        ``stream`` defaults to the constructor's chunk stream (the
        compaction ladder substitutes its rebuilt, smaller stream).
        """
        alive = jnp.asarray(alive_np)
        chunks = list((stream or self.chunk_stream)())
        deg = np.zeros(alive_np.shape[0], np.float32)
        total = 0.0
        n_ok = 0
        n_slots = sum(len(c[0]) for c in chunks)
        done: dict[int, Tuple[np.ndarray, float, int]] = {}
        lock = threading.Lock()

        def work(idx: int) -> int:
            t0 = time.perf_counter()
            s, d, w = chunks[idx]
            dd, tt, cc = _chunk_stats(
                jnp.asarray(s), jnp.asarray(d), jnp.asarray(w), alive
            )
            out = (np.asarray(dd), float(tt), int(cc))
            with lock:
                if idx not in done:  # first completion wins (idempotent)
                    done[idx] = out
                self.chunk_timings.append(time.perf_counter() - t0)
            return idx

        with ThreadPoolExecutor(max_workers=self.n_workers) as ex:
            futs = {ex.submit(work, i): i for i in range(len(chunks))}
            pending = set(futs)
            speculated = False
            while pending:
                fin, pending = wait(pending, return_when=FIRST_COMPLETED)
                del fin
                if (
                    self.speculative
                    and not speculated
                    and len(done) >= (1 - self.speculate_tail_frac) * len(chunks)
                    and pending
                ):
                    # Back-up tasks for the straggler tail.
                    missing = [i for i in range(len(chunks)) if i not in done]
                    for i in missing:
                        pending.add(ex.submit(work, i))
                        self.speculative_reissues += 1
                    speculated = True

        for idx in range(len(chunks)):
            dd, tt, cc = done[idx]
            deg += dd
            total += tt
            n_ok += cc
        return deg, total, n_ok, n_slots

    # ----- geometric compaction (amortized-O(m) streaming) ----------------
    def _compact_stream(
        self,
        stream: Callable[[], Iterator[Chunk]],
        alive_c: np.ndarray,
        id_map: np.ndarray,
    ):
        """Rebuilds the chunk stream over surviving edges with survivors
        renumbered into a dense (power-of-two padded) node range — one extra
        streaming pass, amortized away by the halved stream it produces.
        Returns (stream, alive_c, id_map, n_slots).

        Memory note: the rebuilt stream keeps the surviving chunks resident
        in host RAM (never concatenated — per-chunk arrays only, so there is
        no 2x materialization spike).  The first trigger fires at under half
        the stream, so residency is < m/2 edges and halves per rung; for
        streams whose SURVIVORS cannot fit in memory, keep
        ``compaction='off'`` (a disk-spill rebuild is a ROADMAP item)."""
        from repro.graph.partition import pow2_bucket

        surv = alive_c[: len(id_map)]
        n_alive = int(surv.sum())
        relabel = (np.cumsum(alive_c) - 1).astype(np.int64)
        # Pow2-padded node space (with >= 1 permanently-dead pad node for
        # edge padding below): the jitted chunk kernel sees O(log n)
        # distinct degree-vector shapes across the whole ladder.
        n_pad = pow2_bucket(n_alive + 1, floor=64)
        pad_id = np.int32(n_pad - 1)  # never alive -> pad edges never count
        chunks = []
        n_edges = 0
        for s, d, w in stream():
            ok = alive_c[s] & alive_c[d]
            kept = int(ok.sum())
            if kept == 0:
                continue
            # Per-chunk pow2 length so surviving (ragged) chunks land on a
            # bounded set of shapes instead of one compile per chunk.
            cap = pow2_bucket(kept, floor=256)
            cs = np.full(cap, pad_id, np.int32)
            cd = np.full(cap, pad_id, np.int32)
            cw = np.zeros(cap, w.dtype)
            cs[:kept] = relabel[s[ok]]
            cd[:kept] = relabel[d[ok]]
            cw[:kept] = w[ok]
            chunks.append((cs, cd, cw))
            n_edges += kept
        new_alive = np.arange(n_pad) < n_alive
        new_id_map = id_map[surv]
        self.compactions += 1

        def gen() -> Iterator[Chunk]:
            yield from chunks

        return gen, new_alive, new_id_map, n_edges

    # ----- the algorithm ---------------------------------------------------
    def run(self, max_passes: Optional[int] = None, resume: bool = True) -> StreamState:
        st = self._load() if resume else None
        if st is None:
            st = StreamState(
                alive=np.ones(self.n_nodes, bool),
                best_alive=np.ones(self.n_nodes, bool),
                best_rho=-np.inf,
                pass_idx=0,
            )
        from repro.core.density import max_passes_bound

        if max_passes is None:
            max_passes = max_passes_bound(self.n_nodes, self.eps)

        from repro.core.engine import undirected_pass_step

        # Compact view of the live subproblem: ``id_map`` maps compact node
        # ids back to original ids (identity until the first compaction);
        # the FULL-space StreamState is maintained throughout, so the
        # checkpoint format and all outputs are unchanged.
        stream = self.chunk_stream
        id_map = np.arange(self.n_nodes, dtype=np.int64)
        alive_c = st.alive.copy()
        n_slots: Optional[int] = None

        while st.alive.any() and st.pass_idx < max_passes:
            deg, total, e_alive, n_slots = self._pass_stats(alive_c, stream)
            n_alive = int(st.alive.sum())
            # The threshold/removal rule is the engine's UndirectedThreshold
            # policy step — the streaming driver only supplies the chunked
            # degree accumulation around it.
            new_alive_c, rho_arr = undirected_pass_step(
                jnp.asarray(alive_c), jnp.asarray(deg), float(total), self.eps
            )
            new_alive_c = np.asarray(new_alive_c)
            rho = float(rho_arr)
            st.history.append((n_alive, total, rho))
            if rho > st.best_rho:
                st.best_rho = rho
                st.best_alive = st.alive.copy()
            full = np.zeros(self.n_nodes, bool)
            full[id_map] = new_alive_c[: len(id_map)]
            st.alive = full
            st.pass_idx += 1
            self._save(st)
            alive_c = new_alive_c
            if (
                self.compaction == "geometric"
                and st.alive.any()
                and st.pass_idx < max_passes  # a rebuild must have a consumer
                and 2 * e_alive < n_slots
            ):
                stream, alive_c, id_map, n_slots = self._compact_stream(
                    stream, alive_c, id_map
                )
        return st


def chunked_from_arrays(
    src: np.ndarray, dst: np.ndarray, w: Optional[np.ndarray], chunk: int
) -> Callable[[], Iterator[Chunk]]:
    """Chunk-stream factory over in-memory / memmapped edge arrays."""
    if w is None:
        w = np.ones_like(src, np.float32)

    def gen() -> Iterator[Chunk]:
        for lo in range(0, len(src), chunk):
            hi = min(lo + chunk, len(src))
            yield src[lo:hi], dst[lo:hi], w[lo:hi]

    return gen
