"""Semi-streaming driver for Algorithm 1 (the paper's streaming model).

The graph's edge list lives outside accelerator memory (numpy arrays, memmap
or any chunk iterator); only O(n) node state (alive bitmap, degree vector,
best set) is held.  Each pass streams the edges chunk by chunk, accumulating
degrees with a jitted kernel — exactly the paper's "store and update the
current node degrees" loop.

Production concerns implemented here (this is the fault-tolerance layer for
the paper's own workload):
  * per-pass atomic checkpointing of the O(n) state -> restart resumes
    mid-algorithm after a crash;
  * straggler mitigation: chunks are dispatched to a worker pool and the
    slowest tail is speculatively re-issued (Hadoop-style backup tasks);
    results are idempotent so first-completion wins;
  * chunk results are pure reductions, so retries/duplicates are safe.
"""

from __future__ import annotations

import json
import os
import tempfile
import threading
import time
from concurrent.futures import FIRST_COMPLETED, ThreadPoolExecutor, wait
from dataclasses import dataclass, field
from typing import Callable, Iterable, Iterator, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Chunk = Tuple[np.ndarray, np.ndarray, np.ndarray]  # (src, dst, weight)


@jax.jit
def _chunk_stats(src, dst, w, alive):
    """Partial (degree vector, total weight) for one edge chunk.

    Accumulates in float32 regardless of the incoming weight dtype so the
    chunk reduction is stable for low-precision edge streams (bf16/f16
    weights) and identical across chunkings."""
    n = alive.shape[0]
    ok = alive[src] & alive[dst]
    w_alive = jnp.where(ok, w.astype(jnp.float32), jnp.float32(0.0))
    deg = jax.ops.segment_sum(w_alive, src, num_segments=n)
    deg = deg + jax.ops.segment_sum(w_alive, dst, num_segments=n)
    return deg, jnp.sum(w_alive)


@dataclass
class StreamState:
    alive: np.ndarray
    best_alive: np.ndarray
    best_rho: float
    pass_idx: int
    history: list = field(default_factory=list)  # (n, m, rho) per pass


class StreamingDensest:
    """Multi-pass semi-streaming Algorithm 1 with checkpoint/restart."""

    def __init__(
        self,
        chunk_stream: Callable[[], Iterator[Chunk]],
        n_nodes: int,
        eps: float = 0.5,
        checkpoint_dir: Optional[str] = None,
        n_workers: int = 4,
        speculative: bool = True,
        speculate_tail_frac: float = 0.2,
    ):
        self.chunk_stream = chunk_stream
        self.n_nodes = n_nodes
        self.eps = eps
        self.checkpoint_dir = checkpoint_dir
        self.n_workers = n_workers
        self.speculative = speculative
        self.speculate_tail_frac = speculate_tail_frac
        self.chunk_timings: list[float] = []
        self.speculative_reissues = 0

    # ----- checkpointing -------------------------------------------------
    def _ckpt_path(self) -> Optional[str]:
        if self.checkpoint_dir is None:
            return None
        return os.path.join(self.checkpoint_dir, "stream_state.npz")

    def _save(self, st: StreamState) -> None:
        """Atomic checkpoint write: savez to a temp file, fsync, then
        ``os.replace`` — a crash at any point leaves either the old or the
        new checkpoint, never a torn one.  The temp file is removed on
        failure as well."""
        path = self._ckpt_path()
        if path is None:
            return
        os.makedirs(self.checkpoint_dir, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=self.checkpoint_dir, suffix=".npz.tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                np.savez(
                    f,
                    alive=st.alive,
                    best_alive=st.best_alive,
                    best_rho=np.float64(st.best_rho),
                    pass_idx=np.int64(st.pass_idx),
                    history=np.asarray(st.history, np.float64).reshape(-1, 3),
                )
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    def _load(self) -> Optional[StreamState]:
        path = self._ckpt_path()
        if path is None or not os.path.exists(path):
            return None
        z = np.load(path)
        return StreamState(
            alive=z["alive"],
            best_alive=z["best_alive"],
            best_rho=float(z["best_rho"]),
            pass_idx=int(z["pass_idx"]),
            history=[tuple(r) for r in z["history"]],
        )

    # ----- one streaming pass --------------------------------------------
    def _pass_stats(self, alive_np: np.ndarray) -> Tuple[np.ndarray, float]:
        """Streams all chunks once; returns (degree vector, total weight).

        Chunks are processed by a worker pool; the slowest tail is
        speculatively re-issued.  Reductions are order-independent.
        """
        alive = jnp.asarray(alive_np)
        chunks = list(self.chunk_stream())
        deg = np.zeros(self.n_nodes, np.float32)
        total = 0.0
        done: dict[int, Tuple[np.ndarray, float]] = {}
        lock = threading.Lock()

        def work(idx: int) -> int:
            t0 = time.perf_counter()
            s, d, w = chunks[idx]
            dd, tt = _chunk_stats(jnp.asarray(s), jnp.asarray(d), jnp.asarray(w), alive)
            out = (np.asarray(dd), float(tt))
            with lock:
                if idx not in done:  # first completion wins (idempotent)
                    done[idx] = out
                self.chunk_timings.append(time.perf_counter() - t0)
            return idx

        with ThreadPoolExecutor(max_workers=self.n_workers) as ex:
            futs = {ex.submit(work, i): i for i in range(len(chunks))}
            pending = set(futs)
            speculated = False
            while pending:
                fin, pending = wait(pending, return_when=FIRST_COMPLETED)
                del fin
                if (
                    self.speculative
                    and not speculated
                    and len(done) >= (1 - self.speculate_tail_frac) * len(chunks)
                    and pending
                ):
                    # Back-up tasks for the straggler tail.
                    missing = [i for i in range(len(chunks)) if i not in done]
                    for i in missing:
                        pending.add(ex.submit(work, i))
                        self.speculative_reissues += 1
                    speculated = True

        for idx in range(len(chunks)):
            dd, tt = done[idx]
            deg += dd
            total += tt
        return deg, total

    # ----- the algorithm ---------------------------------------------------
    def run(self, max_passes: Optional[int] = None, resume: bool = True) -> StreamState:
        st = self._load() if resume else None
        if st is None:
            st = StreamState(
                alive=np.ones(self.n_nodes, bool),
                best_alive=np.ones(self.n_nodes, bool),
                best_rho=-np.inf,
                pass_idx=0,
            )
        from repro.core.density import max_passes_bound

        if max_passes is None:
            max_passes = max_passes_bound(self.n_nodes, self.eps)

        from repro.core.engine import undirected_pass_step

        while st.alive.any() and st.pass_idx < max_passes:
            deg, total = self._pass_stats(st.alive)
            n_alive = int(st.alive.sum())
            # The threshold/removal rule is the engine's UndirectedThreshold
            # policy step — the streaming driver only supplies the chunked
            # degree accumulation around it.
            new_alive, rho_arr = undirected_pass_step(
                jnp.asarray(st.alive), jnp.asarray(deg), float(total), self.eps
            )
            rho = float(rho_arr)
            st.history.append((n_alive, total, rho))
            if rho > st.best_rho:
                st.best_rho = rho
                st.best_alive = st.alive.copy()
            st.alive = np.asarray(new_alive)
            st.pass_idx += 1
            self._save(st)
        return st


def chunked_from_arrays(
    src: np.ndarray, dst: np.ndarray, w: Optional[np.ndarray], chunk: int
) -> Callable[[], Iterator[Chunk]]:
    """Chunk-stream factory over in-memory / memmapped edge arrays."""
    if w is None:
        w = np.ones_like(src, np.float32)

    def gen() -> Iterator[Chunk]:
        for lo in range(0, len(src), chunk):
            hi = min(lo + chunk, len(src))
            yield src[lo:hi], dst[lo:hi], w[lo:hi]

    return gen
