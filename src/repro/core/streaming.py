"""Semi-streaming driver for Algorithm 1 (the paper's streaming model).

The graph's edge list lives outside accelerator memory (numpy arrays, memmap
or any chunk iterator); only O(n) node state (alive bitmap, degree vector,
best set) is held.  Each pass streams the edges chunk by chunk through a
bounded-in-flight async pipeline: at most ``prefetch`` chunks are resident
at any time, chunk reads (memmap I/O) and device degree kernels overlap in
the worker pool, and the host reduces completed chunks strictly in stream
order — exactly the paper's "store and update the current node degrees"
loop, but out-of-core for the edges AND for the pipeline.

Production concerns implemented here (this is the fault-tolerance layer for
the paper's own workload):
  * per-pass atomic checkpointing of the O(n) state -> restart resumes
    mid-algorithm after a crash; the checkpoint write itself is deferred
    into the next pass's pipeline window (overlapped with chunk work);
  * straggler mitigation: chunks are dispatched to a worker pool and the
    slowest tail is speculatively re-issued (Hadoop-style backup tasks);
    results are idempotent so first-completion wins;
  * exception safety: a failing chunk worker re-raises its REAL error
    (never a downstream ``KeyError``); with ``speculative`` on, a failed
    attempt is retried once and a still-running duplicate may complete the
    chunk first (first success wins).  A failing pass never loses the
    previous pass's completed checkpoint;
  * out-of-core compaction: with ``spill_dir`` set, the geometric ladder's
    rebuilt survivor stream is written to disk-backed memmaps instead of
    host RAM, so streams whose SURVIVORS exceed memory still ride the
    amortized-O(m) ladder; the spill participates in checkpoint/resume.
"""

from __future__ import annotations

import collections
import os
import shutil
import threading
import time
import warnings
from concurrent.futures import FIRST_COMPLETED, Future, ThreadPoolExecutor, wait
from dataclasses import dataclass, field
from typing import Callable, Dict, Iterator, Optional, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import constants, faults

Chunk = Tuple[np.ndarray, np.ndarray, np.ndarray]  # (src, dst, weight)

# Rolling bound on the per-chunk timing record (straggler observability
# without a per-chunk-per-pass host-memory leak on million-chunk streams).
_TIMINGS_WINDOW = 4096
# How many times a FAILED chunk (no success yet, no duplicate in flight) is
# re-issued before its error surfaces.  Counted separately from straggler
# speculation, so a speculated chunk keeps its full retry budget; a
# deterministic error still surfaces after one retry instead of looping.
_MAX_FAILURE_RETRIES = 1


@jax.jit
def _chunk_stats(src, dst, w, alive):
    """Partial (degree vector, total weight, alive edge count) for one edge
    chunk.

    Accumulates in float32 regardless of the incoming weight dtype so the
    chunk reduction is stable for low-precision edge streams (bf16/f16
    weights) and identical across chunkings.  The degree count itself is
    the engine's :func:`~repro.core.engine.segment_degree_count` (§5.2's
    reduce-side count exists once); the alive edge count feeds the
    geometric compaction trigger."""
    from repro.core.engine import segment_degree_count

    ok = alive[src] & alive[dst]
    w_alive = jnp.where(ok, w.astype(jnp.float32), jnp.float32(0.0))
    deg, total = segment_degree_count(src, dst, w_alive, alive.shape[0])
    return deg, total, jnp.sum(ok.astype(jnp.int32))


_PASS_STEP = None


def _pass_step():
    """Jitted Algorithm-1 pass step (lazy: engine imports streaming's
    sibling modules).  ``run()`` syncs the step's two SCALARS (rho, new
    alive count) right away — it needs them for best-tracking and the loop
    condition — so the device step itself is not overlapped; what the jit
    buys is that the O(n) alive-bitmap transfer and the rest of the host
    finalization (scatter, best copy, checkpoint fsync) are deferred into
    the next pass's pipeline window instead of blocking between passes."""
    global _PASS_STEP
    if _PASS_STEP is None:
        from repro.core.engine import undirected_pass_step

        _PASS_STEP = jax.jit(undirected_pass_step, static_argnames=("eps",))
    return _PASS_STEP


class _Deferred:
    """Exactly-once wrapper for a pass's deferred host finalization (runs
    either inside the next pass's pipeline window or at loop exit)."""

    def __init__(self, fn: Callable[[], None]):
        self._fn = fn
        self._ran = False

    def __call__(self) -> None:
        if not self._ran:
            self._ran = True
            self._fn()


@dataclass
class StreamState:
    alive: np.ndarray
    best_alive: np.ndarray
    best_rho: float
    pass_idx: int
    history: list = field(default_factory=list)  # (n_alive, e_alive, rho)


class StreamingDensest:
    """Multi-pass semi-streaming Algorithm 1 with checkpoint/restart.

    ``prefetch`` bounds the number of chunks resident in host memory during
    a pass (the async pipeline's window); ``spill_dir`` redirects the
    geometric ladder's rebuilt streams to disk-backed memmaps;
    ``residency_cap_edges`` is an optional hard bound on the edges the
    driver may hold in host RAM — exceeding it without a ``spill_dir``
    raises instead of silently going in-core.
    """

    def __init__(
        self,
        chunk_stream: Callable[[], Iterator[Chunk]],
        n_nodes: int,
        eps: float = 0.5,
        checkpoint_dir: Optional[str] = None,
        n_workers: int = 4,
        speculative: bool = True,
        speculate_tail_frac: float = 0.2,
        compaction: str = "off",
        prefetch: int = 8,
        spill_dir: Optional[str] = None,
        residency_cap_edges: Optional[int] = None,
    ):
        if compaction not in ("off", "geometric"):
            raise ValueError(
                f"compaction={compaction!r} not in ('off', 'geometric')"
            )
        if prefetch < 1:
            raise ValueError(f"prefetch={prefetch} must be >= 1")
        if spill_dir is not None and compaction != "geometric":
            raise ValueError(
                "spill_dir is the geometric ladder's disk spill; this "
                "driver needs compaction='geometric' to use it"
            )
        self.chunk_stream = chunk_stream
        self.n_nodes = n_nodes
        self.eps = eps
        self.checkpoint_dir = checkpoint_dir
        self.n_workers = n_workers
        self.speculative = speculative
        self.speculate_tail_frac = speculate_tail_frac
        self.compaction = compaction
        self.prefetch = prefetch
        self.spill_dir = spill_dir
        self.residency_cap_edges = residency_cap_edges
        # Observability (host-memory-bounded): a rolling window of chunk
        # timings plus peak-residency high-water marks.
        self.chunk_timings: collections.deque = collections.deque(
            maxlen=_TIMINGS_WINDOW
        )
        self.speculative_reissues = 0
        self.compactions = 0  # geometric: stream rebuilds performed
        self.spill_rungs = 0  # geometric: rebuilds that went to disk
        self.peak_resident_chunks = 0  # max chunks materialized at once
        self.peak_resident_edges = 0  # max edge slots in host RAM at once
        # Edge slots pinned in host RAM by an in-RAM rebuilt stream (0 for
        # the caller's stream and for spilled rebuilds).
        self._stream_resident_edges = 0
        self._cur_rung_dir: Optional[str] = None

    # ----- checkpointing -------------------------------------------------
    def _ckpt_path(self) -> Optional[str]:
        if self.checkpoint_dir is None:
            return None
        return os.path.join(self.checkpoint_dir, "stream_state.npz")

    def _save(self, st: StreamState) -> None:
        """Atomic checkpoint write (:func:`repro.ioutil.atomic_write_file`):
        a crash at any point leaves either the old or the new checkpoint,
        never a torn one."""
        path = self._ckpt_path()
        if path is None:
            return
        from repro.ioutil import atomic_write_file

        faults.fire("streaming.checkpoint_save")
        atomic_write_file(
            path,
            lambda f: np.savez(
                f,
                alive=st.alive,
                best_alive=st.best_alive,
                best_rho=np.float64(st.best_rho),
                pass_idx=np.int64(st.pass_idx),
                history=np.asarray(st.history, np.float64).reshape(-1, 3),
            ),
            suffix=".npz.tmp",
        )

    def _load(self) -> Optional[StreamState]:
        """Fail-open checkpoint read: a corrupt or truncated checkpoint
        (torn copy, bad disk, injected fault) warns, quarantines the bad
        file with ONE atomic rename (``<path>.corrupt`` — kept for the
        operator's post-mortem) and resumes as a fresh run, instead of
        crashing the restart path the checkpoint exists to protect."""
        path = self._ckpt_path()
        if path is None or not os.path.exists(path):
            return None
        try:
            faults.fire("streaming.checkpoint_load")
            z = np.load(path)
            return StreamState(
                alive=z["alive"],
                best_alive=z["best_alive"],
                best_rho=float(z["best_rho"]),
                pass_idx=int(z["pass_idx"]),
                history=[tuple(r) for r in z["history"]],
            )
        except Exception as e:  # noqa: BLE001 — quarantine + start fresh
            quarantine = path + ".corrupt"
            # repro: allow(fault-sites) recovery path of the hooked streaming.checkpoint_load try above
            try:
                # repro: allow(atomic-io) quarantine rename of a corrupt file, not an artifact publish
                os.replace(path, quarantine)
            except OSError:
                quarantine = "<rename failed>"
            warnings.warn(
                f"checkpoint {path} is unreadable "
                f"({type(e).__name__}: {e}); quarantined to {quarantine}, "
                "starting fresh",
                RuntimeWarning,
                stacklevel=2,
            )
            return None

    # ----- one streaming pass --------------------------------------------
    def _pass_stats(
        self,
        alive,
        stream: Optional[Callable[[], Iterator[Chunk]]] = None,
        prelude: Optional[Callable[[], None]] = None,
    ) -> Tuple[np.ndarray, float, int, int]:
        """Streams all chunks once through the bounded async pipeline;
        returns (degree vector, total weight, alive edge count, edge slots
        streamed).

        At most ``prefetch`` chunks are materialized at any moment: chunks
        are pulled lazily from the stream iterator, dispatched to the worker
        pool (chunk reads and device kernels overlap across workers), and
        reduced on the host STRICTLY IN STREAM ORDER as the reduce frontier
        advances — so the result is bit-identical to a synchronous pass for
        every ``prefetch``/``n_workers`` setting and completion order.

        ``prelude`` (the previous pass's deferred finalization: best-set
        bookkeeping + checkpoint fsync) runs right after the first window is
        dispatched, overlapped with chunk work; it runs even if the pass
        fails, so an exploding chunk never loses completed-pass state.

        Failure semantics: a chunk worker's exception is re-raised with its
        real traceback (never a downstream ``KeyError``).  Speculative
        duplicates stay first-success-wins: a failure is ignored while a
        duplicate is in flight or has already succeeded; with
        ``speculative`` on, a failed chunk with no live duplicate is retried
        once before the error surfaces.  ``stream`` defaults to the
        constructor's chunk stream (the compaction ladder substitutes its
        rebuilt, smaller stream).
        """
        alive = jnp.asarray(alive)
        window = max(int(self.prefetch), 1)
        it = iter((stream or self.chunk_stream)())
        deg = np.zeros(alive.shape[0], np.float32)
        total = 0.0
        n_ok = 0
        n_slots = 0
        resident: Dict[int, Chunk] = {}  # materialized, not yet reduced
        done: Dict[int, Tuple[np.ndarray, float, int]] = {}
        inflight: Dict[int, int] = {}
        retries: Dict[int, int] = {}  # failure-triggered re-issues only
        reduced = 0  # the in-order reduce frontier
        resident_edges = 0
        n_seen = 0
        exhausted = False
        speculated = False
        lock = threading.Lock()

        def work(idx: int, chunk: Chunk) -> int:
            t0 = time.perf_counter()
            # Chaos hook: every ATTEMPT (first issue, speculative duplicate,
            # retry) of a chunk is one hit at this site, keyed by chunk
            # index — so tests drive the real retry/speculation machinery.
            faults.fire("streaming.chunk", key=idx)
            s, d, w = chunk
            dd, tt, cc = _chunk_stats(
                jnp.asarray(s), jnp.asarray(d), jnp.asarray(w), alive
            )
            out = (np.asarray(dd), float(tt), int(cc))
            with lock:
                # First completion wins (idempotent); a late duplicate of an
                # already-reduced chunk must not re-enter ``done``.
                if idx not in done and idx in resident:
                    done[idx] = out
                self.chunk_timings.append(time.perf_counter() - t0)
            return idx

        prelude_ran = prelude is None
        try:
            with ThreadPoolExecutor(max_workers=self.n_workers) as ex:
                pending: Set[Future] = set()
                futmap: Dict[Future, int] = {}

                def submit(idx: int) -> None:
                    inflight[idx] = inflight.get(idx, 0) + 1
                    fut = ex.submit(work, idx, resident[idx])
                    futmap[fut] = idx
                    pending.add(fut)

                def fill() -> None:
                    nonlocal exhausted, n_seen, n_slots, resident_edges
                    while not exhausted and len(resident) < window:
                        try:
                            chunk = next(it)
                        except StopIteration:
                            exhausted = True
                            break
                        idx = n_seen
                        n_seen += 1
                        n_slots += len(chunk[0])
                        with lock:
                            resident[idx] = chunk
                            resident_edges += len(chunk[0])
                        assert len(resident) <= window
                        self.peak_resident_chunks = max(
                            self.peak_resident_chunks, len(resident)
                        )
                        self.peak_resident_edges = max(
                            self.peak_resident_edges,
                            resident_edges + self._stream_resident_edges,
                        )
                        submit(idx)

                fill()
                if prelude is not None:
                    prelude()
                    prelude_ran = True
                while pending:
                    fin, not_done = wait(pending, return_when=FIRST_COMPLETED)
                    pending = not_done
                    for fut in fin:
                        idx = futmap.pop(fut)
                        err = fut.exception()
                        with lock:
                            inflight[idx] -= 1
                            succeeded = idx in done or idx < reduced
                            live_dup = inflight[idx] > 0
                        if err is not None and not succeeded and not live_dup:
                            if (
                                self.speculative
                                and retries.get(idx, 0) < _MAX_FAILURE_RETRIES
                                and idx in resident
                            ):
                                retries[idx] = retries.get(idx, 0) + 1
                                self.speculative_reissues += 1
                                submit(idx)
                            else:
                                raise err  # the chunk's REAL error
                        if not inflight[idx] and (succeeded or err is None):
                            inflight.pop(idx, None)  # bounded bookkeeping
                            retries.pop(idx, None)
                    # Advance the in-order reduce frontier and refill the
                    # window (reduction overlaps in-flight chunk work; the
                    # O(n) adds run outside the lock).
                    ready = []
                    with lock:
                        while reduced in done:
                            ready.append(done.pop(reduced))
                            chunk = resident.pop(reduced)
                            resident_edges -= len(chunk[0])
                            reduced += 1
                    for dd, tt, cc in ready:
                        deg += dd
                        total += tt
                        n_ok += cc
                    fill()
                    # Back-up tasks for the straggler tail (one round).
                    if (
                        self.speculative
                        and not speculated
                        and exhausted
                        and pending
                        and reduced + len(done)
                        >= (1 - self.speculate_tail_frac) * n_seen
                    ):
                        for idx in list(resident):
                            if idx not in done and inflight.get(idx, 0) > 0:
                                self.speculative_reissues += 1
                                submit(idx)
                        speculated = True
        finally:
            if not prelude_ran:
                prelude()
        return deg, total, n_ok, n_slots

    # ----- geometric compaction (amortized-O(m) streaming) ----------------
    def _compact_stream(
        self,
        stream: Callable[[], Iterator[Chunk]],
        alive_c: np.ndarray,
        id_map: np.ndarray,
        pass_idx: int,
    ):
        """Rebuilds the chunk stream over surviving edges with survivors
        renumbered into a dense (power-of-two padded) node range — one extra
        streaming pass, amortized away by the halved stream it produces.
        Returns ``(stream, alive_c, id_map, n_slots)`` where ``n_slots`` is
        the PADDED slot total of the rebuilt stream — the same quantity the
        next :meth:`_pass_stats` reports and the rung trigger in
        :meth:`run` compares against.

        Memory note: without ``spill_dir`` the rebuilt stream keeps the
        surviving chunks resident in host RAM (never concatenated —
        per-chunk arrays only, so there is no 2x materialization spike);
        the first trigger fires at under half the stream, so residency is
        < m/2 edge slots and halves per rung.  With ``spill_dir`` the
        rebuilt chunks are appended to disk-backed memmaps instead
        (O(chunk) host memory) and the spill — id_map included — is
        published atomically so checkpoint resume can re-enter the ladder
        mid-rung; streams whose SURVIVORS exceed host memory ride the
        ladder this way.  ``residency_cap_edges`` turns a too-large in-RAM
        rebuild into an error instead of a silent memory blow-up.
        """
        from repro.graph.edgelist import EdgeSpillWriter
        from repro.graph.partition import pow2_bucket

        surv = alive_c[: len(id_map)]
        n_alive = int(surv.sum())
        relabel = (np.cumsum(alive_c) - 1).astype(np.int64)
        # Pow2-padded node space (with >= 1 permanently-dead pad node for
        # edge padding below): the jitted chunk kernel sees O(log n)
        # distinct degree-vector shapes across the whole ladder.
        n_pad = pow2_bucket(n_alive + 1, floor=constants.STREAM_REBUILD_NODE_FLOOR)
        pad_id = np.int32(n_pad - 1)  # never alive -> pad edges never count

        spill: Optional[EdgeSpillWriter] = None
        rung_dir: Optional[str] = None
        if self.spill_dir is not None:
            rung_dir = os.path.join(
                self.spill_dir, f"rung_{self.compactions:04d}"
            )
            if os.path.exists(rung_dir):  # stale partial spill from a crash
                shutil.rmtree(rung_dir)
        chunks = []
        caps = []
        n_slots = 0
        w_dtype = None
        try:
            for s, d, w in stream():
                ok = alive_c[s] & alive_c[d]
                kept = int(ok.sum())
                if kept == 0:
                    continue
                # Per-chunk pow2 length so surviving (ragged) chunks land on
                # a bounded set of shapes instead of one compile per chunk.
                cap = pow2_bucket(kept, floor=constants.STREAM_REBUILD_CHUNK_FLOOR)
                cs = np.full(cap, pad_id, np.int32)
                cd = np.full(cap, pad_id, np.int32)
                cw = np.zeros(cap, w.dtype)
                cs[:kept] = relabel[s[ok]]
                cd[:kept] = relabel[d[ok]]
                cw[:kept] = w[ok]
                n_slots += cap
                w_dtype = w.dtype
                if rung_dir is not None:
                    if spill is None:
                        spill = EdgeSpillWriter(rung_dir, w.dtype)
                    spill.append(cs, cd, cw)
                    caps.append(cap)
                else:
                    # The source rung's chunks stay resident while the new
                    # rung accumulates, so the cap (and the peak metric)
                    # covers BOTH — no transient overshoot goes unreported.
                    building = n_slots + self._stream_resident_edges
                    if (
                        self.residency_cap_edges is not None
                        and building > self.residency_cap_edges
                    ):
                        raise RuntimeError(
                            f"compaction rebuild holds {building} edge slots"
                            " in host RAM (source rung + survivors so far),"
                            " exceeding residency_cap_edges="
                            f"{self.residency_cap_edges}; set spill_dir= to"
                            " rebuild the stream on disk instead"
                        )
                    self.peak_resident_edges = max(
                        self.peak_resident_edges, building
                    )
                    chunks.append((cs, cd, cw))
        except BaseException:
            if spill is not None:
                spill.abort()  # close fds + drop the partial rung dir
            raise
        new_alive = np.arange(n_pad) < n_alive
        new_id_map = id_map[surv]

        if rung_dir is not None:
            if spill is None:  # no survivors: publish an empty spill
                spill = EdgeSpillWriter(
                    rung_dir, w_dtype if w_dtype is not None else np.float32
                )
            # repro: allow(fault-sites) spill.finalize fires edgelist.spill_publish inside this try
            try:
                np.save(os.path.join(rung_dir, "id_map.npy"), new_id_map)
                # Publish is atomic (manifest last); a failure here — disk
                # full, injected spill_publish fault — aborts the partial
                # rung so resume can never adopt it, and the error
                # surfaces (the ladder has no stream to continue on).
                spill.finalize(
                    caps=caps,
                    n_pad=int(n_pad),
                    n_alive=int(n_alive),
                    n_nodes=int(self.n_nodes),
                    eps=self.eps,  # guards resume against foreign rungs
                    pass_idx=int(pass_idx),
                    rung=int(self.compactions),
                )
            except BaseException:
                spill.abort()
                raise
            prev = self._cur_rung_dir
            self._cur_rung_dir = rung_dir
            if prev is not None and prev != rung_dir:
                shutil.rmtree(prev, ignore_errors=True)
            gen = _spilled_stream(rung_dir)
            self._stream_resident_edges = 0
            self.spill_rungs += 1
        else:

            def gen() -> Iterator[Chunk]:
                yield from chunks

            self._stream_resident_edges = n_slots
        self.compactions += 1
        return gen, new_alive, new_id_map, n_slots

    def _load_spill(self, st: StreamState):
        """Resume hook: re-enter the ladder on the latest finalized spill
        rung consistent with the checkpoint (the spill was built from an
        alive set at ``manifest.pass_idx <= st.pass_idx``; alive only
        shrinks, so filtering its chunks by the CURRENT alive bitmap is
        exact).  Returns ``(stream, alive_c, id_map)`` or None."""
        from repro.graph.edgelist import open_edge_spill

        if self.spill_dir is None or not os.path.isdir(self.spill_dir):
            return None
        best = None
        for name in sorted(os.listdir(self.spill_dir)):
            rung_dir = os.path.join(self.spill_dir, name)
            if not name.startswith("rung_"):
                continue
            opened = open_edge_spill(rung_dir)
            if opened is None:  # unfinalized (crashed mid-spill): ignore
                continue
            man = opened[3]
            if (
                man.get("n_nodes") != self.n_nodes
                or man.get("eps") != self.eps
                or man.get("pass_idx", 1 << 62) > st.pass_idx
            ):
                continue
            if best is None or man["rung"] > best[1]["rung"]:
                best = (rung_dir, man)
        if best is None:
            return None
        rung_dir, man = best
        id_map = np.load(os.path.join(rung_dir, "id_map.npy"))
        alive_c = np.zeros(man["n_pad"], bool)
        alive_c[: len(id_map)] = st.alive[id_map]
        self.compactions = int(man["rung"]) + 1
        self.spill_rungs = int(man["rung"]) + 1
        self._cur_rung_dir = rung_dir
        return _spilled_stream(rung_dir), alive_c, id_map

    # ----- the algorithm ---------------------------------------------------
    def run(self, max_passes: Optional[int] = None, resume: bool = True) -> StreamState:
        st = self._load() if resume else None
        fresh = st is None
        if fresh:
            st = StreamState(
                alive=np.ones(self.n_nodes, bool),
                best_alive=np.ones(self.n_nodes, bool),
                best_rho=-np.inf,
                pass_idx=0,
            )
        from repro.core.density import max_passes_bound

        if max_passes is None:
            max_passes = max_passes_bound(self.n_nodes, self.eps)

        # Compact view of the live subproblem: ``id_map`` maps compact node
        # ids back to original ids (identity until the first compaction);
        # the FULL-space StreamState is maintained throughout, so the
        # checkpoint format and all outputs are unchanged.
        stream = self.chunk_stream
        id_map = np.arange(self.n_nodes, dtype=np.int64)
        alive_c = st.alive.copy()
        self._stream_resident_edges = 0
        if self.compaction == "geometric" and self.spill_dir is not None:
            if fresh:
                # New lineage: clear rungs of any previous run sharing this
                # spill_dir, so a later resume can never adopt one of them
                # (only the highest rung a run reaches outlives it).
                if os.path.isdir(self.spill_dir):
                    for name in os.listdir(self.spill_dir):
                        if name.startswith("rung_"):
                            shutil.rmtree(
                                os.path.join(self.spill_dir, name),
                                ignore_errors=True,
                            )
            else:
                rec = self._load_spill(st)
                if rec is not None:
                    stream, alive_c, id_map = rec

        step = _pass_step()
        alive_dev = jnp.asarray(alive_c)
        n_cur = int(st.alive.sum())
        pending: Optional[_Deferred] = None
        try:
            while n_cur > 0 and st.pass_idx < max_passes:
                deg, total, e_alive, n_slots = self._pass_stats(
                    alive_dev, stream, prelude=pending
                )
                pending = None
                # The threshold/removal rule is the engine's
                # UndirectedThreshold policy step — the streaming driver only
                # supplies the chunked degree accumulation around it.  The
                # jitted step is dispatched here; everything below that needs
                # only scalars syncs them, and the O(n) host bookkeeping
                # (best-set copy, full-space scatter, checkpoint fsync) is
                # DEFERRED into the next pass's pipeline window.
                new_alive_dev, rho_dev = step(
                    alive_dev, jnp.asarray(deg), np.float32(total), eps=self.eps
                )
                rho = float(rho_dev)
                n_new = int(jnp.count_nonzero(new_alive_dev))

                def fin(
                    st=st,
                    prev_alive=st.alive,
                    n_prev=n_cur,
                    e_alive=e_alive,
                    rho=rho,
                    dev=new_alive_dev,
                    idm=id_map,
                ):
                    st.history.append((n_prev, e_alive, rho))
                    if rho > st.best_rho:
                        st.best_rho = rho
                        st.best_alive = prev_alive.copy()
                    full = np.zeros(self.n_nodes, bool)
                    full[idm] = np.asarray(dev)[: len(idm)]
                    st.alive = full
                    self._save(st)

                st.pass_idx += 1
                pending = _Deferred(fin)
                alive_dev = new_alive_dev
                n_cur = n_new
                if (
                    self.compaction == "geometric"
                    and n_cur > 0
                    and st.pass_idx < max_passes  # a rebuild needs a consumer
                    and 2 * e_alive < n_slots
                ):
                    pending()  # the rebuild reads a settled checkpoint state
                    pending = None
                    alive_c = np.asarray(alive_dev)
                    stream, alive_c, id_map, n_slots = self._compact_stream(
                        stream, alive_c, id_map, st.pass_idx
                    )
                    alive_dev = jnp.asarray(alive_c)
        finally:
            if pending is not None:
                pending()
        return st


def _spilled_stream(rung_dir: str) -> Callable[[], Iterator[Chunk]]:
    """Chunk-stream factory over a finalized spill rung: each chunk is a
    memmap slice, read from disk on demand (O(chunk) host residency)."""
    from repro.graph.edgelist import open_edge_spill

    def gen() -> Iterator[Chunk]:
        opened = open_edge_spill(rung_dir)
        if opened is None:
            raise FileNotFoundError(f"no finalized edge spill in {rung_dir}")
        src, dst, w, man = opened
        off = 0
        for cap in man["caps"]:
            yield src[off : off + cap], dst[off : off + cap], w[off : off + cap]
            off += cap

    return gen


def chunked_from_arrays(
    src: np.ndarray, dst: np.ndarray, w: Optional[np.ndarray], chunk: int
) -> Callable[[], Iterator[Chunk]]:
    """Chunk-stream factory over in-memory / memmapped edge arrays."""
    if w is None:
        w = np.ones_like(src, np.float32)

    def gen() -> Iterator[Chunk]:
        for lo in range(0, len(src), chunk):
            hi = min(lo + chunk, len(src))
            yield src[lo:hi], dst[lo:hi], w[lo:hi]

    return gen


def chunked_from_memmap(
    store_dir: str, chunk: int
) -> Callable[[], Iterator[Chunk]]:
    """Chunk-stream factory over an on-disk edge store written by
    :func:`repro.graph.edgelist.save_edges_memmap`: the edges never enter
    host RAM whole — each chunk is a memmap slice read on demand, so the
    stream's home is the disk, as §4's model intends."""
    from repro.graph.edgelist import open_edges_memmap

    def gen() -> Iterator[Chunk]:
        src, dst, w = open_edges_memmap(store_dir)
        for lo in range(0, len(src), chunk):
            hi = min(lo + chunk, len(src))
            yield src[lo:hi], dst[lo:hi], w[lo:hi]

    return gen
