"""PeelEngine — the single peel-pass implementation behind every algorithm.

This is the *mechanism* layer: the declarative front door that lowers onto
it lives in core/api.py (``Problem`` -> policy × backend × substrate ->
``run_peel``); prefer ``repro.core.solve`` unless you are composing engine
pieces directly.

Algorithms 1, 2 and 3 of the paper share one pass structure: count induced
degrees, compute the density, record the best intermediate set, remove the
below-threshold nodes.  This module implements that pass body EXACTLY ONCE
as a ``jax.lax.while_loop`` parameterized along two orthogonal axes:

  * a **RemovalPolicy** — which nodes leave the graph each pass, and what
    "density" and "keep going" mean;
  * a **DegreeBackend** — how induced degrees (and the total alive edge
    weight) are computed from the edge list.

A third axis, the **substrate**, is how the loop is launched: plain ``jit``
(core/peel*.py), a host-side chunked pass loop (core/streaming.py, which
reuses :func:`undirected_pass_step` so the removal rule still lives here),
or ``shard_map`` over a device mesh (core/mapreduce.py, which runs
:func:`run_peel` *inside* the mapped function with a psum'ing backend).

A fourth knob, the **compaction runtime**, is how the loop is *scheduled*
across shrinking buffers: the paper's Lemma 4 guarantees the node set
shrinks by a ``(1+eps)`` factor per pass, so scanning all ``m`` padded edge
slots every pass wastes geometrically-growing fractions of the buffer.
:func:`run_peel` therefore supports running in SEGMENTS: ``compact_below``
stops the while-loop as soon as the post-removal alive edge count falls
under the threshold, and ``init_alive`` / ``init_t_alive`` / ``init_t``
let the next segment continue the SAME loop (absolute pass counter,
best-set tracking merged by the caller) on a smaller renumbered buffer.
The host-side gather/relabel ladder lives in core/api.py
(``Problem(compaction='geometric'|'twophase')``); compaction is pure
renumbering, so segmented runs are bit-identical to single-segment runs
for integer-valued edge weights (and reassociation-level equal otherwise).
Pass ``k`` then costs ``O(m_k)`` instead of ``O(m)`` — amortized ``O(m)``
total work across the ladder.

Policy × backend matrix (the paper section each cell realizes)::

    policy \\ backend   | exact segsum | count-sketch | pallas tiled | mesh psum
    -------------------+--------------+--------------+--------------+-----------
    undirected_        | Alg 1 (§4.1) | §5.1, Table 4| kernels/     | §5.2 MapReduce
      threshold        |              |              | peel_degree  | (+ sketch §5.1)
    at_least_k_        | Alg 2 (§4.2) |      —*      |      —*      | §5.2 (topk)
      fraction         |              |              |              |
    directed_st        | Alg 3 (§4.3) | §5.1 per-    |      —*      | §5.2 (directed)
                       |              | endpoint     |              |

    —* = composes through the same engine but has no dedicated wrapper yet;
    any DegreeBackend works with any policy of matching directedness.

The removal threshold ``2(1+eps)·rho(S)`` exists only here
(:func:`removal_threshold`); wrappers must not re-derive it.

Adding a new backend
====================
Implement an object with

  ``undirected(edges, w_alive) -> (deg[N], total)`` and/or
  ``directed(edges, w_alive) -> (out_deg[N], in_deg[N], total)``

where ``w_alive`` is the per-edge alive weight the engine already computed
(0.0 for masked/dead edges).  Return the *global* degree vector — inside a
``shard_map`` substrate that means psum'ing your local partials (fuse the
scalar ``total`` into the same reduction; see :class:`MeshSegmentSumBackend`).
Then pass an instance to :func:`run_peel` — no loop code is needed.

Adding a new policy is the same exercise against :class:`RemovalPolicy`:
density/eligible/keep_going plus a ``removal`` rule returning the per-side
removal bitmaps.  Parallel peeling variants (shared-memory batched removal,
directed-stream policies) slot in here rather than as new loops.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, NamedTuple, Optional, Protocol, Tuple

import jax
import jax.numpy as jnp

from repro.graph.edgelist import EdgeList

# ---------------------------------------------------------------------------
# The one threshold site (acceptance: grep for "2.0 * (1.0 + eps)")
# ---------------------------------------------------------------------------


def removal_threshold(eps: float, rho: jax.Array) -> jax.Array:
    """The paper's removal threshold 2(1+eps)·rho(S) — the only place the
    expression exists in the codebase."""
    return 2.0 * (1.0 + eps) * rho


def segment_degree_count(
    src: jax.Array, dst: jax.Array, w_alive: jax.Array, n_nodes: int
) -> Tuple[jax.Array, jax.Array]:
    """The reduce-side degree count of §5.2: both-endpoint segment_sum plus
    the total alive edge weight.  The ONLY implementation of the exact
    undirected count — :class:`ExactBackend`, ``density.exact_degrees`` and
    the streaming chunk reducer all delegate here (like
    :func:`removal_threshold`, the expression exists once)."""
    deg = jax.ops.segment_sum(w_alive, src, num_segments=n_nodes)
    deg = deg + jax.ops.segment_sum(w_alive, dst, num_segments=n_nodes)
    return deg, jnp.sum(w_alive)


def compact_edges(
    ok: jax.Array, arrays: Tuple[jax.Array, ...], capacity: int
) -> Tuple[jax.Array, ...]:
    """The IN-PROGRAM compact step of the segment loop: masked prefix-sum
    relabeling of edge slots.  Slots where ``ok`` is True are stable-scattered
    (original order preserved) to the front of fresh ``capacity``-slot zero
    buffers; everything else lands out of bounds and is dropped.

    Pure and traceable — this is what lets a compaction ladder run entirely
    inside one compiled program (the mesh ladder pairs it with an all-gather;
    see ``core/mapreduce.mesh_compact_edges``).  Unlike the host ladder's
    gather, the target ``capacity`` is STATIC: callers must guarantee the
    survivor count fits (the ``compact_below`` trigger is exactly that
    guarantee — a segment only exits below half its buffer, and survivors of
    a terminated run are never peeled again, so overflow drops are harmless).

    Spelled as prefix-sum + rank search + gather rather than a scatter:
    ``searchsorted`` finds the k-th survivor's slot, and XLA lowers the
    gather an order of magnitude faster than the equivalent masked scatter
    on CPU (measured 8x on the tracked benchmark's rung sizes).
    """
    cs = jnp.cumsum(ok.astype(jnp.int32))
    ranks = jnp.arange(1, capacity + 1, dtype=jnp.int32)
    idx = jnp.searchsorted(cs, ranks, side="left")  # len(ok) when rank > total
    return tuple(a.at[idx].get(mode="fill", fill_value=0) for a in arrays)


# ---------------------------------------------------------------------------
# State / outcome — the single pair replacing the old per-loop families
# ---------------------------------------------------------------------------


class PassStats(NamedTuple):
    """Per-pass scalars handed to the policy's removal rule."""

    rho: jax.Array  # float32[] density of the current set
    total: jax.Array  # float32[] alive edge weight |E(S)| (or |E(S,T)|)
    n_s: jax.Array  # int32[] |S|
    n_t: jax.Array  # int32[] |T| (== |S| for undirected policies)


class PeelState(NamedTuple):
    """Loop carry.  For undirected policies the T-side arrays are empty
    ``bool[0]`` placeholders so the pytree structure stays uniform."""

    alive: jax.Array  # bool[N] current S
    t_alive: jax.Array  # bool[N] current T (directed) | bool[0]
    best_alive: jax.Array  # bool[N] best S seen
    best_t: jax.Array  # bool[N] best T seen (directed) | bool[0]
    best_rho: jax.Array  # float32[]
    best_size: jax.Array  # int32[] |S| of the best set
    t: jax.Array  # int32[] pass counter
    alive_edges: jax.Array  # int32[] post-removal alive edge count (0 if untracked)
    edge_ok: jax.Array  # bool[E] post-removal edge filter | bool[0] if untracked
    history_n: jax.Array  # int32[hist_len]
    history_m: jax.Array  # float32[hist_len]
    history_rho: jax.Array  # float32[hist_len]


class PeelOutcome(NamedTuple):
    """Result of any peel run; every public result type aliases this."""

    best_alive: jax.Array  # bool[N] the output set S~ (S side for directed)
    best_t: jax.Array  # bool[N] T side (directed) | bool[0]
    best_density: jax.Array  # float32[] rho of the best set
    best_size: jax.Array  # int32[] |S~|
    passes: jax.Array  # int32[] passes executed
    alive: jax.Array  # bool[N] FINAL S bitmap (for phased/compacted runs)
    t_alive: jax.Array  # bool[N] final T bitmap | bool[0]
    history_n: jax.Array  # int32[hist_len] per-pass |S| (-1 padding)
    history_m: jax.Array  # float32[hist_len] per-pass |E(S)|
    history_rho: jax.Array  # float32[hist_len] per-pass rho

    @property
    def best_s(self) -> jax.Array:
        """Directed-result spelling of the S-side best bitmap."""
        return self.best_alive


# ---------------------------------------------------------------------------
# Policies
# ---------------------------------------------------------------------------


class RemovalPolicy(Protocol):
    """What a pass removes; instances may close over traced scalars."""

    directed: bool

    def density(self, total: jax.Array, n_s: jax.Array, n_t: jax.Array) -> jax.Array:
        """rho of the current set(s)."""

    def eligible(self, n_s: jax.Array, n_t: jax.Array) -> jax.Array:
        """May the current set become the recorded best?"""

    def keep_going(self, n_s: jax.Array, n_t: jax.Array) -> jax.Array:
        """while-loop continuation test (max_passes is handled by the engine)."""

    def removal(
        self,
        s_alive: jax.Array,
        t_alive: jax.Array,
        deg_s: jax.Array,
        deg_t: jax.Array,
        stats: PassStats,
    ) -> Tuple[jax.Array, Optional[jax.Array]]:
        """(remove-from-S bitmap, remove-from-T bitmap or None)."""


def _undirected_density(total, n_s):
    return jnp.where(n_s > 0, total / jnp.maximum(n_s, 1), 0.0)


@dataclasses.dataclass(frozen=True)
class UndirectedThreshold:
    """Algorithm 1: drop every node with deg <= 2(1+eps)·rho(S).

    The min-degree progress fallback (remove the current minimum-degree
    nodes when rounding would make the removal set empty) preserves the
    approximation proof verbatim and guarantees termination.
    """

    eps: float
    directed: bool = dataclasses.field(default=False, init=False)

    def density(self, total, n_s, n_t):
        return _undirected_density(total, n_s)

    def eligible(self, n_s, n_t):
        return n_s > 0

    def keep_going(self, n_s, n_t):
        return n_s > 0

    def removal(self, s_alive, t_alive, deg_s, deg_t, stats):
        thresh = removal_threshold(self.eps, stats.rho)
        deg_alive = jnp.where(s_alive, deg_s, jnp.inf)
        min_deg = jnp.min(deg_alive)
        rm = s_alive & ((deg_s <= thresh) | (deg_s <= min_deg))
        return rm, None


@dataclasses.dataclass(frozen=True)
class AtLeastKFraction:
    """Algorithm 2: of the below-threshold candidates A~(S), remove only the
    eps/(1+eps)·|S| lowest-degree ones (a deterministic choice of the subset
    the paper leaves free); only sets with |S| >= k are eligible.

    ``ceil_count``/``min_deg_fallback`` select between the two historical
    realizations (single-device used floor + fallback; the distributed one
    used ceil without) so both keep their exact pre-refactor outputs.
    """

    k: int
    eps: float
    min_deg_fallback: bool = True
    ceil_count: bool = False
    directed: bool = dataclasses.field(default=False, init=False)

    def density(self, total, n_s, n_t):
        return _undirected_density(total, n_s)

    def eligible(self, n_s, n_t):
        return n_s >= self.k

    def keep_going(self, n_s, n_t):
        return n_s >= self.k

    def removal(self, s_alive, t_alive, deg_s, deg_t, stats):
        thresh = removal_threshold(self.eps, stats.rho)
        if self.min_deg_fallback:
            deg_alive = jnp.where(s_alive, deg_s, jnp.inf)
            cand = s_alive & ((deg_s <= thresh) | (deg_s <= jnp.min(deg_alive)))
        else:
            cand = s_alive & (deg_s <= thresh)
        nf = stats.n_s.astype(jnp.float32)
        if self.ceil_count:
            r = jnp.ceil(nf * self.eps / (1.0 + self.eps)).astype(jnp.int32)
        else:
            r = ((self.eps / (1.0 + self.eps)) * nf).astype(jnp.int32)
        r = jnp.maximum(r, 1)
        # Rank candidates by (degree, node id): stable argsort puts every
        # candidate ahead of non-candidates (their key is +inf).
        n = deg_s.shape[0]
        key = jnp.where(cand, deg_s, jnp.inf)
        order = jnp.argsort(key)
        rank = jnp.zeros((n,), jnp.int32).at[order].set(jnp.arange(n, dtype=jnp.int32))
        rm = cand & (rank < r)
        return rm, None


@dataclasses.dataclass(frozen=True)
class DirectedST:
    """Algorithm 3 for a fixed ratio guess c = |S|/|T| (c may be traced):
    peel S by out-degree when |S|/|T| >= c, else peel T by in-degree."""

    eps: float
    c: Any  # float or traced float32 scalar (vmap-able over the c grid)
    directed: bool = dataclasses.field(default=True, init=False)

    def density(self, total, n_s, n_t):
        denom = jnp.sqrt(
            jnp.maximum(n_s.astype(jnp.float32), 1.0)
            * jnp.maximum(n_t.astype(jnp.float32), 1.0)
        )
        return jnp.where((n_s > 0) & (n_t > 0), total / denom, 0.0)

    def eligible(self, n_s, n_t):
        return (n_s > 0) & (n_t > 0)

    def keep_going(self, n_s, n_t):
        return (n_s > 0) & (n_t > 0)

    def removal(self, s_alive, t_alive, out_deg, in_deg, stats):
        ns_f = jnp.maximum(stats.n_s.astype(jnp.float32), 1.0)
        nt_f = jnp.maximum(stats.n_t.astype(jnp.float32), 1.0)
        peel_s = ns_f / nt_f >= self.c
        thr_s = (1.0 + self.eps) * stats.total / ns_f
        outd = jnp.where(s_alive, out_deg, jnp.inf)
        rm_s = s_alive & ((out_deg <= thr_s) | (out_deg <= jnp.min(outd)))
        thr_t = (1.0 + self.eps) * stats.total / nt_f
        ind = jnp.where(t_alive, in_deg, jnp.inf)
        rm_t = t_alive & ((in_deg <= thr_t) | (in_deg <= jnp.min(ind)))
        return rm_s & peel_s, rm_t & ~peel_s


# ---------------------------------------------------------------------------
# Backends
# ---------------------------------------------------------------------------


class DegreeBackend(Protocol):
    """Induced-degree computation.  ``w_alive`` is the engine-computed
    per-edge alive weight; implementations return GLOBAL degrees + total."""

    def undirected(
        self, edges: EdgeList, w_alive: jax.Array
    ) -> Tuple[jax.Array, jax.Array]: ...

    def directed(
        self, edges: EdgeList, w_alive: jax.Array
    ) -> Tuple[jax.Array, jax.Array, jax.Array]: ...


class ExactBackend:
    """segment_sum degrees — the paper's reduce-side count (§5.2, 1 device)."""

    def undirected(self, edges, w_alive):
        return segment_degree_count(edges.src, edges.dst, w_alive, edges.n_nodes)

    def directed(self, edges, w_alive):
        n = edges.n_nodes
        out_deg = jax.ops.segment_sum(w_alive, edges.src, num_segments=n)
        in_deg = jax.ops.segment_sum(w_alive, edges.dst, num_segments=n)
        return out_deg, in_deg, jnp.sum(w_alive)


class FnBackend:
    """Adapts a legacy ``degree_fn(edges, w_alive) -> deg[N]`` hook (the
    Count-Sketch and Pallas degree functions) into a DegreeBackend."""

    def __init__(self, degree_fn):
        self.degree_fn = degree_fn

    def undirected(self, edges, w_alive):
        return self.degree_fn(edges, w_alive), jnp.sum(w_alive)

    def directed(self, edges, w_alive):
        raise NotImplementedError(
            "degree_fn hooks are undirected; use a backend with a directed() rule"
        )


@dataclasses.dataclass(frozen=True)
class MeshSegmentSumBackend:
    """Mesh-sharded degrees for use INSIDE ``shard_map`` (paper §5.2).

    Local segment_sum partials over the edge shard, then ONE fused psum of
    ``[deg | total]`` over the edge axes — the density counter rides along
    in the same collective, so a pass costs exactly one reduction.
    ``wire_dtype='bf16'`` halves the degree psum (see core/mapreduce.py).
    """

    axes: Tuple[str, ...]
    wire_dtype: str = "f32"

    def _psum(self, packed: jax.Array) -> jax.Array:
        if self.wire_dtype == "bf16":
            return jax.lax.psum(packed.astype(jnp.bfloat16), self.axes).astype(
                jnp.float32
            )
        return jax.lax.psum(packed, self.axes)

    def undirected(self, edges, w_alive):
        deg, total = ExactBackend().undirected(edges, w_alive)
        packed = self._psum(jnp.concatenate([deg, total[None]]))
        return packed[:-1], packed[-1]

    def directed(self, edges, w_alive):
        n = edges.n_nodes
        out_deg, in_deg, total = ExactBackend().directed(edges, w_alive)
        packed = self._psum(jnp.concatenate([out_deg, in_deg, total[None]]))
        return packed[:n], packed[n : 2 * n], packed[-1]

    def count_edges(self, ok: jax.Array) -> jax.Array:
        """Global alive-edge count (the compaction trigger): local count of
        this shard's alive edges, psummed over the edge axes so every device
        agrees on when a segment ends."""
        return jax.lax.psum(jnp.sum(ok.astype(jnp.int32)), self.axes)


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------


def _count_ok(backend, ok: jax.Array) -> jax.Array:
    """int32[] count of an alive-edge mask.  Backends that reduce across
    devices (shard_map substrates) expose ``count_edges`` so the segment
    boundary is a collective decision; everything else counts locally."""
    counter = getattr(backend, "count_edges", None)
    if counter is not None:
        return counter(ok)
    return jnp.sum(ok.astype(jnp.int32))


def run_peel(
    edges: EdgeList,
    policy: RemovalPolicy,
    backend: DegreeBackend,
    max_passes: int,
    *,
    track_history: bool = False,
    init_alive: Optional[jax.Array] = None,
    init_t_alive: Optional[jax.Array] = None,
    init_best_empty: bool = False,
    init_t: Optional[jax.Array] = None,
    compact_below: Optional[int] = None,
    init_alive_edges: Optional[jax.Array] = None,
    init_ok_from_mask: bool = False,
    with_edge_state: bool = False,
) -> PeelOutcome:
    """Runs the peel loop to completion.  Pure and traceable: wrappers add
    ``jit``/``vmap``/``shard_map`` around it (substrate axis).

    Segment controls (the compaction runtime; see the module docstring):
    ``init_alive`` / ``init_t_alive`` seed S / T (default: all nodes) on a
    renumbered buffer; ``init_best_empty`` starts the best set at empty
    instead of S_0 (the recorded best is then only ever a set the loop
    actually evaluated); ``init_t`` (int32 scalar) continues the ABSOLUTE
    pass counter so ``t < max_passes`` budgets and ``track_history``
    indices span segments; ``compact_below`` stops the loop once the
    post-removal alive edge count drops under it (the caller then gathers
    survivors into a smaller buffer and re-enters with the carried state).
    The post-removal edge filter is CARRIED in the loop state and reused
    as the next pass's filter, so the trigger adds no O(m) scan per pass —
    each pass computes the mask exactly once, like the classic loop.
    Callers that already know the entry state can skip the one entry-time
    filter too: ``init_ok_from_mask`` declares that every masked edge has
    both endpoints alive initially (true for a freshly compacted buffer,
    whose gather kept exactly the alive edges), and ``init_alive_edges``
    supplies its count (the survivor count the compaction just computed).
    ``compact_below=None`` is the classic single-segment run — the count
    and the carried mask are never materialized.

    ``with_edge_state`` (requires ``compact_below``) returns ``(outcome,
    edge_ok, alive_edges)`` instead: the carried post-removal edge filter
    and its count at exit — exactly the survivor set an in-program
    compaction needs, already computed by the final pass (the single-program
    mesh ladder reuses it instead of paying another O(m) filter).
    """
    n = edges.n_nodes
    directed = policy.directed
    hist_len = max_passes if track_history else 1
    dummy = jnp.zeros((0,), bool)

    alive0 = jnp.ones((n,), bool) if init_alive is None else init_alive
    if directed:
        ta0 = alive0 if init_t_alive is None else init_t_alive
    else:
        ta0 = dummy
    best0 = jnp.zeros_like(alive0) if init_best_empty else alive0
    t0 = jnp.asarray(0 if init_t is None else init_t, jnp.int32)

    def counts(s: PeelState):
        n_s = jnp.sum(s.alive.astype(jnp.int32))
        n_t = jnp.sum(s.t_alive.astype(jnp.int32)) if directed else n_s
        return n_s, n_t

    def cond(s: PeelState):
        n_s, n_t = counts(s)
        going = policy.keep_going(n_s, n_t) & (s.t < max_passes)
        if compact_below is not None:
            going = going & (s.alive_edges >= compact_below)
        return going

    def body(s: PeelState) -> PeelState:
        ta = s.t_alive if directed else s.alive
        # (3) of §5.2: the per-pass edge filter against the alive bitmap(s).
        # Compacted segments carry it from the previous pass's removal, so
        # it is computed exactly once per pass either way.
        if compact_below is not None:
            ok = s.edge_ok
        else:
            ok = edges.mask & s.alive[edges.src] & ta[edges.dst]
        w_alive = jnp.where(ok, edges.weight, 0.0)
        # (2): the degree count — the only backend-dependent step.
        if directed:
            deg_s, deg_t, total = backend.directed(edges, w_alive)
        else:
            deg_s, total = backend.undirected(edges, w_alive)
            deg_t = deg_s
        # (1): density + best-intermediate-set tracking.
        n_s, n_t = counts(s)
        rho = policy.density(total, n_s, n_t)
        stats = PassStats(rho=rho, total=total, n_s=n_s, n_t=n_t)

        improved = policy.eligible(n_s, n_t) & (rho > s.best_rho)
        best_alive = jnp.where(improved, s.alive, s.best_alive)
        best_t = jnp.where(improved, ta, s.best_t) if directed else s.best_t
        best_rho = jnp.where(improved, rho, s.best_rho)
        best_size = jnp.where(improved, n_s, s.best_size)

        rm_s, rm_t = policy.removal(s.alive, ta, deg_s, deg_t, stats)
        alive = s.alive & ~rm_s
        t_alive = (ta & ~rm_t) if directed else s.t_alive

        if compact_below is not None:
            ok_next = edges.mask & alive[edges.src] & (
                t_alive if directed else alive
            )[edges.dst]
            ae = _count_ok(backend, ok_next)
        else:
            ok_next, ae = s.edge_ok, s.alive_edges

        if track_history:
            hn = s.history_n.at[s.t].set(n_s)
            hm = s.history_m.at[s.t].set(total)
            hr = s.history_rho.at[s.t].set(rho)
        else:
            hn, hm, hr = s.history_n, s.history_m, s.history_rho
        return PeelState(
            alive, t_alive, best_alive, best_t, best_rho, best_size,
            s.t + 1, ae, ok_next, hn, hm, hr,
        )

    if compact_below is not None:
        if init_ok_from_mask:
            ok0 = edges.mask
        else:
            # One O(m) filter at segment entry; pass 1 reuses it.
            ok0 = (
                edges.mask
                & alive0[edges.src]
                & (ta0 if directed else alive0)[edges.dst]
            )
        if init_alive_edges is not None:
            ae0 = jnp.asarray(init_alive_edges, jnp.int32)
        else:
            ae0 = _count_ok(backend, ok0)
    else:
        ok0 = jnp.zeros((0,), bool)
        ae0 = jnp.asarray(0, jnp.int32)
    init = PeelState(
        alive=alive0,
        t_alive=ta0,
        best_alive=best0,
        best_t=(jnp.zeros_like(ta0) if init_best_empty else ta0) if directed else dummy,
        best_rho=jnp.asarray(-jnp.inf, jnp.float32),
        best_size=jnp.asarray(0, jnp.int32),
        t=t0,
        alive_edges=ae0,
        edge_ok=ok0,
        history_n=jnp.full((hist_len,), -1, jnp.int32),
        history_m=jnp.zeros((hist_len,), jnp.float32),
        history_rho=jnp.zeros((hist_len,), jnp.float32),
    )
    out = jax.lax.while_loop(cond, body, init)
    outcome = PeelOutcome(
        best_alive=out.best_alive,
        best_t=out.best_t,
        best_density=out.best_rho,
        best_size=out.best_size,
        passes=out.t,
        alive=out.alive,
        t_alive=out.t_alive,
        history_n=out.history_n,
        history_m=out.history_m,
        history_rho=out.history_rho,
    )
    if with_edge_state:
        if compact_below is None:
            raise ValueError("with_edge_state needs compact_below (the "
                             "carried filter is only materialized then)")
        return outcome, out.edge_ok, out.alive_edges
    return outcome


# ---------------------------------------------------------------------------
# Host-substrate policy step (the streaming driver's removal rule)
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("eps",))
def undirected_pass_step(
    alive: jax.Array, deg: jax.Array, total: jax.Array, eps: float
) -> Tuple[jax.Array, jax.Array]:
    """One Algorithm-1 pass on explicit node state: ``(new_alive, rho)``.

    The semi-streaming driver accumulates ``deg``/``total`` by chunked
    passes over out-of-core edges and then applies THIS step, so the
    threshold/removal logic is shared with every in-core substrate.
    """
    policy = UndirectedThreshold(eps)
    n_alive = jnp.sum(alive.astype(jnp.int32))
    total = jnp.asarray(total, jnp.float32)
    rho = policy.density(total, n_alive, n_alive)
    stats = PassStats(rho=rho, total=total, n_s=n_alive, n_t=n_alive)
    rm, _ = policy.removal(alive, alive, deg, deg, stats)
    return alive & ~rm, rho
