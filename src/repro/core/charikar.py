"""Charikar's node-at-a-time greedy 2-approximation [10] — the baseline the
paper builds on.  Removes the single minimum-degree node per step with a
lazy-deletion heap; O(m log n).  Host-side numpy (this is the *comparison*
algorithm; it needs n passes in the streaming model, which is the paper's
whole motivation)."""

from __future__ import annotations

import heapq
from typing import Tuple

import numpy as np

from repro.graph.edgelist import EdgeList, to_csr


def charikar_greedy(edges: EdgeList) -> Tuple[np.ndarray, float]:
    """Returns (node_indices, density) of the best intermediate subgraph."""
    indptr, indices = to_csr(edges)
    n = edges.n_nodes
    deg = np.diff(indptr).astype(np.int64)
    m = int(deg.sum()) // 2
    alive = np.ones(n, bool)
    heap = [(int(deg[v]), v) for v in range(n)]
    heapq.heapify(heap)

    best_density = m / n if n else 0.0
    removal_order = np.empty(n, np.int64)
    cur_m, cur_n = m, n
    best_step = 0  # number of removals in the best prefix
    for step in range(n):
        while True:
            d, v = heapq.heappop(heap)
            if alive[v] and d == deg[v]:
                break
        alive[v] = False
        removal_order[step] = v
        cur_m -= int(deg[v])
        cur_n -= 1
        for u in indices[indptr[v] : indptr[v + 1]]:
            if alive[u]:
                deg[u] -= 1
                heapq.heappush(heap, (int(deg[u]), int(u)))
        deg[v] = 0
        if cur_n > 0 and cur_m / cur_n > best_density:
            best_density = cur_m / cur_n
            best_step = step + 1
    keep = np.ones(n, bool)
    keep[removal_order[:best_step]] = False
    return np.nonzero(keep)[0], float(best_density)
