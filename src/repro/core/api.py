"""One front door for the paper's densest-subgraph algorithms.

The public surface is three names:

  * :class:`Problem` — a frozen, hashable spec of WHAT to solve: the
    objective (Algorithm 1/2/3), eps, k, the directed ratio c (or None for
    the geometric c-grid), the degree backend (``exact | sketch | pallas |
    auto``) and the launch substrate (``jit | mesh | streaming | auto``).
  * :func:`solve` / :class:`Solver` — lowers a Problem onto the PeelEngine's
    RemovalPolicy × DegreeBackend × substrate axes (core/engine.py) and runs
    it.  A Solver memoizes the jitted programs keyed on the Problem's static
    fields plus ``(n_nodes, padded m, dtype)`` so repeated calls at
    production request rates never retrace; :data:`default_solver` backs the
    module-level helpers and every legacy wrapper.
  * :func:`solve_batch` — the ROADMAP's batched driver: multi-eps, multi-c
    and stacked same-shape-graph sweeps as ONE vmapped XLA program (the
    engine is vmap-clean; the directed c-grid proved it).

Every result is a :class:`DenseSubgraphResult`: the engine's
:class:`~repro.core.engine.PeelOutcome` arrays plus a static
:class:`Provenance` recording which cell of the policy × backend × substrate
matrix actually ran.  The historical ``PeelResult`` / ``PeelTopKResult`` /
``DirectedPeelResult`` names are deprecated aliases of it.

Lowering map (Problem field -> engine axis)::

    objective  undirected   -> UndirectedThreshold(eps)           (Alg 1, §4.1)
               at_least_k   -> AtLeastKFraction(k, eps, variants) (Alg 2, §4.2)
               directed     -> DirectedST(eps, c)                 (Alg 3, §4.3)
    backend    exact        -> ExactBackend (segment_sum)
               sketch       -> SketchBackend / _MeshSketchBackend (§5.1)
               pallas       -> tiled-degree kernel via FnBackend  (kernels/)
    substrate  jit          -> jax.jit(run_peel)                  (peel*.py)
               mesh         -> shard_map + psum backends          (§5.2)
               streaming    -> StreamingDensest chunked driver    (§4, semi-streaming)
    compaction geometric    -> Solver._run_compacted ladder       (amortized O(m))
               twophase     -> same ladder, one fixed compaction  (legacy schedule)

The legacy entry points (``densest_subgraph``, ``densest_subgraph_at_least_k``,
``densest_subgraph_directed``, ``densest_directed_search``,
``densest_subgraph_sketched``, ``densest_subgraph_distributed``,
``StreamingDensest``) are thin delegations through this module's lowering
and stay bit-identical to their pre-redesign outputs.
"""

from __future__ import annotations

import collections
import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro import constants
from repro.core.density import max_passes_bound
from repro.core.engine import (
    AtLeastKFraction,
    DirectedST,
    ExactBackend,
    FnBackend,
    MeshSegmentSumBackend,
    PeelOutcome,
    RemovalPolicy,
    UndirectedThreshold,
    run_peel,
)
from repro.graph.edgelist import EdgeList
from repro.graph.partition import ladder_schedule, pow2_bucket

__all__ = [
    "DenseSubgraphResult",
    "Problem",
    "Provenance",
    "Solver",
    "default_solver",
    "deprecated_alias_getattr",
    "run_cell",
    "solve",
    "solve_batch",
    "stack_graphs",
]

_OBJECTIVES = ("undirected", "at_least_k", "directed")
_BACKENDS = ("exact", "sketch", "pallas", "auto")
_SUBSTRATES = ("jit", "mesh", "streaming", "local", "auto")
_COMPACTIONS = ("off", "twophase", "geometric", "auto")
_STREAM_MODES = ("insert", "turnstile")

# Above this node count, "auto" trades the O(n) exact degree vector for the
# O(t*b) Count-Sketch (§5.1's memory regime).
_AUTO_SKETCH_NODES = 1_000_000

# Geometric compaction ladder floors/capacities: aliased from the one
# constants surface (repro.constants — rationale and the pow2-constants
# analysis rule live there).  Module-level aliases keep the historical
# names monkeypatch-able (tests patch api._LADDER_MIN_EDGES to force deep
# ladders at tiny sizes).
_COMPACT_MIN_EDGES = constants.COMPACT_MIN_EDGES
_COMPACT_MIN_NODES = constants.COMPACT_MIN_NODES
_COMPACT_MAX_SEGMENTS = constants.COMPACT_MAX_SEGMENTS
_LADDER_STRIDE = constants.LADDER_STRIDE
_LADDER_MIN_EDGES = constants.LADDER_MIN_EDGES
_LOCAL_BUDGET = constants.LOCAL_BUDGET
_LOCAL_ROUNDS = constants.LOCAL_ROUNDS


# ---------------------------------------------------------------------------
# Problem — the declarative spec
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Problem:
    """What to solve.  Frozen and hashable: the static half of a Solver
    cache key.  Use the :meth:`undirected` / :meth:`at_least_k` /
    :meth:`directed` constructors for the common cases; 30-second tour::

        from repro.core import Problem, solve
        res = solve(edges, Problem.undirected(eps=0.5))
        res.best_density, res.nodes(), res.provenance

    Field-by-field reference (fields marked *cache-key-exempt* never force
    a recompile: the Solver drops them from program-cache keys whenever the
    resolved cell does not read them — see :meth:`Solver._key`):

    **Objective** (which algorithm):

    * ``objective`` — ``'undirected'`` (Alg 1), ``'at_least_k'`` (Alg 2),
      ``'directed'`` (Alg 3).
    * ``eps`` — slack of the removal threshold ``2(1+eps)·rho``; drives
      both the approximation factor and the O(log n / eps) pass bound.
    * ``k`` — Alg 2 only: minimum ``|S|``.
    * ``c`` — Alg 3 only: the ``|S|/|T|`` ratio guess; ``None`` sweeps the
      geometric c-grid (resolution ``c_delta``), the paper's practical
      recipe.  ``c`` enters compiled programs as a RUNTIME scalar, so the
      whole grid shares one compilation (cache-key-exempt on those kinds).
    * ``c_delta`` — grid ratio (> 1); host-side only, cache-key-exempt.
    * ``max_passes`` — static trip count; ``None`` means the Lemma 4 bound
      (doubled for directed, Lemma 13).  Keys the cache via its resolved
      value.
    * ``track_history`` — record per-pass ``(|S|, edge mass, rho)``.

    **Backend** (how induced degrees are computed):

    * ``backend`` — ``'exact'`` (segment_sum), ``'sketch'`` (§5.1
      Count-Sketch), ``'pallas'`` (tiled TPU kernel), or ``'auto'``
      (sketch above ~1M nodes, exact otherwise; exact when a ladder or the
      streaming substrate constrains it).
    * ``sketch_tables`` / ``sketch_buckets`` / ``sketch_seed`` — §5.1
      table geometry; cache-key-exempt unless the sketch backend runs.
    * ``sketch_node_chunk`` — mesh sketch only: degree-query streaming
      chunk (bounds the transient query footprint).
    * ``tile_size`` / ``tile_block`` — Pallas tile geometry;
      cache-key-exempt unless the pallas backend runs.
    * ``pallas_interpret`` — ``None`` = compiled on TPU, interpreter
      elsewhere; ``True`` forces interpret mode.

    **Substrate** (how the loop is launched):

    * ``substrate`` — ``'jit'``, ``'mesh'`` (shard_map over an
      edge-sharded device mesh, §5.2; needs ``solve(..., mesh=...)``),
      ``'streaming'`` (host-chunked driver, O(n) node state),
      ``'local'`` (Andersen per-seed exploration, below), or
      ``'auto'`` (mesh iff a mesh was supplied and >1 device is visible).
    * ``edge_axes`` / ``wire_dtype`` — mesh only: shard axes and the
      degree-psum wire format (``'bf16'`` halves the dominant collective);
      cache-key-exempt elsewhere.
    * ``stream_chunk`` / ``stream_workers`` — streaming chunk size and
      worker pool.
    * ``stream_prefetch`` — bounds the chunks resident in the async
      pipeline (the out-of-core memory contract; bit-identical to the
      synchronous order for every setting).
    * ``spill_dir`` — sends the streaming ladder's rebuilt survivor
      streams to disk-backed memmaps (atomic manifest, resume re-enters
      mid-rung).  Needs the geometric ladder: rejected on the streaming
      substrate with an explicit ``compaction='off'``/``'twophase'``.
    * ``residency_cap_edges`` — errors a too-big IN-RAM streaming rebuild
      instead of spiking memory (the spilled path is exempt — that is its
      point); pair it with ``spill_dir`` to make the cap recoverable.
      All ``stream_*``/``spill_dir``/``residency_cap_edges`` knobs are
      host-side driver state: uniformly cache-key-exempt, and ignored on
      non-streaming substrates (the irrelevant-knob convention).

    **Turnstile runtime** (dynamic graph streams with DELETIONS — the MTVV
    ℓ0-sampling runtime, core/turnstile.py; both fields are uniformly
    cache-key-exempt: the driver is host-side and its sample peel
    re-enters the program cache as an ordinary insert-mode solve):

    * ``stream_mode`` — ``'insert'`` (default; every substrate's classic
      append-only edge stream) or ``'turnstile'``: the graph is a dynamic
      stream of ±edge update batches absorbed by an ℓ0-sampling sketch,
      peeled on a uniform edge sample with density rescaled by the sample
      rate ((1+eps)·(2+2eps) end-to-end).  ``solve()`` one-shots it
      (insert the given edges, answer one query); continuous
      update/query cycles hold a live :class:`repro.core.turnstile.
      TurnstileDensest` (or the serve/ service).  Undirected, unweighted,
      exact/pallas degree backends only — ``backend='sketch'`` is
      rejected (it would sketch a sketch); mesh/streaming substrates are
      rejected; compaction is ignored (nothing to amortize at sample
      scale).
    * ``sample_edges`` — the sample budget τ: queries recover the lowest
      sketch level holding at most this many edges (level 0 ⇒ the exact
      live graph).  Larger τ tightens the sampling (1+eps) factor at
      O(τ·log n) sketch memory.  ``sketch_seed`` (below) also seeds the
      ℓ0 hash family — same seed, bit-reproducible runs.

    **Local substrate** (Andersen's per-seed exploration, arXiv
    cs/0702078 — core/local.py; all three knobs are host-side extraction
    state, uniformly cache-key-exempt: the compiled program only ever
    sees the bucket-padded candidate subgraph):

    * ``substrate='local'`` answers PER-SEED queries: ``solve(graph,
      problem, seed=<node id>)`` grows a pruned-frontier candidate set
      around the seed (work bounded by the budget, independent of n) and
      peels its induced subgraph through the same cached jit pass body.
      Undirected objective and exact backend only; compaction is forced
      off (nothing to amortize at candidate scale).  Provenance reports
      ``substrate='local'`` and ``extras['local']`` carries the
      exploration counters.  The result's density never exceeds the
      exact optimum and is (2+2eps)-approximate FOR THE CANDIDATE SET —
      the whole-graph guarantee does not survive locality
      (docs/serving.md; pinned by tests/test_property_serve.py).
    * ``local_budget`` — candidate-set size cap (the per-query work
      knob; the serving engine's degrade ladder halves it under
      pressure).
    * ``local_rounds`` — frontier expansion round cap.
    * ``local_alpha`` — prune threshold scale: a frontier vertex joins
      only with ``deg into T >= max(local_alpha * rho(T), 1)``; 1.0
      admits exactly the vertices that cannot dilute T's density.

    **Serving** (host-side, cache-key-exempt):

    * ``cache_dir`` — backs the Solver's program cache with an on-disk tier
      of serialized compiled executables, so a fresh process (a serving
      replica, a restarted worker) skips the cold compile entirely
      (``jax.experimental.serialize_executable`` under the hood; entries
      are fingerprinted by backend + jax/jaxlib/repro versions and any
      mismatch or corruption silently falls back to a recompile — see
      core/progcache.py and docs/serving.md).  ``Solver(cache_dir=...)``
      takes precedence; jit-substrate programs only (mesh executables embed
      a device topology and stay in-memory).

    **Compaction runtime** (the scheduling knob; host/ladder state, so the
    whole group is cache-key-exempt — segment programs key on bucket
    shapes instead):

    * ``compaction`` — ``'off'``: classic single-segment loop;
      ``'geometric'``: the amortized-O(m) ladder — run in segments, gather
      survivors into the next power-of-two bucket when the alive edge
      count falls below the trigger (on the mesh substrate the WHOLE
      ladder is one compiled collective-only program); ``'twophase'``:
      exactly one compaction after ``twophase_passes`` passes (the
      historical ``make_distributed_peel_twophase`` schedule); ``'auto'``
      (DEFAULT): geometric for exact/pallas, off for sketch (Count-Sketch
      estimates hash node ids, so renumbering would change them).
      Compaction is pure renumbering: results are bit-identical to
      ``'off'`` for integer-valued edge weights (e.g. unweighted graphs).
      See docs/compaction.md.
    * ``twophase_passes`` — twophase phase-1 pass budget.
    * ``min_deg_fallback`` / ``ceil_count`` — Alg 2 realization variants
      (floor+fallback = single-device legacy, ceil without = distributed
      legacy); cache-key-exempt for other objectives.
    """

    objective: str = "undirected"
    eps: float = 0.5
    k: Optional[int] = None  # at_least_k: minimum |S|
    c: Optional[float] = None  # directed: |S|/|T| guess; None -> grid
    c_delta: float = 2.0  # directed grid resolution (§6.4)
    backend: str = "exact"
    substrate: str = "jit"
    max_passes: Optional[int] = None  # None -> Lemma 4/13 bound
    track_history: bool = False
    # Compaction runtime (scheduling; never keys compiled programs).  The
    # default is 'auto' (ROADMAP soak item, flipped after PRs 3-4): exact and
    # pallas backends ride the geometric ladder by default, sketch stays off.
    compaction: str = "auto"  # off | twophase | geometric | auto
    twophase_passes: int = 8  # compaction='twophase': phase-1 pass budget
    # Algorithm 2 realization knobs (floor+fallback = single-device legacy,
    # ceil w/o fallback = distributed legacy).
    min_deg_fallback: bool = True
    ceil_count: bool = False
    # Count-Sketch (§5.1) parameters.
    sketch_tables: int = 5
    sketch_buckets: int = 1 << 13
    sketch_seed: int = 0
    sketch_node_chunk: int = 1 << 20  # mesh sketch: query streaming chunk
    # Pallas tiled-degree kernel parameters.  ``pallas_interpret=None`` means
    # "compiled on TPU, interpreter elsewhere" (kernels resolve it against
    # jax.default_backend()); True forces interpret mode everywhere.
    tile_size: int = 1024
    tile_block: int = 512
    pallas_interpret: Optional[bool] = None
    # Mesh substrate parameters.
    edge_axes: Tuple[str, ...] = ("data",)
    wire_dtype: str = "f32"  # f32 | bf16 degree-psum wire format
    # Streaming substrate parameters.  ``stream_prefetch`` bounds the chunks
    # resident in the async pipeline; ``spill_dir`` sends the geometric
    # ladder's rebuilt survivor streams to disk-backed memmaps (out-of-core
    # compaction; None keeps survivors in host RAM).
    stream_chunk: int = 1 << 20
    stream_workers: int = 4
    stream_prefetch: int = 8
    spill_dir: Optional[str] = None
    residency_cap_edges: Optional[int] = None
    # Turnstile runtime (±edge update streams, core/turnstile.py).  Host
    # driver state, uniformly cache-key-exempt; ``sketch_seed`` above also
    # seeds the ℓ0 hash family.
    stream_mode: str = "insert"  # insert | turnstile
    sample_edges: int = 1 << 14  # ℓ0 sample budget τ (per-query peel size)
    # Local (Andersen) substrate parameters (core/local.py).  Host-side
    # exploration state, uniformly cache-key-exempt: the compiled program
    # only ever sees the bucket-padded candidate subgraph.
    local_budget: int = _LOCAL_BUDGET  # candidate-set size cap
    local_rounds: int = _LOCAL_ROUNDS  # frontier expansion round cap
    local_alpha: float = 1.0  # prune scale: deg into T >= alpha * rho(T)
    # Persistent program cache (host-side knob, uniformly cache-key-exempt):
    # directory for serialized compiled programs so a FRESH process skips the
    # cold compile (see core/progcache.py and docs/serving.md).  A
    # Solver(cache_dir=...) setting takes precedence over this field.
    cache_dir: Optional[str] = None

    def __post_init__(self):
        if self.objective not in _OBJECTIVES:
            raise ValueError(
                f"objective={self.objective!r} not in {_OBJECTIVES}"
            )
        if self.backend not in _BACKENDS:
            raise ValueError(f"backend={self.backend!r} not in {_BACKENDS}")
        if self.substrate not in _SUBSTRATES:
            raise ValueError(
                f"substrate={self.substrate!r} not in {_SUBSTRATES}"
            )
        if self.compaction not in _COMPACTIONS:
            raise ValueError(
                f"compaction={self.compaction!r} not in {_COMPACTIONS}"
            )
        if self.twophase_passes < 1:
            raise ValueError(
                f"twophase_passes={self.twophase_passes} must be >= 1"
            )
        if self.objective == "at_least_k" and (self.k is None or self.k < 1):
            raise ValueError("objective='at_least_k' needs k >= 1")
        if self.c_delta <= 1.0:
            raise ValueError(
                f"c_delta={self.c_delta} must be > 1 (geometric grid ratio)"
            )
        if self.wire_dtype not in ("f32", "bf16"):
            raise ValueError(f"wire_dtype={self.wire_dtype!r} not in (f32, bf16)")
        if self.stream_prefetch < 1:
            raise ValueError(
                f"stream_prefetch={self.stream_prefetch} must be >= 1"
            )
        if self.residency_cap_edges is not None and self.residency_cap_edges < 1:
            raise ValueError(
                f"residency_cap_edges={self.residency_cap_edges} must be >= 1"
            )
        if self.stream_mode not in _STREAM_MODES:
            raise ValueError(
                f"stream_mode={self.stream_mode!r} not in {_STREAM_MODES}"
            )
        if self.sample_edges < 1:
            raise ValueError(f"sample_edges={self.sample_edges} must be >= 1")
        if self.local_budget < 1:
            raise ValueError(f"local_budget={self.local_budget} must be >= 1")
        if self.local_rounds < 1:
            raise ValueError(f"local_rounds={self.local_rounds} must be >= 1")
        if self.local_alpha < 0:
            raise ValueError(f"local_alpha={self.local_alpha} must be >= 0")
        if not isinstance(self.edge_axes, tuple):
            object.__setattr__(self, "edge_axes", tuple(self.edge_axes))

    # -- constructors -------------------------------------------------------
    @classmethod
    def undirected(cls, eps: float = 0.5, **kw) -> "Problem":
        """Algorithm 1: (2+2eps)-approximate densest subgraph."""
        return cls(objective="undirected", eps=float(eps), **kw)

    @classmethod
    def at_least_k(cls, k: int, eps: float = 0.5, **kw) -> "Problem":
        """Algorithm 2: (3+3eps)-approximate densest subgraph, |S| >= k."""
        return cls(objective="at_least_k", k=int(k), eps=float(eps), **kw)

    @classmethod
    def directed(
        cls, c: Optional[float] = None, eps: float = 0.5, **kw
    ) -> "Problem":
        """Algorithm 3: directed densest subgraph, fixed c or c-grid."""
        return cls(
            objective="directed",
            c=None if c is None else float(c),
            eps=float(eps),
            **kw,
        )

    # -- resolution ---------------------------------------------------------
    def resolve(self, n_nodes: int, have_mesh: bool = False) -> "Problem":
        """Resolves ``auto`` axes against the graph/host and validates that
        the requested matrix cell exists.  ``auto`` only picks the mesh
        substrate when the caller actually supplied a mesh (``have_mesh``)."""
        if self.stream_mode == "turnstile":
            # The turnstile runtime is its own cell: sketch updates on
            # device, sampled peel on the jit substrate (core/turnstile.py).
            if self.objective != "undirected":
                raise ValueError(
                    "stream_mode='turnstile' implements Algorithm 1 over "
                    "the MTVV edge sample; use objective='undirected'"
                )
            if self.backend == "sketch":
                raise ValueError(
                    "backend='sketch' under stream_mode='turnstile' would "
                    "sketch a sketch: the ℓ0 edge sample already bounds the "
                    "peel's degree memory — use backend='exact' or 'pallas'"
                )
            if self.substrate in ("mesh", "streaming", "local"):
                raise ValueError(
                    "stream_mode='turnstile' is its own runtime (device "
                    "sketch + sampled peel on the jit substrate); use "
                    "substrate='jit' or 'auto'"
                )
            # Compaction is an irrelevant knob at sample scale: quietly
            # ignored, like stream_* off the streaming substrate.
            return dataclasses.replace(
                self,
                backend="exact" if self.backend == "auto" else self.backend,
                substrate="jit",
                compaction="off",
            )
        if self.substrate == "local":
            # Andersen local exploration: host frontier pruning + a jit
            # solve of the bucket-padded candidate subgraph (core/local.py).
            if self.objective != "undirected":
                raise ValueError(
                    "substrate='local' prunes its frontier against the "
                    "undirected density (Andersen, arXiv cs/0702078); use "
                    "objective='undirected'"
                )
            if self.backend in ("sketch", "pallas"):
                raise ValueError(
                    "substrate='local' peels a budget-bounded candidate "
                    "subgraph — degree sketching/tiling has nothing to "
                    "amortize at that scale; use backend='exact' (or 'auto')"
                )
            # Compaction is an irrelevant knob at candidate scale: quietly
            # forced off, like the turnstile runtime.
            return dataclasses.replace(
                self,
                backend="exact" if self.backend == "auto" else self.backend,
                compaction="off",
            )
        backend = self.backend
        substrate = self.substrate
        if substrate == "auto":
            substrate = "mesh" if have_mesh and len(jax.devices()) > 1 else "jit"
        if backend == "auto":
            # The streaming driver IS the large-graph memory regime (O(n)
            # node state, out-of-core edges): its only cell is exact.
            if substrate == "streaming":
                backend = "exact"
            elif self.compaction in ("geometric", "twophase"):
                # An explicit compaction request constrains the resolution:
                # sketch estimates hash node ids, so only exact-arithmetic
                # backends can ride the ladder.
                backend = "exact"
            else:
                backend = "sketch" if n_nodes > _AUTO_SKETCH_NODES else "exact"
        compaction = self.compaction
        if compaction == "auto":
            # Geometric compaction is pure renumbering for exact-arithmetic
            # backends; Count-Sketch estimates hash node ids, so renumbering
            # would change them — auto keeps sketch runs uncompacted.
            compaction = "geometric" if backend in ("exact", "pallas") else "off"
        p = self
        if (
            backend != self.backend
            or substrate != self.substrate
            or compaction != self.compaction
        ):
            p = dataclasses.replace(
                self, backend=backend, substrate=substrate, compaction=compaction
            )
        if p.compaction != "off" and p.backend == "sketch":
            raise ValueError(
                "compaction renumbers node ids, which changes Count-Sketch "
                "degree estimates; backend='sketch' needs compaction='off'"
            )
        if p.compaction == "twophase" and p.substrate == "streaming":
            raise ValueError(
                "the streaming driver compacts geometrically; use "
                "compaction='geometric' or 'off' with substrate='streaming'"
            )
        if (
            p.spill_dir is not None
            and p.substrate == "streaming"
            and p.compaction != "geometric"
        ):
            # (On non-streaming substrates stream_* knobs — spill_dir
            # included — are uniformly ignored, per the irrelevant-knob
            # convention the program-cache keys rely on.)
            raise ValueError(
                "spill_dir is the streaming ladder's disk spill; a "
                "streaming solve needs compaction='geometric' (or 'auto') "
                "to use it"
            )
        if p.objective == "directed" and p.backend == "pallas":
            raise ValueError(
                "the tiled-degree kernel counts both endpoints (undirected); "
                "directed objectives need backend='exact' or 'sketch'"
            )
        if p.substrate == "mesh" and p.backend == "pallas":
            raise ValueError("backend='pallas' has no mesh (shard_map) cell yet")
        if p.substrate == "streaming" and (
            p.objective != "undirected" or p.backend != "exact"
        ):
            raise ValueError(
                "the streaming substrate implements Algorithm 1 with exact "
                "chunked degrees; use objective='undirected', backend='exact'"
            )
        return p

    def resolved_max_passes(self, n_nodes: int) -> int:
        """Static trip count: explicit, or the Lemma 4 bound (doubled for
        directed runs — Lemma 13 shrinks one of S/T per pass)."""
        if self.max_passes is not None:
            return int(self.max_passes)
        bound = max_passes_bound(n_nodes, self.eps)
        return 2 * bound if self.objective == "directed" else bound


# The machine-checked cache-key classification of EVERY Problem field (the
# ``cache-key-hygiene`` analysis rule parses this dict and cross-checks it
# against the dataclass — a new field that is not classified here is a
# lint error, so the contract can never silently rot):
#
#   'static'      — part of what the compiled program computes; always in
#                   the program-cache key (modulo the runtime-argument
#                   carve-outs _key documents, e.g. c / swept eps).
#   'conditional' — keys the cache only when the resolved cell reads it
#                   (sketch geometry, pallas tiles, mesh wiring); dropped
#                   otherwise so irrelevant knobs never force a recompile.
#   'exempt'      — host-side driver/scheduling state, NEVER part of a
#                   compiled program: uniformly dropped from cache keys,
#                   and reading one inside a traced program builder is a
#                   lint error (it would bake a host knob into compiled
#                   output without keying it — the cache-poisoning bug
#                   class PR 4's review caught by hand).
_FIELD_CLASS = {
    "objective": "static",
    "eps": "static",
    "k": "static",
    "c": "static",
    "backend": "static",
    "substrate": "static",
    "max_passes": "static",  # keys via its RESOLVED value (the mp slot)
    "track_history": "static",
    "min_deg_fallback": "static",
    "ceil_count": "static",
    "sketch_tables": "conditional",
    "sketch_buckets": "conditional",
    "sketch_seed": "conditional",
    "sketch_node_chunk": "conditional",
    "tile_size": "conditional",
    "tile_block": "conditional",
    "pallas_interpret": "conditional",
    "edge_axes": "conditional",
    "wire_dtype": "conditional",
    "c_delta": "exempt",
    "compaction": "exempt",
    "twophase_passes": "exempt",
    "stream_chunk": "exempt",
    "stream_workers": "exempt",
    "stream_prefetch": "exempt",
    "spill_dir": "exempt",
    "residency_cap_edges": "exempt",
    "stream_mode": "exempt",
    "sample_edges": "exempt",
    "local_budget": "exempt",
    "local_rounds": "exempt",
    "local_alpha": "exempt",
    "cache_dir": "exempt",
}

# The uniform exclusion set _key starts from (max_passes keys separately
# through its resolved value).
_EXEMPT_FIELDS = frozenset(
    f for f, cls in _FIELD_CLASS.items() if cls == "exempt"
)


# ---------------------------------------------------------------------------
# Result type — PeelOutcome arrays + provenance
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Provenance:
    """Which cell of the policy × backend × substrate matrix produced a
    result (static metadata, hashable)."""

    objective: str
    policy: str
    backend: str
    substrate: str
    n_nodes: int
    max_passes: int
    batch: Optional[str] = None  # None | "eps" | "c" | "graphs"
    cache_hit: bool = False
    compaction: str = "off"  # off | twophase | geometric (resolved)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DenseSubgraphResult:
    """The one result type of the front door (and the deprecation target of
    ``PeelResult`` / ``PeelTopKResult`` / ``DirectedPeelResult``).

    Field-compatible with :class:`~repro.core.engine.PeelOutcome`; batched
    solves carry a leading sweep axis on every array.  ``extras`` holds
    sweep-level host data (the directed grid's per-c profile).
    """

    best_alive: jax.Array  # bool[N] the output set S~ (S side for directed)
    best_t: jax.Array  # bool[N] T side (directed) | bool[0]
    best_density: jax.Array  # float32[] rho of the best set
    best_size: jax.Array  # int32[] |S~|
    passes: jax.Array  # int32[] passes executed
    alive: jax.Array  # bool[N] final S bitmap
    t_alive: jax.Array  # bool[N] final T bitmap | bool[0]
    history_n: jax.Array  # int32[hist] per-pass |S| (-1 padding)
    # Per-pass edge mass of S.  jit/mesh record the alive WEIGHT total; the
    # streaming substrate records the alive edge COUNT (its O(n)-state
    # contract) — identical for unit weights.
    history_m: jax.Array  # float32[hist]
    history_rho: jax.Array  # float32[hist] per-pass rho
    extras: Optional[Dict[str, Any]] = None
    provenance: Optional[Provenance] = dataclasses.field(
        default=None, metadata=dict(static=True)
    )

    @property
    def best_s(self) -> jax.Array:
        """Directed-result spelling of the S-side best bitmap."""
        return self.best_alive

    @property
    def mask(self) -> jax.Array:
        return self.best_alive

    @classmethod
    def from_outcome(
        cls,
        out: PeelOutcome,
        provenance: Optional[Provenance] = None,
        extras: Optional[Dict[str, Any]] = None,
    ) -> "DenseSubgraphResult":
        return cls(*out, extras=extras, provenance=provenance)

    # Host conveniences (not for use under tracing).
    def nodes(self) -> np.ndarray:
        """Node ids of the best set (S side for directed)."""
        return np.nonzero(np.asarray(self.best_alive))[0]

    def t_nodes(self) -> np.ndarray:
        """Node ids of the best T side (directed results)."""
        return np.nonzero(np.asarray(self.best_t))[0]

    @property
    def density(self) -> float:
        return float(self.best_density)


# ---------------------------------------------------------------------------
# Lowering: Problem -> RemovalPolicy × DegreeBackend
# ---------------------------------------------------------------------------


def _policy_for(
    problem: Problem, *, eps: Any = None, c: Any = None
) -> RemovalPolicy:
    """Problem -> RemovalPolicy.  ``eps``/``c`` may be traced scalars (the
    batched sweeps rely on it)."""
    e = problem.eps if eps is None else eps
    if problem.objective == "undirected":
        return UndirectedThreshold(e)
    if problem.objective == "at_least_k":
        return AtLeastKFraction(
            k=problem.k,
            eps=e,
            min_deg_fallback=problem.min_deg_fallback,
            ceil_count=problem.ceil_count,
        )
    cc = problem.c if c is None else c
    if cc is None:
        raise ValueError(
            "directed lowering needs a concrete or traced c; Problem.c=None "
            "(grid search) is handled by solve()/solve_batch()"
        )
    return DirectedST(eps=e, c=jnp.asarray(cc, jnp.float32))


def _backend_for(
    problem: Problem,
    n_nodes: int,
    *,
    degree_fn: Optional[Callable] = None,
    tiling: Optional[Tuple[jax.Array, jax.Array]] = None,
):
    """Problem -> DegreeBackend (jit substrate).  ``degree_fn`` is the
    legacy hook escape hatch; ``tiling`` carries the Pallas bucketing arrays
    as runtime values so compiled programs stay graph-independent."""
    if degree_fn is not None:
        return FnBackend(degree_fn)
    if problem.backend == "exact":
        return ExactBackend()
    if problem.backend == "sketch":
        from repro.core.countsketch import SketchBackend, make_sketch_params

        return SketchBackend(
            make_sketch_params(
                problem.sketch_tables, problem.sketch_buckets, problem.sketch_seed
            )
        )
    if problem.backend == "pallas":
        if tiling is None:
            raise ValueError("backend='pallas' needs tiling arrays")
        from repro.kernels.peel_degree.ops import tiled_degrees

        tl, ei = tiling

        def fn(edges: EdgeList, w_alive: jax.Array) -> jax.Array:
            return tiled_degrees(
                tl, ei, w_alive,
                tile_size=problem.tile_size, n_nodes=n_nodes,
                interpret=problem.pallas_interpret,
            )

        return FnBackend(fn)
    raise ValueError(f"unresolved backend {problem.backend!r}")


def run_cell(
    edges: EdgeList,
    problem: Problem,
    *,
    eps: Any = None,
    c: Any = None,
    degree_fn: Optional[Callable] = None,
    tiling: Optional[Tuple[jax.Array, jax.Array]] = None,
    max_passes: Optional[int] = None,
    init_alive: Optional[jax.Array] = None,
    init_t_alive: Optional[jax.Array] = None,
    init_t: Optional[jax.Array] = None,
    init_best_empty: bool = False,
    compact_below: Optional[int] = None,
    init_alive_edges: Optional[jax.Array] = None,
    init_ok_from_mask: bool = False,
) -> PeelOutcome:
    """The pure, traceable lowering core: one Problem cell -> ``run_peel``.

    Safe under jit/vmap/shard_map; ``eps`` and ``c`` may be traced scalars.
    Everything in solve()/solve_batch() and every legacy wrapper bottoms out
    here (substrates add their own launch wrappers around it).  The
    ``init_*``/``compact_below`` segment controls are forwarded to
    :func:`~repro.core.engine.run_peel` — ``run_cell`` itself is always ONE
    segment; the host-side compaction ladder around it lives in
    :class:`Solver` (``Problem.compaction`` is ignored here).
    """
    prob = problem.resolve(edges.n_nodes)
    mp = max_passes if max_passes is not None else prob.resolved_max_passes(edges.n_nodes)
    policy = _policy_for(prob, eps=eps, c=c)
    backend = _backend_for(prob, edges.n_nodes, degree_fn=degree_fn, tiling=tiling)
    return run_peel(
        edges, policy, backend, mp, track_history=prob.track_history,
        init_alive=init_alive, init_t_alive=init_t_alive, init_t=init_t,
        init_best_empty=init_best_empty, compact_below=compact_below,
        init_alive_edges=init_alive_edges, init_ok_from_mask=init_ok_from_mask,
    )


def c_grid(n_nodes: int, delta: float = 2.0) -> np.ndarray:
    """Geometric grid of c = |S|/|T| guesses: delta^j covering [1/n, n]."""
    j_max = int(math.ceil(math.log(max(n_nodes, 2)) / math.log(delta)))
    return np.asarray([delta**j for j in range(-j_max, j_max + 1)], np.float32)


def stack_graphs(graphs: Sequence[EdgeList]) -> EdgeList:
    """Stacks same-shape EdgeLists along a leading batch axis for
    :meth:`Solver.solve_batch` (which also accepts the sequence directly).
    The result is a batched container: per-graph helpers that assume 1-D
    edge arrays (``n_edges_padded``, ``with_padding``) don't apply to it."""
    g0 = graphs[0]
    for g in graphs[1:]:
        if g.n_nodes != g0.n_nodes or g.n_edges_padded != g0.n_edges_padded:
            raise ValueError(
                "stacked sweeps need same-shape graphs: got "
                f"(n={g.n_nodes}, E={g.n_edges_padded}) vs "
                f"(n={g0.n_nodes}, E={g0.n_edges_padded})"
            )
        if g.directed != g0.directed:
            raise ValueError("stacked sweeps need uniform directedness")
    return EdgeList(
        src=jnp.stack([g.src for g in graphs]),
        dst=jnp.stack([g.dst for g in graphs]),
        weight=jnp.stack([g.weight for g in graphs]),
        mask=jnp.stack([g.mask for g in graphs]),
        n_nodes=g0.n_nodes,
        directed=g0.directed,
    )


def deprecated_alias_getattr(module_name: str, aliases: Dict[str, Any]):
    """Builds a module ``__getattr__`` that serves deprecated names with a
    DeprecationWarning (the PeelResult-family shims share this one body)."""

    def __getattr__(name: str):
        target = aliases.get(name)
        if target is not None:
            import warnings

            warnings.warn(
                f"{module_name}.{name} is deprecated; use "
                "repro.core.DenseSubgraphResult",
                DeprecationWarning,
                stacklevel=2,
            )
            return target
        raise AttributeError(f"module {module_name!r} has no attribute {name!r}")

    return __getattr__


def _tiling_arrays(edges: EdgeList, problem: Problem, pow2_pad: bool = False):
    """Host-side Pallas tile bucketing for this graph (runtime args of the
    cached program, so the compiled code is reusable across graphs).

    This is an O(E) numpy pass per call — the compiled program is cached but
    the bucketing is not (it depends on edge CONTENT, which a shape-keyed
    cache cannot see).  For request-rate serving of one graph, bucket once
    and pass ``degree_fn=degree_fn_from_tiling(tiled)`` instead: the hook
    keys the program cache by identity and skips the per-call rebuild.

    ``pow2_pad`` rounds the per-tile edge capacity up to a power of two so
    the compaction ladder's re-bucketed tilings land on a bounded set of
    shapes (one compile per bucket, reused across segments and graphs)."""
    from repro.kernels.peel_degree.ops import tiling_for_edges

    tiled = tiling_for_edges(
        edges, tile_size=problem.tile_size, block=problem.tile_block,
        pow2_pad=pow2_pad,
    )
    return jnp.asarray(tiled.target_local), jnp.asarray(tiled.edge_index)


# ---------------------------------------------------------------------------
# Solver — compile caching + batched drivers
# ---------------------------------------------------------------------------


def _host_keep_going(prob: Problem, n_s: int, n_t: int) -> bool:
    """Host mirror of the policies' ``keep_going`` tests, used by the
    compaction scheduler to decide whether a segment ended by termination
    or by hitting its compaction trigger."""
    if prob.objective == "at_least_k":
        return n_s >= int(prob.k)
    if prob.objective == "directed":
        return n_s > 0 and n_t > 0
    return n_s > 0


def _policy_name(problem: Problem) -> str:
    return {
        "undirected": "undirected_threshold",
        "at_least_k": "at_least_k_fraction",
        "directed": "directed_st",
    }[problem.objective]


def _fields_key(problem: Problem, exclude: Tuple[str, ...] = ()) -> Tuple:
    """Hashable tuple of the Problem's static fields, minus the fields a
    program takes as runtime arguments (c for directed programs, eps for
    eps-sweeps)."""
    return tuple(
        (f.name, getattr(problem, f.name))
        for f in dataclasses.fields(problem)
        if f.name not in exclude
    )


class _DiskBackedProgram:
    """A cached program with an on-disk tier: per concrete input signature,
    either loads a serialized executable from ``cache_dir`` (no trace, no
    lowering, no XLA compile) or AOT-compiles the wrapped jitted program and
    publishes it.  The signature is part of the disk key because one Solver
    key can legally serve several input shapes (e.g. the eps-sweep program
    re-specializes per eps-vector length, exactly like ``jax.jit`` would)."""

    def __init__(self, solver: "Solver", jit_fn: Callable, cache_dir: str, key: Tuple):
        self._solver = solver
        self._jit = jit_fn
        self._dir = cache_dir
        self._key = key
        self._execs: Dict[Tuple, Callable] = {}

    @staticmethod
    def _sig(args) -> Tuple:
        return tuple(
            (tuple(leaf.shape), str(leaf.dtype))
            for leaf in jax.tree_util.tree_leaves(args)
        )

    def _resolve(self, sig: Tuple, args) -> Callable:
        from repro.core import progcache

        disk_key = (self._key, sig)
        path = progcache.entry_path(self._dir, disk_key)
        loaded = progcache.load(path, disk_key)
        if loaded is not None:
            self._solver.disk_hits += 1
            return loaded
        self._solver.disk_misses += 1
        compiled = self._jit.lower(*args).compile()
        if not progcache.store(path, disk_key, compiled):
            self._solver.disk_store_errors += 1
            # Rate-limited observability: warn ONCE per solver on the first
            # failed publish (every subsequent failure only counts) — a
            # full/read-only cache dir degrades cold-start, not answers.
            if self._solver.disk_store_errors == 1:
                import logging

                logging.getLogger("repro.progcache").warning(
                    "persistent program cache store failed (dir=%s); solves "
                    "continue but fresh processes will recompile — further "
                    "failures are counted in Solver.disk_store_errors "
                    "without logging",
                    self._dir,
                )
        return compiled

    def __call__(self, *args):
        sig = self._sig(args)
        fn = self._execs.get(sig)
        if fn is None:
            fn = self._resolve(sig, args)
            self._execs[sig] = fn
        return fn(*args)


# Program kinds eligible for the disk tier: single-device jit programs.
# Mesh executables (mesh/cseg_mesh/ladder_mesh) embed a device topology and
# their keys hold live Mesh objects — they stay in-memory only.
_DISK_KINDS = ("solve", "eps", "c", "graphs", "cseg")


class Solver:
    """The stateful front door: memoizes jitted programs so same-shape
    requests never retrace.

    Cache key: ``(kind, problem static fields, max_passes, n_nodes,
    padded m, weight dtype, degree_fn, aux shapes | mesh)``.  ``trace_count``
    counts actual retraces (incremented inside the traced Python bodies) and
    ``cache_hits``/``cache_misses`` count program-cache lookups — the
    observability hooks the retrace tests and bench_api use.

    ``cache_dir`` adds a PERSISTENT tier under the in-memory cache: compiled
    programs are serialized to disk (``core/progcache.py``) so a fresh
    process pays zero compiles for shapes any earlier process already
    served — ``disk_hits``/``disk_misses`` count that tier's lookups.  A
    ``Problem(cache_dir=...)`` enables the same per-request (the Solver
    argument wins when both are set).

    ``max_cached_programs`` bounds the in-memory cache with LRU eviction
    (``cache_evictions`` counts) so a long-lived serving process holding
    many shape buckets cannot grow without bound; the default (None) keeps
    the historical unbounded behavior.  Evicted programs that have a disk
    entry reload from it without recompiling.
    """

    def __init__(
        self,
        cache_dir: Optional[str] = None,
        max_cached_programs: Optional[int] = None,
    ):
        if max_cached_programs is not None and max_cached_programs < 1:
            raise ValueError(
                f"max_cached_programs={max_cached_programs} must be >= 1"
            )
        self._programs: Dict[Tuple, Callable] = collections.OrderedDict()
        self.cache_dir = cache_dir
        self.max_cached_programs = max_cached_programs
        self.cache_hits = 0
        self.cache_misses = 0
        self.cache_evictions = 0
        self.trace_count = 0
        self.disk_hits = 0
        self.disk_misses = 0
        self.disk_store_errors = 0

    def stats(self) -> Dict[str, int]:
        """All cache/compile counters in one dict — the observability
        surface bench_api/bench_serve and the serving stats() hooks read
        (disk_store_errors > 0 means the persistent tier is degraded:
        solves still succeed but fresh processes will recompile)."""
        return {
            "cache_hits": self.cache_hits,
            "cache_misses": self.cache_misses,
            "cache_evictions": self.cache_evictions,
            "trace_count": self.trace_count,
            "disk_hits": self.disk_hits,
            "disk_misses": self.disk_misses,
            "disk_store_errors": self.disk_store_errors,
            "cached_programs": len(self._programs),
        }

    # -- cache plumbing -----------------------------------------------------
    def _mark_trace(self) -> None:
        # Runs only while jax traces the program body: a retrace counter.
        self.trace_count += 1

    def _disk_dir(self, problem: Problem) -> Optional[str]:
        """Effective persistent-cache directory: the Solver's own setting
        wins; otherwise the Problem's (cache-key-exempt) knob."""
        return self.cache_dir if self.cache_dir is not None else problem.cache_dir

    def _get(
        self,
        key: Tuple,
        build: Callable[[], Callable],
        disk_dir: Optional[str] = None,
    ):
        fn = self._programs.get(key)
        if fn is None:
            self.cache_misses += 1
            fn = build()
            if disk_dir is not None and key[0] in _DISK_KINDS and key[6] is None:
                # degree_fn hooks (key[6]) are keyed by object identity,
                # which no other process can reproduce — memory tier only.
                fn = _DiskBackedProgram(self, fn, disk_dir, key)
            self._programs[key] = fn
            if self.max_cached_programs is not None:
                while len(self._programs) > self.max_cached_programs:
                    self._programs.popitem(last=False)  # LRU
                    self.cache_evictions += 1
            return fn, False
        self.cache_hits += 1
        self._programs.move_to_end(key)
        return fn, True

    def cache_size(self) -> int:
        return len(self._programs)

    def _key(
        self,
        kind: str,
        problem: Problem,
        mp: int,
        n_nodes: int,
        m_padded: int,
        dtype,
        degree_fn,
        aux: Tuple = (),
    ) -> Tuple:
        # A field may only be dropped from the key when the program takes it
        # as a RUNTIME argument (c for per-c and c-sweep programs, eps for
        # eps-sweep programs — the eps/graphs sweeps bake a fixed directed c
        # into the closure, so c must key those) or when the resolved cell
        # never reads it (no spurious recompiles from irrelevant knobs).
        # The uniform exclusions come from _FIELD_CLASS ('exempt' = host
        # driver/scheduling state: stream_*/spill/cache_dir/turnstile knobs,
        # and compaction/twophase_passes — segment programs key on (seg
        # max_passes, compact_below) via mp/aux instead, so geometric and
        # twophase ladders share bucket programs); max_passes keys through
        # its resolved value (the mp slot).
        exclude = {"max_passes"} | _EXEMPT_FIELDS
        if kind in ("solve", "mesh", "c", "cseg", "cseg_mesh", "ladder_mesh"):
            exclude.add("c")  # these programs take c as a runtime argument
        if kind == "eps":
            exclude.add("eps")
        if problem.objective != "at_least_k":
            exclude |= {"k", "min_deg_fallback", "ceil_count"}
        if problem.backend != "sketch":
            exclude |= {"sketch_tables", "sketch_buckets", "sketch_seed"}
        if not (problem.backend == "sketch" and problem.substrate == "mesh"):
            exclude.add("sketch_node_chunk")
        if problem.backend != "pallas":
            exclude |= {"tile_size", "tile_block", "pallas_interpret"}
        if problem.substrate != "mesh":
            exclude |= {"edge_axes", "wire_dtype"}
        return (
            kind,
            _fields_key(problem, exclude),
            mp,
            n_nodes,
            m_padded,
            str(dtype),
            degree_fn,
            aux,
        )

    # -- program builders ---------------------------------------------------
    def _build_jit_program(
        self,
        problem: Problem,
        mp: int,
        kind: str,
        degree_fn: Optional[Callable],
        with_tiling: bool,
    ) -> Callable:
        solver = self
        directed = problem.objective == "directed"

        def cell(edges, *, eps=None, c=None, tiling=None):
            return run_cell(
                edges, problem, eps=eps, c=c, degree_fn=degree_fn,
                tiling=tiling, max_passes=mp,
            )

        if kind == "solve":
            if with_tiling:
                def fn(edges, tl, ei):
                    solver._mark_trace()
                    return cell(edges, tiling=(tl, ei))
            elif directed:
                def fn(edges, c):
                    solver._mark_trace()
                    return cell(edges, c=c)
            else:
                def fn(edges):
                    solver._mark_trace()
                    return cell(edges)
        elif kind == "eps":
            if with_tiling:
                def fn(edges, tl, ei, eps_vec):
                    solver._mark_trace()
                    return jax.vmap(
                        lambda e: cell(edges, eps=e, tiling=(tl, ei))
                    )(eps_vec)
            else:
                def fn(edges, eps_vec):
                    solver._mark_trace()
                    return jax.vmap(lambda e: cell(edges, eps=e))(eps_vec)
        elif kind == "c":
            def fn(edges, c_vec):
                solver._mark_trace()
                return jax.vmap(lambda c: cell(edges, c=c))(c_vec)
        elif kind == "graphs":
            def fn(edges):
                solver._mark_trace()
                return jax.vmap(lambda g: cell(g))(edges)
        else:
            raise ValueError(kind)
        return jax.jit(fn)

    def _build_segment_program(
        self,
        problem: Problem,
        seg_mp: int,
        compact_below: Optional[int],
        with_tiling: bool,
    ) -> Callable:
        """One rung of the compaction ladder on the jit substrate:
        ``fn(edges[, tl, ei], alive0[, ta0], t0, ae0[, c]) -> PeelOutcome``.
        ``compact_below`` is baked in statically (it derives from the edge
        buffer size, which already keys the cache), so each power-of-two
        bucket compiles exactly once and is reused across graphs, segments
        and compaction modes.  ``ae0`` is the host-known alive-edge count
        of the entry state and the entry filter is the edge mask itself
        (a fresh bucket holds exactly the surviving alive edges), so a rung
        does NO edge work beyond its passes."""
        solver = self
        directed = problem.objective == "directed"

        def cell(edges, alive0, ta0, t0, ae0, c=None, tiling=None):
            return run_cell(
                edges, problem, c=c, tiling=tiling, max_passes=seg_mp,
                init_alive=alive0, init_t_alive=ta0, init_t=t0,
                init_best_empty=True, compact_below=compact_below,
                init_alive_edges=ae0, init_ok_from_mask=True,
            )

        if with_tiling:
            def fn(edges, tl, ei, alive0, t0, ae0):
                solver._mark_trace()
                return cell(edges, alive0, None, t0, ae0, tiling=(tl, ei))
        elif directed:
            def fn(edges, alive0, ta0, t0, ae0, c):
                solver._mark_trace()
                return cell(edges, alive0, ta0, t0, ae0, c=c)
        else:
            def fn(edges, alive0, t0, ae0):
                solver._mark_trace()
                return cell(edges, alive0, None, t0, ae0)
        return jax.jit(fn)

    def _build_mesh_program(
        self,
        problem: Problem,
        mp: int,
        mesh,
        n_nodes: int,
        segment: bool = False,
        compact_below: Optional[int] = None,
    ) -> Callable:
        """shard_map substrate (§5.2): edges sharded over ``edge_axes``,
        node state replicated, one fused psum per pass.  With ``segment``
        the program is one rung of the compaction ladder — it takes the
        replicated carried state (alive bitmap(s), absolute pass counter)
        and stops at ``compact_below``; the alive-edge trigger count is
        psummed (``MeshSegmentSumBackend.count_edges``) so all devices
        agree on the segment boundary."""
        from jax.sharding import PartitionSpec as P

        from repro.compat import shard_map

        axes = tuple(problem.edge_axes)
        if problem.backend == "sketch":
            from repro.core.countsketch import make_sketch_params
            from repro.core.mapreduce import _MeshSketchBackend

            backend = _MeshSketchBackend(
                params=make_sketch_params(
                    problem.sketch_tables,
                    problem.sketch_buckets,
                    problem.sketch_seed,
                ),
                axes=axes,
                node_chunk=min(problem.sketch_node_chunk, max(n_nodes, 1)),
            )
        else:
            backend = MeshSegmentSumBackend(axes, problem.wire_dtype)
        solver = self
        directed = problem.objective == "directed"

        def _local_run(src, dst, weight, mask, c=None, **seg_kw):
            e = EdgeList(src=src, dst=dst, weight=weight, mask=mask, n_nodes=n_nodes)
            policy = _policy_for(problem, c=c)
            return run_peel(
                e, policy, backend, mp, track_history=problem.track_history,
                **seg_kw,
            )

        if segment:
            # ae0 is the replicated host-known entry count; the entry filter
            # is the (sharded) edge mask itself, so a rung starts without
            # scanning its shard.
            seg_static = dict(
                init_best_empty=True, compact_below=compact_below,
                init_ok_from_mask=True,
            )
            if directed:
                def local(src, dst, weight, mask, alive0, ta0, t0, ae0, c):
                    return _local_run(
                        src, dst, weight, mask, c,
                        init_alive=alive0, init_t_alive=ta0, init_t=t0,
                        init_alive_edges=ae0, **seg_static,
                    )

                in_specs = (P(axes),) * 4 + (P(), P(), P(), P(), P())
            else:
                def local(src, dst, weight, mask, alive0, t0, ae0):
                    return _local_run(
                        src, dst, weight, mask,
                        init_alive=alive0, init_t=t0,
                        init_alive_edges=ae0, **seg_static,
                    )

                in_specs = (P(axes),) * 4 + (P(), P(), P())
        elif directed:
            def local(src, dst, weight, mask, c):
                return _local_run(src, dst, weight, mask, c)

            in_specs = (P(axes),) * 4 + (P(),)
        else:
            def local(src, dst, weight, mask):
                return _local_run(src, dst, weight, mask)

            in_specs = (P(axes),) * 4

        mapped = shard_map(
            local, mesh=mesh, in_specs=in_specs, out_specs=P(), check_vma=False
        )

        def fn(*args):
            solver._mark_trace()
            return mapped(*args)

        return jax.jit(fn)

    def _mesh_fn(self, prob: Problem, mesh, n_nodes: int):
        """Cached shard_map program for a RESOLVED problem.  Keyed without
        edge shapes (jit re-keys on shard shapes internally) so
        ``make_distributed_*`` warming and ``solve(substrate='mesh')``
        serving share one compilation."""
        mp = prob.resolved_max_passes(n_nodes)
        key = self._key("mesh", prob, mp, n_nodes, -1, "sharded", None, (mesh,))
        fn, hit = self._get(
            key, lambda: self._build_mesh_program(prob, mp, mesh, n_nodes)
        )
        return fn, hit, mp

    def mesh_program(
        self, problem: Problem, mesh, n_nodes: int
    ) -> Callable:
        """The cached shard_map program ``fn(src, dst, weight, mask[, c]) ->
        PeelOutcome`` — the lowering target of the ``make_distributed_*``
        builders in core/mapreduce.py."""
        fn, _, _ = self._mesh_fn(problem.resolve(n_nodes), mesh, n_nodes)
        return fn

    # -- single-program mesh ladder (collective-only compaction) ------------
    def _build_mesh_ladder_program(
        self,
        problem: Problem,
        mp: int,
        mesh,
        n_nodes: int,
        schedule: Tuple[int, ...],
    ) -> Callable:
        """The WHOLE geometric compaction ladder as ONE ``jit(shard_map)``
        program (mesh substrate): every rung's peel segment AND the
        compaction between rungs run inside the compiled program, so a
        multi-device run is collective-only end to end — no host
        gather/reshard per rung (the ``_run_compacted`` schedule's mesh cost
        this replaces).

        ``schedule`` is the static Lemma-4 bucket ladder
        (:func:`~repro.graph.partition.ladder_schedule`): per-shard edge
        capacities descending geometrically from the padded input (half
        first, then a stride of ``_LADDER_STRIDE``).  Rung ``i`` peels with
        its psummed alive-edge trigger at the NEXT rung's (global)
        capacity — half occupancy for rung 0, like the host ladder's
        trigger; a quarter for the stride-4 tail — so on trigger exit the
        survivors provably fit rung ``i+1``; survivor edges are then
        prefix-sum compacted and redistributed with an all-gather
        (:func:`~repro.core.mapreduce.mesh_compact_edges`).  Node bitmaps
        stay replicated in the FULL id space (no static bound exists on
        isolated-but-alive nodes, so node renumbering stays a host-ladder
        concern); since compaction is pure edge re-bucketing here, results
        are bit-identical to the host ladder and to ``compaction='off'`` for
        integer-valued weights.

        Returns ``fn(src, dst, weight, mask[, c]) -> (PeelOutcome,
        rung_t)`` where the edge arrays carry ``schedule[0] * n_shards``
        slots sharded over ``edge_axes`` and ``rung_t`` is the int32[R]
        absolute pass counter after each rung (the ladder report's
        per-rung passes, fetched with the result in the same launch).
        """
        from jax.sharding import PartitionSpec as P

        from repro.compat import shard_map
        from repro.core.mapreduce import mesh_compact_edges

        axes = tuple(problem.edge_axes)
        n_shards = int(np.prod([mesh.shape[a] for a in axes]))
        backend = MeshSegmentSumBackend(axes, problem.wire_dtype)
        solver = self
        directed = problem.objective == "directed"
        n_rungs = len(schedule)
        hist_len = mp if problem.track_history else 1

        def ladder_local(src, dst, weight, mask, c=None):
            policy = _policy_for(problem, c=c)
            n = n_nodes
            empty = jnp.zeros((0,), bool)
            alive = jnp.ones((n,), bool)
            ta = jnp.ones((n,), bool) if directed else empty
            # Best-set seed matches the uncompacted loop's best0=alive0: if
            # no pass ever records an eligible set, the full set comes back.
            best_alive = jnp.ones((n,), bool)
            best_t = jnp.ones((n,), bool) if directed else empty
            best_rho = jnp.asarray(-jnp.inf, jnp.float32)
            best_size = jnp.asarray(0, jnp.int32)
            t = jnp.asarray(0, jnp.int32)
            # Entry count of rung 0: one psum over the input mask (every
            # masked edge has both endpoints alive at t=0); later rungs
            # reuse the survivor count the compaction just gathered.
            ae = backend.count_edges(mask)
            hist_n = jnp.full((hist_len,), -1, jnp.int32)
            hist_m = jnp.zeros((hist_len,), jnp.float32)
            hist_rho = jnp.zeros((hist_len,), jnp.float32)
            rung_t = []
            for i, cap in enumerate(schedule):
                last = i == n_rungs - 1
                # The trigger sits at the NEXT rung's capacity: a rung only
                # exits early once its survivors provably fit there.
                compact_below = None if last else schedule[i + 1] * n_shards
                edges_i = EdgeList(
                    src=src, dst=dst, weight=weight, mask=mask, n_nodes=n
                )
                out = run_peel(
                    edges_i, policy, backend, mp,
                    track_history=problem.track_history,
                    init_alive=alive,
                    init_t_alive=ta if directed else None,
                    init_t=t, init_best_empty=True,
                    compact_below=compact_below,
                    init_alive_edges=ae, init_ok_from_mask=True,
                    with_edge_state=not last,
                )
                if not last:
                    # The carried post-removal filter and its psummed count
                    # ARE the compaction inputs — no re-filter, no re-count.
                    out, edge_ok, ae = out
                alive = out.alive
                if directed:
                    ta = out.t_alive
                t = out.passes
                # Strict >: the earliest rung (pass) wins ties, as in the
                # single-segment loop and the host ladder.
                improved = out.best_density > best_rho
                best_alive = jnp.where(improved, out.best_alive, best_alive)
                if directed:
                    best_t = jnp.where(improved, out.best_t, best_t)
                best_rho = jnp.where(improved, out.best_density, best_rho)
                best_size = jnp.where(improved, out.best_size, best_size)
                if problem.track_history:
                    # Absolute pass indexing: rungs write disjoint slots.
                    sel = out.history_n >= 0
                    hist_n = jnp.where(sel, out.history_n, hist_n)
                    hist_m = jnp.where(sel, out.history_m, hist_m)
                    hist_rho = jnp.where(sel, out.history_rho, hist_rho)
                rung_t.append(t)
                if not last:
                    src, dst, weight, mask = mesh_compact_edges(
                        src, dst, weight, edge_ok, ae, schedule[i + 1], axes,
                    )
            outcome = PeelOutcome(
                best_alive=best_alive,
                best_t=best_t,
                best_density=best_rho,
                best_size=best_size,
                passes=t,
                alive=alive,
                t_alive=ta,
                history_n=hist_n,
                history_m=hist_m,
                history_rho=hist_rho,
            )
            return outcome, jnp.stack(rung_t)

        in_specs = (P(axes),) * 4 + ((P(),) if directed else ())
        mapped = shard_map(
            ladder_local, mesh=mesh, in_specs=in_specs,
            out_specs=(P(), P()), check_vma=False,
        )

        def fn(*args):
            solver._mark_trace()
            return mapped(*args)

        return jax.jit(fn)

    def mesh_ladder_program(
        self, problem: Problem, mesh, n_nodes: int, m_edges: int
    ) -> Tuple[Callable, Tuple[int, ...], int, bool]:
        """The cached single-program mesh ladder for a graph with ``m_edges``
        edge slots: ``(fn, schedule, n_shards, hit)`` where ``fn(src, dst,
        weight, mask[, c]) -> (PeelOutcome, rung_t)`` expects the edge
        arrays padded to ``schedule[0] * n_shards`` slots and sharded over
        ``problem.edge_axes`` — the lowering target of
        :func:`~repro.core.mapreduce.make_distributed_peel_ladder` and of
        ``solve()`` for mesh × ``compaction='geometric'``.  The program
        cache key includes the static bucket schedule; rung 0 is the exact
        shard-rounded input size, so only graphs with the SAME padded edge
        count share a compilation (repeat solves and the whole directed
        c-grid do — c is a runtime scalar)."""
        prob = problem.resolve(n_nodes, have_mesh=True)
        axes = tuple(prob.edge_axes)
        n_shards = int(np.prod([mesh.shape[a] for a in axes]))
        shard_m0 = -(-max(int(m_edges), 1) // n_shards)  # ceil division
        # Rung 0 is the INPUT buffer: it keeps its exact (shard-rounded)
        # size — pow2 bucketing there would only pad the heaviest passes.
        # Its trigger fires at HALF occupancy (rung 1 = pow2(m0/2), the
        # host ladder's trigger point — low-eps runs shrink slowly and
        # need the early compact); after that the tail descends by
        # _LADDER_STRIDE, pow2-bucketed so every later rung's program is
        # shared across graphs landing on the same bucket.
        floor = pow2_bucket(max(1, _LADDER_MIN_EDGES // n_shards))
        half = pow2_bucket(-(-shard_m0 // 2), floor)
        tail = ladder_schedule(
            max(half // _LADDER_STRIDE, 1), floor=floor,
            stride=_LADDER_STRIDE,
        )
        schedule = (shard_m0,)
        schedule += (half,) if half < shard_m0 else ()
        # ladder_schedule clamps its floor down when the top is already
        # smaller; keep only tail rungs at or above the REAL floor (a
        # sub-floor rung would pay its fixed cost for a trivial pass).
        schedule += tuple(c for c in tail if c < schedule[-1] and c >= floor)
        mp = prob.resolved_max_passes(n_nodes)
        key = self._key(
            "ladder_mesh", prob, mp, n_nodes, -1, "sharded", None,
            (mesh, schedule),
        )
        fn, hit = self._get(
            key,
            lambda: self._build_mesh_ladder_program(
                prob, mp, mesh, n_nodes, schedule
            ),
        )
        return fn, schedule, n_shards, hit

    def _mesh_ladder_runner(
        self, graph: EdgeList, prob: Problem, mesh
    ) -> Callable[[Optional[float]], Tuple[PeelOutcome, Dict[str, Any], bool]]:
        """``_run_compacted``'s mesh × geometric replacement: pads and
        shards the graph ONCE, then returns ``run(c)`` launching the
        single-program ladder (collective-only; zero host gather/reshard
        round-trips between rungs) — the directed c-grid reuses both the
        sharded arrays and the compiled program across all its c values,
        like the uncompacted mesh path."""
        from repro.core.mapreduce import shard_edges

        fn, schedule, n_shards, hit = self.mesh_ladder_program(
            prob, mesh, graph.n_nodes, graph.n_edges_padded
        )
        padded = graph.with_padding(schedule[0] * n_shards)
        sh = shard_edges(padded, mesh, prob.edge_axes)
        base_args = (sh.src, sh.dst, sh.weight, sh.mask)

        def run(c: Optional[float]) -> Tuple[PeelOutcome, Dict[str, Any], bool]:
            args = base_args
            if prob.objective == "directed":
                args += (jnp.float32(c),)
            out, rung_t = fn(*args)
            rung_t = np.asarray(rung_t)
            segments = []
            slots = 0
            prev = 0
            for i, cap in enumerate(schedule):
                m_buf = cap * n_shards
                passes = int(rung_t[i]) - prev
                prev = int(rung_t[i])
                slots += passes * m_buf
                segments.append(
                    {
                        "n_buf": int(graph.n_nodes),
                        "m_buf": m_buf,
                        "passes": passes,
                        "compact_below": (
                            None if i == len(schedule) - 1
                            else schedule[i + 1] * n_shards
                        ),
                        "cache_hit": bool(hit),
                    }
                )
            ladder = {
                "mode": prob.compaction,
                "segments": segments,
                "edge_slots_scanned": int(slots),
                "passes": int(out.passes),
                "single_program": True,
                "host_round_trips": 0,  # vs one gather/reshard per rung
                "schedule": [cap * n_shards for cap in schedule],
            }
            return out, ladder, hit

        return run

    # -- compaction ladder (geometric | twophase) ---------------------------
    def _segment_fn(
        self,
        prob: Problem,
        seg_mp: int,
        compact_below: Optional[int],
        n_cur: int,
        m_cur: int,
        dtype,
        tiling_shapes: Tuple,
        mesh,
    ):
        """Cached program for one ladder rung (jit or mesh substrate)."""
        if prob.substrate == "mesh":
            key = self._key(
                "cseg_mesh", prob, seg_mp, n_cur, -1, "sharded", None,
                (mesh, compact_below),
            )
            return self._get(
                key,
                lambda: self._build_mesh_program(
                    prob, seg_mp, mesh, n_cur,
                    segment=True, compact_below=compact_below,
                ),
            )
        with_tiling = prob.backend == "pallas"
        key = self._key(
            "cseg", prob, seg_mp, n_cur, m_cur, dtype, None,
            (compact_below,) + tiling_shapes,
        )
        return self._get(
            key,
            lambda: self._build_segment_program(
                prob, seg_mp, compact_below, with_tiling
            ),
            disk_dir=self._disk_dir(prob),
        )

    def _run_compacted(
        self, graph: EdgeList, prob: Problem, mesh, c: Optional[float]
    ) -> Tuple[PeelOutcome, Dict[str, Any], bool]:
        """The geometric-compaction runtime: runs the SAME engine loop in
        segments, gathering survivors (edges and nodes) into the next
        power-of-two buffer whenever the alive edge count falls below half
        the current padded buffer — pass k then scans O(m_k) edge slots
        instead of O(m), amortized O(m) total (Lemma 4 drives the geometric
        shrink; cf. the per-round compaction in Mitrović & Pan).

        Compaction is pure renumbering (a stable gather over survivors), so
        the pass-by-pass removal decisions — and therefore best set, best
        density, final bitmaps, pass count and history — are bit-identical
        to the uncompacted loop for integer-valued edge weights, and equal
        up to float reassociation otherwise.  ``compaction='twophase'``
        reuses the same machinery with a fixed schedule: one compaction
        after ``twophase_passes`` passes (the historical
        ``make_distributed_peel_twophase`` recipe).

        On the mesh substrate this host schedule now serves only
        ``'twophase'``: mesh × ``'geometric'`` lowers onto the
        single-program collective-only ladder (:meth:`_mesh_ladder_runner`).
        Calling this directly with mesh × geometric still runs the host
        gather/reshard ladder — the benchmark's comparison baseline.

        Returns ``(outcome in the ORIGINAL id space, ladder report, all
        segment programs were cache hits)``.
        """
        directed = prob.objective == "directed"
        n0 = graph.n_nodes
        mp = prob.resolved_max_passes(n0)
        dtype = graph.weight.dtype
        # Host-side buffers of the current rung (device arrays are rebuilt
        # per segment; each rung is half the size, so total transfer/gather
        # work telescopes to O(m)).
        src = np.asarray(graph.src)
        dst = np.asarray(graph.dst)
        w = np.asarray(graph.weight)
        msk = np.asarray(graph.mask)
        id_map = np.arange(n0, dtype=np.int64)  # compact id -> original id
        n_cur = n0
        s_al = np.ones(n0, bool)
        t_al = np.ones(n0, bool) if directed else None

        hist_len = mp if prob.track_history else 1
        hist_n = np.full(hist_len, -1, np.int32)
        hist_m = np.zeros(hist_len, np.float32)
        hist_rho = np.zeros(hist_len, np.float32)
        best_rho = float("-inf")
        # Seed the best set with S_0, matching the uncompacted loop's
        # best0=alive0: if NO pass ever records an eligible set (zero-pass
        # runs — k > n, max_passes=0), both paths return the full set.
        best_alive = np.ones(n0, bool)
        best_t = np.ones(n0, bool) if directed else None
        best_size = 0
        t_done = 0
        segments = []
        slots_scanned = 0
        # Alive-edge count of the entry state of the NEXT rung: all real
        # edges initially; the survivor count after each compaction.  Only
        # read by rungs entered right after (re)initialization, where it is
        # exact — terminal (compact_below=None) segments ignore it.
        cur_alive_edges = int(msk.sum())
        twophase = prob.compaction == "twophase"
        # twophase_passes >= 1 is Problem-validated; mp=0 must stay 0 so a
        # zero-budget run executes no passes, exactly like 'off'.
        tp_k1 = min(int(prob.twophase_passes), mp)
        no_more_compact = False
        all_hit = True

        for seg_idx in range(_COMPACT_MAX_SEGMENTS):
            seg_mp = tp_k1 if (twophase and seg_idx == 0) else mp
            compact_below = None
            if prob.compaction == "geometric" and not no_more_compact:
                compact_below = max(len(src) // 2, 1)

            # ---- launch one segment on the current buffer ----
            edges = EdgeList(
                src=jnp.asarray(src), dst=jnp.asarray(dst),
                weight=jnp.asarray(w), mask=jnp.asarray(msk),
                n_nodes=n_cur, directed=graph.directed,
            )
            aux_arrays: Tuple = ()
            if prob.backend == "pallas":
                aux_arrays = _tiling_arrays(edges, prob, pow2_pad=True)
            # Carried segment state, identical on both substrates (must
            # track the _build_segment_program/_build_mesh_program
            # signatures): alive bitmap(s), absolute pass counter, entry
            # alive-edge count, and the runtime c for directed policies.
            carried: Tuple = (jnp.asarray(s_al),)
            if directed:
                carried += (jnp.asarray(t_al),)
            carried += (
                jnp.asarray(t_done, jnp.int32),
                jnp.asarray(cur_alive_edges, jnp.int32),
            )
            if directed:
                carried += (jnp.float32(c),)
            if prob.substrate == "mesh":
                from repro.core.mapreduce import shard_edges

                sh = shard_edges(edges, mesh, prob.edge_axes)
                m_buf = sh.n_edges_padded
                if compact_below is not None:
                    compact_below = max(m_buf // 2, 1)
                fn, hit = self._segment_fn(
                    prob, seg_mp, compact_below, n_cur, m_buf, dtype, (), mesh
                )
                out = fn(sh.src, sh.dst, sh.weight, sh.mask, *carried)
            else:
                m_buf = edges.n_edges_padded
                fn, hit = self._segment_fn(
                    prob, seg_mp, compact_below, n_cur, m_buf, dtype,
                    tuple(a.shape for a in aux_arrays), None,
                )
                out = fn(edges, *aux_arrays, *carried)
            all_hit = all_hit and hit

            # ---- fold the segment into the global answer ----
            t_prev = t_done
            t_done = int(out.passes)
            s_al = np.asarray(out.alive)
            if directed:
                t_al = np.asarray(out.t_alive)
            seg_rho = float(out.best_density)
            if seg_rho > best_rho:  # strict: earliest pass wins ties, as in
                best_rho = seg_rho  # the single-segment loop
                ba = np.asarray(out.best_alive)
                best_alive = np.zeros(n0, bool)
                best_alive[id_map] = ba[: len(id_map)]
                if directed:
                    bt = np.asarray(out.best_t)
                    best_t = np.zeros(n0, bool)
                    best_t[id_map] = bt[: len(id_map)]
                best_size = int(out.best_size)
            if prob.track_history:
                shn = np.asarray(out.history_n)
                sel = shn >= 0
                hist_n[: len(shn)][sel] = shn[sel]
                hist_m[: len(shn)][sel] = np.asarray(out.history_m)[sel]
                hist_rho[: len(shn)][sel] = np.asarray(out.history_rho)[sel]
            seg_passes = t_done - t_prev
            slots_scanned += seg_passes * m_buf
            segments.append(
                {
                    "n_buf": int(n_cur),
                    "m_buf": int(m_buf),
                    "passes": int(seg_passes),
                    "compact_below": compact_below,
                    "cache_hit": bool(hit),
                }
            )

            # ---- terminated? ----
            n_s = int(s_al.sum())
            n_t = int(t_al.sum()) if directed else n_s
            if t_done >= mp or not _host_keep_going(prob, n_s, n_t):
                break

            # ---- compact survivors into the next bucket ----
            surv = (s_al | t_al) if directed else s_al
            ta_np = t_al if directed else s_al
            ok_e = msk & s_al[src] & ta_np[dst]
            e_alive = int(ok_e.sum())
            n_alive = int(surv.sum())
            new_m = pow2_bucket(max(e_alive, 1), _COMPACT_MIN_EDGES)
            new_n = pow2_bucket(max(n_alive, 1), _COMPACT_MIN_NODES)
            if new_m >= len(src) and new_n >= n_cur:
                # Bucket floor reached: finish on this buffer uncompacted.
                no_more_compact = True
                continue
            relabel = np.cumsum(surv) - 1  # stable: preserves id order
            keep = np.nonzero(ok_e)[0]
            new_src = np.zeros(new_m, src.dtype)
            new_dst = np.zeros(new_m, dst.dtype)
            new_w = np.zeros(new_m, w.dtype)
            new_msk = np.zeros(new_m, bool)
            new_src[: len(keep)] = relabel[src[keep]]
            new_dst[: len(keep)] = relabel[dst[keep]]
            new_w[: len(keep)] = w[keep]
            new_msk[: len(keep)] = True
            # id_map covers only the real (unpadded) ids; pad nodes are never
            # alive, so slicing the survivor mask to its length is exact.
            id_map = id_map[surv[: len(id_map)]]
            new_s = np.zeros(new_n, bool)
            new_s[:n_alive] = s_al[surv]
            s_al = new_s
            if directed:
                new_t = np.zeros(new_n, bool)
                new_t[:n_alive] = t_al[surv]
                t_al = new_t
            src, dst, w, msk = new_src, new_dst, new_w, new_msk
            n_cur = new_n
            cur_alive_edges = e_alive
        else:
            raise RuntimeError(
                f"compaction ladder exceeded {_COMPACT_MAX_SEGMENTS} segments"
            )

        # ---- map the final state back to the original id space ----
        alive_full = np.zeros(n0, bool)
        alive_full[id_map] = s_al[: len(id_map)]
        if directed:
            t_full = np.zeros(n0, bool)
            t_full[id_map] = t_al[: len(id_map)]
        empty = jnp.zeros((0,), bool)
        outcome = PeelOutcome(
            best_alive=jnp.asarray(best_alive),
            best_t=jnp.asarray(best_t) if directed else empty,
            best_density=jnp.asarray(best_rho, jnp.float32),
            best_size=jnp.asarray(best_size, jnp.int32),
            passes=jnp.asarray(t_done, jnp.int32),
            alive=jnp.asarray(alive_full),
            t_alive=jnp.asarray(t_full) if directed else empty,
            history_n=jnp.asarray(hist_n),
            history_m=jnp.asarray(hist_m),
            history_rho=jnp.asarray(hist_rho),
        )
        ladder = {
            "mode": prob.compaction,
            "segments": segments,
            "edge_slots_scanned": int(slots_scanned),
            "passes": int(t_done),
            "single_program": False,
            # Each rung is its own program launch, with a host
            # gather/relabel (and reshard, on mesh) between rungs.
            "host_round_trips": len(segments),
        }
        return outcome, ladder, all_hit

    def _solve_compacted(
        self, graph: EdgeList, prob: Problem, mesh
    ) -> DenseSubgraphResult:
        """solve() tail for ``compaction in ('geometric', 'twophase')`` on
        the jit/mesh substrates (streaming compacts inside its driver).
        mesh × geometric lowers onto the SINGLE-PROGRAM ladder
        (:meth:`_mesh_ladder_runner`, collective-only compaction; the graph
        is sharded once, reused across the c-grid); everything else runs
        the host gather/relabel schedule (:meth:`_run_compacted`).
        """
        if prob.substrate == "mesh" and mesh is None:
            raise ValueError("substrate='mesh' needs solve(..., mesh=Mesh)")
        if prob.substrate == "mesh" and prob.compaction == "geometric":
            launch = self._mesh_ladder_runner(graph, prob, mesh)
            runner = lambda g, p, m, c: launch(c)
        else:
            runner = self._run_compacted
        n = graph.n_nodes
        mp = prob.resolved_max_passes(n)
        if prob.objective == "directed" and prob.c is None:
            # The c-grid loop, per-c through the ladder: the real cache-hit
            # flag and the winning c's ladder report survive into the result.
            grid = c_grid(n, prob.c_delta)
            best = best_c = best_ladder = None
            rhos, passes = [], []
            all_hit = True
            for cv in grid:
                out, ladder, hit = runner(graph, prob, mesh, float(cv))
                all_hit = all_hit and hit
                rho = float(out.best_density)
                rhos.append(rho)
                passes.append(int(out.passes))
                if best is None or rho > float(best.best_density):
                    best, best_c, best_ladder = out, float(cv), ladder
            extras = {
                "best_c": best_c,
                "c_grid": np.asarray(grid),
                "c_density": np.asarray(rhos),
                "c_passes": np.asarray(passes),
                "compaction": best_ladder,
            }
            return self._wrap(best, prob, n, mp, all_hit, extras=extras)
        c = prob.c if prob.objective == "directed" else None
        out, ladder, hit = runner(graph, prob, mesh, c)
        return self._wrap(out, prob, n, mp, hit, extras={"compaction": ladder})

    # -- result wrapping ----------------------------------------------------
    def _wrap(
        self,
        out: PeelOutcome,
        problem: Problem,
        n_nodes: int,
        mp: int,
        cache_hit: bool,
        extras: Optional[Dict[str, Any]] = None,
        batch: Optional[str] = None,
    ) -> DenseSubgraphResult:
        prov = Provenance(
            objective=problem.objective,
            policy=_policy_name(problem),
            backend=problem.backend,
            substrate=problem.substrate,
            n_nodes=n_nodes,
            max_passes=mp,
            batch=batch,
            cache_hit=cache_hit,
            compaction=problem.compaction,
        )
        return DenseSubgraphResult.from_outcome(out, provenance=prov, extras=extras)

    # -- solve --------------------------------------------------------------
    def solve(
        self,
        graph: EdgeList,
        problem: Problem,
        *,
        mesh=None,
        degree_fn: Optional[Callable] = None,
        checkpoint_dir: Optional[str] = None,
        resume: bool = False,
        seed: Optional[int] = None,
    ) -> DenseSubgraphResult:
        """Runs one Problem on one graph.

        As in ``examples/quickstart.py``::

            res = solver.solve(edges, Problem.undirected(eps=0.5))
            rho = float(res.best_density)      # density of the best set
            nodes = res.nodes()                # its node ids (host-side)
            res.provenance                     # which matrix cell ran

        ``mesh`` is required for the mesh substrate;
        ``checkpoint_dir``/``resume`` apply to streaming; ``seed`` is
        required by (and only by) ``substrate='local'`` — the node whose
        dense neighborhood is wanted; ``degree_fn`` is the legacy
        custom-degree hook (keys the cache by identity).
        Repeated same-shape solves hit the program cache and never retrace
        (``trace_count``/``cache_hits`` are the observability counters).
        """
        if not isinstance(graph, EdgeList):
            raise TypeError(
                f"solve() takes an EdgeList graph, got {type(graph).__name__}"
            )
        prob = problem.resolve(graph.n_nodes, have_mesh=mesh is not None)
        if (
            degree_fn is not None
            and prob.compaction != "off"
            and problem.compaction == "auto"
        ):
            # Like the sketch downgrade in resolve(): a degree_fn hook binds
            # one fixed graph, so 'auto' (the default) falls back to the
            # uncompacted loop instead of erroring — only an EXPLICIT
            # ladder request conflicts with the hook.
            prob = dataclasses.replace(prob, compaction="off")
        if prob.substrate != "streaming" and (checkpoint_dir is not None or resume):
            raise ValueError(
                "checkpoint_dir/resume only apply to substrate='streaming'"
            )
        if prob.substrate == "local":
            if mesh is not None:
                raise ValueError(
                    "substrate='local' is a host exploration + jit solve; "
                    "a mesh does not apply"
                )
            if degree_fn is not None:
                raise ValueError(
                    "degree_fn hooks bind one fixed graph; the local "
                    "candidate subgraph changes per seed"
                )
            return self._solve_local(graph, prob, seed)
        if seed is not None:
            raise ValueError(
                "seed= is the substrate='local' per-seed query knob; "
                f"substrate={prob.substrate!r} solves the whole graph"
            )
        if prob.stream_mode == "turnstile":
            if degree_fn is not None:
                raise ValueError(
                    "degree_fn hooks bind one fixed graph; the turnstile "
                    "sample changes per query — use backend='exact'|'pallas'"
                )
            return self._solve_turnstile(graph, prob)
        if prob.substrate == "streaming":
            if degree_fn is not None:
                raise ValueError(
                    "degree_fn hooks only apply to the jit substrate"
                )
            return self._solve_streaming(graph, prob, checkpoint_dir, resume)
        if prob.compaction in ("geometric", "twophase"):
            if degree_fn is not None:
                raise ValueError(
                    "degree_fn hooks bind one fixed graph; compaction "
                    "renumbers buffers per segment — use compaction='off'"
                )
            return self._solve_compacted(graph, prob, mesh)
        if prob.substrate == "mesh":
            if degree_fn is not None:
                raise ValueError(
                    "degree_fn hooks only apply to the jit substrate; mesh "
                    "runs need a psum'ing backend (backend='exact'|'sketch')"
                )
            return self._solve_mesh(graph, prob, mesh)

        n = graph.n_nodes
        mp = prob.resolved_max_passes(n)
        with_tiling = prob.backend == "pallas" and degree_fn is None
        aux: Tuple = ()
        if with_tiling:
            aux = _tiling_arrays(graph, prob)
        key = self._key(
            "solve", prob, mp, n, graph.n_edges_padded,
            graph.weight.dtype, degree_fn, tuple(a.shape for a in aux),
        )
        fn, hit = self._get(
            key,
            lambda: self._build_jit_program(prob, mp, "solve", degree_fn, with_tiling),
            disk_dir=self._disk_dir(prob),
        )
        if prob.objective == "directed":
            if prob.c is None:
                return self._directed_grid(graph, prob, mp, fn, hit)
            out = fn(graph, jnp.float32(prob.c))
        else:
            out = fn(graph, *aux)
        return self._wrap(out, prob, n, mp, hit)

    def _directed_grid(
        self, graph: EdgeList, prob: Problem, mp: int, fn, hit: bool
    ) -> DenseSubgraphResult:
        """The paper's practical directed recipe: sweep the geometric c-grid
        through ONE compiled per-c program (c is a runtime scalar)."""
        grid = c_grid(graph.n_nodes, prob.c_delta)
        best = None
        best_c = None
        rhos = []
        passes = []
        for c in grid:
            out = fn(graph, jnp.float32(c))
            rho = float(out.best_density)
            rhos.append(rho)
            passes.append(int(out.passes))
            if best is None or rho > float(best.best_density):
                best, best_c = out, float(c)
        extras = {
            "best_c": best_c,
            "c_grid": np.asarray(grid),
            "c_density": np.asarray(rhos),
            "c_passes": np.asarray(passes),
        }
        return self._wrap(best, prob, graph.n_nodes, mp, hit, extras=extras)

    def _solve_mesh(
        self, graph: EdgeList, prob: Problem, mesh
    ) -> DenseSubgraphResult:
        if mesh is None:
            raise ValueError("substrate='mesh' needs solve(..., mesh=Mesh)")
        from repro.core.mapreduce import shard_edges

        sh = shard_edges(graph, mesh, prob.edge_axes)
        fn, hit, mp = self._mesh_fn(prob, mesh, sh.n_nodes)
        if prob.objective == "directed":
            if prob.c is None:
                grid_fn = lambda e, c: fn(e.src, e.dst, e.weight, e.mask, c)
                return self._directed_grid(sh, prob, mp, grid_fn, hit)
            out = fn(sh.src, sh.dst, sh.weight, sh.mask, jnp.float32(prob.c))
        else:
            out = fn(sh.src, sh.dst, sh.weight, sh.mask)
        return self._wrap(out, prob, sh.n_nodes, mp, hit)

    def _solve_local(
        self, graph: EdgeList, prob: Problem, seed
    ) -> DenseSubgraphResult:
        """Andersen local substrate (``substrate='local'``): pruned-frontier
        exploration around ``seed`` (core/local.py), then the SAME jit pass
        body over the bucket-padded candidate subgraph.  The program cache
        sees an ordinary pow2-bucket 'solve' program — shared with the
        serving engine's buckets, so repeated queries never retrace.

        The result's bitmaps are scattered back to the ORIGINAL id space
        (history/passes describe the padded candidate buffer), provenance
        reports ``substrate='local'``, and ``extras['local']`` carries the
        exploration counters.  One-shot front door: the CSR build here is
        O(m) per call — request-rate serving holds a persistent
        :class:`repro.serve.densest.DensestQueryEngine` instead, which
        builds the CSR once and batches same-bucket queries."""
        from repro.core.local import LocalExplorer

        if seed is None:
            raise ValueError(
                "substrate='local' answers per-seed queries: "
                "solve(graph, problem, seed=<node id>)"
            )
        explorer = LocalExplorer.from_edgelist(graph)
        padded, ex = explorer.extract(
            seed,
            budget=prob.local_budget,
            max_rounds=prob.local_rounds,
            alpha=prob.local_alpha,
        )
        sub = self.solve(padded, dataclasses.replace(prob, substrate="jit"))
        nodes = ex.candidates
        n = graph.n_nodes

        def lift(bitmap) -> jax.Array:
            # Padded-buffer bitmap -> original id space (pad ids dropped).
            row = np.asarray(bitmap)
            local = np.nonzero(row)[0]
            local = local[local < len(nodes)]  # isolated pad nodes
            full = np.zeros(n, bool)
            full[nodes[local]] = True
            return jnp.asarray(full)

        best_alive = lift(sub.best_alive)
        out = PeelOutcome(
            best_alive=best_alive,
            best_t=sub.best_t,
            best_density=sub.best_density,
            best_size=jnp.sum(best_alive.astype(jnp.int32)),
            passes=sub.passes,
            alive=lift(sub.alive),
            t_alive=sub.t_alive,
            history_n=sub.history_n,
            history_m=sub.history_m,
            history_rho=sub.history_rho,
        )
        extras = {
            "local": {
                "seed": int(ex.seed),
                "candidates": nodes,
                "n_candidates": int(len(nodes)),
                "m_candidates": int(np.asarray(padded.mask).sum()),
                "rounds": int(ex.rounds),
                "nodes_touched": int(ex.nodes_touched),
                "edges_scanned": int(ex.edges_scanned),
                "frontier_exhausted": bool(ex.frontier_exhausted),
                "budget": int(prob.local_budget),
                "bucket": (int(padded.n_nodes), int(padded.n_edges_padded)),
            }
        }
        return self._wrap(
            out,
            prob,
            n,
            sub.provenance.max_passes,
            sub.provenance.cache_hit,
            extras=extras,
        )

    def _solve_turnstile(
        self, graph: EdgeList, prob: Problem
    ) -> DenseSubgraphResult:
        """One-shot turnstile solve: builds a
        :class:`~repro.core.turnstile.TurnstileDensest`, inserts every real
        edge of ``graph`` as one ±edge batch, and answers one query — the
        front-door lowering of ``Problem(stream_mode='turnstile')``.
        Continuous update/query cycles hold their own live driver
        (core/turnstile.py, or the serve/ density service)."""
        from repro.core.turnstile import TurnstileDensest

        if graph.directed:
            raise ValueError("stream_mode='turnstile' needs an undirected graph")
        mask = np.asarray(graph.mask)
        if not np.all(np.asarray(graph.weight)[mask] == 1.0):
            raise ValueError(
                "stream_mode='turnstile' streams are unweighted edge SETS "
                "(the ℓ0 sample has no weight field); got non-unit weights"
            )
        td = TurnstileDensest(graph.n_nodes, prob, solver=self)
        src = np.asarray(graph.src)[mask]
        dst = np.asarray(graph.dst)[mask]
        td.apply(insert_edges=(src, dst))
        return td.query()

    def _solve_streaming(
        self,
        graph: EdgeList,
        prob: Problem,
        checkpoint_dir: Optional[str],
        resume: bool,
    ) -> DenseSubgraphResult:
        """Semi-streaming substrate: chunked multi-pass driver with O(n)
        node state (StreamingDensest keeps the checkpoint/straggler logic).
        ``stream_prefetch`` bounds the async pipeline's resident chunks and
        ``spill_dir`` sends ladder rebuilds to disk-backed memmaps; the
        result's ``extras['streaming']`` reports the pipeline's residency
        and straggler/compaction counters."""
        from repro.core.streaming import StreamingDensest, chunked_from_arrays

        mask = np.asarray(graph.mask)
        src = np.asarray(graph.src)[mask]
        dst = np.asarray(graph.dst)[mask]
        w = np.asarray(graph.weight)[mask]
        drv = StreamingDensest(
            chunked_from_arrays(src, dst, w, chunk=prob.stream_chunk),
            n_nodes=graph.n_nodes,
            eps=prob.eps,
            checkpoint_dir=checkpoint_dir,
            n_workers=prob.stream_workers,
            prefetch=prob.stream_prefetch,
            spill_dir=prob.spill_dir,
            residency_cap_edges=prob.residency_cap_edges,
            compaction="geometric" if prob.compaction == "geometric" else "off",
        )
        st = drv.run(max_passes=prob.max_passes, resume=resume)
        extras = {
            "streaming": {
                "peak_resident_chunks": drv.peak_resident_chunks,
                "peak_resident_edges": drv.peak_resident_edges,
                "speculative_reissues": drv.speculative_reissues,
                "compactions": drv.compactions,
                "spill_rungs": drv.spill_rungs,
            }
        }
        mp = prob.resolved_max_passes(graph.n_nodes)
        hist = np.asarray(st.history, np.float64).reshape(-1, 3)
        best_alive = jnp.asarray(st.best_alive)
        out = PeelOutcome(
            best_alive=best_alive,
            best_t=jnp.zeros((0,), bool),
            best_density=jnp.asarray(st.best_rho, jnp.float32),
            best_size=jnp.sum(best_alive.astype(jnp.int32)),
            passes=jnp.asarray(st.pass_idx, jnp.int32),
            alive=jnp.asarray(st.alive),
            t_alive=jnp.zeros((0,), bool),
            history_n=jnp.asarray(hist[:, 0], jnp.int32),
            history_m=jnp.asarray(hist[:, 1], jnp.float32),
            history_rho=jnp.asarray(hist[:, 2], jnp.float32),
        )
        return self._wrap(out, prob, graph.n_nodes, mp, cache_hit=False, extras=extras)

    # -- solve_batch --------------------------------------------------------
    def solve_batch(
        self,
        graph: Union[EdgeList, Sequence[EdgeList]],
        problem: Problem,
        *,
        eps=None,
        c=None,
        degree_fn: Optional[Callable] = None,
    ) -> DenseSubgraphResult:
        """One XLA program for a whole sweep (ROADMAP batched driver).

        As in ``examples/quickstart.py``::

            sweep = solver.solve_batch(
                edges, Problem.undirected(max_passes=64), eps=[0.1, 0.5, 1.0]
            )
            sweep.best_density                 # float32[3], one per eps

        Exactly one batch axis: ``eps=`` (vector of eps values), ``c=``
        (vector of directed ratio guesses), or a sequence of same-shape
        graphs.  Every array of the result gains a leading sweep axis; the
        engine's vmapped while_loop runs to the slowest lane but each lane's
        values are bit-identical to its standalone solve (for eps values
        exactly representable in float32).

        With ``max_passes=None`` the static trip bound is taken at the
        loosest point of the sweep (min eps); pass an explicit
        ``Problem.max_passes`` to pin it.  Sweeps share ONE vmapped
        program, so there is no per-lane buffer to compact:
        ``compaction='auto'`` quietly resolves to off, an explicit ladder
        raises.
        """
        stacked = isinstance(graph, (list, tuple)) or (
            isinstance(graph, EdgeList) and graph.src.ndim == 2
        )
        if sum(x is not None for x in (eps, c)) + stacked != 1:
            raise ValueError(
                "solve_batch needs exactly one batch axis: eps=, c=, or "
                "stacked same-shape graphs (a sequence or a stack_graphs result)"
            )

        def _resolve_batchable(n_nodes: int) -> Problem:
            # Batched sweeps are ONE vmapped program: lanes shrink at
            # different rates, so there is no shared buffer to compact.
            # 'auto' quietly resolves to off; an explicit ladder is an error.
            p = problem.resolve(n_nodes)
            if p.stream_mode == "turnstile":
                raise ValueError(
                    "solve_batch sweeps are single vmapped programs; the "
                    "turnstile runtime is a host update/query driver — "
                    "query a live TurnstileDensest per sweep point instead"
                )
            if p.compaction != "off":
                if problem.compaction == "auto":
                    p = dataclasses.replace(p, compaction="off")
                else:
                    raise ValueError(
                        "solve_batch sweeps share one vmapped program; "
                        "per-lane compaction is not possible — use "
                        "compaction='off' (or 'auto')"
                    )
            return p

        if stacked:
            batched = graph if isinstance(graph, EdgeList) else stack_graphs(list(graph))
            prob = _resolve_batchable(batched.n_nodes)
            if prob.substrate != "jit":
                raise ValueError("solve_batch runs on the jit substrate")
            if prob.backend == "pallas":
                raise ValueError(
                    "stacked-graph sweeps need a graph-independent backend "
                    "(tile bucketing is per-graph); use exact or sketch"
                )
            if prob.objective == "directed" and prob.c is None:
                raise ValueError("stacked directed sweeps need a fixed c")
            mp = prob.resolved_max_passes(batched.n_nodes)
            key = self._key(
                "graphs", prob, mp, batched.n_nodes, batched.src.shape,
                batched.weight.dtype, degree_fn,
            )
            fn, hit = self._get(
                key,
                lambda: self._build_jit_program(prob, mp, "graphs", degree_fn, False),
                disk_dir=self._disk_dir(prob),
            )
            out = fn(batched)
            return self._wrap(out, prob, batched.n_nodes, mp, hit, batch="graphs")

        if not isinstance(graph, EdgeList):
            raise TypeError(
                f"solve_batch takes an EdgeList or a sequence, got {type(graph).__name__}"
            )
        prob = _resolve_batchable(graph.n_nodes)
        if prob.substrate != "jit":
            raise ValueError("solve_batch runs on the jit substrate")
        n = graph.n_nodes

        if eps is not None:
            eps_host = np.asarray(eps, np.float32).reshape(-1)
            if prob.max_passes is not None:
                mp = int(prob.max_passes)
            else:
                loosest = dataclasses.replace(prob, eps=float(eps_host.min()))
                mp = loosest.resolved_max_passes(n)
            if prob.objective == "directed" and prob.c is None:
                raise ValueError("eps sweeps over a directed Problem need a fixed c")
            with_tiling = prob.backend == "pallas" and degree_fn is None
            aux: Tuple = _tiling_arrays(graph, prob) if with_tiling else ()
            key = self._key(
                "eps", prob, mp, n, graph.n_edges_padded,
                graph.weight.dtype, degree_fn, tuple(a.shape for a in aux),
            )
            fn, hit = self._get(
                key,
                lambda: self._build_jit_program(prob, mp, "eps", degree_fn, with_tiling),
                disk_dir=self._disk_dir(prob),
            )
            out = fn(graph, *aux, jnp.asarray(eps_host))
            return self._wrap(out, prob, n, mp, hit, batch="eps")

        # c sweep (directed only)
        if prob.objective != "directed":
            raise ValueError("c sweeps only apply to the directed objective")
        c_host = np.asarray(c, np.float32).reshape(-1)
        mp = prob.resolved_max_passes(n)
        key = self._key(
            "c", prob, mp, n, graph.n_edges_padded,
            graph.weight.dtype, degree_fn,
        )
        fn, hit = self._get(
            key,
            lambda: self._build_jit_program(prob, mp, "c", degree_fn, False),
            disk_dir=self._disk_dir(prob),
        )
        out = fn(graph, jnp.asarray(c_host))
        return self._wrap(out, prob, n, mp, hit, batch="c")


# ---------------------------------------------------------------------------
# Module-level front door (one shared program cache)
# ---------------------------------------------------------------------------

default_solver = Solver()


def solve(graph: EdgeList, problem: Problem, **kw) -> DenseSubgraphResult:
    """``Solver.solve`` on the process-wide :data:`default_solver` (shared
    compile cache — the production entry point and the target of every
    legacy wrapper)."""
    return default_solver.solve(graph, problem, **kw)


def solve_batch(graph, problem: Problem, **kw) -> DenseSubgraphResult:
    """``Solver.solve_batch`` on the process-wide :data:`default_solver`."""
    return default_solver.solve_batch(graph, problem, **kw)
