"""One front door for the paper's densest-subgraph algorithms.

The public surface is three names:

  * :class:`Problem` — a frozen, hashable spec of WHAT to solve: the
    objective (Algorithm 1/2/3), eps, k, the directed ratio c (or None for
    the geometric c-grid), the degree backend (``exact | sketch | pallas |
    auto``) and the launch substrate (``jit | mesh | streaming | auto``).
  * :func:`solve` / :class:`Solver` — lowers a Problem onto the PeelEngine's
    RemovalPolicy × DegreeBackend × substrate axes (core/engine.py) and runs
    it.  A Solver memoizes the jitted programs keyed on the Problem's static
    fields plus ``(n_nodes, padded m, dtype)`` so repeated calls at
    production request rates never retrace; :data:`default_solver` backs the
    module-level helpers and every legacy wrapper.
  * :func:`solve_batch` — the ROADMAP's batched driver: multi-eps, multi-c
    and stacked same-shape-graph sweeps as ONE vmapped XLA program (the
    engine is vmap-clean; the directed c-grid proved it).

Every result is a :class:`DenseSubgraphResult`: the engine's
:class:`~repro.core.engine.PeelOutcome` arrays plus a static
:class:`Provenance` recording which cell of the policy × backend × substrate
matrix actually ran.  The historical ``PeelResult`` / ``PeelTopKResult`` /
``DirectedPeelResult`` names are deprecated aliases of it.

Lowering map (Problem field -> engine axis)::

    objective  undirected   -> UndirectedThreshold(eps)           (Alg 1, §4.1)
               at_least_k   -> AtLeastKFraction(k, eps, variants) (Alg 2, §4.2)
               directed     -> DirectedST(eps, c)                 (Alg 3, §4.3)
    backend    exact        -> ExactBackend (segment_sum)
               sketch       -> SketchBackend / _MeshSketchBackend (§5.1)
               pallas       -> tiled-degree kernel via FnBackend  (kernels/)
    substrate  jit          -> jax.jit(run_peel)                  (peel*.py)
               mesh         -> shard_map + psum backends          (§5.2)
               streaming    -> StreamingDensest chunked driver    (§4, semi-streaming)

The legacy entry points (``densest_subgraph``, ``densest_subgraph_at_least_k``,
``densest_subgraph_directed``, ``densest_directed_search``,
``densest_subgraph_sketched``, ``densest_subgraph_distributed``,
``StreamingDensest``) are thin delegations through this module's lowering
and stay bit-identical to their pre-redesign outputs.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.density import max_passes_bound
from repro.core.engine import (
    AtLeastKFraction,
    DirectedST,
    ExactBackend,
    FnBackend,
    MeshSegmentSumBackend,
    PeelOutcome,
    RemovalPolicy,
    UndirectedThreshold,
    run_peel,
)
from repro.graph.edgelist import EdgeList

__all__ = [
    "DenseSubgraphResult",
    "Problem",
    "Provenance",
    "Solver",
    "default_solver",
    "deprecated_alias_getattr",
    "run_cell",
    "solve",
    "solve_batch",
    "stack_graphs",
]

_OBJECTIVES = ("undirected", "at_least_k", "directed")
_BACKENDS = ("exact", "sketch", "pallas", "auto")
_SUBSTRATES = ("jit", "mesh", "streaming", "auto")

# Above this node count, "auto" trades the O(n) exact degree vector for the
# O(t*b) Count-Sketch (§5.1's memory regime).
_AUTO_SKETCH_NODES = 1_000_000


# ---------------------------------------------------------------------------
# Problem — the declarative spec
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Problem:
    """What to solve.  Frozen and hashable: the static half of a Solver
    cache key.  Use the :meth:`undirected` / :meth:`at_least_k` /
    :meth:`directed` constructors for the common cases.

    ``backend='auto'`` picks sketch above ~1M nodes, exact otherwise;
    ``substrate='auto'`` picks mesh when a mesh was supplied and more than
    one device is visible, jit otherwise.  ``c=None`` with the directed
    objective means "search the geometric c-grid" (resolution ``c_delta``),
    the paper's practical recipe.
    """

    objective: str = "undirected"
    eps: float = 0.5
    k: Optional[int] = None  # at_least_k: minimum |S|
    c: Optional[float] = None  # directed: |S|/|T| guess; None -> grid
    c_delta: float = 2.0  # directed grid resolution (§6.4)
    backend: str = "exact"
    substrate: str = "jit"
    max_passes: Optional[int] = None  # None -> Lemma 4/13 bound
    track_history: bool = False
    # Algorithm 2 realization knobs (floor+fallback = single-device legacy,
    # ceil w/o fallback = distributed legacy).
    min_deg_fallback: bool = True
    ceil_count: bool = False
    # Count-Sketch (§5.1) parameters.
    sketch_tables: int = 5
    sketch_buckets: int = 1 << 13
    sketch_seed: int = 0
    sketch_node_chunk: int = 1 << 20  # mesh sketch: query streaming chunk
    # Pallas tiled-degree kernel parameters.
    tile_size: int = 1024
    tile_block: int = 512
    # Mesh substrate parameters.
    edge_axes: Tuple[str, ...] = ("data",)
    wire_dtype: str = "f32"  # f32 | bf16 degree-psum wire format
    # Streaming substrate parameters.
    stream_chunk: int = 1 << 20
    stream_workers: int = 4

    def __post_init__(self):
        if self.objective not in _OBJECTIVES:
            raise ValueError(
                f"objective={self.objective!r} not in {_OBJECTIVES}"
            )
        if self.backend not in _BACKENDS:
            raise ValueError(f"backend={self.backend!r} not in {_BACKENDS}")
        if self.substrate not in _SUBSTRATES:
            raise ValueError(
                f"substrate={self.substrate!r} not in {_SUBSTRATES}"
            )
        if self.objective == "at_least_k" and (self.k is None or self.k < 1):
            raise ValueError("objective='at_least_k' needs k >= 1")
        if self.c_delta <= 1.0:
            raise ValueError(
                f"c_delta={self.c_delta} must be > 1 (geometric grid ratio)"
            )
        if self.wire_dtype not in ("f32", "bf16"):
            raise ValueError(f"wire_dtype={self.wire_dtype!r} not in (f32, bf16)")
        if not isinstance(self.edge_axes, tuple):
            object.__setattr__(self, "edge_axes", tuple(self.edge_axes))

    # -- constructors -------------------------------------------------------
    @classmethod
    def undirected(cls, eps: float = 0.5, **kw) -> "Problem":
        """Algorithm 1: (2+2eps)-approximate densest subgraph."""
        return cls(objective="undirected", eps=float(eps), **kw)

    @classmethod
    def at_least_k(cls, k: int, eps: float = 0.5, **kw) -> "Problem":
        """Algorithm 2: (3+3eps)-approximate densest subgraph, |S| >= k."""
        return cls(objective="at_least_k", k=int(k), eps=float(eps), **kw)

    @classmethod
    def directed(
        cls, c: Optional[float] = None, eps: float = 0.5, **kw
    ) -> "Problem":
        """Algorithm 3: directed densest subgraph, fixed c or c-grid."""
        return cls(
            objective="directed",
            c=None if c is None else float(c),
            eps=float(eps),
            **kw,
        )

    # -- resolution ---------------------------------------------------------
    def resolve(self, n_nodes: int, have_mesh: bool = False) -> "Problem":
        """Resolves ``auto`` axes against the graph/host and validates that
        the requested matrix cell exists.  ``auto`` only picks the mesh
        substrate when the caller actually supplied a mesh (``have_mesh``)."""
        backend = self.backend
        substrate = self.substrate
        if substrate == "auto":
            substrate = "mesh" if have_mesh and len(jax.devices()) > 1 else "jit"
        if backend == "auto":
            # The streaming driver IS the large-graph memory regime (O(n)
            # node state, out-of-core edges): its only cell is exact.
            if substrate == "streaming":
                backend = "exact"
            else:
                backend = "sketch" if n_nodes > _AUTO_SKETCH_NODES else "exact"
        p = self
        if backend != self.backend or substrate != self.substrate:
            p = dataclasses.replace(self, backend=backend, substrate=substrate)
        if p.objective == "directed" and p.backend == "pallas":
            raise ValueError(
                "the tiled-degree kernel counts both endpoints (undirected); "
                "directed objectives need backend='exact' or 'sketch'"
            )
        if p.substrate == "mesh" and p.backend == "pallas":
            raise ValueError("backend='pallas' has no mesh (shard_map) cell yet")
        if p.substrate == "streaming" and (
            p.objective != "undirected" or p.backend != "exact"
        ):
            raise ValueError(
                "the streaming substrate implements Algorithm 1 with exact "
                "chunked degrees; use objective='undirected', backend='exact'"
            )
        return p

    def resolved_max_passes(self, n_nodes: int) -> int:
        """Static trip count: explicit, or the Lemma 4 bound (doubled for
        directed runs — Lemma 13 shrinks one of S/T per pass)."""
        if self.max_passes is not None:
            return int(self.max_passes)
        bound = max_passes_bound(n_nodes, self.eps)
        return 2 * bound if self.objective == "directed" else bound


# ---------------------------------------------------------------------------
# Result type — PeelOutcome arrays + provenance
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Provenance:
    """Which cell of the policy × backend × substrate matrix produced a
    result (static metadata, hashable)."""

    objective: str
    policy: str
    backend: str
    substrate: str
    n_nodes: int
    max_passes: int
    batch: Optional[str] = None  # None | "eps" | "c" | "graphs"
    cache_hit: bool = False


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DenseSubgraphResult:
    """The one result type of the front door (and the deprecation target of
    ``PeelResult`` / ``PeelTopKResult`` / ``DirectedPeelResult``).

    Field-compatible with :class:`~repro.core.engine.PeelOutcome`; batched
    solves carry a leading sweep axis on every array.  ``extras`` holds
    sweep-level host data (the directed grid's per-c profile).
    """

    best_alive: jax.Array  # bool[N] the output set S~ (S side for directed)
    best_t: jax.Array  # bool[N] T side (directed) | bool[0]
    best_density: jax.Array  # float32[] rho of the best set
    best_size: jax.Array  # int32[] |S~|
    passes: jax.Array  # int32[] passes executed
    alive: jax.Array  # bool[N] final S bitmap
    t_alive: jax.Array  # bool[N] final T bitmap | bool[0]
    history_n: jax.Array  # int32[hist] per-pass |S| (-1 padding)
    history_m: jax.Array  # float32[hist] per-pass |E(S)|
    history_rho: jax.Array  # float32[hist] per-pass rho
    extras: Optional[Dict[str, Any]] = None
    provenance: Optional[Provenance] = dataclasses.field(
        default=None, metadata=dict(static=True)
    )

    @property
    def best_s(self) -> jax.Array:
        """Directed-result spelling of the S-side best bitmap."""
        return self.best_alive

    @property
    def mask(self) -> jax.Array:
        return self.best_alive

    @classmethod
    def from_outcome(
        cls,
        out: PeelOutcome,
        provenance: Optional[Provenance] = None,
        extras: Optional[Dict[str, Any]] = None,
    ) -> "DenseSubgraphResult":
        return cls(*out, extras=extras, provenance=provenance)

    # Host conveniences (not for use under tracing).
    def nodes(self) -> np.ndarray:
        """Node ids of the best set (S side for directed)."""
        return np.nonzero(np.asarray(self.best_alive))[0]

    def t_nodes(self) -> np.ndarray:
        """Node ids of the best T side (directed results)."""
        return np.nonzero(np.asarray(self.best_t))[0]

    @property
    def density(self) -> float:
        return float(self.best_density)


# ---------------------------------------------------------------------------
# Lowering: Problem -> RemovalPolicy × DegreeBackend
# ---------------------------------------------------------------------------


def _policy_for(
    problem: Problem, *, eps: Any = None, c: Any = None
) -> RemovalPolicy:
    """Problem -> RemovalPolicy.  ``eps``/``c`` may be traced scalars (the
    batched sweeps rely on it)."""
    e = problem.eps if eps is None else eps
    if problem.objective == "undirected":
        return UndirectedThreshold(e)
    if problem.objective == "at_least_k":
        return AtLeastKFraction(
            k=problem.k,
            eps=e,
            min_deg_fallback=problem.min_deg_fallback,
            ceil_count=problem.ceil_count,
        )
    cc = problem.c if c is None else c
    if cc is None:
        raise ValueError(
            "directed lowering needs a concrete or traced c; Problem.c=None "
            "(grid search) is handled by solve()/solve_batch()"
        )
    return DirectedST(eps=e, c=jnp.asarray(cc, jnp.float32))


def _backend_for(
    problem: Problem,
    n_nodes: int,
    *,
    degree_fn: Optional[Callable] = None,
    tiling: Optional[Tuple[jax.Array, jax.Array]] = None,
):
    """Problem -> DegreeBackend (jit substrate).  ``degree_fn`` is the
    legacy hook escape hatch; ``tiling`` carries the Pallas bucketing arrays
    as runtime values so compiled programs stay graph-independent."""
    if degree_fn is not None:
        return FnBackend(degree_fn)
    if problem.backend == "exact":
        return ExactBackend()
    if problem.backend == "sketch":
        from repro.core.countsketch import SketchBackend, make_sketch_params

        return SketchBackend(
            make_sketch_params(
                problem.sketch_tables, problem.sketch_buckets, problem.sketch_seed
            )
        )
    if problem.backend == "pallas":
        if tiling is None:
            raise ValueError("backend='pallas' needs tiling arrays")
        from repro.kernels.peel_degree.ops import tiled_degrees

        tl, ei = tiling

        def fn(edges: EdgeList, w_alive: jax.Array) -> jax.Array:
            return tiled_degrees(
                tl, ei, w_alive,
                tile_size=problem.tile_size, n_nodes=n_nodes,
            )

        return FnBackend(fn)
    raise ValueError(f"unresolved backend {problem.backend!r}")


def run_cell(
    edges: EdgeList,
    problem: Problem,
    *,
    eps: Any = None,
    c: Any = None,
    degree_fn: Optional[Callable] = None,
    tiling: Optional[Tuple[jax.Array, jax.Array]] = None,
    max_passes: Optional[int] = None,
) -> PeelOutcome:
    """The pure, traceable lowering core: one Problem cell -> ``run_peel``.

    Safe under jit/vmap/shard_map; ``eps`` and ``c`` may be traced scalars.
    Everything in solve()/solve_batch() and every legacy wrapper bottoms out
    here (substrates add their own launch wrappers around it).
    """
    prob = problem.resolve(edges.n_nodes)
    mp = max_passes if max_passes is not None else prob.resolved_max_passes(edges.n_nodes)
    policy = _policy_for(prob, eps=eps, c=c)
    backend = _backend_for(prob, edges.n_nodes, degree_fn=degree_fn, tiling=tiling)
    return run_peel(
        edges, policy, backend, mp, track_history=prob.track_history
    )


def c_grid(n_nodes: int, delta: float = 2.0) -> np.ndarray:
    """Geometric grid of c = |S|/|T| guesses: delta^j covering [1/n, n]."""
    j_max = int(math.ceil(math.log(max(n_nodes, 2)) / math.log(delta)))
    return np.asarray([delta**j for j in range(-j_max, j_max + 1)], np.float32)


def stack_graphs(graphs: Sequence[EdgeList]) -> EdgeList:
    """Stacks same-shape EdgeLists along a leading batch axis for
    :meth:`Solver.solve_batch` (which also accepts the sequence directly).
    The result is a batched container: per-graph helpers that assume 1-D
    edge arrays (``n_edges_padded``, ``with_padding``) don't apply to it."""
    g0 = graphs[0]
    for g in graphs[1:]:
        if g.n_nodes != g0.n_nodes or g.n_edges_padded != g0.n_edges_padded:
            raise ValueError(
                "stacked sweeps need same-shape graphs: got "
                f"(n={g.n_nodes}, E={g.n_edges_padded}) vs "
                f"(n={g0.n_nodes}, E={g0.n_edges_padded})"
            )
        if g.directed != g0.directed:
            raise ValueError("stacked sweeps need uniform directedness")
    return EdgeList(
        src=jnp.stack([g.src for g in graphs]),
        dst=jnp.stack([g.dst for g in graphs]),
        weight=jnp.stack([g.weight for g in graphs]),
        mask=jnp.stack([g.mask for g in graphs]),
        n_nodes=g0.n_nodes,
        directed=g0.directed,
    )


def deprecated_alias_getattr(module_name: str, aliases: Dict[str, Any]):
    """Builds a module ``__getattr__`` that serves deprecated names with a
    DeprecationWarning (the PeelResult-family shims share this one body)."""

    def __getattr__(name: str):
        target = aliases.get(name)
        if target is not None:
            import warnings

            warnings.warn(
                f"{module_name}.{name} is deprecated; use "
                "repro.core.DenseSubgraphResult",
                DeprecationWarning,
                stacklevel=2,
            )
            return target
        raise AttributeError(f"module {module_name!r} has no attribute {name!r}")

    return __getattr__


def _tiling_arrays(edges: EdgeList, problem: Problem):
    """Host-side Pallas tile bucketing for this graph (runtime args of the
    cached program, so the compiled code is reusable across graphs).

    This is an O(E) numpy pass per call — the compiled program is cached but
    the bucketing is not (it depends on edge CONTENT, which a shape-keyed
    cache cannot see).  For request-rate serving of one graph, bucket once
    and pass ``degree_fn=degree_fn_from_tiling(tiled)`` instead: the hook
    keys the program cache by identity and skips the per-call rebuild."""
    from repro.kernels.peel_degree.ops import tiling_for_edges

    tiled = tiling_for_edges(
        edges, tile_size=problem.tile_size, block=problem.tile_block
    )
    return jnp.asarray(tiled.target_local), jnp.asarray(tiled.edge_index)


# ---------------------------------------------------------------------------
# Solver — compile caching + batched drivers
# ---------------------------------------------------------------------------


def _policy_name(problem: Problem) -> str:
    return {
        "undirected": "undirected_threshold",
        "at_least_k": "at_least_k_fraction",
        "directed": "directed_st",
    }[problem.objective]


def _fields_key(problem: Problem, exclude: Tuple[str, ...] = ()) -> Tuple:
    """Hashable tuple of the Problem's static fields, minus the fields a
    program takes as runtime arguments (c for directed programs, eps for
    eps-sweeps)."""
    return tuple(
        (f.name, getattr(problem, f.name))
        for f in dataclasses.fields(problem)
        if f.name not in exclude
    )


class Solver:
    """The stateful front door: memoizes jitted programs so same-shape
    requests never retrace.

    Cache key: ``(kind, problem static fields, max_passes, n_nodes,
    padded m, weight dtype, degree_fn, aux shapes | mesh)``.  ``trace_count``
    counts actual retraces (incremented inside the traced Python bodies) and
    ``cache_hits``/``cache_misses`` count program-cache lookups — the
    observability hooks the retrace tests and bench_api use.
    """

    def __init__(self):
        self._programs: Dict[Tuple, Callable] = {}
        self.cache_hits = 0
        self.cache_misses = 0
        self.trace_count = 0

    # -- cache plumbing -----------------------------------------------------
    def _mark_trace(self) -> None:
        # Runs only while jax traces the program body: a retrace counter.
        self.trace_count += 1

    def _get(self, key: Tuple, build: Callable[[], Callable]):
        fn = self._programs.get(key)
        if fn is None:
            self.cache_misses += 1
            fn = build()
            self._programs[key] = fn
            return fn, False
        self.cache_hits += 1
        return fn, True

    def cache_size(self) -> int:
        return len(self._programs)

    def _key(
        self,
        kind: str,
        problem: Problem,
        mp: int,
        n_nodes: int,
        m_padded: int,
        dtype,
        degree_fn,
        aux: Tuple = (),
    ) -> Tuple:
        # A field may only be dropped from the key when the program takes it
        # as a RUNTIME argument (c for per-c and c-sweep programs, eps for
        # eps-sweep programs — the eps/graphs sweeps bake a fixed directed c
        # into the closure, so c must key those) or when the resolved cell
        # never reads it (no spurious recompiles from irrelevant knobs).
        exclude = {"max_passes", "c_delta"}  # host-side grid loop only
        if kind in ("solve", "mesh", "c"):
            exclude.add("c")
        if kind == "eps":
            exclude.add("eps")
        if problem.objective != "at_least_k":
            exclude |= {"k", "min_deg_fallback", "ceil_count"}
        if problem.backend != "sketch":
            exclude |= {"sketch_tables", "sketch_buckets", "sketch_seed"}
        if not (problem.backend == "sketch" and problem.substrate == "mesh"):
            exclude.add("sketch_node_chunk")
        if problem.backend != "pallas":
            exclude |= {"tile_size", "tile_block"}
        if problem.substrate != "mesh":
            exclude |= {"edge_axes", "wire_dtype"}
        # Programs are never built for the streaming substrate.
        exclude |= {"stream_chunk", "stream_workers"}
        return (
            kind,
            _fields_key(problem, exclude),
            mp,
            n_nodes,
            m_padded,
            str(dtype),
            degree_fn,
            aux,
        )

    # -- program builders ---------------------------------------------------
    def _build_jit_program(
        self,
        problem: Problem,
        mp: int,
        kind: str,
        degree_fn: Optional[Callable],
        with_tiling: bool,
    ) -> Callable:
        solver = self
        directed = problem.objective == "directed"

        def cell(edges, *, eps=None, c=None, tiling=None):
            return run_cell(
                edges, problem, eps=eps, c=c, degree_fn=degree_fn,
                tiling=tiling, max_passes=mp,
            )

        if kind == "solve":
            if with_tiling:
                def fn(edges, tl, ei):
                    solver._mark_trace()
                    return cell(edges, tiling=(tl, ei))
            elif directed:
                def fn(edges, c):
                    solver._mark_trace()
                    return cell(edges, c=c)
            else:
                def fn(edges):
                    solver._mark_trace()
                    return cell(edges)
        elif kind == "eps":
            if with_tiling:
                def fn(edges, tl, ei, eps_vec):
                    solver._mark_trace()
                    return jax.vmap(
                        lambda e: cell(edges, eps=e, tiling=(tl, ei))
                    )(eps_vec)
            else:
                def fn(edges, eps_vec):
                    solver._mark_trace()
                    return jax.vmap(lambda e: cell(edges, eps=e))(eps_vec)
        elif kind == "c":
            def fn(edges, c_vec):
                solver._mark_trace()
                return jax.vmap(lambda c: cell(edges, c=c))(c_vec)
        elif kind == "graphs":
            def fn(edges):
                solver._mark_trace()
                return jax.vmap(lambda g: cell(g))(edges)
        else:
            raise ValueError(kind)
        return jax.jit(fn)

    def _build_mesh_program(
        self, problem: Problem, mp: int, mesh, n_nodes: int
    ) -> Callable:
        """shard_map substrate (§5.2): edges sharded over ``edge_axes``,
        node state replicated, one fused psum per pass."""
        from jax.sharding import PartitionSpec as P

        from repro.compat import shard_map

        axes = tuple(problem.edge_axes)
        if problem.backend == "sketch":
            from repro.core.countsketch import make_sketch_params
            from repro.core.mapreduce import _MeshSketchBackend

            backend = _MeshSketchBackend(
                params=make_sketch_params(
                    problem.sketch_tables,
                    problem.sketch_buckets,
                    problem.sketch_seed,
                ),
                axes=axes,
                node_chunk=min(problem.sketch_node_chunk, max(n_nodes, 1)),
            )
        else:
            backend = MeshSegmentSumBackend(axes, problem.wire_dtype)
        solver = self
        directed = problem.objective == "directed"

        def _local_run(src, dst, weight, mask, c=None):
            e = EdgeList(src=src, dst=dst, weight=weight, mask=mask, n_nodes=n_nodes)
            policy = _policy_for(problem, c=c)
            return run_peel(
                e, policy, backend, mp, track_history=problem.track_history
            )

        if directed:
            def local(src, dst, weight, mask, c):
                return _local_run(src, dst, weight, mask, c)

            in_specs = (P(axes),) * 4 + (P(),)
        else:
            def local(src, dst, weight, mask):
                return _local_run(src, dst, weight, mask)

            in_specs = (P(axes),) * 4

        mapped = shard_map(
            local, mesh=mesh, in_specs=in_specs, out_specs=P(), check_vma=False
        )

        def fn(*args):
            solver._mark_trace()
            return mapped(*args)

        return jax.jit(fn)

    def _mesh_fn(self, prob: Problem, mesh, n_nodes: int):
        """Cached shard_map program for a RESOLVED problem.  Keyed without
        edge shapes (jit re-keys on shard shapes internally) so
        ``make_distributed_*`` warming and ``solve(substrate='mesh')``
        serving share one compilation."""
        mp = prob.resolved_max_passes(n_nodes)
        key = self._key("mesh", prob, mp, n_nodes, -1, "sharded", None, (mesh,))
        fn, hit = self._get(
            key, lambda: self._build_mesh_program(prob, mp, mesh, n_nodes)
        )
        return fn, hit, mp

    def mesh_program(
        self, problem: Problem, mesh, n_nodes: int
    ) -> Callable:
        """The cached shard_map program ``fn(src, dst, weight, mask[, c]) ->
        PeelOutcome`` — the lowering target of the ``make_distributed_*``
        builders in core/mapreduce.py."""
        fn, _, _ = self._mesh_fn(problem.resolve(n_nodes), mesh, n_nodes)
        return fn

    # -- result wrapping ----------------------------------------------------
    def _wrap(
        self,
        out: PeelOutcome,
        problem: Problem,
        n_nodes: int,
        mp: int,
        cache_hit: bool,
        extras: Optional[Dict[str, Any]] = None,
        batch: Optional[str] = None,
    ) -> DenseSubgraphResult:
        prov = Provenance(
            objective=problem.objective,
            policy=_policy_name(problem),
            backend=problem.backend,
            substrate=problem.substrate,
            n_nodes=n_nodes,
            max_passes=mp,
            batch=batch,
            cache_hit=cache_hit,
        )
        return DenseSubgraphResult.from_outcome(out, provenance=prov, extras=extras)

    # -- solve --------------------------------------------------------------
    def solve(
        self,
        graph: EdgeList,
        problem: Problem,
        *,
        mesh=None,
        degree_fn: Optional[Callable] = None,
        checkpoint_dir: Optional[str] = None,
        resume: bool = False,
    ) -> DenseSubgraphResult:
        """Runs one Problem on one graph.  ``mesh`` is required for the mesh
        substrate; ``checkpoint_dir``/``resume`` apply to streaming;
        ``degree_fn`` is the legacy custom-degree hook (keys the cache by
        identity)."""
        if not isinstance(graph, EdgeList):
            raise TypeError(
                f"solve() takes an EdgeList graph, got {type(graph).__name__}"
            )
        prob = problem.resolve(graph.n_nodes, have_mesh=mesh is not None)
        if prob.substrate != "streaming" and (checkpoint_dir is not None or resume):
            raise ValueError(
                "checkpoint_dir/resume only apply to substrate='streaming'"
            )
        if prob.substrate == "streaming":
            if degree_fn is not None:
                raise ValueError(
                    "degree_fn hooks only apply to the jit substrate"
                )
            return self._solve_streaming(graph, prob, checkpoint_dir, resume)
        if prob.substrate == "mesh":
            if degree_fn is not None:
                raise ValueError(
                    "degree_fn hooks only apply to the jit substrate; mesh "
                    "runs need a psum'ing backend (backend='exact'|'sketch')"
                )
            return self._solve_mesh(graph, prob, mesh)

        n = graph.n_nodes
        mp = prob.resolved_max_passes(n)
        with_tiling = prob.backend == "pallas" and degree_fn is None
        aux: Tuple = ()
        if with_tiling:
            aux = _tiling_arrays(graph, prob)
        key = self._key(
            "solve", prob, mp, n, graph.n_edges_padded,
            graph.weight.dtype, degree_fn, tuple(a.shape for a in aux),
        )
        fn, hit = self._get(
            key,
            lambda: self._build_jit_program(prob, mp, "solve", degree_fn, with_tiling),
        )
        if prob.objective == "directed":
            if prob.c is None:
                return self._directed_grid(graph, prob, mp, fn, hit)
            out = fn(graph, jnp.float32(prob.c))
        else:
            out = fn(graph, *aux)
        return self._wrap(out, prob, n, mp, hit)

    def _directed_grid(
        self, graph: EdgeList, prob: Problem, mp: int, fn, hit: bool
    ) -> DenseSubgraphResult:
        """The paper's practical directed recipe: sweep the geometric c-grid
        through ONE compiled per-c program (c is a runtime scalar)."""
        grid = c_grid(graph.n_nodes, prob.c_delta)
        best = None
        best_c = None
        rhos = []
        passes = []
        for c in grid:
            out = fn(graph, jnp.float32(c))
            rho = float(out.best_density)
            rhos.append(rho)
            passes.append(int(out.passes))
            if best is None or rho > float(best.best_density):
                best, best_c = out, float(c)
        extras = {
            "best_c": best_c,
            "c_grid": np.asarray(grid),
            "c_density": np.asarray(rhos),
            "c_passes": np.asarray(passes),
        }
        return self._wrap(best, prob, graph.n_nodes, mp, hit, extras=extras)

    def _solve_mesh(
        self, graph: EdgeList, prob: Problem, mesh
    ) -> DenseSubgraphResult:
        if mesh is None:
            raise ValueError("substrate='mesh' needs solve(..., mesh=Mesh)")
        from repro.core.mapreduce import shard_edges

        sh = shard_edges(graph, mesh, prob.edge_axes)
        fn, hit, mp = self._mesh_fn(prob, mesh, sh.n_nodes)
        if prob.objective == "directed":
            if prob.c is None:
                grid_fn = lambda e, c: fn(e.src, e.dst, e.weight, e.mask, c)
                return self._directed_grid(sh, prob, mp, grid_fn, hit)
            out = fn(sh.src, sh.dst, sh.weight, sh.mask, jnp.float32(prob.c))
        else:
            out = fn(sh.src, sh.dst, sh.weight, sh.mask)
        return self._wrap(out, prob, sh.n_nodes, mp, hit)

    def _solve_streaming(
        self,
        graph: EdgeList,
        prob: Problem,
        checkpoint_dir: Optional[str],
        resume: bool,
    ) -> DenseSubgraphResult:
        """Semi-streaming substrate: chunked multi-pass driver with O(n)
        node state (StreamingDensest keeps the checkpoint/straggler logic)."""
        from repro.core.streaming import StreamingDensest, chunked_from_arrays

        mask = np.asarray(graph.mask)
        src = np.asarray(graph.src)[mask]
        dst = np.asarray(graph.dst)[mask]
        w = np.asarray(graph.weight)[mask]
        drv = StreamingDensest(
            chunked_from_arrays(src, dst, w, chunk=prob.stream_chunk),
            n_nodes=graph.n_nodes,
            eps=prob.eps,
            checkpoint_dir=checkpoint_dir,
            n_workers=prob.stream_workers,
        )
        st = drv.run(max_passes=prob.max_passes, resume=resume)
        mp = prob.resolved_max_passes(graph.n_nodes)
        hist = np.asarray(st.history, np.float64).reshape(-1, 3)
        best_alive = jnp.asarray(st.best_alive)
        out = PeelOutcome(
            best_alive=best_alive,
            best_t=jnp.zeros((0,), bool),
            best_density=jnp.asarray(st.best_rho, jnp.float32),
            best_size=jnp.sum(best_alive.astype(jnp.int32)),
            passes=jnp.asarray(st.pass_idx, jnp.int32),
            alive=jnp.asarray(st.alive),
            t_alive=jnp.zeros((0,), bool),
            history_n=jnp.asarray(hist[:, 0], jnp.int32),
            history_m=jnp.asarray(hist[:, 1], jnp.float32),
            history_rho=jnp.asarray(hist[:, 2], jnp.float32),
        )
        return self._wrap(out, prob, graph.n_nodes, mp, cache_hit=False)

    # -- solve_batch --------------------------------------------------------
    def solve_batch(
        self,
        graph: Union[EdgeList, Sequence[EdgeList]],
        problem: Problem,
        *,
        eps=None,
        c=None,
        degree_fn: Optional[Callable] = None,
    ) -> DenseSubgraphResult:
        """One XLA program for a whole sweep (ROADMAP batched driver).

        Exactly one batch axis: ``eps=`` (vector of eps values), ``c=``
        (vector of directed ratio guesses), or a sequence of same-shape
        graphs.  Every array of the result gains a leading sweep axis; the
        engine's vmapped while_loop runs to the slowest lane but each lane's
        values are bit-identical to its standalone solve (for eps values
        exactly representable in float32).

        With ``max_passes=None`` the static trip bound is taken at the
        loosest point of the sweep (min eps); pass an explicit
        ``Problem.max_passes`` to pin it.
        """
        stacked = isinstance(graph, (list, tuple)) or (
            isinstance(graph, EdgeList) and graph.src.ndim == 2
        )
        if sum(x is not None for x in (eps, c)) + stacked != 1:
            raise ValueError(
                "solve_batch needs exactly one batch axis: eps=, c=, or "
                "stacked same-shape graphs (a sequence or a stack_graphs result)"
            )

        if stacked:
            batched = graph if isinstance(graph, EdgeList) else stack_graphs(list(graph))
            prob = problem.resolve(batched.n_nodes)
            if prob.substrate != "jit":
                raise ValueError("solve_batch runs on the jit substrate")
            if prob.backend == "pallas":
                raise ValueError(
                    "stacked-graph sweeps need a graph-independent backend "
                    "(tile bucketing is per-graph); use exact or sketch"
                )
            if prob.objective == "directed" and prob.c is None:
                raise ValueError("stacked directed sweeps need a fixed c")
            mp = prob.resolved_max_passes(batched.n_nodes)
            key = self._key(
                "graphs", prob, mp, batched.n_nodes, batched.src.shape,
                batched.weight.dtype, degree_fn,
            )
            fn, hit = self._get(
                key,
                lambda: self._build_jit_program(prob, mp, "graphs", degree_fn, False),
            )
            out = fn(batched)
            return self._wrap(out, prob, batched.n_nodes, mp, hit, batch="graphs")

        if not isinstance(graph, EdgeList):
            raise TypeError(
                f"solve_batch takes an EdgeList or a sequence, got {type(graph).__name__}"
            )
        prob = problem.resolve(graph.n_nodes)
        if prob.substrate != "jit":
            raise ValueError("solve_batch runs on the jit substrate")
        n = graph.n_nodes

        if eps is not None:
            eps_host = np.asarray(eps, np.float32).reshape(-1)
            if prob.max_passes is not None:
                mp = int(prob.max_passes)
            else:
                loosest = dataclasses.replace(prob, eps=float(eps_host.min()))
                mp = loosest.resolved_max_passes(n)
            if prob.objective == "directed" and prob.c is None:
                raise ValueError("eps sweeps over a directed Problem need a fixed c")
            with_tiling = prob.backend == "pallas" and degree_fn is None
            aux: Tuple = _tiling_arrays(graph, prob) if with_tiling else ()
            key = self._key(
                "eps", prob, mp, n, graph.n_edges_padded,
                graph.weight.dtype, degree_fn, tuple(a.shape for a in aux),
            )
            fn, hit = self._get(
                key,
                lambda: self._build_jit_program(prob, mp, "eps", degree_fn, with_tiling),
            )
            out = fn(graph, *aux, jnp.asarray(eps_host))
            return self._wrap(out, prob, n, mp, hit, batch="eps")

        # c sweep (directed only)
        if prob.objective != "directed":
            raise ValueError("c sweeps only apply to the directed objective")
        c_host = np.asarray(c, np.float32).reshape(-1)
        mp = prob.resolved_max_passes(n)
        key = self._key(
            "c", prob, mp, n, graph.n_edges_padded,
            graph.weight.dtype, degree_fn,
        )
        fn, hit = self._get(
            key, lambda: self._build_jit_program(prob, mp, "c", degree_fn, False)
        )
        out = fn(graph, jnp.asarray(c_host))
        return self._wrap(out, prob, n, mp, hit, batch="c")


# ---------------------------------------------------------------------------
# Module-level front door (one shared program cache)
# ---------------------------------------------------------------------------

default_solver = Solver()


def solve(graph: EdgeList, problem: Problem, **kw) -> DenseSubgraphResult:
    """``Solver.solve`` on the process-wide :data:`default_solver` (shared
    compile cache — the production entry point and the target of every
    legacy wrapper)."""
    return default_solver.solve(graph, problem, **kw)


def solve_batch(graph, problem: Problem, **kw) -> DenseSubgraphResult:
    """``Solver.solve_batch`` on the process-wide :data:`default_solver`."""
    return default_solver.solve_batch(graph, problem, **kw)
