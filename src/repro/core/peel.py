"""Algorithm 1 — (2+2eps)-approximate densest subgraph for undirected graphs.

The whole O(log_{1+eps} n)-pass algorithm compiles to a single
``jax.lax.while_loop``: each iteration is one streaming/MapReduce pass of the
paper (degree count + density + threshold removal).  A ``degree_fn`` hook lets
the Count-Sketch variant (§5.1) reuse the identical loop.

The removal rule adds one safeguard on top of the paper's: when floating-point
rounding would make ``A(S)`` empty (mathematically impossible since the
minimum degree is <= 2 rho(S)), the current minimum-degree nodes are removed.
This preserves the approximation proof verbatim (a removed node i in S* still
has deg_S(i) <= 2(1+eps) rho(S)) and guarantees progress.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.density import (
    alive_edge_weight,
    exact_degrees,
    max_passes_bound,
)
from repro.graph.edgelist import EdgeList


class PeelResult(NamedTuple):
    best_alive: jax.Array  # bool[N] the output subgraph S~
    best_density: jax.Array  # float32[] rho(S~)
    passes: jax.Array  # int32[] number of while-loop passes executed
    # Per-pass trajectory (for Fig 6.2/6.3-style analyses); padded with -1/0.
    history_n: jax.Array  # int32[max_passes]
    history_m: jax.Array  # float32[max_passes]
    history_rho: jax.Array  # float32[max_passes]


class _State(NamedTuple):
    alive: jax.Array
    best_alive: jax.Array
    best_rho: jax.Array
    t: jax.Array
    history_n: jax.Array
    history_m: jax.Array
    history_rho: jax.Array


def _default_degree_fn(edges: EdgeList, w_alive: jax.Array) -> jax.Array:
    return exact_degrees(edges, w_alive)


@partial(jax.jit, static_argnames=("eps", "max_passes", "degree_fn", "track_history"))
def densest_subgraph(
    edges: EdgeList,
    eps: float = 0.5,
    max_passes: Optional[int] = None,
    degree_fn: Callable[[EdgeList, jax.Array], jax.Array] = _default_degree_fn,
    track_history: bool = True,
) -> PeelResult:
    """Runs Algorithm 1 and returns the best intermediate subgraph."""
    n = edges.n_nodes
    if max_passes is None:
        max_passes = max_passes_bound(n, eps)
    hist_len = max_passes if track_history else 1

    def loop_stats(alive):
        w_alive = alive_edge_weight(edges, alive)
        deg = degree_fn(edges, w_alive)
        total = jnp.sum(w_alive)
        n_alive = jnp.sum(alive.astype(jnp.int32))
        rho = jnp.where(n_alive > 0, total / jnp.maximum(n_alive, 1), 0.0)
        return deg, total, n_alive, rho

    def cond(s: _State):
        return (jnp.sum(s.alive.astype(jnp.int32)) > 0) & (s.t < max_passes)

    def body(s: _State) -> _State:
        deg, total, n_alive, rho = loop_stats(s.alive)
        # Track the best set seen so far (each intermediate S is evaluated
        # when it becomes current; S_0 = V is evaluated at t=0).
        improved = rho > s.best_rho
        best_alive = jnp.where(improved, s.alive, s.best_alive)
        best_rho = jnp.maximum(rho, s.best_rho)

        thresh = 2.0 * (1.0 + eps) * rho
        # Exact degrees are float; use the min-degree fallback for progress.
        deg_alive = jnp.where(s.alive, deg, jnp.inf)
        min_deg = jnp.min(deg_alive)
        remove = s.alive & ((deg <= thresh) | (deg <= min_deg))
        alive = s.alive & ~remove

        if track_history:
            hn = s.history_n.at[s.t].set(n_alive)
            hm = s.history_m.at[s.t].set(total)
            hr = s.history_rho.at[s.t].set(rho)
        else:
            hn, hm, hr = s.history_n, s.history_m, s.history_rho
        return _State(alive, best_alive, best_rho, s.t + 1, hn, hm, hr)

    init = _State(
        alive=jnp.ones((n,), bool) ,
        best_alive=jnp.ones((n,), bool),
        best_rho=jnp.asarray(-jnp.inf, jnp.float32),
        t=jnp.asarray(0, jnp.int32),
        history_n=jnp.full((hist_len,), -1, jnp.int32),
        history_m=jnp.zeros((hist_len,), jnp.float32),
        history_rho=jnp.zeros((hist_len,), jnp.float32),
    )
    out = jax.lax.while_loop(cond, body, init)
    return PeelResult(
        best_alive=out.best_alive,
        best_density=out.best_rho,
        passes=out.t,
        history_n=out.history_n,
        history_m=out.history_m,
        history_rho=out.history_rho,
    )


def densest_subgraph_sets(edges: EdgeList, eps: float = 0.5, **kw):
    """Convenience host-side wrapper returning (node_index_array, density)."""
    import numpy as np

    res = densest_subgraph(edges, eps=eps, **kw)
    alive = np.asarray(res.best_alive)
    return np.nonzero(alive)[0], float(res.best_density)
