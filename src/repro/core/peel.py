"""Algorithm 1 — (2+2eps)-approximate densest subgraph for undirected graphs.

Thin wrapper over the PeelEngine (core/engine.py): Algorithm 1 is the
``UndirectedThreshold`` policy on the exact segment-sum backend, jitted as a
single ``lax.while_loop`` program.  A ``degree_fn`` hook lets the
Count-Sketch (§5.1) and Pallas tiled-degree backends reuse the identical
loop via :class:`repro.core.engine.FnBackend`.

The removal rule adds one safeguard on top of the paper's: when floating-point
rounding would make ``A(S)`` empty (mathematically impossible since the
minimum degree is <= 2 rho(S)), the current minimum-degree nodes are removed.
This preserves the approximation proof verbatim (a removed node i in S* still
has deg_S(i) <= 2(1+eps) rho(S)) and guarantees progress.
"""

from __future__ import annotations

from functools import partial
from typing import Callable, Optional

import jax

from repro.core.density import exact_degrees, max_passes_bound
from repro.core.engine import (
    FnBackend,
    PeelOutcome,
    UndirectedThreshold,
    run_peel,
)
from repro.graph.edgelist import EdgeList

# The engine outcome IS the public result type (best_alive, best_density,
# passes, history_*) — kept under the historical name.
PeelResult = PeelOutcome


def _default_degree_fn(edges: EdgeList, w_alive: jax.Array) -> jax.Array:
    return exact_degrees(edges, w_alive)


@partial(jax.jit, static_argnames=("eps", "max_passes", "degree_fn", "track_history"))
def densest_subgraph(
    edges: EdgeList,
    eps: float = 0.5,
    max_passes: Optional[int] = None,
    degree_fn: Callable[[EdgeList, jax.Array], jax.Array] = _default_degree_fn,
    track_history: bool = True,
) -> PeelResult:
    """Runs Algorithm 1 and returns the best intermediate subgraph."""
    if max_passes is None:
        max_passes = max_passes_bound(edges.n_nodes, eps)
    return run_peel(
        edges,
        UndirectedThreshold(eps),
        FnBackend(degree_fn),
        max_passes,
        track_history=track_history,
    )


def densest_subgraph_sets(edges: EdgeList, eps: float = 0.5, **kw):
    """Convenience host-side wrapper returning (node_index_array, density)."""
    import numpy as np

    res = densest_subgraph(edges, eps=eps, **kw)
    alive = np.asarray(res.best_alive)
    return np.nonzero(alive)[0], float(res.best_density)
