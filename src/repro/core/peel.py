"""Algorithm 1 — (2+2eps)-approximate densest subgraph for undirected graphs.

Thin delegation through the front door (core/api.py): Algorithm 1 is
``Problem.undirected(eps)`` lowered onto the ``UndirectedThreshold`` policy
and the exact segment-sum backend, jitted as a single ``lax.while_loop``
program and memoized by the default :class:`~repro.core.api.Solver` so
repeated same-shape calls never retrace.  A ``degree_fn`` hook lets the
Count-Sketch (§5.1) and Pallas tiled-degree backends reuse the identical
loop via :class:`repro.core.engine.FnBackend`.

The removal rule adds one safeguard on top of the paper's: when floating-point
rounding would make ``A(S)`` empty (mathematically impossible since the
minimum degree is <= 2 rho(S)), the current minimum-degree nodes are removed.
This preserves the approximation proof verbatim (a removed node i in S* still
has deg_S(i) <= 2(1+eps) rho(S)) and guarantees progress.
"""

from __future__ import annotations

from typing import Callable, Optional

import jax

from repro.core.api import (
    DenseSubgraphResult,
    Problem,
    deprecated_alias_getattr,
    solve,
)
from repro.core.density import exact_degrees
from repro.graph.edgelist import EdgeList


def _default_degree_fn(edges: EdgeList, w_alive: jax.Array) -> jax.Array:
    return exact_degrees(edges, w_alive)


def densest_subgraph(
    edges: EdgeList,
    eps: float = 0.5,
    max_passes: Optional[int] = None,
    degree_fn: Callable[[EdgeList, jax.Array], jax.Array] = _default_degree_fn,
    track_history: bool = True,
    compaction: str = "off",
) -> DenseSubgraphResult:
    """Runs Algorithm 1 and returns the best intermediate subgraph.

    ``compaction='geometric'`` runs the same loop through the amortized-O(m)
    compaction ladder (bit-identical results for integer-valued weights; see
    ``Problem.compaction``).  Incompatible with a custom ``degree_fn``,
    which binds one fixed graph."""
    problem = Problem.undirected(
        eps=eps, max_passes=max_passes, track_history=track_history,
        compaction=compaction,
    )
    hook = None if degree_fn is _default_degree_fn else degree_fn
    return solve(edges, problem, degree_fn=hook)


def densest_subgraph_sets(edges: EdgeList, eps: float = 0.5, **kw):
    """Convenience host-side wrapper returning (node_index_array, density)."""
    res = densest_subgraph(edges, eps=eps, **kw)
    return res.nodes(), float(res.best_density)


__getattr__ = deprecated_alias_getattr(
    __name__, {"PeelResult": DenseSubgraphResult}
)
