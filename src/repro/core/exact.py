"""Exact densest-subgraph solvers (the paper's §6.2 quality oracle).

The paper solves the LP of Charikar [10] with COIN-OR CLP; offline we use the
other exact method the paper cites — Goldberg's max-flow characterization —
via ``scipy.sparse.csgraph.maximum_flow`` with exact rational binary search:
distinct subgraph densities are fractions with denominator <= n, so two
distinct densities differ by at least 1/(n(n-1)); once the search interval is
narrower than that, the last feasible cut's source side is an *exact* optimum.

A brute-force subset enumerator (n <= 20) validates the flow solver in tests.
"""

from __future__ import annotations

from itertools import combinations
from typing import Tuple

import numpy as np
from scipy.sparse import csr_matrix
from scipy.sparse.csgraph import maximum_flow

from repro.graph.edgelist import EdgeList


def _edges_numpy(edges: EdgeList) -> Tuple[np.ndarray, np.ndarray, int]:
    mask = np.asarray(edges.mask)
    src = np.asarray(edges.src)[mask].astype(np.int64)
    dst = np.asarray(edges.dst)[mask].astype(np.int64)
    return src, dst, edges.n_nodes


def densest_subgraph_exact(edges: EdgeList) -> Tuple[np.ndarray, float]:
    """Exact maximum-density subgraph of an unweighted undirected graph.

    Returns (node_indices, density).  Uses Goldberg's network:
      cap(s, v) = m;  cap(v, t) = m + 2g - deg(v);  cap(u<->v) = 1 per edge,
    scaled by the rational denominator of g to keep capacities integral.
    There is a subgraph with density > g  iff  mincut < m * n.
    """
    src, dst, n = _edges_numpy(edges)
    m = src.shape[0]
    if m == 0:
        return np.asarray([0]), 0.0
    deg = np.zeros(n, np.int64)
    np.add.at(deg, src, 1)
    np.add.at(deg, dst, 1)

    s_id, t_id = n, n + 1

    def feasible(p: int, q: int) -> Tuple[bool, np.ndarray]:
        """Is there S with rho(S) > p/q?  Capacities scaled by q."""
        rows = np.concatenate([
            np.full(n, s_id), np.arange(n), src, dst,
        ])
        cols = np.concatenate([
            np.arange(n), np.full(n, t_id), dst, src,
        ])
        caps = np.concatenate([
            np.full(n, m * q, np.int64),
            m * q + 2 * p - q * deg,
            np.full(m, q, np.int64),
            np.full(m, q, np.int64),
        ])
        graph = csr_matrix((caps, (rows, cols)), shape=(n + 2, n + 2))
        res = maximum_flow(graph, s_id, t_id)
        if res.flow_value >= m * n * q:
            return False, np.asarray([], np.int64)
        # Source side of the min cut via BFS on the residual graph.
        residual = graph - res.flow
        residual.data = np.maximum(residual.data, 0)
        seen = np.zeros(n + 2, bool)
        seen[s_id] = True
        frontier = [s_id]
        indptr, indices, data = residual.indptr, residual.indices, residual.data
        while frontier:
            u = frontier.pop()
            for e in range(indptr[u], indptr[u + 1]):
                v = indices[e]
                if data[e] > 0 and not seen[v]:
                    seen[v] = True
                    frontier.append(v)
        side = np.nonzero(seen[:n])[0]
        return side.size > 0, side

    # Dinkelbach iteration: repeatedly ask "is there S with rho(S) > p/q?"
    # starting from rho(V) and jumping to the witness's own density.  Every
    # candidate density is |E(S)|/|S| so q <= n and the scaled capacities
    # stay ~m*n (a rational *binary* search needs denominators up to n(n-1),
    # which overflowed the flow solver's capacities beyond n ~ 10^3 and
    # silently returned garbage — caught by examples/quickstart.py).
    # Strictly increasing densities => termination; typically <= ~10 cuts.
    best_side = np.arange(n)
    p_cur, q_cur = m, n  # rho(V)
    for _ in range(4 * n):  # worst-case guard; practice: a handful
        ok, side = feasible(p_cur, q_cur)
        if not ok or side.size == 0:
            break
        inset = np.zeros(n, bool)
        inset[side] = True
        p_new = int(np.sum(inset[src] & inset[dst]))
        q_new = int(side.size)
        if p_new * q_cur <= p_cur * q_new:  # no strict improvement: done
            break
        best_side, p_cur, q_cur = side, p_new, q_new
    dens = _density_np(src, dst, best_side, n)
    return best_side, dens


def _density_np(src: np.ndarray, dst: np.ndarray, nodes: np.ndarray, n: int) -> float:
    inset = np.zeros(n, bool)
    inset[nodes] = True
    m_in = int(np.sum(inset[src] & inset[dst]))
    return m_in / max(len(nodes), 1)


def densest_subgraph_brute(edges: EdgeList) -> Tuple[np.ndarray, float]:
    """Brute-force over all non-empty subsets; n <= 20 only (test oracle)."""
    src, dst, n = _edges_numpy(edges)
    assert n <= 20, "brute force limited to tiny graphs"
    best_nodes, best = np.asarray([0]), -1.0
    for size in range(1, n + 1):
        for comb in combinations(range(n), size):
            nodes = np.asarray(comb)
            d = _density_np(src, dst, nodes, n)
            if d > best:
                best, best_nodes = d, nodes
    return best_nodes, best


def densest_directed_brute(edges: EdgeList) -> Tuple[np.ndarray, np.ndarray, float]:
    """Brute force over S, T subsets for directed density (n <= 10)."""
    src, dst, n = _edges_numpy(edges)
    assert n <= 10
    best = (-1.0, np.asarray([0]), np.asarray([0]))
    subsets = []
    for size in range(1, n + 1):
        subsets.extend(combinations(range(n), size))
    for S in subsets:
        s_mask = np.zeros(n, bool)
        s_mask[list(S)] = True
        for T in subsets:
            t_mask = np.zeros(n, bool)
            t_mask[list(T)] = True
            m_in = int(np.sum(s_mask[src] & t_mask[dst]))
            d = m_in / np.sqrt(len(S) * len(T))
            if d > best[0]:
                best = (d, np.asarray(S), np.asarray(T))
    return best[1], best[2], best[0]
