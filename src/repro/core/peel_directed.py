"""Algorithm 3 — (2+2eps)-approximate densest subgraph for directed graphs.

Thin wrapper over the PeelEngine: the ``DirectedST`` policy (dual S/T
bitmaps; when |S|/|T| >= c it peels S by out-degree into T, otherwise peels
T by in-degree from S — the paper's simplified size-based choice, §4.3) on
the exact backend.  A geometric grid of c values (resolution delta) costs at
most an extra delta factor in the approximation (§6.4);
``densest_directed_search`` runs the grid, and because c enters the policy
as a traced scalar the whole grid also batches under ``vmap``.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.density import max_passes_bound
from repro.core.engine import DirectedST, ExactBackend, PeelOutcome, run_peel
from repro.graph.edgelist import EdgeList

DirectedPeelResult = PeelOutcome  # best_s / best_t / best_density / passes


@partial(jax.jit, static_argnames=("eps", "max_passes"))
def densest_subgraph_directed(
    edges: EdgeList,
    c: jax.Array | float,
    eps: float = 0.5,
    max_passes: Optional[int] = None,
) -> DirectedPeelResult:
    """Algorithm 3 for one value of c (c may be a traced scalar)."""
    if max_passes is None:
        # Either |S| or |T| shrinks by 1/(1+eps) per pass (Lemma 13).
        max_passes = 2 * max_passes_bound(edges.n_nodes, eps)
    policy = DirectedST(eps=eps, c=jnp.asarray(c, jnp.float32))
    return run_peel(edges, policy, ExactBackend(), max_passes)


def c_grid(n_nodes: int, delta: float = 2.0) -> np.ndarray:
    """Geometric grid of c = |S|/|T| guesses: delta^j covering [1/n, n]."""
    j_max = int(math.ceil(math.log(max(n_nodes, 2)) / math.log(delta)))
    return np.asarray([delta**j for j in range(-j_max, j_max + 1)], np.float32)


def densest_directed_search(
    edges: EdgeList,
    eps: float = 0.5,
    delta: float = 2.0,
    max_passes: Optional[int] = None,
):
    """Grid search over c (the paper's practical recipe).

    Returns (result, best_c, per_c_densities, per_c_passes).  One compilation
    is reused across all c values because c enters as a traced scalar.
    """
    best = None
    best_c = None
    rhos = []
    passes = []
    for c in c_grid(edges.n_nodes, delta):
        r = densest_subgraph_directed(edges, float(c), eps=eps, max_passes=max_passes)
        rho = float(r.best_density)
        rhos.append(rho)
        passes.append(int(r.passes))
        if best is None or rho > float(best.best_density):
            best, best_c = r, float(c)
    return best, best_c, np.asarray(rhos), np.asarray(passes)


def densest_directed_search_vmapped(
    edges: EdgeList,
    eps: float = 0.5,
    delta: float = 2.0,
    max_passes: Optional[int] = None,
):
    """The whole c grid in ONE compiled program via vmap (beyond-paper).

    The paper evaluates c values as separate runs (~35 min/c on Hadoop for
    TWITTER); c enters Algorithm 3 only through the peel-S-or-T branch, so
    the grid batches cleanly: every streaming pass over the edges serves all
    c values simultaneously — the same amortize-across-instances trick the
    paper's sketch uses across its t hash tables.  Pass count becomes the
    max over the grid (vmapped while_loop runs to the slowest c), which is
    the right trade once edge I/O dominates.

    Returns (best_c, best_rho, rhos[n_c], passes[n_c]).
    """
    cs = jnp.asarray(c_grid(edges.n_nodes, delta))

    def one(c):
        r = densest_subgraph_directed(edges, c, eps=eps, max_passes=max_passes)
        return r.best_density, r.passes

    rhos, passes = jax.jit(jax.vmap(one))(cs)
    best_i = int(jnp.argmax(rhos))
    return float(cs[best_i]), float(rhos[best_i]), np.asarray(rhos), np.asarray(passes)
