"""Algorithm 3 — (2+2eps)-approximate densest subgraph for directed graphs.

For a fixed ratio guess c = |S|/|T|, the algorithm alternates: when
|S|/|T| >= c it peels S by out-degree into T, otherwise peels T by in-degree
from S (the paper's simplified size-based choice, §4.3).  A geometric grid of
c values (resolution delta) costs at most an extra delta factor in the
approximation (§6.4); ``densest_directed_search`` runs the grid.
"""

from __future__ import annotations

import math
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.density import directed_stats, max_passes_bound
from repro.graph.edgelist import EdgeList


class DirectedPeelResult(NamedTuple):
    best_s: jax.Array  # bool[N]
    best_t: jax.Array  # bool[N]
    best_density: jax.Array
    passes: jax.Array


class _State(NamedTuple):
    s_alive: jax.Array
    t_alive: jax.Array
    best_s: jax.Array
    best_t: jax.Array
    best_rho: jax.Array
    t: jax.Array


@partial(jax.jit, static_argnames=("eps", "max_passes"))
def densest_subgraph_directed(
    edges: EdgeList,
    c: jax.Array | float,
    eps: float = 0.5,
    max_passes: Optional[int] = None,
) -> DirectedPeelResult:
    """Algorithm 3 for one value of c (c may be a traced scalar)."""
    n = edges.n_nodes
    if max_passes is None:
        # Either |S| or |T| shrinks by 1/(1+eps) per pass (Lemma 13).
        max_passes = 2 * max_passes_bound(n, eps)
    c = jnp.asarray(c, jnp.float32)

    def cond(s: _State):
        ns = jnp.sum(s.s_alive.astype(jnp.int32))
        nt = jnp.sum(s.t_alive.astype(jnp.int32))
        return (ns > 0) & (nt > 0) & (s.t < max_passes)

    def body(s: _State) -> _State:
        st = directed_stats(edges, s.s_alive, s.t_alive)
        improved = st.density > s.best_rho
        best_s = jnp.where(improved, s.s_alive, s.best_s)
        best_t = jnp.where(improved, s.t_alive, s.best_t)
        best_rho = jnp.maximum(st.density, s.best_rho)

        ns_f = jnp.maximum(st.n_s.astype(jnp.float32), 1.0)
        nt_f = jnp.maximum(st.n_t.astype(jnp.float32), 1.0)
        peel_s = ns_f / nt_f >= c

        # Peel S by out-degree (with min-degree progress fallback).
        thr_s = (1.0 + eps) * st.total_weight / ns_f
        outd = jnp.where(s.s_alive, st.out_deg, jnp.inf)
        min_out = jnp.min(outd)
        rm_s = s.s_alive & ((st.out_deg <= thr_s) | (st.out_deg <= min_out))
        # Peel T by in-degree.
        thr_t = (1.0 + eps) * st.total_weight / nt_f
        ind = jnp.where(s.t_alive, st.in_deg, jnp.inf)
        min_in = jnp.min(ind)
        rm_t = s.t_alive & ((st.in_deg <= thr_t) | (st.in_deg <= min_in))

        s_alive = jnp.where(peel_s, s.s_alive & ~rm_s, s.s_alive)
        t_alive = jnp.where(peel_s, s.t_alive, s.t_alive & ~rm_t)
        return _State(s_alive, t_alive, best_s, best_t, best_rho, s.t + 1)

    init = _State(
        s_alive=jnp.ones((n,), bool),
        t_alive=jnp.ones((n,), bool),
        best_s=jnp.ones((n,), bool),
        best_t=jnp.ones((n,), bool),
        best_rho=jnp.asarray(-jnp.inf, jnp.float32),
        t=jnp.asarray(0, jnp.int32),
    )
    out = jax.lax.while_loop(cond, body, init)
    return DirectedPeelResult(out.best_s, out.best_t, out.best_rho, out.t)


def c_grid(n_nodes: int, delta: float = 2.0) -> np.ndarray:
    """Geometric grid of c = |S|/|T| guesses: delta^j covering [1/n, n]."""
    j_max = int(math.ceil(math.log(max(n_nodes, 2)) / math.log(delta)))
    return np.asarray([delta**j for j in range(-j_max, j_max + 1)], np.float32)


def densest_directed_search(
    edges: EdgeList,
    eps: float = 0.5,
    delta: float = 2.0,
    max_passes: Optional[int] = None,
):
    """Grid search over c (the paper's practical recipe).

    Returns (result, best_c, per_c_densities, per_c_passes).  One compilation
    is reused across all c values because c enters as a traced scalar.
    """
    best = None
    best_c = None
    rhos = []
    passes = []
    for c in c_grid(edges.n_nodes, delta):
        r = densest_subgraph_directed(edges, float(c), eps=eps, max_passes=max_passes)
        rho = float(r.best_density)
        rhos.append(rho)
        passes.append(int(r.passes))
        if best is None or rho > float(best.best_density):
            best, best_c = r, float(c)
    return best, best_c, np.asarray(rhos), np.asarray(passes)


def densest_directed_search_vmapped(
    edges: EdgeList,
    eps: float = 0.5,
    delta: float = 2.0,
    max_passes: Optional[int] = None,
):
    """The whole c grid in ONE compiled program via vmap (beyond-paper).

    The paper evaluates c values as separate runs (~35 min/c on Hadoop for
    TWITTER); c enters Algorithm 3 only through the peel-S-or-T branch, so
    the grid batches cleanly: every streaming pass over the edges serves all
    c values simultaneously — the same amortize-across-instances trick the
    paper's sketch uses across its t hash tables.  Pass count becomes the
    max over the grid (vmapped while_loop runs to the slowest c), which is
    the right trade once edge I/O dominates.

    Returns (best_c, best_rho, rhos[n_c], passes[n_c]).
    """
    cs = jnp.asarray(c_grid(edges.n_nodes, delta))

    def one(c):
        r = densest_subgraph_directed(edges, c, eps=eps, max_passes=max_passes)
        return r.best_density, r.passes

    rhos, passes = jax.jit(jax.vmap(one))(cs)
    best_i = int(jnp.argmax(rhos))
    return float(cs[best_i]), float(rhos[best_i]), np.asarray(rhos), np.asarray(passes)
