"""Algorithm 3 — (2+2eps)-approximate densest subgraph for directed graphs.

Thin delegation through the front door (core/api.py): ``Problem.directed``
lowers onto the ``DirectedST`` policy (dual S/T bitmaps; when |S|/|T| >= c
it peels S by out-degree into T, otherwise peels T by in-degree from S — the
paper's simplified size-based choice, §4.3) on the exact backend.  A
geometric grid of c values (resolution delta) costs at most an extra delta
factor in the approximation (§6.4); ``c=None`` runs the grid through ONE
cached compiled program (c enters as a runtime scalar), and
``densest_directed_search_vmapped`` batches the whole grid as one XLA
program via ``solve_batch``.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.api import (
    DenseSubgraphResult,
    Problem,
    c_grid,
    deprecated_alias_getattr,
    run_cell,
    solve,
    solve_batch,
)
from repro.graph.edgelist import EdgeList

__all__ = [
    "c_grid",
    "densest_directed_search",
    "densest_directed_search_vmapped",
    "densest_subgraph_directed",
]


def densest_subgraph_directed(
    edges: EdgeList,
    c: jax.Array | float,
    eps: float = 0.5,
    max_passes: Optional[int] = None,
    compaction: str = "off",
):
    """Algorithm 3 for one value of c (c may be a traced scalar).

    With a concrete c this routes through the cached front door and returns
    a :class:`DenseSubgraphResult`; with a TRACED c (inside jit/vmap) it
    returns the engine's raw ``PeelOutcome`` — same arrays, but no
    ``provenance``/``extras``/host helpers on that branch.  ``compaction``
    is pinned off by default, like every legacy wrapper, so pre-flip
    outputs stay exact for ANY weights — and so both branches agree (the
    traced-c path runs the classic loop; ``run_cell`` never compacts)."""
    if isinstance(c, jax.core.Tracer):
        # Inside jit/vmap (e.g. a vmapped c-grid): stay on the pure lowering
        # path; the caller owns the compilation.
        prob = Problem.directed(eps=eps, max_passes=max_passes)
        return run_cell(edges, prob, c=c)
    return solve(
        edges,
        Problem.directed(
            c=float(c), eps=eps, max_passes=max_passes, compaction=compaction
        ),
    )


def densest_directed_search(
    edges: EdgeList,
    eps: float = 0.5,
    delta: float = 2.0,
    max_passes: Optional[int] = None,
    compaction: str = "off",
):
    """Grid search over c (the paper's practical recipe).

    Returns (result, best_c, per_c_densities, per_c_passes).  One compilation
    is reused across all c values because c enters as a runtime scalar.
    ``compaction='geometric'`` runs every c's peel through the amortized-O(m)
    ladder (the dual S/T bitmaps are renumbered together).
    """
    res = solve(
        edges,
        Problem.directed(
            c=None, eps=eps, c_delta=delta, max_passes=max_passes,
            compaction=compaction,
        ),
    )
    ex = res.extras
    return res, ex["best_c"], np.asarray(ex["c_density"]), np.asarray(ex["c_passes"])


def densest_directed_search_vmapped(
    edges: EdgeList,
    eps: float = 0.5,
    delta: float = 2.0,
    max_passes: Optional[int] = None,
):
    """The whole c grid in ONE compiled program via solve_batch (beyond-paper).

    The paper evaluates c values as separate runs (~35 min/c on Hadoop for
    TWITTER); c enters Algorithm 3 only through the peel-S-or-T branch, so
    the grid batches cleanly: every streaming pass over the edges serves all
    c values simultaneously — the same amortize-across-instances trick the
    paper's sketch uses across its t hash tables.  Pass count becomes the
    max over the grid (vmapped while_loop runs to the slowest c), which is
    the right trade once edge I/O dominates.

    Returns (best_c, best_rho, rhos[n_c], passes[n_c]).
    """
    cs = c_grid(edges.n_nodes, delta)
    res = solve_batch(
        edges,
        Problem.directed(eps=eps, max_passes=max_passes),
        c=jnp.asarray(cs),
    )
    rhos = res.best_density
    best_i = int(jnp.argmax(rhos))
    return (
        float(cs[best_i]),
        float(rhos[best_i]),
        np.asarray(rhos),
        np.asarray(res.passes),
    )


__getattr__ = deprecated_alias_getattr(
    __name__, {"DirectedPeelResult": DenseSubgraphResult}
)
