"""Algorithm 2 — (3+3eps)-approximate densest subgraph of size >= k.

Difference from Algorithm 1 (per the paper): instead of removing *all* nodes
below the 2(1+eps) rho(S) threshold, remove only |A(S)| = eps/(1+eps) |S| of
them (the lowest-degree ones, a deterministic choice of the subset the paper
leaves free).  Inequality (4.2) guarantees the candidate set is large enough.
Only sets with |S| >= k are eligible as the answer; the loop stops once
|S| < k (Lemma 11).
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.density import alive_edge_weight, exact_degrees, max_passes_bound
from repro.graph.edgelist import EdgeList


class PeelTopKResult(NamedTuple):
    best_alive: jax.Array
    best_density: jax.Array
    best_size: jax.Array
    passes: jax.Array


class _State(NamedTuple):
    alive: jax.Array
    best_alive: jax.Array
    best_rho: jax.Array
    best_size: jax.Array
    t: jax.Array


@partial(jax.jit, static_argnames=("k", "eps", "max_passes"))
def densest_subgraph_at_least_k(
    edges: EdgeList,
    k: int,
    eps: float = 0.5,
    max_passes: Optional[int] = None,
) -> PeelTopKResult:
    n = edges.n_nodes
    if max_passes is None:
        max_passes = max_passes_bound(n, eps)
    frac = eps / (1.0 + eps)

    def cond(s: _State):
        return (jnp.sum(s.alive.astype(jnp.int32)) >= k) & (s.t < max_passes)

    def body(s: _State) -> _State:
        w_alive = alive_edge_weight(edges, s.alive)
        deg = exact_degrees(edges, w_alive)
        total = jnp.sum(w_alive)
        n_alive = jnp.sum(s.alive.astype(jnp.int32))
        rho = jnp.where(n_alive > 0, total / jnp.maximum(n_alive, 1), 0.0)

        eligible = n_alive >= k
        improved = eligible & (rho > s.best_rho)
        best_alive = jnp.where(improved, s.alive, s.best_alive)
        best_rho = jnp.where(improved, rho, s.best_rho)
        best_size = jnp.where(improved, n_alive, s.best_size)

        # Candidate set A~(S): below-threshold nodes; remove exactly
        # r = max(1, floor(frac * |S|)) of the lowest-degree ones.
        thresh = 2.0 * (1.0 + eps) * rho
        deg_alive = jnp.where(s.alive, deg, jnp.inf)
        min_deg = jnp.min(deg_alive)
        cand = s.alive & ((deg <= thresh) | (deg <= min_deg))
        r = jnp.maximum((frac * n_alive.astype(jnp.float32)).astype(jnp.int32), 1)
        # Rank alive candidate nodes by degree (stable => ties by node id).
        key = jnp.where(cand, deg, jnp.inf)
        order = jnp.argsort(key)  # stable
        rank = jnp.zeros((n,), jnp.int32).at[order].set(jnp.arange(n, dtype=jnp.int32))
        remove = cand & (rank < r)
        alive = s.alive & ~remove
        return _State(alive, best_alive, best_rho, best_size, s.t + 1)

    init = _State(
        alive=jnp.ones((n,), bool),
        best_alive=jnp.ones((n,), bool),
        best_rho=jnp.asarray(-jnp.inf, jnp.float32),
        best_size=jnp.asarray(0, jnp.int32),
        t=jnp.asarray(0, jnp.int32),
    )
    out = jax.lax.while_loop(cond, body, init)
    return PeelTopKResult(out.best_alive, out.best_rho, out.best_size, out.t)
