"""Algorithm 2 — (3+3eps)-approximate densest subgraph of size >= k.

Thin wrapper over the PeelEngine: the ``AtLeastKFraction`` policy (remove
only |A(S)| = eps/(1+eps) |S| lowest-degree candidates per pass, a
deterministic choice of the subset the paper leaves free) on the exact
backend.  Inequality (4.2) guarantees the candidate set is large enough;
only sets with |S| >= k are eligible as the answer and the loop stops once
|S| < k (Lemma 11).
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax

from repro.core.density import max_passes_bound
from repro.core.engine import (
    AtLeastKFraction,
    ExactBackend,
    PeelOutcome,
    run_peel,
)
from repro.graph.edgelist import EdgeList

PeelTopKResult = PeelOutcome  # best_alive / best_density / best_size / passes


@partial(jax.jit, static_argnames=("k", "eps", "max_passes"))
def densest_subgraph_at_least_k(
    edges: EdgeList,
    k: int,
    eps: float = 0.5,
    max_passes: Optional[int] = None,
) -> PeelTopKResult:
    if max_passes is None:
        max_passes = max_passes_bound(edges.n_nodes, eps)
    return run_peel(
        edges, AtLeastKFraction(k=k, eps=eps), ExactBackend(), max_passes
    )
