"""Algorithm 2 — (3+3eps)-approximate densest subgraph of size >= k.

Thin delegation through the front door (core/api.py): ``Problem.at_least_k``
lowers onto the ``AtLeastKFraction`` policy (remove only
|A(S)| = eps/(1+eps) |S| lowest-degree candidates per pass, a deterministic
choice of the subset the paper leaves free) on the exact backend.
Inequality (4.2) guarantees the candidate set is large enough; only sets
with |S| >= k are eligible as the answer and the loop stops once |S| < k
(Lemma 11).
"""

from __future__ import annotations

from typing import Optional

from repro.core.api import (
    DenseSubgraphResult,
    Problem,
    deprecated_alias_getattr,
    solve,
)
from repro.graph.edgelist import EdgeList


def densest_subgraph_at_least_k(
    edges: EdgeList,
    k: int,
    eps: float = 0.5,
    max_passes: Optional[int] = None,
    compaction: str = "off",
) -> DenseSubgraphResult:
    """``compaction='geometric'`` rides the amortized-O(m) ladder — the
    rank-selection removal is renumbering-invariant (stable relabeling
    preserves the (degree, id) tie-break order), so results stay
    bit-identical for integer-valued weights."""
    return solve(
        edges,
        Problem.at_least_k(
            k=k, eps=eps, max_passes=max_passes, compaction=compaction
        ),
    )


__getattr__ = deprecated_alias_getattr(
    __name__, {"PeelTopKResult": DenseSubgraphResult}
)
