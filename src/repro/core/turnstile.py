"""Turnstile runtime: continuous densest-subgraph maintenance over dynamic
graph streams (McGregor–Tench–Vorotnikova–Vu, arXiv 1506.04417).

Every other substrate (jit / mesh / streaming) consumes an insert-only edge
stream.  This module is the fourth runtime: the graph arrives as BATCHES of
edge insertions AND deletions, absorbed by an update-linear ℓ0-sampling
sketch (``kernels/l0_sampler/``), and "what is the densest subgraph right
now?" is answered by recovering the sketch's uniform edge sample on the
host and peeling ONLY the sample with the existing engine — density
rescaled by the sample rate.  MTVV Theorem 6: peeling a uniform
Θ(n·polylog/eps²)-edge sample yields a (1+eps)-factor-degraded estimate,
so the end-to-end guarantee is (1+eps)·(2+2eps) against the true maximum
density.

Split of labor:

* :class:`TurnstileSketch` — the device-resident sketch state and the ONE
  jitted update program.  ``apply()`` pads each batch into power-of-two
  buckets, so repeated same-magnitude batches reuse a single compilation
  (``trace_count`` is the observability counter, same convention as
  :class:`~repro.core.api.Solver`).  Sketches with equal params merge by
  addition (:meth:`TurnstileSketch.merge`).
* :class:`TurnstileDensest` — the query driver: recover → pad sample into
  a pow2 edge bucket → ``Solver.solve`` (the sample peel hits the Solver's
  program cache like any other same-shape solve) → rescale.  Query
  metadata (sample level/rate, recovery failures, decode rounds) lands in
  ``extras['turnstile']``.

The front door reaches here via ``Problem(stream_mode='turnstile')``
(``Solver._solve_turnstile`` builds a one-shot driver); serving holds a
live driver via :class:`repro.serve.turnstile.TurnstileDensityService`.

Semantics contract (see docs/turnstile.md): the stream must describe a
SIMPLE undirected graph — deleting an edge that is not live, or inserting
a live edge again, corrupts the sketch in a way 1-sparse recovery detects
only probabilistically.  Use :func:`repro.graph.edgelist.apply_updates`
as the exact host-side reference for well-formed churn streams.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro import constants, faults
from repro.core.api import DenseSubgraphResult, Problem, Solver, default_solver
from repro.graph.edgelist import EdgeList
from repro.graph.partition import pow2_bucket
from repro.kernels import hashing
from repro.kernels.l0_sampler import L0Params, l0_update, make_l0_params

__all__ = [
    "TurnstileDensest",
    "TurnstileSketch",
]

# Edge buckets the recovered sample is padded into before peeling: one
# compiled peel program per pow2 bucket, shared across queries.
_SAMPLE_EDGE_FLOOR = constants.TURNSTILE_SAMPLE_EDGE_FLOOR
# Node bucket floor for the compacted sample peel (query() relabels the
# sample onto its touched nodes when that shrinks the node space).
_SAMPLE_NODE_FLOOR = constants.TURNSTILE_SAMPLE_NODE_FLOOR
# Update batches are padded to pow2 buckets above this floor: one compiled
# update program serves every batch up to the floor, then one per doubling.
_BATCH_FLOOR = constants.TURNSTILE_BATCH_FLOOR
# Decode-round runaway guard (real decodes finish in O(log k) rounds).
_MAX_DECODE_ROUNDS = 256


# -- numpy mirrors of the kernels/hashing.py family -------------------------
# The host decoder re-hashes recovery candidates; numpy uint32 arithmetic
# wraps mod 2^32 exactly like the XLA ops, so these are bit-identical to
# hashing.mix32_pair / bucket32 (the recover-vs-insert tests pin it).


def _np_mix32_pair(a_x, a_y, c, x, y):
    x = x.astype(np.uint32)
    y = y.astype(np.uint32)
    a_x = np.asarray(a_x, np.uint32)  # scalar or per-element multiplier
    a_y = np.asarray(a_y, np.uint32)
    c = np.asarray(c, np.uint32)
    with np.errstate(over="ignore"):
        h = a_x * x + a_y * y + c
        h = h ^ (h >> np.uint32(16))
        h = h * np.uint32(hashing.AVALANCHE)
        h = h ^ (h >> np.uint32(15))
    return h


def _np_edge_cells(p: L0Params, u: np.ndarray, v: np.ndarray) -> np.ndarray:
    a = np.asarray(p.a_cell)
    c = np.asarray(p.c_cell)
    return np.stack(
        [
            (_np_mix32_pair(a[j, 0], a[j, 1], c[j], u, v) % np.uint32(p.n_cells)).astype(
                np.int32
            )
            for j in range(p.n_tables)
        ]
    )


def _np_edge_fingerprint(p: L0Params, u: np.ndarray, v: np.ndarray) -> np.ndarray:
    a = np.asarray(p.a_fp)
    c = np.asarray(p.c_fp)
    return _np_mix32_pair(a[0], a[1], c[0], u, v).view(np.int32)


def _np_edge_level(p: L0Params, u: np.ndarray, v: np.ndarray) -> np.ndarray:
    a = np.asarray(p.a_lvl)
    c = np.asarray(p.c_lvl)
    h = _np_mix32_pair(a[0], a[1], c[0], u, v)
    # #{l in [1, L-1] : h < 2^(32-l)} == min(L-1, 32 - bit_length(h)): the
    # closed form the decoder's hot loop needs (uint32 is exact in float64,
    # so floor(log2) IS the high-bit position; h == 0 -> bit_length 0 ->
    # clamped to L-1, matching "below every threshold").
    bits = np.zeros(h.shape, np.int64)
    nz = h > 0
    bits[nz] = np.floor(np.log2(h[nz].astype(np.float64))).astype(np.int64) + 1
    return np.minimum(p.n_levels - 1, 32 - bits).astype(np.int32)


def _as_edge_arrays(
    edges: Union[np.ndarray, Tuple, None]
) -> Tuple[np.ndarray, np.ndarray]:
    """Accepts an (k, 2) array or a (src, dst) pair; returns int32 arrays."""
    if edges is None:
        z = np.zeros(0, np.int32)
        return z, z
    if isinstance(edges, tuple) and len(edges) == 2:
        src, dst = edges
    else:
        arr = np.asarray(edges)
        if arr.ndim != 2 or arr.shape[1] != 2:
            raise ValueError(
                f"edge batch must be a (k, 2) array or a (src, dst) pair, "
                f"got shape {arr.shape}"
            )
        src, dst = arr[:, 0], arr[:, 1]
    return np.asarray(src, np.int32), np.asarray(dst, np.int32)


class TurnstileSketch:
    """Device-resident ℓ0-sampling sketch of a dynamic edge SET.

    State is one int32 tensor ``[n_levels, n_tables, n_cells, 4]`` updated
    by a single donated jitted program; :meth:`apply` absorbs a batch of
    insertions and deletions, :meth:`recover` decodes the current uniform
    edge sample on the host.  All updates are linear, so
    ``sketch(A).merge(sketch(B)) == sketch(A ∪ B)`` bit for bit, updates
    commute, and an insert followed by a delete restores the exact
    all-zeros state.
    """

    def __init__(
        self,
        n_nodes: int,
        sample_edges: int = 1 << 14,
        *,
        n_levels: int = 32,
        n_tables: int = 3,
        seed: int = 0,
        use_pallas: Optional[bool] = None,
        interpret: Optional[bool] = None,
        batch_floor: int = _BATCH_FLOOR,
    ):
        if sample_edges < 1:
            raise ValueError(f"sample_edges={sample_edges} must be >= 1")
        if n_levels < 1:
            raise ValueError(f"n_levels={n_levels} must be >= 1")
        self.n_nodes = int(n_nodes)
        self.sample_edges = int(sample_edges)
        self.seed = int(seed)
        # C = pow2(sample_edges) cells per table: the decoder only commits
        # to a level holding <= sample_edges edges, so the d=3 tables run
        # at load <= 1/3 — comfortably inside the IBLT peeling threshold.
        n_cells = pow2_bucket(self.sample_edges, _SAMPLE_EDGE_FLOOR)
        self.params: L0Params = make_l0_params(
            n_levels=n_levels, n_cells=n_cells, n_tables=n_tables, seed=seed
        )
        self.tables = jnp.zeros(
            (n_levels, n_tables, n_cells, 4), jnp.int32
        )
        # None -> the kernels' dispatch rule: Pallas when compiled on TPU,
        # the segment-sum reference elsewhere (it IS the right CPU program).
        if use_pallas is None:
            use_pallas = jax.default_backend() == "tpu"
        self._use_pallas = bool(use_pallas)
        self._interpret = interpret
        self.batch_floor = int(batch_floor)
        # Observability: trace_count increments inside the traced body, so
        # repeated same-shape batches prove single-compilation (the Solver
        # convention); the rest are host counters.
        self.trace_count = 0
        self.batches_applied = 0
        self.updates_applied = 0
        self.recovery_failures = 0
        self.recovery_escalations = 0  # recoveries that succeeded above l*
        sketch = self

        def _update(tables, u, v, s):
            sketch.trace_count += 1
            return l0_update(
                tables,
                u,
                v,
                s,
                sketch.params,
                use_pallas=sketch._use_pallas,
                interpret=sketch._interpret,
            )

        self._update = jax.jit(_update, donate_argnums=0)
        # Query-path device reductions (jitted once; `level` is static and
        # only a handful of level values ever occur).
        self._counts_fn = jax.jit(lambda t: jnp.sum(t[:, 0, :, 0], axis=1))
        self._agg_fn = jax.jit(
            lambda t, level: jnp.sum(t[level:], axis=0, dtype=jnp.int32),
            static_argnums=1,
        )

    # -- updates ------------------------------------------------------------
    def apply(
        self,
        insert_edges: Union[np.ndarray, Tuple, None] = None,
        delete_edges: Union[np.ndarray, Tuple, None] = None,
    ) -> "TurnstileSketch":
        """Absorbs one batched turnstile update (±edges) into the sketch.

        Batches are padded to power-of-two buckets (floor
        ``batch_floor``), so all batches up to the floor — and every
        doubling above it — share ONE cached jitted program.  A single
        batch must not contain the same edge on both sides: deletions are
        not ordered against insertions inside a batch (linearity makes
        the sum well-defined, but insert+delete of the SAME edge in one
        batch only makes sense if it was live before or is inserted
        first — split such updates across batches).
        """
        ins_u, ins_v = _as_edge_arrays(insert_edges)
        del_u, del_v = _as_edge_arrays(delete_edges)
        k = len(ins_u) + len(del_u)
        if k == 0:
            return self
        u = np.concatenate([ins_u, del_u])
        v = np.concatenate([ins_v, del_v])
        s = np.concatenate(
            [np.ones(len(ins_u), np.int32), -np.ones(len(del_u), np.int32)]
        )
        pad = pow2_bucket(k, self.batch_floor) - k
        if pad:
            u = np.pad(u, (0, pad))
            v = np.pad(v, (0, pad))
            s = np.pad(s, (0, pad))  # sgn 0: padding rows vanish
        self.tables = self._update(
            self.tables, jnp.asarray(u), jnp.asarray(v), jnp.asarray(s)
        )
        self.batches_applied += 1
        self.updates_applied += k
        return self

    def merge(self, other: "TurnstileSketch") -> "TurnstileSketch":
        """Folds another sketch of the SAME geometry and seed into this one
        (sketch(A) + sketch(B) == sketch(A ∪ B) for disjoint A, B; more
        generally the sketch of the summed update streams)."""
        if not isinstance(other, TurnstileSketch):
            raise TypeError(f"cannot merge {type(other).__name__}")
        if (
            self.tables.shape != other.tables.shape
            or self.seed != other.seed
            or self.n_nodes != other.n_nodes
        ):
            raise ValueError(
                "mergeable sketches need identical geometry "
                f"(shape, seed, n_nodes): {self.tables.shape}/{self.seed} vs "
                f"{other.tables.shape}/{other.seed}"
            )
        self.tables = self.tables + other.tables
        self.batches_applied += other.batches_applied
        self.updates_applied += other.updates_applied
        return self

    # -- recovery -----------------------------------------------------------
    def level_counts(self) -> np.ndarray:
        """int64[L] EXACT number of live edges per level (the count field
        is linear, so collisions don't distort totals)."""
        # Any one table's count column sums to the per-level edge count;
        # reduced on device so the host never touches the full tensor.
        # int32 on device (x64 may be off), widened on the host — per-level
        # counts are bounded by the live edge count, far below 2^31.
        return np.asarray(self._counts_fn(self.tables)).astype(np.int64)

    def recover(
        self, target: Optional[int] = None
    ) -> Tuple[np.ndarray, int, Dict[str, Any]]:
        """Decodes the current uniform edge sample.

        Picks the smallest level ``l*`` whose suffix (levels >= l*) holds
        at most ``target`` edges — an EXACT count, read from the linear
        count fields — then peels 1-sparse cells of the suffix-summed
        tables.  ``l* == 0`` means the whole live edge set fit the budget:
        the "sample" is exact.  A level that fails to fully decode
        (collisions the d-table peeling cannot break, or a corrupted
        stream) increments ``recovery_failures`` and the next level is
        tried; exhausting all levels raises.

        Returns ``(edges int32[k, 2] sorted by (u, v), level, info)``.
        """
        tau = self.sample_edges if target is None else int(target)
        L = self.tables.shape[0]
        counts = self.level_counts()
        suffix = counts[::-1].cumsum()[::-1]
        l_star = int(np.argmax(suffix <= tau)) if (suffix <= tau).any() else L
        failures0 = self.recovery_failures
        for level in range(l_star, L):
            # Suffix-sum of the per-level tables == the sketch of the
            # Bernoulli(2^-level) sample (linearity); wraparound int32.
            # Reduced on device: only the [d, C, 4] aggregate crosses to
            # the host, not the full [L, d, C, 4] tensor.
            agg = np.asarray(self._agg_fn(self.tables, level))
            try:
                # Injection point for chaos tests: a fired fault is a
                # decode failure, exercising the real escalation path.
                faults.fire("turnstile.decode", key=level)
                decoded = self._decode(agg, level)
            except faults.InjectedFault:
                decoded = None
            if decoded is not None:
                edges, rounds = decoded
                if level > l_star:
                    self.recovery_escalations += 1
                info = {
                    "level": level,
                    "first_level_tried": l_star,
                    "sample_rate": 2.0 ** (-level),
                    "sample_edges_recovered": int(len(edges)),
                    "recovery_failures": self.recovery_failures - failures0,
                    "decode_rounds": rounds,
                    "exact": level == 0,
                    "level_suffix_count": int(suffix[level]),
                }
                return edges, level, info
            self.recovery_failures += 1
        raise RuntimeError(
            f"l0 recovery failed at every level >= {l_star} "
            f"(suffix counts {suffix[min(l_star, L - 1):].tolist()}; "
            "was the same live edge inserted twice, or a non-live edge "
            "deleted?)"
        )

    def _decode(
        self, agg: np.ndarray, level: int
    ) -> Optional[Tuple[np.ndarray, int]]:
        """IBLT peeling of one aggregated [d, C, 4] table set.  Returns
        ``(edges sorted by (u, v), rounds)`` on full decode (all cells
        return to zero), else None."""
        p = self.params
        d, C = p.n_tables, p.n_cells
        work = agg.copy()
        n = self.n_nodes
        seen_keys = np.zeros(0, np.int64)
        out_u: list = []
        out_v: list = []
        rounds = 0
        a_cell = np.asarray(p.a_cell)
        c_cell = np.asarray(p.c_cell)
        # Round 1 scans every cell; later rounds only re-examine cells the
        # previous round's subtractions TOUCHED — unreachable collision
        # debris has unchanging content, so re-validating it every round
        # buys nothing (this is queue-based IBLT peeling, vectorized).
        cand = np.nonzero(work[:, :, 0] == 1)  # (table, cell) singletons
        for rounds in range(1, _MAX_DECODE_ROUNDS + 1):
            if len(cand[0]) == 0:
                break
            got = work[cand[0], cand[1]]  # one gather: [k, 4]
            u, v, fp = got[:, 1], got[:, 2], got[:, 3]
            ok = (u >= 0) & (v > u) & (v < n)
            uu = np.where(ok, u, 0).astype(np.int32)
            vv = np.where(ok, v, 1).astype(np.int32)
            # A true singleton re-hashes consistently: fingerprint, its own
            # cell in the table it was found in (one gathered pair-hash,
            # not all d), and a level >= the suffix floor.  Anything else
            # is a collision artifact this round cannot peel yet.
            ok &= _np_edge_fingerprint(p, uu, vv) == fp
            own = _np_mix32_pair(
                a_cell[cand[0], 0], a_cell[cand[0], 1], c_cell[cand[0]], uu, vv
            )
            ok &= (own % np.uint32(C)).astype(np.int64) == cand[1]
            ok &= _np_edge_level(p, uu, vv) >= level
            if not ok.any():
                break
            # Dedup (the same edge peels as a singleton in several tables).
            key = u[ok].astype(np.int64) * n + v[ok]
            _, first = np.unique(key, return_index=True)
            eu = u[ok][first].astype(np.int32)
            ev = v[ok][first].astype(np.int32)
            fresh = (
                ~np.isin(key[first], seen_keys)
                if seen_keys.size
                else np.ones(len(first), bool)
            )
            if not fresh.any():
                break
            eu, ev = eu[fresh], ev[fresh]
            seen_keys = np.concatenate([seen_keys, key[first][fresh]])
            # Subtract the recovered edges from ALL their cells (wraparound
            # int32), exposing new singletons for the next round.  The
            # scatter is a per-field bincount: sums stay < 2^45, exact in
            # float64, then re-wrapped mod 2^32 (ufunc.at is ~100x slower
            # at sample-sized rounds).
            ecells = _np_edge_cells(p, eu, ev)  # [d, k]
            efp = _np_edge_fingerprint(p, eu, ev)
            vals = np.stack(
                [np.ones(len(eu), np.int32), eu, ev, efp], axis=-1
            ).astype(np.float64).reshape(-1)  # [k*4] field-interleaved
            for j in range(d):
                flat_idx = (ecells[j][:, None] * 4 + np.arange(4)).reshape(-1)
                acc = np.bincount(
                    flat_idx, weights=vals, minlength=C * 4
                ).astype(np.int64).reshape(C, 4)
                diff = work[j].astype(np.int64) - acc
                work[j] = (
                    (diff & np.int64(0xFFFFFFFF)).astype(np.uint32).view(np.int32)
                )
            out_u.append(eu)
            out_v.append(ev)
            flat = np.unique(
                np.repeat(np.arange(d), ecells.shape[1]) * C + ecells.reshape(-1)
            )
            tj, cj = flat // C, flat % C
            hit = work[tj, cj, 0] == 1
            cand = (tj[hit], cj[hit])
        if not np.all(work == 0):
            return None
        if out_u:
            eu = np.concatenate(out_u)
            ev = np.concatenate(out_v)
        else:
            eu = np.zeros(0, np.int32)
            ev = np.zeros(0, np.int32)
        order = np.lexsort((ev, eu))
        return np.stack([eu[order], ev[order]], axis=1), rounds


class TurnstileDensest:
    """Continuous densest-subgraph maintenance: a :class:`TurnstileSketch`
    feeding the EXISTING peel engine through the Solver's program cache.

    ``problem`` must be (or resolve to) ``stream_mode='turnstile'``; its
    ``sample_edges`` / ``sketch_seed`` configure the sketch and its
    objective knobs (eps, max_passes, track_history, exact-vs-pallas
    degree backend) configure the per-query sample peel.  ``query()``
    returns a standard :class:`~repro.core.api.DenseSubgraphResult` whose
    density estimates are rescaled by the inverse sample rate and whose
    ``extras['turnstile']`` carries the recovery telemetry.
    """

    def __init__(
        self,
        n_nodes: int,
        problem: Optional[Problem] = None,
        *,
        solver: Optional[Solver] = None,
        n_levels: int = 32,
        n_tables: int = 3,
        use_pallas: Optional[bool] = None,
        interpret: Optional[bool] = None,
        batch_floor: int = _BATCH_FLOOR,
    ):
        if problem is None:
            problem = Problem.undirected(stream_mode="turnstile")
        prob = problem.resolve(n_nodes)
        if prob.stream_mode != "turnstile":
            raise ValueError(
                f"TurnstileDensest needs Problem(stream_mode='turnstile'), "
                f"got stream_mode={problem.stream_mode!r}"
            )
        self.n_nodes = int(n_nodes)
        self.problem = prob
        self.solver = solver if solver is not None else default_solver
        self.sketch = TurnstileSketch(
            n_nodes,
            prob.sample_edges,
            n_levels=n_levels,
            n_tables=n_tables,
            seed=prob.sketch_seed,
            use_pallas=use_pallas,
            interpret=interpret,
            batch_floor=batch_floor,
        )

    def apply(self, insert_edges=None, delete_edges=None) -> "TurnstileDensest":
        """Absorbs one ±edge batch (see :meth:`TurnstileSketch.apply`)."""
        self.sketch.apply(insert_edges, delete_edges)
        return self

    def query(self) -> DenseSubgraphResult:
        """Current (1+eps)·(2+2eps)-approximate densest subgraph.

        Recovers the sample, pads it into a pow2 edge bucket (one peel
        compilation per bucket, shared across queries) and runs the
        standard undirected peel; ``best_density`` / ``history_m`` /
        ``history_rho`` come back multiplied by ``2^level`` (the inverse
        sample rate).  ``level == 0`` means the estimate is EXACT (the
        whole live graph fit the sample budget).

        When the sample touches far fewer nodes than the graph has (the
        normal case at scale: at most ``2*sample_edges`` of them), the
        peel runs in a COMPACTED node space — per-pass cost O(tau), not
        O(n).  ``extras['turnstile']['sample_nodes']`` then maps compact
        ids back to original ids (``res.best_alive[i]`` describes original
        node ``sample_nodes[i]``); without the key, ids are original.
        """
        edges, level, info = self.sketch.recover()
        k = len(edges)
        e_src = edges[:, 0] if k else np.zeros(0, np.int32)
        e_dst = edges[:, 1] if k else np.zeros(0, np.int32)
        nodes = np.unique(edges) if k else np.zeros(0, np.int32)
        n_peel = pow2_bucket(max(len(nodes), 1), _SAMPLE_NODE_FLOOR)
        compacted = n_peel < self.n_nodes
        if compacted:
            e_src = np.searchsorted(nodes, e_src).astype(np.int32)
            e_dst = np.searchsorted(nodes, e_dst).astype(np.int32)
        else:
            n_peel = self.n_nodes
        m_pad = pow2_bucket(max(k, 1), _SAMPLE_EDGE_FLOOR)
        src = np.zeros(m_pad, np.int32)
        dst = np.zeros(m_pad, np.int32)
        msk = np.zeros(m_pad, bool)
        src[:k] = e_src
        dst[:k] = e_dst
        msk[:k] = True
        sample = EdgeList(
            src=jnp.asarray(src),
            dst=jnp.asarray(dst),
            weight=jnp.asarray(msk.astype(np.float32)),
            mask=jnp.asarray(msk),
            n_nodes=n_peel,
            directed=False,
        )
        # The sample peel is an ordinary insert-mode solve: small pow2
        # buffer, ladder off (nothing to amortize at sample scale).  Its
        # program cache key is shared with any other same-shape solve —
        # stream_mode/sample_edges are uniformly cache-key-exempt.
        inner = dataclasses.replace(
            self.problem, stream_mode="insert", compaction="off", substrate="jit"
        )
        res = self.solver.solve(sample, inner)
        scale = float(2**level)
        info = dict(info)
        info["updates_applied"] = self.sketch.updates_applied
        info["batches_applied"] = self.sketch.batches_applied
        info["sample_padded_edges"] = int(m_pad)
        info["sample_n_nodes"] = int(n_peel)
        if compacted:
            info["sample_nodes"] = nodes
        extras = dict(res.extras or {})
        extras["turnstile"] = info
        prov = res.provenance
        if prov is not None:
            prov = dataclasses.replace(prov, substrate="turnstile")
        hist_scale = jnp.float32(scale)
        return dataclasses.replace(
            res,
            best_density=res.best_density * hist_scale,
            history_m=res.history_m * hist_scale,
            history_rho=res.history_rho * hist_scale,
            extras=extras,
            provenance=prov,
        )
