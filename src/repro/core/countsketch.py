"""Count-Sketch degree estimation (paper §5.1).

Exactly the Charikar-Chen-Farach-Colton sketch used as a black box by the
paper: t independent tables of b signed counters; an edge (x, y) updates
counter (i, h_i(x)) by g_i(x) and (i, h_i(y)) by g_i(y); the degree estimate
of x is the median over i of c[i, h_i(x)] * g_i(x).

The sketch replaces the O(n) exact degree vector: on TPU it keeps per-pass
node state at O(t*b) so that only edges need to be sharded even for
billion-node graphs (see DESIGN.md §2).  Hashing is uint32 multiply-shift
(Dietzfelbinger-style, wrap-around multiply then high bits), fully vectorized
and int32-safe (no x64 requirement).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.api import DenseSubgraphResult, Problem, solve
from repro.graph.edgelist import EdgeList
from repro.kernels import hashing

__all__ = [
    "SketchBackend",
    "SketchParams",
    "densest_subgraph_sketched",
    "make_sketch_params",
    "query_degrees",
    "sketch_degrees_from_edges",
    "sketch_endpoint_counters",
    "sketched_degree_fn",
]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SketchParams:
    """Hash parameters for t tables over b buckets."""

    a_h: jax.Array  # uint32[t] odd multipliers for the bucket hash
    c_h: jax.Array  # uint32[t] offsets
    a_g: jax.Array  # uint32[t] odd multipliers for the sign hash
    c_g: jax.Array  # uint32[t] offsets
    n_buckets: int = dataclasses.field(metadata=dict(static=True))

    @property
    def n_tables(self) -> int:
        return self.a_h.shape[0]


def make_sketch_params(t: int, b: int, seed: int = 0) -> SketchParams:
    rng = np.random.default_rng(seed)
    odd = lambda: (rng.integers(0, 1 << 31, size=t, dtype=np.int64) * 2 + 1).astype(np.uint32)
    any32 = lambda: rng.integers(0, 1 << 32, size=t, dtype=np.int64).astype(np.uint32)
    return SketchParams(
        jnp.asarray(odd()), jnp.asarray(any32()), jnp.asarray(odd()), jnp.asarray(any32()), b
    )


def _mix(a: jax.Array, c: jax.Array, x: jax.Array) -> jax.Array:
    """uint32[t, ...] wrap-around multiply-shift mix of node ids.

    Broadcasting wrapper over the shared :mod:`repro.kernels.hashing`
    family (one per-table parameter row against the whole id array); the
    mix itself lives there so the ℓ0 sampler and this sketch stay one
    hash function.  Bit-identical to the historical inline spelling
    (pinned by ``tests/test_turnstile.py::test_hashing_regression``).
    """
    xu = x.astype(jnp.uint32)[None]
    a = a[(...,) + (None,) * x.ndim]
    c = c[(...,) + (None,) * x.ndim]
    return hashing.mix32(a, c, xu)


def _hash_bucket(p: SketchParams, x: jax.Array) -> jax.Array:
    return hashing.bucket32(_mix(p.a_h, p.c_h, x), p.n_buckets)


def _hash_sign(p: SketchParams, x: jax.Array) -> jax.Array:
    return hashing.sign32(_mix(p.a_g, p.c_g, x))


def sketch_endpoint_counters(
    p: SketchParams, ids: jax.Array, w_alive: jax.Array
) -> jax.Array:
    """Counter table float32[t, b] for ONE endpoint array of the edge stream
    (update counter (i, h_i(x)) by g_i(x)·w for every edge endpoint x)."""
    t, b = p.n_tables, p.n_buckets
    buckets = _hash_bucket(p, ids)  # [t, E]
    signs = _hash_sign(p, ids)  # [t, E]
    flat_idx = (buckets + (jnp.arange(t, dtype=jnp.int32) * b)[:, None]).reshape(-1)
    vals = (signs * w_alive[None, :]).reshape(-1)
    return jax.ops.segment_sum(vals, flat_idx, num_segments=t * b).reshape(t, b)


def sketch_degrees_from_edges(
    p: SketchParams, edges: EdgeList, w_alive: jax.Array
) -> jax.Array:
    """Builds the counter table float32[t, b] from the (masked) edge stream.

    Each alive edge contributes to both endpoints' counters, exactly the
    streaming update rule of §5.1 (weighted for weighted graphs).
    """
    return sketch_endpoint_counters(p, edges.src, w_alive) + sketch_endpoint_counters(
        p, edges.dst, w_alive
    )


def query_degrees(p: SketchParams, counters: jax.Array, nodes: jax.Array) -> jax.Array:
    """Median-of-t degree estimates for the given node ids."""
    buckets = _hash_bucket(p, nodes)  # [t, N]
    signs = _hash_sign(p, nodes)  # [t, N]
    est = jnp.take_along_axis(counters, buckets, axis=1) * signs  # [t, N]
    return jnp.median(est, axis=0)


class SketchBackend:
    """Engine ``DegreeBackend`` backed by the §5.1 Count-Sketch.

    Undirected degrees use the shared two-endpoint counter table; the
    directed rule keeps SEPARATE out/in tables (accumulate src endpoints
    only / dst endpoints only) so Algorithm 3's out- and in-degree
    estimates stay unbiased for their own side.
    """

    def __init__(self, params: SketchParams):
        self.params = params

    def undirected(self, edges: EdgeList, w_alive: jax.Array):
        counters = sketch_degrees_from_edges(self.params, edges, w_alive)
        nodes = jnp.arange(edges.n_nodes, dtype=jnp.int32)
        return query_degrees(self.params, counters, nodes), jnp.sum(w_alive)

    def directed(self, edges: EdgeList, w_alive: jax.Array):
        c_out = sketch_endpoint_counters(self.params, edges.src, w_alive)
        c_in = sketch_endpoint_counters(self.params, edges.dst, w_alive)
        nodes = jnp.arange(edges.n_nodes, dtype=jnp.int32)
        out_deg = query_degrees(self.params, c_out, nodes)
        in_deg = query_degrees(self.params, c_in, nodes)
        return out_deg, in_deg, jnp.sum(w_alive)


def sketched_degree_fn(p: SketchParams):
    """degree_fn hook for core.peel.densest_subgraph using the sketch."""

    def fn(edges: EdgeList, w_alive: jax.Array) -> jax.Array:
        counters = sketch_degrees_from_edges(p, edges, w_alive)
        all_nodes = jnp.arange(edges.n_nodes, dtype=jnp.int32)
        return query_degrees(p, counters, all_nodes)

    return fn


def densest_subgraph_sketched(
    edges: EdgeList,
    eps: float = 0.5,
    t: int = 5,
    b: int = 1 << 13,
    seed: int = 0,
    max_passes: Optional[int] = None,
) -> DenseSubgraphResult:
    """Algorithm 1 with Count-Sketch degrees (the Table 4 configuration).

    Thin delegation through the front door: ``Problem(backend='sketch')``
    lowers onto :class:`SketchBackend`, which is bit-identical to the
    historical ``degree_fn=sketched_degree_fn(params)`` hook (the engine
    equivalence tests pin this)."""
    problem = Problem.undirected(
        eps=eps,
        max_passes=max_passes,
        track_history=True,
        backend="sketch",
        sketch_tables=t,
        sketch_buckets=b,
        sketch_seed=seed,
    )
    return solve(edges, problem)
