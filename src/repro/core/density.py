"""Density / induced-degree primitives shared by all peeling algorithms.

These are the paper's three MapReduce building blocks (§5.2):
  (1) graph density        -> masked reductions,
  (2) per-node degrees     -> segment_sum over the edge list,
  (3) node removal         -> alive-bitmap update + edge mask recomputation.

All functions are pure and jit/shard_map friendly.  When run under
``shard_map`` with edges sharded, callers psum the outputs (see
core/mapreduce.py); the math is identical, which is exactly the paper's
observation that every pass only needs associative reductions.
"""

from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.graph.edgelist import EdgeList

# Degree function signature: (edges, alive_src, alive_dst) -> deg[N]
DegreeFn = Callable[[EdgeList, jax.Array, jax.Array], jax.Array]


class GraphStats(NamedTuple):
    deg: jax.Array  # float32[N] induced (weighted) degree
    total_weight: jax.Array  # float32[] sum of alive edge weights |E(S)|
    n_alive: jax.Array  # int32[] |S|
    density: jax.Array  # float32[] rho(S); 0 when S is empty


def alive_edge_weight(edges: EdgeList, alive: jax.Array) -> jax.Array:
    """float32[E]: weight for edges whose both endpoints are alive, else 0."""
    ok = edges.mask & alive[edges.src] & alive[edges.dst]
    return jnp.where(ok, edges.weight, 0.0)


def exact_degrees(edges: EdgeList, w_alive: jax.Array) -> jax.Array:
    """Induced degrees via segment_sum — delegates to the engine's
    :func:`~repro.core.engine.segment_degree_count` so the reduce-side
    count of §5.2 exists exactly once."""
    from repro.core.engine import segment_degree_count

    deg, _ = segment_degree_count(edges.src, edges.dst, w_alive, edges.n_nodes)
    return deg


def undirected_stats(edges: EdgeList, alive: jax.Array) -> GraphStats:
    """All per-pass statistics of Algorithm 1 in one fused computation."""
    w_alive = alive_edge_weight(edges, alive)
    deg = exact_degrees(edges, w_alive)
    total = jnp.sum(w_alive)
    n_alive = jnp.sum(alive.astype(jnp.int32))
    density = jnp.where(n_alive > 0, total / jnp.maximum(n_alive, 1), 0.0)
    return GraphStats(deg=deg, total_weight=total, n_alive=n_alive, density=density)


class DirectedStats(NamedTuple):
    out_deg: jax.Array  # float32[N] |E(i, T)|
    in_deg: jax.Array  # float32[N] |E(S, j)|
    total_weight: jax.Array  # |E(S, T)|
    n_s: jax.Array
    n_t: jax.Array
    density: jax.Array  # |E(S,T)| / sqrt(|S| |T|)


def directed_stats(edges: EdgeList, s_alive: jax.Array, t_alive: jax.Array) -> DirectedStats:
    ok = edges.mask & s_alive[edges.src] & t_alive[edges.dst]
    w = jnp.where(ok, edges.weight, 0.0)
    n = edges.n_nodes
    out_deg = jax.ops.segment_sum(w, edges.src, num_segments=n)
    in_deg = jax.ops.segment_sum(w, edges.dst, num_segments=n)
    total = jnp.sum(w)
    n_s = jnp.sum(s_alive.astype(jnp.int32))
    n_t = jnp.sum(t_alive.astype(jnp.int32))
    denom = jnp.sqrt(jnp.maximum(n_s.astype(jnp.float32), 1.0) * jnp.maximum(n_t.astype(jnp.float32), 1.0))
    density = jnp.where((n_s > 0) & (n_t > 0), total / denom, 0.0)
    return DirectedStats(out_deg, in_deg, total, n_s, n_t, density)


def density_of(edges: EdgeList, alive: jax.Array) -> jax.Array:
    """rho(S) for a node subset, recomputed from scratch (used for validation)."""
    return undirected_stats(edges, alive).density


def max_passes_bound(n_nodes: int, eps: float, floor: int = 8) -> int:
    """Static trip-count bound: ceil(log_{1+eps} n) + slack (Lemma 4).

    Capped at n+1: the algorithm removes at least one node per pass (min-
    degree fallback), so n+1 is a true worst case — and it keeps the bound
    int32-safe when eps is within float noise of 0."""
    import math

    if eps <= 0:
        return int(n_nodes) + 1  # one node per pass worst case (Charikar regime)
    bound = int(math.ceil(math.log(max(n_nodes, 2)) / math.log1p(eps))) + 4
    return max(floor, min(bound, int(n_nodes) + 1))
