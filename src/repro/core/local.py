"""Andersen-style local exploration — the ``substrate='local'`` extraction.

Per-seed densest-subgraph queries should not pay for the whole graph, or
even for a whole BFS ball whose size is governed by tuning knobs
(``radius``, ``max_ego_nodes``) rather than by theory.  Andersen's local
algorithm (arXiv cs/0702078, PAPERS.md) grows a candidate set around the
seed through PRUNED frontier rounds: a frontier vertex is admitted only
if its degree into the current candidate set T clears a threshold tied
to T's density, so the expansion follows the dense core around the seed
instead of the raw neighborhood ball, and a hard ``budget`` caps |T|.
Per-query work is O(rounds × vol(T)) — bounded by the budget and the
candidate degrees, independent of n (``benchmarks/bench_serve.py`` holds
the scaling claim against the BFS baseline).

The pruning rule (the documented extraction contract, also in
docs/serving.md):

  * each round's frontier is every vertex adjacent to T but outside it;
  * a frontier vertex u is admitted iff ``deg_T(u) >= max(alpha *
    rho(T), 1)`` where ``rho(T)`` is T's internal edge density — with
    ``alpha=1`` a vertex is admitted exactly when adding it cannot
    dilute the density ((w+d)/(s+1) >= w/s iff d >= w/s);
  * when admissions would exceed the budget, the strongest ties into T
    win, lowest id on ties (deterministic truncation);
  * total scan work is capped at ``budget * volume_factor`` CSR slots,
    enforced at ADMISSION in the same deterministic order: a vertex
    whose row does not fit in the remaining work budget is not admitted
    (so a power-law hub one hop from the seed cannot blow the per-query
    cost — its row is never scanned, and pruning keeps expanding through
    the vertices that do fit);
  * exploration stops when the pruned frontier is empty
    (``frontier_exhausted``), the budget or volume cap is reached, or
    ``max_rounds`` rounds have run.

Each admitted vertex's CSR row is scanned exactly ONCE (degrees into T
are maintained incrementally), so the total edge work equals vol(T),
itself <= budget * volume_factor by the admission rule — the counters
on :class:`LocalExploration` report it.

The candidate set then feeds the SAME engine pass body as every other
substrate: :func:`induced_padded` relabels the induced subgraph into the
serving layer's pow2 (node, edge) buckets (bit-identical to
``serve/densest.py`` extraction, which delegates here), and the peel of
that buffer is an ordinary cached jit program — see
``Solver._solve_local`` (core/api.py) and ``DensestQueryEngine``
(serve/densest.py).

What guarantee survives: the peel returns a genuine subgraph of the
input graph, so its density NEVER exceeds the exact optimum, and it is a
(2+2eps)-approximation of the densest subgraph INSIDE the candidate set
(for BFS extraction the same statement holds with "radius-r ego-net" in
place of "candidate set").  The whole-graph (2+2eps) guarantee does not
survive locality — no algorithm touching O(budget) vertices can promise
it — which is why tests/test_property_serve.py pins exactly the
envelope above, per extraction mode, against the exact oracle.

Pure numpy, no jax: this module is host-side extraction; the solve that
follows it is the cached jit program.
"""

from __future__ import annotations

import dataclasses
import operator
from typing import Optional, Tuple

import numpy as np

from repro import constants
from repro.graph.edgelist import EdgeList, to_csr
from repro.graph.partition import pow2_bucket

__all__ = [
    "LocalExploration",
    "LocalExplorer",
    "adjacency_rows",
    "check_count",
    "check_seed",
    "induced_padded",
]

# Aliased from the one constants surface (repro.constants): exploration
# budget/round defaults shared by the api front door and the serving engine.
_LOCAL_BUDGET = constants.LOCAL_BUDGET
_LOCAL_ROUNDS = constants.LOCAL_ROUNDS
_LOCAL_VOLUME_FACTOR = constants.LOCAL_VOLUME_FACTOR
_NODE_FLOOR = constants.SERVE_NODE_FLOOR
_EDGE_FLOOR = constants.SERVE_EDGE_FLOOR


def check_seed(seed, n_nodes: int) -> int:
    """Strict seed validation shared by the api front door and the serving
    engine's ``submit`` (the admission contract): a real integer node id in
    ``[0, n_nodes)``.  Bools and non-integral floats are TypeErrors — a
    float seed used to slip past the range check and silently truncate."""
    if isinstance(seed, (bool, np.bool_)):
        raise TypeError("seed must be an integer node id, got bool")
    try:
        s = operator.index(seed)
    except TypeError:
        raise TypeError(
            f"seed must be an integer node id, got {type(seed).__name__}"
        ) from None
    if not 0 <= s < n_nodes:
        raise ValueError(f"seed={s} not in [0, {n_nodes})")
    return s


def check_count(value, name: str, minimum: int = 1) -> int:
    """Strict positive-integer knob validation (radius, budget, rounds)."""
    if isinstance(value, (bool, np.bool_)):
        raise TypeError(f"{name} must be an integer, got bool")
    try:
        v = operator.index(value)
    except TypeError:
        raise TypeError(
            f"{name} must be an integer, got {type(value).__name__}"
        ) from None
    if v < minimum:
        raise ValueError(f"{name}={v} must be >= {minimum}")
    return v


def adjacency_rows(
    indptr: np.ndarray, nodes: np.ndarray
) -> Tuple[np.ndarray, np.ndarray]:
    """Concatenated CSR rows of ``nodes``: ``(slot_idx, row_src)`` where
    ``slot_idx`` indexes indices/weights and ``row_src[i]`` is the node
    whose row slot ``i`` came from (vectorized multi-range gather)."""
    starts = indptr[nodes]
    counts = indptr[nodes + 1] - starts
    total = int(counts.sum())
    if total == 0:
        return np.empty(0, np.int64), np.empty(0, np.int64)
    shift = np.repeat(
        starts - np.concatenate(([0], np.cumsum(counts)[:-1])), counts
    )
    slot_idx = shift + np.arange(total)
    return slot_idx, np.repeat(nodes.astype(np.int64), counts)


def induced_padded(
    indptr: np.ndarray,
    indices: np.ndarray,
    weights: Optional[np.ndarray],
    nodes: np.ndarray,
    member: np.ndarray,
    local_id: np.ndarray,
    *,
    node_floor: int = _NODE_FLOOR,
    edge_floor: int = _EDGE_FLOOR,
) -> EdgeList:
    """The induced subgraph of sorted ``nodes`` as a bucket-padded EdgeList:
    THE one extraction body both the serving engine (BFS and local modes)
    and the ``substrate='local'`` front door solve, so every path is
    bit-identical by construction.

    Compact ids follow the sorted order (local id i ↔ ``nodes[i]``; ids >=
    ``len(nodes)`` are isolated pad nodes, removed by the peel in pass 1).
    ``member``/``local_id`` are caller-owned n-length scratch arrays
    (returned reset/ stale respectively).  Buffers stay NUMPY: the device
    transfer happens at solve time, amortized across a stacked batch on
    the serving path."""
    nodes = np.asarray(nodes, np.int64)
    member[nodes] = True
    slot_idx, row_src = adjacency_rows(indptr, nodes)
    dsts = indices[slot_idx].astype(np.int64)
    # Induced edges, each undirected pair once: the symmetrized CSR holds
    # (u,v) and (v,u); src<dst keeps exactly one.
    keep = member[dsts] & (row_src < dsts)
    member[nodes] = False  # reset scratch before any return
    local_id[nodes] = np.arange(len(nodes), dtype=np.int32)
    src_l = local_id[row_src[keep]]
    dst_l = local_id[dsts[keep]]
    if weights is None:
        w = np.ones(len(src_l), np.float32)
    else:
        w = np.asarray(weights[slot_idx[keep]], np.float32)
    m = len(src_l)
    n_b = pow2_bucket(len(nodes), node_floor)
    m_b = pow2_bucket(max(m, 1), edge_floor)
    src_p = np.zeros(m_b, np.int32)
    dst_p = np.zeros(m_b, np.int32)
    w_p = np.zeros(m_b, np.float32)
    msk = np.zeros(m_b, bool)
    src_p[:m] = src_l
    dst_p[:m] = dst_l
    w_p[:m] = w
    msk[:m] = True
    return EdgeList(
        src=src_p, dst=dst_p, weight=w_p, mask=msk, n_nodes=int(n_b)
    )


@dataclasses.dataclass(frozen=True)
class LocalExploration:
    """One pruned-frontier exploration's outcome + work counters."""

    seed: int
    candidates: np.ndarray  # sorted original ids, seed included
    rounds: int  # expansion rounds executed
    nodes_touched: int  # distinct vertices examined (candidates + frontier)
    edges_scanned: int  # CSR slots read — the per-query work measure
    frontier_exhausted: bool  # pruning closed the set before the budget


class LocalExplorer:
    """Pruned-frontier exploration over one host CSR (see module docstring
    for the pruning rule).  Build once per graph and reuse across queries:
    the scratch arrays are O(n) but every ``explore`` touches only the
    candidates' neighborhoods.
    """

    def __init__(
        self,
        indptr: np.ndarray,
        indices: np.ndarray,
        weights: Optional[np.ndarray] = None,
        *,
        n_nodes: Optional[int] = None,
    ):
        self._indptr = np.asarray(indptr, np.int64)
        self._indices = np.asarray(indices)
        self._weights = (
            None if weights is None else np.asarray(weights, np.float32)
        )
        self.n_nodes = int(
            len(self._indptr) - 1 if n_nodes is None else n_nodes
        )
        self._member = np.zeros(self.n_nodes, bool)  # T membership scratch
        self._local_id = np.zeros(self.n_nodes, np.int32)  # relabel scratch
        self._deg_t = np.zeros(self.n_nodes, np.int32)  # deg into T scratch

    @classmethod
    def from_edgelist(cls, graph: EdgeList) -> "LocalExplorer":
        if graph.directed:
            raise ValueError(
                "the local exploration is undirected (Andersen's setting); "
                "got a directed graph"
            )
        indptr, indices, w = to_csr(graph, return_weights=True)
        return cls(indptr, indices, w, n_nodes=graph.n_nodes)

    def explore(
        self,
        seed,
        *,
        budget: int = _LOCAL_BUDGET,
        max_rounds: int = _LOCAL_ROUNDS,
        alpha: float = 1.0,
        volume_factor: int = _LOCAL_VOLUME_FACTOR,
    ) -> LocalExploration:
        """Runs the pruned-frontier expansion from ``seed``; deterministic
        for fixed inputs (pure numpy, sorted tie-breaks).  Work is capped
        at ``budget * volume_factor`` CSR slots (module docstring)."""
        s = check_seed(seed, self.n_nodes)
        budget = check_count(budget, "budget")
        max_rounds = check_count(max_rounds, "max_rounds")
        vol_cap = budget * check_count(volume_factor, "volume_factor")
        if alpha < 0:
            raise ValueError(f"alpha={alpha} must be >= 0")
        member, deg_t = self._member, self._deg_t
        cand = np.asarray([s], np.int64)
        member[s] = True
        touched_parts = []  # admitted rows' neighbor ids (duplicates kept)
        edges_scanned = 0

        def scan(batch: np.ndarray) -> None:
            # Each admitted vertex's row is scanned exactly once, here:
            # afterwards deg_t[v] == |N(v) ∩ T| for EVERY vertex v.
            nonlocal edges_scanned
            slot_idx, _ = adjacency_rows(self._indptr, batch)
            nb = self._indices[slot_idx].astype(np.int64)
            edges_scanned += int(nb.size)
            if nb.size:
                np.add.at(deg_t, nb, 1)
                touched_parts.append(nb)

        scan(cand)
        rounds = 0
        exhausted = False
        while (
            rounds < max_rounds
            and len(cand) < budget
            and edges_scanned < vol_cap
        ):
            seen = (
                np.unique(np.concatenate(touched_parts))
                if touched_parts
                else np.empty(0, np.int64)
            )
            frontier = seen[~member[seen]]
            if frontier.size == 0:
                exhausted = True
                break
            # T's internal density from the incremental degrees (unweighted
            # counts — the pruning heuristic matches Andersen's unweighted
            # setting; the final density comes from the real weighted peel).
            rho = float(deg_t[cand].sum()) / (2.0 * len(cand))
            d_f = deg_t[frontier]
            keep = d_f >= max(alpha * rho, 1.0)
            frontier, d_f = frontier[keep], d_f[keep]
            if frontier.size == 0:
                exhausted = True  # pruning closed the set
                break
            # Deterministic admission order: strongest ties into T first,
            # lowest id on ties; the budget and volume caps cut along it.
            order = np.lexsort((frontier, -d_f))
            frontier = frontier[order[: budget - len(cand)]]
            # Volume cap at admission: a vertex whose CSR row does not fit
            # in the remaining work budget is NOT admitted (its row is
            # never scanned), keeping total work <= vol_cap even when a
            # hub sits one hop away.  Individually-oversized rows are
            # skipped first so one hub does not shadow the small rows
            # admitted after it; the rest cut at the cumulative cap.
            remaining = vol_cap - edges_scanned
            sizes = self._indptr[frontier + 1] - self._indptr[frontier]
            if (sizes > remaining).any():
                frontier = frontier[sizes <= remaining]
                sizes = self._indptr[frontier + 1] - self._indptr[frontier]
            fit = np.cumsum(sizes) <= remaining
            if not fit.all():
                frontier = frontier[fit]
            if frontier.size == 0:
                break
            member[frontier] = True
            cand = np.concatenate([cand, frontier])
            scan(frontier)
            rounds += 1
        seen = (
            np.unique(np.concatenate(touched_parts))
            if touched_parts
            else np.empty(0, np.int64)
        )
        nodes_touched = int(np.union1d(seen, cand).size)
        candidates = np.sort(cand)
        # Reset scratch for the next query.
        member[cand] = False
        deg_t[seen] = 0
        return LocalExploration(
            seed=s,
            candidates=candidates,
            rounds=rounds,
            nodes_touched=nodes_touched,
            edges_scanned=edges_scanned,
            frontier_exhausted=exhausted,
        )

    def extract(
        self,
        seed,
        *,
        budget: int = _LOCAL_BUDGET,
        max_rounds: int = _LOCAL_ROUNDS,
        alpha: float = 1.0,
        volume_factor: int = _LOCAL_VOLUME_FACTOR,
        node_floor: int = _NODE_FLOOR,
        edge_floor: int = _EDGE_FLOOR,
    ) -> Tuple[EdgeList, LocalExploration]:
        """Explore + relabel: the candidate set's induced subgraph in the
        serving bucket format (see :func:`induced_padded`)."""
        ex = self.explore(
            seed,
            budget=budget,
            max_rounds=max_rounds,
            alpha=alpha,
            volume_factor=volume_factor,
        )
        padded = induced_padded(
            self._indptr,
            self._indices,
            self._weights,
            ex.candidates,
            self._member,
            self._local_id,
            node_floor=node_floor,
            edge_floor=edge_floor,
        )
        return padded, ex
