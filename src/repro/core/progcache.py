"""Persistent (on-disk) compiled-program cache for the Solver.

The Solver's in-memory program cache means a long-lived process never
retraces a same-shape solve — but a FRESH process pays the full cold
compile again (the ~90x overhead tracked in
``experiments/bench/BENCH_api.json``).  This module gives that cache a disk
tier, the pattern of JAX's own persistent compilation cache
(``jax.experimental.compilation_cache``), specialized to the Solver's
already-shape-keyed programs:

  * an entry is one AOT-compiled executable, serialized with
    ``jax.experimental.serialize_executable`` (the compiled XLA binary plus
    its input/output pytree layout — loading it needs NO tracing, NO
    lowering and NO XLA compilation);
  * the file name is a SHA-256 over the Solver's program-cache key (problem
    static fields, shapes, dtype) AND the environment :func:`fingerprint`
    (backend + jax/jaxlib/repro versions + cache format), so entries from a
    different environment can never be picked up by name;
  * the fingerprint and key are ALSO stored inside the entry and re-checked
    on load (belt and braces against hash collisions or copied cache dirs);
  * writes go through :func:`repro.ioutil.atomic_write_file` (same-dir temp
    + fsync + ``os.replace``), so a reader sees an old entry or a new one,
    never a torn write;
  * any load failure — corrupt pickle, stale fingerprint, a deserialization
    error from a different device topology — silently falls back to a fresh
    compile, which then overwrites the bad entry.

A cache directory can be shared by every process of a serving fleet: the
first process compiles and publishes, the rest start warm (see
docs/serving.md for the invalidation contract).
"""

from __future__ import annotations

import hashlib
import os
import pickle
from typing import Any, Callable, Optional

from repro import faults

# Bump to invalidate every existing cache entry on a format change.
FORMAT_VERSION = 1

_ENTRY_SUFFIX = ".jaxprog"


def fingerprint() -> dict:
    """Environment fingerprint baked into every entry (name and payload).

    Serialized executables are backend- and version-specific binaries; any
    mismatch here must read as a cache miss, never a load attempt.
    """
    import jax
    import jaxlib

    import repro

    return {
        "format": FORMAT_VERSION,
        "backend": jax.default_backend(),
        "jax": jax.__version__,
        "jaxlib": jaxlib.__version__,
        "repro": repro.__version__,
    }


def entry_path(cache_dir: str, key: Any) -> str:
    """File path of the entry for ``key`` (a hashable, repr-stable Solver
    program-cache key) under the current environment fingerprint."""
    digest = hashlib.sha256(
        (repr(key) + repr(sorted(fingerprint().items()))).encode()
    ).hexdigest()
    return os.path.join(cache_dir, digest + _ENTRY_SUFFIX)


def store(path: str, key: Any, compiled) -> bool:
    """Serializes an AOT-compiled executable (``jit(fn).lower(...).compile()``)
    to ``path`` atomically.  Best-effort: returns False instead of raising —
    a failed publish must never fail the solve that produced the program."""
    try:
        faults.fire("progcache.store", key=path)
        from jax.experimental import serialize_executable

        from repro.ioutil import atomic_write_file

        payload = serialize_executable.serialize(compiled)
        blob = pickle.dumps(
            {
                "fingerprint": fingerprint(),
                "key": repr(key),
                "payload": payload,
            }
        )
        atomic_write_file(path, lambda f: f.write(blob), suffix=_ENTRY_SUFFIX + ".tmp")
        return True
    except Exception:
        return False


def load(path: str, key: Any) -> Optional[Callable]:
    """Loads the executable stored for ``key`` at ``path``, or None.

    None covers every miss shape — absent file, torn/corrupt bytes, an
    entry written by a different environment (fingerprint mismatch), a
    SHA-collision entry for a different key, or a payload the current
    runtime cannot deserialize.  The caller recompiles and overwrites.
    """
    try:
        faults.fire("progcache.load", key=path)
        with open(path, "rb") as f:
            entry = pickle.loads(f.read())
        if entry.get("fingerprint") != fingerprint():
            return None
        if entry.get("key") != repr(key):
            return None
        from jax.experimental import serialize_executable

        serialized, in_tree, out_tree = entry["payload"]
        return serialize_executable.deserialize_and_load(
            serialized, in_tree, out_tree
        )
    except FileNotFoundError:
        return None
    except Exception:
        return None
