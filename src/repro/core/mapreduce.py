"""MapReduce-model realization of Algorithms 1/2/3 on a JAX device mesh (§5.2).

The paper's per-pass MapReduce jobs become collectives over an edge-sharded
mesh:

  map  (emit <u;v>, <v;u>)          ->  per-shard segment_sum into deg[N]
  shuffle + reduce (count per key)  ->  jax.lax.psum over the edge axes
  density counters                  ->  psum of local edge weight
  node filter (2 MR passes)         ->  alive-bitmap mask, recomputed locally

This module is the *shard_map substrate* of the PeelEngine.  The
``make_distributed_*`` builders are thin delegations through the front
door's mesh lowering (:meth:`repro.core.api.Solver.mesh_program`): every
one constructs a ``Problem`` and receives the cached
``jit(shard_map(run_peel))`` program with a psum'ing backend
(:class:`~repro.core.engine.MeshSegmentSumBackend` or the Count-Sketch
:class:`_MeshSketchBackend`).  The pass body — threshold, best-set
tracking, removal — is the engine's; nothing here re-implements it.

The *entire* O(log_{1+eps} n)-pass algorithm is one compiled XLA program: a
``lax.while_loop`` whose body contains exactly two fused collectives per pass
(degree psum + density psum — the density one rides along in the same
reduction).  Node state (alive bitmap) is replicated, edges are sharded: the
paper's semi-streaming O(n)-state assumption.

Used by: tests (vs the single-device reference), bench_scale (Fig 6.7
analogue), and the production dry-run (``--arch densest-mapreduce``).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.core.api import DenseSubgraphResult, Problem, default_solver, solve
from repro.core.density import max_passes_bound
from repro.core.engine import (
    MeshSegmentSumBackend,
    PeelOutcome,
    UndirectedThreshold,
    run_peel,
)
from repro.graph.edgelist import EdgeList


def shard_edges(edges: EdgeList, mesh: Mesh, axes: Sequence[str]) -> EdgeList:
    """Pads E to a multiple of the edge-shard count and device_puts shards."""
    n_shards = int(np.prod([mesh.shape[a] for a in axes]))
    padded = edges.with_padding(n_shards)
    spec = P(tuple(axes))
    sh = NamedSharding(mesh, spec)
    return EdgeList(
        src=jax.device_put(padded.src, sh),
        dst=jax.device_put(padded.dst, sh),
        weight=jax.device_put(padded.weight, sh),
        mask=jax.device_put(padded.mask, sh),
        n_nodes=padded.n_nodes,
        directed=padded.directed,
    )


def _local_edges(src, dst, weight, mask, n_nodes: int) -> EdgeList:
    """The per-device EdgeList view inside shard_map."""
    return EdgeList(src=src, dst=dst, weight=weight, mask=mask, n_nodes=n_nodes)


def flat_shard_index(axes: Sequence[str]) -> jax.Array:
    """This device's position along the (flattened) edge-shard axis inside
    ``shard_map`` — the row-major combination of ``lax.axis_index`` over
    ``axes``, matching both ``PartitionSpec((axes,))`` block order and the
    concatenation order of ``lax.all_gather(..., axes, tiled=True)``."""
    return jax.lax.axis_index(tuple(axes))


def mesh_compact_edges(
    src: jax.Array,
    dst: jax.Array,
    weight: jax.Array,
    ok: jax.Array,
    alive_edges: jax.Array,
    new_cap: int,
    axes: Sequence[str],
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """One collective compaction step of the single-program mesh ladder
    (for use INSIDE ``shard_map``): gathers every shard's edges, prefix-sum
    compacts the survivors (``ok`` — the post-removal edge filter the peel
    loop already carries; its psummed count is ``alive_edges``, the trigger
    count every device just agreed on) into the next rung's
    ``new_cap``-per-shard buffer, and hands each device its new shard — no
    host gather/reshard, just collectives, and no re-filter/re-count work.

    The all-gather is ``O(m_i)`` and rung sizes shrink geometrically, so
    the total gather TRAFFIC over the whole ladder telescopes to
    ``O(m_0)`` — the same order as ONE host round-trip, without ever
    leaving the compiled program.  Peak per-device RESIDENCY is another
    matter: the gathered arrays momentarily materialize all ``m_i`` slots
    on every device, so the rung-0 compaction needs O(m_0) per-device
    memory — fine whenever the uncompacted graph would fit one device
    (the regime the tracked benchmark measures), but NOT for graphs
    sharded precisely because they don't; such runs should keep
    ``compaction='off'``/``'twophase'`` on the mesh substrate for now (a
    balanced all_to_all exchange that keeps residency O(m_i / n_shards)
    is the ROADMAP refinement).  Shards are contiguous blocks in
    axis-index order, and the prefix-sum scatter is stable, so the
    surviving edges keep their original global order: degree sums see the
    same addends in the same order as the host ladder (bit-identical for
    integer-valued weights).

    Returns ``(src', dst', weight', mask')`` — this device's next-rung
    shard.
    """
    from repro.core.engine import compact_edges

    axes = tuple(axes)
    g_ok, g_src, g_dst, g_w = (
        jax.lax.all_gather(x, axes, tiled=True) for x in (ok, src, dst, weight)
    )
    n_shards = g_ok.shape[0] // ok.shape[0]
    total_next = new_cap * n_shards
    n_src, n_dst, n_w = compact_edges(g_ok, (g_src, g_dst, g_w), total_next)
    n_mask = jnp.arange(total_next, dtype=jnp.int32) < alive_edges
    start = flat_shard_index(axes) * new_cap
    sl = lambda a: jax.lax.dynamic_slice_in_dim(a, start, new_cap)
    return sl(n_src), sl(n_dst), sl(n_w), sl(n_mask)


def make_distributed_peel(
    mesh: Mesh,
    edge_axes: Tuple[str, ...] = ("data",),
    eps: float = 0.5,
    max_passes: Optional[int] = None,
    n_nodes: Optional[int] = None,
    wire_dtype: str = "f32",
):
    """Builds the jitted multi-device Algorithm 1.

    Returns fn(src, dst, weight, mask) -> PeelOutcome, where edge arrays are
    sharded over ``edge_axes`` and everything else is replicated.

    ``wire_dtype='bf16'`` halves the per-pass degree psum (the dominant
    collective): partial degrees are cast to bf16 before the reduction and
    back after.  For unweighted graphs local partials are exact integers;
    the reduced sum carries <=0.4% relative rounding — harmless to the
    threshold test because (a) the removal rule keeps the approximation
    proof's slack and (b) the min-degree progress fallback is unaffected
    (EXPERIMENTS.md Perf, densest x twitter_lg).
    """
    assert n_nodes is not None
    problem = Problem.undirected(
        eps=eps,
        max_passes=max_passes,
        substrate="mesh",
        edge_axes=tuple(edge_axes),
        wire_dtype=wire_dtype,
    )
    return default_solver.mesh_program(problem, mesh, n_nodes)


def densest_subgraph_distributed(
    edges: EdgeList,
    mesh: Mesh,
    edge_axes: Tuple[str, ...] = ("data",),
    eps: float = 0.5,
    max_passes: Optional[int] = None,
    compaction: str = "off",
) -> DenseSubgraphResult:
    """Convenience wrapper: shard + run through the front door.
    ``compaction`` is pinned off by default, like every legacy wrapper, so
    pre-flip outputs stay exact for any weights; pass ``'geometric'`` for
    the single-program mesh ladder."""
    problem = Problem.undirected(
        eps=eps, max_passes=max_passes, substrate="mesh",
        edge_axes=tuple(edge_axes), compaction=compaction,
    )
    return solve(edges, problem, mesh=mesh)


def make_distributed_peel_compacted(
    mesh: Mesh,
    edge_axes: Tuple[str, ...] = ("data",),
    eps: float = 0.5,
    max_passes: Optional[int] = None,
    n_nodes: Optional[int] = None,
    wire_dtype: str = "f32",
    compaction: str = "geometric",
):
    """Distributed Algorithm 1 on the GEOMETRIC compaction ladder.

    The multi-level generalization of :func:`make_distributed_peel_twophase`:
    whenever the (psummed) alive edge count falls below half the current
    padded buffer, survivor edges are compacted into the next power-of-two
    bucket and the SAME engine loop continues there — every edge-level cost
    shrinks with the graph, for amortized-O(m) total work.  With
    ``compaction='geometric'`` (the default) the whole ladder now runs as
    ONE compiled ``shard_map`` program via
    :func:`make_distributed_peel_ladder`'s lowering (collective-only, no
    host round-trip per rung); ``compaction='twophase'`` keeps the host
    gather/relabel schedule.  Returns ``fn(edges: EdgeList) ->
    DenseSubgraphResult`` (an EdgeList-level entry point, unlike the
    raw-array single-program builders; ``n_nodes``, if given, is validated
    against each graph for signature parity with the sibling builders).
    """
    problem = Problem.undirected(
        eps=eps,
        max_passes=max_passes,
        substrate="mesh",
        edge_axes=tuple(edge_axes),
        wire_dtype=wire_dtype,
        compaction=compaction,
    )

    def run(edges: EdgeList) -> DenseSubgraphResult:
        if n_nodes is not None and edges.n_nodes != n_nodes:
            raise ValueError(
                f"graph has n_nodes={edges.n_nodes}, builder was sized for "
                f"{n_nodes}"
            )
        return solve(edges, problem, mesh=mesh)

    return run


def make_distributed_peel_ladder(
    mesh: Mesh,
    edge_axes: Tuple[str, ...] = ("data",),
    eps: float = 0.5,
    max_passes: Optional[int] = None,
    n_nodes: Optional[int] = None,
    m_edges: Optional[int] = None,
    wire_dtype: str = "f32",
):
    """The single-program mesh compaction ladder: the WHOLE geometric
    Lemma-4 schedule — every peel segment and every inter-rung compaction —
    as ONE compiled ``jit(shard_map(...))`` program, collective-only end to
    end (degree psum + alive-edge trigger psum per pass, one all-gather
    redistribution per rung; zero host gather/reshard round-trips).

    This is the multi-level generalization of
    :func:`make_distributed_peel_twophase`'s single-XLA-program idea: the
    bucket sizes derive statically from the padded edge count — rung ``i``
    exits below the NEXT rung's capacity (the psummed trigger every device
    agrees on), so its survivors provably fit there and the full shape
    ladder is known at trace time
    (:func:`repro.graph.partition.ladder_schedule`); eps enters as the
    Lemma-4 pass budget baked into every rung.

    Returns ``run(src, dst, weight, mask) -> PeelOutcome`` over arrays
    padded to ``run.n_edge_slots`` (= ``run.schedule[0] * n_shards``) and
    sharded over ``edge_axes`` — signature parity with
    :func:`make_distributed_peel`.  ``run.schedule`` exposes the static
    per-shard bucket sizes; for per-rung pass counts and the full ladder
    report, go through the front door instead — ``solve(...,
    Problem(substrate='mesh', compaction='geometric'))`` returns it in
    ``extras['compaction']``.
    """
    assert n_nodes is not None
    assert m_edges is not None, "the static bucket schedule needs m_edges"
    problem = Problem.undirected(
        eps=eps,
        max_passes=max_passes,
        substrate="mesh",
        edge_axes=tuple(edge_axes),
        wire_dtype=wire_dtype,
        compaction="geometric",
    )
    fn, schedule, n_shards, _ = default_solver.mesh_ladder_program(
        problem, mesh, n_nodes, m_edges
    )

    def run(src, dst, weight, mask) -> PeelOutcome:
        out, _rung_t = fn(src, dst, weight, mask)
        return out

    run.schedule = schedule
    run.n_edge_slots = schedule[0] * n_shards
    return run


def make_distributed_peel_twophase(
    mesh: Mesh,
    edge_axes: Tuple[str, ...] = ("data",),
    eps: float = 0.5,
    max_passes: Optional[int] = None,
    n_nodes: Optional[int] = None,
    phase1_passes: int = 8,
    wire_dtype: str = "f32",
):
    """Algorithm 1 with PROVABLE mid-run compaction (beyond-paper perf).

    Lemma 4 guarantees |S| shrinks by >= (1+eps) every pass, so after K
    passes |S| < n/(1+eps)^K — a STATIC bound.  Phase 1 runs (up to) K
    engine passes on the full id space; the survivors are then renumbered
    into a dense range of that static size and phase 2 continues there,
    shrinking the per-pass O(n) degree psum (the dominant collective) by
    (1+eps)^K for the remaining O(log n) passes.  Semantics are identical to
    the single-phase peel (compaction is pure renumbering; tested) — both
    phases are the SAME engine loop, just on different id spaces.

    SUPERSEDED as the compaction entry point: this single-XLA-program
    two-level schedule is now a special case of the engine's compaction
    runtime — prefer ``Problem(compaction='twophase'|'geometric')`` via the
    front door (or :func:`make_distributed_peel_compacted`), which
    generalizes the renumbering into a multi-level ladder shared by all
    substrates.  Kept for callers that need the whole run as ONE compiled
    program (no host round-trip between phases).
    """
    axes = tuple(edge_axes)
    assert n_nodes is not None
    n = n_nodes
    mp = max_passes if max_passes is not None else max_passes_bound(n, eps)
    k1 = min(phase1_passes, mp)
    n2 = int(np.ceil(n / (1.0 + eps) ** k1)) + 1  # static Lemma-4 bound
    mp2 = max(mp - k1, 4)
    policy = UndirectedThreshold(eps)
    backend = MeshSegmentSumBackend(axes, wire_dtype)

    def peel_local(src, dst, weight, mask):
        # ---- phase 1: up to K passes on the full id space ----
        edges1 = _local_edges(src, dst, weight, mask, n)
        out1 = run_peel(edges1, policy, backend, k1, init_best_empty=True)
        alive1 = out1.alive

        # ---- compaction: renumber survivors into [0, n2) ----
        n_alive1 = jnp.sum(alive1.astype(jnp.int32))
        relabel = jnp.cumsum(alive1.astype(jnp.int32)) - 1  # full -> compact
        relabel = jnp.minimum(relabel, n2 - 1)  # clamp (bound is provable)
        ok_e = mask & alive1[src] & alive1[dst]
        trash = n2  # extra bucket for dead edges
        src2 = jnp.where(ok_e, relabel[src], trash)
        dst2 = jnp.where(ok_e, relabel[dst], trash)
        w2 = jnp.where(ok_e, weight, 0.0)

        # ---- phase 2: the same engine loop on the compacted ids ----
        edges2 = _local_edges(src2, dst2, w2, ok_e, n2 + 1)
        alive2_init = jnp.arange(n2 + 1, dtype=jnp.int32) < n_alive1
        out2 = run_peel(
            edges2, policy, backend, mp2,
            init_alive=alive2_init, init_best_empty=True,
        )

        # ---- merge: map the phase-2 best/final sets back to full ids ----
        best2_full = alive1 & out2.best_alive[jnp.minimum(relabel, n2 - 1)]
        use2 = out2.best_density > out1.best_density
        best_alive = jnp.where(use2, best2_full, out1.best_alive)
        best_rho = jnp.maximum(out1.best_density, out2.best_density)
        final_alive = alive1 & out2.alive[jnp.minimum(relabel, n2 - 1)]
        return best_alive, best_rho, out1.passes + out2.passes, final_alive

    sharded = shard_map(
        peel_local,
        mesh=mesh,
        in_specs=(P(axes),) * 4,
        out_specs=(P(), P(), P(), P()),
        check_vma=False,
    )

    @jax.jit
    def run(src, dst, weight, mask) -> PeelOutcome:
        best_alive, best_rho, t, final_alive = sharded(src, dst, weight, mask)
        return PeelOutcome(
            best_alive=best_alive,
            best_t=jnp.zeros((0,), bool),
            best_density=best_rho,
            best_size=jnp.sum(best_alive.astype(jnp.int32)),
            passes=t,
            alive=final_alive,
            t_alive=jnp.zeros((0,), bool),
            history_n=jnp.zeros((1,), jnp.int32),
            history_m=jnp.zeros((1,), jnp.float32),
            history_rho=jnp.zeros((1,), jnp.float32),
        )

    return run


@dataclasses.dataclass(frozen=True)
class _MeshSketchBackend:
    """Count-Sketch degrees inside shard_map (paper §5.1 at §5.2 scale).

    Per-pass cross-device traffic is the O(t*b) counter table (one fused
    psum with the density counter), NOT the O(n) degree vector; the degree
    *queries* stream over node chunks (``lax.map``) so the transient query
    footprint stays O(node_chunk) on top of the O(n) estimate vector the
    engine's removal rule consumes.
    """

    params: object  # SketchParams
    axes: Tuple[str, ...]
    node_chunk: int

    def undirected(self, edges: EdgeList, w_alive: jax.Array):
        from repro.core.countsketch import (
            query_degrees,
            sketch_degrees_from_edges,
        )

        t = self.params.n_tables
        b = self.params.n_buckets
        local = sketch_degrees_from_edges(self.params, edges, w_alive)
        packed = jnp.concatenate([local.reshape(-1), jnp.sum(w_alive)[None]])
        packed = jax.lax.psum(packed, self.axes)  # O(t*b) traffic, not O(n)
        counters = packed[:-1].reshape(t, b)
        total = packed[-1]

        n = edges.n_nodes
        n_chunks = (n + self.node_chunk - 1) // self.node_chunk

        def query_chunk(ci):
            ids = ci * self.node_chunk + jnp.arange(self.node_chunk, dtype=jnp.int32)
            return query_degrees(self.params, counters, ids)

        est = jax.lax.map(query_chunk, jnp.arange(n_chunks, dtype=jnp.int32))
        return est.reshape(-1)[:n], total

    def directed(self, edges: EdgeList, w_alive: jax.Array):
        raise NotImplementedError("use SketchBackend for directed sketched peels")


def make_distributed_sketched_peel(
    mesh: Mesh,
    edge_axes: Tuple[str, ...] = ("data",),
    eps: float = 0.5,
    max_passes: int = 48,
    n_nodes: Optional[int] = None,
    t: int = 5,
    b: int = 1 << 17,
    node_chunk: int = 1 << 20,
    seed: int = 0,
):
    """Distributed Algorithm 1 with Count-Sketch degrees (paper §5.1).

    This is the billion-node configuration: only edges are sharded, node
    bitmaps stay replicated, and the per-pass collective is the O(t*b)
    counter psum.  Returns fn(src, dst, weight, mask) ->
    (best_alive, best_rho, passes).
    """
    assert n_nodes is not None
    problem = Problem.undirected(
        eps=eps,
        max_passes=max_passes,
        substrate="mesh",
        backend="sketch",
        edge_axes=tuple(edge_axes),
        sketch_tables=t,
        sketch_buckets=b,
        sketch_seed=seed,
        sketch_node_chunk=node_chunk,
    )
    fn = default_solver.mesh_program(problem, mesh, n_nodes)

    def run(src, dst, weight, mask):
        out = fn(src, dst, weight, mask)
        return out.best_alive, out.best_density, out.passes

    return run


def make_distributed_topk_peel(
    mesh: Mesh,
    edge_axes: Tuple[str, ...] = ("data",),
    k: int = 1,
    eps: float = 0.5,
    max_passes: Optional[int] = None,
    n_nodes: Optional[int] = None,
):
    """Distributed Algorithm 2 (densest subgraph with |S| >= k).

    Per pass, removes exactly ceil(eps/(1+eps)·|S|) of the LOWEST-degree
    nodes among the threshold-eligible set (the paper's 'smallest number of
    nodes necessary for convergence').  Degrees are replicated after the
    psum, so the rank selection is computed identically on every device —
    no extra collective beyond Algorithm 1's.
    """
    assert n_nodes is not None
    problem = Problem.at_least_k(
        k=k,
        eps=eps,
        max_passes=max_passes,
        substrate="mesh",
        edge_axes=tuple(edge_axes),
        min_deg_fallback=False,
        ceil_count=True,
    )
    return default_solver.mesh_program(problem, mesh, n_nodes)


def make_distributed_directed_peel(
    mesh: Mesh,
    edge_axes: Tuple[str, ...] = ("data",),
    eps: float = 0.5,
    max_passes: Optional[int] = None,
    n_nodes: Optional[int] = None,
):
    """Distributed Algorithm 3 (directed) for a runtime ratio c.

    Returns fn(src, dst, weight, mask, c) -> (best_s, best_t, rho, passes).
    """
    assert n_nodes is not None
    problem = Problem.directed(
        eps=eps,
        max_passes=max_passes,
        substrate="mesh",
        edge_axes=tuple(edge_axes),
    )
    fn = default_solver.mesh_program(problem, mesh, n_nodes)

    def run(src, dst, weight, mask, c):
        out = fn(src, dst, weight, mask, c)
        return out.best_alive, out.best_t, out.best_density, out.passes

    return run
