"""MapReduce-model realization of Algorithm 1/3 on a JAX device mesh (§5.2).

The paper's per-pass MapReduce jobs become collectives over an edge-sharded
mesh:

  map  (emit <u;v>, <v;u>)          ->  per-shard segment_sum into deg[N]
  shuffle + reduce (count per key)  ->  jax.lax.psum over the edge axes
  density counters                  ->  psum of local edge weight
  node filter (2 MR passes)         ->  alive-bitmap mask, recomputed locally

The *entire* O(log_{1+eps} n)-pass algorithm is one compiled XLA program: a
``lax.while_loop`` whose body contains exactly two fused collectives per pass
(degree psum + density psum — the density one rides along in the same
reduction).  Node state (alive bitmap) is replicated, edges are sharded: the
paper's semi-streaming O(n)-state assumption.

Used by: tests (vs the single-device reference), bench_scale (Fig 6.7
analogue), and the production dry-run (``--arch densest-mapreduce``).
"""

from __future__ import annotations

from functools import partial
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax import shard_map

from repro.core.density import max_passes_bound
from repro.core.peel import PeelResult
from repro.graph.edgelist import EdgeList


def shard_edges(edges: EdgeList, mesh: Mesh, axes: Sequence[str]) -> EdgeList:
    """Pads E to a multiple of the edge-shard count and device_puts shards."""
    n_shards = int(np.prod([mesh.shape[a] for a in axes]))
    padded = edges.with_padding(n_shards)
    spec = P(tuple(axes))
    sh = NamedSharding(mesh, spec)
    return EdgeList(
        src=jax.device_put(padded.src, sh),
        dst=jax.device_put(padded.dst, sh),
        weight=jax.device_put(padded.weight, sh),
        mask=jax.device_put(padded.mask, sh),
        n_nodes=padded.n_nodes,
        directed=padded.directed,
    )


def make_distributed_peel(
    mesh: Mesh,
    edge_axes: Tuple[str, ...] = ("data",),
    eps: float = 0.5,
    max_passes: Optional[int] = None,
    n_nodes: Optional[int] = None,
    wire_dtype: str = "f32",
):
    """Builds the jitted multi-device Algorithm 1.

    Returns fn(src, dst, weight, mask) -> PeelResult, where edge arrays are
    sharded over ``edge_axes`` and everything else is replicated.

    ``wire_dtype='bf16'`` halves the per-pass degree psum (the dominant
    collective): partial degrees are cast to bf16 before the reduction and
    back after.  For unweighted graphs local partials are exact integers;
    the reduced sum carries <=0.4% relative rounding — harmless to the
    threshold test because (a) the removal rule keeps the approximation
    proof's slack and (b) the min-degree progress fallback is unaffected
    (EXPERIMENTS.md Perf, densest x twitter_lg).
    """
    axes = tuple(edge_axes)
    # Axes of the mesh that do NOT shard edges still run the same program;
    # psum over edge axes only.
    espec = P(axes)
    rspec = P()

    def peel_local(src, dst, weight, mask):
        n = n_nodes
        assert n is not None
        mp = max_passes if max_passes is not None else max_passes_bound(n, eps)

        def stats(alive):
            ok = mask & alive[src] & alive[dst]
            w_alive = jnp.where(ok, weight, 0.0)
            deg = jax.ops.segment_sum(w_alive, src, num_segments=n)
            deg = deg + jax.ops.segment_sum(w_alive, dst, num_segments=n)
            # One fused reduction: [deg | total] -> psum.
            packed = jnp.concatenate([deg, jnp.sum(w_alive)[None]])
            if wire_dtype == "bf16":
                packed = jax.lax.psum(packed.astype(jnp.bfloat16), axes)
                packed = packed.astype(jnp.float32)
            else:
                packed = jax.lax.psum(packed, axes)
            return packed[:-1], packed[-1]

        def cond(s):
            alive, _, _, t = s
            return (jnp.sum(alive.astype(jnp.int32)) > 0) & (t < mp)

        def body(s):
            alive, best_alive, best_rho, t = s
            deg, total = stats(alive)
            n_alive = jnp.sum(alive.astype(jnp.int32))
            rho = jnp.where(n_alive > 0, total / jnp.maximum(n_alive, 1), 0.0)
            improved = rho > best_rho
            best_alive = jnp.where(improved, alive, best_alive)
            best_rho = jnp.maximum(rho, best_rho)
            thresh = 2.0 * (1.0 + eps) * rho
            deg_alive = jnp.where(alive, deg, jnp.inf)
            min_deg = jnp.min(deg_alive)
            remove = alive & ((deg <= thresh) | (deg <= min_deg))
            return (alive & ~remove, best_alive, best_rho, t + 1)

        init = (
            jnp.ones((n,), bool),
            jnp.ones((n,), bool),
            jnp.asarray(-jnp.inf, jnp.float32),
            jnp.asarray(0, jnp.int32),
        )
        alive, best_alive, best_rho, t = jax.lax.while_loop(cond, body, init)
        return best_alive, best_rho, t

    sharded = shard_map(
        peel_local,
        mesh=mesh,
        in_specs=(espec, espec, espec, espec),
        out_specs=(rspec, rspec, rspec),
        check_vma=False,
    )

    @jax.jit
    def run(src, dst, weight, mask) -> PeelResult:
        best_alive, best_rho, t = sharded(src, dst, weight, mask)
        return PeelResult(
            best_alive=best_alive,
            best_density=best_rho,
            passes=t,
            history_n=jnp.zeros((1,), jnp.int32),
            history_m=jnp.zeros((1,), jnp.float32),
            history_rho=jnp.zeros((1,), jnp.float32),
        )

    return run


def densest_subgraph_distributed(
    edges: EdgeList,
    mesh: Mesh,
    edge_axes: Tuple[str, ...] = ("data",),
    eps: float = 0.5,
    max_passes: Optional[int] = None,
) -> PeelResult:
    """Convenience wrapper: shard + run."""
    sharded = shard_edges(edges, mesh, edge_axes)
    fn = make_distributed_peel(
        mesh, edge_axes, eps=eps, max_passes=max_passes, n_nodes=sharded.n_nodes
    )
    return fn(sharded.src, sharded.dst, sharded.weight, sharded.mask)


def make_distributed_peel_twophase(
    mesh: Mesh,
    edge_axes: Tuple[str, ...] = ("data",),
    eps: float = 0.5,
    max_passes: Optional[int] = None,
    n_nodes: Optional[int] = None,
    phase1_passes: int = 8,
    wire_dtype: str = "f32",
):
    """Algorithm 1 with PROVABLE mid-run compaction (beyond-paper perf).

    Lemma 4 guarantees |S| shrinks by >= (1+eps) every pass, so after K
    passes |S| < n/(1+eps)^K — a STATIC bound.  Phase 1 runs K passes on the
    full id space; the survivors are then renumbered into a dense range of
    that static size and phase 2 continues there, shrinking the per-pass
    O(n) degree psum (the dominant collective) by (1+eps)^K for the
    remaining O(log n) passes.  Semantics are identical to the single-phase
    peel (compaction is pure renumbering; tested).
    """
    axes = tuple(edge_axes)
    espec = P(axes)
    rspec = P()
    assert n_nodes is not None
    n = n_nodes
    mp = max_passes if max_passes is not None else max_passes_bound(n, eps)
    k1 = min(phase1_passes, mp)
    n2 = int(np.ceil(n / (1.0 + eps) ** k1)) + 1  # static Lemma-4 bound
    mp2 = max(mp - k1, 4)

    def peel_local(src, dst, weight, mask):
        def psum_packed(packed):
            if wire_dtype == "bf16":
                return jax.lax.psum(packed.astype(jnp.bfloat16), axes).astype(
                    jnp.float32
                )
            return jax.lax.psum(packed, axes)

        def make_stats(s, d, m_, w_, nn):
            def stats(alive):
                ok = m_ & alive[s] & alive[d]
                w_alive = jnp.where(ok, w_, 0.0)
                deg = jax.ops.segment_sum(w_alive, s, num_segments=nn)
                deg = deg + jax.ops.segment_sum(w_alive, d, num_segments=nn)
                packed = psum_packed(
                    jnp.concatenate([deg, jnp.sum(w_alive)[None]])
                )
                return packed[:-1], packed[-1]

            return stats

        def make_body(stats):
            def body(s_):
                alive, best_alive, best_rho, t = s_
                deg, total = stats(alive)
                n_alive = jnp.sum(alive.astype(jnp.int32))
                rho = jnp.where(n_alive > 0, total / jnp.maximum(n_alive, 1), 0.0)
                improved = (rho > best_rho) & (n_alive > 0)
                best_alive = jnp.where(improved, alive, best_alive)
                best_rho = jnp.where(improved, rho, best_rho)
                thresh = 2.0 * (1.0 + eps) * rho
                deg_alive = jnp.where(alive, deg, jnp.inf)
                min_deg = jnp.min(deg_alive)
                remove = alive & ((deg <= thresh) | (deg <= min_deg))
                return (alive & ~remove, best_alive, best_rho, t + 1)

            return body

        # ---- phase 1: K fixed passes on the full id space ----
        stats1 = make_stats(src, dst, mask, weight, n)
        body1 = make_body(stats1)
        init1 = (
            jnp.ones((n,), bool), jnp.zeros((n,), bool),
            jnp.asarray(-jnp.inf, jnp.float32), jnp.asarray(0, jnp.int32),
        )
        alive1, best1, rho1, t1 = jax.lax.fori_loop(
            0, k1, lambda _, s_: body1(s_), init1
        )

        # ---- compaction: renumber survivors into [0, n2) ----
        n_alive1 = jnp.sum(alive1.astype(jnp.int32))
        relabel = jnp.cumsum(alive1.astype(jnp.int32)) - 1  # full -> compact
        relabel = jnp.minimum(relabel, n2 - 1)  # clamp (bound is provable)
        ok_e = mask & alive1[src] & alive1[dst]
        trash = n2  # extra bucket for dead edges
        src2 = jnp.where(ok_e, relabel[src], trash)
        dst2 = jnp.where(ok_e, relabel[dst], trash)
        w2 = jnp.where(ok_e, weight, 0.0)

        # ---- phase 2: while-loop on the compacted ids ----
        stats2 = make_stats(src2, dst2, ok_e, w2, n2 + 1)
        body2 = make_body(stats2)
        alive2_init = jnp.arange(n2 + 1, dtype=jnp.int32) < n_alive1

        def cond2(s_):
            return (jnp.sum(s_[0].astype(jnp.int32)) > 0) & (s_[3] < mp2)

        init2 = (
            alive2_init, jnp.zeros((n2 + 1,), bool),
            jnp.asarray(-jnp.inf, jnp.float32), jnp.asarray(0, jnp.int32),
        )
        alive2, best2, rho2, t2 = jax.lax.while_loop(cond2, body2, init2)

        # ---- merge: map the phase-2 best set back to full ids ----
        best2_full = alive1 & best2[jnp.minimum(relabel, n2 - 1)]
        use2 = rho2 > rho1
        best_alive = jnp.where(use2, best2_full, best1)
        best_rho = jnp.maximum(rho1, rho2)
        return best_alive, best_rho, t1 + t2

    sharded = shard_map(
        peel_local,
        mesh=mesh,
        in_specs=(espec, espec, espec, espec),
        out_specs=(rspec, rspec, rspec),
        check_vma=False,
    )

    @jax.jit
    def run(src, dst, weight, mask) -> PeelResult:
        best_alive, best_rho, t = sharded(src, dst, weight, mask)
        return PeelResult(
            best_alive=best_alive, best_density=best_rho, passes=t,
            history_n=jnp.zeros((1,), jnp.int32),
            history_m=jnp.zeros((1,), jnp.float32),
            history_rho=jnp.zeros((1,), jnp.float32),
        )

    return run


def make_distributed_sketched_peel(
    mesh: Mesh,
    edge_axes: Tuple[str, ...] = ("data",),
    eps: float = 0.5,
    max_passes: int = 48,
    n_nodes: Optional[int] = None,
    t: int = 5,
    b: int = 1 << 17,
    node_chunk: int = 1 << 20,
    seed: int = 0,
):
    """Distributed Algorithm 1 with Count-Sketch degrees (paper §5.1).

    This is the billion-node configuration: per-pass cross-device traffic is
    the O(t*b) counter table (psum), NOT the O(n) degree vector; node state
    (alive/best bitmaps) stays replicated, and degree *queries* stream over
    node chunks so peak memory is O(t*b + node_chunk) beyond the bitmaps.
    """
    from repro.core.countsketch import (
        _hash_bucket,
        _hash_sign,
        make_sketch_params,
    )

    axes = tuple(edge_axes)
    espec = P(axes)
    rspec = P()
    sketch = make_sketch_params(t, b, seed)
    assert n_nodes is not None
    n = n_nodes
    n_pad = ((n + node_chunk - 1) // node_chunk) * node_chunk
    n_chunks = n_pad // node_chunk

    def peel_local(src, dst, weight, mask):
        def counters_of(alive):
            ok = mask & alive[src] & alive[dst]
            w = jnp.where(ok, weight, 0.0)

            def accumulate(x):
                buckets = _hash_bucket(sketch, x)  # [t, E]
                signs = _hash_sign(sketch, x)
                flat = (
                    buckets + (jnp.arange(t, dtype=jnp.int32) * b)[:, None]
                ).reshape(-1)
                vals = (signs * w[None, :]).reshape(-1)
                return jax.ops.segment_sum(vals, flat, num_segments=t * b)

            local = accumulate(src) + accumulate(dst)
            packed = jnp.concatenate([local, jnp.sum(w)[None]])
            packed = jax.lax.psum(packed, axes)  # O(t*b) traffic, not O(n)
            return packed[:-1].reshape(t, b), packed[-1]

        def est_chunk(counters, chunk_idx):
            ids = chunk_idx * node_chunk + jnp.arange(node_chunk, dtype=jnp.int32)
            buckets = _hash_bucket(sketch, ids)  # [t, C]
            signs = _hash_sign(sketch, ids)
            est = jnp.take_along_axis(counters, buckets, axis=1) * signs
            return jnp.median(est, axis=0), ids

        def cond(s):
            alive, _, _, tt = s
            return (jnp.sum(alive.astype(jnp.int64)) > 0) & (tt < max_passes)

        def body(s):
            alive, best_alive, best_rho, tt = s
            counters, total = counters_of(alive)
            n_alive = jnp.sum(alive.astype(jnp.int64)).astype(jnp.float32)
            rho = jnp.where(n_alive > 0, total / jnp.maximum(n_alive, 1.0), 0.0)
            improved = rho > best_rho
            best_alive = jnp.where(improved, alive, best_alive)
            best_rho = jnp.maximum(rho, best_rho)
            thresh = 2.0 * (1.0 + eps) * rho

            # Pass 1 over node chunks: global min estimated degree (progress
            # fallback).  Pass 2: threshold removal.
            def min_body(carry, ci):
                counters_ = counters
                est, ids = est_chunk(counters_, ci)
                ok = (ids < n) & alive[jnp.minimum(ids, n - 1)]
                est = jnp.where(ok, est, jnp.inf)
                return jnp.minimum(carry, jnp.min(est)), None

            min_deg, _ = jax.lax.scan(
                min_body, jnp.asarray(jnp.inf, jnp.float32),
                jnp.arange(n_chunks, dtype=jnp.int32),
            )

            def rm_body(alive_c, ci):
                est, ids = est_chunk(counters, ci)
                idsc = jnp.minimum(ids, n - 1)
                was = alive_c[idsc] & (ids < n)
                remove = was & ((est <= thresh) | (est <= min_deg))
                return alive_c.at[idsc].set(
                    jnp.where(ids < n, was & ~remove, alive_c[idsc])
                ), None

            alive, _ = jax.lax.scan(
                rm_body, alive, jnp.arange(n_chunks, dtype=jnp.int32)
            )
            return (alive, best_alive, best_rho, tt + 1)

        init = (
            jnp.ones((n,), bool),
            jnp.ones((n,), bool),
            jnp.asarray(-jnp.inf, jnp.float32),
            jnp.asarray(0, jnp.int32),
        )
        alive, best_alive, best_rho, tt = jax.lax.while_loop(cond, body, init)
        return best_alive, best_rho, tt

    sharded = shard_map(
        peel_local,
        mesh=mesh,
        in_specs=(espec, espec, espec, espec),
        out_specs=(rspec, rspec, rspec),
        check_vma=False,
    )
    return jax.jit(sharded)


def make_distributed_topk_peel(
    mesh: Mesh,
    edge_axes: Tuple[str, ...] = ("data",),
    k: int = 1,
    eps: float = 0.5,
    max_passes: Optional[int] = None,
    n_nodes: Optional[int] = None,
):
    """Distributed Algorithm 2 (densest subgraph with |S| >= k).

    Per pass, removes exactly ceil(eps/(1+eps)·|S|) of the LOWEST-degree
    nodes among the threshold-eligible set (the paper's 'smallest number of
    nodes necessary for convergence').  Degrees are replicated after the
    psum, so the rank selection is computed identically on every device —
    no extra collective beyond Algorithm 1's.
    """
    axes = tuple(edge_axes)
    espec = P(axes)
    rspec = P()
    assert n_nodes is not None
    n = n_nodes
    mp = max_passes if max_passes is not None else max_passes_bound(n, eps)

    def peel_local(src, dst, weight, mask):
        def stats(alive):
            ok = mask & alive[src] & alive[dst]
            w_alive = jnp.where(ok, weight, 0.0)
            deg = jax.ops.segment_sum(w_alive, src, num_segments=n)
            deg = deg + jax.ops.segment_sum(w_alive, dst, num_segments=n)
            packed = jax.lax.psum(
                jnp.concatenate([deg, jnp.sum(w_alive)[None]]), axes
            )
            return packed[:-1], packed[-1]

        def cond(s):
            alive, _, _, t = s
            return (jnp.sum(alive.astype(jnp.int32)) >= k) & (t < mp)

        def body(s):
            alive, best_alive, best_rho, t = s
            deg, total = stats(alive)
            n_alive = jnp.sum(alive.astype(jnp.int32))
            rho = jnp.where(n_alive > 0, total / jnp.maximum(n_alive, 1), 0.0)
            improved = (rho > best_rho) & (n_alive >= k)
            best_alive = jnp.where(improved, alive, best_alive)
            best_rho = jnp.where(improved, rho, best_rho)
            # A~(S): threshold-eligible; remove the ceil(eps/(1+eps)|S|)
            # lowest-degree of them (ranked by degree, ties by id).
            thresh = 2.0 * (1.0 + eps) * rho
            n_rm = jnp.ceil(
                n_alive.astype(jnp.float32) * eps / (1.0 + eps)
            ).astype(jnp.int32)
            n_rm = jnp.maximum(n_rm, 1)
            eligible = alive & (deg <= thresh)
            # rank within eligible set: sort (deg, id) ascending
            big = jnp.asarray(jnp.inf, jnp.float32)
            key = jnp.where(eligible, deg, big)
            order = jnp.argsort(key)  # eligible first, by degree
            rank = jnp.zeros((n,), jnp.int32).at[order].set(
                jnp.arange(n, dtype=jnp.int32)
            )
            n_eligible = jnp.sum(eligible.astype(jnp.int32))
            remove = eligible & (rank < jnp.minimum(n_rm, n_eligible))
            return (alive & ~remove, best_alive, best_rho, t + 1)

        init = (
            jnp.ones((n,), bool), jnp.ones((n,), bool),
            jnp.asarray(-jnp.inf, jnp.float32), jnp.asarray(0, jnp.int32),
        )
        alive, best_alive, best_rho, t = jax.lax.while_loop(cond, body, init)
        return best_alive, best_rho, t

    sharded = shard_map(
        peel_local,
        mesh=mesh,
        in_specs=(espec, espec, espec, espec),
        out_specs=(rspec, rspec, rspec),
        check_vma=False,
    )

    @jax.jit
    def run(src, dst, weight, mask) -> PeelResult:
        best_alive, best_rho, t = sharded(src, dst, weight, mask)
        return PeelResult(
            best_alive=best_alive, best_density=best_rho, passes=t,
            history_n=jnp.zeros((1,), jnp.int32),
            history_m=jnp.zeros((1,), jnp.float32),
            history_rho=jnp.zeros((1,), jnp.float32),
        )

    return run


def make_distributed_directed_peel(
    mesh: Mesh,
    edge_axes: Tuple[str, ...] = ("data",),
    eps: float = 0.5,
    max_passes: Optional[int] = None,
    n_nodes: Optional[int] = None,
):
    """Distributed Algorithm 3 (directed) for a traced ratio c."""
    axes = tuple(edge_axes)
    espec = P(axes)
    rspec = P()

    def peel_local(src, dst, weight, mask, c):
        n = n_nodes
        assert n is not None
        mp = max_passes if max_passes is not None else 2 * max_passes_bound(n, eps)

        def stats(s_alive, t_alive):
            ok = mask & s_alive[src] & t_alive[dst]
            w = jnp.where(ok, weight, 0.0)
            out_deg = jax.ops.segment_sum(w, src, num_segments=n)
            in_deg = jax.ops.segment_sum(w, dst, num_segments=n)
            packed = jnp.concatenate([out_deg, in_deg, jnp.sum(w)[None]])
            packed = jax.lax.psum(packed, axes)
            return packed[:n], packed[n : 2 * n], packed[-1]

        def cond(s):
            s_alive, t_alive = s[0], s[1]
            return (
                (jnp.sum(s_alive.astype(jnp.int32)) > 0)
                & (jnp.sum(t_alive.astype(jnp.int32)) > 0)
                & (s[5] < mp)
            )

        def body(s):
            s_alive, t_alive, best_s, best_t, best_rho, t = s
            out_deg, in_deg, total = stats(s_alive, t_alive)
            ns = jnp.sum(s_alive.astype(jnp.int32))
            nt = jnp.sum(t_alive.astype(jnp.int32))
            ns_f = jnp.maximum(ns.astype(jnp.float32), 1.0)
            nt_f = jnp.maximum(nt.astype(jnp.float32), 1.0)
            rho = jnp.where(
                (ns > 0) & (nt > 0), total / jnp.sqrt(ns_f * nt_f), 0.0
            )
            improved = rho > best_rho
            best_s = jnp.where(improved, s_alive, best_s)
            best_t = jnp.where(improved, t_alive, best_t)
            best_rho = jnp.maximum(rho, best_rho)
            peel_s = ns_f / nt_f >= c
            thr_s = (1.0 + eps) * total / ns_f
            outd = jnp.where(s_alive, out_deg, jnp.inf)
            rm_s = s_alive & ((out_deg <= thr_s) | (out_deg <= jnp.min(outd)))
            thr_t = (1.0 + eps) * total / nt_f
            ind = jnp.where(t_alive, in_deg, jnp.inf)
            rm_t = t_alive & ((in_deg <= thr_t) | (in_deg <= jnp.min(ind)))
            s_alive = jnp.where(peel_s, s_alive & ~rm_s, s_alive)
            t_alive = jnp.where(peel_s, t_alive, t_alive & ~rm_t)
            return (s_alive, t_alive, best_s, best_t, best_rho, t + 1)

        ones = jnp.ones((n,), bool)
        init = (ones, ones, ones, ones, jnp.asarray(-jnp.inf, jnp.float32), jnp.asarray(0, jnp.int32))
        out = jax.lax.while_loop(cond, body, init)
        return out[2], out[3], out[4], out[5]

    sharded = shard_map(
        peel_local,
        mesh=mesh,
        in_specs=(espec, espec, espec, espec, rspec),
        out_specs=(rspec, rspec, rspec, rspec),
        check_vma=False,
    )
    return jax.jit(sharded)
