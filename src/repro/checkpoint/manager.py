"""Fault-tolerant checkpointing (no orbax offline — built on numpy + msgpack).

Production properties implemented here:

  * ATOMIC: write to ``<dir>/tmp.<step>/`` then ``os.replace`` to
    ``step_<n>/`` — a preempted writer never corrupts the latest checkpoint;
  * MESH-INDEPENDENT: arrays are saved as full (addressable-gathered) numpy
    buffers with a pytree manifest, so a restore may use a different mesh
    shape / device count (elastic rescale restores then re-shards);
  * ASYNC: ``save_async`` snapshots to host memory synchronously (cheap) and
    writes in a daemon thread, overlapping I/O with the next training steps —
    a step watchdog or SIGTERM handler can still join() the writer;
  * KEEP-K: old checkpoints garbage-collected after a successful save;
  * SELF-DESCRIBING: manifest.msgpack stores the treedef, shapes, dtypes and
    user metadata (step, rng state, data-pipeline cursor) for restart.

On a real multi-host pod each host writes only its addressable shards and a
process-0 barrier commits the manifest; the single-process layout here keeps
the same two-phase commit structure (documented in DESIGN.md §4).
"""

from __future__ import annotations

import dataclasses
import os
import re
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

_STEP_RE = re.compile(r"^step_(\d+)$")


def _tree_paths(tree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        out.append((jax.tree_util.keystr(path), leaf))
    return out


def save_checkpoint(
    directory: str,
    step: int,
    state: Any,
    metadata: Optional[Dict[str, Any]] = None,
    keep: int = 3,
) -> str:
    """Synchronous atomic save. Returns the final checkpoint path."""
    arrays = {}
    manifest = {"step": int(step), "metadata": metadata or {}, "leaves": []}
    for key, leaf in _tree_paths(state):
        arr = np.asarray(jax.device_get(leaf))
        arrays[key] = arr
        manifest["leaves"].append(
            {"key": key, "shape": list(arr.shape), "dtype": str(arr.dtype)}
        )

    os.makedirs(directory, exist_ok=True)
    tmp = os.path.join(directory, f"tmp.{step}.{os.getpid()}")
    final = os.path.join(directory, f"step_{step}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    # npz holds every leaf; keys are sanitized tree paths.
    np.savez(os.path.join(tmp, "arrays.npz"), **{k: v for k, v in arrays.items()})
    # repro: allow(atomic-io) write lands in tmp.<step>/ — the directory rename below is the publish
    with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
        f.write(msgpack.packb(manifest, use_bin_type=True))
    if os.path.exists(final):
        shutil.rmtree(final)
    # repro: allow(atomic-io) directory-level two-phase commit: this rename IS the atomic publish
    os.replace(tmp, final)
    _gc(directory, keep)
    return final


def _gc(directory: str, keep: int):
    steps = sorted(all_steps(directory))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(directory, f"step_{s}"), ignore_errors=True)


def all_steps(directory: str) -> List[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        m = _STEP_RE.match(name)
        if m and os.path.exists(os.path.join(directory, name, "manifest.msgpack")):
            out.append(int(m.group(1)))
    return sorted(out)


def restore_checkpoint(
    directory: str,
    step: int,
    like: Any,
    shardings: Any = None,
) -> Tuple[Any, Dict[str, Any]]:
    """Restores into the structure of ``like``; re-shards onto ``shardings``
    (pytree of NamedSharding / None) if given — the elastic-rescale path."""
    path = os.path.join(directory, f"step_{step}")
    with open(os.path.join(path, "manifest.msgpack"), "rb") as f:
        manifest = msgpack.unpackb(f.read(), raw=False)
    data = np.load(os.path.join(path, "arrays.npz"))

    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    shard_flat = (
        jax.tree.leaves(shardings, is_leaf=lambda x: x is None or hasattr(x, "spec"))
        if shardings is not None
        else [None] * len(flat)
    )
    leaves = []
    for (kpath, leaf), sh in zip(flat, shard_flat):
        key = jax.tree_util.keystr(kpath)
        if key not in data:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = data[key]
        want_shape = tuple(leaf.shape)
        if tuple(arr.shape) != want_shape:
            raise ValueError(f"{key}: checkpoint {arr.shape} != expected {want_shape}")
        if sh is not None:
            leaves.append(jax.device_put(arr, sh))
        else:
            leaves.append(jnp.asarray(arr))
    state = jax.tree_util.tree_unflatten(treedef, leaves)
    return state, manifest["metadata"]


def restore_latest(directory: str, like: Any, shardings: Any = None):
    steps = all_steps(directory)
    if not steps:
        return None, None, -1
    state, meta = restore_checkpoint(directory, steps[-1], like, shardings)
    return state, meta, steps[-1]


@dataclasses.dataclass
class CheckpointManager:
    """Async keep-k checkpointer with a join()-able writer thread."""

    directory: str
    keep: int = 3
    _thread: Optional[threading.Thread] = None
    _error: Optional[BaseException] = None

    def save_async(self, step: int, state: Any, metadata=None):
        """Snapshot to host now, write in background."""
        self.join()
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)

        def _write():
            try:
                save_checkpoint(
                    self.directory, step, host_state, metadata, keep=self.keep
                )
            except BaseException as e:  # surfaced on next join()
                self._error = e

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def save(self, step: int, state: Any, metadata=None):
        self.join()
        return save_checkpoint(self.directory, step, state, metadata, keep=self.keep)

    def join(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def latest_step(self) -> int:
        steps = all_steps(self.directory)
        return steps[-1] if steps else -1
