"""Serving hook for the turnstile runtime: one live sketch, cheap
"current density" answers between update batches.

:class:`TurnstileDensityService` owns a
:class:`~repro.core.turnstile.TurnstileDensest` and adds the serving
concern the core driver deliberately doesn't have: query-result CACHING
keyed on a dirty flag.  Updates are absorbed immediately (the sketch is
device-resident and update-linear; an ``apply`` is one cached jitted
program), but the sampled peel only reruns when an update actually landed
since the last query — repeated density reads between batches are O(1)
host lookups.

A :class:`~repro.serve.densest.DensestQueryEngine` can
:meth:`~repro.serve.densest.DensestQueryEngine.attach_turnstile` one of
these, answering whole-graph "how dense is the graph RIGHT NOW" probes
from the same process that serves per-seed ego-net queries.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple, Union

import numpy as np

from repro.core.api import DenseSubgraphResult, Problem, Solver
from repro.core.turnstile import TurnstileDensest

__all__ = ["TurnstileDensityService"]


class TurnstileDensityService:
    """A live turnstile driver with dirty-flag query caching.

    ``apply()`` feeds ±edge batches to the sketch and marks the cached
    answer stale; ``result()`` / ``density()`` re-query ONLY when stale.
    Counters: ``updates_applied`` / ``batches_applied`` mirror the
    sketch's, ``queries_served`` counts reads, ``queries_computed`` counts
    actual sampled peels (the difference is cache traffic).

    Resilience (docs/resilience.md): with ``serve_stale=True`` (default)
    a recompute that FAILS — sketch recovery exhausted its level
    escalation, or an injected ``serve``-layer fault — serves the
    last-good cached answer instead of raising, stamps ``last_error`` and
    counts ``stale_results_served``.  The stale answer is real previously
    computed data, never fabricated; with no cached answer yet the error
    propagates (there is nothing true to serve).
    """

    def __init__(
        self,
        n_nodes: int,
        problem: Optional[Problem] = None,
        *,
        solver: Optional[Solver] = None,
        cache_dir: Optional[str] = None,
        serve_stale: bool = True,
        **driver_kw,
    ):
        if problem is None:
            problem = Problem.undirected(stream_mode="turnstile")
        if solver is None:
            solver = Solver(cache_dir=cache_dir)
        self.driver = TurnstileDensest(
            n_nodes, problem, solver=solver, **driver_kw
        )
        self.solver = solver
        self.serve_stale = bool(serve_stale)
        self._cached: Optional[DenseSubgraphResult] = None
        self._dirty = True  # an empty graph is still a valid first query
        self.queries_served = 0
        self.queries_computed = 0
        self.queries_failed = 0
        self.stale_results_served = 0
        self.last_error: Optional[str] = None

    @property
    def n_nodes(self) -> int:
        return self.driver.n_nodes

    @property
    def updates_applied(self) -> int:
        return self.driver.sketch.updates_applied

    @property
    def batches_applied(self) -> int:
        return self.driver.sketch.batches_applied

    def apply(
        self,
        insert_edges: Union[np.ndarray, Tuple, None] = None,
        delete_edges: Union[np.ndarray, Tuple, None] = None,
    ) -> "TurnstileDensityService":
        """Absorbs one ±edge batch and marks the cached answer stale."""
        before = self.driver.sketch.batches_applied
        self.driver.apply(insert_edges, delete_edges)
        if self.driver.sketch.batches_applied != before:  # empty batch: no-op
            self._dirty = True
        return self

    def result(self) -> DenseSubgraphResult:
        """The current densest-subgraph answer (recomputed only if an
        update arrived since the last query)."""
        self.queries_served += 1
        if self._dirty or self._cached is None:
            try:
                self._cached = self.driver.query()
            except Exception as e:  # noqa: BLE001 — serve stale, never fake
                self.queries_failed += 1
                self.last_error = f"{type(e).__name__}: {e}"
                if self.serve_stale and self._cached is not None:
                    # Last-good answer; _dirty stays True so the next read
                    # retries the recompute.
                    self.stale_results_served += 1
                    return self._cached
                raise
            self.queries_computed += 1
            self._dirty = False
        return self._cached

    def density(self) -> float:
        """Current (1+eps)·(2+2eps)-approximate maximum density."""
        return float(self.result().best_density)

    def stats(self) -> Dict[str, Any]:
        """Serving + sketch + solver counters in one dict, so degraded
        operation (escalations, stale serves, disk-store failures) is
        observable from the service alone."""
        return {
            "updates_applied": self.updates_applied,
            "batches_applied": self.batches_applied,
            "queries_served": self.queries_served,
            "queries_computed": self.queries_computed,
            "queries_failed": self.queries_failed,
            "stale_results_served": self.stale_results_served,
            "last_error": self.last_error,
            "recovery_failures": self.driver.sketch.recovery_failures,
            "recovery_escalations": self.driver.sketch.recovery_escalations,
            "update_trace_count": self.driver.sketch.trace_count,
            "disk_store_errors": self.solver.disk_store_errors,
        }
