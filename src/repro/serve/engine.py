"""Batched LM serving engine: continuous batching over a fixed-slot KV cache.

Production structure (single-host scale model of the decode_32k cell):

  * fixed ``n_slots`` decode slots, each holding one request's KV state
    inside a shared [L, slots, max_len, Hkv, D] cache (the dry-run's
    decode-cell layout, batch dim = slots);
  * admission: new requests prefill into a free slot (prefill and decode are
    separate jitted programs, as in disaggregated serving);
  * every engine step decodes ONE token for ALL active slots (continuous
    batching — finished requests retire immediately, their slot is reusable
    on the next step, no head-of-line blocking);
  * deterministic greedy sampling (argmax) for testability; the sampler is
    a pluggable fn(logits) -> token.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Callable, Deque, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import TransformerConfig, decode_step, prefill


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # int32[P]
    max_new: int = 16
    eos_id: Optional[int] = None
    # filled by the engine:
    tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    rejected: bool = False  # shed at admission (bounded queue full)


class ServeEngine:
    def __init__(
        self,
        params,
        cfg: TransformerConfig,
        n_slots: int = 4,
        max_len: int = 256,
        sampler: Optional[Callable] = None,
        max_queue: Optional[int] = None,
    ):
        if max_queue is not None and max_queue < 1:
            raise ValueError(f"max_queue={max_queue} must be >= 1")
        self.params = params
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_len = max_len if cfg.window is None else min(max_len, cfg.window)
        self.sampler = sampler or (lambda logits: jnp.argmax(logits, -1))
        if cfg.window is not None:
            # Rolling caches must match the prefill buffer layout exactly
            # (slot s holds position p with p % window == s).
            self.max_len = cfg.window
        shape = (cfg.n_layers, n_slots, self.max_len, cfg.n_kv_heads, cfg.d_head)
        self.cache = {
            "k": jnp.zeros(shape, jnp.bfloat16),
            "v": jnp.zeros(shape, jnp.bfloat16),
        }
        self.cur_len = np.zeros(n_slots, np.int64)
        self.slot_req: List[Optional[Request]] = [None] * n_slots
        # FIFO admission queue; deque so admission is O(1) per request
        # (list.pop(0) is O(n) and the queue can be deep under load).
        self.queue: Deque[Request] = collections.deque()
        self.max_queue = max_queue
        self.rejected = 0  # requests shed at admission
        self._decode = jax.jit(self._decode_impl)

    # --- public API ---

    def submit(self, req: Request) -> bool:
        """Enqueues ``req``; with ``max_queue`` set, a full queue SHEDS the
        request instead of queueing unboundedly — ``req.rejected`` is set
        and False returned (the explicit load-shedding outcome, same
        contract as the densest engine's ``status='rejected'``)."""
        if self.max_queue is not None and len(self.queue) >= self.max_queue:
            req.rejected = True
            self.rejected += 1
            return False
        self.queue.append(req)
        return True

    def step(self) -> List[Request]:
        """Admit + decode one token for all active slots; returns finished."""
        self._admit()
        finished = []
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if active:
            self._decode_active(active)
            for i in active:
                r = self.slot_req[i]
                tok = r.tokens[-1]
                if (r.eos_id is not None and tok == r.eos_id) or len(
                    r.tokens
                ) >= r.max_new:
                    r.done = True
                    finished.append(r)
                    self.slot_req[i] = None
        return finished

    def run_to_completion(self, max_steps: int = 10_000) -> List[Request]:
        out = []
        for _ in range(max_steps):
            out.extend(self.step())
            if not self.queue and all(r is None for r in self.slot_req):
                break
        return out

    # --- internals ---

    def _admit(self):
        for i in range(self.n_slots):
            if self.slot_req[i] is None and self.queue:
                req = self.queue.popleft()
                self._prefill_into(i, req)
                self.slot_req[i] = req

    def _prefill_into(self, slot: int, req: Request):
        p = len(req.prompt)
        if self.cfg.window is None and p + req.max_new > self.max_len:
            raise ValueError(
                f"request {req.rid}: prompt {p} + max_new {req.max_new} "
                f"exceeds cache {self.max_len}"
            )
        tokens = jnp.asarray(req.prompt, jnp.int32)[None]
        logits, cache, cur_len = prefill(self.params, self.cfg, tokens)
        keep = min(p, self.max_len)
        # Copy the request's prefill cache into the shared slot.
        for key in ("k", "v"):
            blk = cache[key][:, 0]  # [L, P(or window), H, D]
            self.cache[key] = jax.lax.dynamic_update_slice(
                self.cache[key],
                blk[:, None, :keep].astype(self.cache[key].dtype),
                (0, slot, 0, 0, 0),
            )
        self.cur_len[slot] = p
        first = int(jax.device_get(self.sampler(logits))[0])
        req.tokens.append(first)

    def _decode_impl(self, params, cache, tokens, cur_lens):
        """Per-slot-position decode: vmap of a B=1 decode over the slot dim,
        so every request attends at ITS OWN position (continuous batching
        with heterogeneous lengths)."""

        def one_slot(cache_k, cache_v, tok, cur):
            # cache_k/v: [L, M, H, D]; tok: int32[1]; cur: int32[]
            c = {"k": cache_k[:, None], "v": cache_v[:, None]}
            logits, nc, _ = decode_step(params, self.cfg, c, tok[None], cur)
            return logits[0], nc["k"][:, 0], nc["v"][:, 0]

        logits, nk, nv = jax.vmap(
            one_slot, in_axes=(1, 1, 0, 0), out_axes=(0, 1, 1)
        )(cache["k"], cache["v"], tokens, cur_lens)
        return logits, {"k": nk, "v": nv}

    def _decode_active(self, active: List[int]):
        toks = np.zeros((self.n_slots, 1), np.int32)
        for i in active:
            toks[i, 0] = self.slot_req[i].tokens[-1]
        cur = jnp.asarray(self.cur_len, jnp.int32)
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(toks), cur
        )
        nxt = jax.device_get(self.sampler(logits))
        for i in active:
            self.slot_req[i].tokens.append(int(nxt[i]))
            self.cur_len[i] += 1
