"""Seed-batched densest-subgraph query engine (the serving front line).

Production traffic is per-seed queries — "give me the dense community
around THIS node" — not whole-graph solves.  This engine makes a query's
cost depend on the seed's NEIGHBORHOOD, not on n, and makes a fleet of
concurrent queries share a handful of compiled programs:

  * **Host-resident CSR adjacency**, built once from the edge list
    (:func:`repro.graph.edgelist.to_csr`): O(1) neighbor lookups, no device
    round-trip during extraction.
  * **Bounded-radius ego-net extraction**: BFS out to ``radius`` hops
    (optionally truncated at ``max_ego_nodes``), then the induced subgraph
    is relabeled into a compact id space — O(vol(ego)) host work per query.
  * **Power-of-two bucketing**: each extracted subgraph is padded into a
    pow2 node bucket and pow2 edge bucket
    (:func:`repro.graph.partition.pow2_bucket`, the compaction ladder's
    bucket rule), and batches are padded to pow2 LANE counts — so every
    query the fleet will ever see lands on O(log² size × log batch)
    distinct program shapes.  Pad nodes are isolated: the peel removes them
    in pass 1 (degree 0 is always ≤ the removal threshold), so the
    (2+2eps) approximation guarantee holds on the padded buffer (see
    docs/serving.md for the short proof sketch).
  * **Micro-batching with a deadline**: queries queue (FIFO deque) until
    ``max_batch`` are waiting or the oldest has waited ``max_wait_ms``;
    a flush coalesces same-bucket queries and solves each bucket group as
    ONE vmapped ``solve_batch`` program.  Each lane is bit-identical to a
    standalone ``solve()`` of the same padded subgraph (the engine's
    correctness contract, held by tests/test_serve_densest.py).
  * **Persistent warmth**: give the engine (or its Solver) a ``cache_dir``
    and a fresh replica loads every bucket program from disk instead of
    compiling (``core/progcache.py``) — the cold-start path tracked by
    ``benchmarks/bench_serve.py``.
  * **Two extraction modes** behind one knob: ``extraction='bfs'`` (the
    radius-hop ego-net above) or ``extraction='local'`` — Andersen's
    pruned-frontier exploration (``core/local.py``, arXiv cs/0702078),
    whose per-query work is bounded by ``local_budget`` instead of the
    neighborhood volume, so it stays flat as the graph grows
    (``benchmarks/bench_serve.py`` tracks the sweep).  Both modes land in
    the same buckets, batches, and resilience ladder; the shrink degrade
    rung re-extracts at smaller radius (BFS) or halved budget (local).
    A ``Problem(substrate='local')`` selects the local mode and supplies
    its exploration knobs; the solves lower onto jit lanes either way.
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro import constants, faults
from repro.core.api import Problem, Solver
from repro.core.local import (
    LocalExplorer,
    check_count,
    check_seed,
    induced_padded,
)
from repro.graph.edgelist import EdgeList, to_csr
from repro.graph.partition import pow2_bucket
from repro.serve.resilience import CircuitBreaker, ResilienceConfig

__all__ = ["DensestQueryEngine", "QueryResult"]

# Bucket floors (aliased from the one constants surface, repro.constants):
# below these the pad fraction is irrelevant and smaller buckets would only
# mint more compiled programs.
_NODE_FLOOR = constants.SERVE_NODE_FLOOR
_EDGE_FLOOR = constants.SERVE_EDGE_FLOOR
# Local-extraction budget floor: the shrink degrade rung halves a query's
# budget down to (not past) this.
_LOCAL_BUDGET_FLOOR = constants.LOCAL_BUDGET_FLOOR


@dataclasses.dataclass(frozen=True)
class QueryResult:
    """One answered seed query.

    ``nodes`` are ORIGINAL graph ids (bucket pad nodes are filtered out);
    ``density`` is the peel's best density on the padded ego-net buffer —
    a (2+2eps)-approximation of the ego-net's densest subgraph.

    Failure provenance (the resilience contract, docs/resilience.md):
    ``status`` is ``'ok'`` (the full exact-path answer), ``'degraded'``
    (a real but weaker answer; ``fallback`` names its source —
    ``'radius:<r>'``/``'budget:<b>'`` per extraction mode,
    ``'turnstile_density'`` or ``'last_good'``),
    ``'rejected'`` (shed at admission by a full bounded queue) or
    ``'failed'`` (every fallback exhausted).  ``error`` carries the
    original solve error for every non-``'ok'`` status and ``attempts``
    counts solve attempts (retries included).  A degraded answer is
    never fabricated — it is always genuinely computed data.
    """

    qid: int
    seed: int
    nodes: np.ndarray  # original-id members of the best set
    density: float
    seed_in_set: bool
    n_ego: int  # extracted subgraph size: nodes (ego-net or candidate set)
    m_ego: int  # extracted subgraph size: edges
    bucket: Tuple[int, int, int]  # (node bucket, edge bucket, batch lanes)
    latency_s: float  # submit -> answer (engine clock)
    status: str = "ok"  # ok | degraded | rejected | failed
    fallback: Optional[str] = None  # provenance of a degraded answer
    error: Optional[str] = None  # original error for non-ok statuses
    attempts: int = 1  # solve attempts spent (0: never reached a solve)

    @property
    def size(self) -> int:
        return int(len(self.nodes))

    @property
    def degraded(self) -> bool:
        return self.status == "degraded"

    @property
    def answered(self) -> bool:
        """True when the query got a real answer (exact or degraded)."""
        return self.status in ("ok", "degraded")


@dataclasses.dataclass
class _Pending:
    qid: int
    seed: int
    radius: int  # BFS extraction (0 under extraction='local')
    budget: int  # local extraction (0 under extraction='bfs')
    submitted_at: float


class DensestQueryEngine:
    """Answers per-seed densest-subgraph queries over one host graph.

    Synchronous pump (the style of :class:`repro.serve.engine.ServeEngine`):
    ``submit()`` enqueues, ``step()`` flushes a batch when one is due
    (``max_batch`` reached or the oldest query older than ``max_wait_ms``),
    ``flush()`` forces everything out, and ``query()`` / ``query_many()``
    are the one-call conveniences.  ``time_fn`` is injectable so deadline
    behavior is testable without sleeping.

    Undirected host graphs only; the Problem must lower onto the jit
    substrate (``Problem(substrate='local')`` is accepted and selects the
    local extraction — its solves still run as jit lanes) and — for
    stacked lanes — a graph-independent backend.

    ``extraction`` picks how a query's subgraph is carved out:
    ``'bfs'`` (default) is the radius-hop ego-net; ``'local'`` is the
    Andersen pruned-frontier exploration (``core/local.py``) whose
    per-query work is capped by ``local_budget`` — the per-query override
    is ``budget=`` (``radius=`` in BFS mode).  Both modes share the
    buckets, the batching, the resilience ladder, and the QueryResult
    contract; each lane stays bit-identical to a standalone ``solve()``
    of the same padded buffer (for the local mode that standalone is
    ``solve(graph, Problem(substrate='local'), seed=...)``).
    """

    def __init__(
        self,
        graph: EdgeList,
        problem: Optional[Problem] = None,
        *,
        solver: Optional[Solver] = None,
        cache_dir: Optional[str] = None,
        radius: int = 2,
        max_batch: int = 32,
        max_wait_ms: float = 5.0,
        max_ego_nodes: Optional[int] = None,
        node_floor: int = _NODE_FLOOR,
        edge_floor: int = _EDGE_FLOOR,
        time_fn: Callable[[], float] = time.monotonic,
        resilience: Optional[ResilienceConfig] = None,
        sleep_fn: Callable[[float], None] = time.sleep,
        extraction: Optional[str] = None,
        local_budget: Optional[int] = None,
        local_rounds: Optional[int] = None,
        local_alpha: Optional[float] = None,
    ):
        if graph.directed:
            raise ValueError(
                "DensestQueryEngine serves undirected host graphs "
                "(both extraction modes are undirected)"
            )
        problem = problem if problem is not None else Problem.undirected()
        if problem.substrate == "local":
            # Problem(substrate='local') IS the local serving spec: apply
            # its validation (undirected objective, exact backend,
            # compaction off), inherit its exploration knobs, and lower
            # the lane solves onto the jit substrate.
            resolved = problem.resolve(graph.n_nodes)
            extraction = "local" if extraction is None else extraction
            if local_budget is None:
                local_budget = resolved.local_budget
            if local_rounds is None:
                local_rounds = resolved.local_rounds
            if local_alpha is None:
                local_alpha = resolved.local_alpha
            problem = dataclasses.replace(resolved, substrate="jit")
        if problem.substrate not in ("jit", "auto"):
            raise ValueError(
                "per-seed serving batches extracted subgraphs on the jit "
                f"substrate; substrate={problem.substrate!r} does not apply"
            )
        if problem.backend == "pallas":
            raise ValueError(
                "stacked-lane sweeps need a graph-independent backend "
                "(tile bucketing is per-graph); use backend='exact'"
            )
        if problem.objective == "directed":
            raise ValueError(
                "per-seed extraction is undirected; directed objectives "
                "have no serving cell"
            )
        extraction = "bfs" if extraction is None else extraction
        if extraction not in ("bfs", "local"):
            raise ValueError(
                f"extraction={extraction!r} not in ('bfs', 'local')"
            )
        if extraction == "local" and problem.objective != "undirected":
            raise ValueError(
                "extraction='local' prunes its frontier against the "
                "undirected density; use objective='undirected'"
            )
        if radius < 1:
            raise ValueError(f"radius={radius} must be >= 1")
        if max_batch < 1:
            raise ValueError(f"max_batch={max_batch} must be >= 1")
        if max_wait_ms < 0:
            raise ValueError(f"max_wait_ms={max_wait_ms} must be >= 0")
        self.problem = problem
        self.solver = solver if solver is not None else Solver(cache_dir=cache_dir)
        self.extraction = extraction
        self.local_budget = check_count(
            problem.local_budget if local_budget is None else local_budget,
            "local_budget",
        )
        self.local_rounds = check_count(
            problem.local_rounds if local_rounds is None else local_rounds,
            "local_rounds",
        )
        self.local_alpha = float(
            problem.local_alpha if local_alpha is None else local_alpha
        )
        if self.local_alpha < 0:
            raise ValueError(f"local_alpha={self.local_alpha} must be >= 0")
        self.radius = int(radius)
        self.max_batch = int(max_batch)
        self.max_wait_ms = float(max_wait_ms)
        self.max_ego_nodes = max_ego_nodes
        self.node_floor = int(node_floor)
        self.edge_floor = int(edge_floor)
        self._time = time_fn
        self.n_nodes = graph.n_nodes
        # Host-resident weighted CSR, built once; every query reads it.
        self._indptr, self._indices, self._csr_w = to_csr(
            graph, return_weights=True
        )
        self._member = np.zeros(graph.n_nodes, bool)  # reusable scratch
        self._local_id = np.zeros(graph.n_nodes, np.int32)  # relabel scratch
        # Local-mode explorer over the SAME CSR arrays (no copy); its own
        # scratch keeps the BFS path's `_member` usage independent.
        self._explorer: Optional[LocalExplorer] = (
            LocalExplorer(
                self._indptr, self._indices, self._csr_w,
                n_nodes=graph.n_nodes,
            )
            if extraction == "local"
            else None
        )
        # Local-extraction work counters (bench_serve's scaling evidence).
        self.local_nodes_touched = 0
        self.local_edges_scanned = 0
        # FIFO admission queue (deque: O(1) popleft, arbitrarily deep).
        self._queue: Deque[_Pending] = collections.deque()
        self._next_qid = 0
        # Observability: queries answered, batches flushed, lanes solved
        # (incl. pad lanes), and the bucket -> lane-count histogram.
        self.queries_answered = 0
        self.batches_flushed = 0
        self.lanes_solved = 0
        self.pad_lanes = 0
        self.bucket_histogram: Dict[Tuple[int, int], int] = {}
        # Optional whole-graph turnstile sidecar (attach_turnstile).
        self._turnstile = None
        # Resilience policy (None: legacy behavior except group-failure
        # isolation, which always holds — see _process).
        self.resilience = resilience
        self._sleep = sleep_fn
        self._breaker: Optional[CircuitBreaker] = (
            CircuitBreaker(
                resilience.breaker_threshold,
                resilience.breaker_cooldown_s,
                time_fn=time_fn,
            )
            if resilience is not None
            else None
        )
        # Rejected-at-admission results waiting to be drained by the next
        # step()/flush(), and the last-good per-seed answer cache (bounded
        # by the number of distinct seeds; only kept when the last_good
        # degrade rung is enabled).
        self._shed: List[QueryResult] = []
        self._last_good: Dict[int, QueryResult] = {}
        self.queries_rejected = 0
        self.queries_degraded = 0
        self.queries_failed = 0
        self.solve_retries = 0
        self.breaker_open_skips = 0
        self.deadline_stops = 0

    # -- turnstile attachment -----------------------------------------------
    def attach_turnstile(self, service) -> "DensestQueryEngine":
        """Attaches a live :class:`repro.serve.turnstile.TurnstileDensityService`
        so this engine can also answer whole-graph "current density" probes
        between its per-seed batches.  The sidecar tracks the DYNAMIC graph
        (its own ±edge stream); the engine's host CSR stays the static
        snapshot it was built from — the two views are independent by design.
        """
        if not (hasattr(service, "density") and hasattr(service, "apply")):
            raise ValueError(
                "attach_turnstile expects a TurnstileDensityService-like "
                "object with apply()/density()"
            )
        if service.n_nodes != self.n_nodes:
            raise ValueError(
                f"turnstile service tracks n_nodes={service.n_nodes}, "
                f"engine serves n_nodes={self.n_nodes}"
            )
        self._turnstile = service
        return self

    def current_density(self) -> float:
        """The attached turnstile sidecar's current approximate maximum
        density (cached between update batches)."""
        if self._turnstile is None:
            raise ValueError(
                "no turnstile service attached; call attach_turnstile() first"
            )
        return self._turnstile.density()

    # -- extraction ---------------------------------------------------------
    def _adjacency_rows(self, nodes: np.ndarray):
        """Concatenated CSR rows of ``nodes``: returns ``(slot_idx,
        row_src)`` where ``slot_idx`` indexes indices/weights and
        ``row_src[i]`` is the node whose row slot ``i`` came from."""
        starts = self._indptr[nodes]
        counts = self._indptr[nodes + 1] - starts
        total = int(counts.sum())
        if total == 0:
            return np.empty(0, np.int64), np.empty(0, np.int64)
        # Vectorized multi-range gather: offset of each slot within the
        # concatenation, shifted to its row's CSR start.
        shift = np.repeat(
            starts - np.concatenate(([0], np.cumsum(counts)[:-1])), counts
        )
        slot_idx = shift + np.arange(total)
        return slot_idx, np.repeat(nodes.astype(np.int64), counts)

    def _ego_nodes(self, seed: int, radius: int) -> np.ndarray:
        """Sorted ids of the radius-hop ego-net around ``seed``; leaves
        ``self._member`` SET for those ids (the caller resets it)."""
        member = self._member
        member[seed] = True
        layers = [np.asarray([seed], np.int64)]
        frontier = layers[0]
        n_total = 1
        for _ in range(radius):
            slot_idx, _ = self._adjacency_rows(frontier)
            nb = np.unique(self._indices[slot_idx].astype(np.int64))
            nb = nb[~member[nb]]
            if nb.size == 0:
                break
            if (
                self.max_ego_nodes is not None
                and n_total + nb.size > self.max_ego_nodes
            ):
                # Deterministic truncation: keep the lowest ids of the
                # overflowing layer (documented extraction contract).
                nb = nb[: max(self.max_ego_nodes - n_total, 0)]
                if nb.size == 0:
                    break
            member[nb] = True
            layers.append(nb)
            frontier = nb
            n_total += nb.size
        return np.sort(np.concatenate(layers))

    def extract(
        self,
        seed: int,
        radius: Optional[int] = None,
        *,
        budget: Optional[int] = None,
    ) -> Tuple[EdgeList, np.ndarray]:
        """The extracted subgraph of ``seed`` — radius-hop ego-net (BFS
        mode) or pruned-frontier candidate set (local mode) — as a
        bucket-padded EdgeList plus the sorted original ids its compact
        ids map to (local id i ↔ ``nodes[i]``; ids >= ``len(nodes)`` are
        isolated pad nodes).  The padding body is
        :func:`repro.core.local.induced_padded`, shared with the
        ``substrate='local'`` front door, so every path solves a
        bit-identical buffer.

        This is THE extraction the engine serves — the sequential baseline
        and the bit-identity tests call it so both sides solve the same
        padded buffer.
        """
        seed = check_seed(seed, self.n_nodes)
        if self.extraction == "local":
            if radius is not None:
                raise ValueError(
                    "extraction='local' has no radius; the per-query "
                    "knob is budget="
                )
            b = (
                self.local_budget
                if budget is None
                else check_count(budget, "budget")
            )
            ex = self._explorer.explore(
                seed, budget=b, max_rounds=self.local_rounds,
                alpha=self.local_alpha,
            )
            nodes = ex.candidates
            self.local_nodes_touched += ex.nodes_touched
            self.local_edges_scanned += ex.edges_scanned
        else:
            if budget is not None:
                raise ValueError(
                    "budget= only applies to extraction='local'; the "
                    "BFS per-query knob is radius="
                )
            r = (
                self.radius
                if radius is None
                else check_count(radius, "radius")
            )
            nodes = self._ego_nodes(seed, r)
            self._member[nodes] = False  # reset the BFS scratch
        # Buffers stay NUMPY: the device transfer happens at solve time —
        # once per call for a sequential solve(), once per STACKED BATCH
        # on the engine's coalesced path (the transfer is amortized across
        # the whole bucket group; see _process).
        padded = induced_padded(
            self._indptr, self._indices, self._csr_w, nodes,
            self._member, self._local_id,
            node_floor=self.node_floor, edge_floor=self.edge_floor,
        )
        return padded, nodes

    # -- queueing -----------------------------------------------------------
    def submit(
        self,
        seed: int,
        radius: Optional[int] = None,
        *,
        budget: Optional[int] = None,
    ) -> int:
        """Enqueues a seed query; returns its qid.  Nothing runs until a
        batch is due (``step``) or forced (``flush``).

        Validation happens HERE, at admission (the serving contract): the
        seed must be a real integer node id in range (bools and floats
        are rejected — a float used to slip past the range check and
        silently truncate inside the queue), and the per-query override —
        ``radius=`` in BFS mode, ``budget=`` in local mode — must be a
        positive integer matching the engine's extraction mode.

        With ``resilience.max_queue`` set, a full admission queue SHEDS the
        query instead of growing without bound: the qid is still returned,
        and the next drain yields a ``status='rejected'`` result for it."""
        seed = check_seed(seed, self.n_nodes)
        if self.extraction == "local":
            if radius is not None:
                raise ValueError(
                    "extraction='local' has no radius; the per-query "
                    "knob is budget="
                )
            q_radius = 0
            q_budget = (
                self.local_budget
                if budget is None
                else check_count(budget, "budget")
            )
        else:
            if budget is not None:
                raise ValueError(
                    "budget= only applies to extraction='local'; the "
                    "BFS per-query knob is radius="
                )
            q_radius = (
                self.radius
                if radius is None
                else check_count(radius, "radius")
            )
            q_budget = 0
        qid = self._next_qid
        self._next_qid += 1
        cfg = self.resilience
        if (
            cfg is not None
            and cfg.max_queue is not None
            and len(self._queue) >= cfg.max_queue
        ):
            self.queries_rejected += 1
            self._shed.append(
                QueryResult(
                    qid=qid,
                    seed=int(seed),
                    nodes=np.empty(0, np.int64),
                    density=float("nan"),
                    seed_in_set=False,
                    n_ego=0,
                    m_ego=0,
                    bucket=(0, 0, 0),
                    latency_s=0.0,
                    status="rejected",
                    error=f"queue full (max_queue={cfg.max_queue})",
                    attempts=0,
                )
            )
            return qid
        self._queue.append(
            _Pending(
                qid=qid, seed=seed, radius=q_radius, budget=q_budget,
                submitted_at=self._time(),
            )
        )
        return qid

    def pending(self) -> int:
        return len(self._queue)

    def batch_due(self, now: Optional[float] = None) -> bool:
        """The flush condition: a full batch is waiting, or the OLDEST
        query has aged past the ``max_wait_ms`` deadline (the latency
        bound a queued query is guaranteed under a live pump)."""
        if not self._queue:
            return False
        if len(self._queue) >= self.max_batch:
            return True
        now = self._time() if now is None else now
        return (now - self._queue[0].submitted_at) * 1000.0 >= self.max_wait_ms

    def _drain_shed(self) -> List[QueryResult]:
        out, self._shed = self._shed, []
        return out

    def step(self, now: Optional[float] = None) -> List[QueryResult]:
        """Flushes ONE batch if due (at most ``max_batch`` queries, FIFO);
        returns its results (plus any shed ``rejected`` results), or []
        when nothing is due yet."""
        if not self.batch_due(now):
            return self._drain_shed()
        take = min(self.max_batch, len(self._queue))
        out = self._drain_shed()
        out.extend(self._process([self._queue.popleft() for _ in range(take)]))
        return out

    def flush(self) -> List[QueryResult]:
        """Drains the whole queue now, deadline or not, in FIFO batches of
        ``max_batch``."""
        out: List[QueryResult] = self._drain_shed()
        while self._queue:
            take = min(self.max_batch, len(self._queue))
            out.extend(
                self._process([self._queue.popleft() for _ in range(take)])
            )
        return out

    def query(
        self,
        seed: int,
        radius: Optional[int] = None,
        *,
        budget: Optional[int] = None,
    ) -> QueryResult:
        """One synchronous query (submit + flush)."""
        qid = self.submit(seed, radius, budget=budget)
        for res in self.flush():
            if res.qid == qid:
                return res
        raise RuntimeError(f"query {qid} lost in flush")  # pragma: no cover

    def query_many(
        self,
        seeds: Sequence[int],
        radius: Optional[int] = None,
        *,
        budget: Optional[int] = None,
    ) -> List[QueryResult]:
        """Answers many seeds through the batched path; results in seed
        order."""
        qids = [self.submit(s, radius, budget=budget) for s in seeds]
        by_qid = {r.qid: r for r in self.flush()}
        return [by_qid[q] for q in qids]

    # -- the batched solve --------------------------------------------------
    @staticmethod
    def _members(nodes: np.ndarray, alive_row: np.ndarray) -> np.ndarray:
        """Original-id members of one lane's best set (pad nodes dropped)."""
        local = np.nonzero(alive_row)[0]
        local = local[local < len(nodes)]  # drop isolated pad nodes
        return nodes[local]

    @staticmethod
    def _seed_in(member_nodes: np.ndarray, seed: int) -> bool:
        pos = np.searchsorted(member_nodes, seed)
        return bool(pos < len(member_nodes) and member_nodes[pos] == seed)

    def _solve_group(
        self,
        gkey: Tuple[int, int],
        stacked: EdgeList,
        oldest_submitted_at: float,
    ):
        """Solves one stacked bucket group under the resilience policy:
        breaker gate, bounded retry with deterministic backoff, deadline
        cut-off.  Returns ``(result_or_None, error_or_None, attempts)`` —
        it never raises, so a failed group can only poison its own lanes."""
        cfg = self.resilience
        breaker = self._breaker
        if breaker is not None and not breaker.allow(gkey):
            self.breaker_open_skips += 1
            return None, f"CircuitOpen: breaker open for bucket {gkey}", 0
        max_retries = cfg.max_retries if cfg is not None else 0
        attempts = 0
        while True:
            attempts += 1
            try:
                faults.fire("serve.solve", key=gkey)
                res = self.solver.solve_batch(stacked, self.problem)
            except Exception as e:  # noqa: BLE001 — isolate, degrade, report
                err = f"{type(e).__name__}: {e}"
                if breaker is not None:
                    breaker.record_failure(gkey)
                retry = attempts  # 1-based number of the NEXT retry
                if retry > max_retries:
                    return None, err, attempts
                if cfg is not None and cfg.deadline_ms is not None:
                    # The first attempt always ran; further retries are
                    # granted only while the group's oldest query still
                    # has deadline budget.
                    waited_ms = (self._time() - oldest_submitted_at) * 1000.0
                    if waited_ms >= cfg.deadline_ms:
                        self.deadline_stops += 1
                        return None, err, attempts
                self.solve_retries += 1
                if cfg is not None:
                    delay = cfg.backoff_s(retry, key=gkey)
                    if delay > 0:
                        self._sleep(delay)
                continue
            if breaker is not None:
                breaker.record_success(gkey)
            return res, None, attempts

    def _extract_pending(self, q: _Pending) -> Tuple[EdgeList, np.ndarray]:
        if self.extraction == "local":
            return self.extract(q.seed, budget=q.budget)
        return self.extract(q.seed, q.radius)

    def _shrink_rungs(self, q: _Pending) -> List[Tuple[str, int]]:
        """The shrink ladder for one query: decreasing radii (BFS mode) or
        halving budgets down to the floor (local mode)."""
        if self.extraction == "local":
            rungs = []
            b = q.budget // 2
            while b >= _LOCAL_BUDGET_FLOOR:
                rungs.append(("budget", b))
                b //= 2
            return rungs
        return [("radius", r) for r in range(q.radius - 1, 0, -1)]

    def _shrink_fallback(
        self, q: _Pending, err: str, attempts: int
    ) -> Optional[QueryResult]:
        """The first degrade rung: re-extract a SMALLER subgraph —
        shrinking radius under BFS extraction, halving budget (down to the
        LOCAL_BUDGET_FLOOR) under local extraction — and solve each as a
        single (unbatched) program.  Real data or None."""
        for kind, v in self._shrink_rungs(q):
            try:
                if kind == "budget":
                    padded, nodes = self.extract(q.seed, budget=v)
                else:
                    padded, nodes = self.extract(q.seed, v)
                faults.fire("serve.solve", key=("fallback", q.qid, v))
                res = self.solver.solve(padded, self.problem)
            except Exception:  # noqa: BLE001 — try the next rung down
                attempts += 1
                continue
            attempts += 1
            member_nodes = self._members(nodes, np.asarray(res.best_alive))
            return QueryResult(
                qid=q.qid,
                seed=q.seed,
                nodes=member_nodes,
                density=float(np.asarray(res.best_density)),
                seed_in_set=self._seed_in(member_nodes, q.seed),
                n_ego=int(len(nodes)),
                m_ego=int(np.asarray(padded.mask).sum()),
                bucket=(int(padded.n_nodes), int(padded.n_edges_padded), 1),
                latency_s=float(self._time() - q.submitted_at),
                status="degraded",
                fallback=f"{kind}:{v}",
                error=err,
                attempts=attempts,
            )
        return None

    def _fallback(
        self,
        q: _Pending,
        n_ego: int,
        m_ego: int,
        bucket: Tuple[int, int, int],
        err: str,
        attempts: int,
    ) -> QueryResult:
        """The degradation ladder for one poisoned lane: smaller-radius
        ego-net -> cached turnstile density -> last-good cached answer ->
        explicit failure.  Every rung returns REAL data; nothing is ever
        fabricated (docs/resilience.md)."""
        cfg = self.resilience
        if cfg is not None:
            can_shrink = (
                q.budget > _LOCAL_BUDGET_FLOOR
                if self.extraction == "local"
                else q.radius > 1
            )
            if cfg.degrade_radius and can_shrink:
                res = self._shrink_fallback(q, err, attempts)
                if res is not None:
                    self.queries_degraded += 1
                    return res
            if cfg.degrade_turnstile and self._turnstile is not None:
                try:
                    rho = float(self._turnstile.density())
                except Exception:  # noqa: BLE001 — rung down
                    pass
                else:
                    self.queries_degraded += 1
                    return QueryResult(
                        qid=q.qid,
                        seed=q.seed,
                        nodes=np.empty(0, np.int64),
                        density=rho,
                        seed_in_set=False,
                        n_ego=n_ego,
                        m_ego=m_ego,
                        bucket=bucket,
                        latency_s=float(self._time() - q.submitted_at),
                        status="degraded",
                        fallback="turnstile_density",
                        error=err,
                        attempts=attempts,
                    )
            if cfg.degrade_last_good:
                prev = self._last_good.get(q.seed)
                if prev is not None:
                    self.queries_degraded += 1
                    return dataclasses.replace(
                        prev,
                        qid=q.qid,
                        latency_s=float(self._time() - q.submitted_at),
                        status="degraded",
                        fallback="last_good",
                        error=err,
                        attempts=attempts,
                    )
        self.queries_failed += 1
        return QueryResult(
            qid=q.qid,
            seed=q.seed,
            nodes=np.empty(0, np.int64),
            density=float("nan"),
            seed_in_set=False,
            n_ego=n_ego,
            m_ego=m_ego,
            bucket=bucket,
            latency_s=float(self._time() - q.submitted_at),
            status="failed",
            error=err,
            attempts=attempts,
        )

    def _process(self, batch: List[_Pending]) -> List[QueryResult]:
        """Extract + coalesce + solve one batch: same-bucket queries become
        lanes of ONE vmapped solve_batch program per (node, edge) bucket.

        Group isolation (the resilience contract, held with OR without a
        ResilienceConfig): a bucket group whose solve fails poisons only
        its own lanes — each gets a deterministic per-lane outcome through
        the degradation ladder — while sibling groups answer normally."""
        groups: Dict[Tuple[int, int], List[Tuple[_Pending, EdgeList, np.ndarray]]]
        groups = {}
        for q in batch:
            padded, nodes = self._extract_pending(q)
            key = (padded.n_nodes, padded.n_edges_padded)
            groups.setdefault(key, []).append((q, padded, nodes))
        results: List[QueryResult] = []
        cfg = self.resilience
        keep_last_good = cfg is not None and cfg.degrade_last_good
        for (n_b, m_b), items in groups.items():
            lanes = pow2_bucket(len(items))
            # One stacked (lanes, m_b) buffer per leaf, built HOST-side:
            # the whole bucket group crosses to the device as a single
            # transfer per leaf instead of one per lane.
            src_s = np.zeros((lanes, m_b), np.int32)
            dst_s = np.zeros((lanes, m_b), np.int32)
            w_s = np.zeros((lanes, m_b), np.float32)
            msk_s = np.zeros((lanes, m_b), bool)
            for j, (_, g, _) in enumerate(items):
                src_s[j] = g.src
                dst_s[j] = g.dst
                w_s[j] = g.weight
                msk_s[j] = g.mask
            stacked = EdgeList(
                src=src_s, dst=dst_s, weight=w_s, mask=msk_s,
                n_nodes=int(n_b),
            )
            res, err, attempts = self._solve_group(
                (int(n_b), int(m_b)),
                stacked,
                min(q.submitted_at for q, _, _ in items),
            )
            if res is None:
                bucket = (int(n_b), int(m_b), int(lanes))
                for q, padded, nodes in items:
                    results.append(
                        self._fallback(
                            q,
                            int(len(nodes)),
                            int(np.asarray(padded.mask).sum()),
                            bucket,
                            err,
                            attempts,
                        )
                    )
                continue
            best_alive = np.asarray(res.best_alive)
            best_rho = np.asarray(res.best_density)
            done_at = self._time()
            self.lanes_solved += lanes
            self.pad_lanes += lanes - len(items)
            self.bucket_histogram[(n_b, m_b)] = (
                self.bucket_histogram.get((n_b, m_b), 0) + lanes
            )
            for j, (q, padded, nodes) in enumerate(items):
                member_nodes = self._members(nodes, best_alive[j])
                result = QueryResult(
                    qid=q.qid,
                    seed=q.seed,
                    nodes=member_nodes,
                    density=float(best_rho[j]),
                    seed_in_set=self._seed_in(member_nodes, q.seed),
                    n_ego=int(len(nodes)),
                    m_ego=int(np.asarray(padded.mask).sum()),
                    bucket=(int(n_b), int(m_b), int(lanes)),
                    latency_s=float(done_at - q.submitted_at),
                    attempts=attempts,
                )
                if keep_last_good:
                    self._last_good[q.seed] = result
                results.append(result)
        self.queries_answered += len(batch)
        self.batches_flushed += 1
        results.sort(key=lambda r: r.qid)
        return results

    # -- observability -------------------------------------------------------
    def stats(self) -> Dict[str, object]:
        """Engine counters in one dict (resilience outcomes included)."""
        return {
            "queries_answered": self.queries_answered,
            "batches_flushed": self.batches_flushed,
            "lanes_solved": self.lanes_solved,
            "pad_lanes": self.pad_lanes,
            "queries_rejected": self.queries_rejected,
            "queries_degraded": self.queries_degraded,
            "queries_failed": self.queries_failed,
            "solve_retries": self.solve_retries,
            "local_nodes_touched": self.local_nodes_touched,
            "local_edges_scanned": self.local_edges_scanned,
            "breaker_open_skips": self.breaker_open_skips,
            "deadline_stops": self.deadline_stops,
            "breaker_opened": (
                self._breaker.opened if self._breaker is not None else 0
            ),
        }
