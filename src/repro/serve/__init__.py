from repro.serve.engine import ServeEngine, Request
from repro.serve.densest import DensestQueryEngine, QueryResult
from repro.serve.resilience import CircuitBreaker, ResilienceConfig
from repro.serve.turnstile import TurnstileDensityService
