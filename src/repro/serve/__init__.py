from repro.serve.engine import ServeEngine, Request
from repro.serve.densest import DensestQueryEngine, QueryResult
from repro.serve.turnstile import TurnstileDensityService
