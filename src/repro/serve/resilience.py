"""Deadline / retry / degrade policy layer for the serving runtime.

The throughput half of serving (micro-batching, persistent compile cache)
landed in PRs 6–7; this module is the FAILURE half.  It deliberately
contains no solving code — just the policy objects
:class:`repro.serve.densest.DensestQueryEngine` consults on its solve
path:

  * :class:`ResilienceConfig` — per-query deadline budgets, a bounded
    retry schedule with exponential backoff and DETERMINISTIC jitter
    (seeded via :func:`repro.faults.deterministic_uniform`, so a replayed
    fault storm replays its exact timing), circuit-breaker and
    load-shedding knobs, and the graceful-degradation ladder toggles
    (smaller-radius ego-net → cached turnstile density → last-good
    cached answer);
  * :class:`CircuitBreaker` — a per-bucket consecutive-failure breaker
    with a cooldown half-open probe, clock-injectable for tests.

The degradation contract (docs/resilience.md): a degraded answer is
always REAL data — a genuinely solved smaller ego-net, a genuinely
computed whole-graph density, or a previously verified answer — flagged
``degraded=True`` with ``fallback`` naming its provenance.  Nothing is
ever fabricated; when the ladder is exhausted the query returns
``status='failed'`` with the real error attached.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

from repro.faults import deterministic_uniform

__all__ = ["CircuitBreaker", "ResilienceConfig"]

# QueryResult.status values (serve/densest.py attaches them).
STATUS_OK = "ok"
STATUS_DEGRADED = "degraded"
STATUS_REJECTED = "rejected"
STATUS_FAILED = "failed"


@dataclasses.dataclass(frozen=True)
class ResilienceConfig:
    """Per-engine resilience policy.

    ``deadline_ms`` is the per-query latency budget measured from
    ``submit()``: the FIRST solve attempt always runs (an answer beats a
    breach by microseconds), but retries are granted only while the
    group's oldest query still has budget; past it, failure goes straight
    to the degradation ladder.  ``max_retries`` bounds re-solves of a
    failed bucket group; retry ``i`` waits
    ``backoff_base_ms * backoff_mult**(i-1)`` scaled by a deterministic
    jitter in ``[1 - backoff_jitter, 1)``.  ``breaker_threshold``
    consecutive failures of one bucket open its circuit for
    ``breaker_cooldown_s`` (then one half-open probe).  ``max_queue``
    bounds the admission queue — the excess is shed at submit time with
    an explicit ``rejected`` outcome instead of unbounded queueing.
    """

    deadline_ms: Optional[float] = None
    max_retries: int = 2
    backoff_base_ms: float = 1.0
    backoff_mult: float = 2.0
    backoff_jitter: float = 0.5
    jitter_seed: int = 0
    breaker_threshold: int = 5
    breaker_cooldown_s: float = 30.0
    max_queue: Optional[int] = None
    degrade_radius: bool = True
    degrade_turnstile: bool = True
    degrade_last_good: bool = True

    def __post_init__(self):
        if self.deadline_ms is not None and self.deadline_ms <= 0:
            raise ValueError(f"deadline_ms={self.deadline_ms} must be > 0")
        if self.max_retries < 0:
            raise ValueError(f"max_retries={self.max_retries} must be >= 0")
        if self.backoff_base_ms < 0:
            raise ValueError(
                f"backoff_base_ms={self.backoff_base_ms} must be >= 0"
            )
        if self.backoff_mult < 1.0:
            raise ValueError(
                f"backoff_mult={self.backoff_mult} must be >= 1"
            )
        if not (0.0 <= self.backoff_jitter <= 1.0):
            raise ValueError(
                f"backoff_jitter={self.backoff_jitter} not in [0, 1]"
            )
        if self.breaker_threshold < 1:
            raise ValueError(
                f"breaker_threshold={self.breaker_threshold} must be >= 1"
            )
        if self.breaker_cooldown_s < 0:
            raise ValueError(
                f"breaker_cooldown_s={self.breaker_cooldown_s} must be >= 0"
            )
        if self.max_queue is not None and self.max_queue < 1:
            raise ValueError(f"max_queue={self.max_queue} must be >= 1")

    def backoff_s(self, retry: int, key: Any = None) -> float:
        """Seconds to wait before retry number ``retry`` (1-based) of the
        work item identified by ``key``.  Exponential in ``retry`` with a
        deterministic jitter: two processes with the same config and key
        back off identically (replayable chaos tests), while distinct
        keys decorrelate (no synchronized thundering-herd retries)."""
        if retry < 1:
            raise ValueError(f"retry={retry} must be >= 1 (1-based)")
        step = self.backoff_base_ms * self.backoff_mult ** (retry - 1)
        u = deterministic_uniform(self.jitter_seed, key, retry)
        return step * (1.0 - self.backoff_jitter * u) / 1000.0


class CircuitBreaker:
    """Per-key consecutive-failure circuit breaker.

    ``record_failure`` increments a key's consecutive-failure count and
    opens the circuit (stamps the cooldown clock) at ``threshold``;
    ``record_success`` resets it.  ``allow`` answers "may this key
    attempt real work right now?" — True while closed, False while open,
    and True again once the cooldown elapses (the half-open probe; a
    probe failure re-opens with a fresh cooldown).  Keys are independent:
    one poisoned bucket shape cannot trip the whole engine.
    """

    def __init__(
        self,
        threshold: int,
        cooldown_s: float,
        time_fn: Callable[[], float] = time.monotonic,
    ):
        if threshold < 1:
            raise ValueError(f"threshold={threshold} must be >= 1")
        if cooldown_s < 0:
            raise ValueError(f"cooldown_s={cooldown_s} must be >= 0")
        self.threshold = int(threshold)
        self.cooldown_s = float(cooldown_s)
        self._time = time_fn
        self._consecutive: Dict[Any, int] = {}
        self._opened_at: Dict[Any, float] = {}
        self.opened = 0  # times any key's circuit opened (incl. re-opens)

    def state(self, key: Any) -> str:
        if self._consecutive.get(key, 0) < self.threshold:
            return "closed"
        if self._time() - self._opened_at[key] >= self.cooldown_s:
            return "half_open"
        return "open"

    def allow(self, key: Any) -> bool:
        return self.state(key) != "open"

    def record_success(self, key: Any) -> None:
        self._consecutive.pop(key, None)
        self._opened_at.pop(key, None)

    def record_failure(self, key: Any) -> None:
        n = self._consecutive.get(key, 0) + 1
        self._consecutive[key] = n
        if n >= self.threshold:
            # Opening (or re-opening after a failed half-open probe)
            # restarts the cooldown window.
            self._opened_at[key] = self._time()
            self.opened += 1
