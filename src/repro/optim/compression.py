"""Gradient compression for cross-pod reduction (distributed-optimization
trick from DESIGN.md §4):

  * bf16 cast (2x) — loss-free in practice for all-reduce;
  * int8 block quantization with ERROR FEEDBACK (residual carried to the next
    step, 1-bit-Adam style) — 4x wire bytes.

Used by the shard_map data-parallel trainer and the pipeline's pod-boundary
gradient sync; unit-tested for the error-feedback contract (compression error
does not accumulate over steps).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    kind: str = "none"  # none | bf16 | int8_ef
    block: int = 256


def init_error_state(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_decompress_psum(
    grads: Any,
    err: Optional[Any],
    cfg: CompressionConfig,
    axis_name: Optional[str] = None,
) -> Tuple[Any, Optional[Any], float]:
    """Compresses grads, (optionally) psums over ``axis_name`` inside
    shard_map, decompresses; returns (grads, new_err, wire_bytes_factor)."""

    def maybe_psum(x):
        return jax.lax.psum(x, axis_name) if axis_name is not None else x

    if cfg.kind == "none":
        return jax.tree.map(maybe_psum, grads), err, 1.0

    if cfg.kind == "bf16":
        out = jax.tree.map(
            lambda g: maybe_psum(g.astype(jnp.bfloat16)).astype(jnp.float32), grads
        )
        return out, err, 0.5

    if cfg.kind == "int8_ef":
        assert err is not None

        def one(g, e):
            g = g.astype(jnp.float32) + e  # error feedback
            flat = g.reshape(-1)
            pad = (-flat.size) % cfg.block
            flat_p = jnp.pad(flat, (0, pad)).reshape(-1, cfg.block)
            scale = jnp.max(jnp.abs(flat_p), axis=1) / 127.0
            # Shared per-block scale across shards (one tiny pmax collective)
            # so the int8 payloads can be summed exactly in int32.
            if axis_name is not None:
                scale = jax.lax.pmax(scale, axis_name)
            scale = jnp.maximum(scale, 1e-12)
            q = jnp.clip(jnp.round(flat_p / scale[:, None]), -127, 127)
            deq_local = q * scale[:, None]
            new_e = (flat_p - deq_local).reshape(-1)[: flat.size].reshape(g.shape)
            # Wire payload: int8 (summed in int32 on the reduction tree).
            q_sum = maybe_psum(q.astype(jnp.int32)).astype(jnp.float32)
            out = (q_sum * scale[:, None]).reshape(-1)[: flat.size].reshape(g.shape)
            return out, new_e

        flat, tdef = jax.tree.flatten(grads)
        flat_e = tdef.flatten_up_to(err)
        outs = [one(g, e) for g, e in zip(flat, flat_e)]
        return (
            tdef.unflatten([o[0] for o in outs]),
            tdef.unflatten([o[1] for o in outs]),
            0.26,
        )

    raise ValueError(cfg.kind)
