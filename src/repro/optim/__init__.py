from repro.optim.adamw import (
    AdamWConfig,
    AdamWState,
    abstract_state,
    apply_updates,
    global_norm,
    init_state,
)
from repro.optim.compression import (
    CompressionConfig,
    compress_decompress_psum,
    init_error_state,
)
from repro.optim.schedules import constant, warmup_cosine

__all__ = [
    "AdamWConfig",
    "AdamWState",
    "CompressionConfig",
    "abstract_state",
    "apply_updates",
    "compress_decompress_psum",
    "constant",
    "global_norm",
    "init_error_state",
    "init_state",
    "warmup_cosine",
]
