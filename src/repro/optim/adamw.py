"""Hand-rolled AdamW (no optax offline) with production features:

  * decoupled weight decay, bias-corrected moments, global-norm clipping;
  * configurable moment dtype: fp32 | bf16 | int8 block-quantized
    (8-bit-Adam style, arXiv:2110.02861) — the int8 path is what lets the
    400B-param llama4 cell fit 16 GB/chip optimizer state (DESIGN.md §4);
  * moments inherit the parameter sharding (ZeRO via the fsdp axis).
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    moment_dtype: str = "fp32"  # fp32 | bf16 | int8
    block: int = 256  # int8 quantization block size


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Quantized:
    q: Any  # int8 payload (flattened, padded to block multiple)
    scale: Any  # f32 per-block absmax scales
    # Original shape must stay STATIC metadata: it is a reshape target under
    # jit (a NamedTuple would turn the ints into tracers at jit boundaries).
    shape: Tuple[int, ...] = dataclasses.field(metadata=dict(static=True))


def _quantize(x: jax.Array, block: int) -> Quantized:
    shape = x.shape
    flat = x.reshape(-1)
    pad = (-flat.size) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1) / 127.0
    q = jnp.round(blocks / jnp.maximum(scale[:, None], 1e-12)).astype(jnp.int8)
    return Quantized(q=q, scale=scale, shape=shape)


def _dequantize(z: Quantized) -> jax.Array:
    flat = (z.q.astype(jnp.float32) * z.scale[:, None]).reshape(-1)
    n = 1
    for s in z.shape:
        n *= s
    return flat[:n].reshape(z.shape)


def _encode_moment(x: jax.Array, cfg: AdamWConfig, nonneg: bool = False):
    if cfg.moment_dtype == "fp32":
        return x
    if cfg.moment_dtype == "bf16":
        return x.astype(jnp.bfloat16)
    if cfg.moment_dtype == "int8":
        # Second moments span many decades near 0; linear absmax int8 there
        # zeroes small nu and blows up 1/sqrt(nu) (8-bit-Adam uses nonlinear
        # quantization for the same reason).  sqrt-domain quantization keeps
        # the RELATIVE error of sqrt(nu) bounded by absmax/127.
        return _quantize(jnp.sqrt(jnp.maximum(x, 0.0)) if nonneg else x, cfg.block)
    raise ValueError(cfg.moment_dtype)


def _decode_moment(x, cfg: AdamWConfig, nonneg: bool = False) -> jax.Array:
    if isinstance(x, Quantized):
        y = _dequantize(x)
        return jnp.square(y) if nonneg else y
    return x.astype(jnp.float32)


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any  # pytree matching params (possibly Quantized leaves)
    nu: Any


def init_state(params, cfg: AdamWConfig) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    enc = lambda t: jax.tree.map(lambda x: _encode_moment(x, cfg), t)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=enc(zeros), nu=enc(zeros))


def abstract_state(abstract_params, cfg: AdamWConfig) -> AdamWState:
    return jax.eval_shape(lambda p: init_state(p, cfg), abstract_params)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def apply_updates(
    params, grads, state: AdamWState, cfg: AdamWConfig
):
    """Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    if cfg.clip_norm is not None:
        factor = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * factor, grads)

    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    is_q = lambda x: isinstance(x, Quantized)

    def upd(p, g, mu_e, nu_e):
        g = g.astype(jnp.float32)
        mu = _decode_moment(mu_e, cfg)
        nu = _decode_moment(nu_e, cfg, nonneg=True)
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(g)
        mu_hat = mu / b1c
        nu_hat = nu / b2c
        delta = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps)
        p32 = p.astype(jnp.float32)
        p32 = p32 - cfg.lr * (delta + cfg.weight_decay * p32)
        return (
            p32.astype(p.dtype),
            _encode_moment(mu, cfg),
            _encode_moment(nu, cfg, nonneg=True),
        )

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_mu = jax.tree.flatten(state.mu, is_leaf=is_q)[0]
    flat_nu = jax.tree.flatten(state.nu, is_leaf=is_q)[0]
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_mu = tdef.unflatten([o[1] for o in out])
    new_nu = tdef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, mu=new_mu, nu=new_nu), {"grad_norm": gnorm}
