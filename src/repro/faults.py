"""Seeded, deterministic fault injection for the resilience runtime.

Every failure-prone site in the stack calls :func:`fire` with a stable
site name (and, where it matters, a per-item key): streaming chunk
workers and checkpoint save/load (``core/streaming.py``), persistent
program-cache load/store (``core/progcache.py``), spill publish
(``graph/edgelist.py``), IBLT decode (``core/turnstile.py``) and the
serving engine's solve dispatch (``serve/densest.py``).  With no plan
installed the hook is a module-global ``None`` check — zero cost, no
behavioral change, bit-identical outputs (the equivalence assertions in
``tests/test_resilience.py`` hold this).

With a :class:`FaultPlan` installed, each ``fire`` consults the plan's
rules and may inject latency (a real sleep, exercising straggler and
deadline paths) and/or raise :class:`InjectedFault` — deterministically:

  * ``fail_nth`` fails specific 1-based hit indices of a ``(site, key)``
    pair, so "chunk 3's first attempt AND its retry fail" is one rule;
  * ``fail_prob`` fails each hit with probability ``p`` under a counter
    PRNG keyed on ``(plan seed, site, key, hit index)`` — the same plan
    seed reproduces the same fault storm bit for bit, in any process;
  * ``latency_s`` sleeps before the (possible) failure; ``latency_nth``
    restricts the sleep to specific hits (default: every matching hit).

The plan records per-site/per-key hit and failure counters, so chaos
tests assert exact retry budgets instead of monkeypatching internals.

Sites (the fault-site table in docs/resilience.md):

=========================== ===================== =========================
site                        key                   effect of a failure
=========================== ===================== =========================
``streaming.chunk``         chunk index           chunk-worker retry path
``streaming.checkpoint_save``                     checkpoint write fails
``streaming.checkpoint_load``                     quarantine + fresh start
``progcache.load``          entry path            fail-open recompile
``progcache.store``         entry path            best-effort store skipped
``edgelist.spill_publish``                        spill abort, rung dropped
``turnstile.decode``        level                 escalate a level sparser
``serve.solve``             bucket / fallback tag retry -> degrade chain
=========================== ===================== =========================
"""

from __future__ import annotations

import dataclasses
import hashlib
import threading
import time
from contextlib import contextmanager
from typing import Any, Callable, Dict, Optional, Tuple

__all__ = [
    "KNOWN_SITES",
    "FaultPlan",
    "FaultRule",
    "InjectedFault",
    "active",
    "deterministic_uniform",
    "fire",
    "install",
    "installed",
    "uninstall",
]

# The fault-site registry: every ``faults.fire(site, ...)`` call in
# ``src/repro`` must name a site listed here, with a documented row in the
# docs/resilience.md table — enforced statically by the ``fault-sites``
# analysis rule (scripts/analyze.py), which parses this tuple rather than
# importing the module.  Adding a site = add it here, document it, thread
# the hook.  (FaultPlan rules stay permissive at runtime so tests can
# exercise the plan machinery with toy site names.)
KNOWN_SITES = (
    "streaming.chunk",
    "streaming.checkpoint_save",
    "streaming.checkpoint_load",
    "progcache.load",
    "progcache.store",
    "edgelist.spill_publish",
    "turnstile.decode",
    "serve.solve",
)


class InjectedFault(RuntimeError):
    """The error :func:`fire` raises at a scheduled failure.  A plain
    ``RuntimeError`` subclass so every real error-handling path (retry,
    fail-open, escalation, degradation) treats it like a genuine fault."""

    def __init__(self, site: str, key: Any, hit: int):
        super().__init__(
            f"injected fault at site={site!r} key={key!r} hit={hit}"
        )
        self.site = site
        self.key = key
        self.hit = hit


def deterministic_uniform(*parts: Any) -> float:
    """A uniform float in [0, 1) that is a pure function of ``parts``
    (hashed via their ``repr``): the counter PRNG behind ``fail_prob``
    schedules and the resilience layer's deterministic backoff jitter.
    Stable across processes and platforms (no ``hash()`` randomization)."""
    digest = hashlib.blake2b(
        "\x1f".join(repr(p) for p in parts).encode(), digest_size=8
    ).digest()
    return int.from_bytes(digest, "big") / float(1 << 64)


@dataclasses.dataclass(frozen=True)
class FaultRule:
    """One failure schedule for one site.

    ``key=None`` matches every key fired at the site; a non-None ``key``
    matches only that key.  Hit indices are 1-based and counted per
    ``(site, key)`` pair (a keyed rule therefore counts each item's own
    attempts — attempt, speculative duplicate, retry — separately from
    its siblings').
    """

    site: str
    key: Any = None
    fail_nth: Tuple[int, ...] = ()
    fail_prob: float = 0.0
    max_fails: Optional[int] = None  # cap on fail_prob-triggered failures
    latency_s: float = 0.0
    latency_nth: Tuple[int, ...] = ()  # empty: latency on every hit

    def __post_init__(self):
        if not (0.0 <= self.fail_prob <= 1.0):
            raise ValueError(f"fail_prob={self.fail_prob} not in [0, 1]")
        if self.latency_s < 0:
            raise ValueError(f"latency_s={self.latency_s} must be >= 0")
        if self.max_fails is not None and self.max_fails < 0:
            raise ValueError(f"max_fails={self.max_fails} must be >= 0")

    def matches(self, key: Any) -> bool:
        return self.key is None or self.key == key


class FaultPlan:
    """A seeded set of :class:`FaultRule` schedules plus hit/failure
    accounting.  Build with the fluent helpers::

        plan = (FaultPlan(seed=7)
                .fail_nth("streaming.chunk", 1, 2, key=3)
                .fail_prob("serve.solve", 0.2)
                .latency("streaming.chunk", 0.5, nth=(1,), key=5))
        with faults.active(plan):
            ...

    Counters (all per plan, thread-safe): ``hits_at(site, key)`` /
    ``failures_at(site, key)`` aggregate over keys when ``key`` is left
    at its ``...`` sentinel.  ``sleep_fn`` is injectable so latency
    rules are testable without real sleeping.
    """

    def __init__(
        self,
        seed: int = 0,
        *,
        sleep_fn: Callable[[float], None] = time.sleep,
    ):
        self.seed = int(seed)
        self.rules: list[FaultRule] = []
        self._sleep = sleep_fn
        self._lock = threading.Lock()
        self._hits: Dict[Tuple[str, Any], int] = {}
        self._failures: Dict[Tuple[str, Any], int] = {}
        self._prob_fails: Dict[int, int] = {}  # rule index -> fails so far

    # -- fluent rule builders ------------------------------------------------
    def add(self, rule: FaultRule) -> "FaultPlan":
        self.rules.append(rule)
        return self

    def fail_nth(self, site: str, *nth: int, key: Any = None) -> "FaultPlan":
        return self.add(FaultRule(site=site, key=key, fail_nth=tuple(nth)))

    def fail_prob(
        self,
        site: str,
        p: float,
        *,
        key: Any = None,
        max_fails: Optional[int] = None,
    ) -> "FaultPlan":
        return self.add(
            FaultRule(site=site, key=key, fail_prob=p, max_fails=max_fails)
        )

    def latency(
        self,
        site: str,
        seconds: float,
        *,
        key: Any = None,
        nth: Tuple[int, ...] = (),
    ) -> "FaultPlan":
        return self.add(
            FaultRule(
                site=site, key=key, latency_s=seconds, latency_nth=tuple(nth)
            )
        )

    # -- accounting ----------------------------------------------------------
    def hits_at(self, site: str, key: Any = ...) -> int:
        with self._lock:
            if key is ...:
                return sum(
                    n for (s, _), n in self._hits.items() if s == site
                )
            return self._hits.get((site, key), 0)

    def failures_at(self, site: str, key: Any = ...) -> int:
        with self._lock:
            if key is ...:
                return sum(
                    n for (s, _), n in self._failures.items() if s == site
                )
            return self._failures.get((site, key), 0)

    # -- the hook ------------------------------------------------------------
    def fire(self, site: str, key: Any = None) -> None:
        with self._lock:
            hit = self._hits.get((site, key), 0) + 1
            self._hits[(site, key)] = hit
            delay = 0.0
            fail = False
            for i, rule in enumerate(self.rules):
                if rule.site != site or not rule.matches(key):
                    continue
                if rule.latency_s > 0 and (
                    not rule.latency_nth or hit in rule.latency_nth
                ):
                    delay = max(delay, rule.latency_s)
                if hit in rule.fail_nth:
                    fail = True
                elif rule.fail_prob > 0:
                    budget_ok = (
                        rule.max_fails is None
                        or self._prob_fails.get(i, 0) < rule.max_fails
                    )
                    if budget_ok and (
                        deterministic_uniform(self.seed, site, key, hit)
                        < rule.fail_prob
                    ):
                        self._prob_fails[i] = self._prob_fails.get(i, 0) + 1
                        fail = True
            if fail:
                self._failures[(site, key)] = (
                    self._failures.get((site, key), 0) + 1
                )
        # Sleep OUTSIDE the lock: concurrent sites (chunk workers) must not
        # serialize on an injected straggler.
        if delay > 0:
            self._sleep(delay)
        if fail:
            raise InjectedFault(site, key, hit)


# -- module-level installation ----------------------------------------------

_ACTIVE: Optional[FaultPlan] = None


def install(plan: FaultPlan) -> FaultPlan:
    """Installs ``plan`` as the process-wide active plan (replacing any
    previous one) and returns it."""
    global _ACTIVE
    if not isinstance(plan, FaultPlan):
        raise TypeError(f"install expects a FaultPlan, got {type(plan).__name__}")
    _ACTIVE = plan
    return plan


def uninstall() -> None:
    """Removes the active plan; every ``fire`` is a no-op again."""
    global _ACTIVE
    _ACTIVE = None


def installed() -> Optional[FaultPlan]:
    return _ACTIVE


@contextmanager
def active(plan: FaultPlan):
    """Context manager: install ``plan`` for the block, restore the
    previous plan (usually None) on exit — exception or not."""
    global _ACTIVE
    prev = _ACTIVE
    install(plan)
    try:
        yield plan
    finally:
        _ACTIVE = prev


def fire(site: str, key: Any = None) -> None:
    """The injection hook instrumented sites call.  No plan installed —
    the common production case — is one global read and a ``None`` check;
    the site's behavior and outputs are untouched."""
    plan = _ACTIVE
    if plan is None:
        return
    plan.fire(site, key)
