import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Perf-iteration driver: compile one cell (with overrides), print the three
roofline terms AND the top collectives/fusions by executed bytes — the
evidence each hypothesis -> change -> measure cycle reads.

  python -m repro.launch.hillclimb --arch qwen2-72b --shape train_4k \
      [--overrides '{"rules": {"embed": null}}'] [--top 12]
"""

import argparse
import json
import re
from collections import defaultdict


def top_ops(hlo: str, n_devices: int, top: int = 12):
    """(opcode, size) aggregated with while-loop trip multipliers."""
    from repro.launch import hlo_stats

    comps = hlo_stats.parse_computations(hlo)
    entry = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            m = hlo_stats._COMP_RE.match(line.strip())
            if m:
                entry = m.group(1)
    # multiplier per computation via DFS from entry
    mult = defaultdict(float)
    mult[entry] = 1.0
    order = [entry]
    seen = {entry}
    while order:
        comp = order.pop(0)
        for ins in comps.get(comp, []):
            if ins.opcode == "while":
                m = hlo_stats._COND_BODY_RE.search(ins.line)
                if not m:
                    continue
                trip = hlo_stats._trip_count(comps.get(m.group(1), []), comps) or 1
                for sub in (m.group(1), m.group(2)):
                    mult[sub] += mult[comp] * trip
                    if sub not in seen:
                        seen.add(sub)
                        order.append(sub)
            else:
                m = hlo_stats._TO_APPLY_RE.search(ins.line) or hlo_stats._CALLS_RE.search(ins.line)
                if m and ins.opcode in ("call", "fusion"):
                    sub = m.group(1)
                    mult[sub] += mult[comp]
                    if sub not in seen:
                        seen.add(sub)
                        order.append(sub)
    rows = []
    for comp, instrs in comps.items():
        k = mult.get(comp, 0.0)
        if k <= 0:
            continue
        sizes = {i.name: i.result_bytes for i in instrs}
        for ins in instrs:
            if ins.opcode in hlo_stats._COLLECTIVES:
                b = hlo_stats._collective_bytes(ins, sizes, n_devices) * k
                meta = re.search(r'op_name="([^"]+)"', ins.line)
                rows.append(
                    (b, ins.opcode, f"x{k:.0f}", ins.result_bytes,
                     (meta.group(1)[-90:] if meta else ins.name))
                )
    rows.sort(reverse=True)
    return rows[:top]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--overrides", type=str, default=None)
    ap.add_argument("--variant", default="probe")
    ap.add_argument("--top", type=int, default=12)
    ap.add_argument("--save", default=None, help="also persist json under this variant")
    args = ap.parse_args()

    overrides = json.loads(args.overrides) if args.overrides else None
    from repro.launch import hlo_stats, roofline
    from repro.launch.cells import build_cell, lower_cell

    cell = build_cell(
        args.arch, args.shape, multi_pod=args.multi_pod, overrides=overrides
    )
    compiled = lower_cell(cell).compile()
    hlo = compiled.as_text()
    stats = hlo_stats.analyze(hlo, cell.info["n_devices"])
    rl = roofline.from_stats(
        args.arch, args.shape, cell.info["mesh"], cell.info["n_devices"], stats,
        model_flops=float(cell.info.get("flops_model", 0)),
    )
    try:
        mem = compiled.memory_analysis()
        temp = mem.temp_size_in_bytes / 2**30
        arg = mem.argument_size_in_bytes / 2**30
    except Exception:
        temp = arg = float("nan")
    print(
        f"terms_s compute={rl.compute_s:.3f} memory={rl.memory_s:.3f} "
        f"collective={rl.collective_s:.3f} bound={rl.bound} "
        f"6ND/HLO={rl.model_flops_ratio:.3f} frac={rl.roofline_fraction:.2%}"
    )
    print(f"mem/dev GiB: args={arg:.2f} temp={temp:.2f}")
    print("by_collective GB/dev:", {k: round(v / 1e9, 1) for k, v in stats["by_collective"].items()})
    print("top collectives (executed GB/dev):")
    for b, op, k, rb, name in top_ops(hlo, cell.info["n_devices"], args.top):
        print(f"  {b/1e9:9.2f} GB {op:20s} {k:>5} blk={rb/2**20:8.1f}MiB  {name}")
    if args.save:
        from repro.launch.dryrun import run_cell

        run_cell(args.arch, args.shape, args.multi_pod, overrides=overrides,
                 variant=args.save)


if __name__ == "__main__":
    main()
