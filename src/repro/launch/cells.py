"""Builds one dry-run cell: (jit-able step fn, abstract sharded inputs) for
an (architecture x input-shape x mesh) combination.

Everything is ShapeDtypeStruct-based — no device allocation; ``.lower()`` +
``.compile()`` on the result is the multi-pod dry-run.  The same builder
drives the roofline analyzer and the perf variants (``overrides``).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Mapping, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchSpec, ShapeSpec
from repro.configs.registry import get_arch
from repro.data.synthetic import abstract_batch
from repro.launch import tables
from repro.launch.mesh import all_axes, make_production_mesh
from repro.optim import AdamWConfig, abstract_state
from repro.optim.adamw import Quantized
from repro.sharding.rules import (
    AxisRules,
    pspecs_for_params,
    sharding_ctx,
)
from repro.train.step import (
    make_loss_fn,
    make_lm_prefill,
    make_recsys_retrieval,
    make_recsys_serve,
    make_train_step,
    specialize_gnn_config,
)

# Per-arch optimizer-state dtype: int8 block-quantized Adam moments are what
# let the 774B-param llama4 cell approach 16 GB/chip (8-bit-Adam, DESIGN §4).
_MOMENT_DTYPE = {
    "llama4-maverick-400b-a17b": "int8",
    "qwen2-72b": "fp32",
}


class Cell(NamedTuple):
    arch_id: str
    shape_name: str
    spec: ArchSpec
    shape: ShapeSpec
    mesh: Mesh
    rules: AxisRules
    fn: Callable
    args: Tuple[Any, ...]
    # Static metadata for the roofline report.
    info: Dict[str, Any]


class SkipCell(Exception):
    pass


def _named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def _sanitize_pspec(shape, spec: P, mesh: Mesh) -> P:
    """Drops sharding from dims the mesh axes don't divide evenly: explicit
    INPUT shardings must tile exactly (GSPMD pads only intermediates).
    Tiny GNN weights like (1433, 64) or heads (128, 7) fall back toward
    replication dim by dim."""
    out = []
    for i, ax in enumerate(tuple(spec) + (None,) * (len(shape) - len(tuple(spec)))):
        if ax is None:
            out.append(None)
            continue
        axs = (ax,) if isinstance(ax, str) else tuple(ax)
        total = 1
        for a in axs:
            total *= mesh.shape[a]
        if shape[i] % total == 0:
            out.append(ax)
        else:
            # try a prefix of the axes (e.g. ('pod','data') -> ('pod',))
            kept = []
            run = 1
            for a in axs:
                if shape[i] % (run * mesh.shape[a]) == 0:
                    kept.append(a)
                    run *= mesh.shape[a]
                else:
                    break
            out.append(tuple(kept) if kept else None)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def _attach(sds_tree, pspec_tree, mesh: Mesh):
    """Rebuild ShapeDtypeStructs with (divisibility-sanitized) shardings."""
    return jax.tree.map(
        lambda s, p: jax.ShapeDtypeStruct(
            s.shape, s.dtype,
            sharding=_named(mesh, _sanitize_pspec(s.shape, p, mesh)),
        ),
        sds_tree,
        pspec_tree,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )


def _batch_pspecs(batch_sds, axes_map, rules: AxisRules):
    def one(key_path, leaf):
        key = key_path[-1].key if hasattr(key_path[-1], "key") else str(key_path[-1])
        axes = axes_map.get(key)
        if axes is None:
            return P()
        axes = tuple(axes)[: leaf.ndim] + (None,) * max(0, leaf.ndim - len(axes))
        return rules.pspec(axes)

    return jax.tree_util.tree_map_with_path(
        one, batch_sds, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct)
    )


def _opt_pspecs(opt_abstract, param_pspecs, mesh: Mesh, rules: AxisRules):
    """Moments inherit the parameter sharding; int8 Quantized payloads are
    rank-changed (blocked), so they shard dim0 over all axes when divisible."""
    flat_all = tuple(mesh.axis_names)
    n_dev = int(np.prod(list(mesh.shape.values())))
    flat_params_spec = jax.tree.leaves(
        param_pspecs, is_leaf=lambda x: isinstance(x, P)
    )

    def mirror(tree):
        leaves, tdef = jax.tree.flatten(
            tree, is_leaf=lambda x: isinstance(x, Quantized)
        )
        out = []
        for leaf, ps in zip(leaves, flat_params_spec):
            if isinstance(leaf, Quantized):
                nb = leaf.q.shape[0]
                sp = P(flat_all) if nb % n_dev == 0 else P()
                out.append(Quantized(q=sp, scale=sp, shape=leaf.shape))
            else:
                out.append(ps)
        return tdef.unflatten(out)

    from repro.optim.adamw import AdamWState

    return AdamWState(
        step=P(), mu=mirror(opt_abstract.mu), nu=mirror(opt_abstract.nu)
    )


def _attach_opt(opt_abstract, opt_pspecs, mesh):
    def go(sds, spec):
        if isinstance(sds, jax.ShapeDtypeStruct):
            return jax.ShapeDtypeStruct(
                sds.shape, sds.dtype,
                sharding=_named(mesh, _sanitize_pspec(sds.shape, spec, mesh)),
            )
        return sds

    return jax.tree.map(
        go, opt_abstract, opt_pspecs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )


# ---------------------------------------------------------------------------
# Family builders
# ---------------------------------------------------------------------------


def _abstract_params(spec: ArchSpec, cfg) -> Any:
    from repro.train.step import init_model_params

    return jax.eval_shape(
        lambda k: init_model_params(spec, k, cfg=cfg), jax.random.PRNGKey(0)
    )


def _lm_cell(spec, shape, mesh, rules, overrides) -> Tuple[Callable, Tuple, Dict]:
    cfg = dataclasses.replace(
        spec.config,
        attn_impl=overrides.get("attn_impl", "auto"),
        remat=overrides.get("remat", True),
        q_chunk=overrides.get("q_chunk", spec.config.q_chunk),
        kv_chunk=overrides.get("kv_chunk", spec.config.kv_chunk),
    )
    p = dict(shape.params)
    params = _abstract_params(spec, cfg)
    pspecs = pspecs_for_params(params, spec.param_rules, rules)
    params_sds = _attach(params, pspecs, mesh)
    axes_map = tables.input_axes(spec, shape)
    batch = abstract_batch(spec, shape)
    batch_sds = _attach(batch, _batch_pspecs(batch, axes_map, rules), mesh)
    info = {
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "tokens_per_step": p["global_batch"] * (p["seq_len"] if shape.kind == "train" else 1),
    }

    if shape.kind == "train":
        opt_cfg = AdamWConfig(
            moment_dtype=overrides.get(
                "moment_dtype", _MOMENT_DTYPE.get(spec.arch_id, "fp32")
            )
        )
        loss_fn = make_loss_fn(spec, shape.kind, cfg=cfg)
        step = make_train_step(loss_fn, opt_cfg)
        opt = abstract_state(params, opt_cfg)
        opt_sds = _attach_opt(opt, _opt_pspecs(opt, pspecs, mesh, rules), mesh)
        info["flops_model"] = 6 * cfg.active_param_count() * info["tokens_per_step"]
        return step, (params_sds, opt_sds, batch_sds), info

    if shape.kind == "prefill":
        step = make_lm_prefill(cfg)
        info["tokens_per_step"] = p["global_batch"] * p["seq_len"]
        info["flops_model"] = 2 * cfg.active_param_count() * info["tokens_per_step"]
        return step, (params_sds, batch_sds), info

    if shape.kind in ("decode", "decode_long"):
        from repro.models.transformer import cache_spec, decode_step

        b = p["global_batch"]
        spec_c = cache_spec(cfg, b, p["seq_len"])
        cache = spec_c.abstract()
        cache_pspec = {
            k: rules.pspec((None, "batch", "kv_seq", None, None))
            for k in cache
        }
        cache_sds = _attach(cache, cache_pspec, mesh)
        cur_len = jax.ShapeDtypeStruct((), jnp.int32, sharding=_named(mesh, P()))

        def step(params, cache, tokens, cur_len):
            logits, cache, cur_len = decode_step(params, cfg, cache, tokens, cur_len)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return {"next": nxt, "logits": logits}, cache, cur_len

        tokens = batch_sds["tokens"]
        info["flops_model"] = 2 * cfg.active_param_count() * b
        info["kv_cache_bytes"] = int(
            2 * np.prod([cfg.n_layers, b, spec_c.max_len, cfg.n_kv_heads, cfg.d_head])
            * 2
        )
        return step, (params_sds, cache_sds, tokens, cur_len), info

    raise ValueError(shape.kind)


def _gnn_flops_model(spec, cfg, shape) -> int:
    """Analytic 'useful' FLOPs: per-layer dense transforms x nodes (+edges),
    x3 for fwd+bwd.  Message passing adds O(E*d) adds counted at 2 flops."""
    p = dict(shape.params)
    d_h = cfg.d_hidden if hasattr(cfg, "d_hidden") else 128
    if shape.kind == "sampled_train" and spec.arch_id == "graphsage-reddit":
        r = p["batch_nodes"]
        f1, f2 = p["fanout"]
        n_eff = r * (1 + f1 + f1 * f2)
        e_eff = r * f1 + r * f1 * f2
    elif shape.kind == "molecule_train":
        n_eff = p["batch"] * p["n_nodes"]
        e_eff = p["batch"] * p["n_edges"]
    else:
        n_eff = p["n_nodes"]
        e_eff = p["n_edges"]
    d_in = p.get("d_feat", d_h)
    n_layers = getattr(cfg, "n_layers", 2)
    per_node = 2 * (d_in * d_h + (n_layers - 1) * d_h * d_h + d_h * d_h)
    per_edge = 2 * d_h * n_layers
    return 3 * (n_eff * per_node + e_eff * per_edge)


def _gnn_cell(spec, shape, mesh, rules, overrides):
    cfg = specialize_gnn_config(spec.config, dict(shape.params))
    params = _abstract_params(spec, cfg)
    pspecs = pspecs_for_params(params, spec.param_rules, rules)
    params_sds = _attach(params, pspecs, mesh)
    batch = abstract_batch(spec, shape)
    axes_map = tables.input_axes(spec, shape)
    batch_sds = _attach(batch, _batch_pspecs(batch, axes_map, rules), mesh)
    opt_cfg = AdamWConfig(moment_dtype=overrides.get("moment_dtype", "fp32"))
    loss_fn = make_loss_fn(spec, shape.kind, cfg=cfg)
    step = make_train_step(loss_fn, opt_cfg)
    opt = abstract_state(params, opt_cfg)
    opt_sds = _attach_opt(opt, _opt_pspecs(opt, pspecs, mesh, rules), mesh)
    info = {
        "params": int(
            sum(np.prod(l.shape) for l in jax.tree.leaves(params))
        ),
        "flops_model": _gnn_flops_model(spec, cfg, shape),
    }
    return step, (params_sds, opt_sds, batch_sds), info


def _recsys_cell(spec, shape, mesh, rules, overrides):
    cfg = spec.config
    params = _abstract_params(spec, cfg)
    pspecs = pspecs_for_params(params, spec.param_rules, rules)
    params_sds = _attach(params, pspecs, mesh)
    batch = abstract_batch(spec, shape)
    axes_map = tables.input_axes(spec, shape)
    batch_sds = _attach(batch, _batch_pspecs(batch, axes_map, rules), mesh)
    p = dict(shape.params)
    d = cfg.embed_dim
    tower = 0
    din = 2 * d
    for t_d in cfg.tower_dims:
        tower += din * t_d
        din = t_d
    item_tower = 0
    din = d
    for t_d in cfg.tower_dims:
        item_tower += din * t_d
        din = t_d
    info = {
        "params": int(sum(np.prod(l.shape) for l in jax.tree.leaves(params))),
    }
    if shape.kind == "train":
        opt_cfg = AdamWConfig(moment_dtype=overrides.get("moment_dtype", "fp32"))
        loss_fn = make_loss_fn(spec, shape.kind, cfg=cfg)
        step = make_train_step(loss_fn, opt_cfg)
        opt = abstract_state(params, opt_cfg)
        opt_sds = _attach_opt(opt, _opt_pspecs(opt, pspecs, mesh, rules), mesh)
        b = p["batch"]
        info["flops_model"] = 3 * (
            b * 2 * (tower + item_tower) + 2 * b * b * cfg.tower_dims[-1]
        )
        return step, (params_sds, opt_sds, batch_sds), info
    if shape.kind == "serve":
        step = make_recsys_serve(cfg)
        b = p["batch"]
        info["flops_model"] = b * 2 * (tower + item_tower)
        return step, (params_sds, batch_sds), info
    if shape.kind == "retrieval":
        step = make_recsys_retrieval(cfg, k=overrides.get("topk", 100))
        nc = p["n_candidates"]
        info["flops_model"] = 2 * tower + nc * 2 * item_tower + 2 * nc * cfg.tower_dims[-1]
        return step, (params_sds, batch_sds), info
    raise ValueError(shape.kind)


def _densest_cell(spec, shape, mesh, rules, overrides):
    from repro.core.mapreduce import (
        make_distributed_peel,
        make_distributed_sketched_peel,
    )

    p = dict(shape.params)
    n, m = p["n_nodes"], p["n_edges"]
    eps = overrides.get("eps", spec.config.eps)
    max_passes = overrides.get("max_passes", spec.config.max_passes)
    edge_axes = all_axes(mesh)
    n_shards = int(np.prod([mesh.shape[a] for a in edge_axes]))
    m_pad = ((m + n_shards - 1) // n_shards) * n_shards
    espec = rules.pspec(("edges",))
    batch = {
        "src": jax.ShapeDtypeStruct((m_pad,), jnp.int32, sharding=_named(mesh, espec)),
        "dst": jax.ShapeDtypeStruct((m_pad,), jnp.int32, sharding=_named(mesh, espec)),
        "weight": jax.ShapeDtypeStruct((m_pad,), jnp.float32, sharding=_named(mesh, espec)),
        "mask": jax.ShapeDtypeStruct((m_pad,), jnp.bool_, sharding=_named(mesh, espec)),
    }
    # Every branch below is the same PeelEngine loop (core/engine.py) under
    # shard_map; the override picks the policy / degree backend combination.
    policy = overrides.get("policy", "undirected")
    wants_sketch = shape.kind == "peel_sketched" or overrides.get("use_sketch")
    if policy != "undirected" and wants_sketch:
        raise ValueError(
            f"policy={policy!r} has no distributed Count-Sketch builder yet; "
            "drop the sketch config or use policy='undirected'"
        )
    if policy == "topk":
        from repro.core.mapreduce import make_distributed_topk_peel

        fn = make_distributed_topk_peel(
            mesh, edge_axes, k=int(overrides.get("k", 2)), eps=eps,
            max_passes=max_passes, n_nodes=n,
        )
    elif policy == "directed":
        from repro.core.mapreduce import make_distributed_directed_peel

        dfn = make_distributed_directed_peel(
            mesh, edge_axes, eps=eps, max_passes=max_passes, n_nodes=n
        )
        c = float(overrides.get("c", 1.0))
        fn = lambda src, dst, weight, mask: dfn(src, dst, weight, mask, c)
    elif shape.kind == "peel_sketched" or overrides.get("use_sketch"):
        fn = make_distributed_sketched_peel(
            mesh, edge_axes, eps=eps, max_passes=max_passes, n_nodes=n,
            t=p.get("t", overrides.get("t", 5)),
            b=p.get("b", overrides.get("b", 1 << 17)),
        )
    elif overrides.get("twophase"):
        from repro.core.mapreduce import make_distributed_peel_twophase

        fn = make_distributed_peel_twophase(
            mesh, edge_axes, eps=eps, max_passes=max_passes, n_nodes=n,
            phase1_passes=int(overrides["twophase"]),
            wire_dtype=overrides.get("wire_dtype", "f32"),
        )
    else:
        fn = make_distributed_peel(
            mesh, edge_axes, eps=eps, max_passes=max_passes, n_nodes=n,
            wire_dtype=overrides.get("wire_dtype", "f32"),
        )

    def step(src, dst, weight, mask):
        return fn(src, dst, weight, mask)

    # Per-pass useful work: one weighted degree count (2 adds per endpoint
    # per edge) + threshold scan; expected passes ~ log_{1+eps} n.
    import math

    exp_passes = min(max_passes, math.ceil(math.log(max(n, 2)) / math.log1p(eps)))
    info = {
        "params": 0,
        "flops_model": exp_passes * (4 * m + 4 * n),
        "expected_passes": exp_passes,
    }
    return step, (batch["src"], batch["dst"], batch["weight"], batch["mask"]), info


_FAMILY_BUILDERS = {
    "lm": _lm_cell,
    "gnn": _gnn_cell,
    "recsys": _recsys_cell,
    "densest": _densest_cell,
}


def build_cell(
    arch_id: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    overrides: Optional[Mapping[str, Any]] = None,
    mesh: Optional[Mesh] = None,
) -> Cell:
    overrides = dict(overrides or {})
    spec = get_arch(arch_id)
    shape = spec.shape(shape_name)
    if shape.skip_reason is not None and not overrides.get("force", False):
        raise SkipCell(shape.skip_reason)
    if mesh is None:
        mesh = make_production_mesh(multi_pod=multi_pod)
    rules = tables.rules_for(
        spec, shape, multi_pod, extra=overrides.get("rules")
    )
    fn, args, info = _FAMILY_BUILDERS[spec.family](
        spec, shape, mesh, rules, overrides
    )
    info.update(
        mesh="x".join(f"{k}={v}" for k, v in mesh.shape.items()),
        n_devices=int(np.prod(list(mesh.shape.values()))),
        family=spec.family,
        kind=shape.kind,
    )
    return Cell(
        arch_id=arch_id, shape_name=shape_name, spec=spec, shape=shape,
        mesh=mesh, rules=rules, fn=fn, args=args, info=info,
    )


def lower_cell(cell: Cell):
    """Traces + lowers the cell under the ambient sharding context."""
    with sharding_ctx(cell.mesh, cell.rules):
        return jax.jit(cell.fn).lower(*cell.args)
