"""Production mesh construction.

`make_production_mesh` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state; the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first jax
init, smoke tests see the 1 real CPU device.
"""

from __future__ import annotations

from typing import Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips across DCI."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices but only {len(devices)} present; "
            "run under dryrun.py (which forces 512 host devices)"
        )
    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_mesh(shape: Sequence[int], axes: Sequence[str]) -> Mesh:
    """Arbitrary mesh over the first prod(shape) devices (tests, examples)."""
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(f"need {n} devices, have {len(devices)}")
    return Mesh(np.asarray(devices[:n]).reshape(tuple(shape)), tuple(axes))


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    """The pure-DP axes of a production mesh ('pod'+'data' when present)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def all_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(mesh.axis_names)
