"""Assembles EXPERIMENTS.md tables from experiments/dryrun/*.json.

``python -m repro.launch.report`` prints the §Dry-run and §Roofline markdown
tables (single-pod roofline, multi-pod compile proof) and a sorted summary
used to pick the hillclimb cells.
"""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

from repro.ioutil import atomic_write_file


def load(out_dir: str = "experiments/dryrun", variant: str | None = "baseline") -> List[Dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if variant is not None and r.get("variant", "baseline") != variant:
            continue
        recs.append(r)
    return recs


def fmt_bytes(b) -> str:
    if b is None:
        return "-"
    return f"{b/2**30:.2f}"


def roofline_table(recs: List[Dict], mesh: str = "16x16") -> str:
    head = (
        "| arch | shape | compute_s | memory_s | collective_s | bound | "
        "HLO GFLOP/dev | coll GB/dev | mem GiB/dev | 6ND/HLO | roofline frac |\n"
        "|---|---|---|---|---|---|---|---|---|---|---|"
    )
    lines = [head]
    for r in recs:
        if r["mesh"] != mesh:
            continue
        if r["status"] == "skipped":
            lines.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | skipped | — | — | — | — | — |"
            )
            continue
        if r["status"] != "ok":
            lines.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | ERROR | — | — | — | — | — |"
            )
            continue
        rl = r["roofline"]
        mem = r.get("memory_analysis", {})
        peak = None
        if isinstance(mem.get("temp_size_in_bytes"), int):
            peak = (
                mem.get("temp_size_in_bytes", 0)
                + mem.get("argument_size_in_bytes", 0)
            )
        lines.append(
            "| {a} | {s} | {c:.3f} | {m:.3f} | {x:.3f} | {b} | {gf:.0f} | {cb:.2f} | {pk} | {ra:.2f} | {fr:.1%} |".format(
                a=r["arch"], s=r["shape"],
                c=rl["compute_s"], m=rl["memory_s"], x=rl["collective_s"],
                b=rl["bound"], gf=rl["flops_per_dev"] / 1e9,
                cb=rl["coll_bytes_per_dev"] / 1e9,
                pk=fmt_bytes(peak), ra=rl["model_flops_ratio"],
                fr=rl["roofline_fraction"],
            )
        )
    return "\n".join(lines)


def dryrun_table(recs: List[Dict]) -> str:
    head = (
        "| arch | shape | mesh | status | compile_s | args GiB/dev | temp GiB/dev | "
        "collectives (count) |\n|---|---|---|---|---|---|---|---|"
    )
    lines = [head]
    for r in recs:
        if r["status"] == "skipped":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | skipped ({r['skip_reason'][:40]}…) | — | — | — | — |"
            )
            continue
        mem = r.get("memory_analysis", {})
        hs = r.get("hlo_stats", {})
        lines.append(
            "| {a} | {s} | {m} | {st} | {cs} | {ag} | {tp} | {cc:.0f} |".format(
                a=r["arch"], s=r["shape"], m=r["mesh"], st=r["status"],
                cs=r.get("compile_s", "-"),
                ag=fmt_bytes(mem.get("argument_size_in_bytes")),
                tp=fmt_bytes(mem.get("temp_size_in_bytes")),
                cc=hs.get("collective_count", 0),
            )
        )
    return "\n".join(lines)


def pick_hillclimb(recs: List[Dict]) -> List[Dict]:
    ok = [r for r in recs if r["status"] == "ok" and r["mesh"] == "16x16"]
    by_frac = sorted(ok, key=lambda r: r["roofline"]["roofline_fraction"])
    by_coll = sorted(
        ok,
        key=lambda r: -(
            r["roofline"]["collective_s"]
            / max(r["roofline"]["step_time_s"], 1e-12)
        ),
    )
    return {
        "worst_fraction": [
            (r["arch"], r["shape"], f"{r['roofline']['roofline_fraction']:.2%}")
            for r in by_frac[:8]
        ],
        "most_collective_bound": [
            (r["arch"], r["shape"],
             f"coll/total={r['roofline']['collective_s']/max(r['roofline']['step_time_s'],1e-12):.2f}",
             f"frac={r['roofline']['roofline_fraction']:.2%}")
            for r in by_coll[:8]
        ],
    }


def generate() -> str:
    recs = load()
    variants = load(variant=None)
    named = [r for r in variants if r.get("variant", "baseline") != "baseline"]
    parts = [
        "### Dry-run: all cells x both meshes\n",
        dryrun_table(recs),
        "\n### Roofline terms (single-pod 16x16, current defaults)\n",
        roofline_table(recs, "16x16"),
    ]
    if named:
        parts.append("\n### Saved perf variants\n")
        parts.append(roofline_table(named, "16x16").replace(
            "| arch |", "| arch(variant) |"
        ))
        # annotate variant names
        lines = parts[-1].splitlines()
        out = lines[:2]
        vi = 0
        for r in named:
            if r["mesh"] != "16x16":
                continue
            row = lines[2 + vi]
            out.append(row.replace(
                f"| {r['arch']} |", f"| {r['arch']} ({r['variant']}) |", 1
            ))
            vi += 1
        parts[-1] = "\n".join(out)
    return "\n".join(parts)


def inject(path: str = "EXPERIMENTS.md"):
    begin, end = "<!-- GENERATED:BEGIN -->", "<!-- GENERATED:END -->"
    with open(path) as f:
        doc = f.read()
    pre = doc.split(begin)[0]
    post = doc.split(end)[1]
    body = pre + begin + "\n" + generate() + "\n" + end + post
    atomic_write_file(path, lambda f: f.write(body), mode="w")
    print(f"injected tables into {path}")


if __name__ == "__main__":
    import sys

    if "--inject" in sys.argv:
        inject()
    else:
        recs = load()
        print("## Dry-run (both meshes)\n")
        print(dryrun_table(recs))
        print("\n## Roofline (single-pod 16x16)\n")
        print(roofline_table(recs, "16x16"))
        print("\n## Hillclimb candidates\n")
        print(json.dumps(pick_hillclimb(recs), indent=1))
