"""Serving driver: ``python -m repro.launch.serve --arch <id> --requests N``.

Runs the continuous-batching engine (serve/engine.py) on a REDUCED config
with synthetic prompts, reporting per-phase latency stats — the CPU-scale
shadow of the decode_32k production cell.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from repro.configs import get_arch
    from repro.serve.engine import Request, ServeEngine
    from repro.train.step import init_model_params

    spec = get_arch(args.arch)
    assert spec.family == "lm", "serving driver is for the LM family"
    cfg = dataclasses.replace(spec.reduced_config, remat=False)
    params = init_model_params(spec, jax.random.PRNGKey(args.seed), cfg=cfg)
    rng = np.random.default_rng(args.seed)

    eng = ServeEngine(params, cfg, n_slots=args.slots, max_len=args.max_len)
    t0 = time.time()
    for i in range(args.requests):
        plen = int(rng.integers(4, 17))
        eng.submit(
            Request(
                rid=i,
                prompt=rng.integers(0, cfg.vocab, plen, dtype=np.int32),
                max_new=args.max_new,
            )
        )
    done = eng.run_to_completion()
    wall = time.time() - t0
    toks = sum(len(r.tokens) for r in done)
    print(
        json.dumps(
            {
                "arch": args.arch,
                "requests": len(done),
                "generated_tokens": toks,
                "wall_s": round(wall, 2),
                "tok_per_s": round(toks / wall, 1),
            }
        )
    )
    return done


if __name__ == "__main__":
    main()
