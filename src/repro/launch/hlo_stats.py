"""Static analyzer for compiled (SPMD-partitioned) HLO text.

Extracts the three roofline inputs per device:

  * ``flops``            — 2*M*N*K over every dot (+ cheap elementwise est.),
  * ``hbm_bytes``        — sum of operand+result bytes at fusion boundaries
                           (the XLA fusion boundary IS the HBM round-trip),
  * ``collective_bytes`` — ring-model bytes per device for all-reduce /
                           all-gather / reduce-scatter / all-to-all /
                           collective-permute,

with call-graph rollup: ``while`` bodies are multiplied by their trip count
(recovered from the loop condition's comparison constant — this is what
``compiled.cost_analysis()`` gets wrong: it visits loop bodies once, so an
80-layer scan under-reports FLOPs by 80x).

The HLO text shapes are PER-DEVICE (post-partitioning), so all outputs are
per-device quantities.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.-]+)\s*=\s*(.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.-]+)\s*\(.*\)\s*->\s*.*\{\s*$")
_OPERAND_RE = re.compile(r"%([\w.-]+)")
_GROUPS_RE = re.compile(r"replica_groups=\{(\{[^}]*\})")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w.-]+)")
_COND_BODY_RE = re.compile(r"condition=%?([\w.-]+),\s*body=%?([\w.-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CALLS_RE = re.compile(r"calls=%?([\w.-]+)")
_DIMS_RE = {
    "lhs_contracting": re.compile(r"lhs_contracting_dims=\{([\d,]*)\}"),
    "lhs_batch": re.compile(r"lhs_batch_dims=\{([\d,]*)\}"),
}

# Opcodes that are pure plumbing — no FLOPs, no HBM traffic of their own.
# 'copy' is included: nearly all copies in partitioned loop bodies are
# carried-buffer pass-throughs that XLA's buffer assignment elides (counting
# them inflated loop-body traffic by ~100x in measurement).
_PLUMBING = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "custom-call", "copy",
}
_CONTROL = {"while", "conditional", "call"}
_COLLECTIVES = {
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "all-reduce-start", "all-gather-start",
    "collective-permute-start",
}


def _type_bytes_and_dims(type_str: str) -> Tuple[int, List[Tuple[str, List[int]]]]:
    """Total bytes + per-component (dtype, dims) of a (possibly tuple) type."""
    comps = []
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims_s = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        dims = [int(x) for x in dims_s.split(",") if x] if dims_s else []
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
        comps.append((dt, dims))
    return total, comps


def _split_type_rest(rhs: str) -> Tuple[str, str, str]:
    """rhs = '<type> <opcode>(<operands>), attrs' -> (type, opcode, rest)."""
    rhs = rhs.strip()
    if rhs.startswith("("):  # tuple type: match balanced parens
        depth = 0
        for i, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    type_str = rhs[: i + 1]
                    rest = rhs[i + 1 :].strip()
                    break
        else:
            return rhs, "", ""
    else:
        sp = rhs.find(" ")
        if sp < 0:
            return rhs, "", ""
        type_str = rhs[:sp]
        rest = rhs[sp + 1 :].strip()
    op_m = re.match(r"([\w-]+)", rest)
    opcode = op_m.group(1) if op_m else ""
    return type_str, opcode, rest


@dataclasses.dataclass
class Instr:
    name: str
    opcode: str
    result_bytes: int
    result_dims: List[Tuple[str, List[int]]]
    operands: List[str]
    line: str


@dataclasses.dataclass
class Totals:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_count: float = 0.0
    by_collective: Dict[str, float] = dataclasses.field(default_factory=dict)
    unknown_trip_loops: int = 0

    def scaled(self, k: float) -> "Totals":
        return Totals(
            self.flops * k, self.hbm_bytes * k, self.collective_bytes * k,
            self.collective_count * k,
            {n: v * k for n, v in self.by_collective.items()},
            self.unknown_trip_loops,
        )

    def add(self, o: "Totals"):
        self.flops += o.flops
        self.hbm_bytes += o.hbm_bytes
        self.collective_bytes += o.collective_bytes
        self.collective_count += o.collective_count
        for n, v in o.by_collective.items():
            self.by_collective[n] = self.by_collective.get(n, 0.0) + v
        self.unknown_trip_loops += o.unknown_trip_loops


def parse_computations(hlo: str) -> Dict[str, List[Instr]]:
    comps: Dict[str, List[Instr]] = {}
    cur: Optional[str] = None
    for line in hlo.splitlines():
        if cur is None:
            m = _COMP_RE.match(line.strip())
            if m and "->" in line:
                cur = m.group(1)
                comps[cur] = []
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        type_str, opcode, rest = _split_type_rest(rhs)
        rb, rdims = _type_bytes_and_dims(type_str)
        # Operand names: inside the first (...) after the opcode.
        paren = rest.find("(")
        operands: List[str] = []
        if paren >= 0:
            depth, j = 0, paren
            for j in range(paren, len(rest)):
                if rest[j] == "(":
                    depth += 1
                elif rest[j] == ")":
                    depth -= 1
                    if depth == 0:
                        break
            operands = _OPERAND_RE.findall(rest[paren : j + 1])
        comps[cur].append(Instr(name, opcode, rb, rdims, operands, rest))
    return comps


def _group_size(line: str, n_devices: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        ids = [x for x in m.group(1).strip("{}").split(",") if x.strip()]
        return max(1, len(ids))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return max(1, int(m.group(2)))
    return n_devices


def _collective_bytes(instr: Instr, sizes: Dict[str, int], n_devices: int) -> float:
    g = _group_size(instr.line, n_devices)
    if g <= 1:
        return 0.0
    op = instr.opcode.replace("-start", "")
    in_bytes = sum(sizes.get(o, 0) for o in instr.operands)
    out_bytes = instr.result_bytes
    # XLA:CPU promotes bf16 reductions to f32 ("..._promoted" computations);
    # on TPU the wire dtype stays bf16 — count the real (half) bytes.
    if "_promoted" in instr.line:
        in_bytes //= 2
        out_bytes //= 2
    if op == "all-reduce":
        return 2.0 * in_bytes * (g - 1) / g
    if op == "all-gather":
        return out_bytes * (g - 1) / g
    if op == "reduce-scatter":
        return in_bytes * (g - 1) / g
    if op == "all-to-all":
        return in_bytes * (g - 1) / g
    if op == "collective-permute":
        return float(out_bytes)
    return 0.0


def _dot_flops(instr: Instr, comps_sizes: Dict[str, List[Tuple[str, List[int]]]]) -> float:
    """2 x (result elements) x (contracted elements)."""
    res_elems = 1
    for _, dims in instr.result_dims:
        for d in dims:
            res_elems *= d
    m = _DIMS_RE["lhs_contracting"].search(instr.line)
    contract = 1
    if m and instr.operands:
        lhs_dims_list = comps_sizes.get(instr.operands[0])
        idxs = [int(x) for x in m.group(1).split(",") if x]
        if lhs_dims_list:
            _, lhs_dims = lhs_dims_list[0]
            for i in idxs:
                if i < len(lhs_dims):
                    contract *= lhs_dims[i]
    return 2.0 * res_elems * contract


def _trip_count(
    cond_instrs: List[Instr], comps: Optional[Dict[str, List[Instr]]] = None
) -> Optional[int]:
    """Scan-style loops compare the induction var against a constant.  Data-
    dependent loops (the peel's 'alive nonempty AND t < max') keep the
    constant inside a fused compare — search called fusions too and treat the
    bound as the (upper-bound) trip count."""
    instrs = list(cond_instrs)
    if comps is not None:
        for ins in cond_instrs:
            if ins.opcode in ("fusion", "call"):
                m = _TO_APPLY_RE.search(ins.line) or _CALLS_RE.search(ins.line)
                if m and m.group(1) in comps:
                    instrs.extend(comps[m.group(1)])
    consts: Dict[str, int] = {}
    for ins in instrs:
        if ins.opcode == "constant":
            m = re.search(r"constant\((-?\d+)\)", ins.line)
            if m:
                consts[ins.name] = int(m.group(1))
    for ins in instrs:
        if ins.opcode == "compare" and ("direction=LT" in ins.line or "direction=GT" in ins.line):
            for o in ins.operands:
                if o in consts and consts[o] > 0:
                    return consts[o]
    # Fallback: any positive constant in the condition.
    pos = [v for v in consts.values() if v > 0]
    return max(pos) if pos else None


def analyze(
    hlo: str,
    n_devices: int,
    default_trip: int = 1,
    trip_override: Optional[int] = None,
) -> Dict[str, float]:
    """Full-program per-device totals (entry computation rollup)."""
    comps = parse_computations(hlo)
    entry = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_RE.match(line.strip())
            if m:
                entry = m.group(1)
                break
    if entry is None:  # heuristics: biggest computation
        entry = max(comps, key=lambda c: len(comps[c]))

    memo: Dict[str, Totals] = {}

    def total_of(comp: str, stack=()) -> Totals:
        if comp in memo:
            return memo[comp]
        if comp in stack or comp not in comps:
            return Totals()
        t = Totals()
        instrs = comps[comp]
        sizes = {i.name: i.result_bytes for i in instrs}
        dims = {i.name: i.result_dims for i in instrs}
        for ins in instrs:
            op = ins.opcode
            if op in _PLUMBING:
                continue
            if op == "while":
                m = _COND_BODY_RE.search(ins.line)
                if m:
                    cond, body = m.group(1), m.group(2)
                    trip = trip_override or _trip_count(comps.get(cond, []), comps)
                    sub = total_of(body, stack + (comp,))
                    sub_c = total_of(cond, stack + (comp,))
                    if trip is None:
                        trip = default_trip
                        t.unknown_trip_loops += 1
                    t.add(sub.scaled(trip))
                    t.add(sub_c.scaled(trip))
                continue
            if op == "conditional":
                m = _BRANCHES_RE.search(ins.line)
                if m:
                    branches = _OPERAND_RE.findall(m.group(1)) or [
                        x.strip().lstrip("%") for x in m.group(1).split(",")
                    ]
                    subs = [total_of(b, stack + (comp,)) for b in branches]
                    if subs:
                        best = max(subs, key=lambda s: s.flops + s.hbm_bytes)
                        t.add(best)
                continue
            if op == "call":
                m = _TO_APPLY_RE.search(ins.line)
                if m:
                    t.add(total_of(m.group(1), stack + (comp,)))
                continue
            if op in _COLLECTIVES:
                b = _collective_bytes(ins, sizes, n_devices)
                t.collective_bytes += b
                t.collective_count += 1
                key = op.replace("-start", "")
                t.by_collective[key] = t.by_collective.get(key, 0.0) + b
                # Collectives also touch HBM on both ends.
                t.hbm_bytes += ins.result_bytes + sum(
                    sizes.get(o, 0) for o in ins.operands
                )
                continue
            if op.endswith("-done") or op.endswith("-update"):
                continue
            if op in ("gather", "dynamic-slice"):
                # Sparse read: traffic = result + indices, NOT the full table.
                idx_bytes = sum(sizes.get(o, 0) for o in ins.operands[1:])
                t.hbm_bytes += 2 * ins.result_bytes + idx_bytes
                continue
            if op in ("scatter", "dynamic-update-slice"):
                # In-place sparse write: updates read+write + indices.
                upd_bytes = sum(sizes.get(o, 0) for o in ins.operands[1:])
                t.hbm_bytes += 2 * upd_bytes
                t.flops += upd_bytes / 4.0  # scatter-add
                continue
            # Leaf compute op: traffic = operands + result.
            boundary = ins.result_bytes + sum(sizes.get(o, 0) for o in ins.operands)
            if op == "dot":
                t.flops += _dot_flops(ins, dims)
                t.hbm_bytes += boundary
            elif op == "fusion":
                m = _TO_APPLY_RE.search(ins.line) or _CALLS_RE.search(ins.line)
                sub = None
                if m:
                    sub = total_of(m.group(1), stack + (comp,))
                    t.flops += sub.flops  # dots inside fusions
                    t.collective_bytes += sub.collective_bytes
                    for n_, v in sub.by_collective.items():
                        t.by_collective[n_] = t.by_collective.get(n_, 0.0) + v
                # Fusion boundary = HBM traffic, EXCEPT operands that are only
                # gathered/scattered inside (embedding tables): those cost the
                # gathered bytes, not the table.
                called = comps.get(m.group(1)) if m else None
                if called is not None:
                    boundary = ins.result_bytes
                    called_sizes = {ci.name: ci.result_bytes for ci in called}
                    params = {}
                    for ci in called:
                        if ci.opcode == "parameter":
                            pm = re.search(r"parameter\((\d+)\)", ci.line)
                            if pm:
                                params[ci.name] = int(pm.group(1))
                    sparse_param_idx = set()
                    sparse_bytes = 0.0
                    for ci in called:
                        if ci.opcode in ("gather", "dynamic-slice", "scatter",
                                         "dynamic-update-slice") and ci.operands:
                            o0 = ci.operands[0]
                            if o0 in params:
                                sparse_param_idx.add(params[o0])
                                if ci.opcode in ("gather", "dynamic-slice"):
                                    sparse_bytes += 2 * ci.result_bytes
                                else:
                                    upd = sum(
                                        called_sizes.get(o, 0)
                                        for o in ci.operands[1:]
                                    )
                                    sparse_bytes += 2 * (upd or ci.result_bytes)
                    for oi, o in enumerate(ins.operands):
                        if oi in sparse_param_idx:
                            continue
                        boundary += sizes.get(o, 0)
                    boundary += sparse_bytes
                t.hbm_bytes += boundary
            elif op in ("reduce", "reduce-window", "select-and-scatter",
                        "sort", "map"):
                # elementwise-ish estimate: 1 flop per input element
                t.flops += sum(sizes.get(o, 0) for o in ins.operands) / 4.0
                t.hbm_bytes += boundary
            else:
                t.hbm_bytes += boundary
        memo[comp] = t
        return t

    tot = total_of(entry)
    return {
        "flops": tot.flops,
        "hbm_bytes": tot.hbm_bytes,
        "collective_bytes": tot.collective_bytes,
        "collective_count": tot.collective_count,
        "by_collective": dict(tot.by_collective),
        "unknown_trip_loops": tot.unknown_trip_loops,
        "n_computations": len(comps),
    }
