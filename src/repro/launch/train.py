"""Training driver: ``python -m repro.launch.train --arch <id> [--reduced]``.

On this CPU container it trains REDUCED configs end-to-end (the quickstart /
examples path); on real hardware the same driver runs full configs — the
mesh, sharding rules, checkpointing and data pipeline are identical code.

XLA flags for real-TPU runs (latency-hiding overlap of the collectives the
dry-run surfaces) are recorded in TPU_XLA_FLAGS below and applied via
--tpu-flags; they are no-ops on CPU.
"""

from __future__ import annotations

import argparse
import json
import os
from typing import Any, Dict

import jax

# Collective/compute overlap flags for real TPU runs (documented + applied
# when --tpu-flags is passed; harmless defaults for the CPU simulation).
TPU_XLA_FLAGS = " ".join(
    [
        "--xla_tpu_enable_async_collective_fusion=true",
        "--xla_tpu_enable_async_collective_fusion_fuse_all_gather=true",
        "--xla_tpu_overlap_compute_collective_tc=true",
        "--xla_enable_async_all_gather=true",
        "--xla_enable_async_collective_permute=true",
    ]
)


def build_data(spec, cfg, shape_kind: str, batch: int, seq: int, seed: int):
    from repro.data import pipeline as pl

    if spec.family == "lm":
        return pl.lm_token_stream(cfg.vocab, batch, seq, seed=seed)
    if spec.family == "recsys":
        return pl.recsys_stream(cfg, batch, seed=seed)
    if spec.family == "gnn":
        from repro.data.synthetic import make_batch
        from repro.data.pipeline import SyntheticStream

        shape = dict(n_nodes=256, n_edges=1024, d_feat=cfg.d_in, n_classes=max(cfg.n_classes, 2))

        def make(rng, step):
            return make_batch(spec, "full_train", reduced_shape=shape, seed=int(rng.integers(1 << 31)))

        return SyntheticStream(make, seed=seed)
    raise ValueError(spec.family)


def main(argv=None) -> Dict[str, Any]:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--grad-compression", choices=["none", "bf16", "int8"], default="none")
    ap.add_argument("--metrics", default=None)
    ap.add_argument("--tpu-flags", action="store_true")
    args = ap.parse_args(argv)

    if args.tpu_flags:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "") + " " + TPU_XLA_FLAGS
        )

    from repro.configs import get_arch
    from repro.optim import AdamWConfig, init_state, apply_updates
    from repro.optim.compression import (
        CompressionConfig,
        compress_decompress_psum,
        init_error_state,
    )
    from repro.train.step import (
        init_model_params,
        make_loss_fn,
        specialize_gnn_config,
    )
    from repro.train.trainer import Trainer, TrainerConfig

    spec = get_arch(args.arch)
    cfg = spec.reduced_config
    if spec.family == "gnn":
        cfg = specialize_gnn_config(
            cfg, dict(d_feat=getattr(cfg, "d_in", 16), n_classes=max(getattr(cfg, "n_classes", 2), 2))
        )

    opt_cfg = AdamWConfig(lr=args.lr, weight_decay=0.01)
    shape_kind = "train" if spec.family != "gnn" else "full_train"
    loss_fn = make_loss_fn(spec, shape_kind, cfg=cfg)

    comp_cfg = CompressionConfig(
        kind={"none": "none", "bf16": "bf16", "int8": "int8_ef"}[
            args.grad_compression
        ]
    )

    def step_fn_raw(state, batch):
        params, opt, err = state
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch=batch
        )
        # The cross-pod compressed all-reduce (axis_name=None on one device:
        # pure quantize/dequantize with error feedback, same numerics).
        grads, err, _ = compress_decompress_psum(grads, err, comp_cfg)
        params, opt, om = apply_updates(params, grads, opt, opt_cfg)
        return (params, opt, err), {**metrics, **om}

    step_fn = jax.jit(step_fn_raw)

    params = init_model_params(spec, jax.random.PRNGKey(args.seed), cfg=cfg)
    opt = init_state(params, opt_cfg)
    err = init_error_state(params) if comp_cfg.kind == "int8_ef" else None
    data = build_data(spec, cfg, shape_kind, args.batch, args.seq, args.seed)

    ckpt_dir = args.ckpt_dir or os.path.join("experiments", "train", args.arch)
    tcfg = TrainerConfig(
        total_steps=args.steps,
        ckpt_dir=ckpt_dir,
        ckpt_every=args.ckpt_every,
        metrics_path=args.metrics,
    )
    trainer = Trainer(tcfg, step_fn, (params, opt, err), data)
    if args.resume and trainer.try_restore():
        print(f"resumed from step {trainer.step}")
    out = trainer.run()
    first = trainer.metrics_log[0]["loss"] if trainer.metrics_log else float("nan")
    print(json.dumps({
        "arch": args.arch, "status": out["status"], "steps": out["step"],
        "first_loss": first, "final_loss": out.get("loss"),
        "wall_s": round(out.get("wall_s", 0), 1),
    }))
    return out


if __name__ == "__main__":
    main()
