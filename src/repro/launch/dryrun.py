import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape) cell
on the production mesh and record memory / cost / collective statistics.

The two lines above MUST stay the first statements in this module: jax locks
the device count on first init, and the 512 placeholder host devices are what
let ``jax.make_mesh`` build the (2, 16, 16) production mesh on one CPU.

Usage:
  python -m repro.launch.dryrun --arch qwen2-72b --shape train_4k
  python -m repro.launch.dryrun --arch qwen2-72b --shape train_4k --multi-pod
  python -m repro.launch.dryrun --all [--include-densest] [--out-dir experiments/dryrun]
  python -m repro.launch.dryrun --arch ... --shape ... --overrides '{"remat": false}'
"""

import argparse
import json
import sys
import time
import traceback


def run_cell(
    arch: str,
    shape: str,
    multi_pod: bool,
    overrides=None,
    out_dir: str = "experiments/dryrun",
    variant: str = "baseline",
):
    import jax  # noqa: F401  (device init must precede mesh construction)

    from repro.launch import hlo_stats, roofline
    from repro.launch.cells import SkipCell, build_cell, lower_cell

    t0 = time.time()
    rec = {
        "arch": arch, "shape": shape,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "variant": variant, "overrides": overrides or {},
    }
    try:
        cell = build_cell(arch, shape, multi_pod=multi_pod, overrides=overrides)
    except SkipCell as e:
        rec.update(status="skipped", skip_reason=str(e))
        return rec
    try:
        lowered = lower_cell(cell)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        try:
            mem = compiled.memory_analysis()
            mem_d = {
                k: int(getattr(mem, k))
                for k in (
                    "argument_size_in_bytes", "output_size_in_bytes",
                    "temp_size_in_bytes", "generated_code_size_in_bytes",
                    "alias_size_in_bytes",
                )
                if hasattr(mem, k)
            }
        except Exception as e:  # CPU backend may not implement everything
            mem_d = {"error": str(e)}
        try:
            cost = compiled.cost_analysis()
            cost_d = {k: float(v) for k, v in cost.items() if isinstance(v, (int, float))} if cost else {}
        except Exception as e:
            cost_d = {"error": str(e)}

        hlo = compiled.as_text()
        stats = hlo_stats.analyze(hlo, cell.info["n_devices"])
        peak_mem = None
        if isinstance(mem_d.get("temp_size_in_bytes"), int):
            peak_mem = mem_d.get("temp_size_in_bytes", 0) + mem_d.get(
                "argument_size_in_bytes", 0
            ) - mem_d.get("alias_size_in_bytes", 0) + mem_d.get("output_size_in_bytes", 0)
        rl = roofline.from_stats(
            arch, shape, rec["mesh"], cell.info["n_devices"], stats,
            model_flops=float(cell.info.get("flops_model", 0)),
            xla_cost=cost_d if "error" not in cost_d else None,
            peak_memory=peak_mem,
        )
        rec.update(
            status="ok",
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            memory_analysis=mem_d,
            cost_analysis={k: v for k, v in cost_d.items() if k in ("flops", "bytes accessed", "utilization operand 0 {}")},
            hlo_stats={
                k: v for k, v in stats.items() if k != "by_collective"
            },
            by_collective=stats.get("by_collective", {}),
            info=cell.info,
            roofline=rl.to_dict(),
            hlo_bytes=len(hlo),
        )
    except Exception as e:
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
    rec["wall_s"] = round(time.time() - t0, 1)

    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = f"{arch}__{shape}__{rec['mesh']}"
        if variant != "baseline":
            tag += f"__{variant}"
        path = os.path.join(out_dir, tag + ".json")
        from repro.ioutil import atomic_write_file

        atomic_write_file(
            path, lambda f: json.dump(rec, f, indent=1, default=str), mode="w"
        )
        rec["path"] = path
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--include-densest", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--overrides", type=str, default=None)
    ap.add_argument("--variant", type=str, default="baseline")
    ap.add_argument("--out-dir", type=str, default="experiments/dryrun")
    args = ap.parse_args()

    overrides = json.loads(args.overrides) if args.overrides else None

    from repro.configs.registry import assigned_cells

    if args.all:
        cells = assigned_cells(include_densest=args.include_densest)
    else:
        if not (args.arch and args.shape):
            ap.error("--arch/--shape or --all required")
        cells = [(args.arch, args.shape)]

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = 0
    for arch, shape in cells:
        for mp in meshes:
            rec = run_cell(
                arch, shape, mp, overrides=overrides, out_dir=args.out_dir,
                variant=args.variant,
            )
            status = rec["status"]
            line = f"[{status:>7}] {arch} x {shape} ({rec['mesh']}) {rec.get('wall_s', 0)}s"
            if status == "ok":
                rl = rec["roofline"]
                line += (
                    f"  bound={rl['bound']} c/m/x={rl['compute_s']*1e3:.1f}/"
                    f"{rl['memory_s']*1e3:.1f}/{rl['collective_s']*1e3:.1f}ms "
                    f"frac={rl['roofline_fraction']:.1%}"
                )
            elif status == "error":
                line += f"  {rec['error'][:200]}"
                failures += 1
            print(line, flush=True)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
