"""Logical-axis -> mesh-axis tables per (family, shape-kind, mesh flavor),
plus the per-input logical-axis declarations the dry-run uses to shard the
abstract batch.

The model code only ever names logical axes ("batch", "heads", "edges", ...);
everything mesh-specific lives here and in the per-arch ``rule_overrides``.
"""

from __future__ import annotations

from typing import Any, Dict, Mapping, Optional, Tuple

from repro.configs.base import ArchSpec, ShapeSpec
from repro.sharding.rules import AxisRules, MeshAxes


def _dp(multi_pod: bool) -> Tuple[str, ...]:
    return ("pod", "data") if multi_pod else ("data",)


def _flat(multi_pod: bool) -> Tuple[str, ...]:
    return ("pod", "data", "model") if multi_pod else ("data", "model")


def _lm_table(multi_pod: bool, kind: str) -> Dict[str, MeshAxes]:
    t: Dict[str, MeshAxes] = {
        "batch": _dp(multi_pod),
        "fsdp": ("data",),
        "tp": ("model",),
        "heads": ("model",),
        "kv_heads": ("model",),
        "heads4": ("model",),  # 4D [b,s,h,d] attention head sharding
        "mlp": ("model",),
        "vocab": ("model",),
        "embed": ("model",),  # residual stream feature-sharded (SP-style)
        "seq": None,  # sequence-parallel residual (perf variant)
        "expert": None,
        "kv_seq": None,
    }
    if kind in ("decode", "decode_long"):
        t["kv_seq"] = ("model",)
        t["embed"] = None  # tiny decode activations; avoid per-token reshards
    return t


def _gnn_table(multi_pod: bool, kind: str) -> Dict[str, MeshAxes]:
    t = {
        # Edges sharded over every mesh axis (the paper's MapReduce edge
        # partitioning); node state replicated for small graphs.
        "batch": _dp(multi_pod),
        "fsdp": ("data",),
        "tp": ("model",),
        "nodes": None,
        "edges": _flat(multi_pod),
        "table_rows": None,
    }
    if kind == "full_train":
        # Perf iteration (EXPERIMENTS.md §Perf, equiformer x ogb_products):
        # replicated node state costs O(N x width) f32 autodiff residuals
        # per layer (60 GB x 12 layers at ogb scale) and full-state psums;
        # sharding nodes over all axes turns those into AG/RS of 1/256
        # slices.  Inputs are padded to 512 (see gnn_full_batch_spec).
        t["nodes"] = _flat(multi_pod)
    return t


def _recsys_table(multi_pod: bool, kind: str) -> Dict[str, MeshAxes]:
    return {
        "batch": _dp(multi_pod),
        "fsdp": ("data",),
        "tp": ("model",),
        "vocab": ("model",),
        "cand": _flat(multi_pod),
    }


def _densest_table(multi_pod: bool, kind: str) -> Dict[str, MeshAxes]:
    return {"edges": _flat(multi_pod)}


_FAMILY_TABLES = {
    "lm": _lm_table,
    "gnn": _gnn_table,
    "recsys": _recsys_table,
    "densest": _densest_table,
}


def _podify(value: MeshAxes, multi_pod: bool, key: str = "") -> MeshAxes:
    """Arch overrides are written in single-pod axis names; on the multi-pod
    mesh any tuple using 'data' widens to ('pod', 'data', ...) — EXCEPT the
    'fsdp' axis: ZeRO weight gathers must stay on fast intra-pod ICI (grads
    reduce across pods once per step; weights gather per layer)."""
    if not multi_pod or value is None or isinstance(value, str):
        return value
    if key == "fsdp":
        return value
    if "data" in value and "pod" not in value:
        return ("pod",) + tuple(value)
    return value


def rules_for(
    spec: ArchSpec,
    shape: ShapeSpec,
    multi_pod: bool,
    extra: Optional[Mapping[str, MeshAxes]] = None,
) -> AxisRules:
    """Family defaults <- arch '*' overrides <- arch per-kind overrides <-
    explicit extra overrides (perf variants)."""
    table = dict(_FAMILY_TABLES[spec.family](multi_pod, shape.kind))
    for layer in (
        spec.rule_overrides.get("*", {}),
        spec.rule_overrides.get(shape.kind, {}),
        dict(extra or {}),
    ):
        for k, v in layer.items():
            table[k] = _podify(v, multi_pod, key=k)
    return AxisRules(table)


# ---------------------------------------------------------------------------
# Input logical axes: pytrees of per-dim logical names matching the abstract
# batch structure from data/synthetic.py.
# ---------------------------------------------------------------------------


def input_axes(spec: ArchSpec, shape: ShapeSpec) -> Dict[str, Any]:
    family, kind = spec.family, shape.kind
    if family == "lm":
        if kind == "train":
            return {"tokens": ("batch", None), "labels": ("batch", None)}
        if kind == "prefill":
            return {"tokens": ("batch", None)}
        if kind in ("decode", "decode_long"):
            return {"tokens": ("batch", None)}
        raise ValueError(kind)
    if family == "gnn":
        if kind in ("full_train", "molecule_train") or (
            kind == "sampled_train" and spec.arch_id != "graphsage-reddit"
        ):
            ax = {
                "features": ("nodes", None),
                "src": ("edges",),
                "dst": ("edges",),
                "edge_mask": ("edges",),
                "labels": ("nodes",),
                "train_mask": ("nodes",),
                "positions": ("nodes", None),
                "graph_ids": ("nodes",),
                "graph_labels": ("batch",),
            }
            return ax
        if kind == "sampled_train":  # graphsage layered minibatch
            return {
                "feat_table": ("table_rows", None),
                "hop0": ("batch",),
                "hop1": ("batch", None),
                "hop2": ("batch", None, None),
                "hop1_mask": ("batch", None),
                "hop2_mask": ("batch", None, None),
                "labels": ("batch",),
            }
        raise ValueError(kind)
    if family == "recsys":
        if kind in ("train", "serve"):
            return {
                "user_id": ("batch",),
                "hist": ("batch", None),
                "hist_mask": ("batch", None),
                "item_id": ("batch",),
                "logq": ("batch",),
            }
        if kind == "retrieval":
            return {
                "user_id": ("batch",),
                "hist": ("batch", None),
                "hist_mask": ("batch", None),
                "cand_ids": ("cand",),
            }
        raise ValueError(kind)
    if family == "densest":
        return {
            "src": ("edges",),
            "dst": ("edges",),
            "weight": ("edges",),
            "mask": ("edges",),
        }
    raise ValueError(family)
