"""Launch layer: production mesh, multi-pod dry-run, roofline analysis,
training/serving drivers.

``dryrun.py`` must be run as its own process (it force-creates 512 host
devices before any jax import side effects); everything else here is
device-count agnostic.
"""
