"""Roofline terms for TPU v5e from a compiled dry-run artifact.

    compute_s    = HLO_FLOPs    / (chips x 197e12 FLOP/s bf16)
    memory_s     = HLO_bytes    / (chips x 819e9  B/s HBM)
    collective_s = coll_bytes   / (chips x 50e9   B/s per ICI link)

HLO quantities come from ``hlo_stats.analyze`` on the SPMD-partitioned
module (per-device shapes), so chips cancels: term = per_device_qty / rate.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

PEAK_FLOPS_BF16 = 197e12  # per chip, TPU v5e
HBM_BW = 819e9  # B/s per chip
ICI_BW = 50e9  # B/s per link (effective, one direction)
HBM_PER_CHIP = 16 * 1024**3  # v5e: 16 GiB


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    n_devices: int
    # per-device quantities from the partitioned HLO
    flops_per_dev: float
    hbm_bytes_per_dev: float
    coll_bytes_per_dev: float
    # analytic
    model_flops_total: float
    # xla's own numbers, for cross-checking
    xla_flops: Optional[float] = None
    xla_bytes: Optional[float] = None
    peak_memory_per_dev: Optional[float] = None
    by_collective: Optional[Dict[str, float]] = None
    notes: str = ""

    @property
    def compute_s(self) -> float:
        return self.flops_per_dev / PEAK_FLOPS_BF16

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes_per_dev / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.coll_bytes_per_dev / ICI_BW

    @property
    def bound(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """No-overlap upper bound is sum; perfect-overlap bound is max.
        We report max (the roofline) and track the sum separately."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def model_flops_ratio(self) -> float:
        """useful / compiled compute (catches remat & padding waste)."""
        hw = self.flops_per_dev * self.n_devices
        return self.model_flops_total / hw if hw else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the chip's peak the step achieves at the bound:
        (useful model FLOPs / chips / peak) / step_time."""
        if self.step_time_s == 0:
            return 0.0
        useful_s = self.model_flops_total / self.n_devices / PEAK_FLOPS_BF16
        return useful_s / self.step_time_s

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d.update(
            compute_s=self.compute_s,
            memory_s=self.memory_s,
            collective_s=self.collective_s,
            bound=self.bound,
            step_time_s=self.step_time_s,
            model_flops_ratio=self.model_flops_ratio,
            roofline_fraction=self.roofline_fraction,
        )
        return d


def from_stats(
    arch: str,
    shape: str,
    mesh: str,
    n_devices: int,
    hlo_stats: Dict[str, float],
    model_flops: float,
    xla_cost: Optional[Dict[str, float]] = None,
    peak_memory: Optional[float] = None,
    notes: str = "",
) -> Roofline:
    return Roofline(
        arch=arch,
        shape=shape,
        mesh=mesh,
        n_devices=n_devices,
        flops_per_dev=hlo_stats["flops"],
        hbm_bytes_per_dev=hlo_stats["hbm_bytes"],
        coll_bytes_per_dev=hlo_stats["collective_bytes"],
        model_flops_total=model_flops,
        xla_flops=(xla_cost or {}).get("flops"),
        xla_bytes=(xla_cost or {}).get("bytes accessed"),
        peak_memory_per_dev=peak_memory,
        by_collective=hlo_stats.get("by_collective"),
        notes=notes,
    )


def fmt_row(r: Roofline) -> str:
    return (
        f"| {r.arch} | {r.shape} | {r.mesh} | "
        f"{r.compute_s*1e3:.2f} | {r.memory_s*1e3:.2f} | {r.collective_s*1e3:.2f} | "
        f"{r.bound} | {r.model_flops_ratio:.2f} | {r.roofline_fraction:.2%} |"
    )
