"""Per-family step builders: loss functions, train_step (fwd+bwd+AdamW), and
serve steps.  Used identically by smoke tests (reduced configs, 1 device),
the real CPU training examples, and the multi-pod dry-run (full configs,
ShapeDtypeStructs)."""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ArchSpec
from repro.optim import AdamWConfig, AdamWState, apply_updates


# ---------------------------------------------------------------------------
# Loss dispatch
# ---------------------------------------------------------------------------


def make_loss_fn(spec: ArchSpec, shape_kind: str, cfg=None) -> Callable:
    """Returns loss(params, batch) -> (scalar, metrics) for an (arch, shape)."""
    cfg = cfg if cfg is not None else spec.config
    family, arch = spec.family, spec.arch_id
    if family == "lm":
        from repro.models.transformer import lm_loss

        return partial(lm_loss, cfg=cfg)

    if family == "gnn":
        if arch == "graphsage-reddit":
            from repro.models.gnn import graphsage as m

            table = {
                "full_train": m.loss_full,
                "sampled_train": m.loss_sampled,
                "molecule_train": m.loss_pooled,
            }
            return partial(table[shape_kind], cfg=cfg)
        mods = {
            "mace": "repro.models.gnn.mace",
            "egnn": "repro.models.gnn.egnn",
            "equiformer-v2": "repro.models.gnn.equiformer_v2",
        }
        import importlib

        m = importlib.import_module(mods[arch])
        if shape_kind == "molecule_train":
            return partial(m.loss_energy, cfg=cfg)
        return partial(m.loss_node_class, cfg=cfg)

    if family == "recsys":
        from repro.models import recsys as m

        return partial(m.loss_in_batch_softmax, cfg=cfg)

    raise ValueError(family)


def init_model_params(spec: ArchSpec, key, cfg=None):
    cfg = cfg if cfg is not None else spec.config
    if spec.family == "lm":
        from repro.models.transformer import init_params

        return init_params(key, cfg)
    if spec.family == "gnn":
        import importlib

        mod = {
            "graphsage-reddit": "repro.models.gnn.graphsage",
            "mace": "repro.models.gnn.mace",
            "egnn": "repro.models.gnn.egnn",
            "equiformer-v2": "repro.models.gnn.equiformer_v2",
        }[spec.arch_id]
        return importlib.import_module(mod).init_params(key, cfg)
    if spec.family == "recsys":
        from repro.models.recsys import init_params

        return init_params(key, cfg)
    raise ValueError(spec.family)


def specialize_gnn_config(cfg, shape_params) -> Any:
    """GNN configs carry d_in/n_classes that depend on the shape's dataset."""
    reps = {}
    if "d_feat" in shape_params:
        reps["d_in"] = shape_params["d_feat"]
    if hasattr(cfg, "n_classes"):
        reps["n_classes"] = shape_params.get("n_classes", 0)
    return dataclasses.replace(cfg, **reps)


# ---------------------------------------------------------------------------
# Train / serve steps
# ---------------------------------------------------------------------------


def make_train_step(loss_fn: Callable, opt_cfg: AdamWConfig):
    """(params, opt_state, batch) -> (params', opt_state', metrics)."""

    def train_step(params, opt_state: AdamWState, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch=batch
        )
        params, opt_state, om = apply_updates(params, grads, opt_state, opt_cfg)
        return params, opt_state, {**metrics, **om}

    return train_step


def make_lm_prefill(cfg):
    from repro.models.transformer import prefill

    def step(params, batch):
        logits, cache, cur_len = prefill(params, cfg, batch["tokens"])
        return {"logits": logits, "cache": cache, "cur_len": cur_len}

    return step


def make_lm_decode(cfg):
    from repro.models.transformer import decode_step

    def step(params, cache, batch, cur_len):
        logits, cache, cur_len = decode_step(params, cfg, cache, batch["tokens"], cur_len)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return {"logits": logits, "next": next_tok}, cache, cur_len

    return step


def make_recsys_serve(cfg):
    from repro.models.recsys import serve_scores

    def step(params, batch):
        return serve_scores(params, cfg, batch)

    return step


def make_recsys_retrieval(cfg, k: int = 100):
    from repro.models.recsys import retrieval_topk

    def step(params, batch):
        scores, idx = retrieval_topk(params, cfg, batch, k=k)
        return {"scores": scores, "indices": idx}

    return step
