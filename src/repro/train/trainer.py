"""Fault-tolerant training driver.

Production behaviors (single-process simulations of the multi-host design,
see DESIGN.md §4):

  * checkpoint/restart: restores the latest complete checkpoint (params,
    optimizer, data cursor, rng) and continues at step+1; the data pipeline
    cursor is part of the checkpoint so restart re-reads no batch twice;
  * preemption: SIGTERM/SIGINT trigger a final synchronous save before exit
    (simulating maintenance-event grace windows);
  * step watchdog: a wall-clock budget per step — a hung collective on real
    hardware surfaces as a timeout, and the driver aborts so the scheduler
    can restart from the checkpoint (here: raises StepTimeout);
  * metrics: loss/grad-norm/throughput appended to a jsonl log (the
    observability hook a fleet scheduler scrapes).
"""

from __future__ import annotations

import dataclasses
import json
import os
import signal
import time
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager, restore_latest


class StepTimeout(RuntimeError):
    pass


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int
    ckpt_dir: str
    ckpt_every: int = 100
    keep: int = 3
    log_every: int = 10
    step_timeout_s: Optional[float] = None  # watchdog budget
    metrics_path: Optional[str] = None


class Trainer:
    """Drives ``state, metrics = step_fn(state, batch)`` with restart safety.

    ``state`` is any pytree (params+opt); ``data`` must expose
    ``checkpoint_state() -> dict`` / ``restore(dict)`` and ``__next__``.
    """

    def __init__(
        self,
        cfg: TrainerConfig,
        step_fn: Callable[[Any, Any], tuple],
        init_state: Any,
        data: Iterator,
    ):
        self.cfg = cfg
        self.step_fn = step_fn
        self.state = init_state
        self.data = data
        self.ckpt = CheckpointManager(cfg.ckpt_dir, keep=cfg.keep)
        self.step = 0
        self._preempted = False
        self.metrics_log: list = []

    # ---- fault-tolerance hooks ----

    def _install_signal_handlers(self):
        def handler(signum, frame):
            self._preempted = True

        for sig in (signal.SIGTERM, signal.SIGINT):
            try:
                signal.signal(sig, handler)
            except ValueError:
                pass  # non-main thread (tests)

    def try_restore(self) -> bool:
        state, meta, step = restore_latest(self.cfg.ckpt_dir, self.state)
        if step < 0:
            return False
        self.state = state
        # Checkpoints store the NEXT step to execute (uniform for mid-run,
        # watchdog, preemption and final saves).
        self.step = int(meta.get("step", step))
        if meta.get("data") is not None and hasattr(self.data, "restore"):
            self.data.restore(meta["data"])
        return True

    def _metadata(self) -> Dict[str, Any]:
        data_state = (
            self.data.checkpoint_state()
            if hasattr(self.data, "checkpoint_state")
            else None
        )
        return {"step": self.step, "data": data_state}  # step == next step

    def save(self, sync: bool = True):
        if sync:
            self.ckpt.save(self.step, self.state, self._metadata())
        else:
            self.ckpt.save_async(self.step, self.state, self._metadata())

    # ---- main loop ----

    def run(self) -> Dict[str, Any]:
        self._install_signal_handlers()
        cfg = self.cfg
        t_start = time.time()
        last_metrics: Dict[str, Any] = {}
        while self.step < cfg.total_steps:
            if self._preempted:
                self.ckpt.join()
                self.save(sync=True)
                return {"status": "preempted", "step": self.step, **last_metrics}
            batch = next(self.data)
            t0 = time.time()
            out = self.step_fn(self.state, batch)
            self.state, metrics = out[0], out[1]
            # Block for the watchdog measurement.
            metrics = {
                k: float(np.asarray(jax.device_get(v)))
                for k, v in metrics.items()
                if np.ndim(v) == 0
            }
            dt = time.time() - t0
            metrics["step_time_s"] = dt
            last_metrics = metrics
            executed = self.step
            self.step += 1  # from here on, self.step == next step to run
            if cfg.step_timeout_s is not None and dt > cfg.step_timeout_s:
                # A hung/straggling step: checkpoint and abort so the
                # scheduler can reschedule (restartability > in-place retry).
                self.ckpt.join()
                self.save(sync=True)
                raise StepTimeout(f"step {executed} took {dt:.1f}s")
            if cfg.metrics_path and executed % cfg.log_every == 0:
                os.makedirs(os.path.dirname(cfg.metrics_path) or ".", exist_ok=True)
                # repro: allow(atomic-io) append-only JSONL metrics log; readers tolerate a torn final line
                with open(cfg.metrics_path, "a") as f:
                    f.write(json.dumps({"step": executed, **metrics}) + "\n")
            self.metrics_log.append({"step": executed, **metrics})
            if cfg.ckpt_every and self.step % cfg.ckpt_every == 0:
                self.save(sync=False)
        self.ckpt.join()
        self.save(sync=True)
        return {
            "status": "done",
            "step": self.step,
            "wall_s": time.time() - t_start,
            **last_metrics,
        }
