"""The ONE surface for pow2 bucket floors and ladder capacities.

Every power-of-two padding floor and geometric-ladder capacity in the
runtime lives here, imported (or aliased) by the module that uses it —
never re-typed as a bare literal at a call site.  The ``pow2-constants``
analysis rule (src/repro/analysis/rules/pow2_constants.py, run by
``scripts/analyze.py``) enforces both directions mechanically:

  * a literal ``floor=``/capacity argument to
    :func:`repro.graph.partition.pow2_bucket` or
    :func:`repro.graph.partition.ladder_schedule` is a finding — pass a
    name defined here instead;
  * a module-level ``*_FLOOR`` / ``*_MIN_EDGES`` / ``*_MIN_NODES`` /
    ``*_STRIDE`` assignment with a literal value anywhere else under
    ``src/repro`` is a finding — aliases (``_X = constants.X``) are fine
    and keep monkeypatch-ability (tests patch ``api._LADDER_MIN_EDGES``).

Why one surface: these values couple compiled-program cache keys (every
distinct bucket shape is one compilation) to scheduling depth (every
floor bounds a ladder).  A re-typed copy that drifts from its sibling
silently doubles the compile population or unbalances a ladder — the
class of bug PR 3 and PR 5 each caught by hand in review.

No jax imports here: this module must stay importable everywhere
(including the jax-free static-analysis tooling).
"""

from __future__ import annotations

# --- host (jit-substrate) geometric compaction ladder (core/api.py) --------
# Survivors gather into the next power-of-two buffer; the floors bound the
# ladder depth and keep the smallest compiled programs non-degenerate.
COMPACT_MIN_EDGES = 256
COMPACT_MIN_NODES = 128
# Runaway guard on ladder depth; real ladders are O(log m) segments.
COMPACT_MAX_SEGMENTS = 64

# --- single-program mesh ladder (core/api.py, §5.2) -------------------------
# Rung capacities shrink by this factor.  4 is the measured sweet spot on
# the tracked benchmark — halving rungs doubles the compaction-collective
# count for edge-slot savings the pass cost no longer dominates
# (benchmarks/bench_peel_compaction.py).
LADDER_STRIDE = 4
# Bucket floor: below this many (global) edge slots a pass is trivial, but
# every extra rung still pays its fixed while-loop/compaction cost inside
# the program, so the mesh ladder stops coarser than the host schedule's
# COMPACT_MIN_EDGES.
LADDER_MIN_EDGES = 4096

# --- streaming compaction rebuild (core/streaming.py) -----------------------
# Pow2-padded node space of a rebuilt survivor stream (with one
# permanently-dead pad node), so the jitted chunk kernel sees O(log n)
# distinct degree-vector shapes across the whole ladder.
STREAM_REBUILD_NODE_FLOOR = 64
# Per-chunk pow2 slot capacity of a rebuilt (ragged) chunk, so surviving
# chunks land on a bounded set of shapes instead of one compile per chunk.
STREAM_REBUILD_CHUNK_FLOOR = 256

# --- serving ego-net buckets (serve/densest.py) -----------------------------
# Extracted ego-nets pad into pow2 (node, edge) buckets so the whole query
# population shares a handful of vmapped programs (docs/serving.md).
SERVE_NODE_FLOOR = 64
SERVE_EDGE_FLOOR = 256

# --- local (Andersen) substrate (core/local.py, serve/densest.py) -----------
# Default candidate-set size cap of the pruned-frontier exploration: per-query
# work is bounded by the budget (times the candidate volume), independent of n.
LOCAL_BUDGET = 512
# Expansion-round cap (each round scans only the newly admitted rows).
LOCAL_ROUNDS = 8
# Degrade-ladder floor: the serving engine's budget-halving fallback rung
# stops here (a smaller candidate set answers nothing a BFS rung would).
LOCAL_BUDGET_FLOOR = 64
# Work (volume) cap factor: one exploration scans at most
# budget * LOCAL_VOLUME_FACTOR CSR slots, applied at ADMISSION (a frontier
# vertex whose row does not fit in the remaining work budget is not
# admitted), so per-query work is bounded by construction even when a
# power-law hub sits next to the seed — the property BENCH_serve.json's
# local_vs_bfs_sweep holds flat across graph sizes.
LOCAL_VOLUME_FACTOR = 32

# --- turnstile runtime (core/turnstile.py) ----------------------------------
# IBLT cell count floor per level (pow2 of the sample budget tau) and the
# compact pow2 buckets the recovered sample is peeled in.
TURNSTILE_SAMPLE_EDGE_FLOOR = 256
TURNSTILE_SAMPLE_NODE_FLOOR = 256
# Update batches pad to pow2 with this floor: one donated update program
# per bucket, a handful of buckets total.
TURNSTILE_BATCH_FLOOR = 1024
