"""repro: densest subgraph in streaming and MapReduce, as a production JAX framework."""

__version__ = "1.0.0"
