"""Synthetic, deterministic, shard-aware input pipelines.

Two views of every batch:
  * ``abstract_batch``: jax.ShapeDtypeStruct stand-ins (weak-type-correct,
    shardable, no allocation) — what the multi-pod dry-run lowers against;
  * ``make_batch``: concrete arrays (small shapes only) for smoke tests,
    examples and real CPU training runs.

Batch layouts per family are documented next to their builders.
"""

from __future__ import annotations

from typing import Any, Dict, Mapping, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchSpec, ShapeSpec

f32 = jnp.float32
i32 = jnp.int32


def _sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(int(s) for s in shape), dtype)


# ---------------------------------------------------------------------------
# LM family
# ---------------------------------------------------------------------------


def lm_train_batch_spec(vocab: int, batch: int, seq: int) -> Dict[str, Any]:
    del vocab
    return {
        "tokens": _sds((batch, seq), i32),
        "labels": _sds((batch, seq), i32),
    }


def lm_train_batch(rng: np.random.Generator, vocab: int, batch: int, seq: int):
    toks = rng.integers(0, vocab, size=(batch, seq + 1), dtype=np.int32)
    return {
        "tokens": jnp.asarray(toks[:, :-1]),
        "labels": jnp.asarray(toks[:, 1:]),
    }


def lm_prefill_spec(batch: int, seq: int) -> Dict[str, Any]:
    return {"tokens": _sds((batch, seq), i32)}


# ---------------------------------------------------------------------------
# GNN family
# ---------------------------------------------------------------------------


def gnn_full_batch_spec(
    n_nodes: int, n_edges: int, d_feat: int, n_classes: int, with_positions: bool,
    pad_to: int = 512,
) -> Dict[str, Any]:
    """Node/edge counts are padded to a multiple of 512 so the node dim can
    shard over every mesh axis (single-pod 256, multi-pod 512); padding
    nodes are isolated (edge_mask False, train_mask 0) so the models'
    segment ops ignore them."""
    n_nodes = ((n_nodes + pad_to - 1) // pad_to) * pad_to
    n_edges = ((n_edges + pad_to - 1) // pad_to) * pad_to
    b = {
        "features": _sds((n_nodes, d_feat), f32),
        "src": _sds((n_edges,), i32),
        "dst": _sds((n_edges,), i32),
        "edge_mask": _sds((n_edges,), jnp.bool_),
        "labels": _sds((n_nodes,), i32),
        "train_mask": _sds((n_nodes,), f32),
    }
    if with_positions:
        b["positions"] = _sds((n_nodes, 3), f32)
    return b


def gnn_full_batch(
    rng: np.random.Generator,
    n_nodes: int,
    n_edges: int,
    d_feat: int,
    n_classes: int,
    with_positions: bool,
):
    src = rng.integers(0, n_nodes, n_edges, dtype=np.int32)
    dst = rng.integers(0, n_nodes, n_edges, dtype=np.int32)
    b = {
        "features": jnp.asarray(rng.standard_normal((n_nodes, d_feat), dtype=np.float32)),
        "src": jnp.asarray(src),
        "dst": jnp.asarray(dst),
        "edge_mask": jnp.asarray(src != dst),
        "labels": jnp.asarray(rng.integers(0, max(n_classes, 2), n_nodes, dtype=np.int32)),
        "train_mask": jnp.asarray((rng.random(n_nodes) < 0.5).astype(np.float32)),
    }
    if with_positions:
        b["positions"] = jnp.asarray(
            rng.standard_normal((n_nodes, 3), dtype=np.float32) * 2.0
        )
    return b


def gnn_molecule_batch_spec(
    batch: int, nodes_per: int, edges_per: int, d_feat: int, with_positions: bool
) -> Dict[str, Any]:
    n = batch * nodes_per
    e = batch * edges_per
    b = {
        "features": _sds((n, d_feat), f32),
        "src": _sds((e,), i32),
        "dst": _sds((e,), i32),
        "edge_mask": _sds((e,), jnp.bool_),
        "graph_ids": _sds((n,), i32),
        "graph_labels": _sds((batch,), f32),
    }
    if with_positions:
        b["positions"] = _sds((n, 3), f32)
    return b


def gnn_molecule_batch(
    rng: np.random.Generator,
    batch: int,
    nodes_per: int,
    edges_per: int,
    d_feat: int,
    with_positions: bool,
):
    n = batch * nodes_per
    e = batch * edges_per
    # Edges stay inside each molecule's node block.
    graph_of_edge = np.repeat(np.arange(batch), edges_per)
    src = (
        rng.integers(0, nodes_per, e) + graph_of_edge * nodes_per
    ).astype(np.int32)
    dst = (
        rng.integers(0, nodes_per, e) + graph_of_edge * nodes_per
    ).astype(np.int32)
    b = {
        "features": jnp.asarray(rng.standard_normal((n, d_feat), dtype=np.float32)),
        "src": jnp.asarray(src),
        "dst": jnp.asarray(dst),
        "edge_mask": jnp.asarray(src != dst),
        "graph_ids": jnp.asarray(np.repeat(np.arange(batch), nodes_per).astype(np.int32)),
        "graph_labels": jnp.asarray(rng.standard_normal(batch).astype(np.float32)),
    }
    if with_positions:
        b["positions"] = jnp.asarray(rng.standard_normal((n, 3), dtype=np.float32) * 2.0)
    return b


def sage_minibatch_spec(
    n_nodes: int, d_feat: int, roots: int, fanout: Tuple[int, int]
) -> Dict[str, Any]:
    f1, f2 = fanout
    return {
        "feat_table": _sds((n_nodes, d_feat), f32),
        "hop0": _sds((roots,), i32),
        "hop1": _sds((roots, f1), i32),
        "hop2": _sds((roots, f1, f2), i32),
        "hop1_mask": _sds((roots, f1), f32),
        "hop2_mask": _sds((roots, f1, f2), f32),
        "labels": _sds((roots,), i32),
    }


def subgraph_minibatch_spec(
    n_table: int, d_feat: int, roots: int, fanout: Tuple[int, int], with_positions: bool
) -> Dict[str, Any]:
    """Sampled-subgraph block for non-SAGE GNNs on minibatch_lg: the layered
    neighborhood flattened into one padded edge list."""
    f1, f2 = fanout
    n = roots * (1 + f1 + f1 * f2)
    e = roots * f1 + roots * f1 * f2
    b = {
        "features": _sds((n, d_feat), f32),
        "src": _sds((e,), i32),
        "dst": _sds((e,), i32),
        "edge_mask": _sds((e,), jnp.bool_),
        "labels": _sds((n,), i32),
        "train_mask": _sds((n,), f32),  # 1.0 on the root nodes
    }
    if with_positions:
        b["positions"] = _sds((n, 3), f32)
    return b


# ---------------------------------------------------------------------------
# RecSys family
# ---------------------------------------------------------------------------


def recsys_train_spec(batch: int, hist: int) -> Dict[str, Any]:
    return {
        "user_id": _sds((batch,), i32),
        "hist": _sds((batch, hist), i32),
        "hist_mask": _sds((batch, hist), f32),
        "item_id": _sds((batch,), i32),
        "logq": _sds((batch,), f32),
    }


def recsys_train_batch(rng, cfg, batch: int):
    h = cfg.hist_len
    # Zipf-ish item popularity for a realistic logQ correction.
    ranks = rng.integers(1, cfg.n_items, size=(batch,))
    q = 1.0 / (np.asarray(ranks, np.float64) ** 0.9)
    return {
        "user_id": jnp.asarray(rng.integers(0, cfg.n_users, batch, dtype=np.int32)),
        "hist": jnp.asarray(rng.integers(0, cfg.n_items, (batch, h), dtype=np.int32)),
        "hist_mask": jnp.asarray((rng.random((batch, h)) < 0.7).astype(np.float32)),
        "item_id": jnp.asarray(rng.integers(0, cfg.n_items, batch, dtype=np.int32)),
        "logq": jnp.asarray(np.log(q / q.sum()).astype(np.float32)),
    }


def recsys_retrieval_spec(n_candidates: int, hist: int) -> Dict[str, Any]:
    return {
        "user_id": _sds((1,), i32),
        "hist": _sds((1, hist), i32),
        "hist_mask": _sds((1, hist), f32),
        "cand_ids": _sds((n_candidates,), i32),
    }


# ---------------------------------------------------------------------------
# Densest-subgraph (the paper's workload)
# ---------------------------------------------------------------------------


def densest_spec(n_nodes: int, n_edges: int) -> Dict[str, Any]:
    return {
        "src": _sds((n_edges,), i32),
        "dst": _sds((n_edges,), i32),
        "weight": _sds((n_edges,), f32),
        "mask": _sds((n_edges,), jnp.bool_),
    }


# ---------------------------------------------------------------------------
# Unified per-(arch, shape) entry points
# ---------------------------------------------------------------------------

_GEOMETRIC = {"mace", "egnn", "equiformer-v2"}


def _gnn_needs_positions(arch_id: str) -> bool:
    return arch_id in _GEOMETRIC


def abstract_batch(spec: ArchSpec, shape: ShapeSpec) -> Dict[str, Any]:
    """ShapeDtypeStruct inputs for one dry-run cell (model inputs only —
    params/caches/opt state are built separately)."""
    p = dict(shape.params)
    if spec.family == "lm":
        cfg = spec.config
        if shape.kind == "train":
            return lm_train_batch_spec(cfg.vocab, p["global_batch"], p["seq_len"])
        if shape.kind == "prefill":
            return lm_prefill_spec(p["global_batch"], p["seq_len"])
        if shape.kind in ("decode", "decode_long"):
            return {"tokens": _sds((p["global_batch"], 1), i32)}
        raise ValueError(shape.kind)
    if spec.family == "gnn":
        pos = _gnn_needs_positions(spec.arch_id)
        if shape.kind == "full_train":
            return gnn_full_batch_spec(
                p["n_nodes"], p["n_edges"], p["d_feat"], p["n_classes"], pos
            )
        if shape.kind == "sampled_train":
            if spec.arch_id == "graphsage-reddit":
                return sage_minibatch_spec(
                    p["n_nodes"], p["d_feat"], p["batch_nodes"], tuple(p["fanout"])
                )
            return subgraph_minibatch_spec(
                p["n_nodes"], p["d_feat"], p["batch_nodes"], tuple(p["fanout"]), pos
            )
        if shape.kind == "molecule_train":
            return gnn_molecule_batch_spec(
                p["batch"], p["n_nodes"], p["n_edges"], p["d_feat"], pos
            )
        raise ValueError(shape.kind)
    if spec.family == "recsys":
        cfg = spec.config
        if shape.kind in ("train", "serve"):
            return recsys_train_spec(p["batch"], cfg.hist_len)
        if shape.kind == "retrieval":
            return recsys_retrieval_spec(p["n_candidates"], cfg.hist_len)
        raise ValueError(shape.kind)
    if spec.family == "densest":
        return densest_spec(p["n_nodes"], p["n_edges"])
    raise ValueError(spec.family)


def make_batch(
    spec: ArchSpec, shape_kind: str, *, reduced_shape: Mapping[str, Any], seed: int = 0
) -> Dict[str, Any]:
    """Concrete batch for smoke tests: same layout, reduced sizes."""
    rng = np.random.default_rng(seed)
    p = dict(reduced_shape)
    if spec.family == "lm":
        cfg = spec.reduced_config
        if shape_kind == "train":
            return lm_train_batch(rng, cfg.vocab, p["global_batch"], p["seq_len"])
        if shape_kind == "prefill":
            t = rng.integers(0, cfg.vocab, (p["global_batch"], p["seq_len"]), dtype=np.int32)
            return {"tokens": jnp.asarray(t)}
        raise ValueError(shape_kind)
    if spec.family == "gnn":
        pos = _gnn_needs_positions(spec.arch_id)
        if shape_kind == "full_train":
            return gnn_full_batch(
                rng, p["n_nodes"], p["n_edges"], p["d_feat"], p["n_classes"], pos
            )
        if shape_kind == "molecule_train":
            return gnn_molecule_batch(
                rng, p["batch"], p["n_nodes"], p["n_edges"], p["d_feat"], pos
            )
        raise ValueError(shape_kind)
    if spec.family == "recsys":
        return recsys_train_batch(rng, spec.reduced_config, p["batch"])
    raise ValueError(spec.family)
