"""Deterministic, resumable, shard-aware input pipelines.

Every pipeline keys batch generation off (seed, step) — not off mutable
iterator state — so:
  * restart at step k reproduces exactly the batch stream from step k
    (checkpoint stores only the integer cursor);
  * multi-host sharding is a pure function of (step, host_id): each host
    materializes only its slice (here: the full batch, single process);
  * straggler re-issue is trivial: any worker can regenerate any batch.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass
class SyntheticStream:
    """Resumable iterator over make_fn(rng, step) batches."""

    make_fn: Callable[[np.random.Generator, int], Any]
    seed: int = 0
    step: int = 0

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        rng = np.random.default_rng((self.seed << 20) ^ self.step)
        batch = self.make_fn(rng, self.step)
        self.step += 1
        return batch

    def checkpoint_state(self) -> Dict[str, int]:
        return {"seed": self.seed, "step": self.step}

    def restore(self, state: Dict[str, int]):
        self.seed = int(state["seed"])
        self.step = int(state["step"])


def lm_token_stream(vocab: int, batch: int, seq: int, seed: int = 0) -> SyntheticStream:
    """Deterministic Zipfian token stream (power-law unigram, like text)."""
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    probs = 1.0 / ranks**1.1
    probs /= probs.sum()
    cum = np.cumsum(probs)

    def make(rng: np.random.Generator, step: int):
        import jax.numpy as jnp

        u = rng.random((batch, seq + 1))
        toks = np.searchsorted(cum, u).astype(np.int32)
        return {
            "tokens": jnp.asarray(toks[:, :-1]),
            "labels": jnp.asarray(toks[:, 1:]),
        }

    return SyntheticStream(make, seed=seed)


def recsys_stream(cfg, batch: int, seed: int = 0) -> SyntheticStream:
    from repro.data.synthetic import recsys_train_batch

    def make(rng, step):
        return recsys_train_batch(rng, cfg, batch)

    return SyntheticStream(make, seed=seed)


def edge_chunk_stream(
    src: np.ndarray, dst: np.ndarray, chunk: int, weight: Optional[np.ndarray] = None
):
    """Multi-pass edge stream for the semi-streaming driver: yields
    (src, dst, w) chunks; the SAME chunk boundaries every pass (stable ids
    for straggler re-issue and per-chunk checksums)."""
    e = len(src)
    if weight is None:
        weight = np.ones(e, np.float32)
    for s in range(0, e, chunk):
        yield s // chunk, (
            src[s : s + chunk],
            dst[s : s + chunk],
            weight[s : s + chunk],
        )
