"""Version compatibility shims for jax APIs that moved between releases.

``shard_map`` graduated from ``jax.experimental.shard_map`` to the top-level
``jax`` namespace (and its replication-check kwarg was renamed from
``check_rep`` to ``check_vma``) across jax releases.  Every module in this
repo imports it from here so the whole tree works on either side of the
move:

    from repro.compat import shard_map

The wrapper accepts both ``check_vma`` and ``check_rep`` and forwards
whichever spelling the installed jax understands.
"""

from __future__ import annotations

import inspect
from typing import Any, Callable

try:  # jax >= 0.6: top-level export
    from jax import shard_map as _shard_map_impl  # type: ignore[attr-defined]
except ImportError:  # older jax: experimental namespace
    from jax.experimental.shard_map import shard_map as _shard_map_impl

_IMPL_PARAMS = frozenset(inspect.signature(_shard_map_impl).parameters)


def shard_map(f: Callable[..., Any], **kwargs: Any) -> Callable[..., Any]:
    """``jax.shard_map`` / ``jax.experimental.shard_map.shard_map`` shim.

    Keyword-only usage (``mesh=``, ``in_specs=``, ``out_specs=``, and
    optionally ``check_vma=``/``check_rep=``), which is how every call site
    in this repo invokes it.
    """
    check = kwargs.pop("check_vma", kwargs.pop("check_rep", None))
    if check is not None:
        if "check_vma" in _IMPL_PARAMS:
            kwargs["check_vma"] = check
        elif "check_rep" in _IMPL_PARAMS:
            kwargs["check_rep"] = check
        # else: the installed jax dropped the flag entirely; omit it.
    return _shard_map_impl(f, **kwargs)
