"""Logical-axis sharding: model code names axes ("embed", "heads", ...) and a
per-run rule table maps them to mesh axes.  Changing the mesh (single-pod
16x16, multi-pod 2x16x16, a test 1x1) never touches model code — the
elastic-scaling contract.

Param shardings are derived from path-pattern rules (regex on the pytree
path), activation shardings from ``shard(x, "batch", "seq", "embed")`` calls
that consult an ambient context (no-ops when no mesh is active, so smoke
tests on one device run the same code).
"""

from __future__ import annotations

import contextlib
import re
import threading
from dataclasses import dataclass
from typing import Any, Mapping, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]


# Default logical-axis -> mesh-axis tables.  "batch" spreads over pod+data;
# tensor-parallel dims go to "model".
SINGLE_POD_RULES: dict[str, MeshAxes] = {
    "batch": ("data",),
    "expert_batch": ("data",),
    "model": ("model",),
    "edges": ("data", "model"),
}
MULTI_POD_RULES: dict[str, MeshAxes] = {
    "batch": ("pod", "data"),
    "expert_batch": ("data",),
    "model": ("model",),
    "edges": ("pod", "data", "model"),
}


@dataclass(frozen=True)
class AxisRules:
    """Maps logical axis names to mesh axes."""

    table: Mapping[str, MeshAxes]

    def mesh_axes(self, logical: Optional[str]) -> MeshAxes:
        if logical is None:
            return None
        return self.table.get(logical, None)

    def pspec(self, logical_axes: Sequence[Optional[str]]) -> P:
        used: list[MeshAxes] = []
        seen: set[str] = set()
        for a in logical_axes:
            m = self.mesh_axes(a)
            # A mesh axis may appear at most once in a PartitionSpec.
            if m is None:
                used.append(None)
                continue
            ms = (m,) if isinstance(m, str) else tuple(m)
            ms = tuple(x for x in ms if x not in seen)
            seen.update(ms)
            used.append(ms if ms else None)
        while used and used[-1] is None:
            used.pop()
        return P(*used)


def make_rules(
    logical_to_mesh: Mapping[str, MeshAxes], base: Optional[Mapping[str, MeshAxes]] = None
) -> AxisRules:
    table = dict(base or {})
    table.update(logical_to_mesh)
    return AxisRules(table)


# ---------------------------------------------------------------------------
# Ambient sharding context (mesh + rules) for activation constraints.
# ---------------------------------------------------------------------------


class _Ctx(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.rules: Optional[AxisRules] = None


_CTX = _Ctx()


@contextlib.contextmanager
def sharding_ctx(mesh: Optional[Mesh], rules: Optional[AxisRules]):
    prev = (_CTX.mesh, _CTX.rules)
    _CTX.mesh, _CTX.rules = mesh, rules
    try:
        yield
    finally:
        _CTX.mesh, _CTX.rules = prev


def shard(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """Constrains activation sharding; identity when no context is active."""
    if _CTX.mesh is None or _CTX.rules is None:
        return x
    if len(logical_axes) != x.ndim:
        raise ValueError(
            f"shard(): got {len(logical_axes)} axes for rank-{x.ndim} array"
        )
    spec = _CTX.rules.pspec(logical_axes)
    return jax.lax.with_sharding_constraint(x, NamedSharding(_CTX.mesh, spec))


def current_mesh() -> Optional[Mesh]:
    return _CTX.mesh


def current_rules() -> Optional[AxisRules]:
    return _CTX.rules


# ---------------------------------------------------------------------------
# Param shardings from path-pattern rules.
# ---------------------------------------------------------------------------

ParamRule = Tuple[str, Tuple[Optional[str], ...]]  # (path regex, logical axes)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            parts.append(str(p.idx))
        elif isinstance(p, jax.tree_util.GetAttrKey):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def logical_axes_for_params(params: Any, param_rules: Sequence[ParamRule]) -> Any:
    """Pytree of logical-axis tuples matching ``params`` structure."""

    def assign(path, leaf):
        ps = _path_str(path)
        for pattern, axes in param_rules:
            if re.search(pattern, ps):
                if len(axes) != leaf.ndim:
                    raise ValueError(
                        f"rule {pattern} gives {len(axes)} axes for rank-{leaf.ndim} "
                        f"param at {ps} with shape {leaf.shape}"
                    )
                return tuple(axes)
        return (None,) * leaf.ndim

    return jax.tree_util.tree_map_with_path(assign, params)


def pspecs_for_params(params: Any, param_rules: Sequence[ParamRule], rules: AxisRules):
    axes_tree = logical_axes_for_params(params, param_rules)
    return jax.tree.map(
        lambda a: rules.pspec(a), axes_tree, is_leaf=lambda x: isinstance(x, tuple)
    )


def shardings_for_params(
    params: Any, param_rules: Sequence[ParamRule], rules: AxisRules, mesh: Mesh
):
    specs = pspecs_for_params(params, param_rules, rules)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs)


def divisibility_check(shape_tree: Any, specs: Any, mesh: Mesh) -> list[str]:
    """Returns a list of human-readable problems where a sharded dim is not
    divisible by its mesh-axis product (caught before XLA does)."""
    problems: list[str] = []

    def check(path, leaf, spec):
        for dim, ax in enumerate(spec):
            if ax is None:
                continue
            axs = (ax,) if isinstance(ax, str) else ax
            total = 1
            for a in axs:
                total *= mesh.shape[a]
            if leaf.shape[dim] % total != 0:
                problems.append(
                    f"{_path_str(path)}: dim {dim} ({leaf.shape[dim]}) % {axs}={total}"
                )

    jax.tree_util.tree_map_with_path(
        check, shape_tree, specs, is_leaf=lambda x: hasattr(x, "shape")
    )
    return problems
