"""GPipe-style pipeline parallelism over a mesh axis via shard_map +
collective_permute.

The layer stack is partitioned into ``n_stages`` contiguous stages placed
along one mesh axis (the multi-pod mesh's 'pod' axis: cross-pod links are
the slowest, and pipeline traffic — one activation tensor per microbatch
per boundary — is the lightest cross-cut of the model, which is why PP is
the standard inter-pod axis).  Microbatches stream through stages in the
classic GPipe schedule:

    for t in range(n_micro + n_stages - 1):      # pipeline "ticks"
        each stage processes microbatch (t - stage) if in range
        boundary activations shift stage -> stage+1 via ppermute

Implemented as a ``lax.scan`` over ticks inside ``shard_map``; bubbles are
the (n_stages - 1) / (n_micro + n_stages - 1) idle fraction, reported by
``bubble_fraction`` and validated in tests.  The backward pass is jax AD
through the scan (activations stashed per tick — classic GPipe memory).
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from repro.compat import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    return (n_stages - 1) / (n_micro + n_stages - 1)


def pipelined_apply(
    mesh: Mesh,
    axis: str,
    stage_fn: Callable[[Any, jax.Array], jax.Array],
    stage_params: Any,  # pytree with leading [n_stages] dim, sharded on axis
    x: jax.Array,  # [n_micro, micro_batch, ...] microbatched input
) -> jax.Array:
    """Runs x through n_stages pipeline stages laid out along ``axis``.

    stage_fn(params_for_stage, h) -> h must preserve h's shape (the
    transformer-layer contract); stage s applies layers [s*L/S, (s+1)*L/S).
    Returns [n_micro, micro_batch, ...] outputs (from the LAST stage,
    broadcast to all shards for loss computation).
    """
    n_stages = mesh.shape[axis]

    def local(params, xs):  # params: [1, ...] slice; xs: [n_micro, mb, ...]
        params = jax.tree.map(lambda p: p[0], params)
        stage = jax.lax.axis_index(axis)
        n_micro = xs.shape[0]
        n_ticks = n_micro + n_stages - 1
        mb_shape = xs.shape[1:]

        def tick(carry, t):
            outputs, inbuf = carry
            # Which microbatch this stage works on at tick t.
            mb_idx = t - stage
            active = (mb_idx >= 0) & (mb_idx < n_micro)
            # Stage 0 reads from the input stream, others from inbuf.
            x_in = jnp.where(
                stage == 0,
                xs[jnp.clip(mb_idx, 0, n_micro - 1)],
                inbuf,
            )
            h = stage_fn(params, x_in)
            h = jnp.where(active, h, jnp.zeros_like(h))
            # Last stage writes its result to the output stream.
            outputs = jax.lax.cond(
                active & (stage == n_stages - 1),
                lambda o: o.at[jnp.clip(mb_idx, 0, n_micro - 1)].set(h),
                lambda o: o,
                outputs,
            )
            # Shift boundary activations stage -> stage + 1.
            nxt = jax.lax.ppermute(
                h, axis, [(i, i + 1) for i in range(n_stages - 1)]
            )
            return (outputs, nxt), None

        outputs = jnp.zeros((n_micro,) + mb_shape, xs.dtype)
        inbuf = jnp.zeros(mb_shape, xs.dtype)
        (outputs, _), _ = jax.lax.scan(
            tick, (outputs, inbuf), jnp.arange(n_ticks)
        )
        # Broadcast final outputs from the last stage to every shard
        # (masked psum — ppermute needs unique destinations).
        outputs = jnp.where(stage == n_stages - 1, outputs, jnp.zeros_like(outputs))
        outputs = jax.lax.psum(outputs, axis)
        return outputs

    other = tuple(a for a in mesh.axis_names if a != axis)
    del other
    return shard_map(
        local,
        mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
        check_vma=False,
    )(stage_params, x)
